"""Layer-1 Pallas kernels: block-wise quantize / dequantize and the fused
4-bit AdamW chunk update.

TPU mapping of the paper's CUDA kernels (DESIGN.md §Hardware-Adaptation):
one normalization block (B=128) = one VMEM tile = one grid step; the
16-entry quantization table is a VMEM-resident constant broadcast to every
grid step via a zero index_map; encode is a vectorized argmin over the
(block, 16) distance matrix (branch-free VPU work, not a scalar binary
search); the fused kernel keeps dequant -> AdamW -> requant inside one
tile so states never round-trip to HBM in f32.

Kernels run with interpret=True: the CPU PJRT plugin cannot execute Mosaic
custom-calls, and interpret-mode lowering inlines the kernel into portable
HLO that the rust runtime executes directly.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

DEFAULT_BLOCK = 128


# --------------------------------------------------------------------------
# Quantize
# --------------------------------------------------------------------------

def _quantize_block_kernel(x_ref, table_ref, codes_ref, scale_ref):
    x = x_ref[...]                       # (block,) VMEM tile
    t = table_ref[...]                   # (K,) broadcast constant
    s = jnp.max(jnp.abs(x))
    safe = jnp.where(s > 0, s, 1.0)
    n = jnp.where(s > 0, x / safe, 0.0)
    d = jnp.abs(n[:, None] - t[None, :])  # (block, K) distance matrix
    codes_ref[...] = jnp.argmin(d, axis=1).astype(jnp.uint8)
    scale_ref[...] = jnp.full((1,), s, dtype=jnp.float32)


def quantize_blockwise(x_flat, table, block: int = DEFAULT_BLOCK):
    """Pallas block-wise quantization of a flat f32 array whose length is a
    multiple of `block`. Returns (codes uint8, scales f32[n/block])."""
    n = x_flat.shape[0]
    assert n % block == 0, "pad to a block multiple before calling"
    grid = n // block
    table = jnp.asarray(table, dtype=jnp.float32)
    k = table.shape[0]
    return pl.pallas_call(
        _quantize_block_kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((k,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.uint8),
            jax.ShapeDtypeStruct((grid,), jnp.float32),
        ],
        interpret=True,
    )(x_flat, table)


# --------------------------------------------------------------------------
# Dequantize
# --------------------------------------------------------------------------

def _dequantize_block_kernel(codes_ref, scale_ref, table_ref, out_ref):
    codes = codes_ref[...]
    t = table_ref[...]
    s = scale_ref[0]
    out_ref[...] = t[codes] * s


def dequantize_blockwise(codes, scales, table, block: int = DEFAULT_BLOCK):
    """Inverse of `quantize_blockwise`."""
    n = codes.shape[0]
    assert n % block == 0
    grid = n // block
    table = jnp.asarray(table, dtype=jnp.float32)
    k = table.shape[0]
    return pl.pallas_call(
        _dequantize_block_kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((k,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(codes, scales, table)


# --------------------------------------------------------------------------
# Fused 4-bit AdamW chunk update (the FSDP-packed / "(fused)" path of the
# paper's Tab. 4): dequantize m,v -> AdamW -> requantize, one VMEM tile at
# a time. Hyperparameters arrive as an 8-vector so the artifact is reusable
# across steps: [lr, beta1, beta2, eps, weight_decay, bc1, bc2, 0] where
# bc1/bc2 are the step-t bias corrections (1 - beta^t), precomputed by the
# rust coordinator.
# --------------------------------------------------------------------------

def _fused_adamw4_kernel(
    w_ref, g_ref, m_codes_ref, m_scale_ref, v_codes_ref, v_scale_ref,
    hyper_ref, m_table_ref, v_table_ref,
    w_out_ref, m_codes_out_ref, m_scale_out_ref, v_codes_out_ref,
    v_scale_out_ref,
):
    w = w_ref[...]
    g = g_ref[...]
    hyper = hyper_ref[...]
    lr, beta1, beta2, eps, wd, bc1, bc2 = (
        hyper[0], hyper[1], hyper[2], hyper[3], hyper[4], hyper[5], hyper[6]
    )
    m_t = m_table_ref[...]
    v_t = v_table_ref[...]

    # Dequantize states (VMEM-resident tiles).
    m = m_t[m_codes_ref[...]] * m_scale_ref[0]
    v = v_t[v_codes_ref[...]] * v_scale_ref[0]

    # AdamW (paper Eq. 1, decoupled weight decay).
    m = beta1 * m + (1.0 - beta1) * g
    v = beta2 * v + (1.0 - beta2) * g * g
    mhat = m / bc1
    vhat = v / bc2
    w_out_ref[...] = w - lr * (mhat / (jnp.sqrt(vhat) + eps) + wd * w)

    # Requantize m (signed table).
    ms = jnp.max(jnp.abs(m))
    ms_safe = jnp.where(ms > 0, ms, 1.0)
    mn = jnp.where(ms > 0, m / ms_safe, 0.0)
    m_codes_out_ref[...] = jnp.argmin(
        jnp.abs(mn[:, None] - m_t[None, :]), axis=1
    ).astype(jnp.uint8)
    m_scale_out_ref[...] = jnp.full((1,), ms, dtype=jnp.float32)

    # Requantize v (unsigned, zero-free linear table).
    vs = jnp.max(jnp.abs(v))
    vs_safe = jnp.where(vs > 0, vs, 1.0)
    vn = jnp.where(vs > 0, v / vs_safe, 0.0)
    v_codes_out_ref[...] = jnp.argmin(
        jnp.abs(vn[:, None] - v_t[None, :]), axis=1
    ).astype(jnp.uint8)
    v_scale_out_ref[...] = jnp.full((1,), vs, dtype=jnp.float32)


@functools.partial(jax.jit, static_argnames=("block",))
def fused_adamw4_chunk(w, g, m_codes, m_scales, v_codes, v_scales, hyper,
                       block: int = DEFAULT_BLOCK):
    """One fused 4-bit AdamW step over a flat chunk (paper's FSDP-packed
    fused path). m uses the signed 4-bit DE table, v the unsigned 4-bit
    linear table (B128 falls out of the grid)."""
    n = w.shape[0]
    assert n % block == 0
    grid = n // block
    m_table = jnp.asarray(ref.build_map("de", 4, True))
    v_table = jnp.asarray(ref.build_map("linear", 4, False))
    km = m_table.shape[0]
    kv = v_table.shape[0]
    blk = lambda: pl.BlockSpec((block,), lambda i: (i,))
    one = lambda: pl.BlockSpec((1,), lambda i: (i,))
    return pl.pallas_call(
        _fused_adamw4_kernel,
        grid=(grid,),
        in_specs=[
            blk(),  # w
            blk(),  # g
            blk(),  # m codes
            one(),  # m scale
            blk(),  # v codes
            one(),  # v scale
            pl.BlockSpec((8,), lambda i: (0,)),   # hyper
            pl.BlockSpec((km,), lambda i: (0,)),  # m table
            pl.BlockSpec((kv,), lambda i: (0,)),  # v table
        ],
        out_specs=[blk(), blk(), one(), blk(), one()],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.uint8),
            jax.ShapeDtypeStruct((grid,), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.uint8),
            jax.ShapeDtypeStruct((grid,), jnp.float32),
        ],
        interpret=True,
    )(w, g, m_codes, m_scales, v_codes, v_scales, hyper, m_table, v_table)
