"""Pure-jnp reference (oracle) for the 4-bit optimizer-state quantizers.

This file is the single source of truth for numerics: the Pallas kernels
(`quant4.py`) are tested against it with hypothesis, and the rust engine is
tested against golden vectors generated from it (`aot.py --golden`). The
constructions mirror the paper (App. E.2, Alg. 4) and the rust module
`rust/src/quant/` exactly:

* Linear mapping:  T(i) = (i+1)/2^b  (zero excluded by construction)
* DE mapping: leading zeros = power-of-ten exponent; fraction bits span
  (0.1, 1); special codes 0 -> 0.0 and 1.0 for the reassigned top code
* DE-0: DE with the zero removed (2^b - 1 codes)
* Block-wise normalization with true division x / scale
* Rank-1 normalization: scale_ij = min(row_max_i, col_max_j)
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp


# --------------------------------------------------------------------------
# Mapping construction (float64, cast to float32 at the end — identical to
# the rust builder in rust/src/quant/mapping.rs).
# --------------------------------------------------------------------------

def _fractions(f_bits: int) -> list:
    n = 1 << f_bits
    step = (1.0 - 0.1) / n
    return [0.5 * ((0.1 + step * k) + (0.1 + step * (k + 1))) for k in range(n)]


def _dynexp_unsigned(bits: int) -> list:
    assert bits >= 2
    vals = [0.0, 1.0]
    for e in range(bits - 1):  # E in [0, b-2]
        f_bits = bits - 1 - e
        scale = 10.0 ** (-e)
        vals.extend(scale * f for f in _fractions(f_bits))
    return vals


def _dynexp_signed(bits: int) -> list:
    assert bits >= 3
    vals = [0.0, 1.0]
    for e in range(bits - 1):  # E in [0, b-2]
        f_bits = bits - 2 - e
        scale = 10.0 ** (-e)
        for f in _fractions(f_bits):
            vals.append(scale * f)
            vals.append(-scale * f)
    return vals


def build_map(kind: str, bits: int, signed: bool) -> np.ndarray:
    """Sorted table of representable values, float32.
    kind in {'linear', 'de', 'de0'}."""
    if kind == "linear":
        if not signed:
            vals = [(i + 1) / (1 << bits) for i in range(1 << bits)]
        else:
            half = 1 << (bits - 1)
            vals = []
            for i in range(half):
                x = (i + 1) / half
                vals.extend([x, -x])
    elif kind in ("de", "de0"):
        vals = _dynexp_signed(bits) if signed else _dynexp_unsigned(bits)
        if kind == "de0":
            vals = [v for v in vals if v != 0.0]
    else:
        raise ValueError(f"unknown map kind {kind!r}")
    vals = sorted(set(vals))
    expected = (1 << bits) - (1 if kind == "de0" else 0)
    assert len(vals) == expected, (kind, bits, signed, len(vals))
    return np.asarray(vals, dtype=np.float32)


# --------------------------------------------------------------------------
# Encode / decode
# --------------------------------------------------------------------------

def encode(n, table) -> jnp.ndarray:
    """argmin_i |n - T(i)| with first-index tie-breaking (jnp.argmin)."""
    n = jnp.asarray(n, dtype=jnp.float32)
    t = jnp.asarray(table, dtype=jnp.float32)
    d = jnp.abs(jnp.expand_dims(n, -1) - t)
    return jnp.argmin(d, axis=-1).astype(jnp.uint8)


def decode(codes, table) -> jnp.ndarray:
    t = jnp.asarray(table, dtype=jnp.float32)
    return t[codes]


# --------------------------------------------------------------------------
# Normalizations
# --------------------------------------------------------------------------

def block_scales(x_flat: jnp.ndarray, block: int) -> jnp.ndarray:
    """Per-block max-magnitude scales; the last block may be partial.
    Returns shape (ceil(n/block),)."""
    n = x_flat.shape[0]
    pad = (-n) % block
    xp = jnp.pad(jnp.abs(x_flat), (0, pad))
    return jnp.max(xp.reshape(-1, block), axis=1)


def quantize_blockwise(x, block: int, table):
    """Returns (codes flat uint8, scales). Normalized with true division;
    zero-scale blocks encode normalized 0."""
    x_flat = jnp.asarray(x, dtype=jnp.float32).reshape(-1)
    scales = block_scales(x_flat, block)
    per_elem = jnp.repeat(scales, block)[: x_flat.shape[0]]
    safe = jnp.where(per_elem > 0, per_elem, 1.0)
    n = jnp.where(per_elem > 0, x_flat / safe, 0.0)
    return encode(n, table), scales


def dequantize_blockwise(codes, scales, block: int, table, n: int):
    per_elem = jnp.repeat(scales, block)[:n]
    return decode(codes, table) * per_elem


def rank1_scales(x2d: jnp.ndarray):
    """Row and column max-magnitude statistics of a 2-D tensor."""
    a = jnp.abs(jnp.asarray(x2d, dtype=jnp.float32))
    return jnp.max(a, axis=1), jnp.max(a, axis=0)


def quantize_rank1(x2d, table):
    """Rank-1 normalization + mapping for a 2-D tensor (paper Alg. 4)."""
    x2d = jnp.asarray(x2d, dtype=jnp.float32)
    r, c = rank1_scales(x2d)
    s = jnp.minimum(r[:, None], c[None, :])
    safe = jnp.where(s > 0, s, 1.0)
    n = jnp.where(s > 0, x2d / safe, 0.0)
    return encode(n, table), r, c


def dequantize_rank1(codes, r, c, table):
    s = jnp.minimum(r[:, None], c[None, :])
    return decode(codes, table) * s


# --------------------------------------------------------------------------
# Reference AdamW (paper Eq. 1 + decoupled weight decay), matching
# rust/src/optim/adamw.rs::adamw_update_tensor.
# --------------------------------------------------------------------------

def adamw_step(w, m, v, g, lr, beta1, beta2, eps, weight_decay, t):
    m = beta1 * m + (1.0 - beta1) * g
    v = beta2 * v + (1.0 - beta2) * g * g
    bc1 = 1.0 - beta1 ** t
    bc2 = 1.0 - beta2 ** t
    mhat = m / bc1
    vhat = v / bc2
    w = w - lr * (mhat / (jnp.sqrt(vhat) + eps) + weight_decay * w)
    return w, m, v


# --------------------------------------------------------------------------
# Reference dense-baseline steps (float32, op-for-op the rust loops in
# rust/src/optim/{sgdm,sm3}.rs). These are the oracle for the golden step
# vectors pinned by rust/tests/golden_parity.rs: numpy's elementwise
# float32 ops round identically to the rust scalar loops, so the match is
# bit-exact as long as the expression nesting mirrors the rust source.
# --------------------------------------------------------------------------

def sgdm_step(w, m, g, lr, beta1, weight_decay):
    """One dense-momentum SGDM step (paper Alg. 2, fp32 state)."""
    m = beta1 * m + g
    w = w - lr * (m + weight_decay * w)
    return w, m


def sm3_step_2d(w, m, mu_row, mu_col, g, lr, beta1, eps, weight_decay):
    """One SM3-II step for a 2-D parameter (cover accumulators)."""
    one = np.float32(1.0)
    nu = np.minimum(mu_row[:, None], mu_col[None, :]) + g * g
    upd = g / (np.sqrt(nu) + eps)
    m = beta1 * m + (one - beta1) * upd
    w = w - lr * (m + weight_decay * w)
    return w, m, nu.max(axis=1), nu.max(axis=0)


def sm3_step_1d(w, m, v, g, lr, beta1, eps, weight_decay):
    """One SM3 step for a 1-D parameter (dense AdaGrad accumulator)."""
    one = np.float32(1.0)
    v = v + g * g
    upd = g / (np.sqrt(v) + eps)
    m = beta1 * m + (one - beta1) * upd
    w = w - lr * (m + weight_decay * w)
    return w, m, v


def fused_adamw4_reference(w, g, m_codes, m_scales, v_codes, v_scales,
                           lr, beta1, beta2, eps, weight_decay, t,
                           block: int, m_table, v_table):
    """One fused 4-bit AdamW step on a flat chunk, entirely via the
    reference quantizers: dequantize states -> AdamW -> requantize.
    Mirrors the Pallas kernel contract in quant4.py."""
    n = w.shape[0]
    m = dequantize_blockwise(m_codes, m_scales, block, m_table, n)
    v = dequantize_blockwise(v_codes, v_scales, block, v_table, n)
    w, m, v = adamw_step(w, m, v, g, lr, beta1, beta2, eps, weight_decay, t)
    m_codes, m_scales = quantize_blockwise(m, block, m_table)
    v_codes, v_scales = quantize_blockwise(v, block, v_table)
    return w, m_codes, m_scales, v_codes, v_scales
