"""AOT entry point: lower the L2 graphs (which embed the L1 Pallas
kernels) to HLO *text* artifacts for the rust PJRT runtime, and emit the
golden parity vectors that pin the rust quantizer to the python oracle.

HLO text — not `.serialize()` — is the interchange format: jax >= 0.5
emits protos with 64-bit instruction ids that xla_extension 0.5.1 rejects;
the text parser reassigns ids (see /opt/xla-example/README.md).

Usage:
    python -m compile.aot --out ../artifacts          # all artifacts
    python -m compile.aot --out ../artifacts --golden # + golden vectors
"""

from __future__ import annotations

import argparse
import json
import os

import numpy as np
import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import quant4, ref

FUSED_CHUNK = 16384  # flat elements per fused-optimizer dispatch
FUSED_BLOCK = 128
TRAIN_BATCH = 8
TRAIN_CONFIGS = {"tiny": model.Config.tiny(), "small": model.Config.small()}


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True so the
    rust side unwraps one tuple)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True: the default printer elides big array
    # literals as `{...}`, which xla_extension 0.5.1's text parser reads
    # back as zeros — silently corrupting e.g. the quantization tables.
    return comp.as_hlo_text(print_large_constants=True)


def lower_train_step(cfg: model.Config, batch: int):
    tokens = jax.ShapeDtypeStruct((batch, cfg.max_seq + 1), jnp.int32)
    params = [
        jax.ShapeDtypeStruct(shape, jnp.float32)
        for _, shape in model.param_specs(cfg)
    ]
    return jax.jit(model.make_train_step(cfg)).lower(tokens, *params)


def lower_eval_loss(cfg: model.Config, batch: int):
    tokens = jax.ShapeDtypeStruct((batch, cfg.max_seq + 1), jnp.int32)
    params = [
        jax.ShapeDtypeStruct(shape, jnp.float32)
        for _, shape in model.param_specs(cfg)
    ]
    return jax.jit(model.make_eval_loss(cfg)).lower(tokens, *params)


def lower_fused_adamw4(n: int):
    f32 = lambda shape: jax.ShapeDtypeStruct(shape, jnp.float32)
    u8 = lambda shape: jax.ShapeDtypeStruct(shape, jnp.uint8)
    grid = n // FUSED_BLOCK

    def fn(w, g, mc, ms, vc, vs, hyper):
        return quant4.fused_adamw4_chunk(w, g, mc, ms, vc, vs, hyper,
                                         block=FUSED_BLOCK)

    return jax.jit(fn).lower(
        f32((n,)), f32((n,)), u8((n,)), f32((grid,)), u8((n,)), f32((grid,)),
        f32((8,)),
    )


def write(path: str, text: str):
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        f.write(text)
    print(f"wrote {path} ({len(text)} chars)")


# --------------------------------------------------------------------------
# Golden parity vectors: inputs + expected codes/scales/dequant computed by
# the oracle, replayed bit-exactly by rust/tests/golden_parity.rs.
# --------------------------------------------------------------------------

def golden_cases():
    rng = np.random.RandomState(20230612)
    cases = []

    def add_blockwise(name, kind, bits, signed, block, x):
        table = ref.build_map(kind, bits, signed)
        codes, scales = ref.quantize_blockwise(x, block, table)
        deq = ref.dequantize_blockwise(codes, scales, block, table, x.size)
        cases.append({
            "name": name,
            "scheme": {"norm": f"B{block}", "map": kind, "bits": bits,
                       "signed": signed},
            "shape": list(x.shape),
            "input": [float(v) for v in x.reshape(-1)],
            "codes": [int(c) for c in np.asarray(codes)],
            "scales": [float(s) for s in np.asarray(scales)],
            "dequant": [float(v) for v in np.asarray(deq)],
        })

    def add_rank1(name, kind, bits, x2d):
        table = ref.build_map(kind, bits, False)
        codes, r, c = ref.quantize_rank1(x2d, table)
        deq = ref.dequantize_rank1(codes, r, c, table)
        cases.append({
            "name": name,
            "scheme": {"norm": "Rank-1", "map": kind, "bits": bits,
                       "signed": False},
            "shape": list(x2d.shape),
            "input": [float(v) for v in x2d.reshape(-1)],
            "codes": [int(v) for v in np.asarray(codes).reshape(-1)],
            "row_scales": [float(v) for v in np.asarray(r)],
            "col_scales": [float(v) for v in np.asarray(c)],
            "dequant": [float(v) for v in np.asarray(deq).reshape(-1)],
        })

    # First-moment style: signed, outliers mixed in.
    m = rng.randn(384).astype(np.float32) * 0.01
    m[::37] = rng.randn(len(m[::37])).astype(np.float32)
    add_blockwise("m_b128_de4", "de", 4, True, 128, m)
    add_blockwise("m_b2048_de8", "de", 8, True, 2048,
                  rng.randn(4096).astype(np.float32) * 0.02)

    # Second-moment style: non-negative, heavy-tailed.
    v = (rng.randn(256).astype(np.float32) * 1e-3) ** 2
    v[::53] = np.abs(rng.randn(len(v[::53])).astype(np.float32)) * 0.1
    add_blockwise("v_b128_linear4", "linear", 4, False, 128, v)
    add_blockwise("v_b128_de0_4", "de0", 4, False, 128, v)

    v2 = (rng.randn(24, 16).astype(np.float32) * 1e-2) ** 2
    v2[:, 3] += 0.5  # column outlier
    v2[5, :] += 0.3  # row outlier
    add_rank1("v_rank1_linear4", "linear", 4, v2)

    # Map tables themselves (rust asserts table equality).
    tables = {}
    for kind in ("linear", "de", "de0"):
        for signed in (False, True):
            t = ref.build_map(kind, 4, signed)
            tables[f"{kind}_4_{'s' if signed else 'u'}"] = [float(v) for v in t]
    tables["de_8_s"] = [float(v) for v in ref.build_map("de", 8, True)]

    return {"cases": cases, "tables": tables}


def golden_step_cases():
    """Golden *step* vectors for the dense baselines (sgdm, sm3): inputs
    plus the expected post-step weights/states computed by the float32
    oracles in ref.py, replayed bit-exactly by rust/tests/golden_parity.rs
    against both the sequential loops and the shard-parallel engine."""
    rng = np.random.RandomState(20230613)
    f32 = np.float32
    hyper = {"beta1": 0.9, "eps": 1e-6, "weight_decay": 0.01}
    lr, b1, eps, wd = f32(0.01), f32(hyper["beta1"]), f32(hyper["eps"]), \
        f32(hyper["weight_decay"])
    steps = 4
    cases = []

    def flat(a):
        return [float(v) for v in np.asarray(a, dtype=np.float32).reshape(-1)]

    def run(name, optimizer, shape, stepper, extract):
        w = rng.randn(*shape).astype(np.float32) * f32(0.5)
        grads = [rng.randn(*shape).astype(np.float32) * f32(0.1)
                 for _ in range(steps)]
        case = {"name": name, "optimizer": optimizer, "shape": list(shape),
                "w0": flat(w), "grads": [flat(g) for g in grads]}
        state = None
        for g in grads:
            w, state = stepper(w, state, g)
        case["final_w"] = flat(w)
        case.update({k: flat(v) for k, v in extract(state).items()})
        cases.append(case)

    def sgdm(w, state, g):
        m = np.zeros_like(w) if state is None else state
        w, m = ref.sgdm_step(w, m, g, lr, b1, wd)
        return w, m

    run("sgdm_2d", "sgdm", (8, 6), sgdm, lambda m: {"final_m": m})
    run("sgdm_1d", "sgdm", (64,), sgdm, lambda m: {"final_m": m})

    def sm3_2d(w, state, g):
        if state is None:
            state = (np.zeros_like(w),
                     np.zeros(w.shape[0], np.float32),
                     np.zeros(w.shape[1], np.float32))
        m, mu_row, mu_col = state
        w, m, mu_row, mu_col = ref.sm3_step_2d(w, m, mu_row, mu_col, g,
                                               lr, b1, eps, wd)
        return w, (m, mu_row, mu_col)

    def sm3_1d(w, state, g):
        if state is None:
            state = (np.zeros_like(w), np.zeros_like(w))
        m, v = state
        w, m, v = ref.sm3_step_1d(w, m, v, g, lr, b1, eps, wd)
        return w, (m, v)

    run("sm3_2d", "sm3", (7, 5), sm3_2d,
        lambda s: {"final_m": s[0], "final_row": s[1], "final_col": s[2]})
    run("sm3_1d", "sm3", (96,), sm3_1d,
        lambda s: {"final_m": s[0], "final_v": s[1]})

    return {"hyper": hyper, "lr": float(lr), "steps": steps, "cases": cases}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--golden", action="store_true",
                    help="also write golden parity vectors")
    ap.add_argument("--golden-out", default="../rust/tests/golden")
    ap.add_argument("--skip-train", action="store_true",
                    help="only lower the fused optimizer artifact")
    args = ap.parse_args()

    out = args.out
    if not args.skip_train:
        for name, cfg in TRAIN_CONFIGS.items():
            lowered = lower_train_step(cfg, TRAIN_BATCH)
            write(os.path.join(out, f"train_step_{name}.hlo.txt"),
                  to_hlo_text(lowered))
            write(os.path.join(out, f"eval_loss_{name}.hlo.txt"),
                  to_hlo_text(lower_eval_loss(cfg, TRAIN_BATCH)))
        # Machine-readable manifest of shapes for the rust runtime.
        manifest = {}
        for name, cfg in TRAIN_CONFIGS.items():
            manifest[name] = {
                "batch": TRAIN_BATCH,
                "vocab": cfg.vocab, "d_model": cfg.d_model,
                "n_heads": cfg.n_heads, "d_ff": cfg.d_ff,
                "n_layers": cfg.n_layers, "max_seq": cfg.max_seq,
                "params": [
                    {"name": n, "shape": list(s)}
                    for n, s in model.param_specs(cfg)
                ],
            }
        manifest["fused_adamw4"] = {"chunk": FUSED_CHUNK, "block": FUSED_BLOCK}
        write(os.path.join(out, "manifest.json"), json.dumps(manifest, indent=1))

    write(os.path.join(out, f"fused_adamw4_{FUSED_CHUNK}.hlo.txt"),
          to_hlo_text(lower_fused_adamw4(FUSED_CHUNK)))

    if args.golden:
        write(os.path.join(args.golden_out, "quant_golden.json"),
              json.dumps(golden_cases()))
        write(os.path.join(args.golden_out, "step_golden.json"),
              json.dumps(golden_step_cases()))


if __name__ == "__main__":
    main()
