"""Layer-2: the JAX transformer LM (fwd + bwd), lowered once by aot.py.

The architecture and parameter ordering mirror the rust builtin engine
(`rust/src/train/transformer.rs` / `TransformerConfig::param_specs`)
exactly: GPT-style pre-LN decoder, learned positional embeddings, ReLU
MLP, separate LM head, LN eps 1e-5. The rust coordinator feeds parameters
positionally in this order and receives (loss, *grads) back.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

LN_EPS = 1e-5


@dataclass(frozen=True)
class Config:
    vocab: int
    d_model: int
    n_heads: int
    d_ff: int
    n_layers: int
    max_seq: int

    @staticmethod
    def tiny():
        return Config(vocab=256, d_model=64, n_heads=4, d_ff=256,
                      n_layers=2, max_seq=32)

    @staticmethod
    def small():
        return Config(vocab=512, d_model=128, n_heads=8, d_ff=512,
                      n_layers=4, max_seq=64)


def param_specs(cfg: Config):
    """(name, shape) list, same order as the rust inventory."""
    d = cfg.d_model
    specs = [("tok_emb", (cfg.vocab, d)), ("pos_emb", (cfg.max_seq, d))]
    for l in range(cfg.n_layers):
        p = f"layers.{l}."
        specs += [
            (p + "ln1.g", (d,)), (p + "ln1.b", (d,)),
            (p + "attn.wq", (d, d)), (p + "attn.wk", (d, d)),
            (p + "attn.wv", (d, d)), (p + "attn.wo", (d, d)),
            (p + "ln2.g", (d,)), (p + "ln2.b", (d,)),
            (p + "mlp.fc1", (d, cfg.d_ff)), (p + "mlp.b1", (cfg.d_ff,)),
            (p + "mlp.fc2", (cfg.d_ff, d)), (p + "mlp.b2", (d,)),
        ]
    specs += [("ln_f.g", (d,)), ("ln_f.b", (d,)), ("lm_head", (d, cfg.vocab))]
    return specs


def init_params(cfg: Config, key):
    """GPT-2-style init, matching the rust initializer's structure (not its
    RNG stream — cross-engine tests compare behaviour, not bits)."""
    params = []
    std, resid_std = 0.02, 0.02 / (2.0 * cfg.n_layers) ** 0.5
    for name, shape in param_specs(cfg):
        key, sub = jax.random.split(key)
        if name.endswith(".g"):
            params.append(jnp.ones(shape, jnp.float32))
        elif name.endswith((".b", ".b1", ".b2")) or ".ln" in name:
            params.append(jnp.zeros(shape, jnp.float32))
        else:
            s = resid_std if ("wo" in name or "fc2" in name) else std
            params.append(jax.random.normal(sub, shape, jnp.float32) * s)
    return params


def _layernorm(x, g, b):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + LN_EPS) * g + b


def forward_loss(cfg: Config, params, tokens):
    """Mean next-token cross-entropy. `tokens`: int32 [B, T+1]."""
    d, heads = cfg.d_model, cfg.n_heads
    hs = d // heads
    inp = tokens[:, :-1]
    tgt = tokens[:, 1:]
    bsz, t_len = inp.shape

    it = iter(params)
    tok_emb = next(it)
    pos_emb = next(it)
    x = tok_emb[inp] + pos_emb[:t_len][None, :, :]

    mask = jnp.tril(jnp.ones((t_len, t_len), bool))
    for _ in range(cfg.n_layers):
        g1, b1 = next(it), next(it)
        wq, wk, wv, wo = next(it), next(it), next(it), next(it)
        g2, b2 = next(it), next(it)
        fc1, bb1, fc2, bb2 = next(it), next(it), next(it), next(it)

        a = _layernorm(x, g1, b1)
        q = (a @ wq).reshape(bsz, t_len, heads, hs)
        k = (a @ wk).reshape(bsz, t_len, heads, hs)
        v = (a @ wv).reshape(bsz, t_len, heads, hs)
        scores = jnp.einsum("bthd,buhd->bhtu", q, k) / jnp.sqrt(
            jnp.asarray(hs, jnp.float32))
        scores = jnp.where(mask[None, None, :, :], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1)
        attn = jnp.einsum("bhtu,buhd->bthd", probs, v).reshape(bsz, t_len, d)
        x = x + attn @ wo

        a2 = _layernorm(x, g2, b2)
        h = jax.nn.relu(a2 @ fc1 + bb1)
        x = x + (h @ fc2 + bb2)

    gf, bf = next(it), next(it)
    lm_head = next(it)
    xf = _layernorm(x, gf, bf)
    logits = xf @ lm_head

    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)
    return jnp.mean(nll)


def make_train_step(cfg: Config):
    """(tokens, *params) -> (loss, *grads). Positional signature so the
    HLO parameter order is explicit for the rust runtime."""
    def step(tokens, *params):
        loss, grads = jax.value_and_grad(
            lambda ps: forward_loss(cfg, ps, tokens))(list(params))
        return (loss, *grads)
    return step


def make_eval_loss(cfg: Config):
    def ev(tokens, *params):
        return (forward_loss(cfg, list(params), tokens),)
    return ev
