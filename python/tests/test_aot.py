"""AOT lowering smoke tests: HLO text is produced, parses basic sanity,
and the golden-vector generator is self-consistent."""

import json

import numpy as np

from compile import aot, model


def test_train_step_lowers_to_hlo_text():
    lowered = aot.lower_train_step(model.Config.tiny(), batch=2)
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text
    assert "f32" in text
    # One HLO parameter per model tensor + tokens.
    n_params = len(model.param_specs(model.Config.tiny()))
    assert text.count("parameter(") >= n_params + 1


def test_fused_adamw4_lowers_with_static_shapes():
    lowered = aot.lower_fused_adamw4(512)
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text
    assert "u8[512" in text.replace(" ", "")


def test_golden_cases_internally_consistent():
    g = aot.golden_cases()
    assert len(g["cases"]) >= 5
    for case in g["cases"]:
        n = int(np.prod(case["shape"]))
        assert len(case["input"]) == n
        assert len(case["codes"]) == n
        assert len(case["dequant"]) == n
        bits = case["scheme"]["bits"]
        assert max(case["codes"]) < (1 << bits)
        # Dequantized magnitude never exceeds the input magnitude bound.
        bound = max(abs(v) for v in case["input"]) * 1.0001 + 1e-12
        assert all(abs(v) <= bound for v in case["dequant"])
    # Tables present and sorted.
    for name, tab in g["tables"].items():
        assert tab == sorted(tab), name


def test_golden_json_serializable():
    text = json.dumps(aot.golden_cases())
    assert len(text) > 1000
    json.loads(text)
