"""Quantization-mapping construction invariants (paper App. E.2)."""

import numpy as np
import pytest

from compile.kernels import ref


@pytest.mark.parametrize("bits", [2, 3, 4, 5, 6, 8])
def test_linear_unsigned(bits):
    t = ref.build_map("linear", bits, False)
    assert len(t) == 1 << bits
    assert t[0] == pytest.approx(1.0 / (1 << bits))
    assert t[-1] == 1.0
    assert (t > 0).all(), "linear map excludes zero by construction"
    assert (np.diff(t) > 0).all()


def test_linear4_min_positive_matches_paper():
    # Paper §4.1: smallest representable of 4-bit Linear is 0.0625.
    t = ref.build_map("linear", 4, False)
    assert t[0] == pytest.approx(0.0625)


def test_de0_min_positive_matches_paper():
    # Paper §4.1: smallest representable of 4-bit DE-0 is 0.0033.
    t = ref.build_map("de0", 4, False)
    assert min(v for v in t if v > 0) == pytest.approx(0.00325, abs=1e-6)


@pytest.mark.parametrize("bits", [3, 4, 8])
@pytest.mark.parametrize("signed", [False, True])
def test_de_counts_and_extremes(bits, signed):
    t = ref.build_map("de", bits, signed)
    assert len(t) == 1 << bits
    assert t[-1] == 1.0
    assert 0.0 in t
    if signed:
        assert t[0] > -1.0, "signed DE is asymmetric: -1 not representable"


def test_de0_drops_exactly_zero():
    de = ref.build_map("de", 4, False)
    de0 = ref.build_map("de0", 4, False)
    assert len(de0) == len(de) - 1
    assert 0.0 in de and 0.0 not in de0
    assert set(np.asarray(de0)) == set(np.asarray(de)) - {0.0}


def test_signed_de4_known_values():
    # From the paper's construction: +/-{0.0055, 0.0325, 0.0775, 0.2125,
    # 0.4375, 0.6625, 0.8875}, 0 and 1.
    t = ref.build_map("de", 4, True)
    expect = sorted(
        [0.0, 1.0]
        + [s * v for v in (0.2125, 0.4375, 0.6625, 0.8875,
                           0.0325, 0.0775, 0.0055) for s in (1, -1)]
    )
    np.testing.assert_allclose(t, np.asarray(expect, np.float32), rtol=1e-6)


def test_encode_is_nearest():
    t = ref.build_map("de", 4, True)
    grid = np.linspace(-1.2, 1.2, 4001).astype(np.float32)
    codes = np.asarray(ref.encode(grid, t))
    brute = np.argmin(np.abs(grid[:, None] - t[None, :]), axis=1)
    np.testing.assert_array_equal(codes, brute)
