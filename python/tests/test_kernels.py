"""Pallas kernels vs the pure-jnp oracle, swept with hypothesis."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import quant4, ref

MAPS = [("de", 4, True), ("de0", 4, False), ("linear", 4, False),
        ("de", 8, True), ("de", 8, False)]


def _rand_array(n, seed, scale_mix=True):
    rng = np.random.RandomState(seed)
    x = rng.randn(n).astype(np.float32)
    if scale_mix:
        # Inject outliers and dead zones like real moment tensors.
        x[:: max(1, n // 7)] *= 100.0
        x[1:: max(1, n // 5)] *= 1e-6
        if n > 3:
            x[3] = 0.0
    return x


@settings(max_examples=30, deadline=None)
@given(
    blocks=st.integers(min_value=1, max_value=6),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    map_idx=st.integers(min_value=0, max_value=len(MAPS) - 1),
    block=st.sampled_from([32, 128, 256]),
)
def test_quantize_matches_ref(blocks, seed, map_idx, block):
    kind, bits, signed = MAPS[map_idx]
    table = ref.build_map(kind, bits, signed)
    n = blocks * block
    x = _rand_array(n, seed)
    if not signed:
        x = np.abs(x)
    c_k, s_k = quant4.quantize_blockwise(jnp.asarray(x), table, block=block)
    c_r, s_r = ref.quantize_blockwise(x, block, table)
    np.testing.assert_array_equal(np.asarray(c_k), np.asarray(c_r))
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r))


@settings(max_examples=20, deadline=None)
@given(
    blocks=st.integers(min_value=1, max_value=4),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_dequantize_roundtrip_bounded(blocks, seed):
    table = ref.build_map("de", 4, True)
    n = blocks * 128
    x = _rand_array(n, seed, scale_mix=False)
    codes, scales = quant4.quantize_blockwise(jnp.asarray(x), table)
    y = np.asarray(quant4.dequantize_blockwise(codes, scales, table))
    # Error bounded by half the largest map gap times the block scale.
    gaps = np.diff(np.asarray(table))
    per = np.repeat(np.asarray(scales), 128)[:n]
    bound = per * (gaps.max() / 2 + 1e-6) + 1e-7
    assert (np.abs(x - y) <= bound).all()


def test_dequantize_matches_ref_exactly():
    table = ref.build_map("linear", 4, False)
    x = np.abs(_rand_array(512, 7))
    codes, scales = ref.quantize_blockwise(x, 128, table)
    y_k = np.asarray(quant4.dequantize_blockwise(
        jnp.asarray(np.asarray(codes)), jnp.asarray(np.asarray(scales)), table))
    y_r = np.asarray(ref.dequantize_blockwise(codes, scales, 128, table, 512))
    np.testing.assert_array_equal(y_k, y_r)


def test_zero_block_is_safe():
    table = ref.build_map("linear", 4, False)
    x = np.zeros(256, np.float32)
    codes, scales = quant4.quantize_blockwise(jnp.asarray(x), table)
    y = np.asarray(quant4.dequantize_blockwise(codes, scales, table))
    assert np.isfinite(y).all()
    np.testing.assert_array_equal(y, x)


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    t=st.integers(min_value=1, max_value=50),
)
def test_fused_adamw4_matches_reference(seed, t):
    rng = np.random.RandomState(seed)
    n = 256
    mt = ref.build_map("de", 4, True)
    vt = ref.build_map("linear", 4, False)
    w = rng.randn(n).astype(np.float32)
    g = rng.randn(n).astype(np.float32) * 0.1
    mc, ms = ref.quantize_blockwise(rng.randn(n).astype(np.float32) * 0.01,
                                    128, mt)
    vc, vs = ref.quantize_blockwise(
        (rng.randn(n).astype(np.float32) * 0.01) ** 2, 128, vt)
    lr, b1, b2, eps, wd = 1e-3, 0.9, 0.999, 1e-6, 0.01
    hyper = np.array([lr, b1, b2, eps, wd, 1 - b1**t, 1 - b2**t, 0.0],
                     np.float32)
    out = quant4.fused_adamw4_chunk(
        jnp.asarray(w), jnp.asarray(g), mc, ms, vc, vs, jnp.asarray(hyper))
    expect = ref.fused_adamw4_reference(
        w, g, np.asarray(mc), np.asarray(ms), np.asarray(vc), np.asarray(vs),
        lr, b1, b2, eps, wd, t, 128, mt, vt)
    names = ["w", "m_codes", "m_scales", "v_codes", "v_scales"]
    for a, b, name in zip(out, expect, names):
        a, b = np.asarray(a), np.asarray(b)
        if a.dtype == np.uint8:
            np.testing.assert_array_equal(a, b, err_msg=name)
        else:
            np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-7,
                                       err_msg=name)


def test_fused_adamw4_descends_quadratic():
    # Drive the fused kernel as a real optimizer for 200 steps.
    n = 256
    rng = np.random.RandomState(0)
    target = rng.randn(n).astype(np.float32)
    w = np.zeros(n, np.float32)
    mt = ref.build_map("de", 4, True)
    vt = ref.build_map("linear", 4, False)
    mc, ms = ref.quantize_blockwise(np.zeros(n, np.float32), 128, mt)
    vc, vs = ref.quantize_blockwise(np.zeros(n, np.float32), 128, vt)
    w_j, mc, ms, vc, vs = (jnp.asarray(w), jnp.asarray(np.asarray(mc)),
                           jnp.asarray(np.asarray(ms)),
                           jnp.asarray(np.asarray(vc)),
                           jnp.asarray(np.asarray(vs)))
    lr, b1, b2, eps, wd = 0.05, 0.9, 0.999, 1e-6, 0.0
    for t in range(1, 201):
        g = w_j - jnp.asarray(target)
        hyper = jnp.asarray(
            np.array([lr, b1, b2, eps, wd, 1 - b1**t, 1 - b2**t, 0],
                     np.float32))
        w_j, mc, ms, vc, vs = quant4.fused_adamw4_chunk(
            w_j, g, mc, ms, vc, vs, hyper)
    rel = float(jnp.sum((w_j - target) ** 2) / jnp.sum(target ** 2))
    assert rel < 5e-2, rel


def test_rank1_ref_tighter_than_per_tensor():
    rng = np.random.RandomState(3)
    x = (rng.randn(32, 24).astype(np.float32) * 1e-3) ** 2
    x[:, 5] += 1.0
    table = ref.build_map("linear", 4, False)
    codes, r, c = ref.quantize_rank1(x, table)
    deq = np.asarray(ref.dequantize_rank1(codes, r, c, table))
    err_r1 = np.abs(deq - x).mean()
    pt_codes = ref.encode(x / np.abs(x).max(), table)
    deq_pt = np.asarray(ref.decode(pt_codes, table)) * np.abs(x).max()
    err_pt = np.abs(deq_pt - x).mean()
    assert err_r1 < err_pt
