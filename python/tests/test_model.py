"""L2 model graph: shapes, loss behaviour, gradients."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model


@pytest.fixture(scope="module")
def cfg():
    return model.Config.tiny()


@pytest.fixture(scope="module")
def params(cfg):
    return model.init_params(cfg, jax.random.PRNGKey(0))


def _tokens(cfg, batch, seed=0):
    rng = np.random.RandomState(seed)
    return jnp.asarray(
        rng.randint(0, cfg.vocab, size=(batch, cfg.max_seq + 1)), jnp.int32)


def test_param_specs_match_init(cfg, params):
    specs = model.param_specs(cfg)
    assert len(specs) == len(params)
    for (name, shape), p in zip(specs, params):
        assert tuple(shape) == p.shape, name


def test_initial_loss_near_uniform(cfg, params):
    loss = model.forward_loss(cfg, params, _tokens(cfg, 4))
    assert abs(float(loss) - np.log(cfg.vocab)) < 0.5


def test_train_step_returns_loss_and_grads(cfg, params):
    step = jax.jit(model.make_train_step(cfg))
    out = step(_tokens(cfg, 2), *params)
    assert len(out) == 1 + len(params)
    loss, grads = out[0], out[1:]
    assert np.isfinite(float(loss))
    for g, p in zip(grads, params):
        assert g.shape == p.shape
        assert np.isfinite(np.asarray(g)).all()


def test_grads_nonzero_everywhere(cfg, params):
    step = jax.jit(model.make_train_step(cfg))
    out = step(_tokens(cfg, 4, seed=3), *params)
    grads = out[1:]
    specs = model.param_specs(cfg)
    for (name, _), g in zip(specs, grads):
        if name == "pos_emb" or name == "tok_emb":
            continue  # rows beyond seq/unused tokens are legitimately zero
        assert float(jnp.abs(g).max()) > 0, f"all-zero grad for {name}"


def test_sgd_on_jax_model_descends(cfg, params):
    step = jax.jit(model.make_train_step(cfg))
    toks = _tokens(cfg, 4, seed=1)  # fixed batch -> loss must drop fast
    ps = [jnp.array(p) for p in params]
    losses = []
    for _ in range(12):
        out = step(toks, *ps)
        losses.append(float(out[0]))
        ps = [p - 0.5 * g for p, g in zip(ps, out[1:])]
    assert losses[-1] < losses[0], losses


def test_eval_loss_matches_forward(cfg, params):
    ev = jax.jit(model.make_eval_loss(cfg))
    toks = _tokens(cfg, 2, seed=5)
    (loss,) = ev(toks, *params)
    direct = model.forward_loss(cfg, params, toks)
    assert float(loss) == pytest.approx(float(direct), rel=1e-6)
