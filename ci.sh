#!/usr/bin/env bash
# CI for lowbit-opt: tier-1 verify (build + tests), style gates, and a
# bench smoke run that records the step-engine perf trajectory in
# BENCH_engine.json.
#
# Usage: ./ci.sh
set -euo pipefail
cd "$(dirname "$0")"

echo "== cargo build --release"
cargo build --release

echo "== cargo test -q"
cargo test -q

echo "== cargo fmt --check"
cargo fmt --check

echo "== cargo clippy (all targets, -D warnings)"
cargo clippy --all-targets -- -D warnings

echo "== bench smoke: quant_throughput"
cargo bench --bench quant_throughput -- --smoke

echo "== bench smoke: optim_step (writes BENCH_engine.json)"
cargo bench --bench optim_step -- --smoke --json BENCH_engine.json

echo "CI OK"
