#!/usr/bin/env bash
# CI for lowbit-opt: tier-1 verify (build + tests), style gates, and a
# bench smoke run that records the step-engine perf trajectory in
# BENCH_engine.json.
#
# The test suite runs under the default engine auto-threading, with
# LOWBIT_ENGINE_THREADS pinned (so every auto-threaded engine path —
# dense + compressed — is exercised at a second worker count on top of
# the explicit 1/2/7 parity matrix), with LOWBIT_KERNEL_TIER forced to
# scalar (so the scalar quant-kernel tier stays covered end to end on
# hosts where auto-dispatch resolves to AVX2 — the differential suites
# require every tier to be bit-identical), and with LOWBIT_ENGINE_SCHED
# forced to queue (the default run resolves to the sticky affinity
# scheduler, so this pass keeps the shared-queue reference scheduler
# covered end to end — results must be bit-identical either way).
#
# BENCH_engine.json, BENCH_offload.json and BENCH_quant.json are
# *appended to*, one run object per CI invocation (dense + compressed
# thread scaling; offload pipeline threads × prefetch depth with
# measured overlap fraction and virtual step time; quant kernel
# encode/decode/roundtrip throughput), so perf regressions stay visible
# across PRs.
#
# Usage: ./ci.sh
set -euo pipefail
cd "$(dirname "$0")"

echo "== cargo build --release"
cargo build --release

echo "== cargo test -q (default engine threads)"
cargo test -q

echo "== cargo test -q (engine threads pinned to 7)"
LOWBIT_ENGINE_THREADS=7 cargo test -q

echo "== cargo test -q (kernel tier forced to scalar)"
LOWBIT_KERNEL_TIER=scalar cargo test -q

echo "== cargo test -q (engine scheduler forced to queue)"
LOWBIT_ENGINE_SCHED=queue cargo test -q

echo "== cargo test -q --features audit (aliasing auditor on)"
cargo test -q --features audit

echo "== cargo test -q --features audit (engine threads pinned to 7)"
LOWBIT_ENGINE_THREADS=7 cargo test -q --features audit

# The chaos suite runs fault-free in every pass above; these two passes
# re-run it under a pinned process-wide fault schedule so the env gate
# (fault::active) is exercised end to end, and once more with the
# aliasing auditor on so retried transfers prove free of false alarms.
echo "== chaos suite under a pinned fault schedule (LOWBIT_FAULTS)"
LOWBIT_FAULTS=1234:0.05:mixed cargo test -q --test chaos

echo "== chaos suite under the pinned schedule + aliasing auditor"
LOWBIT_FAULTS=1234:0.05:mixed cargo test -q --features audit --test chaos

echo "== unsafe-boundary lint"
cargo run --release --bin lint

echo "== cargo fmt --check"
cargo fmt --check

echo "== cargo clippy (all targets, -D warnings)"
cargo clippy --all-targets -- -D warnings

echo "== bench smoke: quant_throughput"
cargo bench --bench quant_throughput -- --smoke

echo "== bench smoke: quant_kernels (appends to BENCH_quant.json)"
cargo bench --bench quant_kernels -- --smoke --json BENCH_quant.json
test -s BENCH_quant.json || { echo "FAIL: quant_kernels did not append to BENCH_quant.json"; exit 1; }

echo "== bench smoke: optim_step (appends to BENCH_engine.json)"
cargo bench --bench optim_step -- --smoke --json BENCH_engine.json
test -s BENCH_engine.json || { echo "FAIL: optim_step did not append to BENCH_engine.json"; exit 1; }

echo "== bench smoke: offload_pipeline (appends to BENCH_offload.json)"
cargo bench --bench offload_pipeline -- --smoke --json BENCH_offload.json
test -s BENCH_offload.json || { echo "FAIL: offload_pipeline did not append to BENCH_offload.json"; exit 1; }

echo "== bench JSON schema: every run carries trace_summary + tier/sched tags + fault counters"
./target/release/lowbit trace --check-bench BENCH_engine.json
./target/release/lowbit trace --check-bench BENCH_offload.json

# The trace-feature passes run last so the feature-set flip costs one
# rebuild instead of thrashing the cache mid-run.
echo "== cargo test -q --features trace (span rings on; includes ctx_cache zero-alloc pins)"
cargo test -q --features trace

echo "== trace smoke: record via LOWBIT_TRACE + the trace subcommand, validate exports"
cargo build --release --features trace
# adamw4 records A/reduce/C/commit (F is factored-v only, C needs
# rank-1 globals — present on the tiny model's 2-D tensors).
LOWBIT_TRACE=trace_train.json ./target/release/lowbit train --steps 3 --quiet
./target/release/lowbit trace --check trace_train.json --expect engine.A,engine.reduce,engine.C,engine.commit
./target/release/lowbit trace --out trace_cli.json --steps 3 --optimizer adamw32
./target/release/lowbit trace --check trace_cli.json --expect dense.adamw32
rm -f trace_train.json trace_cli.json

echo "CI OK"
