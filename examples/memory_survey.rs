//! Memory survey: which models fit in which GPUs under which optimizer —
//! the paper's Tab. 5 arithmetic as a library call.
//!
//! Run: `cargo run --release --example memory_survey`

use lowbit_opt::memory::{largest_trainable, training_bytes, StatePreset, TrainSetup, GB};
use lowbit_opt::model::{llama_family, opt_family};

fn main() {
    let setup = TrainSetup { batch: 1, seq: 512 };
    println!("largest trainable model per budget (batch 1, seq 512):\n");
    println!("{:<8} {:<14} {:<14} {:<14}", "budget", "32-bit AdamW", "4-bit AdamW", "4-bit Factor");
    let fam = opt_family();
    for budget in [16u64, 24, 40, 48, 80] {
        let b = budget * GB;
        let pick = |p| largest_trainable(&fam, p, setup, b).unwrap_or("-");
        println!(
            "{:<8} {:<14} {:<14} {:<14}",
            format!("{budget} GB"),
            pick(StatePreset::AdamW32),
            pick(StatePreset::AdamW4),
            pick(StatePreset::Factor4),
        );
    }

    println!("\nLLaMA family footprints:");
    for m in llama_family() {
        print!("{:<10}", m.name);
        for p in [StatePreset::AdamW32, StatePreset::AdamW8, StatePreset::AdamW4, StatePreset::Factor4] {
            print!(
                "  {}: {:>6.1} GB",
                p.label().split(' ').next().unwrap(),
                training_bytes(&m.cfg, p, setup) as f64 / GB as f64
            );
        }
        println!();
    }
}
