//! Moment atlas: train a small LM, capture its Adam moments, and report
//! per-tensor outlier structure + quantization error under every paper
//! quantizer — the data behind Figs. 1/2/3, exported to
//! `results/moment_atlas.json`.
//!
//! Run: `cargo run --release --example moment_atlas [steps]`

use lowbit_opt::data::MarkovCorpus;
use lowbit_opt::model::TransformerConfig;
use lowbit_opt::optim::adamw::AdamW;
use lowbit_opt::optim::{Hyper, Optimizer, Param};
use lowbit_opt::quant::error::{inv_sqrt_overshoot, reconstruction_error, zero_fraction};
use lowbit_opt::quant::{MapKind, NormKind, Quantizer};
use lowbit_opt::train::{LrSchedule, Trainer, TransformerEngine};
use lowbit_opt::util::json::Json;
use lowbit_opt::util::rng::Pcg64;

fn main() {
    let steps: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(150);
    let cfg = TransformerConfig::tiny();
    let engine = TransformerEngine::new(cfg);
    let corpus = MarkovCorpus::new(cfg.vocab, 11);
    let mut rng = Pcg64::seeded(3);
    let mut params = cfg.init_params(&mut rng);
    let mut opt = AdamW::new(Hyper::default());
    let trainer = Trainer::new(steps, LrSchedule::Constant(2e-3));
    let mut data_rng = Pcg64::seeded(4);
    let mut engine_fn =
        |p: &[Param], b: &lowbit_opt::data::LmBatch| engine.loss_and_grads(p, b);
    trainer.run(&mut params, &mut opt, &mut engine_fn, |_| {
        corpus.sample(8, cfg.max_seq, &mut data_rng)
    });
    println!("trained {} steps; analyzing moments\n", steps);

    let quantizers: Vec<(&str, Quantizer)> = vec![
        ("B2048/DE", Quantizer::new(NormKind::Block(2048), MapKind::DynExp, 4, true)),
        ("B128/DE", Quantizer::first_moment_4bit()),
        ("Rank-1/Linear", Quantizer::second_moment_4bit()),
        ("B128/DE-0", Quantizer::new(NormKind::Block(128), MapKind::DynExpNoZero, 4, false)),
    ];

    let mut entries = Vec::new();
    for (idx, p) in params.iter().enumerate() {
        if p.tensor.numel() < 2048 {
            continue;
        }
        let (m, v) = opt.moments(idx).unwrap();
        println!("{} {:?}", p.name, p.tensor.shape);
        let mut entry = Json::obj();
        entry.set("name", Json::Str(p.name.clone()));
        entry.set("shape", Json::from_usizes(&p.tensor.shape));
        for (qname, q) in &quantizers {
            // First moment for signed quantizers, second for unsigned.
            let (target, which) = if q.signed { (m, "m") } else { (v, "v") };
            let mut r = Pcg64::seeded(0);
            let deq = q.quantize(target, &mut r).dequantize();
            let err = reconstruction_error(target, &deq);
            let extra = if which == "v" {
                format!(
                    " zero_frac {:.3} overshoot {:.3}",
                    zero_fraction(&deq),
                    inv_sqrt_overshoot(target, &deq, 1e-6)
                )
            } else {
                String::new()
            };
            println!(
                "  {which} ~ {qname:<14} mse {:.3e} max {:.3e}{extra}",
                err.mse, err.max_abs
            );
            let mut j = Json::obj();
            j.set("mse", Json::Num(err.mse));
            j.set("max_abs", Json::Num(err.max_abs));
            entry.set(&format!("{which}:{qname}"), j);
        }
        entries.push(entry);
    }
    let mut doc = Json::obj();
    doc.set("steps", Json::Num(steps as f64));
    doc.set("tensors", Json::Arr(entries));
    let path = format!("{}/moment_atlas.json", lowbit_opt::util::results_dir());
    lowbit_opt::util::write_file(&path, &doc.pretty()).unwrap();
    println!("\nwritten to {path}");
}
