//! Quickstart: train a tiny transformer LM with the 4-bit AdamW optimizer
//! (builtin engine, no artifacts needed) and compare its optimizer-state
//! memory against fp32 AdamW.
//!
//! Run: `cargo run --release --example quickstart`

use lowbit_opt::data::MarkovCorpus;
use lowbit_opt::model::TransformerConfig;
use lowbit_opt::optim::{build, Hyper, Optimizer, Param};
use lowbit_opt::train::{LrSchedule, Trainer, TransformerEngine};
use lowbit_opt::util::rng::Pcg64;
use lowbit_opt::util::stats::fmt_bytes;

fn main() {
    let cfg = TransformerConfig::tiny();
    let engine = TransformerEngine::new(cfg);
    let corpus = MarkovCorpus::new(cfg.vocab, 42);
    println!("tiny transformer: {} parameters", cfg.n_params());

    for preset in ["adamw32", "adamw4"] {
        let mut rng = Pcg64::seeded(0);
        let mut params = cfg.init_params(&mut rng);
        let mut opt = build(preset, Hyper::default()).unwrap();
        let trainer = Trainer::new(60, LrSchedule::Constant(2e-3));
        let mut data_rng = Pcg64::seeded(1);
        let mut engine_fn = |p: &[Param], b: &lowbit_opt::data::LmBatch| {
            engine.loss_and_grads(p, b)
        };
        let report = trainer.run(&mut params, opt.as_mut(), &mut engine_fn, |_| {
            corpus.sample(8, cfg.max_seq, &mut data_rng)
        });
        println!(
            "{:<14} loss {:.3} -> {:.3} | {:.1} ms/step | optimizer state {}",
            opt.name(),
            report.losses[0],
            report.final_loss,
            report.step_seconds * 1e3,
            fmt_bytes(report.state_bytes as u64),
        );
    }
    println!("\n4-bit states: same convergence, ~8x smaller optimizer memory.");
}
