//! End-to-end driver: the full three-layer stack on a real (synthetic)
//! workload. The JAX-lowered transformer train-step (which is the L2
//! graph, AOT-compiled to `artifacts/train_step_small.hlo.txt`) runs
//! under the rust PJRT runtime; gradients feed the native 4-bit AdamW
//! (paper Alg. 1); the loss curve is logged to
//! `results/train_lm_curve.json` alongside a 32-bit reference curve
//! (the paper's Fig. 4 setup).
//!
//! Run: `make artifacts && cargo run --release --example train_lm [steps]`

use lowbit_opt::data::MarkovCorpus;
use lowbit_opt::optim::{build, Hyper, Optimizer};
use lowbit_opt::runtime::{PjrtTrainStep, Runtime};
use lowbit_opt::train::{LrSchedule, Trainer};
use lowbit_opt::util::json::Json;
use lowbit_opt::util::rng::Pcg64;
use lowbit_opt::util::stats::fmt_bytes;

fn run_one(
    preset: &str,
    steps: usize,
    rt: &Runtime,
) -> anyhow::Result<(Vec<f32>, f64, usize)> {
    let dir = lowbit_opt::util::artifacts_dir();
    let mut engine = PjrtTrainStep::load(rt, &dir, "small")?;
    let cfg = engine.entry.cfg;
    let batch = engine.entry.batch;
    let mut rng = Pcg64::seeded(7);
    let mut params = cfg.init_params(&mut rng);
    engine.check_params(&params)?;
    let corpus = MarkovCorpus::new(cfg.vocab, 99);
    let mut opt: Box<dyn Optimizer> =
        build(preset, Hyper::default()).expect("preset");
    let trainer = Trainer::new(
        steps,
        LrSchedule::LinearWarmupDecay {
            peak: 2e-3,
            warmup: steps / 10 + 1,
            total: steps,
        },
    );
    let mut data_rng = Pcg64::seeded(8);
    let report = trainer.run(&mut params, opt.as_mut(), &mut engine, |_| {
        corpus.sample(batch, cfg.max_seq, &mut data_rng)
    });
    println!(
        "[{preset}] {} params | {} steps | {:.2} s/step | loss {:.4} -> {:.4} | state {}",
        cfg.n_params(),
        report.steps,
        report.step_seconds,
        report.losses[0],
        report.final_loss,
        fmt_bytes(report.state_bytes as u64)
    );
    Ok((report.losses, report.step_seconds, report.state_bytes))
}

fn main() -> anyhow::Result<()> {
    let steps: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(200);
    let rt = Runtime::cpu()?;
    println!("PJRT platform: {} | end-to-end LM training\n", rt.platform());

    let (curve4, s4, mem4) = run_one("adamw4", steps, &rt)?;
    let (curve32, s32, mem32) = run_one("adamw32", steps, &rt)?;

    // Curve alignment (Fig. 4's claim).
    let tail = (steps / 5).max(1);
    let gap: f64 = curve32
        .iter()
        .rev()
        .take(tail)
        .zip(curve4.iter().rev().take(tail))
        .map(|(a, b)| (a - b).abs() as f64)
        .sum::<f64>()
        / tail as f64;
    println!(
        "\ncurve alignment: mean |gap| over final 20% = {gap:.4} nats \
         | state memory 4-bit/32-bit = {:.3} | step-time ratio = {:.2}",
        mem4 as f64 / mem32 as f64,
        s4 / s32
    );

    let mut doc = Json::obj();
    doc.set("steps", Json::Num(steps as f64));
    doc.set("adamw4", Json::from_f32s(&curve4));
    doc.set("adamw32", Json::from_f32s(&curve32));
    doc.set("tail_gap", Json::Num(gap));
    let path = format!("{}/train_lm_curve.json", lowbit_opt::util::results_dir());
    lowbit_opt::util::write_file(&path, &doc.pretty())?;
    println!("loss curves written to {path}");
    Ok(())
}
