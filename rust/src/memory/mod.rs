#![forbid(unsafe_code)]
//! Memory accounting: exact optimizer-state sizes per preset (the basis of
//! the paper's Tab. 4 "Saved Mem.") and a whole-training-footprint
//! estimator powering the Tab. 5 "largest trainable model" search.
//!
//! The state model replicates the implementation rules exactly:
//! * ≤4096-element tensors stay fp32 (App. D.1);
//! * the 8-bit baseline keeps embedding states fp32;
//! * block-wise scales cost 4 bytes per block, rank-1 scales 4 bytes per
//!   row + column, factored second moments 4 bytes per row + column.

use crate::model::{NamedModel, TransformerConfig};
use crate::optim::ParamKind;

pub const GB: u64 = 1024 * 1024 * 1024;

/// Optimizer presets the estimator understands (same names as
/// `optim::build`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StatePreset {
    AdamW32,
    AdamW8,
    AdamW4,
    Factor4,
    AdafactorB0,
}

impl StatePreset {
    pub fn parse(s: &str) -> Option<StatePreset> {
        Some(match s {
            "adamw32" => StatePreset::AdamW32,
            "adamw8" => StatePreset::AdamW8,
            "adamw4" => StatePreset::AdamW4,
            "factor4" => StatePreset::Factor4,
            "adafactor-b0" => StatePreset::AdafactorB0,
            _ => return None,
        })
    }

    pub fn label(&self) -> &'static str {
        match self {
            StatePreset::AdamW32 => "32-bit AdamW",
            StatePreset::AdamW8 => "8-bit AdamW",
            StatePreset::AdamW4 => "4-bit AdamW",
            StatePreset::Factor4 => "4-bit Factor",
            StatePreset::AdafactorB0 => "Adafactor (b1=0)",
        }
    }
}

/// State bytes for one tensor of `shape` and `kind` under `preset`.
pub fn tensor_state_bytes(shape: &[usize], kind: ParamKind, preset: StatePreset) -> u64 {
    let n: u64 = shape.iter().map(|&d| d as u64).product();
    let dense32 = 2 * 4 * n; // m + v fp32
    let small = n <= 4096;
    match preset {
        StatePreset::AdamW32 => dense32,
        StatePreset::AdamW8 => {
            if small || kind == ParamKind::Embedding {
                dense32
            } else {
                // m + v at 1 byte each + B2048 scales (x2).
                2 * n + 2 * 4 * n.div_ceil(2048)
            }
        }
        StatePreset::AdamW4 => {
            if small {
                dense32
            } else {
                let m = n.div_ceil(2) + 4 * n.div_ceil(128); // B128/DE
                let v = if shape.len() >= 2 {
                    // Rank-1/Linear: codes + row & col stats.
                    let rows = shape[0] as u64;
                    let cols = n / rows;
                    n.div_ceil(2) + 4 * (rows + cols)
                } else {
                    n.div_ceil(2) + 4 * n.div_ceil(128) // B128/Linear 1-D
                };
                m + v
            }
        }
        StatePreset::Factor4 => {
            if small {
                dense32
            } else {
                let m = n.div_ceil(2) + 4 * n.div_ceil(128);
                let v = if shape.len() >= 2 {
                    let rows = shape[0] as u64;
                    4 * (rows + n / rows) // factored stats only
                } else {
                    n.div_ceil(2) + 4 * n.div_ceil(128)
                };
                m + v
            }
        }
        StatePreset::AdafactorB0 => {
            if shape.len() >= 2 {
                let rows = shape[0] as u64;
                4 * (rows + n / rows)
            } else {
                4 * n
            }
        }
    }
}

/// Total optimizer-state bytes for a transformer config.
pub fn model_state_bytes(cfg: &TransformerConfig, preset: StatePreset) -> u64 {
    cfg.param_specs()
        .iter()
        .map(|(_, kind, shape)| tensor_state_bytes(shape, *kind, preset))
        .sum()
}

/// Whole-training memory estimate (bytes) for fine-tuning: fp32 weights +
/// fp32 gradients + optimizer states + activations. The activation model
/// assumes no gradient checkpointing and counts the standard per-layer
/// buffers (residuals, LN outputs, QKV, attention probs, MLP hidden),
/// which is what dominates at batch 1 / seq 512 in the paper's Tab. 5.
#[derive(Clone, Copy, Debug)]
pub struct TrainSetup {
    pub batch: usize,
    pub seq: usize,
}

pub fn activation_bytes(cfg: &TransformerConfig, setup: TrainSetup) -> u64 {
    let b = setup.batch as u64;
    let t = setup.seq as u64;
    let c = cfg.d_model as u64;
    let f = cfg.d_ff as u64;
    let h = cfg.n_heads as u64;
    let l = cfg.n_layers as u64;
    // Per layer: x_in, ln1, q, k, v, attn_out, x_mid, ln2, h1 (d_ff), out
    // = 8 tensors of [B,T,C] + 1 of [B,T,F] + probs [B,H,T,T].
    let per_layer = 8 * b * t * c + b * t * f + b * h * t * t;
    let logits = b * t * cfg.vocab as u64;
    4 * (l * per_layer + logits + 2 * b * t * c)
}

/// Allocator fragmentation + framework/runtime overhead. The paper's
/// Tab. 4 reports *total* memory including "data, activations, and memory
/// fragments"; comparing its measured totals against raw tensor bytes for
/// RoBERTa-L / GPT-2-M / LLaMA-7B gives a consistent ~10% multiplicative
/// overhead plus ~1.5 GB fixed (CUDA context, workspace buffers). We fold
/// the same calibration into the estimator so the Tab. 5 search reproduces
/// the paper's budget boundaries.
pub fn runtime_overhead(raw: u64) -> u64 {
    raw + raw / 10 + 3 * GB / 2
}

pub fn training_bytes(cfg: &TransformerConfig, preset: StatePreset, setup: TrainSetup) -> u64 {
    let n: u64 = cfg.n_params() as u64;
    let weights = 4 * n;
    let grads = 4 * n;
    let states = model_state_bytes(cfg, preset);
    runtime_overhead(weights + grads + states + activation_bytes(cfg, setup))
}

/// The Tab. 5 search: largest model in `family` whose training footprint
/// fits in `budget_bytes`.
pub fn largest_trainable(
    family: &[NamedModel],
    preset: StatePreset,
    setup: TrainSetup,
    budget_bytes: u64,
) -> Option<&'static str> {
    let mut best: Option<(&'static str, u64)> = None;
    for m in family {
        let need = training_bytes(&m.cfg, preset, setup);
        if need <= budget_bytes {
            let n = m.cfg.n_params() as u64;
            if best.map_or(true, |(_, bn)| n > bn) {
                best = Some((m.name, n));
            }
        }
    }
    best.map(|(name, _)| name)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{llama_family, opt_family};
    use crate::optim::{build, Hyper, Optimizer, Param};
    use crate::tensor::Tensor;

    #[test]
    fn estimator_matches_actual_optimizer_bytes() {
        // The analytic model must agree exactly with what the real
        // optimizers report after one step.
        let cfg = TransformerConfig::tiny();
        let mut rng = crate::util::rng::Pcg64::seeded(0);
        for (preset_name, preset) in [
            ("adamw32", StatePreset::AdamW32),
            ("adamw8", StatePreset::AdamW8),
            ("adamw4", StatePreset::AdamW4),
            ("factor4", StatePreset::Factor4),
        ] {
            let mut params: Vec<Param> = cfg.init_params(&mut rng);
            let grads: Vec<Tensor> = params
                .iter()
                .map(|p| Tensor::full(&p.tensor.shape, 0.01))
                .collect();
            let mut opt = build(preset_name, Hyper::default()).unwrap();
            opt.step(&mut params, &grads, 1e-3);
            let actual = opt.state_bytes() as u64;
            let predicted = model_state_bytes(&cfg, preset);
            assert_eq!(
                actual, predicted,
                "{preset_name}: actual {actual} vs predicted {predicted}"
            );
            // The measured allocation (buffer capacities) can only sit
            // at or above the analytic count — and for state buffers,
            // which are sized once and never grown, not far above it.
            let allocated = opt.state_bytes_allocated() as u64;
            assert!(
                allocated >= actual,
                "{preset_name}: allocated {allocated} below analytic {actual}"
            );
            assert!(
                allocated <= 2 * actual,
                "{preset_name}: allocated {allocated} vs analytic {actual} — \
                 state buffers should be sized tight"
            );
        }
    }

    #[test]
    fn state_bytes_ratios_match_paper() {
        // Paper: optimizer states 2x smaller for 4-bit vs 8-bit, ~8x vs
        // 32-bit (modulo fp32-kept small tensors / embeddings).
        let cfg = llama_family()[0].cfg; // LLaMA-7B
        let b32 = model_state_bytes(&cfg, StatePreset::AdamW32);
        let b8 = model_state_bytes(&cfg, StatePreset::AdamW8);
        let b4 = model_state_bytes(&cfg, StatePreset::AdamW4);
        let bf = model_state_bytes(&cfg, StatePreset::Factor4);
        let r84 = b8 as f64 / b4 as f64;
        assert!((1.6..2.4).contains(&r84), "8-bit/4-bit ratio {r84}");
        let r324 = b32 as f64 / b4 as f64;
        assert!((6.0..8.5).contains(&r324), "32-bit/4-bit ratio {r324}");
        assert!(bf < b4, "factored should beat plain 4-bit");
    }

    #[test]
    fn llama7b_fits_80gb_only_with_4bit() {
        // The paper's headline Tab. 5 row: LLaMA-7B trains on one 80GB GPU
        // with 4-bit AdamW but not with 32-bit AdamW.
        let setup = TrainSetup { batch: 1, seq: 512 };
        let fam = llama_family();
        let need32 = training_bytes(&fam[0].cfg, StatePreset::AdamW32, setup);
        let need4 = training_bytes(&fam[0].cfg, StatePreset::AdamW4, setup);
        assert!(need32 > 80 * GB, "32-bit LLaMA-7B should exceed 80GB: {need32}");
        assert!(need4 <= 80 * GB, "4-bit LLaMA-7B should fit 80GB: {need4}");
    }

    #[test]
    fn opt_family_search_shape() {
        let setup = TrainSetup { batch: 1, seq: 512 };
        let fam = opt_family();
        let best32 = largest_trainable(&fam, StatePreset::AdamW32, setup, 24 * GB);
        let best4 = largest_trainable(&fam, StatePreset::AdamW4, setup, 24 * GB);
        // 4-bit must unlock a strictly larger model at 24 GB.
        let idx = |name: Option<&str>| fam.iter().position(|m| Some(m.name) == name);
        assert!(idx(best4) > idx(best32), "4-bit {best4:?} vs 32-bit {best32:?}");
    }

    #[test]
    fn activation_bytes_scale_with_batch() {
        let cfg = TransformerConfig::small();
        let a1 = activation_bytes(&cfg, TrainSetup { batch: 1, seq: 64 });
        let a4 = activation_bytes(&cfg, TrainSetup { batch: 4, seq: 64 });
        assert_eq!(a4, a1 * 4);
    }
}
