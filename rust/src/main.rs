#![forbid(unsafe_code)]
//! `lowbit` — the launcher CLI for the 4-bit-optimizer training framework.
//!
//! Subcommands:
//!   train    train a transformer LM (builtin or PJRT engine)
//!   exp      regenerate a paper table/figure (table1..6, fig1..4, all)
//!   memory   memory estimator / largest-trainable-model search
//!   inspect  dump quantization map tables and quantizer behaviour
//!   trace    record / validate chrome://tracing span exports
//!   info     runtime + artifact status

use lowbit_opt::config::{RawConfig, RunConfig};
use lowbit_opt::data::{LmBatch, MarkovCorpus};
use lowbit_opt::exp::{self, ExpContext};
use lowbit_opt::memory::{training_bytes, StatePreset, TrainSetup, GB};
use lowbit_opt::model::{llama_family, opt_family, TransformerConfig};
use lowbit_opt::obs::trace::PHASE_NAMES;
use lowbit_opt::optim::{Hyper, Optimizer, Param};
use lowbit_opt::quant::{MapKind, QuantMap};
use lowbit_opt::train::{LrSchedule, Trainer, TransformerEngine};
use lowbit_opt::util::cli::Command;
use lowbit_opt::util::json::Json;
use lowbit_opt::util::rng::Pcg64;
use lowbit_opt::util::stats::fmt_bytes;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let code = match argv.first().map(|s| s.as_str()) {
        Some("train") => cmd_train(&argv[1..]),
        Some("exp") => cmd_exp(&argv[1..]),
        Some("memory") => cmd_memory(&argv[1..]),
        Some("inspect") => cmd_inspect(&argv[1..]),
        Some("trace") => cmd_trace(&argv[1..]),
        Some("info") => cmd_info(),
        Some("--help") | Some("-h") | None => {
            print_usage();
            0
        }
        Some(other) => {
            eprintln!("unknown subcommand '{other}'\n");
            print_usage();
            2
        }
    };
    std::process::exit(code);
}

fn print_usage() {
    println!(
        "lowbit — memory-efficient 4-bit optimizer training framework\n\n\
         USAGE: lowbit <subcommand> [options]\n\n\
         Subcommands:\n\
         \x20 train    train a transformer LM with any optimizer preset\n\
         \x20 exp      regenerate a paper table/figure (table1..table6, fig1..fig4, all)\n\
         \x20 memory   memory estimator + largest-trainable-model search\n\
         \x20 inspect  print quantization map tables\n\
         \x20 trace    record a chrome://tracing span export, or validate one\n\
         \x20 info     runtime + artifact status\n\n\
         Run `lowbit <subcommand> --help` for options."
    );
}

fn cmd_train(argv: &[String]) -> i32 {
    let cmd = Command::new("train", "train a transformer LM")
        .opt("config", "TOML config file", None)
        .opt(
            "set",
            "override, e.g. --set train.steps=100 (comma-separable)",
            None,
        )
        .opt("optimizer", "optimizer preset (overrides config)", None)
        .opt("steps", "training steps (overrides config)", None)
        .opt("engine", "builtin | pjrt", None)
        .opt("seed", "run seed", None)
        .opt(
            "threads",
            "step-engine worker threads, dense + compressed presets (0 = auto)",
            None,
        )
        .opt(
            "report-every",
            "print the optimizer's unified StepReport every N steps (0 = off)",
            Some("0"),
        )
        .flag("quiet", "suppress progress logs");
    let args = match cmd.parse(argv) {
        Ok(a) => a,
        Err(msg) => {
            eprintln!("{msg}");
            return 2;
        }
    };
    if args.has_flag("quiet") {
        lowbit_opt::util::set_log_level(1);
    }
    let mut raw = match args.get("config") {
        Some(path) => match RawConfig::load(path) {
            Ok(r) => r,
            Err(e) => {
                eprintln!("config error: {e}");
                return 2;
            }
        },
        None => RawConfig::default(),
    };
    if let Some(sets) = args.get("set") {
        for s in sets.split(',') {
            if let Err(e) = raw.set(s) {
                eprintln!("{e}");
                return 2;
            }
        }
    }
    for (key, target) in [
        ("optimizer", "optimizer.name"),
        ("steps", "train.steps"),
        ("engine", "train.engine"),
        ("seed", "train.seed"),
        ("threads", "train.threads"),
    ] {
        if let Some(v) = args.get(key) {
            raw.set(&format!("{target}={v}")).unwrap();
        }
    }
    let cfg = match RunConfig::from_raw(&raw) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("config error: {e}");
            return 2;
        }
    };
    match run_training(&cfg, args.get_usize("report-every", 0)) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("training failed: {e}");
            1
        }
    }
}

fn run_training(cfg: &RunConfig, report_every: usize) -> anyhow::Result<()> {
    println!(
        "model: {} params | optimizer: {} | engine: {} | steps: {} | threads: {}",
        cfg.model.n_params(),
        cfg.optimizer,
        cfg.engine,
        cfg.steps,
        if cfg.threads == 0 {
            "auto".to_string()
        } else {
            cfg.threads.to_string()
        }
    );
    let mut rng = Pcg64::seeded(cfg.seed);
    let schedule = LrSchedule::LinearWarmupDecay {
        peak: cfg.hyper.lr,
        warmup: cfg.warmup,
        total: cfg.steps,
    };
    let trainer = Trainer::new(cfg.steps, schedule).with_report_every(report_every);

    // Optimizer: presets + the PJRT fused variant.
    let mut opt: Box<dyn Optimizer> = if cfg.optimizer == "adamw4-fused" {
        let rt = lowbit_opt::runtime::Runtime::cpu()?;
        Box::new(lowbit_opt::runtime::fused::FusedAdamW4::load(
            &rt,
            &lowbit_opt::util::artifacts_dir(),
            cfg.hyper,
        )?)
    } else {
        lowbit_opt::optim::build_threaded(&cfg.optimizer, cfg.hyper, cfg.threads)
            .ok_or_else(|| anyhow::anyhow!("unknown optimizer {}", cfg.optimizer))?
    };

    let report = if cfg.engine == "pjrt" {
        let rt = lowbit_opt::runtime::Runtime::cpu()?;
        let mut step = lowbit_opt::runtime::PjrtTrainStep::load(
            &rt,
            &lowbit_opt::util::artifacts_dir(),
            &cfg.artifact_model,
        )?;
        let acfg = step.entry.cfg;
        let abatch = step.entry.batch;
        let mut params = acfg.init_params(&mut rng);
        step.check_params(&params)?;
        let mut data_rng = rng.split(1);
        let corpus = MarkovCorpus::new(acfg.vocab, cfg.seed ^ 0xC0DE);
        trainer.run(&mut params, opt.as_mut(), &mut step, |_| {
            corpus.sample(abatch, acfg.max_seq, &mut data_rng)
        })
    } else {
        let corpus = MarkovCorpus::new(cfg.model.vocab, cfg.seed ^ 0xC0DE);
        let engine = TransformerEngine::new(cfg.model);
        let mut params = cfg.model.init_params(&mut rng);
        let mut data_rng = rng.split(1);
        let mut engine_fn = |p: &[Param], b: &LmBatch| engine.loss_and_grads(p, b);
        let batch = cfg.batch;
        let max_seq = cfg.model.max_seq;
        trainer.run(&mut params, opt.as_mut(), &mut engine_fn, |_| {
            corpus.sample(batch, max_seq, &mut data_rng)
        })
    };

    let probes = 10.min(report.losses.len());
    for k in 0..probes {
        let i = k * report.losses.len().saturating_sub(1) / probes.max(1);
        println!("step {i:>5}  loss {:.4}", report.losses[i]);
    }
    println!(
        "done: {} steps in {:.1}s ({:.1} ms/step) | final loss {:.4} | \
         diverged: {} | optimizer state: {}",
        report.steps,
        report.total_seconds,
        report.step_seconds * 1e3,
        report.final_loss,
        report.diverged,
        fmt_bytes(report.state_bytes as u64)
    );
    Ok(())
}

fn cmd_exp(argv: &[String]) -> i32 {
    let cmd = Command::new("exp", "regenerate a paper table/figure")
        .opt("id", "experiment id (table1..table6, fig1..fig4, all)", Some("all"))
        .flag("full", "full scale (more steps/seeds; default is quick)")
        .flag(
            "measured",
            "table4: also run the executable offload pipeline and report \
             measured virtual-time speedups next to the analytic ones",
        );
    let args = match cmd.parse(argv) {
        Ok(a) => a,
        Err(msg) => {
            eprintln!("{msg}");
            return 2;
        }
    };
    let id = args
        .positional
        .first()
        .map(|s| s.as_str())
        .unwrap_or_else(|| args.get_or("id", "all"))
        .to_string();
    let ctx = ExpContext::new(!args.has_flag("full")).with_measured(args.has_flag("measured"));
    let ids: Vec<&str> = if id == "all" { exp::ids() } else { vec![id.as_str()] };
    for id in ids {
        eprintln!(
            "== running {id} ({}) ==",
            if ctx.quick { "quick" } else { "full" }
        );
        match exp::run(id, &ctx) {
            Some(rendered) => println!("{rendered}"),
            None => {
                eprintln!("unknown experiment '{id}'; known: {:?}", exp::ids());
                return 2;
            }
        }
    }
    0
}

fn cmd_memory(argv: &[String]) -> i32 {
    let cmd = Command::new("memory", "memory estimator")
        .opt("budget", "GPU memory budget in GB", Some("80"))
        .opt("batch", "batch size", Some("1"))
        .opt("seq", "sequence length", Some("512"));
    let args = match cmd.parse(argv) {
        Ok(a) => a,
        Err(msg) => {
            eprintln!("{msg}");
            return 2;
        }
    };
    let setup = TrainSetup {
        batch: args.get_usize("batch", 1),
        seq: args.get_usize("seq", 512),
    };
    let budget = args.get_usize("budget", 80) as u64 * GB;
    println!(
        "budget {} | batch {} | seq {}\n",
        fmt_bytes(budget),
        setup.batch,
        setup.seq
    );
    for fam in [opt_family(), llama_family()] {
        for m in fam {
            print!("{:<12}", m.name);
            for preset in [
                StatePreset::AdamW32,
                StatePreset::AdamW8,
                StatePreset::AdamW4,
                StatePreset::Factor4,
            ] {
                let need = training_bytes(&m.cfg, preset, setup);
                let fit = if need <= budget { "FITS" } else { "over" };
                print!(
                    "  {}={:.1}GB {}",
                    preset.label().split(' ').next().unwrap(),
                    need as f64 / GB as f64,
                    fit
                );
            }
            println!();
        }
    }
    0
}

fn cmd_inspect(argv: &[String]) -> i32 {
    let cmd =
        Command::new("inspect", "print quantization map tables").opt("bits", "bitwidth", Some("4"));
    let args = match cmd.parse(argv) {
        Ok(a) => a,
        Err(msg) => {
            eprintln!("{msg}");
            return 2;
        }
    };
    let bits = args.get_usize("bits", 4) as u8;
    for (kind, name) in [
        (MapKind::Linear, "Linear"),
        (MapKind::DynExp, "DE"),
        (MapKind::DynExpNoZero, "DE-0"),
    ] {
        for signed in [false, true] {
            let m = QuantMap::new(kind, bits, signed);
            println!(
                "{name} {bits}-bit {}: {} values, min positive {:.5}",
                if signed { "signed" } else { "unsigned" },
                m.len(),
                m.min_positive()
            );
            println!("  {:?}", m.values);
        }
    }
    0
}

fn cmd_trace(argv: &[String]) -> i32 {
    let cmd = Command::new(
        "trace",
        "record a chrome://tracing span export from a short training run, \
         or validate an existing export / bench-JSON reporting schema",
    )
    .opt("out", "output path for the recorded trace", Some("trace.json"))
    .opt("steps", "training steps to record", Some("5"))
    .opt("optimizer", "optimizer preset to trace", Some("adamw4"))
    .opt("threads", "engine worker threads (0 = auto)", Some("0"))
    .opt("seed", "run seed", Some("7"))
    .opt("check", "validate FILE as a chrome trace export (instead of recording)", None)
    .opt(
        "expect",
        "comma list of phase names --check requires to be present",
        Some("engine.A,engine.C"),
    )
    .opt(
        "check-bench",
        "validate FILE as BENCH_*.json: every run carries trace_summary/tier/sched \
         and fault counters (faults.retries / faults.rollbacks)",
        None,
    );
    let args = match cmd.parse(argv) {
        Ok(a) => a,
        Err(msg) => {
            eprintln!("{msg}");
            return 2;
        }
    };
    if let Some(path) = args.get("check") {
        return check_trace_file(path, args.get_or("expect", ""));
    }
    if let Some(path) = args.get("check-bench") {
        return check_bench_file(path);
    }

    // Record mode: a short builtin run on the tiny transformer, then dump
    // the spans the optimizer's rings currently hold (a rolling window
    // over the most recent steps; older spans fall off once a ring
    // fills, counted in the summary's `dropped`).
    let steps = args.get_usize("steps", 5);
    let preset = args.get_or("optimizer", "adamw4").to_string();
    let threads = args.get_usize("threads", 0);
    let seed = args.get_usize("seed", 7) as u64;
    let Some(mut opt) = lowbit_opt::optim::build_threaded(&preset, Hyper::default(), threads)
    else {
        eprintln!("unknown optimizer {preset}");
        return 2;
    };
    let cfg = TransformerConfig::tiny();
    let mut rng = Pcg64::seeded(seed);
    let mut params = cfg.init_params(&mut rng);
    let mut data_rng = rng.split(1);
    let corpus = MarkovCorpus::new(cfg.vocab, seed ^ 0xC0DE);
    let engine = TransformerEngine::new(cfg);
    let mut engine_fn = |p: &[Param], b: &LmBatch| engine.loss_and_grads(p, b);
    let trainer = Trainer::new(steps, LrSchedule::Constant(1e-3));
    trainer.run(&mut params, opt.as_mut(), &mut engine_fn, |_| {
        corpus.sample(2, cfg.max_seq, &mut data_rng)
    });
    match opt.as_ref().export_trace() {
        Some(doc) => {
            let out = args.get_or("out", "trace.json");
            let events = doc.get("traceEvents").and_then(|e| e.as_arr()).map_or(0, |a| a.len());
            if let Err(e) = std::fs::write(out, doc.to_string()) {
                eprintln!("cannot write {out}: {e}");
                return 1;
            }
            println!("wrote {out}: {events} span events from a {steps}-step run");
            0
        }
        None => {
            eprintln!(
                "this build records no spans — rebuild with `--features trace` \
                 (and use an engine-backed optimizer preset)"
            );
            1
        }
    }
}

/// `lowbit trace --check`: the file must parse, hold a non-empty
/// `traceEvents` array of complete-event (`"ph":"X"`) entries with finite
/// non-negative timestamps, use only known phase names, and contain every
/// phase listed in `--expect`.
fn check_trace_file(path: &str, expect: &str) -> i32 {
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("cannot read {path}: {e}");
            return 1;
        }
    };
    let doc = match Json::parse(&text) {
        Ok(d) => d,
        Err(e) => {
            eprintln!("{path}: invalid JSON: {e}");
            return 1;
        }
    };
    let Some(events) = doc.get("traceEvents").and_then(|e| e.as_arr()) else {
        eprintln!("{path}: no traceEvents array");
        return 1;
    };
    if events.is_empty() {
        eprintln!("{path}: traceEvents is empty (was the run built with --features trace?)");
        return 1;
    }
    let mut seen: Vec<&str> = Vec::new();
    for (i, ev) in events.iter().enumerate() {
        let Some(name) = ev.get("name").and_then(|n| n.as_str()) else {
            eprintln!("{path}: event {i} has no name");
            return 1;
        };
        if !PHASE_NAMES.contains(&name) {
            eprintln!("{path}: event {i} has unknown phase name '{name}'");
            return 1;
        }
        if ev.get("ph").and_then(|p| p.as_str()) != Some("X") {
            eprintln!("{path}: event {i} is not a complete event (ph != \"X\")");
            return 1;
        }
        for key in ["ts", "dur"] {
            match ev.get(key).and_then(|v| v.as_f64()) {
                Some(x) if x.is_finite() && x >= 0.0 => {}
                _ => {
                    eprintln!("{path}: event {i} has missing or invalid '{key}'");
                    return 1;
                }
            }
        }
        if !seen.contains(&name) {
            seen.push(name);
        }
    }
    for want in expect.split(',').map(str::trim).filter(|s| !s.is_empty()) {
        if !seen.contains(&want) {
            eprintln!("{path}: expected phase '{want}' absent (saw {seen:?})");
            return 1;
        }
    }
    println!("{path}: OK — {} events across phases {seen:?}", events.len());
    0
}

/// `lowbit trace --check-bench`: the file must be a top-level array of run
/// objects, and every run must carry the unified-reporting schema keys —
/// `trace_summary` (with its boolean `enabled` marker), `tier`, `sched`,
/// and `faults` with numeric `retries` / `rollbacks` counters (zeros on a
/// clean run — the key must exist so fault regressions are visible).
fn check_bench_file(path: &str) -> i32 {
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("cannot read {path}: {e}");
            return 1;
        }
    };
    let runs = match Json::parse(&text) {
        Ok(Json::Arr(v)) => v,
        Ok(_) => {
            eprintln!("{path}: expected a top-level array of bench runs");
            return 1;
        }
        Err(e) => {
            eprintln!("{path}: invalid JSON: {e}");
            return 1;
        }
    };
    if runs.is_empty() {
        eprintln!("{path}: no bench runs");
        return 1;
    }
    for (i, run) in runs.iter().enumerate() {
        for key in ["trace_summary", "tier", "sched", "faults"] {
            if run.get(key).is_none() {
                eprintln!("{path}: run {i} missing key '{key}'");
                return 1;
            }
        }
        if run
            .get("trace_summary")
            .and_then(|t| t.get("enabled"))
            .and_then(Json::as_bool)
            .is_none()
        {
            eprintln!("{path}: run {i} trace_summary lacks boolean 'enabled'");
            return 1;
        }
        for key in ["retries", "rollbacks"] {
            if run
                .get("faults")
                .and_then(|f| f.get(key))
                .and_then(Json::as_f64)
                .is_none()
            {
                eprintln!("{path}: run {i} faults lacks numeric '{key}'");
                return 1;
            }
        }
    }
    println!(
        "{path}: OK — {} runs carry trace_summary/tier/sched/faults",
        runs.len()
    );
    0
}

fn cmd_info() -> i32 {
    println!("lowbit-opt — Memory Efficient Optimizers with 4-bit States (NeurIPS'23)");
    let dir = lowbit_opt::util::artifacts_dir();
    let manifest = format!("{dir}/manifest.json");
    if std::path::Path::new(&manifest).exists() {
        println!("artifacts: {dir} (present)");
        match lowbit_opt::runtime::Runtime::cpu() {
            Ok(rt) => println!("PJRT platform: {}", rt.platform()),
            Err(e) => println!("PJRT unavailable: {e}"),
        }
        match lowbit_opt::runtime::ArtifactManifest::load(&dir) {
            Ok(m) => {
                for model in &m.models {
                    println!(
                        "  model '{}': {} tensors, batch {}, vocab {}",
                        model.name,
                        model.params.len(),
                        model.batch,
                        model.cfg.vocab
                    );
                }
                println!(
                    "  fused_adamw4: chunk {} block {}",
                    m.fused_chunk, m.fused_block
                );
            }
            Err(e) => println!("  manifest unreadable: {e}"),
        }
    } else {
        println!("artifacts: missing — run `make artifacts`");
    }
    0
}
