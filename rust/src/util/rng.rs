#![forbid(unsafe_code)]
//! Deterministic, splittable pseudo-random number generation.
//!
//! Every stochastic component of the framework (data synthesis, parameter
//! init, stochastic rounding, experiment seeds) draws from [`Pcg64`], a
//! PCG-XSL-RR 128/64 generator. We implement it locally because the offline
//! crate set has no `rand`; the implementation follows O'Neill's reference
//! constants and is fully deterministic across platforms.

/// PCG-XSL-RR 128/64: 128-bit LCG state, 64-bit xorshift-rotate output.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Create a generator from a seed and a stream id. Different streams
    /// with the same seed are statistically independent.
    pub fn new(seed: u64, stream: u64) -> Self {
        let initseq = ((stream as u128) << 64) | 0xda3e_39cb_94b9_5bdb;
        let mut rng = Pcg64 {
            state: 0,
            inc: (initseq << 1) | 1,
        };
        rng.step();
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.step();
        rng
    }

    /// Single-argument constructor on the default stream.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    #[inline]
    fn step(&mut self) {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
    }

    /// Next uniform `u64`.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.step();
        let s = self.state;
        let xored = ((s >> 64) as u64) ^ (s as u64);
        let rot = (s >> 122) as u32;
        xored.rotate_right(rot)
    }

    /// Next uniform `u32`.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform float in `[0, 1)` with 53-bit resolution.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform float in `[0, 1)` (f32).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in `[lo, hi)`.
    ///
    /// `lo + (hi - lo) * u` with `u < 1` can still round up to exactly
    /// `hi` (e.g. `lo = 0.0, hi = 1e-45`: the product rounds to `hi`),
    /// which would violate the documented half-open interval; clamp such
    /// results to the largest float strictly below `hi`.
    #[inline]
    pub fn uniform(&mut self, lo: f32, hi: f32) -> f32 {
        let x = lo + (hi - lo) * self.next_f32();
        if x >= hi {
            next_below(hi).max(lo)
        } else {
            x
        }
    }

    /// Uniform integer in `[0, n)` via Lemire's multiply-shift (unbiased
    /// enough for our workloads; n is tiny relative to 2^64).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via Box-Muller (cached second value dropped for
    /// simplicity; the generators are cheap).
    pub fn normal(&mut self) -> f32 {
        loop {
            let u1 = self.next_f64();
            if u1 > 1e-12 {
                let u2 = self.next_f64();
                let r = (-2.0 * u1.ln()).sqrt();
                return (r * (2.0 * std::f64::consts::PI * u2).cos()) as f32;
            }
        }
    }

    /// Normal with explicit mean / std.
    pub fn normal_ms(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal()
    }

    /// Fill a slice with N(0, std^2) samples.
    pub fn fill_normal(&mut self, out: &mut [f32], std: f32) {
        for v in out.iter_mut() {
            *v = self.normal() * std;
        }
    }

    /// Derive an independent child generator; used to give each tensor /
    /// worker / experiment arm its own stream deterministically.
    pub fn split(&mut self, tag: u64) -> Pcg64 {
        let seed = self.next_u64() ^ tag.wrapping_mul(0x9e37_79b9_7f4a_7c15);
        Pcg64::new(seed, tag)
    }

    /// Sample an index from unnormalized weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut x = self.next_f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

/// Largest f32 strictly below a finite `x` (bit-decrement toward -inf).
#[inline]
fn next_below(x: f32) -> f32 {
    if x == 0.0 {
        // Covers +0.0 and -0.0: the next value down is -MIN_SUBNORMAL.
        return -f32::from_bits(1);
    }
    let bits = x.to_bits();
    if x > 0.0 {
        f32::from_bits(bits - 1)
    } else {
        f32::from_bits(bits + 1)
    }
}

/// Hash arbitrary labels into a seed; lets experiments derive stable seeds
/// from human-readable names (`seed_from("table1/rank1-linear/run3")`).
pub fn seed_from(label: &str) -> u64 {
    // FNV-1a 64-bit.
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in label.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg64::seeded(42);
        let mut b = Pcg64::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg64::new(42, 0);
        let mut b = Pcg64::new(42, 1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_range() {
        let mut r = Pcg64::seeded(7);
        for _ in 0..10_000 {
            let x = r.next_f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_stays_below_hi() {
        // Regression: with a tiny [lo, hi) span, `lo + (hi - lo) * u`
        // rounds up to exactly `hi` for large `u`, breaking the documented
        // half-open interval.
        let tiny = f32::from_bits(1); // smallest positive subnormal
        let mut r = Pcg64::seeded(13);
        let mut saw_below = false;
        for _ in 0..10_000 {
            let x = r.uniform(0.0, tiny);
            assert!((0.0..tiny).contains(&x), "x = {x:e} not in [0, {tiny:e})");
            saw_below = saw_below || x < tiny;
        }
        assert!(saw_below);
        // Degenerate span returns lo.
        assert_eq!(r.uniform(0.25, 0.25), 0.25);
        // Wide spans are unaffected.
        for _ in 0..10_000 {
            let x = r.uniform(-2.0, 3.0);
            assert!((-2.0..3.0).contains(&x));
        }
    }

    #[test]
    fn next_below_is_adjacent() {
        assert!(next_below(1.0) < 1.0);
        assert_eq!(next_below(1.0), f32::from_bits(1.0f32.to_bits() - 1));
        assert!(next_below(0.0) < 0.0);
        assert!(next_below(-1.0) < -1.0);
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Pcg64::seeded(9);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            seen[r.below(7)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::seeded(3);
        let n = 50_000;
        let mut sum = 0.0f64;
        let mut sumsq = 0.0f64;
        for _ in 0..n {
            let x = r.normal() as f64;
            sum += x;
            sumsq += x * x;
        }
        let mean = sum / n as f64;
        let var = sumsq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn seed_from_stable() {
        assert_eq!(seed_from("abc"), seed_from("abc"));
        assert_ne!(seed_from("abc"), seed_from("abd"));
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Pcg64::seeded(11);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..4000 {
            counts[r.categorical(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[2] > counts[0] * 2);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::seeded(5);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
