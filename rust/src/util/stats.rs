#![forbid(unsafe_code)]
//! Timing and summary-statistics helpers shared by the trainer, the bench
//! harness, and the experiment modules.

use std::time::Instant;

/// Wall-clock stopwatch.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Timer {
        Timer {
            start: Instant::now(),
        }
    }

    pub fn seconds(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn millis(&self) -> f64 {
        self.seconds() * 1e3
    }
}

/// Online mean/variance/min/max accumulator (Welford).
#[derive(Clone, Debug, Default)]
pub struct Summary {
    pub n: usize,
    mean: f64,
    m2: f64,
    pub min: f64,
    pub max: f64,
}

impl Summary {
    pub fn new() -> Summary {
        Summary {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        if x < self.min {
            self.min = x;
        }
        if x > self.max {
            self.max = x;
        }
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn extend(&mut self, xs: &[f64]) {
        for &x in xs {
            self.push(x);
        }
    }
}

/// Summarize a slice directly.
pub fn summarize(xs: &[f64]) -> Summary {
    let mut s = Summary::new();
    s.extend(xs);
    s
}

/// Median of a slice (copies + sorts).
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

/// Percentile in [0, 100] with linear interpolation.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let frac = rank - lo as f64;
        v[lo] * (1.0 - frac) + v[hi] * frac
    }
}

/// Fixed-range histogram; used by the Fig. 1/3 reproductions.
#[derive(Clone, Debug)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub counts: Vec<u64>,
    pub underflow: u64,
    pub overflow: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, bins: usize) -> Histogram {
        assert!(hi > lo && bins > 0);
        Histogram {
            lo,
            hi,
            counts: vec![0; bins],
            underflow: 0,
            overflow: 0,
        }
    }

    pub fn push(&mut self, x: f64) {
        if !x.is_finite() || x < self.lo {
            self.underflow += 1;
            return;
        }
        if x >= self.hi {
            self.overflow += 1;
            return;
        }
        let nbins = self.counts.len();
        let b = ((x - self.lo) / (self.hi - self.lo) * nbins as f64) as usize;
        self.counts[b.min(nbins - 1)] += 1;
    }

    pub fn extend(&mut self, xs: impl IntoIterator<Item = f64>) {
        for x in xs {
            self.push(x);
        }
    }

    pub fn total(&self) -> u64 {
        self.counts.iter().sum::<u64>() + self.underflow + self.overflow
    }

    /// Normalized densities per bin.
    pub fn density(&self) -> Vec<f64> {
        let t = self.total().max(1) as f64;
        self.counts.iter().map(|&c| c as f64 / t).collect()
    }

    /// Render an ASCII sparkline of the histogram (for terminal output of
    /// the figure reproductions).
    pub fn sparkline(&self) -> String {
        const LEVELS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
        let max = self.counts.iter().copied().max().unwrap_or(0).max(1) as f64;
        self.counts
            .iter()
            .map(|&c| {
                let idx = ((c as f64 / max) * 7.0).round() as usize;
                LEVELS[idx.min(7)]
            })
            .collect()
    }
}

/// Format a byte count human-readably.
pub fn fmt_bytes(b: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KB", "MB", "GB", "TB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{b} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

/// One step of Kahan–Babuška–Neumaier compensated summation:
/// accumulate `x` into the running `(sum, comp)` pair. The final value
/// is `sum + comp`. Unlike plain Kahan, the Neumaier branch keeps the
/// exact rounding error of each addition regardless of which operand is
/// larger, so the compensated total carries only second-order (O(u²))
/// error. The engine uses it for Adafactor's column and RMS reductions:
/// per-shard `(sum, comp)` partials merged in shard order agree with the
/// element-order sequential sum exactly in the single-shard case and to
/// the last f64 rounding everywhere else (see `engine/dense.rs`).
#[inline]
pub fn neumaier_add(sum: &mut f64, comp: &mut f64, x: f64) {
    let t = *sum + x;
    *comp += if sum.abs() >= x.abs() {
        (*sum - t) + x
    } else {
        (x - t) + *sum
    };
    *sum = t;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn neumaier_recovers_cancelled_terms() {
        // Naive summation of [1, 1e100, 1, -1e100] gives 0; the
        // compensated total recovers the exact 2.
        let (mut s, mut c) = (0.0f64, 0.0f64);
        for x in [1.0, 1e100, 1.0, -1e100] {
            neumaier_add(&mut s, &mut c, x);
        }
        assert_eq!(s + c, 2.0);
        // Plain accumulation of many small positives drifts; the
        // compensated sum stays exact while the total fits in ~2 f64s.
        let (mut s, mut c) = (0.0f64, 0.0f64);
        let naive: f64 = (0..1_000_000).map(|_| 0.1f64).sum();
        for _ in 0..1_000_000 {
            neumaier_add(&mut s, &mut c, 0.1);
        }
        let exact = 100_000.0f64;
        assert!((s + c - exact).abs() < (naive - exact).abs());
        assert!((s + c - exact).abs() < 1e-9);
    }

    #[test]
    fn summary_mean_std() {
        let s = summarize(&[1.0, 2.0, 3.0, 4.0]);
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert!((s.std() - (5.0f64 / 3.0).sqrt()).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
    }

    #[test]
    fn median_even_odd() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
    }

    #[test]
    fn percentile_interp() {
        let xs = [0.0, 10.0];
        assert!((percentile(&xs, 50.0) - 5.0).abs() < 1e-12);
        assert_eq!(percentile(&xs, 0.0), 0.0);
        assert_eq!(percentile(&xs, 100.0), 10.0);
    }

    #[test]
    fn histogram_binning() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        h.extend([0.5, 1.5, 1.6, 9.99, -1.0, 10.0, f64::NAN].into_iter());
        assert_eq!(h.counts[0], 1);
        assert_eq!(h.counts[1], 2);
        assert_eq!(h.counts[9], 1);
        assert_eq!(h.underflow, 2); // -1 and NaN
        assert_eq!(h.overflow, 1);
        assert_eq!(h.total(), 7);
        assert_eq!(h.sparkline().chars().count(), 10);
    }

    #[test]
    fn bytes_format() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2048), "2.00 KB");
        assert_eq!(fmt_bytes(3 * 1024 * 1024), "3.00 MB");
    }
}
