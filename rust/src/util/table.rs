#![forbid(unsafe_code)]
//! Markdown-style table rendering for the experiment harness. Every paper
//! table reproduction builds a [`Table`] and prints it; the same structure
//! is serialized to `results/*.json`.

use super::json::Json;

#[derive(Clone, Debug)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(
            cells.len(),
            self.headers.len(),
            "row width mismatch in table '{}'",
            self.title
        );
        self.rows.push(cells.to_vec());
    }

    pub fn row_strs(&mut self, cells: &[&str]) {
        self.row(&cells.iter().map(|s| s.to_string()).collect::<Vec<_>>());
    }

    /// Render as a column-aligned markdown table.
    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.chars().count());
            }
        }
        let line = |cells: &[String]| -> String {
            let mut s = String::from("|");
            for i in 0..ncol {
                let c = cells.get(i).map(|x| x.as_str()).unwrap_or("");
                let pad = widths[i] - c.chars().count();
                s.push(' ');
                s.push_str(c);
                s.push_str(&" ".repeat(pad));
                s.push_str(" |");
            }
            s
        };
        let mut out = format!("\n## {}\n\n", self.title);
        out.push_str(&line(&self.headers));
        out.push('\n');
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&"-".repeat(w + 2));
            sep.push('|');
        }
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row));
            out.push('\n');
        }
        out
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("title", Json::Str(self.title.clone()));
        o.set(
            "headers",
            Json::Arr(self.headers.iter().map(|h| Json::Str(h.clone())).collect()),
        );
        o.set(
            "rows",
            Json::Arr(
                self.rows
                    .iter()
                    .map(|r| Json::Arr(r.iter().map(|c| Json::Str(c.clone())).collect()))
                    .collect(),
            ),
        );
        o
    }
}

/// `mean ± std` cell formatting used across the table reproductions.
pub fn pm(mean: f64, std: f64, decimals: usize) -> String {
    format!("{mean:.prec$} ± {std:.prec$}", prec = decimals)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("Demo", &["Optimizer", "Score"]);
        t.row_strs(&["32-bit AdamW", "67.7"]);
        t.row_strs(&["4-bit AdamW (ours)", "67.8"]);
        let r = t.render();
        assert!(r.contains("## Demo"));
        assert!(r.contains("| 4-bit AdamW (ours) | 67.8  |"));
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn width_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row_strs(&["only one"]);
    }

    #[test]
    fn json_roundtrip() {
        let mut t = Table::new("x", &["a"]);
        t.row_strs(&["1"]);
        let j = t.to_json();
        assert_eq!(j.get("title").unwrap().as_str(), Some("x"));
        assert_eq!(
            j.get("rows").unwrap().idx(0).unwrap().idx(0).unwrap().as_str(),
            Some("1")
        );
    }

    #[test]
    fn pm_format() {
        assert_eq!(pm(67.75, 0.51, 1), "67.8 ± 0.5");
    }
}
