#![forbid(unsafe_code)]
//! Minimal JSON value model, parser, and pretty-printer.
//!
//! The offline crate set has no `serde`/`serde_json`, so the framework
//! carries its own small implementation. It supports the full JSON grammar
//! we need for: config files, experiment result dumps (`results/*.json`),
//! and golden parity vectors emitted by the python compile path.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are ordered (BTreeMap) so output is stable.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Insert into an object; panics when self is not an object (programmer
    /// error, used only on the construction side).
    pub fn set(&mut self, key: &str, val: Json) -> &mut Self {
        match self {
            Json::Obj(m) => {
                m.insert(key.to_string(), val);
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Array of numbers → Vec<f32>; None when any element is not a number.
    pub fn as_f32_vec(&self) -> Option<Vec<f32>> {
        let arr = self.as_arr()?;
        let mut out = Vec::with_capacity(arr.len());
        for v in arr {
            out.push(v.as_f64()? as f32);
        }
        Some(out)
    }

    pub fn as_usize_vec(&self) -> Option<Vec<usize>> {
        let arr = self.as_arr()?;
        let mut out = Vec::with_capacity(arr.len());
        for v in arr {
            out.push(v.as_f64()? as usize);
        }
        Some(out)
    }

    pub fn from_f32s(xs: &[f32]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    pub fn from_usizes(xs: &[usize]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    pub fn from_strs(xs: &[&str]) -> Json {
        Json::Arr(xs.iter().map(|s| Json::Str(s.to_string())).collect())
    }

    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty serialization with 2-space indent.
    pub fn pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s.push('\n');
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    if *x == x.trunc() && x.abs() < 1e15 {
                        out.push_str(&format!("{}", *x as i64));
                    } else {
                        out.push_str(&format!("{x}"));
                    }
                } else {
                    // JSON has no Inf/NaN; encode as null (we record such
                    // runs as "diverged" before serialization anyway).
                    out.push_str("null");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            out.push_str(&format!("\\u{:04x}", c as u32))
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(ind) = indent {
                        out.push('\n');
                        out.push_str(&" ".repeat(ind * (depth + 1)));
                    }
                    x.write(out, indent, depth + 1);
                }
                if indent.is_some() && !v.is_empty() {
                    out.push('\n');
                    out.push_str(&" ".repeat(indent.unwrap() * depth));
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(ind) = indent {
                        out.push('\n');
                        out.push_str(&" ".repeat(ind * (depth + 1)));
                    }
                    Json::Str(k.clone()).write(out, None, 0);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if indent.is_some() && !m.is_empty() {
                    out.push('\n');
                    out.push_str(&" ".repeat(indent.unwrap() * depth));
                }
                out.push('}');
            }
        }
    }

    /// Parse a JSON document. Returns an error string with byte position on
    /// malformed input.
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser {
            b: text.as_bytes(),
            i: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            // Python may emit these for inf/nan; accept them leniently.
            Some(b'I') => self.lit("Infinity", Json::Num(f64::INFINITY)),
            Some(b'N') => self.lit("NaN", Json::Num(f64::NAN)),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
            if self.peek() == Some(b'I') {
                return self.lit("Infinity", Json::Num(f64::NEG_INFINITY));
            }
        }
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-') {
                self.i += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| "bad \\u escape".to_string())?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| "bad \\u escape".to_string())?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(format!("bad escape at byte {}", self.i)),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Copy a UTF-8 sequence verbatim.
                    let ch_start = self.i;
                    self.i += 1;
                    while self.i < self.b.len() && (self.b[self.i] & 0xC0) == 0x80 {
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[ch_start..self.i])
                            .map_err(|_| "invalid utf8".to_string())?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": "hi\nthere", "c": null, "d": true}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
        assert_eq!(v.get("a").unwrap().idx(2).unwrap().as_f64(), Some(-300.0));
        assert_eq!(v.get("b").unwrap().as_str(), Some("hi\nthere"));
    }

    #[test]
    fn nested() {
        let v = Json::parse(r#"[{"x": {"y": [[]]}}, []]"#).unwrap();
        assert!(v.idx(0).unwrap().get("x").unwrap().get("y").is_some());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn float_vec() {
        let v = Json::parse("[1.5, 2, 3.25]").unwrap();
        assert_eq!(v.as_f32_vec(), Some(vec![1.5, 2.0, 3.25]));
    }

    #[test]
    fn builder_and_pretty() {
        let mut o = Json::obj();
        o.set("name", Json::Str("t".into()))
            .set("vals", Json::from_f32s(&[1.0, 2.0]));
        let p = o.pretty();
        assert!(p.contains("\"name\""));
        assert_eq!(Json::parse(&p).unwrap(), o);
    }

    #[test]
    fn python_inf_nan_accepted() {
        let v = Json::parse("[Infinity, -Infinity, NaN]").unwrap();
        assert_eq!(v.idx(0).unwrap().as_f64(), Some(f64::INFINITY));
        assert!(v.idx(2).unwrap().as_f64().unwrap().is_nan());
    }

    #[test]
    fn unicode_string() {
        let v = Json::parse(r#""héllo é""#).unwrap();
        assert_eq!(v.as_str(), Some("héllo é"));
    }
}
