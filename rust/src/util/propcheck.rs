#![forbid(unsafe_code)]
//! A miniature property-based testing driver (offline substitute for
//! `proptest`). A property is a closure over a [`Gen`]; the driver runs it
//! for `cases` seeded iterations and, on failure, retries with the failing
//! seed reported so the case can be reproduced exactly.
//!
//! Shrinking is deliberately minimal: generators are encouraged to draw
//! sizes first so that failures at small sizes are found early (sizes grow
//! with the case index).

use super::rng::Pcg64;

/// Generation context handed to properties: a seeded RNG plus a `size`
/// hint that ramps up over the run.
pub struct Gen {
    pub rng: Pcg64,
    pub size: usize,
    pub case: usize,
}

impl Gen {
    /// A length in `[1, size]` (never zero — most tensor properties need
    /// non-empty input; ask for `len0` when zero-length matters).
    pub fn len(&mut self) -> usize {
        1 + self.rng.below(self.size.max(1))
    }

    /// A length in `[0, size]`.
    pub fn len0(&mut self) -> usize {
        self.rng.below(self.size + 1)
    }

    /// A "nice" float: mixes normals, exact zeros, subnormal-ish tiny
    /// values and large outliers — the distributions that matter for
    /// quantization code.
    pub fn f32(&mut self) -> f32 {
        match self.rng.below(10) {
            0 => 0.0,
            1 => self.rng.normal() * 1e-8,
            2 => self.rng.normal() * 1e4,
            3 => -self.rng.next_f32(),
            _ => self.rng.normal(),
        }
    }

    /// Non-negative variant (second-moment-like).
    pub fn f32_nonneg(&mut self) -> f32 {
        self.f32().abs()
    }

    /// Vector of `n` floats.
    pub fn vec_f32(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.f32()).collect()
    }

    pub fn vec_f32_nonneg(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.f32_nonneg()).collect()
    }

    /// Pick one of the provided items.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len())]
    }

    pub fn bool(&mut self) -> bool {
        self.rng.below(2) == 0
    }
}

/// Run `prop` for `cases` cases. Panics (failing the enclosing `#[test]`)
/// with the seed and case number on the first property violation, which the
/// property signals by returning `Err(message)`.
pub fn check<F>(name: &str, cases: usize, mut prop: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    check_seeded(name, cases, 0xC0FFEE, &mut prop);
}

/// Same as [`check`] with an explicit base seed (used to reproduce a
/// reported failure).
pub fn check_seeded<F>(name: &str, cases: usize, base_seed: u64, prop: &mut F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = base_seed ^ (case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        // Sizes ramp from small to larger so that minimal counterexamples
        // surface first.
        let size = 2 + (case * 64) / cases.max(1);
        let mut g = Gen {
            rng: Pcg64::new(seed, 77),
            size,
            case,
        };
        if let Err(msg) = prop(&mut g) {
            panic!(
                "property '{name}' failed at case {case} (base_seed={base_seed:#x}, \
                 case_seed={seed:#x}, size={size}): {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut ran = 0;
        check("tautology", 50, |g| {
            ran += 1;
            let n = g.len();
            if n >= 1 {
                Ok(())
            } else {
                Err("len() returned 0".into())
            }
        });
        assert_eq!(ran, 50);
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn failing_property_panics_with_seed() {
        check("fails", 10, |g| {
            if g.case < 3 {
                Ok(())
            } else {
                Err("boom".into())
            }
        });
    }

    #[test]
    fn generator_mixes_distributions() {
        let mut zeros = 0;
        let mut big = 0;
        check("dist", 200, |g| {
            let x = g.f32();
            if x == 0.0 {
                zeros += 1;
            }
            if x.abs() > 100.0 {
                big += 1;
            }
            Ok(())
        });
        assert!(zeros > 0, "expected some exact zeros");
        assert!(big > 0, "expected some outliers");
    }
}
