#![forbid(unsafe_code)]
//! A small command-line argument parser (the offline crate set has no
//! `clap`). Supports subcommands, `--key value`, `--key=value`, `--flag`,
//! and positional arguments, with generated `--help` text.

use std::collections::BTreeMap;

/// Declarative description of one option.
#[derive(Clone, Debug)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub is_flag: bool,
}

/// Parsed arguments for one (sub)command.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub values: BTreeMap<String, String>,
    pub flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .map(|s| s.parse().unwrap_or_else(|_| panic!("--{name} expects an integer, got '{s}'")))
            .unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .map(|s| s.parse().unwrap_or_else(|_| panic!("--{name} expects a number, got '{s}'")))
            .unwrap_or(default)
    }

    pub fn get_f32(&self, name: &str, default: f32) -> f32 {
        self.get_f64(name, default as f64) as f32
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

/// A command with its option table; `parse` consumes an arg vector.
pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    pub opts: Vec<OptSpec>,
}

impl Command {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Command {
            name,
            about,
            opts: Vec::new(),
        }
    }

    pub fn opt(mut self, name: &'static str, help: &'static str, default: Option<&'static str>) -> Self {
        self.opts.push(OptSpec {
            name,
            help,
            default,
            is_flag: false,
        });
        self
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec {
            name,
            help,
            default: None,
            is_flag: true,
        });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nOptions:\n", self.name, self.about);
        for o in &self.opts {
            let head = if o.is_flag {
                format!("  --{}", o.name)
            } else {
                format!("  --{} <v>", o.name)
            };
            let dflt = o
                .default
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            s.push_str(&format!("{head:28} {}{}\n", o.help, dflt));
        }
        s
    }

    /// Parse an argv slice (without program/subcommand names).
    pub fn parse(&self, argv: &[String]) -> Result<Args, String> {
        let mut args = Args::default();
        for o in &self.opts {
            if let Some(d) = o.default {
                args.values.insert(o.name.to_string(), d.to_string());
            }
        }
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if a == "--help" || a == "-h" {
                return Err(self.usage());
            }
            if let Some(rest) = a.strip_prefix("--") {
                let (key, inline) = match rest.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (rest.to_string(), None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|o| o.name == key)
                    .ok_or_else(|| format!("unknown option --{key}\n\n{}", self.usage()))?;
                if spec.is_flag {
                    if inline.is_some() {
                        return Err(format!("--{key} is a flag and takes no value"));
                    }
                    args.flags.push(key);
                } else {
                    let val = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| format!("--{key} expects a value"))?
                        }
                    };
                    args.values.insert(key, val);
                }
            } else {
                args.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(args)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cmd() -> Command {
        Command::new("train", "train a model")
            .opt("steps", "number of steps", Some("100"))
            .opt("lr", "learning rate", Some("1e-3"))
            .opt("optimizer", "optimizer preset", Some("adamw4"))
            .flag("verbose", "chatty output")
    }

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_apply() {
        let a = cmd().parse(&sv(&[])).unwrap();
        assert_eq!(a.get_usize("steps", 0), 100);
        assert_eq!(a.get("optimizer"), Some("adamw4"));
        assert!(!a.has_flag("verbose"));
    }

    #[test]
    fn equals_and_space_forms() {
        let a = cmd()
            .parse(&sv(&["--steps=5", "--lr", "0.1", "--verbose", "pos1"]))
            .unwrap();
        assert_eq!(a.get_usize("steps", 0), 5);
        assert_eq!(a.get_f64("lr", 0.0), 0.1);
        assert!(a.has_flag("verbose"));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn unknown_option_errors() {
        assert!(cmd().parse(&sv(&["--nope", "1"])).is_err());
    }

    #[test]
    fn missing_value_errors() {
        assert!(cmd().parse(&sv(&["--steps"])).is_err());
    }

    #[test]
    fn help_contains_options() {
        let err = cmd().parse(&sv(&["--help"])).unwrap_err();
        assert!(err.contains("--steps"));
        assert!(err.contains("learning rate"));
    }
}
