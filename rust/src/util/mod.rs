#![forbid(unsafe_code)]
//! Shared substrate: RNG, JSON, CLI parsing, stats, tables, property
//! testing, and a tiny logger. Everything here exists because the offline
//! crate set ships no `rand`/`serde`/`clap`/`proptest`/`criterion`.

pub mod cli;
pub mod json;
pub mod propcheck;
pub mod rng;
pub mod stats;
pub mod table;

use std::io::Write;
use std::sync::atomic::{AtomicU8, Ordering};

static LOG_LEVEL: AtomicU8 = AtomicU8::new(2); // 0=quiet 1=warn 2=info 3=debug

pub fn set_log_level(level: u8) {
    LOG_LEVEL.store(level, Ordering::Relaxed);
}

pub fn log_enabled(level: u8) -> bool {
    LOG_LEVEL.load(Ordering::Relaxed) >= level
}

pub fn log(level: u8, tag: &str, msg: &str) {
    if log_enabled(level) {
        let mut err = std::io::stderr().lock();
        let _ = writeln!(err, "[{tag}] {msg}");
    }
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => { $crate::util::log(2, "info", &format!($($arg)*)) };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)*) => { $crate::util::log(1, "warn", &format!($($arg)*)) };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => { $crate::util::log(3, "debug", &format!($($arg)*)) };
}

/// Ensure a directory exists (mkdir -p).
pub fn ensure_dir(path: &str) -> std::io::Result<()> {
    std::fs::create_dir_all(path)
}

/// Write a string to a file, creating parent directories.
pub fn write_file(path: &str, contents: &str) -> std::io::Result<()> {
    if let Some(parent) = std::path::Path::new(path).parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(path, contents)
}

/// Repository-relative results directory; honors `LOWBIT_RESULTS_DIR`.
pub fn results_dir() -> String {
    std::env::var("LOWBIT_RESULTS_DIR").unwrap_or_else(|_| "results".to_string())
}

/// Repository-relative artifacts directory; honors `LOWBIT_ARTIFACTS_DIR`.
pub fn artifacts_dir() -> String {
    std::env::var("LOWBIT_ARTIFACTS_DIR").unwrap_or_else(|_| "artifacts".to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn write_file_creates_dirs() {
        let dir = std::env::temp_dir().join(format!("lowbit_util_{}", std::process::id()));
        let path = dir.join("a/b/c.txt");
        write_file(path.to_str().unwrap(), "hi").unwrap();
        assert_eq!(std::fs::read_to_string(&path).unwrap(), "hi");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
