#![forbid(unsafe_code)]
//! Quantization mappings **T** : code → value (paper §2.2, App. E.2).
//!
//! A mapping is a sorted table of `2^b` (or `2^b - 1` for DE-0)
//! representable values inside the unit interval (`[0,1]` unsigned,
//! `[-1,1]` signed). Encoding is `argmin_i |n - T(i)|` with ties resolved
//! to the smaller index — implemented branch-free as a partition over
//! precomputed midpoints, bit-exactly matching `jnp.argmin` in the python
//! oracle (`python/compile/kernels/ref.py`).
//!
//! Three mappings from the paper:
//! * **Linear** — `T(i) = (i+1)/2^b`, zero excluded by construction; the
//!   paper's choice for the second moment (§4.1).
//! * **DE** — dynamic exponent (Dettmers'15): leading zeros encode a
//!   power-of-ten exponent, remaining bits a linear fraction in (0.1, 1);
//!   includes 0 and 1 as special codes.
//! * **DE-0** — DE with the zero point removed (one code wasted), the
//!   paper's intermediate fix for the zero-point problem.

use super::kernels::QuantKernels;

/// Which mapping to build.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum MapKind {
    Linear,
    DynExp,
    DynExpNoZero,
}

impl MapKind {
    pub fn name(self) -> &'static str {
        match self {
            MapKind::Linear => "Linear",
            MapKind::DynExp => "DE",
            MapKind::DynExpNoZero => "DE-0",
        }
    }

    pub fn parse(s: &str) -> Option<MapKind> {
        match s.to_ascii_lowercase().as_str() {
            "linear" => Some(MapKind::Linear),
            "de" | "dynexp" | "dynamic" => Some(MapKind::DynExp),
            "de-0" | "de0" | "dynexp0" => Some(MapKind::DynExpNoZero),
            _ => None,
        }
    }
}

/// A concrete quantization mapping: the sorted value table plus midpoints
/// for O(log n) nearest-value encoding.
#[derive(Clone, Debug)]
pub struct QuantMap {
    pub kind: MapKind,
    pub bits: u8,
    pub signed: bool,
    /// Sorted representable values.
    pub values: Vec<f32>,
    /// `mid[i] = (values[i] + values[i+1]) / 2`; `len = values.len()-1`.
    mid: Vec<f32>,
    /// §Perf: midpoints padded with +inf to a fixed 15-lane array so the
    /// 4-bit encode is a fully unrolled, branch-free compare-count.
    mid15: [f32; 15],
    /// §Perf: pair/byte decode LUTs + the LUT/closed-form fast encoder
    /// ([`super::kernels`]), built once with the map so every hot path
    /// holding a cached `&QuantMap` gets them allocation-free.
    kernels: QuantKernels,
}

/// Fraction table for `F` fraction bits: midpoints of a uniform grid over
/// `[0.1, 1]` (paper App. E.2).
fn fractions(f_bits: u32) -> Vec<f64> {
    let n = 1usize << f_bits;
    let step = (1.0 - 0.1) / n as f64;
    (0..n)
        .map(|k| {
            let p_k = 0.1 + step * k as f64;
            let p_k1 = 0.1 + step * (k + 1) as f64;
            0.5 * (p_k + p_k1)
        })
        .collect()
}

/// Build the unsigned dynamic-exponent value set for `b` total bits,
/// including the special codes 0 and 1 (App. E.2: `0…0 → 0`, `0…01 → 1`).
fn dynexp_unsigned_values(b: u32) -> Vec<f64> {
    assert!(b >= 2, "DE needs at least 2 bits");
    let mut vals = vec![0.0, 1.0];
    // Non-special codes: E leading zeros, indicator bit, F = b-1-E fraction
    // bits, for E in [0, b-2] (E = b-1 is the code reassigned to 1.0).
    for e in 0..=(b - 2) {
        let f_bits = b - 1 - e;
        let scale = 10f64.powi(-(e as i32));
        for frac in fractions(f_bits) {
            vals.push(scale * frac);
        }
    }
    vals
}

/// Signed DE for `b` total bits: sign bit + (b-1)-bit unsigned pattern.
/// Special codes: `0,0…0 → 0` and `1,0…0 → 1.0` (asymmetric: −1 is not
/// representable; App. E.2 / bitsandbytes convention).
fn dynexp_signed_values(b: u32) -> Vec<f64> {
    assert!(b >= 3, "signed DE needs at least 3 bits");
    let mut vals = vec![0.0, 1.0];
    // Non-sign part is a (b-1)-bit pattern: E leading zeros, indicator,
    // F = b-2-E fraction bits, for E in [0, b-2]; the all-zero pattern is
    // the special 0 / 1.0 pair handled above.
    for e in 0..=(b - 2) {
        let f_bits = b - 2 - e;
        let scale = 10f64.powi(-(e as i32));
        for frac in fractions(f_bits) {
            vals.push(scale * frac);
            vals.push(-scale * frac);
        }
    }
    vals
}

impl QuantMap {
    pub fn new(kind: MapKind, bits: u8, signed: bool) -> QuantMap {
        let b = bits as u32;
        assert!((2..=8).contains(&b), "supported bitwidths: 2..=8");
        let mut vals: Vec<f64> = match (kind, signed) {
            (MapKind::Linear, false) => {
                // T(i) = (i+1)/2^b — excludes zero by construction.
                let n = 1usize << b;
                (0..n).map(|i| (i + 1) as f64 / n as f64).collect()
            }
            (MapKind::Linear, true) => {
                // Symmetric zero-free linear grid on [-1, 1]: ±(i+1)/2^(b-1).
                let half = 1usize << (b - 1);
                let mut v: Vec<f64> = (0..half)
                    .flat_map(|i| {
                        let x = (i + 1) as f64 / half as f64;
                        [x, -x]
                    })
                    .collect();
                v.sort_by(|a, b| a.partial_cmp(b).unwrap());
                v
            }
            (MapKind::DynExp, false) | (MapKind::DynExpNoZero, false) => {
                dynexp_unsigned_values(b)
            }
            (MapKind::DynExp, true) | (MapKind::DynExpNoZero, true) => dynexp_signed_values(b),
        };
        if kind == MapKind::DynExpNoZero {
            vals.retain(|&v| v != 0.0);
        }
        vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
        vals.dedup();
        let expected = match kind {
            MapKind::DynExpNoZero => (1usize << b) - 1,
            _ => 1usize << b,
        };
        assert_eq!(
            vals.len(),
            expected,
            "{kind:?} b={b} signed={signed}: built {} values, expected {expected}",
            vals.len()
        );
        let values: Vec<f32> = vals.iter().map(|&v| v as f32).collect();
        let mid: Vec<f32> = values
            .windows(2)
            .map(|w| 0.5 * (w[0] + w[1]))
            .collect();
        let mut mid15 = [f32::INFINITY; 15];
        for (dst, &m) in mid15.iter_mut().zip(mid.iter()) {
            *dst = m;
        }
        let kernels = QuantKernels::build(kind, bits, signed, &values, &mid);
        QuantMap {
            kind,
            bits,
            signed,
            values,
            mid,
            mid15,
            kernels,
        }
    }

    /// Number of codes.
    #[inline]
    pub fn len(&self) -> usize {
        self.values.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }

    /// Smallest representable magnitude > 0 (the paper quotes 0.0033 for
    /// 4-bit DE-0 and 0.0625 for 4-bit Linear).
    pub fn min_positive(&self) -> f32 {
        self.values
            .iter()
            .copied()
            .filter(|&v| v > 0.0)
            .fold(f32::INFINITY, f32::min)
    }

    /// Nearest-value encode: `argmin_i |n - T(i)|`, ties to smaller index.
    ///
    /// Perf note (§Perf): for 4-bit maps (≤15 midpoints) a branch-free
    /// count of `mid < n` beats binary search by ~2-3x — the comparisons
    /// vectorize and there are no unpredictable branches. Semantics are
    /// identical: both compute the number of midpoints strictly below `n`
    /// (ties keep the smaller index, matching first-occurrence argmin).
    #[inline]
    pub fn encode(&self, n: f32) -> u8 {
        if self.mid.len() <= 15 {
            // Fixed-length lane array (padded with +inf, which never
            // counts) -> the loop unrolls and vectorizes.
            let mut c = 0u8;
            for &m in self.mid15.iter() {
                c += (m < n) as u8;
            }
            c
        } else {
            self.mid.partition_point(|&m| m < n) as u8
        }
    }

    /// Decode a code to its representable value.
    #[inline]
    pub fn decode(&self, q: u8) -> f32 {
        self.values[q as usize]
    }

    /// The code that decodes to exactly `0.0`, if the map has one.
    /// Linear (both signs) and DE-0 exclude zero by construction
    /// (`None`); plain DynExp carries it — the zero-point asymmetry the
    /// quant-quality metrics diagnose (see `obs::quant`).
    pub fn zero_code(&self) -> Option<u8> {
        self.values.iter().position(|&v| v == 0.0).map(|i| i as u8)
    }

    /// §Perf: the kernel-layer encode ([`super::kernels`]) — closed-form
    /// for Linear maps, bits-keyed LUT for DE/DE-0 — bit-exact to
    /// [`Self::encode`], which stays the oracle-pinned reference the
    /// differential tests compare against.
    #[inline]
    pub fn encode_fast(&self, n: f32) -> u8 {
        self.kernels.encode(n)
    }

    /// The decode/encode LUT bundle for the kernel layer.
    #[inline]
    pub fn kernels(&self) -> &QuantKernels {
        &self.kernels
    }

    /// Bracketing codes for stochastic rounding: returns `(lo, hi)` such
    /// that `T(lo) <= n <= T(hi)` and no representable value is strictly
    /// between them; `lo == hi` when `n` is outside the table or exactly
    /// representable.
    pub fn bracket(&self, n: f32) -> (u8, u8) {
        // NaN compares false against everything: `partition_point` would
        // return 0 and `hi - 1` below would underflow (debug panic; in
        // release a wrapped (255, 0) bracket indexes `values` out of
        // bounds in `encode_stochastic`). Degenerate bracket at code 0
        // matches the deterministic `encode(NaN) == 0` and, being
        // degenerate, consumes no RNG draw on the SR path.
        if n.is_nan() {
            return (0, 0);
        }
        let first = &self.values[0];
        let last = &self.values[self.len() - 1];
        if n <= *first {
            return (0, 0);
        }
        if n >= *last {
            let c = (self.len() - 1) as u8;
            return (c, c);
        }
        let hi = self.values.partition_point(|&v| v < n);
        if self.values[hi] == n {
            (hi as u8, hi as u8)
        } else {
            ((hi - 1) as u8, hi as u8)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn linear_unsigned_4bit() {
        let m = QuantMap::new(MapKind::Linear, 4, false);
        assert_eq!(m.len(), 16);
        assert!((m.min_positive() - 0.0625).abs() < 1e-7);
        assert!((m.decode(15) - 1.0).abs() < 1e-7);
        // No zero point.
        assert!(m.values.iter().all(|&v| v > 0.0));
    }

    #[test]
    fn de_unsigned_4bit_matches_paper() {
        let m = QuantMap::new(MapKind::DynExp, 4, false);
        assert_eq!(m.len(), 16);
        assert_eq!(m.decode(0), 0.0);
        assert!((m.decode(15) - 1.0).abs() < 1e-7);
        // Paper: smallest representable of DE-0 (= smallest positive of DE)
        // is 0.0033 (= 10^-2 * 0.325 rounded).
        let m0 = QuantMap::new(MapKind::DynExpNoZero, 4, false);
        assert_eq!(m0.len(), 15);
        assert!((m0.min_positive() - 0.00325).abs() < 1e-6);
        assert!(m0.values.iter().all(|&v| v != 0.0));
    }

    #[test]
    fn de_signed_4bit_structure() {
        let m = QuantMap::new(MapKind::DynExp, 4, true);
        assert_eq!(m.len(), 16);
        // Asymmetric: +1 representable, -1 not.
        assert!((m.decode(15) - 1.0).abs() < 1e-7);
        assert!(m.values[0] > -1.0);
        // Contains zero.
        assert!(m.values.iter().any(|&v| v == 0.0));
        // Expected extremes from the paper's construction.
        assert!((m.values[0] + 0.8875).abs() < 1e-6, "{}", m.values[0]);
    }

    #[test]
    fn encode_is_argmin() {
        for kind in [MapKind::Linear, MapKind::DynExp, MapKind::DynExpNoZero] {
            for signed in [false, true] {
                if kind == MapKind::Linear && signed {
                    continue; // linear signed exists but brute-check anyway below
                }
                let m = QuantMap::new(kind, 4, signed);
                let lo = if signed { -1.2 } else { -0.2 };
                let mut n = lo;
                while n <= 1.2 {
                    let fast = m.encode(n) as usize;
                    // Brute-force argmin with first-index tie-breaking.
                    let mut best = 0;
                    let mut bestd = f32::INFINITY;
                    for (i, &v) in m.values.iter().enumerate() {
                        let d = (n - v).abs();
                        if d < bestd {
                            bestd = d;
                            best = i;
                        }
                    }
                    assert_eq!(
                        fast, best,
                        "{kind:?} signed={signed} n={n}: fast={fast} brute={best}"
                    );
                    n += 0.001;
                }
            }
        }
    }

    #[test]
    fn bracket_brackets() {
        let m = QuantMap::new(MapKind::DynExp, 4, true);
        let (lo, hi) = m.bracket(0.5);
        assert!(m.decode(lo) <= 0.5 && 0.5 <= m.decode(hi));
        assert_eq!(hi - lo, 1);
        // Exact value → degenerate bracket.
        let v = m.decode(7);
        let (a, b) = m.bracket(v);
        assert_eq!(a, b);
        // Out of range clamps.
        assert_eq!(m.bracket(-5.0), (0, 0));
        let top = (m.len() - 1) as u8;
        assert_eq!(m.bracket(5.0), (top, top));
    }

    #[test]
    fn bracket_nan_is_degenerate_at_zero_code() {
        // Regression: NaN used to underflow `hi - 1` (debug panic, OOB
        // bracket in release). It must match encode's NaN clamp to code 0.
        for kind in [MapKind::Linear, MapKind::DynExp, MapKind::DynExpNoZero] {
            for signed in [false, true] {
                for bits in [4u8, 8u8] {
                    let m = QuantMap::new(kind, bits, signed);
                    assert_eq!(m.bracket(f32::NAN), (0, 0));
                    assert_eq!(m.encode(f32::NAN), 0);
                }
            }
        }
    }

    #[test]
    fn is_empty_reflects_values() {
        // Regression: this used to hardcode `false`.
        let m = QuantMap::new(MapKind::Linear, 4, false);
        assert!(!m.is_empty());
        assert_eq!(m.is_empty(), m.values.is_empty());
        assert_eq!(m.len(), m.values.len());
    }

    #[test]
    fn all_bitwidths_build() {
        for b in 2..=8u8 {
            let m = QuantMap::new(MapKind::Linear, b, false);
            assert_eq!(m.len(), 1 << b);
            if b >= 3 {
                let m = QuantMap::new(MapKind::DynExp, b, true);
                assert_eq!(m.len(), 1 << b);
            }
            let m = QuantMap::new(MapKind::DynExp, b, false);
            assert_eq!(m.len(), 1 << b);
            let m = QuantMap::new(MapKind::DynExpNoZero, b, false);
            assert_eq!(m.len(), (1 << b) - 1);
        }
    }

    #[test]
    fn values_sorted_unique() {
        for kind in [MapKind::Linear, MapKind::DynExp, MapKind::DynExpNoZero] {
            for signed in [false, true] {
                let m = QuantMap::new(kind, 4, signed);
                for w in m.values.windows(2) {
                    assert!(w[0] < w[1], "{kind:?} signed={signed}: not strictly sorted");
                }
            }
        }
    }

    #[test]
    fn de8_matches_bnb_corner_cases() {
        // 8-bit signed DE (the Dettmers 8-bit optimizer map): 256 values,
        // max 1.0, min > -1.0, includes 0.
        let m = QuantMap::new(MapKind::DynExp, 8, true);
        assert_eq!(m.len(), 256);
        assert_eq!(m.decode(255), 1.0);
        assert!(m.values.contains(&0.0));
    }
}
