#![forbid(unsafe_code)]
//! Portable scalar kernel tier — the reference implementation every
//! SIMD tier is pinned against (and the only tier off x86-64).
//!
//! The run kernels here are the PR-5 nibble-granular loops, moved
//! verbatim; the stochastic-rounding and fused EMA kernels wrap the same
//! per-element reference operations (`encode_stochastic`, the phase-C
//! EMA expression) in the run-structured lead/pairs/tail walk, so the
//! packed bytes *and* the RNG draw order are exactly what the unfused
//! `packing::set` loops produce.

use super::super::mapping::QuantMap;
use super::super::stochastic::encode_stochastic;
use super::{ema, set_hi, set_lo, smin};
use crate::util::rng::Pcg64;

/// Fused constant-scale run decode: `out[k] = T(code(pos0 + k)) * s`.
pub fn decode_run_scaled(
    map: &QuantMap,
    bits: u8,
    packed: &[u8],
    pos0: usize,
    s: f32,
    out: &mut [f32],
) {
    if out.is_empty() {
        return;
    }
    let k = map.kernels();
    if bits == 4 {
        let pair = k.pair4();
        let mut pos = pos0;
        let mut o = 0usize;
        if pos % 2 == 1 {
            out[0] = k.decode_byte(packed[pos / 2] >> 4) * s;
            pos += 1;
            o = 1;
        }
        let pairs = (out.len() - o) / 2;
        let byte0 = pos / 2;
        for (ob, &b) in out[o..o + 2 * pairs]
            .chunks_exact_mut(2)
            .zip(packed[byte0..byte0 + pairs].iter())
        {
            let pv = pair[b as usize];
            ob[0] = pv[0] * s;
            ob[1] = pv[1] * s;
        }
        if o + 2 * pairs < out.len() {
            let last = out.len() - 1;
            out[last] = k.decode_byte(packed[(pos0 + last) / 2] & 0x0F) * s;
        }
    } else {
        for (ob, &b) in out.iter_mut().zip(packed[pos0..pos0 + out.len()].iter()) {
            *ob = k.decode_byte(b) * s;
        }
    }
}

/// Fused rank-1 row-segment decode: element `j` scales by
/// `min(r_i, cseg[j])`.
pub fn decode_rank1_row(
    map: &QuantMap,
    bits: u8,
    packed: &[u8],
    pos0: usize,
    ri: f32,
    cseg: &[f32],
    out: &mut [f32],
) {
    debug_assert_eq!(cseg.len(), out.len());
    if out.is_empty() {
        return;
    }
    let k = map.kernels();
    if bits == 4 {
        let pair = k.pair4();
        let mut pos = pos0;
        let mut o = 0usize;
        if pos % 2 == 1 {
            out[0] = k.decode_byte(packed[pos / 2] >> 4) * smin(ri, cseg[0]);
            pos += 1;
            o = 1;
        }
        let pairs = (out.len() - o) / 2;
        let byte0 = pos / 2;
        for ((ob, cs), &b) in out[o..o + 2 * pairs]
            .chunks_exact_mut(2)
            .zip(cseg[o..o + 2 * pairs].chunks_exact(2))
            .zip(packed[byte0..byte0 + pairs].iter())
        {
            let pv = pair[b as usize];
            ob[0] = pv[0] * smin(ri, cs[0]);
            ob[1] = pv[1] * smin(ri, cs[1]);
        }
        if o + 2 * pairs < out.len() {
            let last = out.len() - 1;
            out[last] = k.decode_byte(packed[(pos0 + last) / 2] & 0x0F) * smin(ri, cseg[last]);
        }
    } else {
        for ((ob, &cj), &b) in out
            .iter_mut()
            .zip(cseg.iter())
            .zip(packed[pos0..pos0 + out.len()].iter())
        {
            *ob = k.decode_byte(b) * smin(ri, cj);
        }
    }
}

/// Fused normalize→encode→pack of a constant-scale run (`s > 0`).
pub fn encode_run_scaled(
    map: &QuantMap,
    bits: u8,
    vals: &[f32],
    s: f32,
    pos0: usize,
    dst: &mut [u8],
) {
    debug_assert!(s > 0.0, "zero-scale runs take encode_run_zero");
    if vals.is_empty() {
        return;
    }
    let k = map.kernels();
    if bits == 4 {
        let mut pos = pos0;
        let mut i = 0usize;
        if pos % 2 == 1 {
            set_hi(&mut dst[pos / 2], k.encode(vals[0] / s));
            pos += 1;
            i = 1;
        }
        let pairs = (vals.len() - i) / 2;
        let byte0 = pos / 2;
        for (b, pv) in dst[byte0..byte0 + pairs]
            .iter_mut()
            .zip(vals[i..i + 2 * pairs].chunks_exact(2))
        {
            let c0 = k.encode(pv[0] / s);
            let c1 = k.encode(pv[1] / s);
            *b = c0 | (c1 << 4);
        }
        if i + 2 * pairs < vals.len() {
            let last = vals.len() - 1;
            set_lo(&mut dst[(pos0 + last) / 2], k.encode(vals[last] / s));
        }
    } else {
        for (d, &v) in dst[pos0..pos0 + vals.len()].iter_mut().zip(vals.iter()) {
            *d = k.encode(v / s);
        }
    }
}

/// The rank-1 per-element normalize: divide by `min(ri, cj)` when
/// positive, else emit a normalized 0 (the scalar paths' zero-lane
/// convention).
#[inline(always)]
fn norm(v: f32, ri: f32, cj: f32) -> f32 {
    let s = smin(ri, cj);
    if s > 0.0 {
        v / s
    } else {
        0.0
    }
}

/// Fused rank-1 row-segment encode: element `j` normalizes by
/// `min(r_i, cseg[j])` (zero scales encode a normalized 0).
pub fn encode_rank1_row(
    map: &QuantMap,
    bits: u8,
    vals: &[f32],
    ri: f32,
    cseg: &[f32],
    pos0: usize,
    dst: &mut [u8],
) {
    debug_assert_eq!(cseg.len(), vals.len());
    if vals.is_empty() {
        return;
    }
    let k = map.kernels();
    if bits == 4 {
        let mut pos = pos0;
        let mut i = 0usize;
        if pos % 2 == 1 {
            set_hi(&mut dst[pos / 2], k.encode(norm(vals[0], ri, cseg[0])));
            pos += 1;
            i = 1;
        }
        let pairs = (vals.len() - i) / 2;
        let byte0 = pos / 2;
        for ((b, pv), cs) in dst[byte0..byte0 + pairs]
            .iter_mut()
            .zip(vals[i..i + 2 * pairs].chunks_exact(2))
            .zip(cseg[i..i + 2 * pairs].chunks_exact(2))
        {
            let c0 = k.encode(norm(pv[0], ri, cs[0]));
            let c1 = k.encode(norm(pv[1], ri, cs[1]));
            *b = c0 | (c1 << 4);
        }
        if i + 2 * pairs < vals.len() {
            let last = vals.len() - 1;
            set_lo(
                &mut dst[(pos0 + last) / 2],
                k.encode(norm(vals[last], ri, cseg[last])),
            );
        }
    } else {
        for ((d, &v), &cj) in dst[pos0..pos0 + vals.len()]
            .iter_mut()
            .zip(vals.iter())
            .zip(cseg.iter())
        {
            *d = k.encode(norm(v, ri, cj));
        }
    }
}

/// Stochastic-rounding constant-scale run encode (`s > 0`): the
/// `encode_stochastic` + `packing::set` loop restructured into the
/// lead/pairs/tail walk. Draws happen in element order; degenerate
/// brackets consume none.
#[allow(clippy::too_many_arguments)]
pub fn encode_sr_run_scaled(
    map: &QuantMap,
    bits: u8,
    vals: &[f32],
    s: f32,
    pos0: usize,
    dst: &mut [u8],
    rng: &mut Pcg64,
) {
    debug_assert!(s > 0.0, "zero-scale runs take encode_run_zero");
    if vals.is_empty() {
        return;
    }
    if bits == 4 {
        let mut pos = pos0;
        let mut i = 0usize;
        if pos % 2 == 1 {
            set_hi(&mut dst[pos / 2], encode_stochastic(map, vals[0] / s, rng));
            pos += 1;
            i = 1;
        }
        let pairs = (vals.len() - i) / 2;
        let byte0 = pos / 2;
        for (b, pv) in dst[byte0..byte0 + pairs]
            .iter_mut()
            .zip(vals[i..i + 2 * pairs].chunks_exact(2))
        {
            let c0 = encode_stochastic(map, pv[0] / s, rng);
            let c1 = encode_stochastic(map, pv[1] / s, rng);
            *b = c0 | (c1 << 4);
        }
        if i + 2 * pairs < vals.len() {
            let last = vals.len() - 1;
            set_lo(
                &mut dst[(pos0 + last) / 2],
                encode_stochastic(map, vals[last] / s, rng),
            );
        }
    } else {
        for (d, &v) in dst[pos0..pos0 + vals.len()].iter_mut().zip(vals.iter()) {
            *d = encode_stochastic(map, v / s, rng);
        }
    }
}

/// Stochastic-rounding rank-1 row-segment encode: element `j` normalizes
/// by `min(r_i, cseg[j])`; a zero per-element scale feeds a normalized 0
/// to the SR draw (which for maps without a representable 0 still draws,
/// exactly like the unfused path).
#[allow(clippy::too_many_arguments)]
pub fn encode_sr_rank1_row(
    map: &QuantMap,
    bits: u8,
    vals: &[f32],
    ri: f32,
    cseg: &[f32],
    pos0: usize,
    dst: &mut [u8],
    rng: &mut Pcg64,
) {
    debug_assert_eq!(cseg.len(), vals.len());
    if vals.is_empty() {
        return;
    }
    if bits == 4 {
        let mut pos = pos0;
        let mut i = 0usize;
        if pos % 2 == 1 {
            let code = encode_stochastic(map, norm(vals[0], ri, cseg[0]), rng);
            set_hi(&mut dst[pos / 2], code);
            pos += 1;
            i = 1;
        }
        let pairs = (vals.len() - i) / 2;
        let byte0 = pos / 2;
        for ((b, pv), cs) in dst[byte0..byte0 + pairs]
            .iter_mut()
            .zip(vals[i..i + 2 * pairs].chunks_exact(2))
            .zip(cseg[i..i + 2 * pairs].chunks_exact(2))
        {
            let c0 = encode_stochastic(map, norm(pv[0], ri, cs[0]), rng);
            let c1 = encode_stochastic(map, norm(pv[1], ri, cs[1]), rng);
            *b = c0 | (c1 << 4);
        }
        if i + 2 * pairs < vals.len() {
            let last = vals.len() - 1;
            let code = encode_stochastic(map, norm(vals[last], ri, cseg[last]), rng);
            set_lo(&mut dst[(pos0 + last) / 2], code);
        }
    } else {
        for ((d, &v), &cj) in dst[pos0..pos0 + vals.len()]
            .iter_mut()
            .zip(vals.iter())
            .zip(cseg.iter())
        {
            *d = encode_stochastic(map, norm(v, ri, cj), rng);
        }
    }
}

/// Fused in-place phase-C pass over a constant-scale run: decode the old
/// code (× `old_s`), EMA with `g[k]`, re-encode against `new_s` (> 0)
/// into the same position. The 4-bit walk is in-place safe by
/// construction: the lead's `set_hi` leaves the previous segment's
/// already-final low nibble, whole bytes are read before being written,
/// and the tail's `set_lo` leaves the next segment's untouched high
/// nibble.
#[allow(clippy::too_many_arguments)]
pub fn ema_reencode_run_scaled(
    map: &QuantMap,
    bits: u8,
    packed: &mut [u8],
    pos0: usize,
    old_s: f32,
    new_s: f32,
    g: &[f32],
    beta: f32,
    second: bool,
    stochastic: bool,
    rng: &mut Pcg64,
) {
    debug_assert!(new_s > 0.0, "zero new scales take the unfused fallback");
    if stochastic {
        ema_run_inner(map, bits, packed, pos0, old_s, new_s, g, beta, second, &mut |n| {
            encode_stochastic(map, n, rng)
        });
    } else {
        let k = map.kernels();
        ema_run_inner(map, bits, packed, pos0, old_s, new_s, g, beta, second, &mut |n| k.encode(n));
    }
}

#[allow(clippy::too_many_arguments)]
fn ema_run_inner(
    map: &QuantMap,
    bits: u8,
    packed: &mut [u8],
    pos0: usize,
    old_s: f32,
    new_s: f32,
    g: &[f32],
    beta: f32,
    second: bool,
    enc: &mut dyn FnMut(f32) -> u8,
) {
    if g.is_empty() {
        return;
    }
    let k = map.kernels();
    if bits == 4 {
        let mut pos = pos0;
        let mut i = 0usize;
        if pos % 2 == 1 {
            let slot = &mut packed[pos / 2];
            let x = k.decode_byte(*slot >> 4) * old_s;
            set_hi(slot, enc(ema(beta, x, g[0], second) / new_s));
            pos += 1;
            i = 1;
        }
        let pairs = (g.len() - i) / 2;
        let byte0 = pos / 2;
        for (b, gp) in packed[byte0..byte0 + pairs]
            .iter_mut()
            .zip(g[i..i + 2 * pairs].chunks_exact(2))
        {
            let pv = k.pair4()[*b as usize];
            let c0 = enc(ema(beta, pv[0] * old_s, gp[0], second) / new_s);
            let c1 = enc(ema(beta, pv[1] * old_s, gp[1], second) / new_s);
            *b = c0 | (c1 << 4);
        }
        if i + 2 * pairs < g.len() {
            let last = g.len() - 1;
            let slot = &mut packed[(pos0 + last) / 2];
            let x = k.decode_byte(*slot & 0x0F) * old_s;
            set_lo(slot, enc(ema(beta, x, g[last], second) / new_s));
        }
    } else {
        for (b, &gv) in packed[pos0..pos0 + g.len()].iter_mut().zip(g.iter()) {
            let x = k.decode_byte(*b) * old_s;
            *b = enc(ema(beta, x, gv, second) / new_s);
        }
    }
}

/// Fused in-place phase-C pass over a rank-1 row segment: decode with
/// the old `min(r_i, c_j)` scales, EMA, re-encode against the new ones
/// (a zero new scale encodes a normalized 0).
#[allow(clippy::too_many_arguments)]
pub fn ema_reencode_rank1_row(
    map: &QuantMap,
    bits: u8,
    packed: &mut [u8],
    pos0: usize,
    old_ri: f32,
    old_cseg: &[f32],
    new_ri: f32,
    new_cseg: &[f32],
    g: &[f32],
    beta: f32,
    second: bool,
    stochastic: bool,
    rng: &mut Pcg64,
) {
    if stochastic {
        ema_rank1_inner(
            map,
            bits,
            packed,
            pos0,
            old_ri,
            old_cseg,
            new_ri,
            new_cseg,
            g,
            beta,
            second,
            &mut |n| encode_stochastic(map, n, rng),
        );
    } else {
        let k = map.kernels();
        ema_rank1_inner(
            map,
            bits,
            packed,
            pos0,
            old_ri,
            old_cseg,
            new_ri,
            new_cseg,
            g,
            beta,
            second,
            &mut |n| k.encode(n),
        );
    }
}

#[allow(clippy::too_many_arguments)]
fn ema_rank1_inner(
    map: &QuantMap,
    bits: u8,
    packed: &mut [u8],
    pos0: usize,
    old_ri: f32,
    old_cseg: &[f32],
    new_ri: f32,
    new_cseg: &[f32],
    g: &[f32],
    beta: f32,
    second: bool,
    enc: &mut dyn FnMut(f32) -> u8,
) {
    debug_assert_eq!(old_cseg.len(), g.len());
    debug_assert_eq!(new_cseg.len(), g.len());
    if g.is_empty() {
        return;
    }
    let k = map.kernels();
    if bits == 4 {
        let mut pos = pos0;
        let mut i = 0usize;
        if pos % 2 == 1 {
            let slot = &mut packed[pos / 2];
            let x = k.decode_byte(*slot >> 4) * smin(old_ri, old_cseg[0]);
            let val = ema(beta, x, g[0], second);
            set_hi(slot, enc(norm(val, new_ri, new_cseg[0])));
            pos += 1;
            i = 1;
        }
        let pairs = (g.len() - i) / 2;
        let byte0 = pos / 2;
        for (((b, gp), ocs), ncs) in packed[byte0..byte0 + pairs]
            .iter_mut()
            .zip(g[i..i + 2 * pairs].chunks_exact(2))
            .zip(old_cseg[i..i + 2 * pairs].chunks_exact(2))
            .zip(new_cseg[i..i + 2 * pairs].chunks_exact(2))
        {
            let pv = k.pair4()[*b as usize];
            let v0 = ema(beta, pv[0] * smin(old_ri, ocs[0]), gp[0], second);
            let v1 = ema(beta, pv[1] * smin(old_ri, ocs[1]), gp[1], second);
            let c0 = enc(norm(v0, new_ri, ncs[0]));
            let c1 = enc(norm(v1, new_ri, ncs[1]));
            *b = c0 | (c1 << 4);
        }
        if i + 2 * pairs < g.len() {
            let last = g.len() - 1;
            let slot = &mut packed[(pos0 + last) / 2];
            let x = k.decode_byte(*slot & 0x0F) * smin(old_ri, old_cseg[last]);
            let val = ema(beta, x, g[last], second);
            set_lo(slot, enc(norm(val, new_ri, new_cseg[last])));
        }
    } else {
        for ((b, &gv), (&ocj, &ncj)) in packed[pos0..pos0 + g.len()]
            .iter_mut()
            .zip(g.iter())
            .zip(old_cseg.iter().zip(new_cseg.iter()))
        {
            let x = k.decode_byte(*b) * smin(old_ri, ocj);
            let val = ema(beta, x, gv, second);
            *b = enc(norm(val, new_ri, ncj));
        }
    }
}
