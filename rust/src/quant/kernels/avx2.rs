//! AVX2 kernel tier: 256-bit SIMD implementations of the 4-bit run
//! kernels, bit-identical to the [`scalar`] tier (the dispatch tests and
//! `rust/tests/quant_tiers.rs` pin every arm, adversarial floats
//! included).
//!
//! Exactness is structural, not approximate: every lane operation here
//! is IEEE-identical to the scalar expression it replaces — table
//! lookups (`vpermps` nibble shuffle ≡ the pair LUT), `vdivps`/`vmulps`/
//! `vaddps` (correctly rounded, same association as the scalar code,
//! never contracted into FMA), ordered-quiet compares (≡ Rust `f32`
//! `<`/`==`/`>`), and `vminps` (returns the second operand when the
//! compare fails, exactly the `if a < b { a } else { b }` combiner).
//! Encode is the same `#{mid < n}` midpoint partition the oracle runs,
//! evaluated as 15 broadcast compares over the `+inf`-padded `mid16`
//! table; stochastic rounding evaluates the bracket `(#{v < n}, #{v ==
//! n})` counts in lanes and then draws per element *in element order*,
//! so the RNG stream is draw-for-draw the scalar one.
//!
//! Byte-per-code widths (8-bit maps) decode through an 8-lane
//! `vgatherdps` over the same clamp-padded 256-entry direct table the
//! scalar tier indexes — a pure table load, structurally bit-exact.
//! Non-4-bit encodes, short runs, and the stochastic fused-EMA arm
//! delegate to the scalar tier — same contract, nothing to prove.

// Older toolchains require explicit `unsafe {}` blocks inside these
// `unsafe fn` bodies under `deny(unsafe_op_in_unsafe_fn)`; newer ones
// consider some of those blocks redundant once `target_feature` makes
// the intrinsics callable. Tolerate both so the pinned toolchain can
// move without touching this file.
#![allow(unused_unsafe)]

use std::arch::x86_64::*;

use super::super::mapping::QuantMap;
use super::{ema, scalar, set_hi, set_lo, smin, QuantKernels};
use crate::util::rng::Pcg64;

/// Below this many elements the vector setup (table broadcasts, edge
/// handling) costs more than it saves; the scalar tier takes the run.
const VEC_MIN: usize = 32;

// ---------------------------------------------------------------------
// Safe wrappers — the tier's public surface, signature-compatible with
// `scalar` so the dispatcher and the non-x86 module alias line up.
// ---------------------------------------------------------------------

/// AVX2 [`super::decode_run_scaled`].
pub fn decode_run_scaled(
    map: &QuantMap,
    bits: u8,
    packed: &[u8],
    pos0: usize,
    s: f32,
    out: &mut [f32],
) {
    if out.len() < VEC_MIN {
        return scalar::decode_run_scaled(map, bits, packed, pos0, s, out);
    }
    if bits != 4 {
        // Every non-4-bit width stores one code per byte and decodes
        // through the clamp-padded direct table, so the gather kernel
        // covers them all.
        // SAFETY: AVX2 verified by the dispatcher (see below).
        return unsafe { decode_run_scaled_v8(map.kernels(), packed, pos0, s, out) };
    }
    // SAFETY: this tier is only dispatched (or directly invoked by the
    // differential tests) when `is_x86_feature_detected!("avx2")` holds,
    // satisfying the inner fn's target-feature contract.
    unsafe { decode_run_scaled_v(map.kernels(), packed, pos0, s, out) }
}

/// AVX2 [`super::decode_rank1_row`].
pub fn decode_rank1_row(
    map: &QuantMap,
    bits: u8,
    packed: &[u8],
    pos0: usize,
    ri: f32,
    cseg: &[f32],
    out: &mut [f32],
) {
    if out.len() < VEC_MIN {
        return scalar::decode_rank1_row(map, bits, packed, pos0, ri, cseg, out);
    }
    if bits != 4 {
        // Byte-per-code widths take the gather kernel (see
        // decode_run_scaled).
        // SAFETY: AVX2 verified by the dispatcher (see decode_run_scaled).
        return unsafe { decode_rank1_row_v8(map.kernels(), packed, pos0, ri, cseg, out) };
    }
    // SAFETY: AVX2 verified by the dispatcher (see decode_run_scaled).
    unsafe { decode_rank1_row_v(map.kernels(), packed, pos0, ri, cseg, out) }
}

/// AVX2 [`super::encode_run_scaled`].
pub fn encode_run_scaled(
    map: &QuantMap,
    bits: u8,
    vals: &[f32],
    s: f32,
    pos0: usize,
    dst: &mut [u8],
) {
    if bits != 4 || vals.len() < VEC_MIN {
        return scalar::encode_run_scaled(map, bits, vals, s, pos0, dst);
    }
    // SAFETY: AVX2 verified by the dispatcher (see decode_run_scaled).
    unsafe { encode_run_scaled_v(map.kernels(), vals, s, pos0, dst) }
}

/// AVX2 [`super::encode_rank1_row`].
pub fn encode_rank1_row(
    map: &QuantMap,
    bits: u8,
    vals: &[f32],
    ri: f32,
    cseg: &[f32],
    pos0: usize,
    dst: &mut [u8],
) {
    if bits != 4 || vals.len() < VEC_MIN {
        return scalar::encode_rank1_row(map, bits, vals, ri, cseg, pos0, dst);
    }
    // SAFETY: AVX2 verified by the dispatcher (see decode_run_scaled).
    unsafe { encode_rank1_row_v(map.kernels(), vals, ri, cseg, pos0, dst) }
}

/// AVX2 [`super::encode_sr_run_scaled`].
#[allow(clippy::too_many_arguments)]
pub fn encode_sr_run_scaled(
    map: &QuantMap,
    bits: u8,
    vals: &[f32],
    s: f32,
    pos0: usize,
    dst: &mut [u8],
    rng: &mut Pcg64,
) {
    if bits != 4 || vals.len() < VEC_MIN {
        return scalar::encode_sr_run_scaled(map, bits, vals, s, pos0, dst, rng);
    }
    // SAFETY: AVX2 verified by the dispatcher (see decode_run_scaled).
    unsafe { encode_sr_run_scaled_v(map, vals, s, pos0, dst, rng) }
}

/// AVX2 [`super::encode_sr_rank1_row`].
#[allow(clippy::too_many_arguments)]
pub fn encode_sr_rank1_row(
    map: &QuantMap,
    bits: u8,
    vals: &[f32],
    ri: f32,
    cseg: &[f32],
    pos0: usize,
    dst: &mut [u8],
    rng: &mut Pcg64,
) {
    if bits != 4 || vals.len() < VEC_MIN {
        return scalar::encode_sr_rank1_row(map, bits, vals, ri, cseg, pos0, dst, rng);
    }
    // SAFETY: AVX2 verified by the dispatcher (see decode_run_scaled).
    unsafe { encode_sr_rank1_row_v(map, vals, ri, cseg, pos0, dst, rng) }
}

/// AVX2 [`super::ema_reencode_run_scaled`]. The stochastic arm delegates
/// to the scalar tier (the draw serializes the loop anyway).
#[allow(clippy::too_many_arguments)]
pub fn ema_reencode_run_scaled(
    map: &QuantMap,
    bits: u8,
    packed: &mut [u8],
    pos0: usize,
    old_s: f32,
    new_s: f32,
    g: &[f32],
    beta: f32,
    second: bool,
    stochastic: bool,
    rng: &mut Pcg64,
) {
    if bits != 4 || stochastic || g.len() < VEC_MIN {
        return scalar::ema_reencode_run_scaled(
            map, bits, packed, pos0, old_s, new_s, g, beta, second, stochastic, rng,
        );
    }
    // SAFETY: AVX2 verified by the dispatcher (see decode_run_scaled).
    unsafe { ema_run_v(map.kernels(), packed, pos0, old_s, new_s, g, beta, second) }
}

/// AVX2 [`super::ema_reencode_rank1_row`]. The stochastic arm delegates
/// to the scalar tier.
#[allow(clippy::too_many_arguments)]
pub fn ema_reencode_rank1_row(
    map: &QuantMap,
    bits: u8,
    packed: &mut [u8],
    pos0: usize,
    old_ri: f32,
    old_cseg: &[f32],
    new_ri: f32,
    new_cseg: &[f32],
    g: &[f32],
    beta: f32,
    second: bool,
    stochastic: bool,
    rng: &mut Pcg64,
) {
    if bits != 4 || stochastic || g.len() < VEC_MIN {
        return scalar::ema_reencode_rank1_row(
            map, bits, packed, pos0, old_ri, old_cseg, new_ri, new_cseg, g, beta, second,
            stochastic, rng,
        );
    }
    // SAFETY: AVX2 verified by the dispatcher (see decode_run_scaled).
    unsafe {
        ema_rank1_v(
            map.kernels(),
            packed,
            pos0,
            old_ri,
            old_cseg,
            new_ri,
            new_cseg,
            g,
            beta,
            second,
        )
    }
}

// ---------------------------------------------------------------------
// Register helpers.
// ---------------------------------------------------------------------

/// Unpack 8 packed bytes into their 16 nibble codes, in element order:
/// the first returned register holds elements 0..8 as `u32` lanes, the
/// second elements 8..16.
///
/// # Safety
/// AVX2 must be available and `ptr` must point at 8 readable bytes.
#[target_feature(enable = "avx2")]
#[inline]
unsafe fn unpack16(ptr: *const u8) -> (__m256i, __m256i) {
    // SAFETY: caller contract — AVX2 enabled, 8 bytes readable at `ptr`;
    // everything else is register-only.
    unsafe {
        let w = _mm256_cvtepu8_epi32(_mm_loadl_epi64(ptr as *const __m128i));
        let lo = _mm256_and_si256(w, _mm256_set1_epi32(0x0F));
        let hi = _mm256_srli_epi32::<4>(w);
        // Interleave low/high nibbles back into element order: byte k
        // holds elements 2k (low nibble) and 2k+1 (high nibble).
        let a = _mm256_unpacklo_epi32(lo, hi);
        let c = _mm256_unpackhi_epi32(lo, hi);
        (
            _mm256_permute2x128_si256::<0x20>(a, c),
            _mm256_permute2x128_si256::<0x31>(a, c),
        )
    }
}

/// 16-entry f32 table lookup: two 8-lane `vpermps` gathers selected by
/// bit 3 of each index (moved to the lane sign for `vblendvps`).
///
/// # Safety
/// AVX2 must be available. Index lanes must be in `0..16`.
#[target_feature(enable = "avx2")]
#[inline]
unsafe fn lookup16(tbl_lo: __m256, tbl_hi: __m256, idx: __m256i) -> __m256 {
    // SAFETY: caller contract — AVX2 enabled; register-only ops.
    unsafe {
        let t0 = _mm256_permutevar8x32_ps(tbl_lo, idx);
        let t1 = _mm256_permutevar8x32_ps(tbl_hi, idx);
        let sel = _mm256_castsi256_ps(_mm256_slli_epi32::<28>(idx));
        _mm256_blendv_ps(t0, t1, sel)
    }
}

/// 8-lane nearest-code encode: the oracle's `#{mid < n}` partition as 15
/// broadcast compares over the `+inf`-padded midpoint table (`+inf`
/// lanes never count; NaN input counts nothing and encodes to 0, exactly
/// like the scalar oracle).
///
/// # Safety
/// AVX2 must be available.
#[target_feature(enable = "avx2")]
#[inline]
unsafe fn encode8(mid16: &[f32; 16], n: __m256) -> __m256i {
    // SAFETY: caller contract — AVX2 enabled; register-only ops.
    unsafe {
        let mut cnt = _mm256_setzero_si256();
        for &m in mid16.iter().take(15) {
            let lt = _mm256_cmp_ps::<_CMP_LT_OQ>(_mm256_set1_ps(m), n);
            cnt = _mm256_sub_epi32(cnt, _mm256_castps_si256(lt));
        }
        cnt
    }
}

/// Pack 16 code lanes (two 8-lane registers, element order) into 8
/// bytes, low nibble first.
///
/// # Safety
/// AVX2 must be available and `dst` must point at 8 writable bytes.
#[target_feature(enable = "avx2")]
#[inline]
unsafe fn pack16(c0: __m256i, c1: __m256i, dst: *mut u8) {
    // SAFETY: caller contract — AVX2 enabled, 8 bytes writable at `dst`;
    // the spills land in local arrays of exactly 8 lanes.
    unsafe {
        let mut a = [0u32; 8];
        let mut b = [0u32; 8];
        _mm256_storeu_si256(a.as_mut_ptr() as *mut __m256i, c0);
        _mm256_storeu_si256(b.as_mut_ptr() as *mut __m256i, c1);
        for j in 0..4 {
            *dst.add(j) = (a[2 * j] as u8) | ((a[2 * j + 1] as u8) << 4);
            *dst.add(4 + j) = (b[2 * j] as u8) | ((b[2 * j + 1] as u8) << 4);
        }
    }
}

/// The stochastic-rounding per-lane decision, fed by the vector bracket
/// counts `c = #{values < n}` and `e = #{values == n}`: reproduces
/// `QuantMap::bracket` + the `encode_stochastic` draw exactly —
/// degenerate brackets (NaN or `n` at/beyond an end: `c == 0` or
/// `c >= len`; exact hits: `e > 0`) consume no draw.
#[inline]
fn sr_pick(k: &QuantKernels, n: f32, c: u32, e: u32, rng: &mut Pcg64) -> u8 {
    let len = k.n_codes as u32;
    if c == 0 {
        0
    } else if c >= len {
        (len - 1) as u8
    } else if e > 0 {
        c as u8
    } else {
        let lo = (c - 1) as usize;
        let hi = c as usize;
        let a = k.val16[lo];
        let b = k.val16[hi];
        let p_hi = (n - a) / (b - a);
        if rng.next_f32() < p_hi {
            hi as u8
        } else {
            lo as u8
        }
    }
}

/// 8-lane bracket counts over the `+inf`-padded value table. For
/// `n = +inf` the pad lanes' `+inf == +inf` overcount of `e` is
/// harmless: `c >= len` already decides that lane in [`sr_pick`].
///
/// # Safety
/// AVX2 must be available.
#[target_feature(enable = "avx2")]
#[inline]
unsafe fn sr_counts(vlt16: &[f32; 16], n: __m256) -> (__m256i, __m256i) {
    // SAFETY: caller contract — AVX2 enabled; register-only ops.
    unsafe {
        let mut c = _mm256_setzero_si256();
        let mut e = _mm256_setzero_si256();
        for &v in vlt16.iter() {
            let vv = _mm256_set1_ps(v);
            c = _mm256_sub_epi32(c, _mm256_castps_si256(_mm256_cmp_ps::<_CMP_LT_OQ>(vv, n)));
            e = _mm256_sub_epi32(e, _mm256_castps_si256(_mm256_cmp_ps::<_CMP_EQ_OQ>(vv, n)));
        }
        (c, e)
    }
}

// ---------------------------------------------------------------------
// 4-bit vector kernels. Shape shared by all of them: a (possibly odd)
// lead nibble and tail nibble handled with the exact scalar-tier
// expressions, whole-byte groups of 8 (16 elements) in vector registers,
// and a scalar-tier remainder of fewer than 8 bytes in between.
// ---------------------------------------------------------------------

/// # Safety
/// AVX2 must be available; slice geometry as in the scalar tier (packed
/// covers positions `0..pos0 + out.len()`).
#[target_feature(enable = "avx2")]
unsafe fn decode_run_scaled_v(
    k: &QuantKernels,
    packed: &[u8],
    pos0: usize,
    s: f32,
    out: &mut [f32],
) {
    // SAFETY: target feature per caller contract; all pointer arithmetic
    // stays inside `packed` / `out` — the group loop runs while
    // `p + 8 <= pairs`, and `byte0 + pairs` bytes / `o + 2*pairs` floats
    // are in bounds by the run geometry.
    unsafe {
        let mut pos = pos0;
        let mut o = 0usize;
        if pos % 2 == 1 {
            out[0] = k.decode_byte(packed[pos / 2] >> 4) * s;
            pos += 1;
            o = 1;
        }
        let pairs = (out.len() - o) / 2;
        let byte0 = pos / 2;
        let tbl_lo = _mm256_loadu_ps(k.val16.as_ptr());
        let tbl_hi = _mm256_loadu_ps(k.val16.as_ptr().add(8));
        let vs = _mm256_set1_ps(s);
        let mut p = 0usize;
        while p + 8 <= pairs {
            let (i0, i1) = unpack16(packed.as_ptr().add(byte0 + p));
            let v0 = _mm256_mul_ps(lookup16(tbl_lo, tbl_hi, i0), vs);
            let v1 = _mm256_mul_ps(lookup16(tbl_lo, tbl_hi, i1), vs);
            _mm256_storeu_ps(out.as_mut_ptr().add(o + 2 * p), v0);
            _mm256_storeu_ps(out.as_mut_ptr().add(o + 2 * p + 8), v1);
            p += 8;
        }
        for q in p..pairs {
            let pv = k.decode_pair(packed[byte0 + q]);
            out[o + 2 * q] = pv[0] * s;
            out[o + 2 * q + 1] = pv[1] * s;
        }
        if o + 2 * pairs < out.len() {
            let last = out.len() - 1;
            out[last] = k.decode_byte(packed[(pos0 + last) / 2] & 0x0F) * s;
        }
    }
}

/// # Safety
/// AVX2 must be available; `cseg.len() == out.len()`.
#[target_feature(enable = "avx2")]
unsafe fn decode_rank1_row_v(
    k: &QuantKernels,
    packed: &[u8],
    pos0: usize,
    ri: f32,
    cseg: &[f32],
    out: &mut [f32],
) {
    debug_assert_eq!(cseg.len(), out.len());
    // SAFETY: target feature per caller contract; pointer arithmetic
    // bounded exactly as in decode_run_scaled_v (cseg walks in lockstep
    // with out).
    unsafe {
        let mut pos = pos0;
        let mut o = 0usize;
        if pos % 2 == 1 {
            out[0] = k.decode_byte(packed[pos / 2] >> 4) * smin(ri, cseg[0]);
            pos += 1;
            o = 1;
        }
        let pairs = (out.len() - o) / 2;
        let byte0 = pos / 2;
        let tbl_lo = _mm256_loadu_ps(k.val16.as_ptr());
        let tbl_hi = _mm256_loadu_ps(k.val16.as_ptr().add(8));
        let vri = _mm256_set1_ps(ri);
        let mut p = 0usize;
        while p + 8 <= pairs {
            let (i0, i1) = unpack16(packed.as_ptr().add(byte0 + p));
            // vminps(a, b) = if a < b { a } else { b } — the scalar smin.
            let s0 = _mm256_min_ps(vri, _mm256_loadu_ps(cseg.as_ptr().add(o + 2 * p)));
            let s1 = _mm256_min_ps(vri, _mm256_loadu_ps(cseg.as_ptr().add(o + 2 * p + 8)));
            let v0 = _mm256_mul_ps(lookup16(tbl_lo, tbl_hi, i0), s0);
            let v1 = _mm256_mul_ps(lookup16(tbl_lo, tbl_hi, i1), s1);
            _mm256_storeu_ps(out.as_mut_ptr().add(o + 2 * p), v0);
            _mm256_storeu_ps(out.as_mut_ptr().add(o + 2 * p + 8), v1);
            p += 8;
        }
        for q in p..pairs {
            let pv = k.decode_pair(packed[byte0 + q]);
            out[o + 2 * q] = pv[0] * smin(ri, cseg[o + 2 * q]);
            out[o + 2 * q + 1] = pv[1] * smin(ri, cseg[o + 2 * q + 1]);
        }
        if o + 2 * pairs < out.len() {
            let last = out.len() - 1;
            out[last] = k.decode_byte(packed[(pos0 + last) / 2] & 0x0F) * smin(ri, cseg[last]);
        }
    }
}

// ---------------------------------------------------------------------
// Byte-per-code (8-bit) vector decode: one code per byte, no nibble
// edges — 8 codes widen to i32 lanes, gather from the clamp-padded
// 256-entry direct table (the exact table `decode_byte` indexes, so the
// clamp of out-of-range codes is baked into the load), scale, store.
// ---------------------------------------------------------------------

/// # Safety
/// AVX2 must be available; `packed` covers positions
/// `0..pos0 + out.len()`.
#[target_feature(enable = "avx2")]
unsafe fn decode_run_scaled_v8(
    k: &QuantKernels,
    packed: &[u8],
    pos0: usize,
    s: f32,
    out: &mut [f32],
) {
    // SAFETY: target feature per caller contract; each group loads the 8
    // bytes at `pos0 + p` and stores 8 floats at `p`, in bounds while
    // `p + 8 <= out.len()` by the run geometry; the gather indexes are
    // zero-extended bytes, inside the 256-entry table.
    unsafe {
        let vs = _mm256_set1_ps(s);
        let n = out.len();
        let mut p = 0usize;
        while p + 8 <= n {
            let w = _mm_loadl_epi64(packed.as_ptr().add(pos0 + p) as *const __m128i);
            let idx = _mm256_cvtepu8_epi32(w);
            let v = _mm256_i32gather_ps::<4>(k.byte.as_ptr(), idx);
            _mm256_storeu_ps(out.as_mut_ptr().add(p), _mm256_mul_ps(v, vs));
            p += 8;
        }
        for q in p..n {
            out[q] = k.decode_byte(packed[pos0 + q]) * s;
        }
    }
}

/// # Safety
/// AVX2 must be available; `cseg.len() == out.len()`; `packed` covers
/// positions `0..pos0 + out.len()`.
#[target_feature(enable = "avx2")]
unsafe fn decode_rank1_row_v8(
    k: &QuantKernels,
    packed: &[u8],
    pos0: usize,
    ri: f32,
    cseg: &[f32],
    out: &mut [f32],
) {
    debug_assert_eq!(cseg.len(), out.len());
    // SAFETY: target feature per caller contract; bounds as in
    // decode_run_scaled_v8, with cseg walking in lockstep with out.
    unsafe {
        let vri = _mm256_set1_ps(ri);
        let n = out.len();
        let mut p = 0usize;
        while p + 8 <= n {
            let w = _mm_loadl_epi64(packed.as_ptr().add(pos0 + p) as *const __m128i);
            let idx = _mm256_cvtepu8_epi32(w);
            let v = _mm256_i32gather_ps::<4>(k.byte.as_ptr(), idx);
            // vminps(a, b) = if a < b { a } else { b } — the scalar smin.
            let sv = _mm256_min_ps(vri, _mm256_loadu_ps(cseg.as_ptr().add(p)));
            _mm256_storeu_ps(out.as_mut_ptr().add(p), _mm256_mul_ps(v, sv));
            p += 8;
        }
        for q in p..n {
            out[q] = k.decode_byte(packed[pos0 + q]) * smin(ri, cseg[q]);
        }
    }
}

/// # Safety
/// AVX2 must be available; `dst` covers positions `0..pos0 + vals.len()`.
#[target_feature(enable = "avx2")]
unsafe fn encode_run_scaled_v(
    k: &QuantKernels,
    vals: &[f32],
    s: f32,
    pos0: usize,
    dst: &mut [u8],
) {
    debug_assert!(s > 0.0, "zero-scale runs take encode_run_zero");
    // SAFETY: target feature per caller contract; loads read 8 floats at
    // `i + 2p (+8)` with `p + 8 <= pairs`, stores write the 8 bytes at
    // `byte0 + p` — all inside the slices by the run geometry.
    unsafe {
        let mut pos = pos0;
        let mut i = 0usize;
        if pos % 2 == 1 {
            set_hi(&mut dst[pos / 2], k.encode(vals[0] / s));
            pos += 1;
            i = 1;
        }
        let pairs = (vals.len() - i) / 2;
        let byte0 = pos / 2;
        let vs = _mm256_set1_ps(s);
        let mut p = 0usize;
        while p + 8 <= pairs {
            let n0 = _mm256_div_ps(_mm256_loadu_ps(vals.as_ptr().add(i + 2 * p)), vs);
            let n1 = _mm256_div_ps(_mm256_loadu_ps(vals.as_ptr().add(i + 2 * p + 8)), vs);
            let c0 = encode8(&k.mid16, n0);
            let c1 = encode8(&k.mid16, n1);
            pack16(c0, c1, dst.as_mut_ptr().add(byte0 + p));
            p += 8;
        }
        for q in p..pairs {
            let c0 = k.encode(vals[i + 2 * q] / s);
            let c1 = k.encode(vals[i + 2 * q + 1] / s);
            dst[byte0 + q] = c0 | (c1 << 4);
        }
        if i + 2 * pairs < vals.len() {
            let last = vals.len() - 1;
            set_lo(&mut dst[(pos0 + last) / 2], k.encode(vals[last] / s));
        }
    }
}

/// 8-lane rank-1 normalize: `v / min(ri, c)` where the combined scale is
/// positive, else a literal 0.0 (the masked-out division lanes may
/// produce inf/NaN and are discarded by the blend).
///
/// # Safety
/// AVX2 must be available.
#[target_feature(enable = "avx2")]
#[inline]
unsafe fn norm8(v: __m256, vri: __m256, c: __m256) -> __m256 {
    // SAFETY: caller contract — AVX2 enabled; register-only ops.
    unsafe {
        let sv = _mm256_min_ps(vri, c);
        let zero = _mm256_setzero_ps();
        let mask = _mm256_cmp_ps::<_CMP_GT_OQ>(sv, zero);
        _mm256_blendv_ps(zero, _mm256_div_ps(v, sv), mask)
    }
}

/// # Safety
/// AVX2 must be available; `cseg.len() == vals.len()`; `dst` covers
/// positions `0..pos0 + vals.len()`.
#[target_feature(enable = "avx2")]
unsafe fn encode_rank1_row_v(
    k: &QuantKernels,
    vals: &[f32],
    ri: f32,
    cseg: &[f32],
    pos0: usize,
    dst: &mut [u8],
) {
    debug_assert_eq!(cseg.len(), vals.len());
    // SAFETY: target feature per caller contract; bounds as in
    // encode_run_scaled_v, with cseg walking in lockstep with vals.
    unsafe {
        let mut pos = pos0;
        let mut i = 0usize;
        if pos % 2 == 1 {
            set_hi(&mut dst[pos / 2], k.encode(norm1(vals[0], ri, cseg[0])));
            pos += 1;
            i = 1;
        }
        let pairs = (vals.len() - i) / 2;
        let byte0 = pos / 2;
        let vri = _mm256_set1_ps(ri);
        let mut p = 0usize;
        while p + 8 <= pairs {
            let n0 = norm8(
                _mm256_loadu_ps(vals.as_ptr().add(i + 2 * p)),
                vri,
                _mm256_loadu_ps(cseg.as_ptr().add(i + 2 * p)),
            );
            let n1 = norm8(
                _mm256_loadu_ps(vals.as_ptr().add(i + 2 * p + 8)),
                vri,
                _mm256_loadu_ps(cseg.as_ptr().add(i + 2 * p + 8)),
            );
            let c0 = encode8(&k.mid16, n0);
            let c1 = encode8(&k.mid16, n1);
            pack16(c0, c1, dst.as_mut_ptr().add(byte0 + p));
            p += 8;
        }
        for q in p..pairs {
            let c0 = k.encode(norm1(vals[i + 2 * q], ri, cseg[i + 2 * q]));
            let c1 = k.encode(norm1(vals[i + 2 * q + 1], ri, cseg[i + 2 * q + 1]));
            dst[byte0 + q] = c0 | (c1 << 4);
        }
        if i + 2 * pairs < vals.len() {
            let last = vals.len() - 1;
            set_lo(&mut dst[(pos0 + last) / 2], k.encode(norm1(vals[last], ri, cseg[last])));
        }
    }
}

/// Scalar rank-1 normalize for the edge elements (mirrors the scalar
/// tier's `norm`).
#[inline(always)]
fn norm1(v: f32, ri: f32, cj: f32) -> f32 {
    let s = smin(ri, cj);
    if s > 0.0 {
        v / s
    } else {
        0.0
    }
}

/// # Safety
/// AVX2 must be available; `dst` covers positions `0..pos0 + vals.len()`.
#[target_feature(enable = "avx2")]
unsafe fn encode_sr_run_scaled_v(
    map: &QuantMap,
    vals: &[f32],
    s: f32,
    pos0: usize,
    dst: &mut [u8],
    rng: &mut Pcg64,
) {
    use super::super::stochastic::encode_stochastic;
    debug_assert!(s > 0.0, "zero-scale runs take encode_run_zero");
    let k = map.kernels();
    // SAFETY: target feature per caller contract; vector loads bounded
    // as in encode_run_scaled_v; the per-lane draws spill through local
    // 8-lane arrays and index dst through checked slice ops.
    unsafe {
        let mut pos = pos0;
        let mut i = 0usize;
        if pos % 2 == 1 {
            set_hi(&mut dst[pos / 2], encode_stochastic(map, vals[0] / s, rng));
            pos += 1;
            i = 1;
        }
        let pairs = (vals.len() - i) / 2;
        let byte0 = pos / 2;
        let vs = _mm256_set1_ps(s);
        let mut p = 0usize;
        while p + 8 <= pairs {
            let n0 = _mm256_div_ps(_mm256_loadu_ps(vals.as_ptr().add(i + 2 * p)), vs);
            let n1 = _mm256_div_ps(_mm256_loadu_ps(vals.as_ptr().add(i + 2 * p + 8)), vs);
            sr_group(k, n0, n1, dst.as_mut_ptr().add(byte0 + p), rng);
            p += 8;
        }
        for q in p..pairs {
            let c0 = encode_stochastic(map, vals[i + 2 * q] / s, rng);
            let c1 = encode_stochastic(map, vals[i + 2 * q + 1] / s, rng);
            dst[byte0 + q] = c0 | (c1 << 4);
        }
        if i + 2 * pairs < vals.len() {
            let last = vals.len() - 1;
            set_lo(&mut dst[(pos0 + last) / 2], encode_stochastic(map, vals[last] / s, rng));
        }
    }
}

/// # Safety
/// AVX2 must be available; `cseg.len() == vals.len()`; `dst` covers
/// positions `0..pos0 + vals.len()`.
#[target_feature(enable = "avx2")]
unsafe fn encode_sr_rank1_row_v(
    map: &QuantMap,
    vals: &[f32],
    ri: f32,
    cseg: &[f32],
    pos0: usize,
    dst: &mut [u8],
    rng: &mut Pcg64,
) {
    use super::super::stochastic::encode_stochastic;
    debug_assert_eq!(cseg.len(), vals.len());
    let k = map.kernels();
    // SAFETY: target feature per caller contract; bounds as in
    // encode_sr_run_scaled_v, with cseg walking in lockstep with vals.
    unsafe {
        let mut pos = pos0;
        let mut i = 0usize;
        if pos % 2 == 1 {
            let code = encode_stochastic(map, norm1(vals[0], ri, cseg[0]), rng);
            set_hi(&mut dst[pos / 2], code);
            pos += 1;
            i = 1;
        }
        let pairs = (vals.len() - i) / 2;
        let byte0 = pos / 2;
        let vri = _mm256_set1_ps(ri);
        let mut p = 0usize;
        while p + 8 <= pairs {
            let n0 = norm8(
                _mm256_loadu_ps(vals.as_ptr().add(i + 2 * p)),
                vri,
                _mm256_loadu_ps(cseg.as_ptr().add(i + 2 * p)),
            );
            let n1 = norm8(
                _mm256_loadu_ps(vals.as_ptr().add(i + 2 * p + 8)),
                vri,
                _mm256_loadu_ps(cseg.as_ptr().add(i + 2 * p + 8)),
            );
            sr_group(k, n0, n1, dst.as_mut_ptr().add(byte0 + p), rng);
            p += 8;
        }
        for q in p..pairs {
            let c0 = encode_stochastic(map, norm1(vals[i + 2 * q], ri, cseg[i + 2 * q]), rng);
            let c1 =
                encode_stochastic(map, norm1(vals[i + 2 * q + 1], ri, cseg[i + 2 * q + 1]), rng);
            dst[byte0 + q] = c0 | (c1 << 4);
        }
        if i + 2 * pairs < vals.len() {
            let last = vals.len() - 1;
            let code = encode_stochastic(map, norm1(vals[last], ri, cseg[last]), rng);
            set_lo(&mut dst[(pos0 + last) / 2], code);
        }
    }
}

/// One SR group: bracket counts for 16 normalized lanes in registers,
/// then per-element draws in element order, packed into 8 output bytes.
///
/// # Safety
/// AVX2 must be available and `dst` must point at 8 writable bytes.
#[target_feature(enable = "avx2")]
#[inline]
unsafe fn sr_group(k: &QuantKernels, n0: __m256, n1: __m256, dst: *mut u8, rng: &mut Pcg64) {
    // SAFETY: caller contract — AVX2 enabled, 8 bytes writable at `dst`;
    // spills land in local 8-lane arrays.
    unsafe {
        let (c0, e0) = sr_counts(&k.vlt16, n0);
        let (c1, e1) = sr_counts(&k.vlt16, n1);
        let mut na = [0f32; 8];
        let mut nb = [0f32; 8];
        let mut ca = [0u32; 8];
        let mut cb = [0u32; 8];
        let mut ea = [0u32; 8];
        let mut eb = [0u32; 8];
        _mm256_storeu_ps(na.as_mut_ptr(), n0);
        _mm256_storeu_ps(nb.as_mut_ptr(), n1);
        _mm256_storeu_si256(ca.as_mut_ptr() as *mut __m256i, c0);
        _mm256_storeu_si256(cb.as_mut_ptr() as *mut __m256i, c1);
        _mm256_storeu_si256(ea.as_mut_ptr() as *mut __m256i, e0);
        _mm256_storeu_si256(eb.as_mut_ptr() as *mut __m256i, e1);
        let mut codes = [0u8; 16];
        for lane in 0..8 {
            codes[lane] = sr_pick(k, na[lane], ca[lane], ea[lane], rng);
        }
        for lane in 0..8 {
            codes[8 + lane] = sr_pick(k, nb[lane], cb[lane], eb[lane], rng);
        }
        for j in 0..8 {
            *dst.add(j) = codes[2 * j] | (codes[2 * j + 1] << 4);
        }
    }
}

/// # Safety
/// AVX2 must be available; `packed` covers positions `0..pos0 + g.len()`.
#[target_feature(enable = "avx2")]
#[allow(clippy::too_many_arguments)]
unsafe fn ema_run_v(
    k: &QuantKernels,
    packed: &mut [u8],
    pos0: usize,
    old_s: f32,
    new_s: f32,
    g: &[f32],
    beta: f32,
    second: bool,
) {
    debug_assert!(new_s > 0.0, "zero new scales take the unfused fallback");
    // SAFETY: target feature per caller contract; each group reads its 8
    // bytes before pack16 overwrites them (in-place safe), and all
    // offsets are bounded by the run geometry as in the decode kernels.
    unsafe {
        let mut pos = pos0;
        let mut i = 0usize;
        if pos % 2 == 1 {
            let slot = &mut packed[pos / 2];
            let x = k.decode_byte(*slot >> 4) * old_s;
            set_hi(slot, k.encode(ema(beta, x, g[0], second) / new_s));
            pos += 1;
            i = 1;
        }
        let pairs = (g.len() - i) / 2;
        let byte0 = pos / 2;
        let tbl_lo = _mm256_loadu_ps(k.val16.as_ptr());
        let tbl_hi = _mm256_loadu_ps(k.val16.as_ptr().add(8));
        let vos = _mm256_set1_ps(old_s);
        let vns = _mm256_set1_ps(new_s);
        let vbeta = _mm256_set1_ps(beta);
        let vomb = _mm256_set1_ps(1.0 - beta);
        let mut p = 0usize;
        while p + 8 <= pairs {
            let (i0, i1) = unpack16(packed.as_ptr().add(byte0 + p));
            let x0 = _mm256_mul_ps(lookup16(tbl_lo, tbl_hi, i0), vos);
            let x1 = _mm256_mul_ps(lookup16(tbl_lo, tbl_hi, i1), vos);
            let g0 = _mm256_loadu_ps(g.as_ptr().add(i + 2 * p));
            let g1 = _mm256_loadu_ps(g.as_ptr().add(i + 2 * p + 8));
            let y0 = ema8(vbeta, vomb, x0, g0, second);
            let y1 = ema8(vbeta, vomb, x1, g1, second);
            let c0 = encode8(&k.mid16, _mm256_div_ps(y0, vns));
            let c1 = encode8(&k.mid16, _mm256_div_ps(y1, vns));
            pack16(c0, c1, packed.as_mut_ptr().add(byte0 + p));
            p += 8;
        }
        for q in p..pairs {
            let b = packed[byte0 + q];
            let pv = k.decode_pair(b);
            let c0 = k.encode(ema(beta, pv[0] * old_s, g[i + 2 * q], second) / new_s);
            let c1 = k.encode(ema(beta, pv[1] * old_s, g[i + 2 * q + 1], second) / new_s);
            packed[byte0 + q] = c0 | (c1 << 4);
        }
        if i + 2 * pairs < g.len() {
            let last = g.len() - 1;
            let slot = &mut packed[(pos0 + last) / 2];
            let x = k.decode_byte(*slot & 0x0F) * old_s;
            set_lo(slot, k.encode(ema(beta, x, g[last], second) / new_s));
        }
    }
}

/// 8-lane phase-C EMA, same expression and association as the scalar
/// `ema` (`beta*x + ((1-beta)*g)*g` for the second moment) — separate
/// mul/add, never FMA, so lanes equal the scalar results bit for bit.
///
/// # Safety
/// AVX2 must be available.
#[target_feature(enable = "avx2")]
#[inline]
unsafe fn ema8(vbeta: __m256, vomb: __m256, x: __m256, g: __m256, second: bool) -> __m256 {
    // SAFETY: caller contract — AVX2 enabled; register-only ops.
    unsafe {
        let lhs = _mm256_mul_ps(vbeta, x);
        let rhs = if second {
            _mm256_mul_ps(_mm256_mul_ps(vomb, g), g)
        } else {
            _mm256_mul_ps(vomb, g)
        };
        _mm256_add_ps(lhs, rhs)
    }
}

/// # Safety
/// AVX2 must be available; `old_cseg`/`new_cseg` have `g.len()` entries;
/// `packed` covers positions `0..pos0 + g.len()`.
#[target_feature(enable = "avx2")]
#[allow(clippy::too_many_arguments)]
unsafe fn ema_rank1_v(
    k: &QuantKernels,
    packed: &mut [u8],
    pos0: usize,
    old_ri: f32,
    old_cseg: &[f32],
    new_ri: f32,
    new_cseg: &[f32],
    g: &[f32],
    beta: f32,
    second: bool,
) {
    debug_assert_eq!(old_cseg.len(), g.len());
    debug_assert_eq!(new_cseg.len(), g.len());
    // SAFETY: target feature per caller contract; bounds and in-place
    // ordering as in ema_run_v, with the scale segments walking in
    // lockstep with g.
    unsafe {
        let mut pos = pos0;
        let mut i = 0usize;
        if pos % 2 == 1 {
            let slot = &mut packed[pos / 2];
            let x = k.decode_byte(*slot >> 4) * smin(old_ri, old_cseg[0]);
            let val = ema(beta, x, g[0], second);
            set_hi(slot, k.encode(norm1(val, new_ri, new_cseg[0])));
            pos += 1;
            i = 1;
        }
        let pairs = (g.len() - i) / 2;
        let byte0 = pos / 2;
        let tbl_lo = _mm256_loadu_ps(k.val16.as_ptr());
        let tbl_hi = _mm256_loadu_ps(k.val16.as_ptr().add(8));
        let vori = _mm256_set1_ps(old_ri);
        let vnri = _mm256_set1_ps(new_ri);
        let vbeta = _mm256_set1_ps(beta);
        let vomb = _mm256_set1_ps(1.0 - beta);
        let mut p = 0usize;
        while p + 8 <= pairs {
            let (i0, i1) = unpack16(packed.as_ptr().add(byte0 + p));
            let os0 = _mm256_min_ps(vori, _mm256_loadu_ps(old_cseg.as_ptr().add(i + 2 * p)));
            let os1 = _mm256_min_ps(vori, _mm256_loadu_ps(old_cseg.as_ptr().add(i + 2 * p + 8)));
            let x0 = _mm256_mul_ps(lookup16(tbl_lo, tbl_hi, i0), os0);
            let x1 = _mm256_mul_ps(lookup16(tbl_lo, tbl_hi, i1), os1);
            let g0 = _mm256_loadu_ps(g.as_ptr().add(i + 2 * p));
            let g1 = _mm256_loadu_ps(g.as_ptr().add(i + 2 * p + 8));
            let y0 = ema8(vbeta, vomb, x0, g0, second);
            let y1 = ema8(vbeta, vomb, x1, g1, second);
            let n0 = norm8(y0, vnri, _mm256_loadu_ps(new_cseg.as_ptr().add(i + 2 * p)));
            let n1 = norm8(y1, vnri, _mm256_loadu_ps(new_cseg.as_ptr().add(i + 2 * p + 8)));
            let c0 = encode8(&k.mid16, n0);
            let c1 = encode8(&k.mid16, n1);
            pack16(c0, c1, packed.as_mut_ptr().add(byte0 + p));
            p += 8;
        }
        for q in p..pairs {
            let b = packed[byte0 + q];
            let pv = k.decode_pair(b);
            let (j0, j1) = (i + 2 * q, i + 2 * q + 1);
            let v0 = ema(beta, pv[0] * smin(old_ri, old_cseg[j0]), g[j0], second);
            let v1 = ema(beta, pv[1] * smin(old_ri, old_cseg[j1]), g[j1], second);
            let c0 = k.encode(norm1(v0, new_ri, new_cseg[j0]));
            let c1 = k.encode(norm1(v1, new_ri, new_cseg[j1]));
            packed[byte0 + q] = c0 | (c1 << 4);
        }
        if i + 2 * pairs < g.len() {
            let last = g.len() - 1;
            let slot = &mut packed[(pos0 + last) / 2];
            let x = k.decode_byte(*slot & 0x0F) * smin(old_ri, old_cseg[last]);
            let val = ema(beta, x, g[last], second);
            set_lo(slot, k.encode(norm1(val, new_ri, new_cseg[last])));
        }
    }
}
