//! §Perf kernel layer: nibble-granular decode/encode kernels for the
//! quantizer hot paths (the inner loops every optimizer step spends its
//! time in — see `engine/adamw4.rs` and the offload staged path).
//!
//! Three kernel families, all **bit-exact** to the scalar reference
//! paths they replace (`packing::get`/`set` + [`QuantMap::decode`] /
//! [`QuantMap::encode`]) — the contract the differential tests below and
//! the golden-parity suite pin:
//!
//! * **Pair-LUT decode** — a 256-entry `[f32; 2]` table decodes both
//!   nibbles of a packed byte in one load (Dettmers'22-style fused LUT
//!   dequant), so 4-bit decode loops do no per-element `i / 2` index
//!   arithmetic, parity branch, or shift; 8-bit (and every
//!   one-code-per-byte width) goes through a clamped 256-entry direct
//!   table that a `u8` index can never bounds-check.
//! * **Fast encode** — closed-form arithmetic for Linear maps (their
//!   midpoints are exact dyadic rationals, so the strict-compare count
//!   `#{mid < n}` reduces to a scaled ceil/floor) and a bits-keyed LUT
//!   for DE / DE-0: the top [`LUT_KEY_BITS`] bits of the monotone `u32`
//!   image of `n` select the narrow `[c_lo, c_hi]` band of possible
//!   codes, and at most `c_hi - c_lo` midpoint compares (usually zero)
//!   finish the job — replacing 15 compares (4-bit) or an 8-step binary
//!   search (8-bit) per element.
//! * **Fused run writers** — single-pass kernels that divide by the
//!   scale, encode, and emit whole output bytes (two codes packed per
//!   store). Only a byte the run enters or leaves mid-nibble is
//!   read-modified-written, so the `packing::set` load-store dependency
//!   chain that serialized every encode loop is gone. The family covers
//!   nearest-rounding encode, **stochastic-rounding** encode (the
//!   bracket draw rides the same fused packing; per-element RNG
//!   consumption order is part of the contract), and the **fused
//!   decode→EMA→re-encode** pass the engine's phase C runs in place
//!   over a packed state buffer.
//!
//! # Kernel tiers and runtime dispatch
//!
//! Every run kernel exists in two implementations: [`scalar`] (the
//! portable reference tier) and [`avx2`] (256-bit SIMD for the 4-bit
//! hot arms — shuffle-based 16-entry nibble lookup for decode, vector
//! midpoint compare-count for encode, vectorized normalize + bracket
//! counts for stochastic rounding — plus a gather-based decode over the
//! clamped direct table for the byte-per-code widths). The free
//! functions in this module
//! dispatch on [`active_tier`], resolved **once per process** from the
//! `LOWBIT_KERNEL_TIER` env override (`scalar` | `avx2` | `auto`) or,
//! by default, from `is_x86_feature_detected!("avx2")` — the same
//! read-once pattern as the engine's `LOWBIT_ENGINE_THREADS`.
//!
//! The tiers are **bit-identical** by contract: `QuantMap::encode` (the
//! oracle midpoint partition) and the scalar tier remain the reference,
//! and the SIMD tier is pinned against both by the differential suites
//! here and in `rust/tests/quant_tiers.rs` (adversarial floats — NaN,
//! ±inf, subnormals, `-0.0`, midpoint ties — across bitwidths and start
//! parities). SIMD lanes use only IEEE-exact operations in the scalar
//! order (`div`, `mul`, `add`, compares, table lookups; never FMA), so
//! equality is structural, not approximate.
//!
//! The LUTs live inside [`QuantMap`] itself ([`QuantKernels`], built
//! once in `QuantMap::new`): the optimizer's cached maps — borrowed by
//! the step engine through `StepParams` and by the offload pipeline's
//! staged kernels — carry them for free, so the warm step builds nothing
//! and stays zero-allocation (pinned by `rust/tests/ctx_cache.rs`).

use super::mapping::{MapKind, QuantMap};
use crate::util::rng::Pcg64;

pub mod scalar;

#[cfg(target_arch = "x86_64")]
pub mod avx2;
// Off x86-64 the AVX2 tier can never be resolved (detection is false and
// forcing it panics), so alias the module to keep dispatch arms portable.
#[cfg(not(target_arch = "x86_64"))]
pub use self::scalar as avx2;

// ---------------------------------------------------------------------
// Tier selection.
// ---------------------------------------------------------------------

/// A kernel implementation tier. Selected once per process (see
/// [`active_tier`]); every tier is bit-identical to every other.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelTier {
    /// Portable scalar reference kernels.
    Scalar,
    /// 256-bit AVX2 kernels (x86-64 with AVX2 only).
    Avx2,
}

impl KernelTier {
    /// Stable lowercase name (benches record it per run).
    pub fn name(self) -> &'static str {
        match self {
            KernelTier::Scalar => "scalar",
            KernelTier::Avx2 => "avx2",
        }
    }
}

/// Pure tier-resolution rule, split from the env/cpuid read so tests can
/// pin it: `over` is the raw `LOWBIT_KERNEL_TIER` value (if set),
/// `avx2_detected` the runtime CPU feature check. Forcing `avx2` on a
/// CPU without it is a hard error (silently falling back would make
/// "forced-tier" CI runs meaningless); so is an unrecognized value.
pub fn resolve_tier(over: Option<&str>, avx2_detected: bool) -> KernelTier {
    let auto = || {
        if avx2_detected {
            KernelTier::Avx2
        } else {
            KernelTier::Scalar
        }
    };
    match over {
        None => auto(),
        Some(raw) => match raw.trim().to_ascii_lowercase().as_str() {
            "" | "auto" => auto(),
            "scalar" => KernelTier::Scalar,
            "avx2" => {
                assert!(
                    avx2_detected,
                    "LOWBIT_KERNEL_TIER=avx2 forced, but this CPU does not report AVX2"
                );
                KernelTier::Avx2
            }
            other => panic!(
                "unrecognized LOWBIT_KERNEL_TIER value {other:?} (expected scalar|avx2|auto)"
            ),
        },
    }
}

fn detect_avx2() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        std::is_x86_feature_detected!("avx2")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// The process-wide kernel tier: `LOWBIT_KERNEL_TIER` when set, else CPU
/// feature detection. Read **once** and cached — the dispatchers below
/// sit on every quantizer hot path, so re-reading the environment per
/// call would put a syscall on the inner loop (same rationale and
/// semantics as the engine's `LOWBIT_ENGINE_THREADS`).
pub fn active_tier() -> KernelTier {
    static TIER: std::sync::OnceLock<KernelTier> = std::sync::OnceLock::new();
    *TIER.get_or_init(|| {
        let over = std::env::var("LOWBIT_KERNEL_TIER").ok();
        resolve_tier(over.as_deref(), detect_avx2())
    })
}

// ---------------------------------------------------------------------
// Shared table infrastructure.
// ---------------------------------------------------------------------

/// Top bits of the monotone `u32` float image keying the encode LUT:
/// 12 bits = sign + 8 exponent bits + 3 mantissa bits (4096 buckets, 8
/// sub-buckets per binade — enough that even the 8-bit DE map averages
/// only a few fix-up compares per element).
const LUT_KEY_BITS: u32 = 12;
const LUT_LEN: usize = 1 << LUT_KEY_BITS;

/// Order-preserving `u32` image of a non-NaN `f32`: negative floats flip
/// all bits, non-negative floats set the sign bit, so integer comparison
/// of images matches float comparison of values. (`-0.0` sorts just
/// below `+0.0`; that never flips a strict `mid < n` outcome because
/// the midpoint averaging in `QuantMap::new` can only produce `+0.0`.)
#[inline(always)]
fn monotone(n: f32) -> u32 {
    let b = n.to_bits();
    b ^ ((((b as i32) >> 31) as u32) | 0x8000_0000)
}

/// Write `code` into the low nibble, preserving the high one (the same
/// read-modify-write `packing::set` performs for even positions).
#[inline(always)]
fn set_lo(slot: &mut u8, code: u8) {
    *slot = (*slot & 0xF0) | (code & 0x0F);
}

/// Write `code` into the high nibble, preserving the low one.
#[inline(always)]
fn set_hi(slot: &mut u8, code: u8) {
    *slot = (*slot & 0x0F) | ((code & 0x0F) << 4);
}

/// The rank-1 scale combiner (Alg. 4 line 7) — kept as the exact
/// comparison form the scalar paths use, not `f32::min`.
#[inline(always)]
fn smin(a: f32, b: f32) -> f32 {
    if a < b {
        a
    } else {
        b
    }
}

/// The phase-C moment EMA, in exactly the expression form (and operator
/// association) `engine::adamw4::decode_ema_piece` uses — the fused
/// decode→EMA→re-encode kernels must reproduce it bit for bit.
#[inline(always)]
fn ema(beta: f32, x: f32, gv: f32, second: bool) -> f32 {
    if second {
        beta * x + (1.0 - beta) * gv * gv
    } else {
        beta * x + (1.0 - beta) * gv
    }
}

/// The fast encoder variants (see the module docs). Every variant is
/// bit-exact to the midpoint partition `#{mid < n}` with ties to the
/// smaller index; NaN input encodes to 0, exactly like the all-`false`
/// partition.
#[derive(Clone, Debug)]
enum FastEncode {
    /// Unsigned Linear `T(i) = (i+1)/2^b`: the midpoints are the exact
    /// dyadic rationals `(2i+3)/2^(b+1)`, so with `y = n * 2^(b+1)`
    /// (power-of-two scaling, exact) the count is
    /// `clamp(ceil((y - 3)/2), 0, 2^b - 1)`; the subtraction is exact
    /// wherever the outcome is sensitive to it.
    LinearU { y_scale: f32, top: u8 },
    /// Signed Linear `T = ±(i+1)/2^(b-1)`: midpoints scaled by `2^b` are
    /// `{-(2k+1), 0, +(2k+1) : k in [1, half-1]}` with `half = 2^(b-1)`,
    /// counted closed-form per sign.
    LinearS { y_scale: f32, half: u8 },
    /// Bits-keyed LUT for the DE / DE-0 maps: bucket → `[c_lo, c_hi]`,
    /// the min/max midpoint count over the bucket's value range; at most
    /// `c_hi - c_lo` direct midpoint compares resolve the exact code.
    Lut {
        lut: Box<[[u8; 2]; LUT_LEN]>,
        /// Copy of the map's midpoints for the fix-up compares.
        mid: Box<[f32]>,
    },
}

fn build_lut(mid: &[f32]) -> Box<[[u8; 2]; LUT_LEN]> {
    debug_assert!(mid.len() < 256, "counts must fit a u8");
    let mu: Vec<u32> = mid.iter().map(|&m| monotone(m)).collect();
    debug_assert!(
        mu.windows(2).all(|w| w[0] < w[1]),
        "midpoints must be strictly increasing"
    );
    let shift = 32 - LUT_KEY_BITS;
    let mut lut = vec![[0u8; 2]; LUT_LEN];
    for (t, entry) in lut.iter_mut().enumerate() {
        let lo_u = (t as u32) << shift;
        let hi_u = lo_u | ((1u32 << shift) - 1);
        // For any n in the bucket, #{mid < n} is at least the count
        // below the bucket's first image and at most the count at-or-
        // below its last; midpoints inside that band get compared
        // directly at encode time.
        let lo = mu.partition_point(|&m| m < lo_u) as u8;
        let hi = mu.partition_point(|&m| m <= hi_u) as u8;
        *entry = [lo, hi];
    }
    lut.into_boxed_slice().try_into().expect("LUT_LEN entries")
}

/// Decode/encode LUT bundle riding inside every [`QuantMap`] (built once
/// with the map, borrowed by every hot path).
#[derive(Clone, Debug)]
pub struct QuantKernels {
    /// 4-bit maps: `pair[b] = [T(b & 0xF), T(b >> 4)]`. Table indices
    /// are clamped for maps with fewer than 16 codes (DE-0); valid data
    /// never stores an out-of-table code, so clamping is unreachable on
    /// anything the scalar path would accept.
    pair: Option<Box<[[f32; 2]; 256]>>,
    /// Direct code → value table, clamp-padded to 256 entries so a `u8`
    /// index never bounds-checks.
    byte: Box<[f32; 256]>,
    /// Clamp-padded 16-lane value table (same clamp as `pair`/`byte`):
    /// the AVX2 nibble-lookup decode and the SR bracket endpoint reads
    /// index it with codes, so it must decode exactly like `byte`.
    val16: [f32; 16],
    /// `+inf`-padded 16-lane value table for the vector SR bracket
    /// counts (`+inf` never counts as `< n` or `== n` for finite `n`).
    vlt16: [f32; 16],
    /// `+inf`-padded 16-lane midpoint table: `#{mid16 < n}` over the
    /// first 15 lanes is exactly the 4-bit `QuantMap::encode` partition.
    mid16: [f32; 16],
    /// Number of real codes (15 for 4-bit DE-0, not 16).
    n_codes: u8,
    enc: FastEncode,
    /// `encode(0.0)` — the code every element of a zero-scale block
    /// takes.
    zero_code: u8,
}

impl QuantKernels {
    pub(crate) fn build(
        kind: MapKind,
        bits: u8,
        signed: bool,
        values: &[f32],
        mid: &[f32],
    ) -> QuantKernels {
        let clamp = |i: usize| values[i.min(values.len() - 1)];
        let byte: Box<[f32; 256]> = (0..256)
            .map(clamp)
            .collect::<Vec<f32>>()
            .into_boxed_slice()
            .try_into()
            .expect("256 entries");
        let pair = if bits == 4 {
            let v: Vec<[f32; 2]> = (0..256).map(|b| [clamp(b & 0x0F), clamp(b >> 4)]).collect();
            Some(v.into_boxed_slice().try_into().expect("256 entries"))
        } else {
            None
        };
        let mut val16 = [0.0f32; 16];
        for (i, dst) in val16.iter_mut().enumerate() {
            *dst = clamp(i);
        }
        let mut vlt16 = [f32::INFINITY; 16];
        for (dst, &v) in vlt16.iter_mut().zip(values.iter()) {
            *dst = v;
        }
        let mut mid16 = [f32::INFINITY; 16];
        for (dst, &m) in mid16.iter_mut().zip(mid.iter()) {
            *dst = m;
        }
        let enc = match (kind, signed) {
            (MapKind::Linear, false) => FastEncode::LinearU {
                y_scale: (1u32 << (bits as u32 + 1)) as f32,
                top: ((1u32 << bits) - 1) as u8,
            },
            (MapKind::Linear, true) => FastEncode::LinearS {
                y_scale: (1u32 << bits) as f32,
                half: (1u32 << (bits as u32 - 1)) as u8,
            },
            _ => FastEncode::Lut {
                lut: build_lut(mid),
                mid: mid.to_vec().into_boxed_slice(),
            },
        };
        let zero_code = mid.partition_point(|&m| m < 0.0) as u8;
        QuantKernels {
            pair,
            byte,
            val16,
            vlt16,
            mid16,
            n_codes: values.len() as u8,
            enc,
            zero_code,
        }
    }

    /// LUT / closed-form nearest-code encode — bit-exact to
    /// [`QuantMap::encode`] for every input (NaN included), pinned by
    /// the exhaustive differential tests below.
    #[inline]
    pub fn encode(&self, n: f32) -> u8 {
        if n.is_nan() {
            // The midpoint partition sees all-false compares.
            return 0;
        }
        match &self.enc {
            FastEncode::LinearU { y_scale, top } => {
                let k = ((n * y_scale - 3.0) * 0.5).ceil();
                if k >= *top as f32 {
                    *top
                } else if k >= 1.0 {
                    k as u8
                } else {
                    0
                }
            }
            FastEncode::LinearS { y_scale, half } => {
                let half = *half as u32;
                let y = n * y_scale;
                if y > 0.0 {
                    // half-1 negative midpoints and the zero midpoint
                    // are below, plus the positives strictly below y.
                    let k = ((y - 3.0) * 0.5).ceil();
                    let c = if k >= (half - 1) as f32 {
                        half - 1
                    } else if k >= 1.0 {
                        k as u32
                    } else {
                        0
                    };
                    (half + c) as u8
                } else {
                    // Negative midpoints -(2k+1) above y drop out.
                    let k = ((-y - 1.0) * 0.5).floor();
                    let c = if k >= (half - 1) as f32 {
                        half - 1
                    } else if k >= 1.0 {
                        k as u32
                    } else {
                        0
                    };
                    (half - 1 - c) as u8
                }
            }
            FastEncode::Lut { lut, mid } => {
                let u = monotone(n);
                let [lo, hi] = lut[(u >> (32 - LUT_KEY_BITS)) as usize];
                let mut c = lo;
                for &m in &mid[lo as usize..hi as usize] {
                    c += (m < n) as u8;
                }
                c
            }
        }
    }

    /// Both nibble values of a packed byte (4-bit maps only).
    #[inline]
    pub fn decode_pair(&self, byte: u8) -> [f32; 2] {
        self.pair4()[byte as usize]
    }

    /// Code → value through the clamp-padded direct table.
    #[inline]
    pub fn decode_byte(&self, code: u8) -> f32 {
        self.byte[code as usize]
    }

    /// The code `encode(0.0)` produces.
    #[inline]
    pub fn zero_code(&self) -> u8 {
        self.zero_code
    }

    #[inline]
    fn pair4(&self) -> &[[f32; 2]; 256] {
        self.pair.as_deref().expect("pair LUT exists for 4-bit maps")
    }
}

// ---------------------------------------------------------------------
// Tier-dispatched fused run kernels. Position convention: element `k` of
// the run sits at nibble (4-bit) or byte (otherwise) position `pos0 + k`
// of the packed buffer, i.e. the buffer's coverage starts at position 0.
// Runs may start and end mid-byte; boundary nibbles are handled with the
// scalar `set`/`get` semantics so neighboring runs compose exactly.
// ---------------------------------------------------------------------

/// Fused constant-scale run decode: `out[k] = T(code(pos0 + k)) * s`.
/// Bit-identical to a `packing::get` + `QuantMap::decode` + multiply
/// loop.
pub fn decode_run_scaled(
    map: &QuantMap,
    bits: u8,
    packed: &[u8],
    pos0: usize,
    s: f32,
    out: &mut [f32],
) {
    match active_tier() {
        KernelTier::Scalar => scalar::decode_run_scaled(map, bits, packed, pos0, s, out),
        KernelTier::Avx2 => avx2::decode_run_scaled(map, bits, packed, pos0, s, out),
    }
}

/// Fused rank-1 row-segment decode: element `j` scales by
/// `min(r_i, cseg[j])` — the row statistic is hoisted by the caller,
/// `cseg` holds the column statistics of exactly this segment's columns.
pub fn decode_rank1_row(
    map: &QuantMap,
    bits: u8,
    packed: &[u8],
    pos0: usize,
    ri: f32,
    cseg: &[f32],
    out: &mut [f32],
) {
    match active_tier() {
        KernelTier::Scalar => scalar::decode_rank1_row(map, bits, packed, pos0, ri, cseg, out),
        KernelTier::Avx2 => avx2::decode_rank1_row(map, bits, packed, pos0, ri, cseg, out),
    }
}

/// Fused normalize→encode→pack of a constant-scale run (`s > 0`):
/// position `pos0 + k` of `dst` receives `encode(vals[k] / s)`. Whole
/// output bytes are built in registers and stored once.
pub fn encode_run_scaled(
    map: &QuantMap,
    bits: u8,
    vals: &[f32],
    s: f32,
    pos0: usize,
    dst: &mut [u8],
) {
    match active_tier() {
        KernelTier::Scalar => scalar::encode_run_scaled(map, bits, vals, s, pos0, dst),
        KernelTier::Avx2 => avx2::encode_run_scaled(map, bits, vals, s, pos0, dst),
    }
}

/// Fused rank-1 row-segment encode: element `j` normalizes by
/// `min(r_i, cseg[j])` (zero scales encode a normalized 0, exactly like
/// the scalar path).
pub fn encode_rank1_row(
    map: &QuantMap,
    bits: u8,
    vals: &[f32],
    ri: f32,
    cseg: &[f32],
    pos0: usize,
    dst: &mut [u8],
) {
    match active_tier() {
        KernelTier::Scalar => scalar::encode_rank1_row(map, bits, vals, ri, cseg, pos0, dst),
        KernelTier::Avx2 => avx2::encode_rank1_row(map, bits, vals, ri, cseg, pos0, dst),
    }
}

/// Stochastic-rounding constant-scale run encode (`s > 0`): position
/// `pos0 + k` receives the SR code of `vals[k] / s`, drawing from `rng`
/// in element order exactly like an `encode_stochastic` + `packing::set`
/// loop (degenerate brackets — NaN, exact values, out-of-range — consume
/// no draw).
#[allow(clippy::too_many_arguments)]
pub fn encode_sr_run_scaled(
    map: &QuantMap,
    bits: u8,
    vals: &[f32],
    s: f32,
    pos0: usize,
    dst: &mut [u8],
    rng: &mut Pcg64,
) {
    match active_tier() {
        KernelTier::Scalar => scalar::encode_sr_run_scaled(map, bits, vals, s, pos0, dst, rng),
        KernelTier::Avx2 => avx2::encode_sr_run_scaled(map, bits, vals, s, pos0, dst, rng),
    }
}

/// Stochastic-rounding rank-1 row-segment encode: element `j` normalizes
/// by `min(r_i, cseg[j])` (a zero per-element scale encodes a normalized
/// 0, which for maps without a representable 0 still draws — identical
/// to the scalar `encode_stochastic` path).
#[allow(clippy::too_many_arguments)]
pub fn encode_sr_rank1_row(
    map: &QuantMap,
    bits: u8,
    vals: &[f32],
    ri: f32,
    cseg: &[f32],
    pos0: usize,
    dst: &mut [u8],
    rng: &mut Pcg64,
) {
    match active_tier() {
        KernelTier::Scalar => {
            scalar::encode_sr_rank1_row(map, bits, vals, ri, cseg, pos0, dst, rng)
        }
        KernelTier::Avx2 => avx2::encode_sr_rank1_row(map, bits, vals, ri, cseg, pos0, dst, rng),
    }
}

/// Fused phase-C pass over a constant-scale run, **in place**: decode
/// the old code at position `pos0 + k` (× `old_s`), apply the moment EMA
/// with `g[k]`, and re-encode against `new_s` (> 0) into the same
/// position. Bit-identical to decode-all → EMA → encode-all over the
/// same elements (same f32 ops per element, same RNG draw order under
/// `stochastic`).
#[allow(clippy::too_many_arguments)]
pub fn ema_reencode_run_scaled(
    map: &QuantMap,
    bits: u8,
    packed: &mut [u8],
    pos0: usize,
    old_s: f32,
    new_s: f32,
    g: &[f32],
    beta: f32,
    second: bool,
    stochastic: bool,
    rng: &mut Pcg64,
) {
    match active_tier() {
        KernelTier::Scalar => scalar::ema_reencode_run_scaled(
            map, bits, packed, pos0, old_s, new_s, g, beta, second, stochastic, rng,
        ),
        KernelTier::Avx2 => avx2::ema_reencode_run_scaled(
            map, bits, packed, pos0, old_s, new_s, g, beta, second, stochastic, rng,
        ),
    }
}

/// Fused phase-C pass over a rank-1 row segment, **in place**: decode
/// with the old `min(r_i, c_j)` scales, apply the EMA, re-encode against
/// the new ones (zero new scales encode a normalized 0, like the scalar
/// path).
#[allow(clippy::too_many_arguments)]
pub fn ema_reencode_rank1_row(
    map: &QuantMap,
    bits: u8,
    packed: &mut [u8],
    pos0: usize,
    old_ri: f32,
    old_cseg: &[f32],
    new_ri: f32,
    new_cseg: &[f32],
    g: &[f32],
    beta: f32,
    second: bool,
    stochastic: bool,
    rng: &mut Pcg64,
) {
    match active_tier() {
        KernelTier::Scalar => scalar::ema_reencode_rank1_row(
            map, bits, packed, pos0, old_ri, old_cseg, new_ri, new_cseg, g, beta, second,
            stochastic, rng,
        ),
        KernelTier::Avx2 => avx2::ema_reencode_rank1_row(
            map, bits, packed, pos0, old_ri, old_cseg, new_ri, new_cseg, g, beta, second,
            stochastic, rng,
        ),
    }
}

/// Zero-scale run fill: every element takes `encode(0.0)`, and the RNG
/// is (deliberately) untouched, matching the scalar zero-block arm.
/// Tier-independent — a fill has nothing to vectorize by hand.
pub fn encode_run_zero(map: &QuantMap, bits: u8, len: usize, pos0: usize, dst: &mut [u8]) {
    if len == 0 {
        return;
    }
    let zc = map.kernels().zero_code();
    if bits == 4 {
        let mut pos = pos0;
        let mut i = 0usize;
        if pos % 2 == 1 {
            set_hi(&mut dst[pos / 2], zc);
            pos += 1;
            i = 1;
        }
        let pairs = (len - i) / 2;
        let byte0 = pos / 2;
        dst[byte0..byte0 + pairs].fill(zc | (zc << 4));
        if i + 2 * pairs < len {
            set_lo(&mut dst[(pos0 + len - 1) / 2], zc);
        }
    } else {
        dst[pos0..pos0 + len].fill(zc);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::packing;
    use crate::quant::stochastic::encode_stochastic;
    use crate::util::propcheck;
    use crate::util::rng::Pcg64;

    fn all_maps(bit_choices: &[u8]) -> Vec<QuantMap> {
        let mut maps = Vec::new();
        for kind in [MapKind::Linear, MapKind::DynExp, MapKind::DynExpNoZero] {
            for signed in [false, true] {
                for &b in bit_choices {
                    if kind != MapKind::Linear && signed && b < 3 {
                        continue; // signed DE needs >= 3 bits
                    }
                    maps.push(QuantMap::new(kind, b, signed));
                }
            }
        }
        maps
    }

    /// IEEE next float up/down via bit manipulation (`f32::next_up` is
    /// too recent for the pinned toolchain).
    fn next_after(x: f32, up: bool) -> f32 {
        let b = x.to_bits();
        let nb = if up {
            if b == 0x8000_0000 {
                1 // -0.0 -> smallest positive subnormal
            } else if b & 0x8000_0000 != 0 {
                b - 1
            } else {
                b + 1
            }
        } else if b == 0 {
            0x8000_0001 // +0.0 -> smallest negative subnormal
        } else if b & 0x8000_0000 != 0 {
            b + 1
        } else {
            b - 1
        };
        f32::from_bits(nb)
    }

    #[test]
    fn resolve_tier_rules() {
        use KernelTier::*;
        assert_eq!(resolve_tier(None, true), Avx2);
        assert_eq!(resolve_tier(None, false), Scalar);
        assert_eq!(resolve_tier(Some("auto"), true), Avx2);
        assert_eq!(resolve_tier(Some("auto"), false), Scalar);
        assert_eq!(resolve_tier(Some(""), true), Avx2);
        assert_eq!(resolve_tier(Some("scalar"), true), Scalar);
        assert_eq!(resolve_tier(Some("scalar"), false), Scalar);
        assert_eq!(resolve_tier(Some("AVX2"), true), Avx2);
        assert_eq!(resolve_tier(Some(" avx2 "), true), Avx2);
    }

    #[test]
    fn resolve_tier_rejects_unsupported_force() {
        let r = std::panic::catch_unwind(|| resolve_tier(Some("avx2"), false));
        assert!(r.is_err(), "forcing avx2 without CPU support must panic");
    }

    #[test]
    fn resolve_tier_rejects_unknown_value() {
        let r = std::panic::catch_unwind(|| resolve_tier(Some("neon"), true));
        assert!(r.is_err(), "unknown tier names must panic, not fall back");
    }

    #[test]
    fn active_tier_matches_environment() {
        // The process-wide tier must be exactly what resolve_tier says
        // for this process's environment (CI runs the suite once with
        // LOWBIT_KERNEL_TIER=scalar to pin the forced path end to end).
        let over = std::env::var("LOWBIT_KERNEL_TIER").ok();
        assert_eq!(active_tier(), resolve_tier(over.as_deref(), detect_avx2()));
    }

    #[test]
    fn pair_lut_matches_decode_all_256_bytes() {
        // Exhaustive: every (map kind, signedness, 4/8-bit) combo, every
        // possible packed byte, both nibbles — the pair LUT must agree
        // with the scalar decode (index-clamped for DE-0's missing top
        // code, which valid data never stores).
        for map in all_maps(&[4, 8]) {
            let top = (map.len() - 1) as u8;
            for byte in 0..=255u8 {
                if map.bits == 4 {
                    let [lo, hi] = map.kernels().decode_pair(byte);
                    let exp_lo = map.decode((byte & 0x0F).min(top));
                    let exp_hi = map.decode((byte >> 4).min(top));
                    assert_eq!(
                        [lo.to_bits(), hi.to_bits()],
                        [exp_lo.to_bits(), exp_hi.to_bits()],
                        "{:?} b{} signed={} byte {byte:#04x}",
                        map.kind,
                        map.bits,
                        map.signed
                    );
                }
                let d = map.kernels().decode_byte(byte);
                assert_eq!(d.to_bits(), map.decode(byte.min(top)).to_bits());
            }
        }
    }

    #[test]
    fn val16_table_matches_byte_table() {
        for map in all_maps(&[4]) {
            let k = map.kernels();
            for c in 0..16u8 {
                assert_eq!(k.val16[c as usize].to_bits(), k.decode_byte(c).to_bits());
            }
        }
    }

    #[test]
    fn run_decode_matches_scalar_all_offsets() {
        // The fused run kernels vs the packing::get + decode + multiply
        // loop, across start parities and run lengths (lead/pair/tail
        // arms all exercised).
        let mut rng = Pcg64::seeded(9);
        for map in all_maps(&[4, 8]) {
            let n = 33;
            let codes: Vec<u8> = (0..n)
                .map(|_| (rng.next_u32() as usize % map.len()) as u8)
                .collect();
            let packed = packing::pack(&codes, map.bits);
            let s = 0.37f32;
            for lo in 0..n {
                for hi in [lo, lo + 1, lo + 2, n].into_iter().filter(|&h| h <= n) {
                    let mut out = vec![0.0f32; hi - lo];
                    decode_run_scaled(&map, map.bits, &packed, lo, s, &mut out);
                    for (k, &o) in out.iter().enumerate() {
                        let exp = map.decode(packing::get(&packed, lo + k, map.bits)) * s;
                        assert_eq!(o.to_bits(), exp.to_bits(), "run [{lo},{hi}) elem {k}");
                    }
                }
            }
        }
    }

    #[test]
    fn fast_encode_matches_oracle_dense_grid_and_edges() {
        // Dense grid + targeted edges (every representable value, every
        // midpoint and its two float neighbors — ties included — plus
        // ±0.0, subnormals, out-of-range and non-finite inputs) across
        // bitwidths: the LUT / closed-form encode must equal the
        // midpoint-partition oracle bit-for-bit.
        for map in all_maps(&[2, 3, 4, 5, 8]) {
            let mut pts: Vec<f32> = vec![
                0.0,
                -0.0,
                f32::INFINITY,
                f32::NEG_INFINITY,
                f32::NAN,
                f32::MIN_POSITIVE,
                -f32::MIN_POSITIVE,
                f32::from_bits(1),         // smallest subnormal
                f32::from_bits(0x007F_FFFF), // largest subnormal
                -f32::from_bits(1),
                5.0,
                -5.0,
                1e30,
                -1e30,
                1e-30,
                -1e-30,
            ];
            for w in map.values.windows(2) {
                let m = 0.5 * (w[0] + w[1]); // recomputes the stored midpoint
                for x in [w[0], w[1], m, next_after(m, true), next_after(m, false)] {
                    pts.push(x);
                    pts.push(-x);
                }
            }
            for i in 0..=24_000 {
                pts.push(-1.2 + i as f32 * 1e-4);
            }
            for n in pts {
                let fast = map.encode_fast(n);
                let oracle = map.encode(n);
                assert_eq!(
                    fast, oracle,
                    "{:?} b{} signed={} n={n:?} ({:#010x})",
                    map.kind,
                    map.bits,
                    map.signed,
                    n.to_bits()
                );
            }
        }
    }

    #[test]
    fn fast_encode_matches_oracle_random_bits_property() {
        // Random float bit patterns (NaNs included — both paths must
        // treat them as the all-false partition).
        let maps = all_maps(&[3, 4, 8]);
        propcheck::check("fast-encode-differential", 200, |g| {
            let map = g.choose(&maps);
            for _ in 0..64 {
                let n = f32::from_bits(g.rng.next_u32());
                let fast = map.encode_fast(n);
                let oracle = map.encode(n);
                if fast != oracle {
                    return Err(format!(
                        "{:?} b{} signed={}: n bits {:#010x} fast={fast} oracle={oracle}",
                        map.kind,
                        map.bits,
                        map.signed,
                        n.to_bits()
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn fused_encode_writers_match_scalar_set_paths() {
        // encode_run_scaled / encode_rank1_row / encode_run_zero vs the
        // scalar normalize + encode + packing::set loop, at every start
        // parity (boundary RMW nibbles must compose exactly).
        let mut rng = Pcg64::seeded(4);
        for map in all_maps(&[4, 8]) {
            let n = 21;
            let vals: Vec<f32> = (0..n).map(|_| rng.normal() * 0.8).collect();
            let cseg: Vec<f32> = (0..n).map(|_| rng.next_f32()).collect();
            let ri = 0.6f32;
            let s = 0.9f32;
            for pos0 in [0usize, 1, 2, 3] {
                let blen = packing::packed_len(pos0 + n, map.bits);
                for mode in 0..3 {
                    let mut fused = vec![0xA5u8; blen];
                    let mut scalar = vec![0xA5u8; blen];
                    match mode {
                        0 => {
                            encode_run_scaled(&map, map.bits, &vals, s, pos0, &mut fused);
                            for (j, &v) in vals.iter().enumerate() {
                                packing::set(&mut scalar, pos0 + j, map.encode(v / s), map.bits);
                            }
                        }
                        1 => {
                            encode_rank1_row(&map, map.bits, &vals, ri, &cseg, pos0, &mut fused);
                            for (j, &v) in vals.iter().enumerate() {
                                let sc = if ri < cseg[j] { ri } else { cseg[j] };
                                let nrm = if sc > 0.0 { v / sc } else { 0.0 };
                                packing::set(&mut scalar, pos0 + j, map.encode(nrm), map.bits);
                            }
                        }
                        _ => {
                            encode_run_zero(&map, map.bits, n, pos0, &mut fused);
                            let zc = map.encode(0.0);
                            for j in 0..n {
                                packing::set(&mut scalar, pos0 + j, zc, map.bits);
                            }
                        }
                    }
                    assert_eq!(
                        fused, scalar,
                        "{:?} b{} signed={} pos0={pos0} mode={mode}",
                        map.kind, map.bits, map.signed
                    );
                }
            }
        }
    }

    #[test]
    fn sr_writers_match_scalar_set_paths_and_rng_stream() {
        // The fused SR writers vs the encode_stochastic + packing::set
        // loop: same packed bytes AND the same post-call RNG state (the
        // engine's cross-thread bit-identity rests on draw-for-draw
        // equivalence), at every start parity and for long runs that
        // exercise the vector middle of the AVX2 tier.
        let mut drng = Pcg64::seeded(31);
        for map in all_maps(&[4, 8]) {
            for n in [3usize, 21, 70] {
                let vals: Vec<f32> = (0..n).map(|_| drng.normal() * 0.8).collect();
                let mut cseg: Vec<f32> = (0..n).map(|_| drng.next_f32()).collect();
                cseg[n / 2] = 0.0; // zero per-element scale arm
                let ri = 0.6f32;
                let s = 0.9f32;
                for pos0 in [0usize, 1, 2, 3] {
                    let blen = packing::packed_len(pos0 + n, map.bits);
                    for mode in 0..2 {
                        let mut fused = vec![0xA5u8; blen];
                        let mut reference = vec![0xA5u8; blen];
                        let mut r_f = Pcg64::seeded(7 + mode as u64);
                        let mut r_s = Pcg64::seeded(7 + mode as u64);
                        if mode == 0 {
                            encode_sr_run_scaled(
                                &map, map.bits, &vals, s, pos0, &mut fused, &mut r_f,
                            );
                            for (j, &v) in vals.iter().enumerate() {
                                let code = encode_stochastic(&map, v / s, &mut r_s);
                                packing::set(&mut reference, pos0 + j, code, map.bits);
                            }
                        } else {
                            encode_sr_rank1_row(
                                &map, map.bits, &vals, ri, &cseg, pos0, &mut fused, &mut r_f,
                            );
                            for (j, &v) in vals.iter().enumerate() {
                                let sc = if ri < cseg[j] { ri } else { cseg[j] };
                                let nrm = if sc > 0.0 { v / sc } else { 0.0 };
                                let code = encode_stochastic(&map, nrm, &mut r_s);
                                packing::set(&mut reference, pos0 + j, code, map.bits);
                            }
                        }
                        assert_eq!(
                            fused, reference,
                            "{:?} b{} signed={} n={n} pos0={pos0} mode={mode}",
                            map.kind, map.bits, map.signed
                        );
                        assert_eq!(
                            r_f.next_u64(),
                            r_s.next_u64(),
                            "{:?} b{} signed={} n={n} pos0={pos0} mode={mode}: RNG diverged",
                            map.kind,
                            map.bits,
                            map.signed
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn ema_reencode_matches_decode_then_encode() {
        // The fused in-place phase-C kernels vs the unfused reference:
        // decode every element (old scales), EMA with the gradient,
        // nearest/SR encode (new scales) through packing::set — same
        // final bytes, same RNG stream, at every start parity, for both
        // moment forms, zero new scales included.
        let mut drng = Pcg64::seeded(52);
        for map in all_maps(&[4, 8]) {
            for n in [5usize, 37] {
                let codes: Vec<u8> = (0..n)
                    .map(|_| (drng.next_u32() as usize % map.len()) as u8)
                    .collect();
                let g: Vec<f32> = (0..n).map(|_| drng.normal() * 0.3).collect();
                let old_s = 0.8f32;
                let new_s = 0.55f32;
                let old_c: Vec<f32> = (0..n).map(|_| 0.2 + drng.next_f32()).collect();
                let mut new_c = old_c.clone();
                new_c[n / 3] = 0.0; // zero new per-element scale arm
                let (old_ri, new_ri) = (0.7f32, 0.9f32);
                let beta = 0.9f32;
                for pos0 in [0usize, 1, 2, 3] {
                    for second in [false, true] {
                        for stochastic in [false, true] {
                            for mode in 0..2 {
                                let mut base = vec![0u8; packing::packed_len(pos0 + n, map.bits)];
                                for (j, &c) in codes.iter().enumerate() {
                                    packing::set(&mut base, pos0 + j, c, map.bits);
                                }
                                let mut fused = base.clone();
                                let mut reference = base.clone();
                                let mut r_f = Pcg64::seeded(11);
                                let mut r_s = Pcg64::seeded(11);
                                // Reference: unfused decode → EMA → encode.
                                for j in 0..n {
                                    let c = packing::get(&reference, pos0 + j, map.bits);
                                    let (os, ns) = if mode == 0 {
                                        (old_s, new_s)
                                    } else {
                                        (smin(old_ri, old_c[j]), smin(new_ri, new_c[j]))
                                    };
                                    let x = map.decode(c) * os;
                                    let val = ema(beta, x, g[j], second);
                                    let nrm = if ns > 0.0 { val / ns } else { 0.0 };
                                    let code = if stochastic {
                                        encode_stochastic(&map, nrm, &mut r_s)
                                    } else {
                                        map.encode(nrm)
                                    };
                                    packing::set(&mut reference, pos0 + j, code, map.bits);
                                }
                                if mode == 0 {
                                    ema_reencode_run_scaled(
                                        &map, map.bits, &mut fused, pos0, old_s, new_s, &g, beta,
                                        second, stochastic, &mut r_f,
                                    );
                                } else {
                                    ema_reencode_rank1_row(
                                        &map, map.bits, &mut fused, pos0, old_ri, &old_c, new_ri,
                                        &new_c, &g, beta, second, stochastic, &mut r_f,
                                    );
                                }
                                assert_eq!(
                                    fused, reference,
                                    "{:?} b{} signed={} n={n} pos0={pos0} second={second} \
                                     sr={stochastic} mode={mode}",
                                    map.kind, map.bits, map.signed
                                );
                                assert_eq!(r_f.next_u64(), r_s.next_u64(), "RNG diverged");
                            }
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn zero_code_matches_reference() {
        for map in all_maps(&[2, 3, 4, 5, 8]) {
            assert_eq!(map.kernels().zero_code(), map.encode(0.0));
            assert_eq!(map.encode_fast(0.0), map.encode(0.0));
        }
    }
}
