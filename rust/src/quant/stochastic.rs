#![forbid(unsafe_code)]
//! Stochastic rounding (paper App. E.3): instead of nearest-value
//! rounding, a normalized value between two representable points is
//! rounded up with probability proportional to its distance from the
//! lower point, making the quantizer unbiased in expectation.

use super::mapping::QuantMap;
use crate::util::rng::Pcg64;

/// Stochastically round `n` onto `map`. When `n` lies outside the table or
/// exactly on a representable value the result is deterministic.
#[inline]
pub fn encode_stochastic(map: &QuantMap, n: f32, rng: &mut Pcg64) -> u8 {
    let (lo, hi) = map.bracket(n);
    if lo == hi {
        return lo;
    }
    let a = map.decode(lo);
    let b = map.decode(hi);
    let p_hi = (n - a) / (b - a);
    if rng.next_f32() < p_hi {
        hi
    } else {
        lo
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::mapping::MapKind;

    #[test]
    fn deterministic_on_exact_values() {
        let map = QuantMap::new(MapKind::Linear, 4, false);
        let mut rng = Pcg64::seeded(0);
        for q in 0..map.len() as u8 {
            let v = map.decode(q);
            assert_eq!(encode_stochastic(&map, v, &mut rng), q);
        }
    }

    #[test]
    fn unbiased_in_expectation() {
        let map = QuantMap::new(MapKind::Linear, 4, false);
        // Pick a point 30% of the way between codes 4 (0.3125) and 5 (0.375).
        let a = map.decode(4);
        let b = map.decode(5);
        let n = a + 0.3 * (b - a);
        let mut rng = Pcg64::seeded(123);
        let trials = 20_000;
        let mut mean = 0.0f64;
        for _ in 0..trials {
            mean += map.decode(encode_stochastic(&map, n, &mut rng)) as f64;
        }
        mean /= trials as f64;
        assert!(
            (mean - n as f64).abs() < 2e-3,
            "E[deq] = {mean}, want ~{n}"
        );
    }

    #[test]
    fn clamps_out_of_range() {
        let map = QuantMap::new(MapKind::DynExp, 4, true);
        let mut rng = Pcg64::seeded(1);
        assert_eq!(encode_stochastic(&map, -9.0, &mut rng), 0);
        assert_eq!(
            encode_stochastic(&map, 9.0, &mut rng) as usize,
            map.len() - 1
        );
    }
}
