#![forbid(unsafe_code)]
//! The quantizer `Q = M ∘ N` (paper §2.2) and its persisted form,
//! [`QuantizedTensor`]. This is the compression/decompression pair used by
//! Alg. 1: the optimizer's working state exists in f32 only transiently;
//! what lives in memory between steps is a `QuantizedTensor`.

use super::kernels;
use super::mapping::{MapKind, QuantMap};
use super::normalize::{compute_scales, denormalize, NormKind, Scales};
use super::packing;
use super::stochastic::encode_stochastic;
use crate::tensor::Tensor;
use crate::util::rng::Pcg64;

/// Quantizer configuration. Named `Norm./Map.` in the paper, e.g.
/// `B128/DE` or `Rank-1/Linear`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Quantizer {
    pub norm: NormKind,
    pub map: MapKind,
    pub bits: u8,
    pub signed: bool,
    pub stochastic: bool,
}

impl Quantizer {
    pub fn new(norm: NormKind, map: MapKind, bits: u8, signed: bool) -> Quantizer {
        Quantizer {
            norm,
            map,
            bits,
            signed,
            stochastic: false,
        }
    }

    /// Paper presets -------------------------------------------------

    /// First-moment quantizer of 4-bit AdamW: B128/DE, signed.
    pub fn first_moment_4bit() -> Quantizer {
        Quantizer::new(NormKind::Block(128), MapKind::DynExp, 4, true)
    }

    /// Second-moment quantizer of 4-bit AdamW: Rank-1/Linear, unsigned.
    pub fn second_moment_4bit() -> Quantizer {
        Quantizer::new(NormKind::Rank1, MapKind::Linear, 4, false)
    }

    /// Dettmers'22 8-bit moments: B2048/DE (signed for m, unsigned for v).
    pub fn moment_8bit(signed: bool) -> Quantizer {
        Quantizer::new(NormKind::Block(2048), MapKind::DynExp, 8, signed)
    }

    pub fn with_stochastic(mut self, on: bool) -> Quantizer {
        self.stochastic = on;
        self
    }

    /// Paper-style name, e.g. `B128/DE` or `Rank-1/Linear`.
    pub fn name(&self) -> String {
        format!("{}/{}", self.norm.name(), self.map.name())
    }

    pub fn build_map(&self) -> QuantMap {
        QuantMap::new(self.map, self.bits, self.signed)
    }

    /// Compress a tensor. `rng` is only consulted when
    /// `self.stochastic` is set.
    pub fn quantize(&self, x: &Tensor, rng: &mut Pcg64) -> QuantizedTensor {
        let map = self.build_map();
        self.quantize_with(x, &map, rng)
    }

    /// Compress with a prebuilt map (hot path: the map is cached by the
    /// optimizer and reused across tensors and steps).
    pub fn quantize_with(&self, x: &Tensor, map: &QuantMap, rng: &mut Pcg64) -> QuantizedTensor {
        debug_assert_eq!(map.kind, self.map);
        debug_assert_eq!(map.bits, self.bits);
        let scales = compute_scales(x, self.norm);
        let n = x.numel();
        // §Perf fused arms ([`super::kernels`]): normalize → encode →
        // pack in one pass, whole output bytes per store, no code or
        // norm buffers. True division (not reciprocal multiply) keeps
        // the codes bit-identical to the python oracle, which the golden
        // parity tests require. Stochastic rounding rides the same fused
        // writers — the SR kernels draw from `rng` in element order,
        // exactly like the unfused `encode_stochastic` loop.
        if let Some(packed) = self.quantize_fused(x, map, &scales, rng) {
            return QuantizedTensor {
                shape: x.shape.clone(),
                bits: self.bits,
                packed,
                scales,
                quantizer: *self,
            };
        }
        // Layouts without a fused arm (rank-1 on N-D tensors; stochastic
        // per-tensor with a zero scale, where every element still takes
        // its SR draw on a normalized 0): element-wise reference path.
        let mut codes = vec![0u8; n];
        for (i, &v) in x.data.iter().enumerate() {
            let s = scales.scale_at(i, &x.shape);
            let nrm = if s > 0.0 { v / s } else { 0.0 };
            codes[i] = if self.stochastic {
                encode_stochastic(map, nrm, rng)
            } else {
                map.encode(nrm)
            };
        }
        QuantizedTensor {
            shape: x.shape.clone(),
            bits: self.bits,
            packed: packing::pack(&codes, self.bits),
            scales,
            quantizer: *self,
        }
    }

    /// The fused whole-tensor encode arms: block-scaled, rank-1 on 2-D,
    /// and per-tensor runs go straight to packed bytes through the kernel
    /// layer; stochastic rounding takes the SR kernel variants, which
    /// consume `rng` element-for-element like the unfused loop. Returns
    /// `None` for the layouts that stay on the element-wise path (rank-1
    /// on N-D tensors; stochastic per-tensor with a zero scale, where
    /// every element still draws on a normalized 0).
    fn quantize_fused(
        &self,
        x: &Tensor,
        map: &QuantMap,
        scales: &Scales,
        rng: &mut Pcg64,
    ) -> Option<Vec<u8>> {
        if matches!(scales, Scales::Rank1 { .. }) && x.ndim() != 2 {
            return None; // rank-1 on N-D stays on the element-wise path
        }
        if self.stochastic {
            if let Scales::PerTensor(s) = scales {
                if *s <= 0.0 {
                    return None; // SR on a zero scale still draws per element
                }
            }
        }
        let n = x.numel();
        let mut packed = vec![0u8; packing::packed_len(n, self.bits)];
        match scales {
            Scales::Block { block, scales: sc } => {
                for (bi, chunk) in x.data.chunks(*block).enumerate() {
                    let base = bi * *block;
                    let s = sc[bi];
                    if s > 0.0 {
                        if self.stochastic {
                            kernels::encode_sr_run_scaled(
                                map,
                                self.bits,
                                chunk,
                                s,
                                base,
                                &mut packed,
                                rng,
                            );
                        } else {
                            kernels::encode_run_scaled(map, self.bits, chunk, s, base, &mut packed);
                        }
                    } else {
                        // All-zero block: every code encodes normalized 0
                        // and the RNG is deliberately not consumed.
                        kernels::encode_run_zero(map, self.bits, chunk.len(), base, &mut packed);
                    }
                }
            }
            Scales::Rank1 { per_axis } if x.ndim() == 2 => {
                let (rows, cols) = x.dims2();
                let r = &per_axis[0];
                let c = &per_axis[1];
                for i in 0..rows {
                    let row_vals = &x.data[i * cols..(i + 1) * cols];
                    if self.stochastic {
                        kernels::encode_sr_rank1_row(
                            map,
                            self.bits,
                            row_vals,
                            r[i],
                            c,
                            i * cols,
                            &mut packed,
                            rng,
                        );
                    } else {
                        kernels::encode_rank1_row(
                            map,
                            self.bits,
                            row_vals,
                            r[i],
                            c,
                            i * cols,
                            &mut packed,
                        );
                    }
                }
            }
            Scales::PerTensor(s) => {
                if *s > 0.0 {
                    if self.stochastic {
                        kernels::encode_sr_run_scaled(
                            map,
                            self.bits,
                            &x.data,
                            *s,
                            0,
                            &mut packed,
                            rng,
                        );
                    } else {
                        kernels::encode_run_scaled(map, self.bits, &x.data, *s, 0, &mut packed);
                    }
                } else {
                    kernels::encode_run_zero(map, self.bits, n, 0, &mut packed);
                }
            }
            _ => return None,
        }
        Some(packed)
    }

    // ------------------------------------------------------------------
    // §Perf: range-based hot paths for the shard-parallel step engine
    // ([`crate::engine`]). They quantize element *sub-ranges* with
    // caller-provided output buffers, so a training step allocates
    // nothing per tensor; each mirrors the whole-tensor path above
    // bit-exactly (pinned by `range_apis_match_whole_tensor_paths`).
    // ------------------------------------------------------------------

    /// Quantize a block-aligned element range of a tensor: per-block
    /// scales go to `scales_out` (indexed from the range's first block)
    /// and packed codes to `dst`, the packed-byte sub-range of the same
    /// elements.
    ///
    /// Contract (the caller's — i.e. the engine planner's — to uphold;
    /// only the buffer lengths are debug-asserted here): the range starts
    /// on a block boundary (`vals[0]` is the first element of a block)
    /// and, for 4-bit codes, on an even element so it owns whole bytes;
    /// it ends on a block boundary or at the end of the tensor. A
    /// mid-block start would silently compute a wrong scale for the
    /// partial first block.
    pub fn encode_block_range(
        &self,
        map: &QuantMap,
        vals: &[f32],
        block: usize,
        scales_out: &mut [f32],
        dst: &mut [u8],
        rng: &mut Pcg64,
    ) {
        debug_assert_eq!(map.kind, self.map);
        debug_assert_eq!(map.bits, self.bits);
        debug_assert!(block > 0);
        debug_assert_eq!(scales_out.len(), vals.len().div_ceil(block));
        debug_assert_eq!(dst.len(), packing::packed_len(vals.len(), self.bits));
        for (bi, chunk) in vals.chunks(block).enumerate() {
            let s = chunk.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
            scales_out[bi] = s;
            let base = bi * block;
            if s <= 0.0 {
                // All-zero block: every code encodes normalized 0, and the
                // RNG is deliberately NOT consumed (matches quantize_with).
                kernels::encode_run_zero(map, self.bits, chunk.len(), base, dst);
                continue;
            }
            // §Perf fused normalize→encode→pack (the kernel layer): whole
            // output bytes per store; odd block sizes enter/leave bytes
            // mid-nibble and compose via boundary RMW. The SR variant
            // draws from `rng` in element order like the unfused loop.
            if self.stochastic {
                kernels::encode_sr_run_scaled(map, self.bits, chunk, s, base, dst, rng);
            } else {
                kernels::encode_run_scaled(map, self.bits, chunk, s, base, dst);
            }
        }
        // A trailing partial byte (odd tensor length) keeps its stale high
        // nibble under read-modify-write `set`; clear it so the stored
        // image matches a fresh `pack` of the same codes.
        if self.bits == 4 && vals.len() % 2 == 1 {
            let last = dst.len() - 1;
            dst[last] &= 0x0F;
        }
    }

    /// Encode the element range starting at `elem_lo` of a tensor with
    /// `shape` under precomputed global `scales` (rank-1 or per-tensor),
    /// writing packed codes into `dst` (packed-byte sub-range of the same
    /// elements; `elem_lo` must be even for 4-bit codes). Block scales
    /// belong to [`Self::encode_block_range`] — they are per-range state,
    /// not global.
    #[allow(clippy::too_many_arguments)]
    pub fn encode_range_with_scales(
        &self,
        map: &QuantMap,
        vals: &[f32],
        elem_lo: usize,
        shape: &[usize],
        scales: &Scales,
        dst: &mut [u8],
        rng: &mut Pcg64,
    ) {
        debug_assert_eq!(map.kind, self.map);
        debug_assert_eq!(map.bits, self.bits);
        debug_assert!(
            !matches!(scales, Scales::Block { .. }),
            "block scales are per-range: use encode_block_range"
        );
        debug_assert_eq!(dst.len(), packing::packed_len(vals.len(), self.bits));
        match scales {
            // Row-segment fast path for rank-1 scales on 2-D tensors:
            // the row statistic is hoisted per segment and the fused
            // kernel packs whole bytes (§Perf, the kernel layer).
            Scales::Rank1 { per_axis } if shape.len() == 2 => {
                let cols = shape[1];
                let r = &per_axis[0];
                let c = &per_axis[1];
                let hi = elem_lo + vals.len();
                let mut i = elem_lo;
                while i < hi {
                    let row = i / cols;
                    let row_start = row * cols;
                    let row_end = (row_start + cols).min(hi);
                    let ri = r[row];
                    if self.stochastic {
                        kernels::encode_sr_rank1_row(
                            map,
                            self.bits,
                            &vals[i - elem_lo..row_end - elem_lo],
                            ri,
                            &c[i - row_start..row_end - row_start],
                            i - elem_lo,
                            dst,
                            rng,
                        );
                    } else {
                        kernels::encode_rank1_row(
                            map,
                            self.bits,
                            &vals[i - elem_lo..row_end - elem_lo],
                            ri,
                            &c[i - row_start..row_end - row_start],
                            i - elem_lo,
                            dst,
                        );
                    }
                    i = row_end;
                }
            }
            // Per-tensor scales: one fused constant-scale run. SR with a
            // zero scale stays on the element-wise arm below — every
            // element still takes its draw on a normalized 0.
            Scales::PerTensor(s) if !self.stochastic || *s > 0.0 => {
                if *s <= 0.0 {
                    kernels::encode_run_zero(map, self.bits, vals.len(), 0, dst);
                } else if self.stochastic {
                    kernels::encode_sr_run_scaled(map, self.bits, vals, *s, 0, dst, rng);
                } else {
                    kernels::encode_run_scaled(map, self.bits, vals, *s, 0, dst);
                }
            }
            _ => {
                for (k, &v) in vals.iter().enumerate() {
                    let s = scales.scale_at(elem_lo + k, shape);
                    let nrm = if s > 0.0 { v / s } else { 0.0 };
                    let code = if self.stochastic {
                        encode_stochastic(map, nrm, rng)
                    } else {
                        map.encode(nrm)
                    };
                    packing::set(dst, k, code, self.bits);
                }
            }
        }
        if self.bits == 4 && vals.len() % 2 == 1 {
            let last = dst.len() - 1;
            dst[last] &= 0x0F;
        }
    }

    /// §Perf fused phase-C path: decode the packed element range in
    /// place with `old_scales`, fold the gradient segment `g` into the
    /// moment EMA (`second` selects the squared form), and re-encode
    /// against `new_scales` — one pass over the packed bytes through the
    /// kernel layer, no f32 staging buffer.
    ///
    /// `dst` holds the packed codes of elements `[elem_lo, elem_lo +
    /// g.len())` of a tensor with `shape` (element `k` of the range at
    /// packed position `k`; `elem_lo` must be even for 4-bit codes).
    /// Returns `false` — before touching `dst` — for layout combinations
    /// without a fused arm (mismatched scale kinds, rank-1 on N-D
    /// tensors, non-positive new per-tensor scales under SR); the caller
    /// falls back to the unfused decode → EMA → encode path, which this
    /// method matches bit for bit (packed bytes *and* RNG draw order)
    /// for every layout it does handle.
    #[allow(clippy::too_many_arguments)]
    pub fn ema_reencode_range(
        &self,
        map: &QuantMap,
        dst: &mut [u8],
        elem_lo: usize,
        shape: &[usize],
        old_scales: &Scales,
        new_scales: &Scales,
        g: &[f32],
        beta: f32,
        second: bool,
        rng: &mut Pcg64,
    ) -> bool {
        debug_assert_eq!(map.kind, self.map);
        debug_assert_eq!(map.bits, self.bits);
        debug_assert_eq!(dst.len(), packing::packed_len(g.len(), self.bits));
        match (old_scales, new_scales) {
            (Scales::PerTensor(os), Scales::PerTensor(ns)) if !self.stochastic || *ns > 0.0 => {
                if *ns <= 0.0 {
                    kernels::encode_run_zero(map, self.bits, g.len(), 0, dst);
                } else {
                    kernels::ema_reencode_run_scaled(
                        map,
                        self.bits,
                        dst,
                        0,
                        *os,
                        *ns,
                        g,
                        beta,
                        second,
                        self.stochastic,
                        rng,
                    );
                }
            }
            (Scales::Rank1 { per_axis: oa }, Scales::Rank1 { per_axis: na })
                if shape.len() == 2 =>
            {
                let cols = shape[1];
                let (or, oc) = (&oa[0], &oa[1]);
                let (nr, nc) = (&na[0], &na[1]);
                let hi = elem_lo + g.len();
                let mut i = elem_lo;
                while i < hi {
                    let row = i / cols;
                    let row_start = row * cols;
                    let row_end = (row_start + cols).min(hi);
                    kernels::ema_reencode_rank1_row(
                        map,
                        self.bits,
                        dst,
                        i - elem_lo,
                        or[row],
                        &oc[i - row_start..row_end - row_start],
                        nr[row],
                        &nc[i - row_start..row_end - row_start],
                        &g[i - elem_lo..row_end - elem_lo],
                        beta,
                        second,
                        self.stochastic,
                        rng,
                    );
                    i = row_end;
                }
            }
            _ => return false,
        }
        // Match a fresh encode of the same range: the high nibble of a
        // trailing half byte is cleared (the in-place walk preserves it).
        if self.bits == 4 && g.len() % 2 == 1 {
            let last = dst.len() - 1;
            dst[last] &= 0x0F;
        }
        true
    }
}

/// A compressed tensor: packed codes + quantization scales. This is the
/// persistent representation of an optimizer state (paper Alg. 1's `s̄`).
#[derive(Clone, Debug)]
pub struct QuantizedTensor {
    pub shape: Vec<usize>,
    pub bits: u8,
    pub packed: Vec<u8>,
    pub scales: Scales,
    pub quantizer: Quantizer,
}

impl QuantizedTensor {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    /// Persistent memory footprint in bytes (codes + scales). This is the
    /// quantity the paper's Tab. 4/5 memory accounting is built on.
    pub fn bytes(&self) -> usize {
        self.packed.len() + self.scales.overhead_bytes()
    }

    /// Bytes actually allocated (code and scale buffer capacities);
    /// always `>= bytes()`, the analytic accounting.
    pub fn allocated_bytes(&self) -> usize {
        self.packed.capacity() + self.scales.allocated_bytes()
    }

    /// Decompress to f32 (`N^{-1} ∘ T`).
    pub fn dequantize(&self) -> Tensor {
        let map = self.quantizer.build_map();
        self.dequantize_with(&map)
    }

    /// Decompress with a prebuilt map (hot path). Every arm runs on the
    /// pair-LUT kernel layer (§Perf, [`super::kernels`]): 4-bit codes
    /// decode two nibbles per byte load with no per-element index
    /// arithmetic, at any block size / row-segment parity.
    pub fn dequantize_with(&self, map: &QuantMap) -> Tensor {
        let n = self.numel();
        let mut out = vec![0.0f32; n];
        match &self.scales {
            Scales::Block { block, scales } => {
                for (bi, chunk) in out.chunks_mut(*block).enumerate() {
                    kernels::decode_run_scaled(
                        map,
                        self.bits,
                        &self.packed,
                        bi * *block,
                        scales[bi],
                        chunk,
                    );
                }
            }
            Scales::Rank1 { per_axis } if self.shape.len() == 2 => {
                let rows = self.shape[0];
                let cols = self.shape[1];
                let r = &per_axis[0];
                let c = &per_axis[1];
                for i in 0..rows {
                    kernels::decode_rank1_row(
                        map,
                        self.bits,
                        &self.packed,
                        i * cols,
                        r[i],
                        c,
                        &mut out[i * cols..(i + 1) * cols],
                    );
                }
            }
            Scales::PerTensor(s) => {
                kernels::decode_run_scaled(map, self.bits, &self.packed, 0, *s, &mut out);
            }
            scales => {
                // Rank-1 on N-D tensors: raw LUT decode (×1.0 is exact),
                // then the per-element coordinate walk of denormalize.
                kernels::decode_run_scaled(map, self.bits, &self.packed, 0, 1.0, &mut out);
                denormalize(&mut out, scales, &self.shape);
            }
        }
        Tensor::from_vec(&self.shape, out)
    }

    /// §Perf engine hot path: decompress the element range `[lo, hi)`
    /// into `out` (`out.len() == hi - lo`), no allocation. Bit-identical
    /// to the corresponding slice of [`Self::dequantize_with`].
    pub fn dequantize_range_into(&self, map: &QuantMap, lo: usize, hi: usize, out: &mut [f32]) {
        debug_assert!(lo <= hi && hi <= self.numel());
        dequantize_packed_range_into(
            map,
            self.bits,
            &self.packed,
            0,
            &self.scales,
            &self.shape,
            lo,
            hi,
            out,
        );
    }
}

/// Decompress the element range `[lo, hi)` of a tensor with `shape` from
/// a caller-provided packed-code slice: `packed` holds the codes of
/// elements starting at flat offset `base` (`base == 0` for a
/// whole-tensor buffer; for 4-bit codes `base` must be even so element
/// `e` sits at nibble `e - base`). This is
/// [`QuantizedTensor::dequantize_range_into`] generalized to *detached*
/// code storage — the offload pipeline decodes staged shard-local copies
/// of host-resident codes through it — and is bit-identical to the
/// corresponding slice of [`QuantizedTensor::dequantize_with`].
#[allow(clippy::too_many_arguments)]
pub fn dequantize_packed_range_into(
    map: &QuantMap,
    bits: u8,
    packed: &[u8],
    base: usize,
    scales: &Scales,
    shape: &[usize],
    lo: usize,
    hi: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(map.bits, bits);
    debug_assert!(base <= lo);
    debug_assert!(bits != 4 || base % 2 == 0, "4-bit base must be byte-aligned");
    debug_assert_eq!(out.len(), hi - lo);
    match scales {
        Scales::Block { block, scales } => {
            // §Perf: segment the range at block boundaries — each
            // segment is one constant-scale fused pair-LUT run, with no
            // per-element `i / block` or packed-index arithmetic.
            let mut i = lo;
            while i < hi {
                let seg_end = ((i / block) + 1) * block;
                let seg_end = seg_end.min(hi);
                kernels::decode_run_scaled(
                    map,
                    bits,
                    packed,
                    i - base,
                    scales[i / block],
                    &mut out[i - lo..seg_end - lo],
                );
                i = seg_end;
            }
        }
        Scales::Rank1 { per_axis } if shape.len() == 2 => {
            let cols = shape[1];
            let r = &per_axis[0];
            let c = &per_axis[1];
            let mut i = lo;
            while i < hi {
                let row = i / cols;
                let row_start = row * cols;
                let row_end = (row_start + cols).min(hi);
                kernels::decode_rank1_row(
                    map,
                    bits,
                    packed,
                    i - base,
                    r[row],
                    &c[i - row_start..row_end - row_start],
                    &mut out[i - lo..row_end - lo],
                );
                i = row_end;
            }
        }
        Scales::PerTensor(s) => {
            kernels::decode_run_scaled(map, bits, packed, lo - base, *s, out);
        }
        scales => {
            for (o, i) in out.iter_mut().zip(lo..hi) {
                let code = packing::get(packed, i - base, bits);
                *o = map.decode(code) * scales.scale_at(i, shape);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck;

    fn roundtrip_err(q: Quantizer, x: &Tensor) -> f64 {
        let mut rng = Pcg64::seeded(0);
        let qt = q.quantize(x, &mut rng);
        let y = qt.dequantize();
        let mut worst = 0.0f64;
        for (a, b) in x.data.iter().zip(y.data.iter()) {
            worst = worst.max((a - b).abs() as f64);
        }
        worst
    }

    #[test]
    fn exact_on_representable_values() {
        // A tensor whose entries are exactly scale * T(i) must survive the
        // round trip bit-for-bit.
        let q = Quantizer::new(NormKind::PerTensor, MapKind::Linear, 4, false);
        let map = q.build_map();
        let vals: Vec<f32> = (0..16).map(|i| 2.0 * map.decode(i)).collect();
        let x = Tensor::from_vec(&[16], vals.clone());
        let mut rng = Pcg64::seeded(0);
        let qt = q.quantize(&x, &mut rng);
        assert_eq!(qt.dequantize().data, vals);
    }

    #[test]
    fn bytes_accounting_4bit() {
        let q = Quantizer::first_moment_4bit();
        let x = Tensor::zeros(&[256]);
        let mut rng = Pcg64::seeded(0);
        let qt = q.quantize(&x, &mut rng);
        // 256 codes -> 128 bytes; 2 blocks of 128 -> 8 scale bytes.
        assert_eq!(qt.bytes(), 128 + 8);
    }

    #[test]
    fn bytes_accounting_rank1() {
        let q = Quantizer::second_moment_4bit();
        let x = Tensor::full(&[64, 32], 0.5);
        let mut rng = Pcg64::seeded(0);
        let qt = q.quantize(&x, &mut rng);
        // 2048 codes -> 1024 bytes; scales: 64 + 32 f32s.
        assert_eq!(qt.bytes(), 1024 + 4 * 96);
    }

    #[test]
    fn error_bounded_by_map_resolution() {
        // For per-tensor linear quantization of non-negative input, the
        // roundtrip error is at most scale * (gap/2 + smallest point).
        propcheck::check("linear-roundtrip-bound", 60, |g| {
            let n = g.len() * 3;
            let x = Tensor::from_vec(&[n], g.vec_f32_nonneg(n));
            let q = Quantizer::new(NormKind::PerTensor, MapKind::Linear, 4, false);
            let mut rng = Pcg64::seeded(1);
            let qt = q.quantize(&x, &mut rng);
            let y = qt.dequantize();
            let s = x.abs_max();
            let bound = s * (1.0 / 16.0) + 1e-6; // first point is 1/16 from 0
            for (a, b) in x.data.iter().zip(y.data.iter()) {
                if (a - b).abs() > bound {
                    return Err(format!("err {} > bound {bound}", (a - b).abs()));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn smaller_blocks_never_much_worse() {
        // B128 should approximate a column-outlier tensor much better than
        // B2048 (the Fig. 1 phenomenon).
        let mut rng = Pcg64::seeded(42);
        let rows = 64;
        let cols = 64;
        let mut x = Tensor::randn(&[rows, cols], 0.001, &mut rng);
        for i in 0..rows {
            // Outlier column 7.
            x.set2(i, 7, 1.0 + rng.next_f32());
        }
        let q_small = Quantizer::new(NormKind::Block(128), MapKind::DynExp, 4, true);
        let q_large = Quantizer::new(NormKind::Block(2048), MapKind::DynExp, 4, true);
        let e_small = roundtrip_err(q_small, &x);
        let e_large = roundtrip_err(q_large, &x);
        assert!(
            e_small < e_large,
            "B128 err {e_small} should beat B2048 err {e_large}"
        );
    }

    #[test]
    fn rank1_beats_per_tensor_on_cross_outliers() {
        // Outliers concentrated in one row AND one column: rank-1 gives
        // per-element scales that bound tightly; per-tensor is poisoned.
        let mut rng = Pcg64::seeded(7);
        let mut x = Tensor::randn(&[32, 32], 0.001, &mut rng);
        for j in 0..32 {
            x.set2(3, j, 2.0);
        }
        let q_r1 = Quantizer::new(NormKind::Rank1, MapKind::Linear, 4, false);
        let q_pt = Quantizer::new(NormKind::PerTensor, MapKind::Linear, 4, false);
        let x_abs = x.map(|v| v.abs());
        let e_r1 = roundtrip_err(q_r1, &x_abs);
        let e_pt = roundtrip_err(q_pt, &x_abs);
        assert!(e_r1 <= e_pt, "rank-1 {e_r1} should be <= per-tensor {e_pt}");
    }

    #[test]
    fn quantize_all_presets_roundtrip_property() {
        propcheck::check("preset-roundtrip-finite", 50, |g| {
            let r = 1 + g.rng.below(8);
            let c = 1 + g.rng.below(40);
            let signedness = g.bool();
            let data = if signedness {
                g.vec_f32(r * c)
            } else {
                g.vec_f32_nonneg(r * c)
            };
            let x = Tensor::from_vec(&[r, c], data);
            let q = if signedness {
                *g.choose(&[
                    Quantizer::first_moment_4bit(),
                    Quantizer::moment_8bit(true),
                    Quantizer::first_moment_4bit().with_stochastic(true),
                ])
            } else {
                *g.choose(&[
                    Quantizer::second_moment_4bit(),
                    Quantizer::moment_8bit(false),
                    Quantizer::new(NormKind::Block(128), MapKind::DynExpNoZero, 4, false),
                ])
            };
            let mut rng = Pcg64::seeded(g.case as u64);
            let qt = q.quantize(&x, &mut rng);
            let y = qt.dequantize_with(&q.build_map());
            if y.any_nonfinite() {
                return Err(format!("non-finite dequant under {}", q.name()));
            }
            // Dequantized magnitude can never exceed the scale bound.
            let bound = x.abs_max() * 1.0001 + 1e-12;
            for &v in &y.data {
                if v.abs() > bound {
                    return Err(format!("|deq| {v} > bound {bound} under {}", q.name()));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn preset_names() {
        assert_eq!(Quantizer::first_moment_4bit().name(), "B128/DE");
        assert_eq!(Quantizer::second_moment_4bit().name(), "Rank-1/Linear");
        assert_eq!(Quantizer::moment_8bit(true).name(), "B2048/DE");
    }

    #[test]
    fn range_apis_match_whole_tensor_paths() {
        // The engine's shard contract: encoding/decoding aligned
        // sub-ranges must reproduce the whole-tensor quantize/dequantize
        // bit-exactly (same packed bytes, same f32 values).
        let mut data_rng = Pcg64::seeded(99);
        let x = Tensor::randn(&[48, 40], 0.5, &mut data_rng); // 1920 elems
        let n = x.numel();
        let cases = vec![
            Quantizer::first_moment_4bit(),
            Quantizer::moment_8bit(true),
            Quantizer::new(NormKind::Block(128), MapKind::Linear, 4, false),
            Quantizer::second_moment_4bit(),
            Quantizer::new(NormKind::PerTensor, MapKind::Linear, 4, false),
        ];
        for q in cases {
            let map = q.build_map();
            let mut r0 = Pcg64::seeded(0);
            let whole = q.quantize_with(&x, &map, &mut r0);

            // Split points must respect the scheme's alignment; B2048 on
            // a 1920-element tensor is a single (partial) block.
            let ranges: Vec<(usize, usize)> = match q.norm {
                NormKind::Block(2048) => vec![(0, n)],
                _ => vec![(0, 640), (640, 1280), (1280, n)],
            };

            let mut packed = vec![0u8; whole.packed.len()];
            for &(lo, hi) in &ranges {
                let mut rr = Pcg64::seeded(1);
                let (b0, b1) = if q.bits == 4 {
                    (lo / 2, hi.div_ceil(2))
                } else {
                    (lo, hi)
                };
                match q.norm {
                    NormKind::Block(b) => {
                        let mut sc = vec![0.0f32; (hi - lo).div_ceil(b)];
                        q.encode_block_range(
                            &map,
                            &x.data[lo..hi],
                            b,
                            &mut sc,
                            &mut packed[b0..b1],
                            &mut rr,
                        );
                        match &whole.scales {
                            Scales::Block { scales, .. } => {
                                assert_eq!(&scales[lo / b..hi.div_ceil(b)], &sc[..]);
                            }
                            _ => unreachable!(),
                        }
                    }
                    _ => q.encode_range_with_scales(
                        &map,
                        &x.data[lo..hi],
                        lo,
                        &x.shape,
                        &whole.scales,
                        &mut packed[b0..b1],
                        &mut rr,
                    ),
                }
            }
            assert_eq!(packed, whole.packed, "{} range codes differ", q.name());

            let full = whole.dequantize_with(&map);
            let mut out = vec![0.0f32; n];
            for &(lo, hi) in &ranges {
                whole.dequantize_range_into(&map, lo, hi, &mut out[lo..hi]);
            }
            assert_eq!(out, full.data, "{} range dequant differs", q.name());
        }
    }

    #[test]
    fn detached_range_dequant_matches_method() {
        // The offload pipeline decodes staged shard-local byte slices;
        // the detached path must be bit-identical to the in-place one.
        let mut data_rng = Pcg64::seeded(3);
        let x = Tensor::randn(&[32, 40], 0.5, &mut data_rng);
        for q in [
            Quantizer::second_moment_4bit(),
            Quantizer::new(NormKind::PerTensor, MapKind::Linear, 4, false),
            Quantizer::moment_8bit(true),
        ] {
            let map = q.build_map();
            let mut r = Pcg64::seeded(0);
            let qt = q.quantize_with(&x, &map, &mut r);
            let (lo, hi) = (240usize, 720usize);
            let mut a = vec![0.0f32; hi - lo];
            qt.dequantize_range_into(&map, lo, hi, &mut a);
            let (b0, b1) = if q.bits == 4 { (lo / 2, hi.div_ceil(2)) } else { (lo, hi) };
            let mut b = vec![0.0f32; hi - lo];
            dequantize_packed_range_into(
                &map,
                q.bits,
                &qt.packed[b0..b1],
                lo,
                &qt.scales,
                &qt.shape,
                lo,
                hi,
                &mut b,
            );
            assert_eq!(a, b, "{} detached range dequant differs", q.name());
        }
    }

    #[test]
    fn fused_paths_match_scalar_reference_property() {
        // The kernel-layer arms of quantize_with / dequantize_with vs a
        // scalar reimplementation (scale_at + QuantMap::encode/decode +
        // packing::set/get), across odd/even block sizes, odd column
        // counts (row segments entering bytes mid-nibble), odd lengths,
        // zero blocks and 4/8-bit codes.
        propcheck::check("fused-matches-scalar", 80, |g| {
            let rows = 1 + g.rng.below(9);
            let cols = 1 + g.rng.below(21);
            let mut data = g.vec_f32(rows * cols);
            if g.bool() {
                // Force some all-zero blocks.
                for v in data.iter_mut().take(cols) {
                    *v = 0.0;
                }
            }
            let x = Tensor::from_vec(&[rows, cols], data);
            let q = *g.choose(&[
                Quantizer::new(NormKind::Block(3), MapKind::DynExp, 4, true),
                Quantizer::new(NormKind::Block(4), MapKind::Linear, 4, false),
                Quantizer::new(NormKind::Block(128), MapKind::DynExpNoZero, 4, false),
                Quantizer::new(NormKind::Rank1, MapKind::Linear, 4, false),
                Quantizer::new(NormKind::Rank1, MapKind::DynExp, 4, true),
                Quantizer::new(NormKind::PerTensor, MapKind::Linear, 4, false),
                Quantizer::new(NormKind::Block(5), MapKind::DynExp, 8, true),
                Quantizer::new(NormKind::Rank1, MapKind::DynExp, 8, false),
            ]);
            let map = q.build_map();
            let mut rng = Pcg64::seeded(g.case as u64);
            let qt = q.quantize_with(&x, &map, &mut rng);

            // Scalar encode reference.
            let scales = compute_scales(&x, q.norm);
            let mut ref_packed = vec![0u8; packing::packed_len(x.numel(), q.bits)];
            for (i, &v) in x.data.iter().enumerate() {
                let s = scales.scale_at(i, &x.shape);
                let nrm = if s > 0.0 { v / s } else { 0.0 };
                packing::set(&mut ref_packed, i, map.encode(nrm), q.bits);
            }
            if qt.packed != ref_packed {
                return Err(format!("{}: fused encode differs from scalar", q.name()));
            }

            // Scalar decode reference.
            let y = qt.dequantize_with(&map);
            for (i, &o) in y.data.iter().enumerate() {
                let code = packing::get(&qt.packed, i, q.bits);
                let exp = map.decode(code) * qt.scales.scale_at(i, &x.shape);
                if o.to_bits() != exp.to_bits() {
                    return Err(format!(
                        "{}: fused decode differs from scalar at {i}: {o} vs {exp}",
                        q.name()
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn range_decode_handles_odd_row_segments() {
        // Odd column count => row segments inside a range start and end
        // mid-byte; the fused rank-1 kernels must still match the
        // whole-tensor decode bit-for-bit on every even-aligned range.
        let mut data_rng = Pcg64::seeded(21);
        let x = Tensor::randn(&[9, 7], 0.5, &mut data_rng).map(|v| v.abs());
        let q = Quantizer::second_moment_4bit();
        let map = q.build_map();
        let mut r = Pcg64::seeded(0);
        let qt = q.quantize_with(&x, &map, &mut r);
        let full = qt.dequantize_with(&map);
        let n = x.numel();
        for lo in (0..n).step_by(2) {
            for hi in [lo + 1, lo + 2, (lo + 9).min(n), n] {
                if hi > n {
                    continue;
                }
                let mut out = vec![0.0f32; hi - lo];
                qt.dequantize_range_into(&map, lo, hi, &mut out);
                assert_eq!(out, full.data[lo..hi], "range [{lo},{hi})");
            }
        }
    }

    #[test]
    fn encode_block_range_handles_odd_tail_and_zero_blocks() {
        let q = Quantizer::new(NormKind::Block(4), MapKind::Linear, 4, false);
        let map = q.build_map();
        // 7 elements: one zero block, then a partial block with content.
        let x = Tensor::from_vec(&[7], vec![0.0, 0.0, 0.0, 0.0, 0.5, 1.0, 0.25]);
        let mut rng = Pcg64::seeded(0);
        let whole = q.quantize_with(&x, &map, &mut rng);
        let mut packed = vec![0xFFu8; whole.packed.len()]; // poisoned
        let mut sc = vec![0.0f32; 2];
        let mut rng2 = Pcg64::seeded(0);
        q.encode_block_range(&map, &x.data, 4, &mut sc, &mut packed, &mut rng2);
        assert_eq!(packed, whole.packed, "stale high nibble must be cleared");
        match &whole.scales {
            Scales::Block { scales, .. } => assert_eq!(&sc, scales),
            _ => unreachable!(),
        }
    }

    #[test]
    fn ema_reencode_range_matches_unfused() {
        // The fused in-place decode→EMA→encode path must reproduce the
        // unfused reference (range decode, scalar EMA, range encode)
        // bit-for-bit — packed bytes AND the RNG draw stream — for both
        // moment forms, per-tensor and rank-1 scales, SR on and off,
        // odd column counts and an odd trailing range.
        let mut data_rng = Pcg64::seeded(17);
        let x = Tensor::randn(&[9, 13], 0.5, &mut data_rng).map(|v| v.abs());
        let gt = Tensor::randn(&[9, 13], 0.3, &mut data_rng);
        let n = x.numel();
        let ranges = [(0usize, 60usize), (60, n)]; // second range has odd length
        let cases = [
            Quantizer::new(NormKind::PerTensor, MapKind::Linear, 4, false),
            Quantizer::new(NormKind::PerTensor, MapKind::DynExp, 4, true).with_stochastic(true),
            Quantizer::second_moment_4bit(),
            Quantizer::new(NormKind::Rank1, MapKind::DynExp, 4, true).with_stochastic(true),
            Quantizer::new(NormKind::Rank1, MapKind::DynExp, 8, true).with_stochastic(true),
        ];
        for q in cases {
            for second in [false, true] {
                let beta = if second { 0.99 } else { 0.9 };
                let map = q.build_map();
                let mut r0 = Pcg64::seeded(0);
                let qt = q.quantize_with(&x, &map, &mut r0);

                // New scales, the way the engine's phase B derives them:
                // reduced from the EMA-updated decoded values.
                let old_full = qt.dequantize_with(&map);
                let ema_vals: Vec<f32> = old_full
                    .data
                    .iter()
                    .zip(gt.data.iter())
                    .map(|(&xv, &gv)| {
                        if second {
                            beta * xv + (1.0 - beta) * gv * gv
                        } else {
                            beta * xv + (1.0 - beta) * gv
                        }
                    })
                    .collect();
                let new_scales =
                    compute_scales(&Tensor::from_vec(&[9, 13], ema_vals.clone()), q.norm);

                // Unfused reference: range decode → scalar EMA → range
                // encode into a copy of the old packed image.
                let mut ref_packed = qt.packed.clone();
                let mut rng_a = Pcg64::seeded(5);
                for &(lo, hi) in &ranges {
                    let (b0, b1) = if q.bits == 4 {
                        (lo / 2, hi.div_ceil(2))
                    } else {
                        (lo, hi)
                    };
                    let mut buf = vec![0.0f32; hi - lo];
                    qt.dequantize_range_into(&map, lo, hi, &mut buf);
                    for (k, v) in buf.iter_mut().enumerate() {
                        let gv = gt.data[lo + k];
                        *v = if second {
                            beta * *v + (1.0 - beta) * gv * gv
                        } else {
                            beta * *v + (1.0 - beta) * gv
                        };
                    }
                    q.encode_range_with_scales(
                        &map,
                        &buf,
                        lo,
                        &x.shape,
                        &new_scales,
                        &mut ref_packed[b0..b1],
                        &mut rng_a,
                    );
                }

                // Fused path over the same ranges.
                let mut fused = qt.packed.clone();
                let mut rng_b = Pcg64::seeded(5);
                for &(lo, hi) in &ranges {
                    let (b0, b1) = if q.bits == 4 {
                        (lo / 2, hi.div_ceil(2))
                    } else {
                        (lo, hi)
                    };
                    let ok = q.ema_reencode_range(
                        &map,
                        &mut fused[b0..b1],
                        lo,
                        &x.shape,
                        &qt.scales,
                        &new_scales,
                        &gt.data[lo..hi],
                        beta,
                        second,
                        &mut rng_b,
                    );
                    assert!(ok, "{} should take the fused arm", q.name());
                }
                assert_eq!(fused, ref_packed, "{} second={second}", q.name());
                assert_eq!(
                    rng_a.next_f32().to_bits(),
                    rng_b.next_f32().to_bits(),
                    "{} second={second}: RNG streams diverged",
                    q.name()
                );
            }
        }
    }

    #[test]
    fn ema_reencode_range_rejects_unhandled_layouts_untouched() {
        // Block scales have no fused EMA arm: the method must return
        // false before mutating the buffer or consuming the RNG.
        let mut data_rng = Pcg64::seeded(23);
        let x = Tensor::randn(&[7, 11], 0.5, &mut data_rng);
        let g = Tensor::randn(&[7, 11], 0.3, &mut data_rng);
        let q = Quantizer::first_moment_4bit();
        let map = q.build_map();
        let mut r0 = Pcg64::seeded(0);
        let qt = q.quantize_with(&x, &map, &mut r0);
        let mut dst = qt.packed.clone();
        let before = dst.clone();
        let mut rng = Pcg64::seeded(9);
        let ok = q.ema_reencode_range(
            &map,
            &mut dst,
            0,
            &x.shape,
            &qt.scales,
            &qt.scales,
            &g.data,
            0.9,
            false,
            &mut rng,
        );
        assert!(!ok);
        assert_eq!(dst, before, "rejected call must leave bytes untouched");
        let mut fresh = Pcg64::seeded(9);
        assert_eq!(rng.next_f32().to_bits(), fresh.next_f32().to_bits());
    }
}
