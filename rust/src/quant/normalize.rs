#![forbid(unsafe_code)]
//! Normalization operators **N** (paper §2.2, §4.2, App. G).
//!
//! A normalization assigns every tensor element a positive *quantization
//! scale*; the normalized value `n_j = x_j / scale_j` lands in the unit
//! interval. Scales are what gets stored alongside the packed codes, so
//! each variant also knows its memory overhead:
//!
//! * **per-tensor** — one scale (`max |x|`);
//! * **block-wise(B)** — the flattened tensor is cut into blocks of `B`
//!   elements with one scale each (Dettmers'22 uses B=2048; the paper's
//!   first-moment fix is B=128);
//! * **rank-1** — per-axis max-magnitude statistics; the scale of element
//!   `(i, j, ...)` is the **min** over axes of the statistic (paper
//!   Alg. 4). Falls back to per-tensor for 1-D tensors.

use crate::tensor::Tensor;

/// Which normalization to apply.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum NormKind {
    PerTensor,
    Block(usize),
    Rank1,
}

impl NormKind {
    pub fn name(self) -> String {
        match self {
            NormKind::PerTensor => "per-tensor".to_string(),
            NormKind::Block(b) => format!("B{b}"),
            NormKind::Rank1 => "Rank-1".to_string(),
        }
    }

    pub fn parse(s: &str) -> Option<NormKind> {
        let l = s.to_ascii_lowercase();
        match l.as_str() {
            "per-tensor" | "tensor" => Some(NormKind::PerTensor),
            "rank-1" | "rank1" => Some(NormKind::Rank1),
            _ => l
                .strip_prefix('b')
                .and_then(|n| n.parse::<usize>().ok())
                .filter(|&n| n > 0)
                .map(NormKind::Block),
        }
    }
}

/// Computed scales for one tensor, in the exact layout that would be
/// persisted next to the packed codes.
#[derive(Clone, Debug, PartialEq)]
pub enum Scales {
    PerTensor(f32),
    /// One scale per block of `block` flattened elements.
    Block { block: usize, scales: Vec<f32> },
    /// One max-magnitude statistic vector per axis (paper Alg. 4).
    Rank1 { per_axis: Vec<Vec<f32>> },
}

impl Scales {
    /// Bytes consumed by the persisted scales (f32 each).
    pub fn overhead_bytes(&self) -> usize {
        match self {
            Scales::PerTensor(_) => 4,
            Scales::Block { scales, .. } => 4 * scales.len(),
            Scales::Rank1 { per_axis } => 4 * per_axis.iter().map(|a| a.len()).sum::<usize>(),
        }
    }

    /// Bytes actually allocated for the scales — buffer *capacities*,
    /// so growth slack counts, unlike the analytic
    /// [`Self::overhead_bytes`].
    pub fn allocated_bytes(&self) -> usize {
        match self {
            Scales::PerTensor(_) => 4,
            Scales::Block { scales, .. } => 4 * scales.capacity(),
            Scales::Rank1 { per_axis } => {
                4 * per_axis.iter().map(|a| a.capacity()).sum::<usize>()
            }
        }
    }

    /// The scale of flattened element `idx` of a tensor with `shape`.
    #[inline]
    pub fn scale_at(&self, idx: usize, shape: &[usize]) -> f32 {
        match self {
            Scales::PerTensor(s) => *s,
            Scales::Block { block, scales } => scales[idx / block],
            Scales::Rank1 { per_axis } => {
                // Decompose idx into per-axis coordinates (row-major) and
                // take the min statistic (Alg. 4 line 7).
                let mut rem = idx;
                let mut m = f32::INFINITY;
                for (axis, &dim) in shape.iter().enumerate().rev() {
                    let coord = rem % dim;
                    rem /= dim;
                    let s = per_axis[axis][coord];
                    if s < m {
                        m = s;
                    }
                }
                m
            }
        }
    }
}

/// Compute scales for `x` under `kind`. All statistics are max-magnitude,
/// so they work for both signed (first moment) and non-negative (second
/// moment) tensors.
pub fn compute_scales(x: &Tensor, kind: NormKind) -> Scales {
    match kind {
        NormKind::PerTensor => Scales::PerTensor(x.abs_max()),
        NormKind::Block(block) => {
            assert!(block > 0);
            let scales = x
                .data
                .chunks(block)
                .map(|c| c.iter().fold(0.0f32, |m, &v| m.max(v.abs())))
                .collect();
            Scales::Block { block, scales }
        }
        NormKind::Rank1 => {
            if x.ndim() <= 1 {
                // Paper §4.2: rank-1 falls back to per-tensor for 1-D.
                return Scales::PerTensor(x.abs_max());
            }
            let shape = &x.shape;
            let mut per_axis: Vec<Vec<f32>> =
                shape.iter().map(|&d| vec![0.0f32; d]).collect();
            // Single pass: update every axis statistic per element.
            let mut coords = vec![0usize; shape.len()];
            for &v in &x.data {
                let a = v.abs();
                for (axis, &c) in coords.iter().enumerate() {
                    if a > per_axis[axis][c] {
                        per_axis[axis][c] = a;
                    }
                }
                // Increment row-major coordinates.
                for axis in (0..shape.len()).rev() {
                    coords[axis] += 1;
                    if coords[axis] < shape[axis] {
                        break;
                    }
                    coords[axis] = 0;
                }
            }
            Scales::Rank1 { per_axis }
        }
    }
}

/// Normalize: `n_j = x_j / scale_j`, with zero scales mapping to 0 (an
/// all-zero block has nothing to encode; 0/0 would poison the codes).
pub fn normalize(x: &Tensor, scales: &Scales) -> Vec<f32> {
    let shape = &x.shape;
    x.data
        .iter()
        .enumerate()
        .map(|(i, &v)| {
            let s = scales.scale_at(i, shape);
            if s > 0.0 {
                v / s
            } else {
                0.0
            }
        })
        .collect()
}

/// Denormalize in place: `x_j = n_j * scale_j`.
pub fn denormalize(n: &mut [f32], scales: &Scales, shape: &[usize]) {
    for (i, v) in n.iter_mut().enumerate() {
        *v *= scales.scale_at(i, shape);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck;
    use crate::util::rng::Pcg64;

    #[test]
    fn per_tensor_scale() {
        let x = Tensor::from_vec(&[4], vec![1.0, -3.0, 0.5, 2.0]);
        let s = compute_scales(&x, NormKind::PerTensor);
        assert_eq!(s, Scales::PerTensor(3.0));
        let n = normalize(&x, &s);
        assert!(n.iter().all(|&v| v.abs() <= 1.0));
        assert_eq!(s.overhead_bytes(), 4);
    }

    #[test]
    fn blockwise_partial_last_block() {
        let x = Tensor::from_vec(&[5], vec![1.0, 2.0, -4.0, 0.0, 8.0]);
        let s = compute_scales(&x, NormKind::Block(2));
        match &s {
            Scales::Block { scales, .. } => assert_eq!(scales, &vec![2.0, 4.0, 8.0]),
            _ => panic!(),
        }
        assert_eq!(s.scale_at(4, &[5]), 8.0);
    }

    #[test]
    fn blockwise_zero_block_is_safe() {
        let x = Tensor::from_vec(&[4], vec![0.0, 0.0, 1.0, -1.0]);
        let s = compute_scales(&x, NormKind::Block(2));
        let n = normalize(&x, &s);
        assert!(n.iter().all(|v| v.is_finite()));
        assert_eq!(&n[..2], &[0.0, 0.0]);
    }

    #[test]
    fn rank1_matches_paper_definition_2d() {
        // x = [[1, 8], [4, 2]]; r = [8, 4], c = [4, 8];
        // scale(0,0)=min(8,4)=4, (0,1)=min(8,8)=8, (1,0)=min(4,4)=4, (1,1)=min(4,8)=4
        let x = Tensor::from_vec(&[2, 2], vec![1.0, 8.0, 4.0, 2.0]);
        let s = compute_scales(&x, NormKind::Rank1);
        assert_eq!(s.scale_at(0, &x.shape), 4.0);
        assert_eq!(s.scale_at(1, &x.shape), 8.0);
        assert_eq!(s.scale_at(2, &x.shape), 4.0);
        assert_eq!(s.scale_at(3, &x.shape), 4.0);
        assert_eq!(s.overhead_bytes(), 16); // 2 + 2 stats
    }

    #[test]
    fn rank1_on_1d_falls_back_to_per_tensor() {
        let x = Tensor::from_vec(&[3], vec![1.0, -5.0, 2.0]);
        let s = compute_scales(&x, NormKind::Rank1);
        assert_eq!(s, Scales::PerTensor(5.0));
    }

    #[test]
    fn rank1_3d_consistency() {
        let mut rng = Pcg64::seeded(4);
        let x = Tensor::randn(&[3, 4, 5], 1.0, &mut rng);
        let s = compute_scales(&x, NormKind::Rank1);
        // Every element's scale must be >= |x| (it's a max over a slab
        // containing the element) and equal to the min over its 3 slabs.
        for (i, &v) in x.data.iter().enumerate() {
            let sc = s.scale_at(i, &x.shape);
            assert!(sc >= v.abs() - 1e-6, "scale must bound the element");
        }
    }

    #[test]
    fn normalize_denormalize_is_identity_where_scale_positive() {
        propcheck::check("norm-denorm-roundtrip", 60, |g| {
            let n = g.len() * 4;
            let rows = 1 + g.rng.below(4);
            let cols = (n / rows).max(1);
            let x = Tensor::from_vec(&[rows, cols], g.vec_f32(rows * cols));
            let kind = *g.choose(&[
                NormKind::PerTensor,
                NormKind::Block(3),
                NormKind::Block(128),
                NormKind::Rank1,
            ]);
            let s = compute_scales(&x, kind);
            let mut norm = normalize(&x, &s);
            // All normalized magnitudes must be <= 1.
            for (i, &v) in norm.iter().enumerate() {
                if v.abs() > 1.0 + 1e-6 {
                    return Err(format!("|n[{i}]| = {v} > 1 under {kind:?}"));
                }
            }
            denormalize(&mut norm, &s, &x.shape);
            for (i, (&a, &b)) in x.data.iter().zip(norm.iter()).enumerate() {
                let tol = 1e-5 * a.abs().max(1.0);
                if (a - b).abs() > tol {
                    return Err(format!("roundtrip[{i}]: {a} vs {b} under {kind:?}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn rank1_tighter_than_per_tensor() {
        // Rank-1 scales are elementwise <= the per-tensor scale.
        propcheck::check("rank1-le-pertensor", 40, |g| {
            let r = 2 + g.rng.below(6);
            let c = 2 + g.rng.below(6);
            let x = Tensor::from_vec(&[r, c], g.vec_f32(r * c));
            let s1 = compute_scales(&x, NormKind::Rank1);
            let st = x.abs_max();
            for i in 0..x.numel() {
                if s1.scale_at(i, &x.shape) > st + 1e-6 {
                    return Err("rank-1 scale exceeded per-tensor scale".into());
                }
            }
            Ok(())
        });
    }
}
