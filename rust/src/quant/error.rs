#![forbid(unsafe_code)]
//! Quantization-error metrics, including the paper's zero-point
//! diagnostic: the deviation of the *inverse square root* of the second
//! moment (Fig. 3), which is the quantity the Adam update actually
//! consumes.

use crate::tensor::Tensor;

/// Plain elementwise error statistics between a tensor and its
/// reconstruction.
#[derive(Clone, Copy, Debug, Default)]
pub struct QuantError {
    pub mse: f64,
    pub mean_abs: f64,
    pub max_abs: f64,
    /// Relative error of the mean magnitude (scale preservation).
    pub rel_mean_mag: f64,
}

pub fn reconstruction_error(x: &Tensor, y: &Tensor) -> QuantError {
    assert_eq!(x.shape, y.shape);
    let n = x.numel().max(1) as f64;
    let mut se = 0.0f64;
    let mut ae = 0.0f64;
    let mut mx = 0.0f64;
    let mut mag_x = 0.0f64;
    let mut mag_y = 0.0f64;
    for (&a, &b) in x.data.iter().zip(y.data.iter()) {
        let d = (a - b) as f64;
        se += d * d;
        ae += d.abs();
        mx = mx.max(d.abs());
        mag_x += (a as f64).abs();
        mag_y += (b as f64).abs();
    }
    QuantError {
        mse: se / n,
        mean_abs: ae / n,
        max_abs: mx,
        rel_mean_mag: if mag_x > 0.0 {
            (mag_y - mag_x).abs() / mag_x
        } else {
            0.0
        },
    }
}

/// The paper's Fig. 3 transform: `h(v) = 1 / (sqrt(v) + eps)`. Quantizing
/// `v` to zero sends `h` to `1/eps` (1e6 for the paper's eps) — the
/// zero-point catastrophe.
pub fn inv_sqrt_transform(v: &Tensor, eps: f32) -> Tensor {
    v.map(|x| 1.0 / (x.max(0.0).sqrt() + eps))
}

/// Mean absolute log10 deviation of the inverse-sqrt transform — the
/// scalar we report for the Fig. 3 reproduction. Large values mean the
/// update direction is destroyed even when plain MSE looks small.
pub fn inv_sqrt_log_deviation(v: &Tensor, v_hat: &Tensor, eps: f32) -> f64 {
    assert_eq!(v.shape, v_hat.shape);
    let h = inv_sqrt_transform(v, eps);
    let h_hat = inv_sqrt_transform(v_hat, eps);
    let n = v.numel().max(1) as f64;
    h.data
        .iter()
        .zip(h_hat.data.iter())
        .map(|(&a, &b)| ((b.max(1e-30) as f64).log10() - (a.max(1e-30) as f64).log10()).abs())
        .sum::<f64>()
        / n
}

/// One-sided *overshoot* of the inverse-sqrt transform:
/// `mean log10(max(h(v̂)/h(v), 1))`. Quantizing v below its true value
/// (worst case: to zero) makes the Adam update `m/(sqrt(v)+eps)` explode —
/// this is the direction that destabilizes training. Overestimating v only
/// shrinks the update (conservative), which the paper shows is benign;
/// this metric therefore penalizes only the explosive direction.
pub fn inv_sqrt_overshoot(v: &Tensor, v_hat: &Tensor, eps: f32) -> f64 {
    assert_eq!(v.shape, v_hat.shape);
    let h = inv_sqrt_transform(v, eps);
    let h_hat = inv_sqrt_transform(v_hat, eps);
    let n = v.numel().max(1) as f64;
    h.data
        .iter()
        .zip(h_hat.data.iter())
        .map(|(&a, &b)| {
            let ratio = (b.max(1e-30) / a.max(1e-30)) as f64;
            ratio.max(1.0).log10()
        })
        .sum::<f64>()
        / n
}

/// Fraction of entries quantized to exact zero — the zero-point mass the
/// paper's §4.1 histograms visualize.
pub fn zero_fraction(x: &Tensor) -> f64 {
    if x.numel() == 0 {
        return 0.0;
    }
    x.data.iter().filter(|&&v| v == 0.0).count() as f64 / x.numel() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::mapping::MapKind;
    use crate::quant::normalize::NormKind;
    use crate::quant::quantizer::Quantizer;
    use crate::util::rng::Pcg64;

    #[test]
    fn zero_error_on_identity() {
        let x = Tensor::from_vec(&[3], vec![1.0, 2.0, 3.0]);
        let e = reconstruction_error(&x, &x);
        assert_eq!(e.mse, 0.0);
        assert_eq!(e.max_abs, 0.0);
    }

    #[test]
    fn inv_sqrt_punishes_zero_point() {
        // Second-moment-like values; DE quantization sends the small ones
        // to zero, inflating h(v) to ~1/eps.
        let mut rng = Pcg64::seeded(3);
        let v = Tensor::from_vec(
            &[4096],
            (0..4096)
                .map(|_| {
                    let z: f32 = rng.normal() * 1e-4;
                    z * z + 1e-12
                })
                .collect(),
        )
        // One large outlier so the quantization scale is dominated.
        .map(|x| x)
        ;
        let mut v = v;
        v.data[0] = 1.0;
        let eps = 1e-6;

        let de = Quantizer::new(NormKind::PerTensor, MapKind::DynExp, 4, false);
        let de0 = Quantizer::new(NormKind::PerTensor, MapKind::DynExpNoZero, 4, false);
        let mut r = Pcg64::seeded(0);
        let v_de = de.quantize(&v, &mut r).dequantize();
        let v_de0 = de0.quantize(&v, &mut r).dequantize();

        // DE quantizes the bulk to zero -> h explodes to ~1/eps; DE-0 only
        // *overestimates* v (conservative direction), so its overshoot is
        // near zero while DE's is large.
        let over_de = inv_sqrt_overshoot(&v, &v_de, eps);
        let over_de0 = inv_sqrt_overshoot(&v, &v_de0, eps);
        assert!(
            over_de > over_de0 * 10.0 && over_de > 0.5,
            "DE overshoot {over_de} should dwarf DE-0 overshoot {over_de0}"
        );
        // And DE indeed produces a big zero mass while DE-0 produces none.
        assert!(zero_fraction(&v_de) > 0.5);
        assert_eq!(zero_fraction(&v_de0), 0.0);
    }

    #[test]
    fn inv_sqrt_transform_range() {
        let v = Tensor::from_vec(&[2], vec![0.0, 1.0]);
        let h = inv_sqrt_transform(&v, 1e-6);
        assert!((h.data[0] - 1e6).abs() / 1e6 < 1e-3);
        assert!((h.data[1] - 1.0).abs() < 1e-3);
    }
}
