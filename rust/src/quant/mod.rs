//! The paper's core contribution: 4-bit quantization of optimizer states.
//!
//! * [`mapping`] — quantization mappings **T** (Linear, DE, DE-0);
//! * [`normalize`] — normalization **N** (per-tensor, block-wise, rank-1);
//! * [`packing`] — nibble/byte packing of codes;
//! * [`kernels`] — tiered hot-path kernels (pair-LUT decode,
//!   LUT/closed-form encode, fused normalize→encode→pack, stochastic
//!   rounding and fused EMA re-encode writers), with a runtime-dispatched
//!   scalar/AVX2 implementation tier per kernel;
//! * [`stochastic`] — stochastic rounding;
//! * [`quantizer`] — the composed quantizer `M ∘ N` and
//!   [`quantizer::QuantizedTensor`], the persisted state form;
//! * [`error`] — reconstruction metrics incl. the zero-point diagnostic.
//!
//! # Kernel layer and the bit-exactness contract
//!
//! Every hot arm of [`quantizer`] (whole-tensor and range encode/decode,
//! which the step engine's phases A/C and the offload pipeline's staged
//! kernels ride) is implemented on the [`kernels`] layer: a 256-entry
//! pair LUT decodes both nibbles of a packed 4-bit byte per load, a
//! closed-form (Linear) or bits-keyed-LUT (DE/DE-0) encoder replaces the
//! per-element midpoint compare loop, and fused writers normalize,
//! encode and emit whole packed bytes in one pass — including the
//! stochastic-rounding bracket draw and the engine's phase-C
//! decode→EMA→re-encode loop, which runs in place over the packed state.
//!
//! Each kernel exists as an implementation **tier**: `kernels::scalar`
//! (the portable reference) and `kernels::avx2` (256-bit SIMD), selected
//! once per process by [`kernels::active_tier`] from CPU feature
//! detection, with the `LOWBIT_KERNEL_TIER=scalar|avx2|auto` environment
//! override for forced-tier CI runs. The AVX2 tier vectorizes the 4-bit
//! arms in full and the byte-per-code (8-bit) decode arms via a table
//! gather over the clamp-padded 256-entry direct table; the remaining
//! 8-bit arms delegate to the scalar tier.
//!
//! **Contract:** every tier must match the oracle-pinned scalar paths
//! *bit for bit* — [`mapping::QuantMap::encode`] (the midpoint partition
//! that reproduces the python oracle's `argmin`, ties to the smaller
//! code) and `packing::get`/`set` + [`mapping::QuantMap::decode`] remain
//! the reference semantics; the scalar tier is pinned to them by
//! exhaustive/dense differential tests in `kernels/`, and the SIMD tier
//! is pinned to the scalar tier (adversarial floats — NaN, ±inf,
//! subnormals, `-0.0`, midpoint ties — included) by the same suites plus
//! `rust/tests/quant_tiers.rs`, the golden-parity, engine-parity,
//! offload-pipeline and range-API suites. Stochastic kernels must also
//! consume RNG draws element-for-element like the unfused
//! `stochastic::encode_stochastic` loop, so engine results stay
//! bit-identical across thread counts and tiers. Any new kernel or tier
//! must preserve this equivalence exactly (same f32 operations in the
//! same order per element); perf work that would change results belongs
//! behind a new quantizer scheme, not here.

pub mod error;
pub mod kernels;
pub mod mapping;
pub mod normalize;
pub mod packing;
pub mod quantizer;
pub mod stochastic;

pub use kernels::{active_tier, resolve_tier, KernelTier, QuantKernels};
pub use mapping::{MapKind, QuantMap};
pub use normalize::{NormKind, Scales};
pub use quantizer::{dequantize_packed_range_into, QuantizedTensor, Quantizer};
