//! The paper's core contribution: 4-bit quantization of optimizer states.
//!
//! * [`mapping`] — quantization mappings **T** (Linear, DE, DE-0);
//! * [`normalize`] — normalization **N** (per-tensor, block-wise, rank-1);
//! * [`packing`] — nibble/byte packing of codes;
//! * [`stochastic`] — stochastic rounding;
//! * [`quantizer`] — the composed quantizer `M ∘ N` and
//!   [`quantizer::QuantizedTensor`], the persisted state form;
//! * [`error`] — reconstruction metrics incl. the zero-point diagnostic.

pub mod error;
pub mod mapping;
pub mod normalize;
pub mod packing;
pub mod quantizer;
pub mod stochastic;

pub use mapping::{MapKind, QuantMap};
pub use normalize::{NormKind, Scales};
pub use quantizer::{dequantize_packed_range_into, QuantizedTensor, Quantizer};
