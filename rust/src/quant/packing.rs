//! Bit-packing of quantization codes. 4-bit codes are packed two per byte
//! (low nibble first), 8-bit codes are stored as-is; other bitwidths are
//! stored one code per byte (sub-byte packing beyond 4-bit is not worth
//! the complexity for the bitwidths the paper evaluates).

/// How many bytes `n` codes of `bits` width occupy.
pub fn packed_len(n: usize, bits: u8) -> usize {
    match bits {
        4 => n.div_ceil(2),
        _ => n,
    }
}

/// Pack `codes` (each `< 2^bits`) into bytes.
pub fn pack(codes: &[u8], bits: u8) -> Vec<u8> {
    match bits {
        4 => {
            let mut out = vec![0u8; codes.len().div_ceil(2)];
            for (i, &c) in codes.iter().enumerate() {
                debug_assert!(c < 16, "4-bit code out of range: {c}");
                if i % 2 == 0 {
                    out[i / 2] = c & 0x0F;
                } else {
                    out[i / 2] |= (c & 0x0F) << 4;
                }
            }
            out
        }
        _ => codes.to_vec(),
    }
}

/// Unpack `n` codes of `bits` width from `bytes`.
pub fn unpack(bytes: &[u8], n: usize, bits: u8) -> Vec<u8> {
    match bits {
        4 => {
            let mut out = Vec::with_capacity(n);
            for i in 0..n {
                let b = bytes[i / 2];
                out.push(if i % 2 == 0 { b & 0x0F } else { b >> 4 });
            }
            out
        }
        _ => bytes[..n].to_vec(),
    }
}

/// Read a single code without unpacking the whole buffer.
#[inline]
pub fn get(bytes: &[u8], i: usize, bits: u8) -> u8 {
    match bits {
        4 => {
            let b = bytes[i / 2];
            if i % 2 == 0 {
                b & 0x0F
            } else {
                b >> 4
            }
        }
        _ => bytes[i],
    }
}

/// Write a single code in place.
#[inline]
pub fn set(bytes: &mut [u8], i: usize, code: u8, bits: u8) {
    match bits {
        4 => {
            let slot = &mut bytes[i / 2];
            if i % 2 == 0 {
                *slot = (*slot & 0xF0) | (code & 0x0F);
            } else {
                *slot = (*slot & 0x0F) | ((code & 0x0F) << 4);
            }
        }
        _ => bytes[i] = code,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck;

    #[test]
    fn pack4_roundtrip_odd_len() {
        let codes = vec![1u8, 15, 7, 0, 9];
        let packed = pack(&codes, 4);
        assert_eq!(packed.len(), 3);
        assert_eq!(unpack(&packed, 5, 4), codes);
    }

    #[test]
    fn pack8_is_identity() {
        let codes = vec![0u8, 255, 128];
        assert_eq!(pack(&codes, 8), codes);
        assert_eq!(unpack(&codes, 3, 8), codes);
    }

    #[test]
    fn single_element_access() {
        let codes = vec![3u8, 12, 5, 8];
        let mut packed = pack(&codes, 4);
        for (i, &c) in codes.iter().enumerate() {
            assert_eq!(get(&packed, i, 4), c);
        }
        set(&mut packed, 1, 9, 4);
        assert_eq!(get(&packed, 1, 4), 9);
        assert_eq!(get(&packed, 0, 4), 3); // neighbor untouched
    }

    #[test]
    fn packed_len_matches() {
        assert_eq!(packed_len(0, 4), 0);
        assert_eq!(packed_len(1, 4), 1);
        assert_eq!(packed_len(2, 4), 1);
        assert_eq!(packed_len(3, 4), 2);
        assert_eq!(packed_len(7, 8), 7);
    }

    #[test]
    fn pack_unpack_property() {
        propcheck::check("pack-bijective", 80, |g| {
            let n = g.len0();
            let bits = *g.choose(&[4u8, 8]);
            let mask = if bits == 4 { 0x0F } else { 0xFF };
            let codes: Vec<u8> = (0..n).map(|_| (g.rng.next_u32() as u8) & mask).collect();
            let packed = pack(&codes, bits);
            if packed.len() != packed_len(n, bits) {
                return Err("packed_len mismatch".into());
            }
            if unpack(&packed, n, bits) != codes {
                return Err("unpack(pack(x)) != x".into());
            }
            Ok(())
        });
    }
}
