#![forbid(unsafe_code)]
//! Bit-packing of quantization codes. 4-bit codes are packed two per byte
//! (low nibble first), 8-bit codes are stored as-is; other bitwidths are
//! stored one code per byte (sub-byte packing beyond 4-bit is not worth
//! the complexity for the bitwidths the paper evaluates).

/// How many bytes `n` codes of `bits` width occupy.
pub fn packed_len(n: usize, bits: u8) -> usize {
    match bits {
        4 => n.div_ceil(2),
        _ => n,
    }
}

/// Pack `codes` (each `< 2^bits`) into bytes.
///
/// §Perf: 4-bit codes are consumed a byte-pair at a time — each output
/// byte is built in a register and stored once, with no per-element
/// parity branch or read-modify-write. Semantically pinned to the
/// scalar [`set`] loop by the `bulk-pack-matches-scalar` property below.
pub fn pack(codes: &[u8], bits: u8) -> Vec<u8> {
    match bits {
        4 => {
            let mut out = Vec::with_capacity(codes.len().div_ceil(2));
            let mut pairs = codes.chunks_exact(2);
            for p in &mut pairs {
                debug_assert!(p[0] < 16 && p[1] < 16, "4-bit code out of range");
                out.push((p[0] & 0x0F) | ((p[1] & 0x0F) << 4));
            }
            if let [last] = pairs.remainder() {
                debug_assert!(*last < 16, "4-bit code out of range: {last}");
                out.push(last & 0x0F);
            }
            out
        }
        _ => codes.to_vec(),
    }
}

/// Unpack `n` codes of `bits` width from `bytes`.
///
/// §Perf: the 4-bit arm emits both nibbles per byte load (no per-element
/// `i / 2` or parity branch); pinned to the scalar [`get`] loop by the
/// `bulk-pack-matches-scalar` property below.
pub fn unpack(bytes: &[u8], n: usize, bits: u8) -> Vec<u8> {
    match bits {
        4 => {
            let mut out = Vec::with_capacity(n);
            for &b in &bytes[..n / 2] {
                out.push(b & 0x0F);
                out.push(b >> 4);
            }
            if n % 2 == 1 {
                out.push(bytes[n / 2] & 0x0F);
            }
            out
        }
        _ => bytes[..n].to_vec(),
    }
}

/// Read a single code without unpacking the whole buffer.
#[inline]
pub fn get(bytes: &[u8], i: usize, bits: u8) -> u8 {
    match bits {
        4 => {
            let b = bytes[i / 2];
            if i % 2 == 0 {
                b & 0x0F
            } else {
                b >> 4
            }
        }
        _ => bytes[i],
    }
}

/// Write a single code in place.
#[inline]
pub fn set(bytes: &mut [u8], i: usize, code: u8, bits: u8) {
    match bits {
        4 => {
            let slot = &mut bytes[i / 2];
            if i % 2 == 0 {
                *slot = (*slot & 0xF0) | (code & 0x0F);
            } else {
                *slot = (*slot & 0x0F) | ((code & 0x0F) << 4);
            }
        }
        _ => bytes[i] = code,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck;

    #[test]
    fn pack4_roundtrip_odd_len() {
        let codes = vec![1u8, 15, 7, 0, 9];
        let packed = pack(&codes, 4);
        assert_eq!(packed.len(), 3);
        assert_eq!(unpack(&packed, 5, 4), codes);
    }

    #[test]
    fn pack8_is_identity() {
        let codes = vec![0u8, 255, 128];
        assert_eq!(pack(&codes, 8), codes);
        assert_eq!(unpack(&codes, 3, 8), codes);
    }

    #[test]
    fn single_element_access() {
        let codes = vec![3u8, 12, 5, 8];
        let mut packed = pack(&codes, 4);
        for (i, &c) in codes.iter().enumerate() {
            assert_eq!(get(&packed, i, 4), c);
        }
        set(&mut packed, 1, 9, 4);
        assert_eq!(get(&packed, 1, 4), 9);
        assert_eq!(get(&packed, 0, 4), 3); // neighbor untouched
    }

    #[test]
    fn packed_len_matches() {
        assert_eq!(packed_len(0, 4), 0);
        assert_eq!(packed_len(1, 4), 1);
        assert_eq!(packed_len(2, 4), 1);
        assert_eq!(packed_len(3, 4), 2);
        assert_eq!(packed_len(7, 8), 7);
    }

    #[test]
    fn bulk_pack_matches_scalar_set_get() {
        // The byte-pair bulk rewrites must be semantically identical to
        // the scalar single-code accessors: pack == a `set` loop into a
        // zeroed buffer, unpack == a `get` loop over every element.
        propcheck::check("bulk-pack-matches-scalar", 120, |g| {
            let n = g.len0();
            let bits = *g.choose(&[4u8, 8]);
            let mask = if bits == 4 { 0x0F } else { 0xFF };
            let codes: Vec<u8> = (0..n).map(|_| (g.rng.next_u32() as u8) & mask).collect();
            let packed = pack(&codes, bits);
            let mut scalar = vec![0u8; packed_len(n, bits)];
            for (i, &c) in codes.iter().enumerate() {
                set(&mut scalar, i, c, bits);
            }
            if packed != scalar {
                return Err(format!("pack != scalar set loop (n={n}, bits={bits})"));
            }
            let via_get: Vec<u8> = (0..n).map(|i| get(&packed, i, bits)).collect();
            if unpack(&packed, n, bits) != via_get {
                return Err(format!("unpack != scalar get loop (n={n}, bits={bits})"));
            }
            Ok(())
        });
    }

    #[test]
    fn pack_unpack_property() {
        propcheck::check("pack-bijective", 80, |g| {
            let n = g.len0();
            let bits = *g.choose(&[4u8, 8]);
            let mask = if bits == 4 { 0x0F } else { 0xFF };
            let codes: Vec<u8> = (0..n).map(|_| (g.rng.next_u32() as u8) & mask).collect();
            let packed = pack(&codes, bits);
            if packed.len() != packed_len(n, bits) {
                return Err("packed_len mismatch".into());
            }
            if unpack(&packed, n, bits) != codes {
                return Err("unpack(pack(x)) != x".into());
            }
            Ok(())
        });
    }
}
