#![forbid(unsafe_code)]
//! Deterministic fault injection and integrity primitives.
//!
//! A [`FaultPlan`] is a seeded, schedule-independent description of which
//! transfers fail, which payloads arrive corrupted, and which workers
//! panic. Decisions are pure functions of logical coordinates — (step,
//! phase, task, direction, attempt) — drawn from the plan's own `Pcg64`
//! stream family, so the same plan produces the same faults at any
//! thread count or prefetch depth, and a *retried* transfer re-rolls on
//! its own attempt index rather than replaying the failure forever.
//!
//! Arming:
//! * builder API — `FaultPlan::new(seed).with_rate(r).with_kind(k)`
//!   plus scheduled worker panics via [`FaultPlan::panic_at`]; or
//! * environment — `LOWBIT_FAULTS=seed:rate[:kind]` with
//!   `kind ∈ fail|corrupt|mixed` (default `mixed`), parsed once per
//!   process by [`active`] exactly like the `LOWBIT_ENGINE_SCHED` /
//!   `LOWBIT_KERNEL_TIER` gates (unknown values are a hard error).
//!   Env plans carry no panic schedule: scheduled panics only make
//!   sense under a driver that retries via `Optimizer::try_step`.
//!
//! Unarmed, the whole layer is zero-cost: the offload pipeline checks
//! one `Option` per step and takes the exact pre-fault code path.
//!
//! The module also hosts the integrity primitives the rest of the stack
//! detects corruption with: a table-driven IEEE CRC-32 ([`crc32`], plus
//! the incremental [`Crc32`]) used for per-transfer checksums over
//! staged bytes and per-section checksums in checkpoint manifests.

use crate::util::rng::Pcg64;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::OnceLock;

// ---------------------------------------------------------------------
// CRC-32 (IEEE 802.3, reflected, poly 0xEDB88320)
// ---------------------------------------------------------------------

const CRC_TABLE: [u32; 256] = {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
};

/// Incremental IEEE CRC-32. `update` as bytes stream in, `finish` for
/// the digest; [`crc32`] is the one-shot convenience.
#[derive(Clone, Debug)]
pub struct Crc32 {
    state: u32,
}

impl Crc32 {
    pub fn new() -> Self {
        Crc32 { state: 0xFFFF_FFFF }
    }

    pub fn update(&mut self, bytes: &[u8]) {
        let mut s = self.state;
        for &b in bytes {
            s = CRC_TABLE[((s ^ b as u32) & 0xFF) as usize] ^ (s >> 8);
        }
        self.state = s;
    }

    /// Fold a `f32` slice through the digest by its little-endian bit
    /// pattern (no unsafe byte casts; NaN payloads digest faithfully).
    pub fn update_f32s(&mut self, vals: &[f32]) {
        for v in vals {
            self.update(&v.to_bits().to_le_bytes());
        }
    }

    pub fn finish(&self) -> u32 {
        self.state ^ 0xFFFF_FFFF
    }
}

impl Default for Crc32 {
    fn default() -> Self {
        Self::new()
    }
}

/// One-shot IEEE CRC-32 of a byte slice.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = Crc32::new();
    c.update(bytes);
    c.finish()
}

// ---------------------------------------------------------------------
// Fault plan
// ---------------------------------------------------------------------

/// Which fault family a rate-armed plan injects on the link.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// Transient transfer failures only (payload never arrives).
    Fail,
    /// Payload corruption only (arrives, fails its checksum).
    Corrupt,
    /// A deterministic per-site mix of both (the default).
    Mixed,
}

/// The offload-pipeline phase a fault is keyed to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// Phase A: block-normalized state staging + update.
    A,
    /// Phase C: global re-encode against reduced scales.
    C,
}

impl Phase {
    fn id(self) -> u64 {
        match self {
            Phase::A => 0xA,
            Phase::C => 0xC,
        }
    }
}

/// What an injected transfer fault did to one attempt.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TransferFault {
    /// The transfer failed outright; nothing arrived.
    Fail,
    /// The payload arrived corrupted (stage-in only — the checksum
    /// verify catches it before any compute reads the slot).
    Corrupt,
}

struct PanicPoint {
    step: u64,
    phase: Phase,
    task: usize,
    /// One-shot: a rolled-back step retried at the same `t` must not
    /// re-fire the same panic, or recovery could never converge.
    fired: AtomicBool,
}

/// A seeded, deterministic fault schedule. See the module docs.
pub struct FaultPlan {
    seed: u64,
    rate: f64,
    kind: FaultKind,
    panics: Vec<PanicPoint>,
}

/// Domain-separation salt so fault rolls never correlate with the
/// optimizer's own per-task update streams (which key off the step seed).
const FAULT_STREAM_SALT: u64 = 0xFA17_FA17_FA17_FA17;

fn mix64(mut x: u64) -> u64 {
    x ^= x >> 30;
    x = x.wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x ^= x >> 27;
    x = x.wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^= x >> 31;
    x
}

fn site_key(step: u64, phase: Phase, task: u64, up: bool, attempt: u32) -> u64 {
    let mut k = mix64(step ^ 0x9E37_79B9_7F4A_7C15);
    k = mix64(k ^ phase.id());
    k = mix64(k ^ task);
    mix64(k ^ ((up as u64) << 32) ^ attempt as u64)
}

impl FaultPlan {
    /// A plan with the given seed and nothing armed yet.
    pub fn new(seed: u64) -> Self {
        FaultPlan { seed, rate: 0.0, kind: FaultKind::Mixed, panics: Vec::new() }
    }

    /// An inert plan. Installing it on an optimizer *overrides* an
    /// env-armed plan — the explicit way to pin a run fault-free.
    pub fn none() -> Self {
        FaultPlan::new(0)
    }

    /// Per-attempt transfer fault probability in `[0, 1)`.
    pub fn with_rate(mut self, rate: f64) -> Self {
        assert!(
            (0.0..1.0).contains(&rate),
            "fault rate must be in [0, 1): a rate of 1 can never retry to success"
        );
        self.rate = rate;
        self
    }

    pub fn with_kind(mut self, kind: FaultKind) -> Self {
        self.kind = kind;
        self
    }

    /// Schedule a one-shot worker panic at `(step, phase, task)`.
    /// `step` is the optimizer's post-increment `t` of the step to hit.
    pub fn panic_at(mut self, step: u64, phase: Phase, task: usize) -> Self {
        self.panics.push(PanicPoint { step, phase, task, fired: AtomicBool::new(false) });
        self
    }

    /// Whether this plan can inject anything at all.
    pub fn armed(&self) -> bool {
        self.rate > 0.0 || !self.panics.is_empty()
    }

    /// Roll for a fault on one transfer attempt. Pure in its logical
    /// coordinates: schedule order, thread count and prefetch depth
    /// cannot change the outcome. `up` is the writeback direction;
    /// corruption is modeled on stage-in only (an up-direction hit
    /// degrades to [`TransferFault::Fail`], i.e. replay-from-staging).
    pub fn transfer_fault(
        &self,
        step: u64,
        phase: Phase,
        task: usize,
        up: bool,
        attempt: u32,
    ) -> Option<TransferFault> {
        if self.rate <= 0.0 {
            return None;
        }
        let mut r =
            Pcg64::new(self.seed ^ FAULT_STREAM_SALT, site_key(step, phase, task as u64, up, attempt));
        if r.next_f64() >= self.rate {
            return None;
        }
        let kind = match self.kind {
            FaultKind::Fail => TransferFault::Fail,
            FaultKind::Corrupt => TransferFault::Corrupt,
            FaultKind::Mixed => {
                if r.next_u64() & 1 == 0 {
                    TransferFault::Fail
                } else {
                    TransferFault::Corrupt
                }
            }
        };
        Some(if up { TransferFault::Fail } else { kind })
    }

    /// Deterministic byte offset to corrupt within an `len`-byte staged
    /// payload (same stream family as the fault roll that chose it).
    pub fn corrupt_offset(&self, step: u64, phase: Phase, task: usize, attempt: u32, len: usize) -> usize {
        let k = site_key(step, phase, task as u64, false, attempt);
        let mut r = Pcg64::new(self.seed ^ FAULT_STREAM_SALT.rotate_left(17), k);
        (r.next_u64() % len.max(1) as u64) as usize
    }

    /// True exactly once for a scheduled `(step, phase, task)` panic
    /// point; subsequent calls (the rolled-back retry) see `false`.
    pub fn should_panic(&self, step: u64, phase: Phase, task: usize) -> bool {
        self.panics.iter().any(|p| {
            p.step == step
                && p.phase == phase
                && p.task == task
                && p.fired.compare_exchange(false, true, Ordering::AcqRel, Ordering::Acquire).is_ok()
        })
    }
}

/// Parse a `LOWBIT_FAULTS` spec: `seed:rate[:kind]`.
pub fn parse_spec(spec: &str) -> Result<FaultPlan, String> {
    let mut it = spec.split(':');
    let seed = it
        .next()
        .filter(|s| !s.is_empty())
        .ok_or_else(|| "missing seed (want seed:rate[:kind])".to_string())?
        .parse::<u64>()
        .map_err(|e| format!("bad seed: {e}"))?;
    let rate = it
        .next()
        .ok_or_else(|| "missing rate (want seed:rate[:kind])".to_string())?
        .parse::<f64>()
        .map_err(|e| format!("bad rate: {e}"))?;
    if !(0.0..1.0).contains(&rate) {
        return Err(format!("rate {rate} out of range [0, 1)"));
    }
    let kind = match it.next() {
        None | Some("mixed") => FaultKind::Mixed,
        Some("fail") => FaultKind::Fail,
        Some("corrupt") => FaultKind::Corrupt,
        Some(k) => return Err(format!("unknown fault kind '{k}' (use fail|corrupt|mixed)")),
    };
    if it.next().is_some() {
        return Err("trailing fields after seed:rate:kind".to_string());
    }
    Ok(FaultPlan::new(seed).with_rate(rate).with_kind(kind))
}

/// The process-wide env-armed plan (`LOWBIT_FAULTS=seed:rate[:kind]`),
/// parsed once. `None` when the variable is unset or empty; a malformed
/// spec is a hard configuration error, matching the other env gates.
pub fn active() -> Option<&'static FaultPlan> {
    static ACTIVE: OnceLock<Option<FaultPlan>> = OnceLock::new();
    ACTIVE
        .get_or_init(|| match std::env::var("LOWBIT_FAULTS") {
            Ok(s) if !s.is_empty() => {
                Some(parse_spec(&s).unwrap_or_else(|e| panic!("LOWBIT_FAULTS: {e}")))
            }
            _ => None,
        })
        .as_ref()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_matches_known_vectors() {
        // The canonical IEEE check value.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        // Incremental == one-shot, across arbitrary split points.
        let data = b"the quick brown fox jumps over the lazy dog";
        let whole = crc32(data);
        for split in [0, 1, 7, data.len()] {
            let mut c = Crc32::new();
            c.update(&data[..split]);
            c.update(&data[split..]);
            assert_eq!(c.finish(), whole);
        }
    }

    #[test]
    fn crc32_f32_fold_is_bit_pattern_sensitive() {
        let mut a = Crc32::new();
        a.update_f32s(&[0.0, 1.5]);
        let mut b = Crc32::new();
        b.update_f32s(&[-0.0, 1.5]); // same value comparison-wise, different bits
        assert_ne!(a.finish(), b.finish());
        // f32 fold == byte fold of the LE bit patterns.
        let mut c = Crc32::new();
        c.update(&0.0f32.to_bits().to_le_bytes());
        c.update(&1.5f32.to_bits().to_le_bytes());
        assert_eq!(a.finish(), c.finish());
    }

    #[test]
    fn unarmed_plan_rolls_nothing() {
        let p = FaultPlan::none();
        assert!(!p.armed());
        for task in 0..64 {
            assert_eq!(p.transfer_fault(1, Phase::A, task, false, 0), None);
        }
    }

    #[test]
    fn rolls_are_deterministic_and_attempt_keyed() {
        let p = FaultPlan::new(42).with_rate(0.5);
        let q = FaultPlan::new(42).with_rate(0.5);
        let mut hits = 0;
        let mut attempt_differs = false;
        for task in 0..256 {
            let a = p.transfer_fault(3, Phase::A, task, false, 0);
            assert_eq!(a, q.transfer_fault(3, Phase::A, task, false, 0));
            if a.is_some() {
                hits += 1;
                // A retry re-rolls on its own attempt index; over many
                // sites at rate 0.5 some retry must come up clean.
                if p.transfer_fault(3, Phase::A, task, false, 1).is_none() {
                    attempt_differs = true;
                }
            }
        }
        assert!(hits > 64 && hits < 192, "rate 0.5 should hit roughly half: {hits}/256");
        assert!(attempt_differs, "attempt index must reach the roll");
    }

    #[test]
    fn kind_filters_and_up_direction_degrade() {
        let fail_only = FaultPlan::new(7).with_rate(0.9).with_kind(FaultKind::Fail);
        let corrupt_only = FaultPlan::new(7).with_rate(0.9).with_kind(FaultKind::Corrupt);
        let mut saw_corrupt = false;
        for task in 0..64 {
            if let Some(f) = fail_only.transfer_fault(1, Phase::C, task, false, 0) {
                assert_eq!(f, TransferFault::Fail);
            }
            if let Some(f) = corrupt_only.transfer_fault(1, Phase::C, task, false, 0) {
                assert_eq!(f, TransferFault::Corrupt);
                saw_corrupt = true;
            }
            // Writeback direction never corrupts — replay covers it.
            if let Some(f) = corrupt_only.transfer_fault(1, Phase::C, task, true, 0) {
                assert_eq!(f, TransferFault::Fail);
            }
        }
        assert!(saw_corrupt);
    }

    #[test]
    fn scheduled_panics_fire_exactly_once() {
        let p = FaultPlan::new(1).panic_at(4, Phase::A, 2);
        assert!(p.armed());
        assert!(!p.should_panic(4, Phase::A, 1), "wrong task");
        assert!(!p.should_panic(3, Phase::A, 2), "wrong step");
        assert!(!p.should_panic(4, Phase::C, 2), "wrong phase");
        assert!(p.should_panic(4, Phase::A, 2));
        assert!(!p.should_panic(4, Phase::A, 2), "one-shot: the retry must run clean");
    }

    #[test]
    fn spec_parsing_accepts_and_rejects() {
        let p = parse_spec("9:0.25").unwrap();
        assert!(p.armed());
        assert!(parse_spec("9:0.25:fail").is_ok());
        assert!(parse_spec("9:0.25:corrupt").is_ok());
        assert!(parse_spec("9:0.25:mixed").is_ok());
        for bad in ["", "9", "x:0.1", "9:nope", "9:1.0", "9:-0.1", "9:0.1:weird", "9:0.1:fail:x"] {
            assert!(parse_spec(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn corrupt_offset_is_in_bounds_and_deterministic() {
        let p = FaultPlan::new(11).with_rate(0.5);
        for len in [1usize, 2, 17, 4096] {
            let o = p.corrupt_offset(2, Phase::A, 5, 0, len);
            assert!(o < len);
            assert_eq!(o, p.corrupt_offset(2, Phase::A, 5, 0, len));
        }
    }
}
