#![forbid(unsafe_code)]
//! A small dense f32 tensor used throughout the native (rust) compute and
//! quantization paths. It deliberately stays simple: contiguous row-major
//! storage, explicit shapes, and exactly the operations the builtin
//! training engine and the quantizers need.

use crate::util::rng::Pcg64;

/// Dense row-major f32 tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor {
            shape: shape.to_vec(),
            data: vec![0.0; n],
        }
    }

    pub fn full(shape: &[usize], v: f32) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor {
            shape: shape.to_vec(),
            data: vec![v; n],
        }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {:?} does not match data length {}",
            shape,
            data.len()
        );
        Tensor {
            shape: shape.to_vec(),
            data,
        }
    }

    /// N(0, std^2) init.
    pub fn randn(shape: &[usize], std: f32, rng: &mut Pcg64) -> Tensor {
        let mut t = Tensor::zeros(shape);
        rng.fill_normal(&mut t.data, std);
        t
    }

    /// Uniform(lo, hi) init.
    pub fn rand_uniform(shape: &[usize], lo: f32, hi: f32, rng: &mut Pcg64) -> Tensor {
        let mut t = Tensor::zeros(shape);
        for v in t.data.iter_mut() {
            *v = rng.uniform(lo, hi);
        }
        t
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn ndim(&self) -> usize {
        self.shape.len()
    }

    /// Rows/cols of a 2-D tensor.
    pub fn dims2(&self) -> (usize, usize) {
        assert_eq!(self.ndim(), 2, "dims2 on shape {:?}", self.shape);
        (self.shape[0], self.shape[1])
    }

    pub fn at2(&self, i: usize, j: usize) -> f32 {
        let (_, c) = self.dims2();
        self.data[i * c + j]
    }

    pub fn set2(&mut self, i: usize, j: usize, v: f32) {
        let (_, c) = self.dims2();
        self.data[i * c + j] = v;
    }

    pub fn reshape(mut self, shape: &[usize]) -> Tensor {
        assert_eq!(
            shape.iter().product::<usize>(),
            self.data.len(),
            "reshape {:?} -> {:?}",
            self.shape,
            shape
        );
        self.shape = shape.to_vec();
        self
    }

    pub fn map(&self, f: impl Fn(f32) -> f32) -> Tensor {
        Tensor {
            shape: self.shape.clone(),
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    pub fn zip(&self, other: &Tensor, f: impl Fn(f32, f32) -> f32) -> Tensor {
        assert_eq!(self.shape, other.shape, "zip shape mismatch");
        Tensor {
            shape: self.shape.clone(),
            data: self
                .data
                .iter()
                .zip(other.data.iter())
                .map(|(&a, &b)| f(a, b))
                .collect(),
        }
    }

    pub fn add(&self, other: &Tensor) -> Tensor {
        self.zip(other, |a, b| a + b)
    }

    pub fn sub(&self, other: &Tensor) -> Tensor {
        self.zip(other, |a, b| a - b)
    }

    pub fn mul(&self, other: &Tensor) -> Tensor {
        self.zip(other, |a, b| a * b)
    }

    pub fn scale(&self, s: f32) -> Tensor {
        self.map(|x| x * s)
    }

    pub fn abs_max(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
    }

    pub fn sum(&self) -> f64 {
        self.data.iter().map(|&x| x as f64).sum()
    }

    pub fn mean(&self) -> f64 {
        if self.data.is_empty() {
            0.0
        } else {
            self.sum() / self.data.len() as f64
        }
    }

    /// Root-mean-square of entries (Adafactor's RMS(x)).
    pub fn rms(&self) -> f64 {
        if self.data.is_empty() {
            return 0.0;
        }
        (self.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>()
            / self.data.len() as f64)
            .sqrt()
    }

    pub fn sq_l2(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum()
    }

    pub fn any_nonfinite(&self) -> bool {
        self.data.iter().any(|x| !x.is_finite())
    }

    /// C = A @ B for 2-D tensors. The builtin engine's hot loop; written
    /// in ikj order so the inner loop is a contiguous AXPY the compiler
    /// auto-vectorizes.
    pub fn matmul(&self, other: &Tensor) -> Tensor {
        let (n, k) = self.dims2();
        let (k2, m) = other.dims2();
        assert_eq!(k, k2, "matmul inner dims: {:?} x {:?}", self.shape, other.shape);
        let mut out = Tensor::zeros(&[n, m]);
        for i in 0..n {
            let arow = &self.data[i * k..(i + 1) * k];
            let orow = &mut out.data[i * m..(i + 1) * m];
            for (p, &a) in arow.iter().enumerate() {
                if a == 0.0 {
                    continue;
                }
                let brow = &other.data[p * m..(p + 1) * m];
                for (o, &b) in orow.iter_mut().zip(brow.iter()) {
                    *o += a * b;
                }
            }
        }
        out
    }

    /// C = A^T @ B (A: [k, n], B: [k, m] -> [n, m]); used by backprop.
    pub fn matmul_tn(&self, other: &Tensor) -> Tensor {
        let (k, n) = self.dims2();
        let (k2, m) = other.dims2();
        assert_eq!(k, k2);
        let mut out = Tensor::zeros(&[n, m]);
        for p in 0..k {
            let arow = &self.data[p * n..(p + 1) * n];
            let brow = &other.data[p * m..(p + 1) * m];
            for (i, &a) in arow.iter().enumerate() {
                if a == 0.0 {
                    continue;
                }
                let orow = &mut out.data[i * m..(i + 1) * m];
                for (o, &b) in orow.iter_mut().zip(brow.iter()) {
                    *o += a * b;
                }
            }
        }
        out
    }

    /// C = A @ B^T (A: [n, k], B: [m, k] -> [n, m]); used by backprop.
    pub fn matmul_nt(&self, other: &Tensor) -> Tensor {
        let (n, k) = self.dims2();
        let (m, k2) = other.dims2();
        assert_eq!(k, k2);
        let mut out = Tensor::zeros(&[n, m]);
        for i in 0..n {
            let arow = &self.data[i * k..(i + 1) * k];
            for j in 0..m {
                let brow = &other.data[j * k..(j + 1) * k];
                let mut acc = 0.0f32;
                for (a, b) in arow.iter().zip(brow.iter()) {
                    acc += a * b;
                }
                out.data[i * m + j] = acc;
            }
        }
        out
    }

    /// Row-wise softmax in place (2-D).
    pub fn softmax_rows(&mut self) {
        let (n, m) = self.dims2();
        for i in 0..n {
            let row = &mut self.data[i * m..(i + 1) * m];
            let mx = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
            let mut z = 0.0f32;
            for v in row.iter_mut() {
                *v = (*v - mx).exp();
                z += *v;
            }
            let inv = 1.0 / z;
            for v in row.iter_mut() {
                *v *= inv;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construct_and_index() {
        let t = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.at2(1, 2), 6.0);
        assert_eq!(t.numel(), 6);
        assert_eq!(t.dims2(), (2, 3));
    }

    #[test]
    #[should_panic(expected = "shape")]
    fn bad_shape_panics() {
        Tensor::from_vec(&[2, 2], vec![1.0]);
    }

    #[test]
    fn matmul_known() {
        let a = Tensor::from_vec(&[2, 2], vec![1., 2., 3., 4.]);
        let b = Tensor::from_vec(&[2, 2], vec![5., 6., 7., 8.]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![19., 22., 43., 50.]);
    }

    #[test]
    fn matmul_variants_agree() {
        let mut rng = Pcg64::seeded(1);
        let a = Tensor::randn(&[4, 5], 1.0, &mut rng);
        let b = Tensor::randn(&[5, 3], 1.0, &mut rng);
        let c = a.matmul(&b);
        // A @ B == (A^T)^T @ B via matmul_tn with explicitly transposed A.
        let mut at = Tensor::zeros(&[5, 4]);
        for i in 0..4 {
            for j in 0..5 {
                at.set2(j, i, a.at2(i, j));
            }
        }
        let c2 = at.matmul_tn(&b);
        for (x, y) in c.data.iter().zip(c2.data.iter()) {
            assert!((x - y).abs() < 1e-4);
        }
        // A @ B == matmul_nt(A, B^T)
        let mut bt = Tensor::zeros(&[3, 5]);
        for i in 0..5 {
            for j in 0..3 {
                bt.set2(j, i, b.at2(i, j));
            }
        }
        let c3 = a.matmul_nt(&bt);
        for (x, y) in c.data.iter().zip(c3.data.iter()) {
            assert!((x - y).abs() < 1e-4);
        }
    }

    #[test]
    fn softmax_rows_normalizes() {
        let mut t = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 0., 0., 0.]);
        t.softmax_rows();
        for i in 0..2 {
            let s: f32 = (0..3).map(|j| t.at2(i, j)).sum();
            assert!((s - 1.0).abs() < 1e-5);
        }
        assert!((t.at2(1, 0) - 1.0 / 3.0).abs() < 1e-6);
    }

    #[test]
    fn elementwise_and_stats() {
        let a = Tensor::from_vec(&[3], vec![1., -2., 2.]);
        let b = Tensor::from_vec(&[3], vec![1., 1., 1.]);
        assert_eq!(a.add(&b).data, vec![2., -1., 3.]);
        assert_eq!(a.abs_max(), 2.0);
        assert!((a.rms() - (3.0f64).sqrt()).abs() < 1e-9);
        assert!(!a.any_nonfinite());
        assert!(a.map(|x| x / 0.0).any_nonfinite());
    }
}
