#![forbid(unsafe_code)]
//! Unsafe-boundary lint: a self-contained, comment/string-aware token
//! scanner over `rust/src` that mechanically enforces the crate's
//! unsafe policy (see lib.rs, "The unsafe boundary"):
//!
//! * `unsafe` (blocks, fns, impls) is allowed only in the explicit
//!   [`ALLOWLIST`] of modules — the engine executors, the offload
//!   staging layer, checkpoint byte packing and the SIMD quant-kernel
//!   tier;
//! * every `unsafe` token in an allowlisted file must carry an adjacent
//!   `// SAFETY:` comment (or a `# Safety` doc section for `unsafe fn`
//!   declarations) on the same line or the directly preceding
//!   comment/attribute run — a blank line breaks adjacency;
//! * every non-allowlisted module must be stamped
//!   `#![forbid(unsafe_code)]` (except the [`PARENT_EXEMPT`] module
//!   roots, where the stamp would forbid their allowlisted children;
//!   those must simply contain no `unsafe` at all);
//! * `static mut` and `transmute` are forbidden outside the allowlist
//!   even where the compiler would accept them;
//! * lib.rs must carry `#![deny(unsafe_op_in_unsafe_fn)]`.
//!
//! The scanner masks out comments, strings (incl. raw/byte strings) and
//! char literals before tokenizing, so `"unsafe"` in a string or a doc
//! comment never trips it. No dependencies; the same code runs as the
//! `lint` binary (CI) and inside the `unsafe_lint` tier-1 test, which
//! also locks the lint's own behavior against seeded violations.
//!
//! Run manually: `cargo run --release --bin lint` (or pass an explicit
//! source root as the first argument).

use std::env;
use std::fs;
use std::path::{Path, PathBuf};

/// Files (relative to the source root) that may contain `unsafe`.
pub const ALLOWLIST: &[&str] = &[
    "engine/adamw4.rs",
    "engine/ctx.rs",
    "engine/dense.rs",
    "engine/mod.rs",
    "engine/pool.rs",
    "engine/shared.rs",
    "offload/pipeline.rs",
    "offload/tier.rs",
    "quant/kernels/avx2.rs",
    "train/checkpoint.rs",
];

/// Module roots whose children include allowlisted files: the
/// `#![forbid(unsafe_code)]` stamp would propagate down and break the
/// children, so these are exempt from the stamp — but must themselves
/// contain zero `unsafe`.
pub const PARENT_EXEMPT: &[&str] = &[
    "lib.rs",
    "offload/mod.rs",
    "quant/kernels/mod.rs",
    "quant/mod.rs",
    "train/mod.rs",
];

pub const FORBID_STAMP: &str = "#![forbid(unsafe_code)]";
pub const LIB_DENY: &str = "#![deny(unsafe_op_in_unsafe_fn)]";

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kind {
    /// `unsafe` in an allowlisted file without an adjacent SAFETY comment.
    UndocumentedUnsafe,
    /// `unsafe` token in a file outside the allowlist.
    UnsafeOutsideAllowlist,
    /// `static mut` outside the allowlist.
    StaticMut,
    /// `transmute` outside the allowlist.
    Transmute,
    /// Non-allowlisted module without the `#![forbid(unsafe_code)]` stamp.
    MissingForbidStamp,
    /// lib.rs without `#![deny(unsafe_op_in_unsafe_fn)]`.
    MissingLibDeny,
}

#[derive(Debug, Clone)]
pub struct Violation {
    /// Path relative to the source root, `/`-separated.
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    pub kind: Kind,
    pub msg: String,
}

/// One source line after masking: executable code with comment/string
/// interiors blanked, plus the concatenated comment text.
#[derive(Default)]
struct ScannedLine {
    code: String,
    comment: String,
}

impl ScannedLine {
    fn has_code(&self) -> bool {
        !self.code.trim().is_empty()
    }
}

/// Mask comments, strings and char literals. Line comments, nested
/// block comments, plain/raw/byte strings with escapes, and the
/// char-literal-vs-lifetime ambiguity are handled; the masked code
/// stream preserves line structure so token positions stay meaningful.
fn scan(src: &str) -> Vec<ScannedLine> {
    #[derive(Clone, Copy, PartialEq)]
    enum St {
        Code,
        LineComment,
        BlockComment(u32),
        Str,
        RawStr(u32),
        CharLit,
    }
    let chars: Vec<char> = src.chars().collect();
    let n = chars.len();
    let mut lines: Vec<ScannedLine> = vec![ScannedLine::default()];
    let mut st = St::Code;
    let mut i = 0usize;
    while i < n {
        let c = chars[i];
        if c == '\n' {
            if st == St::LineComment {
                st = St::Code;
            }
            lines.push(ScannedLine::default());
            i += 1;
            continue;
        }
        let cur = lines.last_mut().expect("at least one line");
        match st {
            St::Code => {
                let next = chars.get(i + 1).copied();
                if c == '/' && next == Some('/') {
                    st = St::LineComment;
                    i += 2;
                } else if c == '/' && next == Some('*') {
                    st = St::BlockComment(1);
                    i += 2;
                } else if c == '"' {
                    st = St::Str;
                    cur.code.push(' ');
                    i += 1;
                } else if (c == 'r' || c == 'b')
                    && !prev_is_ident(&chars, i)
                    && raw_or_byte_prefix(&chars, i).is_some()
                {
                    let (hashes, skip, is_char) = raw_or_byte_prefix(&chars, i).expect("checked");
                    st = if is_char {
                        St::CharLit
                    } else if hashes == u32::MAX {
                        St::Str
                    } else {
                        St::RawStr(hashes)
                    };
                    cur.code.push(' ');
                    i += skip;
                } else if c == '\'' {
                    match classify_quote(&chars, i) {
                        Quote::CharStart(skip) => {
                            st = St::CharLit;
                            cur.code.push(' ');
                            i += skip;
                        }
                        Quote::CharWhole(skip) => {
                            cur.code.push(' ');
                            i += skip;
                        }
                        Quote::Lifetime => {
                            cur.code.push(c);
                            i += 1;
                        }
                    }
                } else {
                    cur.code.push(c);
                    i += 1;
                }
            }
            St::LineComment => {
                cur.comment.push(c);
                i += 1;
            }
            St::BlockComment(depth) => {
                let next = chars.get(i + 1).copied();
                if c == '/' && next == Some('*') {
                    st = St::BlockComment(depth + 1);
                    i += 2;
                } else if c == '*' && next == Some('/') {
                    st = if depth == 1 {
                        St::Code
                    } else {
                        St::BlockComment(depth - 1)
                    };
                    i += 2;
                } else {
                    cur.comment.push(c);
                    i += 1;
                }
            }
            St::Str => {
                if c == '\\' {
                    i += 2;
                } else if c == '"' {
                    st = St::Code;
                    i += 1;
                } else {
                    i += 1;
                }
            }
            St::RawStr(hashes) => {
                if c == '"' && closes_raw(&chars, i, hashes) {
                    st = St::Code;
                    i += 1 + hashes as usize;
                } else {
                    i += 1;
                }
            }
            St::CharLit => {
                if c == '\\' {
                    i += 2;
                } else if c == '\'' {
                    st = St::Code;
                    i += 1;
                } else {
                    i += 1;
                }
            }
        }
    }
    lines
}

fn prev_is_ident(chars: &[char], i: usize) -> bool {
    i > 0 && (chars[i - 1].is_alphanumeric() || chars[i - 1] == '_')
}

/// At `chars[i] ∈ {r, b}`: detect `r"`, `r#"`, `b"`, `br"`, `br#"`,
/// `b'`. Returns `(hashes, chars_to_skip, is_char_literal)`; `hashes ==
/// u32::MAX` means a non-raw (escaped) string body.
fn raw_or_byte_prefix(chars: &[char], i: usize) -> Option<(u32, usize, bool)> {
    let mut j = i;
    if chars[j] == 'b' {
        j += 1;
        match chars.get(j) {
            Some('\'') => return Some((0, j - i + 1, true)),
            Some('"') => return Some((u32::MAX, j - i + 1, false)),
            Some('r') => {}
            _ => return None,
        }
    }
    if chars.get(j) == Some(&'r') {
        j += 1;
        let mut hashes = 0u32;
        while chars.get(j) == Some(&'#') {
            hashes += 1;
            j += 1;
        }
        if chars.get(j) == Some(&'"') {
            return Some((hashes, j - i + 1, false));
        }
    }
    None
}

fn closes_raw(chars: &[char], i: usize, hashes: u32) -> bool {
    (1..=hashes as usize).all(|k| chars.get(i + k) == Some(&'#'))
}

enum Quote {
    /// `'` opens a char literal; skip past the opener (and possibly the
    /// escape intro) and continue in `CharLit` state.
    CharStart(usize),
    /// A complete `'x'` literal; skip the whole thing.
    CharWhole(usize),
    /// A lifetime (or loop label) tick: plain code.
    Lifetime,
}

fn classify_quote(chars: &[char], i: usize) -> Quote {
    match chars.get(i + 1) {
        Some('\\') => Quote::CharStart(2),
        Some(&c2) if !(c2.is_alphanumeric() || c2 == '_') => Quote::CharStart(1),
        Some(_) => {
            // Identifier-ish after the tick: `'a'` is a char literal,
            // `'a` / `'static` is a lifetime.
            if chars.get(i + 2) == Some(&'\'') {
                Quote::CharWhole(3)
            } else {
                Quote::Lifetime
            }
        }
        None => Quote::Lifetime,
    }
}

/// 0-based line indices of occurrences of the identifier `word` in the
/// masked code.
fn token_lines(lines: &[ScannedLine], word: &str) -> Vec<usize> {
    let mut out = Vec::new();
    for (ln, l) in lines.iter().enumerate() {
        if find_token(&l.code, word) {
            out.push(ln);
        }
    }
    out
}

fn find_token(code: &str, word: &str) -> bool {
    let bytes = code.as_bytes();
    let mut start = 0usize;
    while let Some(pos) = code[start..].find(word) {
        let at = start + pos;
        let before_ok = at == 0 || !is_ident_byte(bytes[at - 1]);
        let end = at + word.len();
        let after_ok = end >= bytes.len() || !is_ident_byte(bytes[end]);
        if before_ok && after_ok {
            return true;
        }
        start = at + word.len().max(1);
    }
    false
}

fn is_ident_byte(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// `static` immediately followed by `mut` in the masked code of one line.
fn has_static_mut(code: &str) -> bool {
    let tokens: Vec<&str> = code
        .split(|c: char| !(c.is_alphanumeric() || c == '_'))
        .filter(|t| !t.is_empty())
        .collect();
    tokens.windows(2).any(|w| w == ["static", "mut"])
}

/// Is the `unsafe` token at 0-based line `ln` documented? Accepts a
/// `SAFETY:` comment (or a `# Safety` doc section) on the same line or
/// in the comment/attribute run directly above; a blank or plain-code
/// line breaks the run.
fn has_safety_comment(lines: &[ScannedLine], ln: usize) -> bool {
    let documented = |c: &str| c.contains("SAFETY:") || c.contains("# Safety");
    if documented(&lines[ln].comment) {
        return true;
    }
    let mut i = ln;
    while i > 0 {
        i -= 1;
        let l = &lines[i];
        if l.has_code() {
            // Attribute lines (e.g. `#[inline]`) don't break the run:
            // the doc comment of an `unsafe fn` sits above them.
            if l.code.trim_start().starts_with("#[") || l.code.trim_start().starts_with("#![") {
                if documented(&l.comment) {
                    return true;
                }
                continue;
            }
            return false;
        }
        if documented(&l.comment) {
            return true;
        }
        if l.comment.is_empty() {
            // Blank line: adjacency broken.
            return false;
        }
    }
    false
}

fn collect_rs_files(root: &Path, dir: &Path, out: &mut Vec<PathBuf>) {
    let entries = match fs::read_dir(dir) {
        Ok(e) => e,
        Err(_) => return,
    };
    for entry in entries.flatten() {
        let path = entry.path();
        if path.is_dir() {
            collect_rs_files(root, &path, out);
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
}

/// Run every rule over the `.rs` files under `root`. Returns all
/// violations, sorted by file then line.
pub fn run_lint(root: &Path) -> Vec<Violation> {
    let mut files = Vec::new();
    collect_rs_files(root, root, &mut files);
    files.sort();
    let mut violations = Vec::new();
    for path in &files {
        let rel: String = path
            .strip_prefix(root)
            .expect("collected under root")
            .components()
            .map(|c| c.as_os_str().to_string_lossy())
            .collect::<Vec<_>>()
            .join("/");
        let src = match fs::read_to_string(path) {
            Ok(s) => s,
            Err(e) => {
                violations.push(Violation {
                    file: rel,
                    line: 1,
                    kind: Kind::MissingForbidStamp,
                    msg: format!("unreadable source file: {e}"),
                });
                continue;
            }
        };
        let lines = scan(&src);
        let allowlisted = ALLOWLIST.contains(&rel.as_str());
        let parent_exempt = PARENT_EXEMPT.contains(&rel.as_str());
        let unsafe_lines = token_lines(&lines, "unsafe");
        if allowlisted {
            for &ln in &unsafe_lines {
                if !has_safety_comment(&lines, ln) {
                    violations.push(Violation {
                        file: rel.clone(),
                        line: ln + 1,
                        kind: Kind::UndocumentedUnsafe,
                        msg: "`unsafe` without an adjacent `// SAFETY:` comment".into(),
                    });
                }
            }
        } else {
            for &ln in &unsafe_lines {
                violations.push(Violation {
                    file: rel.clone(),
                    line: ln + 1,
                    kind: Kind::UnsafeOutsideAllowlist,
                    msg: "`unsafe` outside the allowlist (see rust/src/bin/lint.rs)".into(),
                });
            }
            for (ln, l) in lines.iter().enumerate() {
                if has_static_mut(&l.code) {
                    violations.push(Violation {
                        file: rel.clone(),
                        line: ln + 1,
                        kind: Kind::StaticMut,
                        msg: "`static mut` outside the allowlist".into(),
                    });
                }
                if find_token(&l.code, "transmute") {
                    violations.push(Violation {
                        file: rel.clone(),
                        line: ln + 1,
                        kind: Kind::Transmute,
                        msg: "`transmute` outside the allowlist".into(),
                    });
                }
            }
            if !parent_exempt && !lines.iter().any(|l| l.code.trim() == FORBID_STAMP) {
                violations.push(Violation {
                    file: rel.clone(),
                    line: 1,
                    kind: Kind::MissingForbidStamp,
                    msg: format!("missing `{FORBID_STAMP}` stamp"),
                });
            }
        }
        if rel == "lib.rs" && !lines.iter().any(|l| l.code.trim() == LIB_DENY) {
            violations.push(Violation {
                file: rel.clone(),
                line: 1,
                kind: Kind::MissingLibDeny,
                msg: format!("lib.rs must carry `{LIB_DENY}`"),
            });
        }
    }
    violations.sort_by(|a, b| (&a.file, a.line).cmp(&(&b.file, b.line)));
    violations
}

/// Default source root: `$CARGO_MANIFEST_DIR/rust/src` (the layout this
/// crate uses), falling back to `./rust/src`.
pub fn default_root() -> PathBuf {
    let manifest = env::var("CARGO_MANIFEST_DIR").unwrap_or_else(|_| ".".into());
    Path::new(&manifest).join("rust").join("src")
}

#[allow(dead_code)]
fn main() {
    let root = env::args()
        .nth(1)
        .map(PathBuf::from)
        .unwrap_or_else(default_root);
    let violations = run_lint(&root);
    if violations.is_empty() {
        println!("unsafe-boundary lint: clean ({})", root.display());
        return;
    }
    for v in &violations {
        eprintln!("{}:{}: [{:?}] {}", v.file, v.line, v.kind, v.msg);
    }
    eprintln!("unsafe-boundary lint: {} violation(s)", violations.len());
    std::process::exit(1);
}
