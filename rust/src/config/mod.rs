#![forbid(unsafe_code)]
//! The configuration system for the `lowbit` launcher: a TOML-subset
//! parser (sections, `key = value` with strings / numbers / booleans),
//! typed run configs with validation, and `--set section.key=value` CLI
//! overrides. No external crates — the offline set ships no `serde`.

use crate::model::TransformerConfig;
use crate::optim::Hyper;
use std::collections::BTreeMap;

/// Raw parsed config: section -> key -> value (string form).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct RawConfig {
    pub sections: BTreeMap<String, BTreeMap<String, String>>,
}

impl RawConfig {
    /// Parse a TOML-subset document: `[section]` headers, `key = value`,
    /// `#` comments. Values keep their string form; typed getters convert.
    pub fn parse(text: &str) -> Result<RawConfig, String> {
        let mut cfg = RawConfig::default();
        let mut section = String::from("root");
        for (lineno, raw) in text.lines().enumerate() {
            let line = match raw.find('#') {
                Some(i) if !raw[..i].contains('"') => &raw[..i],
                _ => raw,
            }
            .trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|l| l.strip_suffix(']')) {
                section = name.trim().to_string();
                cfg.sections.entry(section.clone()).or_default();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
            let v = v.trim().trim_matches('"').to_string();
            cfg.sections
                .entry(section.clone())
                .or_default()
                .insert(k.trim().to_string(), v);
        }
        Ok(cfg)
    }

    pub fn load(path: &str) -> Result<RawConfig, String> {
        let text =
            std::fs::read_to_string(path).map_err(|e| format!("reading {path}: {e}"))?;
        Self::parse(&text)
    }

    /// Apply a `section.key=value` override.
    pub fn set(&mut self, dotted: &str) -> Result<(), String> {
        let (path, value) = dotted
            .split_once('=')
            .ok_or_else(|| format!("override '{dotted}' must be section.key=value"))?;
        let (section, key) = path
            .split_once('.')
            .ok_or_else(|| format!("override '{dotted}' must be section.key=value"))?;
        self.sections
            .entry(section.trim().to_string())
            .or_default()
            .insert(key.trim().to_string(), value.trim().to_string());
        Ok(())
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&str> {
        self.sections.get(section)?.get(key).map(|s| s.as_str())
    }

    pub fn get_usize(&self, section: &str, key: &str, default: usize) -> Result<usize, String> {
        match self.get(section, key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("{section}.{key} = '{v}' is not an integer")),
        }
    }

    pub fn get_f32(&self, section: &str, key: &str, default: f32) -> Result<f32, String> {
        match self.get(section, key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("{section}.{key} = '{v}' is not a number")),
        }
    }

    pub fn get_bool(&self, section: &str, key: &str, default: bool) -> Result<bool, String> {
        match self.get(section, key) {
            None => Ok(default),
            Some("true") => Ok(true),
            Some("false") => Ok(false),
            Some(v) => Err(format!("{section}.{key} = '{v}' is not a boolean")),
        }
    }
}

/// Typed training-run configuration.
#[derive(Clone, Debug)]
pub struct RunConfig {
    pub model: TransformerConfig,
    pub optimizer: String,
    pub hyper: Hyper,
    pub steps: usize,
    pub batch: usize,
    pub warmup: usize,
    pub seed: u64,
    pub engine: String, // "builtin" | "pjrt"
    pub artifact_model: String,
    /// Step-engine worker threads (0 = auto) for every engine-backed
    /// optimizer — compressed presets and the dense baselines alike.
    pub threads: usize,
}

impl Default for RunConfig {
    fn default() -> RunConfig {
        RunConfig {
            model: TransformerConfig::tiny(),
            optimizer: "adamw4".to_string(),
            hyper: Hyper::default(),
            steps: 200,
            batch: 8,
            warmup: 20,
            seed: 0,
            engine: "builtin".to_string(),
            artifact_model: "tiny".to_string(),
            threads: 0,
        }
    }
}

impl RunConfig {
    /// Build from a raw config + defaults, with validation.
    pub fn from_raw(raw: &RawConfig) -> Result<RunConfig, String> {
        let d = RunConfig::default();
        let model = TransformerConfig {
            vocab: raw.get_usize("model", "vocab", d.model.vocab)?,
            d_model: raw.get_usize("model", "d_model", d.model.d_model)?,
            n_heads: raw.get_usize("model", "n_heads", d.model.n_heads)?,
            d_ff: raw.get_usize("model", "d_ff", d.model.d_ff)?,
            n_layers: raw.get_usize("model", "n_layers", d.model.n_layers)?,
            max_seq: raw.get_usize("model", "max_seq", d.model.max_seq)?,
        };
        let hyper = Hyper {
            lr: raw.get_f32("optimizer", "lr", d.hyper.lr)?,
            beta1: raw.get_f32("optimizer", "beta1", d.hyper.beta1)?,
            beta2: raw.get_f32("optimizer", "beta2", d.hyper.beta2)?,
            eps: raw.get_f32("optimizer", "eps", d.hyper.eps)?,
            weight_decay: raw.get_f32("optimizer", "weight_decay", d.hyper.weight_decay)?,
        };
        let cfg = RunConfig {
            model,
            optimizer: raw
                .get("optimizer", "name")
                .unwrap_or(&d.optimizer)
                .to_string(),
            hyper,
            steps: raw.get_usize("train", "steps", d.steps)?,
            batch: raw.get_usize("train", "batch", d.batch)?,
            warmup: raw.get_usize("train", "warmup", d.warmup)?,
            seed: raw.get_usize("train", "seed", d.seed as usize)? as u64,
            engine: raw.get("train", "engine").unwrap_or(&d.engine).to_string(),
            artifact_model: raw
                .get("train", "artifact_model")
                .unwrap_or(&d.artifact_model)
                .to_string(),
            threads: raw.get_usize("train", "threads", d.threads)?,
        };
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.model.d_model % self.model.n_heads != 0 {
            return Err(format!(
                "model.d_model ({}) must be divisible by model.n_heads ({})",
                self.model.d_model, self.model.n_heads
            ));
        }
        if !matches!(self.engine.as_str(), "builtin" | "pjrt") {
            return Err(format!("train.engine '{}' must be builtin|pjrt", self.engine));
        }
        if crate::optim::build(&self.optimizer, self.hyper).is_none()
            && self.optimizer != "adamw4-fused"
        {
            return Err(format!("unknown optimizer '{}'", self.optimizer));
        }
        if self.steps == 0 || self.batch == 0 {
            return Err("train.steps and train.batch must be positive".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# experiment config
[model]
d_model = 64
n_heads = 4   # heads
vocab = 256

[train]
steps = 50
engine = "builtin"

[optimizer]
name = "adamw4"
lr = 2e-3
"#;

    #[test]
    fn parses_sections_and_values() {
        let raw = RawConfig::parse(SAMPLE).unwrap();
        assert_eq!(raw.get("model", "d_model"), Some("64"));
        assert_eq!(raw.get("optimizer", "name"), Some("adamw4"));
        assert_eq!(raw.get_f32("optimizer", "lr", 0.0).unwrap(), 2e-3);
    }

    #[test]
    fn run_config_from_raw_with_defaults() {
        let raw = RawConfig::parse(SAMPLE).unwrap();
        let cfg = RunConfig::from_raw(&raw).unwrap();
        assert_eq!(cfg.model.d_model, 64);
        assert_eq!(cfg.steps, 50);
        assert_eq!(cfg.model.d_ff, TransformerConfig::tiny().d_ff); // default
        assert_eq!(cfg.hyper.lr, 2e-3);
    }

    #[test]
    fn overrides_apply() {
        let mut raw = RawConfig::parse(SAMPLE).unwrap();
        raw.set("train.steps=99").unwrap();
        raw.set("optimizer.name=adamw32").unwrap();
        let cfg = RunConfig::from_raw(&raw).unwrap();
        assert_eq!(cfg.steps, 99);
        assert_eq!(cfg.optimizer, "adamw32");
    }

    #[test]
    fn threads_default_and_override() {
        let raw = RawConfig::parse(SAMPLE).unwrap();
        let cfg = RunConfig::from_raw(&raw).unwrap();
        assert_eq!(cfg.threads, 0, "default is auto");
        let mut raw2 = RawConfig::parse(SAMPLE).unwrap();
        raw2.set("train.threads=4").unwrap();
        assert_eq!(RunConfig::from_raw(&raw2).unwrap().threads, 4);
    }

    #[test]
    fn validation_catches_bad_configs() {
        let mut raw = RawConfig::parse(SAMPLE).unwrap();
        raw.set("model.n_heads=7").unwrap();
        assert!(RunConfig::from_raw(&raw).is_err());

        let mut raw2 = RawConfig::parse(SAMPLE).unwrap();
        raw2.set("optimizer.name=bogus").unwrap();
        assert!(RunConfig::from_raw(&raw2).is_err());

        let mut raw3 = RawConfig::parse(SAMPLE).unwrap();
        raw3.set("train.engine=gpu").unwrap();
        assert!(RunConfig::from_raw(&raw3).is_err());
    }

    #[test]
    fn bad_syntax_reports_line() {
        let err = RawConfig::parse("[a]\nkey value").unwrap_err();
        assert!(err.contains("line 2"), "{err}");
    }
}
