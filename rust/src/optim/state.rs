#![forbid(unsafe_code)]
//! Persistent storage forms of one optimizer-state tensor (paper Alg. 1's
//! `s̄`): full precision, quantized, or factored. The trainer only ever
//! holds one decompressed copy at a time (per-layer decompression).

use super::factor::FactoredSecond;
use crate::quant::{QuantMap, QuantizedTensor, Quantizer};
use crate::tensor::Tensor;
use crate::util::rng::Pcg64;

/// Storage of a first-moment (or momentum) tensor.
pub enum MomentState {
    F32(Tensor),
    Quant(QuantizedTensor),
}

impl MomentState {
    pub fn decompress(&self, map: Option<&QuantMap>) -> Tensor {
        match self {
            MomentState::F32(t) => t.clone(),
            MomentState::Quant(q) => match map {
                Some(m) => q.dequantize_with(m),
                None => q.dequantize(),
            },
        }
    }

    pub fn compress(
        value: Tensor,
        quantizer: Option<&Quantizer>,
        map: Option<&QuantMap>,
        rng: &mut Pcg64,
    ) -> MomentState {
        match (quantizer, map) {
            (Some(q), Some(m)) => MomentState::Quant(q.quantize_with(&value, m, rng)),
            (Some(q), None) => MomentState::Quant(q.quantize(&value, rng)),
            _ => MomentState::F32(value),
        }
    }

    pub fn bytes(&self) -> usize {
        match self {
            MomentState::F32(t) => 4 * t.numel(),
            MomentState::Quant(q) => q.bytes(),
        }
    }

    /// Bytes actually allocated (buffer capacities, including growth
    /// slack) — the measured counterpart of the analytic [`Self::bytes`].
    pub fn allocated_bytes(&self) -> usize {
        match self {
            MomentState::F32(t) => 4 * t.data.capacity(),
            MomentState::Quant(q) => q.allocated_bytes(),
        }
    }
}

/// Storage of a second-moment tensor; adds the factored form (§4.3).
pub enum SecondState {
    F32(Tensor),
    Quant(QuantizedTensor),
    Factored(FactoredSecond),
}

impl SecondState {
    pub fn bytes(&self) -> usize {
        match self {
            SecondState::F32(t) => 4 * t.numel(),
            SecondState::Quant(q) => q.bytes(),
            SecondState::Factored(f) => f.bytes(),
        }
    }

    /// Bytes actually allocated (buffer capacities, including growth
    /// slack) — the measured counterpart of the analytic [`Self::bytes`].
    pub fn allocated_bytes(&self) -> usize {
        match self {
            SecondState::F32(t) => 4 * t.data.capacity(),
            SecondState::Quant(q) => q.allocated_bytes(),
            SecondState::Factored(f) => f.allocated_bytes(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::Quantizer;

    #[test]
    fn moment_roundtrip_f32() {
        let t = Tensor::from_vec(&[3], vec![1.0, 2.0, 3.0]);
        let mut rng = Pcg64::seeded(0);
        let s = MomentState::compress(t.clone(), None, None, &mut rng);
        assert_eq!(s.decompress(None).data, t.data);
        assert_eq!(s.bytes(), 12);
    }

    #[test]
    fn moment_roundtrip_quantized() {
        let q = Quantizer::first_moment_4bit();
        let map = q.build_map();
        let t = Tensor::from_vec(&[4], vec![0.5, -0.25, 1.0, 0.0]);
        let mut rng = Pcg64::seeded(0);
        let s = MomentState::compress(t.clone(), Some(&q), Some(&map), &mut rng);
        let back = s.decompress(Some(&map));
        // Values representable up to 4-bit DE resolution around scale 1.
        for (a, b) in t.data.iter().zip(back.data.iter()) {
            assert!((a - b).abs() < 0.15, "{a} vs {b}");
        }
        assert!(s.bytes() < 12);
    }
}
