#![forbid(unsafe_code)]
//! The optimizer zoo: full-precision baselines (AdamW, SGDM, Adafactor,
//! SM3) and the paper's compressed optimizers (8-bit AdamW, 4-bit AdamW,
//! 4-bit Factor) built on the Alg. 1 compress/decompress wrapper.

pub mod adafactor;
pub mod adamw;
pub mod factor;
pub mod lowbit;
pub mod sgdm;
pub mod sm3;
pub mod state;

use crate::engine::SchedStats;
use crate::obs::report::StepReport;
use crate::tensor::Tensor;
use crate::util::json::Json;

/// What a parameter tensor is; drives per-parameter quantization policy
/// (the 8-bit baseline skips embeddings, the ≤4096 rule skips small
/// tensors such as biases and LayerNorm gains).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ParamKind {
    Embedding,
    Weight,
    Bias,
    Norm,
}

/// A named, classified parameter tensor.
#[derive(Clone, Debug)]
pub struct Param {
    pub name: String,
    pub kind: ParamKind,
    pub tensor: Tensor,
}

impl Param {
    pub fn new(name: &str, kind: ParamKind, tensor: Tensor) -> Param {
        Param {
            name: name.to_string(),
            kind,
            tensor,
        }
    }
}

/// Shared optimizer hyperparameters (paper App. D conventions).
#[derive(Clone, Copy, Debug)]
pub struct Hyper {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
}

impl Default for Hyper {
    fn default() -> Hyper {
        Hyper {
            lr: 1e-3,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-6,
            weight_decay: 0.01,
        }
    }
}

/// A step that aborted mid-flight (e.g. an engine worker panicked) and
/// was rolled back by [`Optimizer::try_step`]. The optimizer and its
/// state are exactly as they were before the step; calling `try_step`
/// again with the same inputs retries it.
#[derive(Clone, Debug)]
pub struct StepError {
    /// Human-readable cause — the panic payload when one was caught.
    pub message: String,
}

impl std::fmt::Display for StepError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "optimizer step aborted: {}", self.message)
    }
}

impl std::error::Error for StepError {}

/// The common optimizer interface. `step` consumes one gradient per
/// parameter (same order); optimizers lazily initialize state on first
/// use, so the same instance works for any model.
pub trait Optimizer {
    /// One update step. `lr` override allows schedules without mutating
    /// the stored hyperparameters.
    fn step(&mut self, params: &mut [Param], grads: &[Tensor], lr: f32);

    /// [`Optimizer::step`] as a transaction: on success equivalent to
    /// `step`; if the step aborts (a worker panic — injected by
    /// `crate::fault` or real), optimizers that override this roll
    /// parameters, optimizer state and the step counter back to their
    /// pre-step values and return `Err`, leaving the instance reusable —
    /// a retry is bit-identical to a never-faulted run. The default
    /// implementation provides no such recovery: it simply forwards to
    /// `step` and propagates any panic.
    fn try_step(
        &mut self,
        params: &mut [Param],
        grads: &[Tensor],
        lr: f32,
    ) -> Result<(), StepError> {
        self.step(params, grads, lr);
        Ok(())
    }

    /// Persistent optimizer-state memory in bytes — the paper's central
    /// accounting quantity (codes + quantization scales + factored stats).
    fn state_bytes(&self) -> usize;

    fn name(&self) -> String;

    /// Steps taken so far (for bias correction and schedules).
    fn t(&self) -> usize;

    /// Drop any cached step context (plan, metadata, scratch arenas) so
    /// the next step rebuilds it from scratch. Results are unaffected —
    /// a rebuilt context replays the identical plan — so this exists for
    /// cold-vs-warm benchmarking and cache tests. No-op for optimizers
    /// without an engine-backed cache.
    fn invalidate_step_cache(&mut self) {}

    /// Engine-scheduler telemetry accumulated by this optimizer's cached
    /// step context (cumulative claim/steal/affinity-hit counts — see
    /// the engine module docs' "Scheduler" section); `None` for
    /// optimizers that don't step through the engine.
    fn sched_stats(&self) -> Option<SchedStats> {
        None
    }

    /// Unified step telemetry (scheduler counters, offload totals, span
    /// summaries, quant-quality metrics — whatever this optimizer
    /// collects; see `obs::report`). `None` for optimizers with no
    /// engine-backed telemetry at all.
    fn step_report(&self) -> Option<StepReport> {
        None
    }

    /// The recorded span rings as one chrome://tracing JSON document
    /// (load via `chrome://tracing` or Perfetto). `None` when the
    /// `trace` feature is compiled out or this optimizer records no
    /// spans.
    fn export_trace(&self) -> Option<Json> {
        None
    }

    /// Optimizer-state bytes actually allocated (buffer capacities,
    /// including growth slack), as opposed to the analytic accounting of
    /// [`Optimizer::state_bytes`]. Defaults to the analytic number for
    /// optimizers that don't track allocation.
    fn state_bytes_allocated(&self) -> usize {
        self.state_bytes()
    }
}

/// Construct an optimizer by preset name (the names used across the
/// experiment harness and CLI):
///
/// * `adamw32`  — 32-bit AdamW
/// * `adamw8`   — 8-bit AdamW, B2048/DE, embeddings kept fp32 (Dettmers'22)
/// * `adamw4`   — 4-bit AdamW (ours): m B128/DE, v Rank-1/Linear
/// * `factor4`  — 4-bit Factor (ours): m B128/DE, v factored (≥2-D) /
///                quantized Rank-1/Linear (1-D)
/// * `adafactor` / `adafactor-b0` — Adafactor with/without first moment
/// * `sm3`      — SM3 with momentum
/// * `sgdm` / `sgdm4` — SGD with (quantized) momentum
pub fn build(preset: &str, hp: Hyper) -> Option<Box<dyn Optimizer>> {
    build_threaded(preset, hp, 0)
}

/// [`build`] with an explicit step-engine worker count (0 = auto) for
/// every engine-backed preset — the compressed optimizers *and* the
/// dense baselines, which shard through the same engine so the Tab. 4
/// speed comparison is apples-to-apples. Thread count is purely a
/// throughput knob: the engine is bit-identical at every setting.
pub fn build_threaded(preset: &str, hp: Hyper, threads: usize) -> Option<Box<dyn Optimizer>> {
    use crate::quant::Quantizer;
    let compressed = |policy: lowbit::QuantPolicy| {
        lowbit::CompressedAdamW::new(hp, policy).with_threads(threads)
    };
    Some(match preset {
        "adamw32" => Box::new(adamw::AdamW::new(hp).with_threads(threads)),
        "adamw8" => Box::new(compressed(lowbit::QuantPolicy::bit8())),
        "adamw4" => Box::new(compressed(lowbit::QuantPolicy::bit4())),
        "adamw4-sr" => Box::new(compressed(lowbit::QuantPolicy::bit4().stochastic())),
        "factor4" => Box::new(compressed(lowbit::QuantPolicy::bit4().factored())),
        "adafactor" => Box::new(adafactor::Adafactor::new(hp, true).with_threads(threads)),
        "adafactor-b0" => Box::new(adafactor::Adafactor::new(hp, false).with_threads(threads)),
        "sm3" => Box::new(sm3::Sm3::new(hp).with_threads(threads)),
        "sgdm" => Box::new(sgdm::Sgdm::new(hp, None).with_threads(threads)),
        // The quantized-momentum variant stays sequential (shared RNG
        // stream); the thread knob is a no-op for it.
        "sgdm4" => Box::new(sgdm::Sgdm::new(
            hp,
            Some(Quantizer::first_moment_4bit()),
        )),
        _ => return None,
    })
}

/// All presets compared in the paper's Tab. 2.
pub fn table2_presets() -> Vec<&'static str> {
    vec![
        "adamw32",
        "adafactor",
        "adafactor-b0",
        "sm3",
        "adamw8",
        "adamw4",
        "factor4",
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_all_presets() {
        for p in table2_presets() {
            assert!(build(p, Hyper::default()).is_some(), "preset {p}");
        }
        assert!(build("adamw4-sr", Hyper::default()).is_some());
        assert!(build("sgdm4", Hyper::default()).is_some());
        assert!(build("nope", Hyper::default()).is_none());
    }
}
