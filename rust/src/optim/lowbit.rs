#![forbid(unsafe_code)]
//! The paper's optimizer: AdamW with compressed states (Alg. 1 + Alg. 3).
//!
//! Per parameter shard and step: decompress m̄, v̄ → run the exact AdamW
//! update → re-compress. Only a shard's states are in full precision at
//! any moment; everything else stays packed. The step itself runs on the
//! shard-parallel [`crate::engine`]: block-aligned shards execute
//! concurrently with one deterministic RNG stream each, so results are
//! bit-identical at every thread count (see the engine module docs for
//! the contract and `rust/tests/engine_parity.rs` for the proof).
//!
//! The quantization policy is fully configurable so the Tab. 1 ablation
//! grid (normalization × mapping × stochastic rounding × factorization ×
//! stable-embedding) is expressible with this one type.

use super::factor::FactoredSecond;
use super::state::{MomentState, SecondState};
use super::{Hyper, Optimizer, Param, ParamKind, StepError};
use crate::engine::{compressed_step, SchedMode, SchedStats, StepContext, StepEngine, StepParams};
use crate::fault::FaultPlan;
use crate::obs::quant::QuantAccum;
use crate::obs::report::{FaultCounters, QuantReport, StepReport};
use crate::offload::{pipeline, OffloadConfig, OffloadReport, OffloadState};
use crate::quant::Scales;
use crate::quant::{MapKind, NormKind, QuantMap, Quantizer};
use crate::tensor::Tensor;
use crate::util::json::Json;
use crate::util::rng::Pcg64;

/// Which states get quantized and how (paper §5 + App. D.1).
#[derive(Clone, Copy, Debug)]
pub struct QuantPolicy {
    /// First-moment quantizer; `None` keeps m in fp32.
    pub m_quant: Option<Quantizer>,
    /// Second-moment quantizer for ≥2-D tensors; `None` keeps v in fp32.
    pub v_quant: Option<Quantizer>,
    /// Second-moment quantizer for 1-D tensors. The paper uses B128 with
    /// the same mapping because rank-1 degenerates to per-tensor on 1-D.
    pub v_quant_1d: Option<Quantizer>,
    /// Factorize the second moment of ≥2-D tensors instead of quantizing
    /// (the "4-bit Factor" optimizer, §4.3).
    pub factor_v: bool,
    /// Tensors with numel <= this stay fp32 (App. D.1: 4096).
    pub min_quant_size: usize,
    /// Keep embedding-layer states fp32 (the 8-bit baseline's behaviour;
    /// also our stand-in for "Stable Embedding" rows in Tab. 1).
    pub skip_embedding: bool,
}

impl QuantPolicy {
    /// 4-bit AdamW (ours): m B128/DE, v Rank-1/Linear (+B128/Linear 1-D).
    pub fn bit4() -> QuantPolicy {
        QuantPolicy {
            m_quant: Some(Quantizer::first_moment_4bit()),
            v_quant: Some(Quantizer::second_moment_4bit()),
            v_quant_1d: Some(Quantizer::new(
                NormKind::Block(128),
                MapKind::Linear,
                4,
                false,
            )),
            factor_v: false,
            min_quant_size: 4096,
            skip_embedding: false,
        }
    }

    /// 8-bit AdamW (Dettmers'22): B2048/DE both moments, embeddings fp32.
    pub fn bit8() -> QuantPolicy {
        QuantPolicy {
            m_quant: Some(Quantizer::moment_8bit(true)),
            v_quant: Some(Quantizer::moment_8bit(false)),
            v_quant_1d: Some(Quantizer::moment_8bit(false)),
            factor_v: false,
            min_quant_size: 4096,
            skip_embedding: true,
        }
    }

    /// Enable second-moment factorization (4-bit Factor).
    pub fn factored(mut self) -> QuantPolicy {
        self.factor_v = true;
        self
    }

    /// Stochastic rounding on both moments (Tab. 1 SR row).
    pub fn stochastic(mut self) -> QuantPolicy {
        self.m_quant = self.m_quant.map(|q| q.with_stochastic(true));
        self.v_quant = self.v_quant.map(|q| q.with_stochastic(true));
        self.v_quant_1d = self.v_quant_1d.map(|q| q.with_stochastic(true));
        self
    }

    /// Keep embedding states fp32 (stable-embedding stand-in).
    pub fn with_skip_embedding(mut self, skip: bool) -> QuantPolicy {
        self.skip_embedding = skip;
        self
    }

    /// Explicit second-moment scheme (Tab. 1 ablation rows).
    pub fn with_v(mut self, q: Option<Quantizer>) -> QuantPolicy {
        self.v_quant = q;
        self.v_quant_1d = q.map(|mut qq| {
            // 1-D fallback keeps the mapping but uses B128 normalization.
            if qq.norm == NormKind::Rank1 {
                qq.norm = NormKind::Block(128);
            }
            qq
        });
        self
    }

    /// Explicit first-moment scheme.
    pub fn with_m(mut self, q: Option<Quantizer>) -> QuantPolicy {
        self.m_quant = q;
        self
    }

    fn should_quantize(&self, p: &Param) -> bool {
        if p.tensor.numel() <= self.min_quant_size {
            return false;
        }
        if self.skip_embedding && p.kind == ParamKind::Embedding {
            return false;
        }
        true
    }
}

/// AdamW with compressed optimizer states, stepped on the shard-parallel
/// [`StepEngine`].
pub struct CompressedAdamW {
    hp: Hyper,
    pub policy: QuantPolicy,
    t: usize,
    m: Vec<MomentState>,
    v: Vec<SecondState>,
    // Cached mapping tables (hot path: built once, borrowed every step).
    m_map: Option<QuantMap>,
    v_map: Option<QuantMap>,
    v1_map: Option<QuantMap>,
    /// Base seed for the per-shard stochastic-rounding streams.
    seed: u64,
    /// Init-time RNG (state initialization only; the step path draws
    /// from deterministic per-shard streams instead).
    rng: Pcg64,
    engine: StepEngine,
    /// Cached step context: plan, metadata, stat slots and re-encode
    /// arenas, reused across steps (rebuilt on layout change or builder
    /// reconfiguration).
    ctx: StepContext,
    /// When set, steps run on the offload pipeline: states live in the
    /// host tier and are staged through the device-scratch budget.
    /// Bit-identical to in-memory execution — this trades simulated
    /// link traffic (tracked in the report) for device state memory.
    offload: Option<OffloadState>,
    /// Steps aborted mid-flight and rolled back by [`Self::try_step`].
    rollbacks: u64,
}

/// Pre-step snapshot of one first-moment state — just the mutable parts
/// (packed codes + scales, or the fp32 values); shapes, quantizer
/// configs and block maps never change mid-step.
enum MSnap {
    F32(Vec<f32>),
    Quant(Vec<u8>, Scales),
}

impl MSnap {
    fn of(s: &MomentState) -> MSnap {
        match s {
            MomentState::F32(t) => MSnap::F32(t.data.clone()),
            MomentState::Quant(q) => MSnap::Quant(q.packed.clone(), q.scales.clone()),
        }
    }

    fn restore(self, s: &mut MomentState) {
        match (self, s) {
            (MSnap::F32(d), MomentState::F32(t)) => t.data = d,
            (MSnap::Quant(p, sc), MomentState::Quant(q)) => {
                q.packed = p;
                q.scales = sc;
            }
            // A step never changes a state's representation.
            _ => unreachable!("moment-state variant changed mid-step"),
        }
    }
}

/// Pre-step snapshot of one second-moment state (see [`MSnap`]).
enum VSnap {
    F32(Vec<f32>),
    Quant(Vec<u8>, Scales),
    Factored(Vec<f32>, Vec<f32>),
}

impl VSnap {
    fn of(s: &SecondState) -> VSnap {
        match s {
            SecondState::F32(t) => VSnap::F32(t.data.clone()),
            SecondState::Quant(q) => VSnap::Quant(q.packed.clone(), q.scales.clone()),
            SecondState::Factored(f) => VSnap::Factored(f.row.clone(), f.col.clone()),
        }
    }

    fn restore(self, s: &mut SecondState) {
        match (self, s) {
            (VSnap::F32(d), SecondState::F32(t)) => t.data = d,
            (VSnap::Quant(p, sc), SecondState::Quant(q)) => {
                q.packed = p;
                q.scales = sc;
            }
            (VSnap::Factored(r, c), SecondState::Factored(f)) => {
                f.row = r;
                f.col = c;
            }
            _ => unreachable!("second-state variant changed mid-step"),
        }
    }
}

impl CompressedAdamW {
    pub fn new(hp: Hyper, policy: QuantPolicy) -> CompressedAdamW {
        CompressedAdamW {
            hp,
            t: 0,
            m_map: policy.m_quant.map(|q| q.build_map()),
            v_map: policy.v_quant.map(|q| q.build_map()),
            v1_map: policy.v_quant_1d.map(|q| q.build_map()),
            policy,
            m: Vec::new(),
            v: Vec::new(),
            seed: 0x10B1,
            rng: Pcg64::seeded(0x10B1),
            engine: StepEngine::new(),
            ctx: StepContext::new(),
            offload: None,
            rollbacks: 0,
        }
    }

    /// Route the optimizer states through the simulated host tier: every
    /// step runs on the offload pipeline (prefetch / compute / writeback
    /// through a bounded device-scratch budget, see
    /// [`crate::offload::pipeline`]). Results are bit-identical to
    /// in-memory execution at any thread count and prefetch depth; the
    /// virtual-time cost shows up in [`Self::offload_report`].
    /// Invalidates the cached step context.
    pub fn offloaded(mut self, cfg: OffloadConfig) -> CompressedAdamW {
        self.offload = Some(OffloadState::new(cfg));
        self.ctx.invalidate();
        self
    }

    /// Accumulated virtual-time measurements of the offloaded steps
    /// (`None` until [`Self::offloaded`] configures the pipeline).
    pub fn offload_report(&self) -> Option<&OffloadReport> {
        self.offload.as_ref().map(|os| &os.report)
    }

    /// Pin a deterministic fault plan on the offload pipeline,
    /// overriding the `LOWBIT_FAULTS` env gate (use
    /// [`FaultPlan::none`] to pin a run fault-free regardless of the
    /// environment). Must be called after [`Self::offloaded`] — faults
    /// are injected at the pipeline's transfer and compute sites, so
    /// there is nowhere to arm them on an in-memory optimizer. Faulted
    /// runs stay bit-identical to fault-free ones; the cost shows up as
    /// retries/rollbacks in [`Self::step_report`].
    pub fn with_faults(mut self, plan: FaultPlan) -> CompressedAdamW {
        self.offload
            .as_mut()
            .expect("with_faults requires an offloaded optimizer (call .offloaded(cfg) first)")
            .faults = Some(plan);
        self
    }

    /// Steps aborted mid-flight and rolled back by [`Self::try_step`].
    pub fn rollbacks(&self) -> u64 {
        self.rollbacks
    }

    /// Enable (or disable) per-step quantization-quality metrics:
    /// RMSE / max-abs / relative quant error of m and v against their
    /// pre-encode fp32 values, nibble-code occupancy histograms (the
    /// zero-point diagnostic — how often DE's zero code fires vs
    /// Linear's never), and per-tensor dynamic-range counters. See
    /// [`crate::obs::quant`]. Runtime-gated — no feature flag; results
    /// are bit-identical with metrics on or off (metered steps take the
    /// reference re-encode arm in phase C, which is pinned equal to the
    /// fused arm), at some throughput cost. Offloaded steps are never
    /// metered.
    pub fn with_quant_metrics(mut self, on: bool) -> CompressedAdamW {
        self.ctx.quant = if on { Some(QuantAccum::default()) } else { None };
        self
    }

    /// The merged quant-quality accumulator of the most recent metered
    /// step (`None` unless [`Self::with_quant_metrics`] enabled it).
    pub fn quant_metrics(&self) -> Option<&QuantAccum> {
        self.ctx.quant_metrics()
    }

    /// Set the engine worker count (0 = auto). Results are bit-identical
    /// at every setting; this is purely a throughput knob. Invalidates
    /// the cached step context.
    pub fn with_threads(mut self, threads: usize) -> CompressedAdamW {
        self.engine = self.engine.clone().with_threads(threads);
        self.ctx.invalidate();
        self
    }

    /// Set the engine shard size in elements (tests use small values to
    /// force multi-shard plans on small tensors). Invalidates the cached
    /// step context.
    pub fn with_shard_elems(mut self, shard_elems: usize) -> CompressedAdamW {
        self.engine = self.engine.clone().with_shard_elems(shard_elems);
        self.ctx.invalidate();
        self
    }

    /// Pin the engine scheduler mode, bypassing the process-level
    /// `LOWBIT_ENGINE_SCHED` resolution. Results are bit-identical in
    /// every mode (the parity suite compares them); this only moves
    /// which worker runs which shard. Invalidates the cached step
    /// context.
    pub fn with_sched(mut self, mode: SchedMode) -> CompressedAdamW {
        self.engine = self.engine.clone().with_sched(mode);
        self.ctx.invalidate();
        self
    }

    /// Set the base seed of the per-shard stochastic-rounding streams.
    pub fn with_seed(mut self, seed: u64) -> CompressedAdamW {
        self.seed = seed;
        self
    }

    pub fn threads(&self) -> usize {
        self.engine.threads()
    }

    fn lazy_init(&mut self, params: &[Param]) {
        if !self.m.is_empty() {
            return;
        }
        for p in params {
            let shape = &p.tensor.shape;
            let quantize = self.policy.should_quantize(p);
            // Initial states are exact zeros; store them compressed from
            // the start (zero quantizes exactly under every scheme).
            let zero = Tensor::zeros(shape);
            let m = if quantize {
                MomentState::compress(
                    zero.clone(),
                    self.policy.m_quant.as_ref(),
                    self.m_map.as_ref(),
                    &mut self.rng,
                )
            } else {
                MomentState::F32(zero.clone())
            };
            let v = if quantize && self.policy.factor_v && shape.len() >= 2 {
                SecondState::Factored(FactoredSecond::zeros(shape))
            } else if quantize {
                let (q, map) = self.v_scheme(shape.len());
                let (q, map) = (q.copied(), map.cloned());
                match q {
                    Some(q) => SecondState::Quant(match &map {
                        Some(m) => q.quantize_with(&zero, m, &mut self.rng),
                        None => q.quantize(&zero, &mut self.rng),
                    }),
                    _ => SecondState::F32(zero),
                }
            } else {
                SecondState::F32(zero)
            };
            self.m.push(m);
            self.v.push(v);
        }
    }

    fn v_scheme(&self, ndim: usize) -> (Option<&Quantizer>, Option<&QuantMap>) {
        if ndim >= 2 {
            (self.policy.v_quant.as_ref(), self.v_map.as_ref())
        } else {
            (self.policy.v_quant_1d.as_ref(), self.v1_map.as_ref())
        }
    }

    /// Step counter + state storage, for checkpointing
    /// ([`crate::train::checkpoint::save_opt_state`]) — the compressed
    /// forms are exposed as-is, so a checkpoint preserves the packed
    /// codes and scales byte-exactly.
    pub fn export_states(&self) -> (usize, &[MomentState], &[SecondState]) {
        (self.t, &self.m, &self.v)
    }

    /// Restore checkpointed states. The optimizer must have been built
    /// with the same policy the states were saved under (decode tables
    /// are rebuilt from the live policy, not persisted) — every
    /// quantized state's scheme is validated against the live policy, so
    /// a checkpoint saved under a different policy is rejected here
    /// instead of decoding garbage (or indexing a wrong-width map) on
    /// the next step. Invalidates the cached step context; the next step
    /// continues bit-identically to the uninterrupted run.
    pub fn import_states(
        &mut self,
        t: usize,
        m: Vec<MomentState>,
        v: Vec<SecondState>,
    ) -> Result<(), String> {
        if m.len() != v.len() {
            return Err("moment lists must pair up".to_string());
        }
        for (i, ms) in m.iter().enumerate() {
            if let MomentState::Quant(qt) = ms {
                match self.policy.m_quant {
                    Some(q) if q == qt.quantizer => {}
                    _ => {
                        return Err(format!(
                            "state {i}: first-moment scheme {} does not match the live policy",
                            qt.quantizer.name()
                        ))
                    }
                }
            }
        }
        for (i, vs) in v.iter().enumerate() {
            match vs {
                SecondState::F32(_) => {}
                SecondState::Factored(_) => {
                    if !self.policy.factor_v {
                        return Err(format!(
                            "state {i}: factored second moment under a non-factored policy"
                        ));
                    }
                }
                SecondState::Quant(qt) => {
                    if self.policy.factor_v && qt.shape.len() >= 2 {
                        return Err(format!(
                            "state {i}: quantized 2-D second moment under a factored policy"
                        ));
                    }
                    let expect = if qt.shape.len() >= 2 {
                        self.policy.v_quant
                    } else {
                        self.policy.v_quant_1d
                    };
                    match expect {
                        Some(q) if q == qt.quantizer => {}
                        _ => {
                            return Err(format!(
                                "state {i}: second-moment scheme {} does not match the live policy",
                                qt.quantizer.name()
                            ))
                        }
                    }
                }
            }
        }
        self.t = t;
        self.m = m;
        self.v = v;
        self.ctx.invalidate();
        Ok(())
    }

    /// Decompressed view of the moments of parameter `idx` (analysis /
    /// figures only; the step path streams per tensor).
    pub fn moments(&self, idx: usize) -> Option<(Tensor, Tensor)> {
        let m = match self.m.get(idx)? {
            MomentState::F32(t) => t.clone(),
            MomentState::Quant(q) => q.dequantize_with(self.m_map.as_ref()?),
        };
        let v = match self.v.get(idx)? {
            SecondState::F32(t) => t.clone(),
            SecondState::Quant(q) => q.dequantize(),
            SecondState::Factored(f) => f.reconstruct(),
        };
        Some((m, v))
    }
}

impl Optimizer for CompressedAdamW {
    fn step(&mut self, params: &mut [Param], grads: &[Tensor], lr: f32) {
        assert_eq!(params.len(), grads.len());
        self.lazy_init(params);
        self.t += 1;
        // The whole decompress → AdamW → requantize pass runs on the
        // shard-parallel engine; the cached decode tables are borrowed
        // (never cloned) and shard scratch replaces per-tensor
        // allocations. Bit-identical at every thread count.
        let sp = StepParams {
            hp: self.hp,
            t: self.t,
            lr,
            base_seed: self.seed,
            m_map: self.m_map.as_ref(),
            v_map: self.v_map.as_ref(),
            v1_map: self.v1_map.as_ref(),
        };
        if let Some(os) = &mut self.offload {
            pipeline::compressed_offloaded_step(
                &self.engine,
                &mut self.ctx,
                os,
                &sp,
                params,
                grads,
                &mut self.m,
                &mut self.v,
            );
        } else {
            compressed_step(
                &self.engine,
                &mut self.ctx,
                &sp,
                params,
                grads,
                &mut self.m,
                &mut self.v,
            );
        }
    }

    /// [`Optimizer::step`] as a transaction. Weights, packed states,
    /// scales and the step counter are snapshotted before the step; if
    /// an engine worker panics mid-step (injected via [`FaultPlan`] or
    /// real), the unwind is caught on the submitter, everything is
    /// rolled back, the cached step context is invalidated, and the
    /// optimizer is reusable — a retried step is bit-identical to a
    /// never-faulted run (`rust/tests/chaos.rs` pins this).
    fn try_step(
        &mut self,
        params: &mut [Param],
        grads: &[Tensor],
        lr: f32,
    ) -> Result<(), StepError> {
        assert_eq!(params.len(), grads.len());
        // Initialize state outside the transaction so the snapshot
        // covers every tensor (init-time RNG draws are not replayed).
        self.lazy_init(params);
        let t0 = self.t;
        let w0: Vec<Vec<f32>> = params.iter().map(|p| p.tensor.data.clone()).collect();
        let m0: Vec<MSnap> = self.m.iter().map(MSnap::of).collect();
        let v0: Vec<VSnap> = self.v.iter().map(VSnap::of).collect();
        // AssertUnwindSafe: on Err every &mut the closure touched is
        // restored from the snapshot (or rebuilt, for the step context)
        // before anyone can observe the broken invariants.
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            self.step(params, grads, lr)
        }));
        match res {
            Ok(()) => Ok(()),
            Err(payload) => {
                for (p, w) in params.iter_mut().zip(w0) {
                    p.tensor.data = w;
                }
                for (s, snap) in self.m.iter_mut().zip(m0) {
                    snap.restore(s);
                }
                for (s, snap) in self.v.iter_mut().zip(v0) {
                    snap.restore(s);
                }
                self.t = t0;
                // Scratch arenas and stat slots may hold a half-finished
                // step; rebuild them from scratch on the next step.
                self.ctx.invalidate();
                self.rollbacks += 1;
                let message = if let Some(s) = payload.downcast_ref::<&str>() {
                    (*s).to_string()
                } else if let Some(s) = payload.downcast_ref::<String>() {
                    s.clone()
                } else {
                    "non-string panic payload".to_string()
                };
                Err(StepError { message })
            }
        }
    }

    fn state_bytes(&self) -> usize {
        self.m.iter().map(|s| s.bytes()).sum::<usize>()
            + self.v.iter().map(|s| s.bytes()).sum::<usize>()
    }

    fn name(&self) -> String {
        let bits = self
            .policy
            .m_quant
            .map(|q| q.bits)
            .or(self.policy.v_quant.map(|q| q.bits))
            .unwrap_or(32);
        if self.policy.factor_v {
            format!("{bits}-bit Factor")
        } else {
            format!("{bits}-bit AdamW")
        }
    }

    fn t(&self) -> usize {
        self.t
    }

    fn invalidate_step_cache(&mut self) {
        self.ctx.invalidate();
    }

    fn sched_stats(&self) -> Option<SchedStats> {
        Some(self.ctx.affinity.stats(self.engine.sched()))
    }

    fn step_report(&self) -> Option<StepReport> {
        let off = self.offload_report();
        let mut r = StepReport {
            step: self.t,
            sched: self.sched_stats(),
            offload: off.copied(),
            spans: None,
            quant: self
                .ctx
                .quant_metrics()
                .filter(|a| !a.is_empty())
                .map(QuantReport::from_accum),
            // Always present for the compressed optimizer (zeros on a
            // clean run) so downstream schemas can rely on the key.
            faults: Some(FaultCounters {
                link_fail_retries: off.map_or(0, |o| o.fail_retries),
                link_corrupt_retries: off.map_or(0, |o| o.corrupt_retries),
                retry_virtual_seconds: off.map_or(0.0, |o| o.retry_seconds),
                rollbacks: self.rollbacks,
            }),
        };
        #[cfg(feature = "trace")]
        {
            let s = crate::obs::report::SpanSummary::from_rings(&self.ctx.trace_rings());
            if !s.phases.is_empty() || s.dropped > 0 {
                r.spans = Some(s);
            }
        }
        Some(r)
    }

    fn export_trace(&self) -> Option<Json> {
        #[cfg(not(feature = "trace"))]
        {
            None
        }
        #[cfg(feature = "trace")]
        {
            Some(crate::obs::trace::chrome_trace(&self.ctx.trace_rings()))
        }
    }

    fn state_bytes_allocated(&self) -> usize {
        self.m.iter().map(|s| s.allocated_bytes()).sum::<usize>()
            + self.v.iter().map(|s| s.allocated_bytes()).sum::<usize>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::adamw::AdamW;
    use crate::util::rng::Pcg64;

    fn quadratic_run(opt: &mut dyn Optimizer, shape: &[usize], steps: usize) -> (f64, Vec<f32>) {
        let mut rng = Pcg64::seeded(31);
        let target = Tensor::randn(shape, 1.0, &mut rng);
        let mut params = vec![Param::new(
            "w",
            ParamKind::Weight,
            Tensor::zeros(shape),
        )];
        for _ in 0..steps {
            let g = params[0].tensor.sub(&target);
            opt.step(&mut params, &[g], 0.05);
        }
        let rel = params[0].tensor.sub(&target).sq_l2() / target.sq_l2();
        (rel, params[0].tensor.data.clone())
    }

    #[test]
    fn disabled_policy_matches_fp32_adamw_exactly() {
        // With all quantizers off, CompressedAdamW must be bit-identical
        // to the 32-bit AdamW baseline.
        let hp = Hyper::default();
        let policy = QuantPolicy {
            m_quant: None,
            v_quant: None,
            v_quant_1d: None,
            factor_v: false,
            min_quant_size: 0,
            skip_embedding: false,
        };
        let mut a = CompressedAdamW::new(hp, policy);
        let mut b = AdamW::new(hp);
        let (_, wa) = quadratic_run(&mut a, &[16, 8], 50);
        let (_, wb) = quadratic_run(&mut b, &[16, 8], 50);
        assert_eq!(wa, wb);
    }

    #[test]
    fn bit4_converges_close_to_fp32() {
        let hp = Hyper {
            weight_decay: 0.0,
            ..Hyper::default()
        };
        // Lower the small-tensor threshold so the toy problem is actually
        // quantized.
        let mut policy = QuantPolicy::bit4();
        policy.min_quant_size = 0;
        let mut q4 = CompressedAdamW::new(hp, policy);
        let (rel, _) = quadratic_run(&mut q4, &[32, 16], 600);
        assert!(rel < 5e-2, "4-bit AdamW rel residual {rel}");
    }

    #[test]
    fn factored_variant_converges() {
        let hp = Hyper {
            weight_decay: 0.0,
            ..Hyper::default()
        };
        let mut policy = QuantPolicy::bit4().factored();
        policy.min_quant_size = 0;
        let mut opt = CompressedAdamW::new(hp, policy);
        let (rel, _) = quadratic_run(&mut opt, &[32, 16], 600);
        assert!(rel < 5e-2, "4-bit Factor rel residual {rel}");
    }

    #[test]
    fn state_bytes_hierarchy() {
        // 32-bit > 8-bit > 4-bit > 4-bit factored, on one 256x256 matrix.
        let hp = Hyper::default();
        let shape = [256usize, 256];
        let mk = |policy: Option<QuantPolicy>| -> usize {
            let mut params = vec![Param::new(
                "w",
                ParamKind::Weight,
                Tensor::zeros(&shape),
            )];
            let g = Tensor::full(&shape, 0.01);
            match policy {
                None => {
                    let mut o = AdamW::new(hp);
                    o.step(&mut params, &[g], 0.01);
                    o.state_bytes()
                }
                Some(p) => {
                    let mut o = CompressedAdamW::new(hp, p);
                    o.step(&mut params, &[g], 0.01);
                    o.state_bytes()
                }
            }
        };
        let b32 = mk(None);
        let b8 = mk(Some(QuantPolicy::bit8()));
        let b4 = mk(Some(QuantPolicy::bit4()));
        let bf = mk(Some(QuantPolicy::bit4().factored()));
        assert_eq!(b32, 2 * 4 * 65536);
        assert!(b8 < b32 / 3, "8-bit {b8} vs 32-bit {b32}");
        assert!(b4 < b8 * 6 / 10, "4-bit {b4} vs 8-bit {b8}");
        assert!(bf < b4 * 6 / 10, "factored {bf} vs 4-bit {b4}");
    }

    #[test]
    fn small_tensor_rule_keeps_fp32() {
        let hp = Hyper::default();
        let policy = QuantPolicy::bit4(); // min_quant_size = 4096
        let mut opt = CompressedAdamW::new(hp, policy);
        let mut params = vec![Param::new(
            "bias",
            ParamKind::Bias,
            Tensor::zeros(&[100]),
        )];
        let g = Tensor::full(&[100], 0.1);
        opt.step(&mut params, &[g], 0.01);
        // 100 params * 2 states * 4 bytes, untouched by quantization.
        assert_eq!(opt.state_bytes(), 800);
    }

    #[test]
    fn skip_embedding_rule() {
        let hp = Hyper::default();
        let policy = QuantPolicy::bit8(); // skip_embedding = true
        let mut opt = CompressedAdamW::new(hp, policy);
        let mut params = vec![
            Param::new("emb", ParamKind::Embedding, Tensor::zeros(&[100, 64])),
            Param::new("w", ParamKind::Weight, Tensor::zeros(&[100, 64])),
        ];
        let g = Tensor::full(&[100, 64], 0.1);
        opt.step(&mut params, &[g.clone(), g], 0.01);
        // Embedding stays 8*numel bytes; weight compresses to ~2*numel.
        let total = opt.state_bytes();
        let dense = 2 * 4 * 6400;
        assert!(total > dense && total < dense + 2 * 6400 + 1024,
            "total {total}");
    }

    #[test]
    fn zero_point_mapping_destabilizes_sparse_gradients() {
        // The Tab. 1 phenomenon in miniature: with rare large gradients,
        // per-block v is dominated by one outlier; DE's zero point crushes
        // the rest of the block to v=0 and the next update explodes.
        let hp = Hyper {
            weight_decay: 0.0,
            eps: 1e-10,
            ..Hyper::default()
        };
        let mk_policy = |map: MapKind| {
            QuantPolicy::bit4()
                .with_v(Some(Quantizer::new(NormKind::Block(2048), map, 4, false)))
        };
        let run = |map: MapKind| -> f64 {
            let mut policy = mk_policy(map);
            policy.min_quant_size = 0;
            policy.m_quant = None; // isolate the second moment
            let mut opt = CompressedAdamW::new(hp, policy);
            let mut rng = Pcg64::seeded(77);
            let n = 4096;
            let mut params = vec![Param::new(
                "w",
                ParamKind::Weight,
                Tensor::zeros(&[64, 64]),
            )];
            let mut worst_step = 0.0f64;
            for s in 0..60 {
                // Mostly tiny gradients with a huge outlier coordinate.
                let mut g = Tensor::randn(&[64, 64], 1e-4, &mut rng);
                g.data[0] = 5.0;
                let before = params[0].tensor.data.clone();
                opt.step(&mut params, &[g], 1e-3);
                if s > 5 {
                    for k in 1..n {
                        let delta = (params[0].tensor.data[k] - before[k]).abs() as f64;
                        worst_step = worst_step.max(delta);
                    }
                }
            }
            worst_step
        };
        let blowup_de = run(MapKind::DynExp);
        let blowup_lin = run(MapKind::Linear);
        // DE zero-point: v quantized to 0 => update magnitude ~ lr (1e-3)
        // for coordinates with tiny gradients. Linear keeps v bounded away
        // from zero => updates stay proportional to the tiny gradients.
        assert!(
            blowup_de > 5.0 * blowup_lin,
            "DE worst step {blowup_de} vs Linear {blowup_lin}"
        );
    }

    #[test]
    fn quant_metrics_reproduce_zero_point_asymmetry() {
        // The same Tab. 1 phenomenon, now *measured* instead of inferred
        // from the trajectory: under sparse gradients one outlier
        // dominates each block's scale and DE's zero code swallows the
        // rest of the block, while Linear has no zero code at all — its
        // occupancy is zero by construction.
        let hp = Hyper {
            weight_decay: 0.0,
            ..Hyper::default()
        };
        let run = |map: MapKind| -> f64 {
            let mut policy = QuantPolicy::bit4()
                .with_v(Some(Quantizer::new(NormKind::Block(2048), map, 4, false)));
            policy.min_quant_size = 0;
            policy.m_quant = None; // isolate the second moment
            let mut opt = CompressedAdamW::new(hp, policy).with_quant_metrics(true);
            let mut rng = Pcg64::seeded(77);
            let mut params = vec![Param::new(
                "w",
                ParamKind::Weight,
                Tensor::zeros(&[64, 64]),
            )];
            for _ in 0..20 {
                // Mostly tiny gradients with a huge outlier coordinate.
                let mut g = Tensor::randn(&[64, 64], 1e-4, &mut rng);
                g.data[0] = 5.0;
                opt.step(&mut params, &[g], 1e-3);
            }
            let acc = opt.quant_metrics().expect("metrics enabled");
            assert!(!acc.is_empty());
            // Every v element is encoded (and metered) once per step; the
            // accumulator holds the last step.
            assert_eq!(acc.v.code_count, 4096);
            assert_eq!(acc.v.count, 4096);
            assert!(acc.v.rmse().is_finite());
            // And the unified report carries the same numbers.
            let rep = opt.step_report().expect("compressed optimizer reports");
            let q = rep.quant.expect("quant metrics in the report");
            assert!((q.v.zero_code_frac - acc.v.zero_code_frac()).abs() < 1e-12);
            acc.v.zero_code_frac()
        };
        let de = run(MapKind::DynExp);
        let lin = run(MapKind::Linear);
        assert_eq!(lin, 0.0, "Linear has no zero code to fire");
        assert!(
            de > 0.5,
            "DE's zero code should dominate sparse blocks, got {de}"
        );
    }

    #[test]
    fn metered_steps_are_bit_identical_to_unmetered() {
        // Quant metrics ride the reference re-encode arm in phase C,
        // which is pinned bit-identical (codes and RNG draws alike) to
        // the fused arm — so metering must never change the trajectory.
        let hp = Hyper::default();
        let mut policy = QuantPolicy::bit4().stochastic();
        policy.min_quant_size = 0;
        let mut plain = CompressedAdamW::new(hp, policy);
        let mut metered = CompressedAdamW::new(hp, policy).with_quant_metrics(true);
        let (_, wa) = quadratic_run(&mut plain, &[32, 16], 40);
        let (_, wb) = quadratic_run(&mut metered, &[32, 16], 40);
        assert_eq!(wa, wb);
    }
}
