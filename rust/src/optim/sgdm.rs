#![forbid(unsafe_code)]
//! SGD with momentum, in both full-precision and compressed form
//! (paper Alg. 2: the quantized state is the momentum buffer). The
//! compressed variant is the optimizer analyzed by the paper's
//! convergence theorem (App. H).
//!
//! The dense (full-precision momentum) variant steps on the
//! shard-parallel [`crate::engine`] by default — the update is purely
//! elementwise, so the sharded schedule is bit-identical to the
//! sequential loop at every thread count. The quantized variant keeps
//! the sequential path (its whole-tensor quantization draws from one
//! shared RNG stream, which does not shard without changing semantics).
//! [`Sgdm::sequential`] is the off-engine reference for the parity
//! suite.

use super::{Hyper, Optimizer, Param};
use crate::engine::{dense, StepContext, StepEngine};
use crate::quant::{QuantMap, QuantizedTensor, Quantizer};
use crate::tensor::Tensor;
use crate::util::rng::Pcg64;

enum Momentum {
    Full(Tensor),
    Quant(QuantizedTensor),
}

pub struct Sgdm {
    hp: Hyper,
    t: usize,
    quantizer: Option<Quantizer>,
    map: Option<QuantMap>,
    state: Vec<Momentum>,
    rng: Pcg64,
    /// Shard-parallel step engine for the dense-momentum variant; `None`
    /// keeps the sequential loop (the off-engine reference).
    engine: Option<StepEngine>,
    /// Cached step context (plan + metadata), reused across steps.
    ctx: StepContext,
}

impl Sgdm {
    pub fn new(hp: Hyper, quantizer: Option<Quantizer>) -> Sgdm {
        let map = quantizer.as_ref().map(|q| q.build_map());
        Sgdm {
            hp,
            t: 0,
            quantizer,
            map,
            state: Vec::new(),
            rng: Pcg64::seeded(0x5D6D),
            engine: Some(StepEngine::new()),
            ctx: StepContext::new(),
        }
    }

    /// Off-engine reference: the plain sequential per-tensor loop.
    pub fn sequential(hp: Hyper, quantizer: Option<Quantizer>) -> Sgdm {
        Sgdm {
            engine: None,
            ..Sgdm::new(hp, quantizer)
        }
    }

    /// Set the engine worker count (0 = auto). Purely a throughput knob:
    /// the elementwise update is bit-identical at every setting.
    /// Invalidates the cached step context.
    pub fn with_threads(mut self, threads: usize) -> Sgdm {
        self.engine = Some(self.engine.unwrap_or_default().with_threads(threads));
        self.ctx.invalidate();
        self
    }

    /// Set the engine shard size in elements. Invalidates the cached
    /// step context.
    pub fn with_shard_elems(mut self, shard_elems: usize) -> Sgdm {
        self.engine = Some(self.engine.unwrap_or_default().with_shard_elems(shard_elems));
        self.ctx.invalidate();
        self
    }

    /// Decompressed view of the momentum of parameter `idx` (tests /
    /// analysis only).
    pub fn momentum(&self, idx: usize) -> Option<Tensor> {
        Some(match self.state.get(idx)? {
            Momentum::Full(t) => t.clone(),
            Momentum::Quant(q) => match &self.map {
                Some(m) => q.dequantize_with(m),
                None => q.dequantize(),
            },
        })
    }
}

impl Optimizer for Sgdm {
    fn step(&mut self, params: &mut [Param], grads: &[Tensor], lr: f32) {
        assert_eq!(params.len(), grads.len());
        if self.state.is_empty() {
            self.state = params
                .iter()
                .map(|p| Momentum::Full(Tensor::zeros(&p.tensor.shape)))
                .collect();
        }
        self.t += 1;
        let beta = self.hp.beta1;
        if self.quantizer.is_none() {
            if let Some(eng) = &self.engine {
                // Dense momentum: shard-parallel elementwise update.
                let mut ms: Vec<&mut Tensor> = self
                    .state
                    .iter_mut()
                    .map(|s| match s {
                        Momentum::Full(t) => t,
                        Momentum::Quant(_) => unreachable!("dense Sgdm holds full momentum"),
                    })
                    .collect();
                dense::sgdm_step(eng, &mut self.ctx, &self.hp, lr, params, grads, &mut ms);
                return;
            }
        }
        for (i, p) in params.iter_mut().enumerate() {
            // Decompress (Alg. 2 line 3).
            let mut m = match &self.state[i] {
                Momentum::Full(t) => t.clone(),
                Momentum::Quant(q) => q.dequantize_with(self.map.as_ref().unwrap()),
            };
            // m <- beta m + g; w <- w - lr m (Alg. 2 lines 4-5).
            for j in 0..m.data.len() {
                m.data[j] = beta * m.data[j] + grads[i].data[j];
                p.tensor.data[j] -=
                    lr * (m.data[j] + self.hp.weight_decay * p.tensor.data[j]);
            }
            // Compress (Alg. 2 line 6).
            self.state[i] = match (&self.quantizer, &self.map) {
                (Some(q), Some(map)) => {
                    Momentum::Quant(q.quantize_with(&m, map, &mut self.rng))
                }
                _ => Momentum::Full(m),
            };
        }
    }

    fn state_bytes(&self) -> usize {
        self.state
            .iter()
            .map(|m| match m {
                Momentum::Full(t) => t.numel() * 4,
                Momentum::Quant(q) => q.bytes(),
            })
            .sum()
    }

    fn name(&self) -> String {
        match &self.quantizer {
            Some(q) => format!("4-bit SGDM ({})", q.name()),
            None => "32-bit SGDM".to_string(),
        }
    }

    fn t(&self) -> usize {
        self.t
    }

    fn invalidate_step_cache(&mut self) {
        self.ctx.invalidate();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::ParamKind;

    fn run_quadratic(opt: &mut dyn Optimizer, steps: usize, lr: f32) -> f64 {
        let target = Tensor::from_vec(&[8], vec![1., -1., 2., 0.5, -0.25, 0.75, -1.5, 0.1]);
        let mut params = vec![Param::new("w", ParamKind::Weight, Tensor::zeros(&[8]))];
        for _ in 0..steps {
            let g = params[0].tensor.sub(&target);
            opt.step(&mut params, &[g], lr);
        }
        params[0].tensor.sub(&target).sq_l2()
    }

    #[test]
    fn full_precision_converges() {
        let hp = Hyper {
            beta1: 0.9,
            weight_decay: 0.0,
            ..Hyper::default()
        };
        let mut opt = Sgdm::new(hp, None);
        assert!(run_quadratic(&mut opt, 300, 0.02) < 1e-6);
    }

    #[test]
    fn quantized_momentum_still_converges() {
        // Paper Thm. 1: quantized SGDM converges to a noise ball around the
        // optimum; on a clean quadratic it should get very close.
        let hp = Hyper {
            beta1: 0.9,
            weight_decay: 0.0,
            ..Hyper::default()
        };
        let mut opt = Sgdm::new(hp, Some(Quantizer::first_moment_4bit()));
        let residual = run_quadratic(&mut opt, 300, 0.02);
        assert!(residual < 1e-2, "residual {residual}");
    }

    #[test]
    fn quantized_state_is_8x_smaller() {
        let hp = Hyper::default();
        let mut full = Sgdm::new(hp, None);
        let mut quant = Sgdm::new(hp, Some(Quantizer::first_moment_4bit()));
        let mk = || vec![Param::new("w", ParamKind::Weight, Tensor::zeros(&[1024]))];
        let g = Tensor::zeros(&[1024]);
        let mut p1 = mk();
        let mut p2 = mk();
        full.step(&mut p1, &[g.clone()], 0.1);
        quant.step(&mut p2, &[g], 0.1);
        assert_eq!(full.state_bytes(), 4096);
        // 512 code bytes + 8 blocks * 4 scale bytes.
        assert_eq!(quant.state_bytes(), 512 + 32);
    }
}
