#![forbid(unsafe_code)]
//! Full-precision AdamW (Loshchilov & Hutter) — the paper's Eq. 1 with
//! decoupled weight decay. This is both the 32-bit baseline and the inner
//! update `A` shared by every compressed variant (they call
//! [`adamw_update_tensor`] on the decompressed states).
//!
//! By default the baseline steps on the shard-parallel
//! [`crate::engine`] (the update is purely elementwise, so the sharded
//! schedule is bit-identical to the sequential loop at every thread
//! count); [`AdamW::sequential`] keeps the plain per-tensor loop as the
//! off-engine reference for the parity suite.

use super::{Hyper, Optimizer, Param};
use crate::engine::{dense, SchedMode, SchedStats, StepContext, StepEngine};
use crate::obs::report::{FaultCounters, StepReport};
use crate::offload::{pipeline, OffloadConfig, OffloadReport, OffloadState};
use crate::tensor::Tensor;
use crate::util::json::Json;

/// In-place AdamW update of one parameter tensor given its decompressed
/// moments. Returns nothing; `m`/`v` are updated to the new (pre-compress)
/// state. Bias correction uses step counter `t` (1-based).
#[allow(clippy::too_many_arguments)]
pub fn adamw_update_tensor(
    w: &mut Tensor,
    m: &mut Tensor,
    v: &mut Tensor,
    g: &Tensor,
    hp: &Hyper,
    lr: f32,
    t: usize,
) {
    debug_assert_eq!(w.shape, g.shape);
    let b1 = hp.beta1;
    let b2 = hp.beta2;
    let bc1 = 1.0 - b1.powi(t as i32);
    let bc2 = 1.0 - b2.powi(t as i32);
    for i in 0..w.data.len() {
        let gi = g.data[i];
        let mi = b1 * m.data[i] + (1.0 - b1) * gi;
        let vi = b2 * v.data[i] + (1.0 - b2) * gi * gi;
        m.data[i] = mi;
        v.data[i] = vi;
        let mhat = mi / bc1;
        let vhat = vi / bc2;
        let upd = mhat / (vhat.sqrt() + hp.eps) + hp.weight_decay * w.data[i];
        w.data[i] -= lr * upd;
    }
}

/// 32-bit AdamW keeping full-precision `m`, `v` per parameter.
pub struct AdamW {
    hp: Hyper,
    t: usize,
    m: Vec<Tensor>,
    v: Vec<Tensor>,
    /// Shard-parallel step engine; `None` keeps the sequential
    /// per-tensor loop (the off-engine reference).
    engine: Option<StepEngine>,
    /// Cached step context (plan + metadata), reused across steps.
    ctx: StepContext,
    /// When set, the fp32 moments live in the host tier and every step
    /// stages them through the offload pipeline (bit-identical to the
    /// in-memory engine; virtual time lands in the report).
    offload: Option<OffloadState>,
}

impl AdamW {
    pub fn new(hp: Hyper) -> AdamW {
        AdamW {
            hp,
            t: 0,
            m: Vec::new(),
            v: Vec::new(),
            engine: Some(StepEngine::new()),
            ctx: StepContext::new(),
            offload: None,
        }
    }

    /// Route the fp32 optimizer states through the simulated host tier:
    /// steps run on the offload pipeline with a bounded device-scratch
    /// budget (see [`crate::offload::pipeline`]), bit-identical to the
    /// in-memory engine at any thread count and prefetch depth.
    /// Invalidates the cached step context.
    pub fn offloaded(mut self, cfg: OffloadConfig) -> AdamW {
        self.offload = Some(OffloadState::new(cfg));
        self.engine = Some(self.engine.unwrap_or_default());
        self.ctx.invalidate();
        self
    }

    /// Accumulated virtual-time measurements of the offloaded steps
    /// (`None` until [`Self::offloaded`] configures the pipeline).
    pub fn offload_report(&self) -> Option<&OffloadReport> {
        self.offload.as_ref().map(|os| &os.report)
    }

    /// Off-engine reference: the plain sequential per-tensor loop.
    pub fn sequential(hp: Hyper) -> AdamW {
        AdamW {
            engine: None,
            ..AdamW::new(hp)
        }
    }

    /// Set the engine worker count (0 = auto). Purely a throughput knob:
    /// the elementwise update is bit-identical at every setting.
    /// Invalidates the cached step context.
    pub fn with_threads(mut self, threads: usize) -> AdamW {
        self.engine = Some(self.engine.unwrap_or_default().with_threads(threads));
        self.ctx.invalidate();
        self
    }

    /// Set the engine shard size in elements (tests use small values to
    /// force multi-shard plans on small tensors). Invalidates the cached
    /// step context.
    pub fn with_shard_elems(mut self, shard_elems: usize) -> AdamW {
        self.engine = Some(self.engine.unwrap_or_default().with_shard_elems(shard_elems));
        self.ctx.invalidate();
        self
    }

    /// Pin the engine scheduler mode, bypassing the process-level
    /// `LOWBIT_ENGINE_SCHED` resolution. Results are bit-identical in
    /// every mode (the parity suite compares them); this only moves
    /// which worker runs which shard. Invalidates the cached step
    /// context.
    pub fn with_sched(mut self, mode: SchedMode) -> AdamW {
        self.engine = Some(self.engine.unwrap_or_default().with_sched(mode));
        self.ctx.invalidate();
        self
    }

    fn lazy_init(&mut self, params: &[Param]) {
        if self.m.is_empty() {
            self.m = params.iter().map(|p| Tensor::zeros(&p.tensor.shape)).collect();
            self.v = params.iter().map(|p| Tensor::zeros(&p.tensor.shape)).collect();
        }
    }

    /// Peek at the current moments (used by the moment-atlas experiments
    /// that visualize outlier patterns, Figs. 1/2).
    pub fn moments(&self, idx: usize) -> Option<(&Tensor, &Tensor)> {
        Some((self.m.get(idx)?, self.v.get(idx)?))
    }
}

impl Optimizer for AdamW {
    fn step(&mut self, params: &mut [Param], grads: &[Tensor], lr: f32) {
        assert_eq!(params.len(), grads.len());
        self.lazy_init(params);
        self.t += 1;
        if let Some(eng) = &self.engine {
            if let Some(os) = &mut self.offload {
                pipeline::dense_offloaded_step(
                    eng,
                    &mut self.ctx,
                    os,
                    &self.hp,
                    self.t,
                    lr,
                    params,
                    grads,
                    &mut self.m,
                    &mut self.v,
                );
            } else {
                dense::adamw32_step(
                    eng,
                    &mut self.ctx,
                    &self.hp,
                    self.t,
                    lr,
                    params,
                    grads,
                    &mut self.m,
                    &mut self.v,
                );
            }
            return;
        }
        for (i, p) in params.iter_mut().enumerate() {
            adamw_update_tensor(
                &mut p.tensor,
                &mut self.m[i],
                &mut self.v[i],
                &grads[i],
                &self.hp,
                lr,
                self.t,
            );
        }
    }

    fn state_bytes(&self) -> usize {
        self.m
            .iter()
            .chain(self.v.iter())
            .map(|t| t.numel() * 4)
            .sum()
    }

    fn name(&self) -> String {
        "32-bit AdamW".to_string()
    }

    fn t(&self) -> usize {
        self.t
    }

    fn invalidate_step_cache(&mut self) {
        self.ctx.invalidate();
    }

    fn sched_stats(&self) -> Option<SchedStats> {
        self.engine.as_ref().map(|eng| self.ctx.affinity.stats(eng.sched()))
    }

    fn step_report(&self) -> Option<StepReport> {
        // The sequential reference loop has no engine telemetry at all.
        self.engine.as_ref()?;
        let mut r = StepReport {
            step: self.t,
            sched: self.sched_stats(),
            offload: self.offload_report().copied(),
            spans: None,
            quant: None,
            // Dense steps have no rollback transaction; only the link's
            // retry counters apply, and only when offloaded.
            faults: self.offload_report().map(|off| FaultCounters {
                link_fail_retries: off.fail_retries,
                link_corrupt_retries: off.corrupt_retries,
                retry_virtual_seconds: off.retry_seconds,
                rollbacks: 0,
            }),
        };
        #[cfg(feature = "trace")]
        {
            let s = crate::obs::report::SpanSummary::from_rings(&self.ctx.trace_rings());
            if !s.phases.is_empty() || s.dropped > 0 {
                r.spans = Some(s);
            }
        }
        Some(r)
    }

    fn export_trace(&self) -> Option<Json> {
        #[cfg(not(feature = "trace"))]
        {
            None
        }
        #[cfg(feature = "trace")]
        {
            self.engine.as_ref()?;
            Some(crate::obs::trace::chrome_trace(&self.ctx.trace_rings()))
        }
    }

    fn state_bytes_allocated(&self) -> usize {
        self.m
            .iter()
            .chain(self.v.iter())
            .map(|t| t.data.capacity() * 4)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::ParamKind;

    /// Minimize f(w) = 0.5 * ||w - target||^2; gradient = w - target.
    fn quadratic_converges(opt: &mut dyn Optimizer, steps: usize) -> f64 {
        let target = Tensor::from_vec(&[4], vec![1.0, -2.0, 3.0, 0.5]);
        let mut params = vec![Param::new(
            "w",
            ParamKind::Weight,
            Tensor::zeros(&[4]),
        )];
        for _ in 0..steps {
            let g = params[0].tensor.sub(&target);
            opt.step(&mut params, &[g], 0.05);
        }
        params[0].tensor.sub(&target).sq_l2()
    }

    #[test]
    fn converges_on_quadratic() {
        let hp = Hyper {
            weight_decay: 0.0,
            ..Hyper::default()
        };
        let mut opt = AdamW::new(hp);
        let residual = quadratic_converges(&mut opt, 800);
        assert!(residual < 1e-3, "residual {residual}");
    }

    #[test]
    fn weight_decay_shrinks_weights() {
        let hp = Hyper {
            weight_decay: 0.5,
            ..Hyper::default()
        };
        let mut opt = AdamW::new(hp);
        let mut params = vec![Param::new(
            "w",
            ParamKind::Weight,
            Tensor::full(&[8], 1.0),
        )];
        let zero_grad = Tensor::zeros(&[8]);
        for _ in 0..50 {
            let g = zero_grad.clone();
            opt.step(&mut params, &[g], 0.1);
        }
        assert!(params[0].tensor.abs_max() < 1.0);
    }

    #[test]
    fn state_bytes_is_8_per_param() {
        let mut opt = AdamW::new(Hyper::default());
        let mut params = vec![Param::new(
            "w",
            ParamKind::Weight,
            Tensor::zeros(&[100]),
        )];
        let g = Tensor::zeros(&[100]);
        opt.step(&mut params, &[g], 0.1);
        assert_eq!(opt.state_bytes(), 800);
    }

    #[test]
    fn bias_correction_first_step() {
        // After one step with beta1=0.9, mhat should equal g exactly.
        let hp = Hyper {
            weight_decay: 0.0,
            eps: 0.0,
            ..Hyper::default()
        };
        let mut w = Tensor::zeros(&[1]);
        let mut m = Tensor::zeros(&[1]);
        let mut v = Tensor::zeros(&[1]);
        let g = Tensor::from_vec(&[1], vec![0.3]);
        adamw_update_tensor(&mut w, &mut m, &mut v, &g, &hp, 1.0, 1);
        // update = mhat / sqrt(vhat) = g/|g| = 1 (sign of g).
        assert!((w.data[0] + 1.0).abs() < 1e-5, "w={}", w.data[0]);
    }
}
