#![forbid(unsafe_code)]
//! SM3 (Anil et al. '19) — the second sublinear baseline in the paper's
//! Tab. 2. The cover is the experimentally-standard choice of co-dimension
//! 1 slices (rows and columns for matrices); one accumulator per slice.
//!
//! SM3-II per step, for a 2-D parameter:
//!   ν_ij = min(μ_row[i], μ_col[j]) + g²_ij
//!   μ_row[i] = max_j ν_ij ;  μ_col[j] = max_i ν_ij
//!   w -= lr * m, with m the β1-momentum of g / sqrt(ν)
//! 1-D parameters degenerate to full AdaGrad accumulators.
//!
//! By default the step runs on the shard-parallel [`crate::engine`]:
//! the per-element update reads only the previous step's accumulators,
//! and the fresh accumulators are a max-reduction (exact under any
//! grouping), so the sharded schedule is bit-identical to the
//! sequential loop at every thread count. [`Sm3::sequential`] keeps the
//! plain loop as the off-engine reference.

use super::{Hyper, Optimizer, Param};
use crate::engine::{dense, StepContext, StepEngine};
use crate::tensor::Tensor;

/// SM3 accumulator state for one parameter tensor (shared with the
/// engine's dense executor).
pub enum Accum {
    /// Per-axis max accumulators (2-D folded shape).
    Cover {
        rows: usize,
        cols: usize,
        mu_row: Vec<f32>,
        mu_col: Vec<f32>,
    },
    /// Dense AdaGrad accumulator (1-D tensors).
    Dense(Tensor),
}

pub struct Sm3 {
    hp: Hyper,
    t: usize,
    acc: Vec<Accum>,
    m: Vec<Tensor>,
    /// Shard-parallel step engine; `None` keeps the sequential loop
    /// (the off-engine reference).
    engine: Option<StepEngine>,
    /// Cached step context (plan + metadata), reused across steps.
    ctx: StepContext,
}

impl Sm3 {
    pub fn new(hp: Hyper) -> Sm3 {
        Sm3 {
            hp,
            t: 0,
            acc: Vec::new(),
            m: Vec::new(),
            engine: Some(StepEngine::new()),
            ctx: StepContext::new(),
        }
    }

    /// Off-engine reference: the plain sequential per-tensor loop.
    pub fn sequential(hp: Hyper) -> Sm3 {
        Sm3 {
            engine: None,
            ..Sm3::new(hp)
        }
    }

    /// Set the engine worker count (0 = auto). Purely a throughput knob:
    /// results are bit-identical at every setting. Invalidates the
    /// cached step context.
    pub fn with_threads(mut self, threads: usize) -> Sm3 {
        self.engine = Some(self.engine.unwrap_or_default().with_threads(threads));
        self.ctx.invalidate();
        self
    }

    /// Set the engine shard size in elements. Invalidates the cached
    /// step context.
    pub fn with_shard_elems(mut self, shard_elems: usize) -> Sm3 {
        self.engine = Some(self.engine.unwrap_or_default().with_shard_elems(shard_elems));
        self.ctx.invalidate();
        self
    }

    /// Momentum buffer of parameter `idx` (tests / analysis only).
    pub fn momentum(&self, idx: usize) -> Option<&Tensor> {
        self.m.get(idx)
    }

    /// Accumulator state of parameter `idx` as `(row-ish, col)` vectors:
    /// cover accumulators for ≥2-D parameters, `(dense, [])` for 1-D.
    pub fn accumulators(&self, idx: usize) -> Option<(Vec<f32>, Vec<f32>)> {
        Some(match self.acc.get(idx)? {
            Accum::Cover { mu_row, mu_col, .. } => (mu_row.clone(), mu_col.clone()),
            Accum::Dense(t) => (t.data.clone(), Vec::new()),
        })
    }

    fn lazy_init(&mut self, params: &[Param]) {
        if !self.acc.is_empty() {
            return;
        }
        for p in params {
            let acc = if p.tensor.ndim() >= 2 {
                let rows = p.tensor.shape[0];
                let cols = p.tensor.numel() / rows;
                Accum::Cover {
                    rows,
                    cols,
                    mu_row: vec![0.0; rows],
                    mu_col: vec![0.0; cols],
                }
            } else {
                Accum::Dense(Tensor::zeros(&p.tensor.shape))
            };
            self.acc.push(acc);
            self.m.push(Tensor::zeros(&p.tensor.shape));
        }
    }
}

impl Optimizer for Sm3 {
    fn step(&mut self, params: &mut [Param], grads: &[Tensor], lr: f32) {
        assert_eq!(params.len(), grads.len());
        self.lazy_init(params);
        self.t += 1;
        if let Some(eng) = &self.engine {
            dense::sm3_step(
                eng,
                &mut self.ctx,
                &self.hp,
                lr,
                params,
                grads,
                &mut self.acc,
                &mut self.m,
            );
            return;
        }
        let b1 = self.hp.beta1;
        for (i, p) in params.iter_mut().enumerate() {
            let g = &grads[i];
            let m = &mut self.m[i];
            match &mut self.acc[i] {
                Accum::Cover {
                    rows,
                    cols,
                    mu_row,
                    mu_col,
                } => {
                    let (rows, cols) = (*rows, *cols);
                    let mut new_row = vec![0.0f32; rows];
                    let mut new_col = vec![0.0f32; cols];
                    for r in 0..rows {
                        let base = r * cols;
                        let mur = mu_row[r];
                        for c in 0..cols {
                            let gv = g.data[base + c];
                            let nu = mur.min(mu_col[c]) + gv * gv;
                            let upd = gv / (nu.sqrt() + self.hp.eps);
                            let mm = b1 * m.data[base + c] + (1.0 - b1) * upd;
                            m.data[base + c] = mm;
                            p.tensor.data[base + c] -= lr
                                * (mm + self.hp.weight_decay * p.tensor.data[base + c]);
                            if nu > new_row[r] {
                                new_row[r] = nu;
                            }
                            if nu > new_col[c] {
                                new_col[c] = nu;
                            }
                        }
                    }
                    *mu_row = new_row;
                    *mu_col = new_col;
                }
                Accum::Dense(v) => {
                    for k in 0..g.data.len() {
                        let gv = g.data[k];
                        v.data[k] += gv * gv;
                        let upd = gv / (v.data[k].sqrt() + self.hp.eps);
                        let mm = b1 * m.data[k] + (1.0 - b1) * upd;
                        m.data[k] = mm;
                        p.tensor.data[k] -=
                            lr * (mm + self.hp.weight_decay * p.tensor.data[k]);
                    }
                }
            }
        }
    }

    fn state_bytes(&self) -> usize {
        let acc: usize = self
            .acc
            .iter()
            .map(|a| match a {
                Accum::Cover { mu_row, mu_col, .. } => 4 * (mu_row.len() + mu_col.len()),
                Accum::Dense(t) => 4 * t.numel(),
            })
            .sum();
        // Momentum buffers are full precision (as in the paper's beta1>0
        // configuration).
        let m: usize = self.m.iter().map(|t| 4 * t.numel()).sum();
        acc + m
    }

    fn name(&self) -> String {
        "32-bit SM3".to_string()
    }

    fn t(&self) -> usize {
        self.t
    }

    fn invalidate_step_cache(&mut self) {
        self.ctx.invalidate();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::ParamKind;
    use crate::util::rng::Pcg64;

    #[test]
    fn converges_on_quadratic() {
        let hp = Hyper {
            weight_decay: 0.0,
            ..Hyper::default()
        };
        let mut opt = Sm3::new(hp);
        let mut rng = Pcg64::seeded(2);
        let target = Tensor::randn(&[6, 5], 1.0, &mut rng);
        let mut params = vec![Param::new(
            "w",
            ParamKind::Weight,
            Tensor::zeros(&[6, 5]),
        )];
        for _ in 0..500 {
            let g = params[0].tensor.sub(&target);
            opt.step(&mut params, &[g], 0.1);
        }
        let rel = params[0].tensor.sub(&target).sq_l2() / target.sq_l2();
        assert!(rel < 5e-2, "rel {rel}");
    }

    #[test]
    fn accumulators_bound_squared_grad_sum() {
        // SM3 invariant: mu_row[i] >= sum_t g_ij(t)^2 for every j (the
        // accumulator upper-bounds the true per-coordinate sum).
        let hp = Hyper::default();
        let mut opt = Sm3::new(hp);
        let mut rng = Pcg64::seeded(5);
        let mut params = vec![Param::new(
            "w",
            ParamKind::Weight,
            Tensor::zeros(&[4, 3]),
        )];
        let mut true_sum = Tensor::zeros(&[4, 3]);
        for _ in 0..20 {
            let g = Tensor::randn(&[4, 3], 1.0, &mut rng);
            for k in 0..12 {
                true_sum.data[k] += g.data[k] * g.data[k];
            }
            opt.step(&mut params, &[g], 0.01);
        }
        match &opt.acc[0] {
            Accum::Cover { mu_row, mu_col, .. } => {
                for i in 0..4 {
                    for j in 0..3 {
                        let bound = mu_row[i].min(mu_col[j]);
                        assert!(
                            bound + 1e-4 >= true_sum.data[i * 3 + j],
                            "cover bound violated at ({i},{j})"
                        );
                    }
                }
            }
            _ => panic!("expected cover accumulator"),
        }
    }

    #[test]
    fn accumulator_memory_sublinear() {
        let hp = Hyper::default();
        let mut opt = Sm3::new(hp);
        let mut params = vec![Param::new(
            "w",
            ParamKind::Weight,
            Tensor::zeros(&[128, 128]),
        )];
        let g = Tensor::zeros(&[128, 128]);
        opt.step(&mut params, &[g], 0.01);
        // accumulators 2*128 f32; momentum dense.
        assert_eq!(opt.state_bytes(), 4 * 256 + 4 * 128 * 128);
    }
}
