#![forbid(unsafe_code)]
//! Rank-1 (outer-product) factorization of the second moment, following
//! Adafactor (Shazeer & Stern '18). For a non-negative matrix `V`, store
//! row sums `R` and column sums `C`; reconstruct `V̂ = R Cᵀ / sum(R)`.
//! This is the paper's §4.3 sublinear representation, reused by both the
//! Adafactor baseline and the 4-bit Factor optimizer. Tensors with more
//! than 2 dims are folded to 2-D over (dim0, rest); 1-D tensors are not
//! factorizable (callers quantize them instead).

use crate::tensor::Tensor;
use crate::util::stats::neumaier_add;

/// Factored second-moment statistics for one ≥2-D tensor.
#[derive(Clone, Debug)]
pub struct FactoredSecond {
    pub shape: Vec<usize>,
    /// Row statistics, length = shape[0].
    pub row: Vec<f32>,
    /// Column statistics, length = numel / shape[0].
    pub col: Vec<f32>,
}

impl FactoredSecond {
    pub fn zeros(shape: &[usize]) -> FactoredSecond {
        assert!(shape.len() >= 2, "factorization needs >= 2 dims");
        let rows = shape[0];
        let cols: usize = shape[1..].iter().product();
        FactoredSecond {
            shape: shape.to_vec(),
            row: vec![0.0; rows],
            col: vec![0.0; cols],
        }
    }

    pub fn rows(&self) -> usize {
        self.row.len()
    }

    pub fn cols(&self) -> usize {
        self.col.len()
    }

    /// Persistent bytes (f32 row + col stats) — sublinear in numel.
    pub fn bytes(&self) -> usize {
        4 * (self.row.len() + self.col.len())
    }

    /// Bytes actually allocated (stat-vector capacities); `>= bytes()`.
    pub fn allocated_bytes(&self) -> usize {
        4 * (self.row.capacity() + self.col.capacity())
    }

    /// EMA update with the squared gradient:
    /// `R ← β2 R + (1-β2) rowmean(G²+eps)`, likewise for `C`
    /// (Adafactor Alg. 1; we use means so R and C share the scale of V).
    ///
    /// Column sums accumulate with compensated (Kahan–Babuška–Neumaier)
    /// f64 summation. This is the sequential reference the shard-
    /// parallel executor (`engine/dense.rs`) must reproduce: with
    /// compensated partials merged in shard order the engine matches
    /// this loop bit-for-bit at any shard size (row sums are plain f32 —
    /// they never cross a shard boundary, so they match trivially).
    pub fn update(&mut self, g: &Tensor, beta2: f32, eps2: f32) {
        let rows = self.rows();
        let cols = self.cols();
        debug_assert_eq!(g.numel(), rows * cols);
        let mut rsum = vec![0.0f32; rows];
        let mut csum = vec![0.0f64; cols];
        let mut ccomp = vec![0.0f64; cols];
        for i in 0..rows {
            let grow = &g.data[i * cols..(i + 1) * cols];
            let mut acc = 0.0f32;
            for (j, &gv) in grow.iter().enumerate() {
                let sq = gv * gv + eps2;
                acc += sq;
                neumaier_add(&mut csum[j], &mut ccomp[j], sq as f64);
            }
            rsum[i] = acc;
        }
        for i in 0..rows {
            self.row[i] = beta2 * self.row[i] + (1.0 - beta2) * (rsum[i] / cols as f32);
        }
        for j in 0..cols {
            let total = csum[j] + ccomp[j];
            self.col[j] = beta2 * self.col[j] + (1.0 - beta2) * ((total / rows as f64) as f32);
        }
    }

    /// Reconstructed second moment at (i, j):
    /// `v̂_ij = R_i C_j / mean(R)` (means-normalized outer product).
    #[inline]
    pub fn reconstruct_at(&self, i: usize, j: usize, row_mean: f32) -> f32 {
        if row_mean <= 0.0 {
            return 0.0;
        }
        self.row[i] * self.col[j] / row_mean
    }

    pub fn row_mean(&self) -> f32 {
        if self.row.is_empty() {
            0.0
        } else {
            self.row.iter().sum::<f32>() / self.row.len() as f32
        }
    }

    /// Dense reconstruction (for tests / analysis only — the optimizer
    /// streams `reconstruct_at`).
    pub fn reconstruct(&self) -> Tensor {
        let rm = self.row_mean();
        let rows = self.rows();
        let cols = self.cols();
        let mut out = Tensor::zeros(&[rows, cols]);
        for i in 0..rows {
            for j in 0..cols {
                out.data[i * cols + j] = self.reconstruct_at(i, j, rm);
            }
        }
        out.reshape(&self.shape)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn exact_for_rank1_input() {
        // If V = r cᵀ exactly, the factorization reconstructs it exactly
        // (after one update from zero with beta2 -> 0).
        let r = [1.0f32, 2.0, 4.0];
        let c = [0.5f32, 1.0];
        let mut g = Tensor::zeros(&[3, 2]);
        for i in 0..3 {
            for j in 0..2 {
                // g² = r_i c_j  =>  g = sqrt(r_i c_j)
                g.data[i * 2 + j] = (r[i] * c[j]).sqrt();
            }
        }
        let mut f = FactoredSecond::zeros(&[3, 2]);
        f.update(&g, 0.0, 0.0);
        let v = f.reconstruct();
        for i in 0..3 {
            for j in 0..2 {
                let want = r[i] * c[j];
                let got = v.data[i * 2 + j];
                assert!(
                    (want - got).abs() < 1e-5,
                    "({i},{j}): want {want} got {got}"
                );
            }
        }
    }

    #[test]
    fn reconstruction_nonnegative_and_bounded() {
        let mut rng = Pcg64::seeded(10);
        let g = Tensor::randn(&[16, 8], 1.0, &mut rng);
        let mut f = FactoredSecond::zeros(&[16, 8]);
        for _ in 0..5 {
            f.update(&g, 0.9, 1e-30);
        }
        let v = f.reconstruct();
        assert!(v.data.iter().all(|&x| x >= 0.0));
        assert!(v.data.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn bytes_sublinear() {
        let f = FactoredSecond::zeros(&[1024, 1024]);
        assert_eq!(f.bytes(), 4 * 2048); // vs 4 * 1M dense
    }

    #[test]
    fn folds_higher_dims() {
        let f = FactoredSecond::zeros(&[4, 3, 2]);
        assert_eq!(f.rows(), 4);
        assert_eq!(f.cols(), 6);
        let g = Tensor::full(&[4, 3, 2], 2.0);
        let mut f2 = f;
        f2.update(&g, 0.0, 0.0);
        let v = f2.reconstruct();
        assert_eq!(v.shape, vec![4, 3, 2]);
        for &x in &v.data {
            assert!((x - 4.0).abs() < 1e-5);
        }
    }
}
