#![forbid(unsafe_code)]
//! Adafactor (Shazeer & Stern '18) — the sublinear-memory baseline of the
//! paper's Tab. 2. Second moment is factored for ≥2-D parameters and kept
//! dense for 1-D; the first moment is optional (`β1 = 0` is the
//! memory-lean configuration the paper also compares).
//!
//! Following the paper's App. D we drive Adafactor with an *external*
//! learning rate and the same β's as AdamW; Adafactor-specific defaults
//! (update clipping `d=1.0`, `eps2=1e-30`) keep their original values.
//!
//! By default the step runs on the shard-parallel [`crate::engine`]
//! (`dense::adafactor_step`: factored statistics → update-RMS → clipped
//! write, with sequential shard-order reductions in between). Results
//! are bit-identical across thread counts; versus the sequential
//! reference ([`Adafactor::sequential`]) they are bit-identical when
//! every tensor fits in one shard and agree to float rounding otherwise
//! (the row/col and RMS sums associate per shard).

use super::factor::FactoredSecond;
use super::{Hyper, Optimizer, Param};
use crate::engine::{dense, StepContext, StepEngine};
use crate::tensor::Tensor;
use crate::util::stats::neumaier_add;

/// Second-moment state for one parameter tensor (shared with the
/// engine's dense executor).
pub enum Second {
    Factored(FactoredSecond),
    Dense(Tensor),
}

pub struct Adafactor {
    hp: Hyper,
    use_momentum: bool,
    t: usize,
    m: Vec<Option<Tensor>>,
    v: Vec<Second>,
    /// Update clipping threshold d (Adafactor Alg. 4).
    pub clip_threshold: f32,
    /// Small constant added to squared gradients.
    pub eps2: f32,
    /// Shard-parallel step engine; `None` keeps the sequential loop
    /// (the off-engine reference).
    engine: Option<StepEngine>,
    /// Cached step context (plan + metadata + f64 aux slots), reused
    /// across steps.
    ctx: StepContext,
}

impl Adafactor {
    pub fn new(hp: Hyper, use_momentum: bool) -> Adafactor {
        Adafactor {
            hp,
            use_momentum,
            t: 0,
            m: Vec::new(),
            v: Vec::new(),
            clip_threshold: 1.0,
            eps2: 1e-30,
            engine: Some(StepEngine::new()),
            ctx: StepContext::new(),
        }
    }

    /// Off-engine reference: the plain sequential per-tensor loop.
    pub fn sequential(hp: Hyper, use_momentum: bool) -> Adafactor {
        Adafactor {
            engine: None,
            ..Adafactor::new(hp, use_momentum)
        }
    }

    /// Set the engine worker count (0 = auto). Invalidates the cached
    /// step context.
    pub fn with_threads(mut self, threads: usize) -> Adafactor {
        self.engine = Some(self.engine.unwrap_or_default().with_threads(threads));
        self.ctx.invalidate();
        self
    }

    /// Set the engine shard size in elements. Invalidates the cached
    /// step context.
    pub fn with_shard_elems(mut self, shard_elems: usize) -> Adafactor {
        self.engine = Some(self.engine.unwrap_or_default().with_shard_elems(shard_elems));
        self.ctx.invalidate();
        self
    }

    /// Momentum buffer of parameter `idx`, when momentum is enabled
    /// (tests / analysis only).
    pub fn momentum(&self, idx: usize) -> Option<&Tensor> {
        self.m.get(idx)?.as_ref()
    }

    /// Second-moment state of parameter `idx` as `(row-ish, col)`
    /// vectors: factored statistics for ≥2-D parameters, `(dense, [])`
    /// for 1-D.
    pub fn second(&self, idx: usize) -> Option<(Vec<f32>, Vec<f32>)> {
        Some(match self.v.get(idx)? {
            Second::Factored(f) => (f.row.clone(), f.col.clone()),
            Second::Dense(t) => (t.data.clone(), Vec::new()),
        })
    }

    fn lazy_init(&mut self, params: &[Param]) {
        if !self.v.is_empty() {
            return;
        }
        for p in params {
            self.v.push(if p.tensor.ndim() >= 2 {
                Second::Factored(FactoredSecond::zeros(&p.tensor.shape))
            } else {
                Second::Dense(Tensor::zeros(&p.tensor.shape))
            });
            self.m.push(if self.use_momentum {
                Some(Tensor::zeros(&p.tensor.shape))
            } else {
                None
            });
        }
    }
}

impl Optimizer for Adafactor {
    fn step(&mut self, params: &mut [Param], grads: &[Tensor], lr: f32) {
        assert_eq!(params.len(), grads.len());
        self.lazy_init(params);
        self.t += 1;
        if let Some(eng) = &self.engine {
            dense::adafactor_step(
                eng,
                &mut self.ctx,
                &self.hp,
                self.t,
                lr,
                self.clip_threshold,
                self.eps2,
                params,
                grads,
                &mut self.m,
                &mut self.v,
            );
            return;
        }
        // Adafactor's default decaying beta2: 1 - t^{-0.8}.
        let beta2 = 1.0 - (self.t as f32).powf(-0.8);
        for (i, p) in params.iter_mut().enumerate() {
            let g = &grads[i];
            // Preconditioned update u = g / sqrt(v̂).
            let mut u = Tensor::zeros(&g.shape);
            match &mut self.v[i] {
                Second::Factored(f) => {
                    f.update(g, beta2, self.eps2);
                    let rm = f.row_mean();
                    let cols = f.cols();
                    for (k, uv) in u.data.iter_mut().enumerate() {
                        let vhat = f.reconstruct_at(k / cols, k % cols, rm);
                        *uv = g.data[k] / (vhat.sqrt() + self.hp.eps);
                    }
                }
                Second::Dense(v) => {
                    for (k, uv) in u.data.iter_mut().enumerate() {
                        let gv = g.data[k];
                        v.data[k] = beta2 * v.data[k] + (1.0 - beta2) * (gv * gv + self.eps2);
                        *uv = gv / (v.data[k].sqrt() + self.hp.eps);
                    }
                }
            }
            // Update clipping: u /= max(1, RMS(u)/d), with the RMS sum
            // accumulated compensated (Kahan-Babuska-Neumaier) in f64 --
            // the exact summation the engine's per-shard partials merge
            // back into, so on-engine and sequential stay bit-equal.
            let (mut s, mut c) = (0.0f64, 0.0f64);
            for &uv in &u.data {
                neumaier_add(&mut s, &mut c, (uv as f64) * (uv as f64));
            }
            let rms = if u.data.is_empty() {
                0.0f32
            } else {
                (((s + c) / u.data.len() as f64).sqrt()) as f32
            };
            let denom = (rms / self.clip_threshold).max(1.0);
            if denom > 1.0 {
                let inv = 1.0 / denom;
                for uv in u.data.iter_mut() {
                    *uv *= inv;
                }
            }
            // Optional momentum on the clipped update.
            if let Some(m) = &mut self.m[i] {
                let b1 = self.hp.beta1;
                for k in 0..u.data.len() {
                    m.data[k] = b1 * m.data[k] + (1.0 - b1) * u.data[k];
                    u.data[k] = m.data[k];
                }
            }
            for k in 0..p.tensor.data.len() {
                p.tensor.data[k] -=
                    lr * (u.data[k] + self.hp.weight_decay * p.tensor.data[k]);
            }
        }
    }

    fn state_bytes(&self) -> usize {
        let second: usize = self
            .v
            .iter()
            .map(|s| match s {
                Second::Factored(f) => f.bytes(),
                Second::Dense(t) => t.numel() * 4,
            })
            .sum();
        let first: usize = self
            .m
            .iter()
            .map(|m| m.as_ref().map_or(0, |t| t.numel() * 4))
            .sum();
        second + first
    }

    fn name(&self) -> String {
        if self.use_momentum {
            "32-bit Adafactor".to_string()
        } else {
            "32-bit Adafactor (b1=0)".to_string()
        }
    }

    fn t(&self) -> usize {
        self.t
    }

    fn invalidate_step_cache(&mut self) {
        self.ctx.invalidate();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::ParamKind;
    use crate::util::rng::Pcg64;

    fn run_quadratic_2d(opt: &mut dyn Optimizer, steps: usize) -> f64 {
        let mut rng = Pcg64::seeded(8);
        let target = Tensor::randn(&[8, 4], 1.0, &mut rng);
        let mut params = vec![Param::new(
            "w",
            ParamKind::Weight,
            Tensor::zeros(&[8, 4]),
        )];
        for _ in 0..steps {
            let g = params[0].tensor.sub(&target);
            opt.step(&mut params, &[g], 0.05);
        }
        params[0].tensor.sub(&target).sq_l2() / target.sq_l2()
    }

    #[test]
    fn converges_with_and_without_momentum() {
        let hp = Hyper {
            weight_decay: 0.0,
            ..Hyper::default()
        };
        for momentum in [true, false] {
            let mut opt = Adafactor::new(hp, momentum);
            let rel = run_quadratic_2d(&mut opt, 600);
            assert!(rel < 1e-2, "momentum={momentum} rel={rel}");
        }
    }

    #[test]
    fn memory_is_sublinear_for_matrices() {
        let hp = Hyper::default();
        let mut opt = Adafactor::new(hp, false);
        let mut params = vec![Param::new(
            "w",
            ParamKind::Weight,
            Tensor::zeros(&[256, 256]),
        )];
        let g = Tensor::zeros(&[256, 256]);
        opt.step(&mut params, &[g], 0.01);
        // 256 + 256 f32 stats, vs 256*256*4 dense.
        assert_eq!(opt.state_bytes(), 4 * 512);
    }

    #[test]
    fn momentum_costs_full_precision_state() {
        let hp = Hyper::default();
        let mut opt = Adafactor::new(hp, true);
        let mut params = vec![Param::new(
            "w",
            ParamKind::Weight,
            Tensor::zeros(&[64, 64]),
        )];
        let g = Tensor::zeros(&[64, 64]);
        opt.step(&mut params, &[g], 0.01);
        assert_eq!(opt.state_bytes(), 4 * 128 + 4 * 64 * 64);
    }

    #[test]
    fn dense_path_for_1d() {
        let hp = Hyper::default();
        let mut opt = Adafactor::new(hp, false);
        let mut params = vec![Param::new("b", ParamKind::Bias, Tensor::zeros(&[32]))];
        let g = Tensor::full(&[32], 0.1);
        opt.step(&mut params, &[g], 0.01);
        assert_eq!(opt.state_bytes(), 32 * 4);
        assert!(params[0].tensor.data.iter().all(|&x| x < 0.0));
    }
}
