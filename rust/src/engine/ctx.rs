//! Cached step contexts: amortize plan/meta/scratch construction.
//!
//! Every executor's shard plan, tensor metadata, stat-slot buffers and
//! scratch arenas are pure functions of (param shapes, state layouts,
//! shard size) — all fixed after the optimizer's lazy init. Rebuilding
//! them on every `step()` was a fixed per-step allocation tax that
//! dominates in the small-model high-step-rate regime, the same fixed
//! cost the 8-bit optimizers of Dettmers et al. pay once at setup rather
//! than per step. [`StepContext`] owns all of it, keyed by an
//! allocation-free fingerprint check against the live layout
//! ([`TensorMeta::matches`] per tensor): steady-state steps reuse
//! everything, while a shape/layout/shard-size change — or an explicit
//! [`StepContext::invalidate`], wired to the optimizer builder setters —
//! rebuilds from scratch.
//!
//! Ownership map (who touches which field):
//!
//! * every executor — `metas`, `plan`, `slots`, `red`, `arena`;
//! * the compressed executor (`adamw4.rs`) — `scratch` (per-worker
//!   decompress buffers), `globals`/`new_bufs`/`new_scales`/
//!   `m_buf_of`/`v_buf_of` (double-buffered re-encode arenas);
//! * the dense Adafactor executor — `aux`/`red64` (compensated f64
//!   column/RMS partials), `invs` (per-tensor clip factors);
//! * the offload pipeline (`crate::offload::pipeline`) —
//!   `stage_bytes`/`stage_vals`, the bounded device-scratch slots that
//!   double-buffer each task's host-resident state through the link.
//!
//! The quantizer decode/encode LUTs (`crate::quant::kernels`) are *not*
//! context state: they ride inside the optimizer's cached `QuantMap`s,
//! which executors borrow through `StepParams` every step — so the warm
//! step builds no tables and the zero-allocation guarantee below covers
//! the entire kernel layer too.
//!
//! The per-step *borrowed* view vectors (`SharedSlice` lists, per-tensor
//! routes) cannot live in the context — they borrow the step's params and
//! states — so their raw `Vec` capacity is recycled instead through
//! [`VecArena`], which hands out empty `Vec`s of any element type and
//! takes the capacity back when the lease drops. Net effect, pinned by
//! `rust/tests/ctx_cache.rs`: a warmed-up step performs **zero**
//! allocations at one thread.

use super::plan::{build_plan, MetaSpec, Plan, TensorMeta};
use super::Affinity;
use crate::obs::quant::QuantAccum;
#[cfg(feature = "trace")]
use crate::obs::trace::{Ring, DEFAULT_RING_CAP};
use crate::quant::{Quantizer, Scales};
use std::alloc::Layout;
use std::cell::RefCell;
use std::mem::{align_of, size_of, ManuallyDrop};
use std::ops::{Deref, DerefMut};
use std::ptr::NonNull;

/// Per-worker scratch buffers for the compressed executor: decompressed
/// moment slices, grown once to the largest shard and reused across every
/// task (and step) the worker runs.
#[derive(Default)]
pub struct StepScratch {
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    /// Per-worker span ring (`trace` feature): the executors record one
    /// span per task body into the slot's ring. Preallocated by
    /// [`StepContext::ensure_scratch`]; recording never allocates.
    #[cfg(feature = "trace")]
    pub ring: Ring,
    /// Per-worker quant-quality accumulator — `Some` only while the
    /// owning optimizer has quant metrics enabled (runtime-gated; sized
    /// on enable, allocation-free per step thereafter).
    pub quant: Option<QuantAccum>,
}

/// A globally-normalized (rank-1 / per-tensor) quantized state scheduled
/// for the phase-C re-encode, with its double-buffer index.
#[derive(Clone, Copy, Debug)]
pub struct GlobalSlot {
    pub tensor: usize,
    pub is_m: bool,
    pub q: Quantizer,
    pub buf: usize,
}

// ---------------------------------------------------------------------
// Recycled Vec capacity.
// ---------------------------------------------------------------------

/// One free-list of raw buffers for a single element layout.
struct LayoutPool {
    size: usize,
    align: usize,
    /// (allocation, capacity in elements) of returned buffers.
    bufs: Vec<(NonNull<u8>, usize)>,
}

/// Recycled `Vec` capacity for the per-step borrowed view vectors.
///
/// The vectors of `SharedSlice` views and per-tensor routes built each
/// step borrow that step's params and states, so they cannot be cached
/// in [`StepContext`] directly — but their *heap capacity* can.
/// [`VecArena::lease`] hands out an empty `Vec<T>` backed by a recycled
/// buffer of matching layout (size + align) when one is free; dropping
/// the [`ArenaVec`] clears it and returns the capacity to the free list.
/// After one warm-up step every lease is allocation-free.
pub struct VecArena {
    pools: RefCell<Vec<LayoutPool>>,
}

// SAFETY: the arena owns raw, unaliased heap buffers (no element ever
// outlives a lease), so moving it between threads moves plain memory.
// It is deliberately not `Sync`: leases are confined to the coordinating
// thread that owns the optimizer.
unsafe impl Send for VecArena {}

impl Default for VecArena {
    fn default() -> VecArena {
        VecArena::new()
    }
}

impl VecArena {
    pub fn new() -> VecArena {
        VecArena {
            pools: RefCell::new(Vec::new()),
        }
    }

    /// Lease an empty `Vec<T>`, reusing recycled capacity of the same
    /// element layout when available. `T` may freely borrow step-local
    /// data: only raw capacity is recycled, never elements.
    pub fn lease<T>(&self) -> ArenaVec<'_, T> {
        let (size, align) = (size_of::<T>(), align_of::<T>());
        let vec = if size == 0 {
            Vec::new()
        } else {
            let mut pools = self.pools.borrow_mut();
            match pools.iter_mut().find(|p| p.size == size && p.align == align) {
                Some(pool) => match pool.bufs.pop() {
                    // SAFETY: the buffer came from a `Vec<U>` with U's
                    // layout equal to T's (pool key), was left empty, and
                    // has a unique owner (popped off the free list), so
                    // rebuilding a Vec over it is the inverse of the
                    // decomposition in `ArenaVec::drop`.
                    Some((ptr, cap)) => unsafe {
                        Vec::from_raw_parts(ptr.as_ptr() as *mut T, 0, cap)
                    },
                    None => Vec::new(),
                },
                None => {
                    pools.push(LayoutPool {
                        size,
                        align,
                        bufs: Vec::new(),
                    });
                    Vec::new()
                }
            }
        };
        ArenaVec {
            vec: ManuallyDrop::new(vec),
            arena: self,
        }
    }
}

impl Drop for VecArena {
    fn drop(&mut self) {
        let pools = self.pools.get_mut();
        for pool in pools.iter_mut() {
            for (ptr, cap) in pool.bufs.drain(..) {
                // SAFETY: each stashed buffer was allocated by a Vec with
                // array layout (size * cap, align) and has not been freed
                // (the free list is its sole owner).
                unsafe {
                    std::alloc::dealloc(
                        ptr.as_ptr(),
                        Layout::from_size_align_unchecked(pool.size * cap, pool.align),
                    );
                }
            }
        }
    }
}

/// A leased `Vec<T>` whose capacity returns to the [`VecArena`] on drop.
pub struct ArenaVec<'a, T> {
    vec: ManuallyDrop<Vec<T>>,
    arena: &'a VecArena,
}

impl<T> ArenaVec<'_, T> {
    /// Plain slice view — what task closures capture. Unlike the lease
    /// itself (which holds the arena's `RefCell`), a `&[T]` is `Sync`
    /// whenever `T` is, so it can cross into the worker pool.
    pub fn as_slice(&self) -> &[T] {
        &self.vec
    }
}

impl<T> Deref for ArenaVec<'_, T> {
    type Target = Vec<T>;
    fn deref(&self) -> &Vec<T> {
        &self.vec
    }
}

impl<T> DerefMut for ArenaVec<'_, T> {
    fn deref_mut(&mut self) -> &mut Vec<T> {
        &mut self.vec
    }
}

impl<T> Drop for ArenaVec<'_, T> {
    fn drop(&mut self) {
        // Drop the elements now — they may borrow step-local data — and
        // keep only the raw capacity.
        self.vec.clear();
        let cap = self.vec.capacity();
        if size_of::<T>() == 0 || cap == 0 {
            // Nothing on the heap; let the (empty) Vec fall away.
            // SAFETY: dropped exactly once, here.
            unsafe { ManuallyDrop::drop(&mut self.vec) };
            return;
        }
        let ptr = self.vec.as_mut_ptr() as *mut u8;
        // SAFETY: a Vec's data pointer is non-null once capacity > 0.
        let ptr = unsafe { NonNull::new_unchecked(ptr) };
        let (size, align) = (size_of::<T>(), align_of::<T>());
        let mut pools = self.arena.pools.borrow_mut();
        let pool = pools
            .iter_mut()
            .find(|p| p.size == size && p.align == align)
            .expect("lease registered this layout");
        pool.bufs.push((ptr, cap));
        // The Vec's buffer now belongs to the pool: forget the Vec (the
        // ManuallyDrop is simply not dropped) so it is not freed twice.
    }
}

// ---------------------------------------------------------------------
// The cached step context.
// ---------------------------------------------------------------------

/// Cached per-optimizer step state: the tensor metadata, the shard plan,
/// and every reusable buffer the executors need, so a steady-state
/// `step()` is construction- and allocation-free. One context per
/// optimizer; executors take `&mut StepContext` alongside the engine.
///
/// The cache key is (per-tensor layout spec, shard size): [`Self::ensure`]
/// revalidates it each step without allocating and rebuilds on any
/// change. [`Self::invalidate`] forces the next step to rebuild — the
/// optimizer builder setters (`with_threads` / `with_shard_elems`) call
/// it so a reconfigured optimizer never steps on a stale plan.
pub struct StepContext {
    /// Shard size the cached plan was built with.
    shard_elems: usize,
    /// False until the first build and after `invalidate`.
    valid: bool,
    /// Bumped on every rebuild (observable for tests / diagnostics).
    generation: u64,
    pub(crate) metas: Vec<TensorMeta>,
    pub(crate) plan: Plan,
    /// f32 stat-slot buffers (`plan.slot_lens`), zeroed by `begin_step`.
    pub(crate) slots: Vec<Vec<f32>>,
    /// f64 auxiliary slots (same slot-id space as `slots`), sized by the
    /// executor on rebuild; zeroed by `begin_step`. Used by the dense
    /// Adafactor executor for compensated column/RMS partials.
    pub(crate) aux: Vec<Vec<f64>>,
    /// Per-worker scratch for the compressed executor, grown to the
    /// resolved worker count.
    pub(crate) scratch: Vec<StepScratch>,
    /// f32 reduction scratch, sized to the largest stat slot.
    pub(crate) red: Vec<f32>,
    /// f64 reduction scratch, sized by the executor on rebuild.
    pub(crate) red64: Vec<f64>,
    /// Per-tensor update-clip factors (dense Adafactor), length n.
    pub(crate) invs: Vec<Option<f32>>,
    /// Globally-normalized quantized states (compressed executor).
    pub(crate) globals: Vec<GlobalSlot>,
    /// Double-buffered packed code arenas, one per entry in `globals`:
    /// phase C encodes into these, and the commit *swaps* them with the
    /// state's packed buffer instead of reallocating.
    pub(crate) new_bufs: Vec<Vec<u8>>,
    /// Reduced scales per buffer; the commit swaps them with the state's
    /// scales so the previous step's `Scales` storage is recycled.
    pub(crate) new_scales: Vec<Option<Scales>>,
    /// Tensor index -> buffer index (or `usize::MAX`) for m / v.
    pub(crate) m_buf_of: Vec<usize>,
    pub(crate) v_buf_of: Vec<usize>,
    /// Recycled capacity for the per-step borrowed view vectors.
    pub(crate) arena: VecArena,
    /// Offload-pipeline staging slots (the bounded device-scratch
    /// budget): slot `k mod depth` double-buffers task `k`'s state
    /// through the host link. Byte arenas hold staged packed codes, f32
    /// arenas hold staged block scales and f32 states. Grown by
    /// [`Self::ensure_stage`]; contents are fully overwritten by each
    /// stage-in before any read.
    pub(crate) stage_bytes: Vec<Vec<u8>>,
    pub(crate) stage_vals: Vec<Vec<f32>>,
    /// The sticky scheduler's persistent task→worker affinity table
    /// (`super::Affinity`): executors thread it into every
    /// `run_tasks*_in` phase so a warmed-up step re-claims the same
    /// shards on the same workers. Grow-only (the zero-allocation
    /// warm-step pins cover it); reset on rebuild since task ids
    /// renumber with the plan.
    pub(crate) affinity: Affinity,
    /// Coordinator-side span ring (`trace` feature): executors record
    /// one span per phase (and per sequential reduction) here.
    /// Preallocated on rebuild; recording never allocates.
    #[cfg(feature = "trace")]
    pub(crate) trace: Ring,
    /// Merged quant-quality accumulator for the most recent step —
    /// `Some` only when the optimizer has quant metrics enabled (the
    /// compressed executor folds the per-worker accumulators in here,
    /// in worker-slot order, at the end of the step).
    pub(crate) quant: Option<QuantAccum>,
}

impl Default for StepContext {
    fn default() -> StepContext {
        StepContext::new()
    }
}

impl StepContext {
    pub fn new() -> StepContext {
        StepContext {
            shard_elems: 0,
            valid: false,
            generation: 0,
            metas: Vec::new(),
            plan: Plan::default(),
            slots: Vec::new(),
            aux: Vec::new(),
            scratch: Vec::new(),
            red: Vec::new(),
            red64: Vec::new(),
            invs: Vec::new(),
            globals: Vec::new(),
            new_bufs: Vec::new(),
            new_scales: Vec::new(),
            m_buf_of: Vec::new(),
            v_buf_of: Vec::new(),
            arena: VecArena::new(),
            stage_bytes: Vec::new(),
            stage_vals: Vec::new(),
            affinity: Affinity::new(),
            #[cfg(feature = "trace")]
            trace: Ring::default(),
            quant: None,
        }
    }

    /// The span rings, paired with their chrome-trace display thread
    /// ids: 0 is the coordinator, `1 + slot` a pool worker. Export-time
    /// only (allocates the pair list).
    #[cfg(feature = "trace")]
    pub fn trace_rings(&self) -> Vec<(u32, &Ring)> {
        let mut rings = Vec::with_capacity(1 + self.scratch.len());
        rings.push((0u32, &self.trace));
        for (i, s) in self.scratch.iter().enumerate() {
            rings.push((i as u32 + 1, &s.ring));
        }
        rings
    }

    /// Forget all recorded spans (storage is kept).
    #[cfg(feature = "trace")]
    pub fn clear_trace(&mut self) {
        self.trace.clear();
        for s in &mut self.scratch {
            s.ring.clear();
        }
    }

    /// The merged quant-quality accumulator of the most recent step, if
    /// the optimizer has quant metrics enabled.
    pub fn quant_metrics(&self) -> Option<&QuantAccum> {
        self.quant.as_ref()
    }

    /// Force the next `ensure` to rebuild (called by the optimizer
    /// builder setters and the cold-step benchmarks).
    pub fn invalidate(&mut self) {
        self.valid = false;
    }

    /// Rebuild count — bumped once per (re)build, so tests can pin both
    /// "steady state reuses the cache" and "layout changes rebuild it".
    pub fn generation(&self) -> u64 {
        self.generation
    }

    /// Validate the cached plan/metas against the live layout and
    /// rebuild them if anything changed. Returns `true` when a rebuild
    /// happened, so executors can re-derive their own cached extras
    /// (`aux`, `globals`, ...). On the steady-state path this performs
    /// no allocation: each tensor's spec is compared in place.
    pub fn ensure<'s>(
        &mut self,
        shard_elems: usize,
        n: usize,
        spec: impl Fn(usize) -> MetaSpec<'s>,
    ) -> bool {
        if self.valid
            && self.shard_elems == shard_elems
            && self.metas.len() == n
            && (0..n).all(|i| self.metas[i].matches(&spec(i)))
        {
            return false;
        }
        self.metas.clear();
        self.metas.extend((0..n).map(|i| spec(i).to_meta()));
        self.plan = build_plan(&self.metas, shard_elems);
        self.slots = self
            .plan
            .slot_lens
            .iter()
            .map(|&l| vec![0.0f32; l])
            .collect();
        self.red = vec![0.0f32; self.plan.slot_lens.iter().copied().max().unwrap_or(0)];
        // Executor-owned extras are cleared; whoever needs them re-sizes
        // them while handling the `true` return.
        self.aux.clear();
        self.red64.clear();
        self.invs.clear();
        self.invs.resize(n, None);
        self.globals.clear();
        self.new_bufs.clear();
        self.new_scales.clear();
        self.m_buf_of.clear();
        self.v_buf_of.clear();
        // Task ids renumber with the plan, so the learned task→worker
        // map is meaningless now (it could only cost mis-seeded steals).
        self.affinity.reset();
        // Preallocate the coordinator span ring (and resolve the trace
        // epoch) on the cold path so warm-step recording never touches
        // the allocator. Recorded spans survive rebuilds — the ring is a
        // rolling window over recent phases, not per-plan state.
        #[cfg(feature = "trace")]
        {
            self.trace.ensure_cap(DEFAULT_RING_CAP);
            let _ = crate::obs::trace::now();
        }
        self.shard_elems = shard_elems;
        self.valid = true;
        self.generation += 1;
        true
    }

    /// Zero the per-step accumulation buffers (stat slots and f64 aux
    /// slots). Allocation-free.
    pub fn begin_step(&mut self) {
        for s in &mut self.slots {
            s.fill(0.0);
        }
        for a in &mut self.aux {
            a.fill(0.0);
        }
    }

    /// Grow the offload staging slots to `depth` entries of at least
    /// `bytes_len` staged code bytes and `vals_len` staged f32s each —
    /// the pipeline's bounded device-scratch budget. Idempotent and
    /// allocation-free once sized.
    pub(crate) fn ensure_stage(&mut self, depth: usize, bytes_len: usize, vals_len: usize) {
        let depth = depth.max(1);
        if self.stage_bytes.len() < depth {
            self.stage_bytes.resize_with(depth, Vec::new);
        }
        if self.stage_vals.len() < depth {
            self.stage_vals.resize_with(depth, Vec::new);
        }
        for b in &mut self.stage_bytes[..depth] {
            if b.len() < bytes_len {
                b.resize(bytes_len, 0);
            }
        }
        for v in &mut self.stage_vals[..depth] {
            if v.len() < vals_len {
                v.resize(vals_len, 0.0);
            }
        }
    }

    /// Grow the per-worker scratch pool to `workers` entries.
    pub(crate) fn ensure_scratch(&mut self, workers: usize) {
        let want = workers.max(1);
        if self.scratch.len() < want {
            self.scratch.resize_with(want, StepScratch::default);
        }
        // Preallocate every slot's span ring (idempotent, grow-only) so
        // task-span recording on the warm path never allocates.
        #[cfg(feature = "trace")]
        for s in &mut self.scratch[..want] {
            s.ring.ensure_cap(DEFAULT_RING_CAP);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::plan::StateLayout;

    fn spec_of(shapes: &[Vec<usize>]) -> impl Fn(usize) -> MetaSpec<'_> {
        move |i| MetaSpec::elementwise(shapes[i].iter().product(), &shapes[i])
    }

    #[test]
    fn ensure_caches_until_layout_changes() {
        let shapes_a = vec![vec![8usize, 16], vec![100usize]];
        let shapes_b = vec![vec![8usize, 16], vec![101usize]];
        let mut ctx = StepContext::new();
        assert!(ctx.ensure(64, 2, spec_of(&shapes_a)), "first build");
        let g1 = ctx.generation();
        assert!(!ctx.ensure(64, 2, spec_of(&shapes_a)), "steady state");
        assert_eq!(ctx.generation(), g1);
        assert!(ctx.ensure(32, 2, spec_of(&shapes_a)), "shard size change");
        assert!(ctx.ensure(32, 2, spec_of(&shapes_b)), "shape change");
        assert!(ctx.ensure(32, 1, spec_of(&shapes_b)), "tensor count change");
        ctx.invalidate();
        assert!(ctx.ensure(32, 1, spec_of(&shapes_b)), "explicit invalidate");
    }

    #[test]
    fn ensure_detects_layout_not_just_shape() {
        let shape = vec![256usize, 2];
        let mut ctx = StepContext::new();
        let f32_spec = |_: usize| MetaSpec::elementwise(512, &shape);
        let global_spec = |_: usize| MetaSpec {
            numel: 512,
            shape: &shape,
            m: StateLayout::F32,
            v: StateLayout::Global,
            m_stat_len: 0,
            v_stat_len: 258,
        };
        assert!(ctx.ensure(64, 1, f32_spec));
        assert!(ctx.ensure(64, 1, global_spec), "state layout change");
        assert!(!ctx.ensure(64, 1, global_spec));
        // The rebuilt plan carries the global state's slots.
        assert!(!ctx.plan.slot_lens.is_empty());
        assert_eq!(ctx.slots.len(), ctx.plan.slot_lens.len());
        assert_eq!(ctx.red.len(), 258);
    }

    #[test]
    fn arena_recycles_capacity_across_leases() {
        let arena = VecArena::new();
        {
            let mut v = arena.lease::<u64>();
            v.extend(0..100u64);
            assert_eq!(v.len(), 100);
        }
        {
            let v = arena.lease::<u64>();
            assert!(v.capacity() >= 100, "capacity recycled, got {}", v.capacity());
            assert!(v.is_empty());
        }
        // Same layout, different type: i64 shares u64's free list.
        {
            let v = arena.lease::<i64>();
            assert!(v.capacity() >= 100, "layout-equal type reuses capacity");
        }
    }

    #[test]
    fn arena_handles_simultaneous_leases_and_drop_types() {
        let arena = VecArena::new();
        let mut a = arena.lease::<String>();
        let mut b = arena.lease::<String>();
        a.push("left".to_string());
        b.push("right".to_string());
        assert_eq!(a[0], "left");
        drop(a);
        drop(b);
        // Both buffers returned; two fresh leases reuse them.
        let c = arena.lease::<String>();
        let d = arena.lease::<String>();
        assert!(c.capacity() >= 1 && d.capacity() >= 1);
        // Zero-sized elements never hit the pool.
        let mut z = arena.lease::<()>();
        z.push(());
        drop(z);
    }

    #[test]
    fn arena_leases_can_borrow_locals() {
        let arena = VecArena::new();
        let data = vec![1u32, 2, 3];
        {
            let mut v = arena.lease::<&u32>();
            v.extend(data.iter());
            assert_eq!(*v[2], 3);
        }
        assert_eq!(data[0], 1);
    }
}
