#![forbid(unsafe_code)]
//! Dynamic aliasing auditor for the engine's unsafe boundary
//! (`--features audit`).
//!
//! The whole shard-parallel story rests on one contract: every
//! [`SharedSlice::range_mut`](super::SharedSlice::range_mut) view handed
//! out during a phase is disjoint from every other live view of the
//! same allocation, unless the two views belong to the same task or to
//! tasks ordered by the phase's dependency edges. The planner proves
//! this on paper (`rust/tests/plan_props.rs` hammers the invariants);
//! this module checks it *at runtime*, on the real schedules the worker
//! pool produces.
//!
//! # How it works
//!
//! Each [`StepEngine`](super::StepEngine) owns one [`Registry`] — a
//! fixed-capacity, lock-free interval tracker. The engine brackets every
//! `run_tasks{,_with,_dep}` call in a [`phase_scope`]: entering a phase
//! advances the registry's epoch and retires all previously registered
//! intervals; leaving it (after the pool has drained) advances the
//! epoch again. Within a phase, every task body runs under a
//! [`task_scope`] that pins `(registry, task id, epoch)` in a
//! thread-local stack. `range_mut` then reports each materialized view
//! to [`check_range`], which:
//!
//! * panics on any out-of-bounds range (even in release builds);
//! * panics if the calling task's epoch snapshot is stale — the view is
//!   being materialized *after* its phase barrier, i.e. a worker ran
//!   past the pool drain;
//! * publishes the view's absolute byte interval into the registry and
//!   scans all intervals live in the current epoch: an overlap with a
//!   different task that is not an ancestor/descendant along the
//!   phase's dependency edges aborts with a report naming **both**
//!   call sites (via `#[track_caller]`).
//!
//! Liveness is phase-scoped on purpose: a view registered by task A
//! stays "live" until the phase barrier, even if the `&mut` was long
//! dropped. That is exactly the discipline the executors promise (no
//! two tasks of one phase may touch the same range at all), and it
//! makes the check schedule-independent — a racy overlap is caught even
//! when this particular run never interleaved the two accesses.
//!
//! Scopes key on the *task id*, never on the worker that ran it, so the
//! auditor is scheduler-blind: a task claimed from a worker's local
//! queue, taken over the shared atomic queue, or stolen from another
//! worker's block registers identical intervals. The forced-steal
//! schedules in `rust/tests/audit_stress.rs` pin this down — stolen
//! schedules must be as false-alarm-free as natural ones.
//!
//! Accesses from outside any engine phase (unit tests poking
//! `range_mut` directly, single-threaded setup code) are bounds-checked
//! but not tracked: with no task scope there is no disjointness claim
//! to verify.
//!
//! The registry is per-engine, reached through the thread-local task
//! scope, so concurrently running tests (or engines) never see each
//! other's intervals. All of this module is safe code — the auditor
//! watches the unsafe boundary without being part of it.

use std::cell::RefCell;
use std::collections::HashMap;
use std::panic::Location;
use std::sync::atomic::{AtomicU32, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// Max tracked intervals per phase. A phase registers a handful of
/// views per task; the biggest test plans run a few thousand tasks, so
/// this leaves two orders of magnitude of headroom. Overflow panics
/// (never silently drops a check).
pub const SLOT_CAPACITY: usize = 1 << 16;

/// Task-id namespace for per-worker-slot scopes (scratch claimed by
/// worker slot, not by task). Distinct from every queue index.
pub const SLOT_TASK_BASE: u64 = 1 << 62;

/// Sentinel in the dependency table: "no predecessor".
const NO_DEP: usize = usize::MAX;

/// One published interval: the absolute byte range a `range_mut` call
/// materialized, tagged with its task, epoch and interned call site.
/// `epoch` is written last (SeqCst) to publish the record.
struct Slot {
    epoch: AtomicU64,
    lo: AtomicUsize,
    hi: AtomicUsize,
    task: AtomicU64,
    site: AtomicU32,
}

impl Default for Slot {
    fn default() -> Slot {
        Slot {
            epoch: AtomicU64::new(0),
            lo: AtomicUsize::new(0),
            hi: AtomicUsize::new(0),
            task: AtomicU64::new(0),
            site: AtomicU32::new(0),
        }
    }
}

/// Per-engine interval tracker. Epoch 0 is "no phase ever ran" — slots
/// also start at epoch 0, which is why [`phase_scope`] advances the
/// epoch *before* the phase body runs.
pub struct Registry {
    epoch: AtomicU64,
    cursor: AtomicUsize,
    slots: OnceLock<Box<[Slot]>>,
    /// Predecessor edge per task id for the current phase
    /// (`run_tasks_dep`); empty for unordered phases.
    deps: Mutex<Vec<usize>>,
}

impl std::fmt::Debug for Registry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "audit::Registry {{ epoch: {}, live: {} }}",
            self.epoch.load(Ordering::Relaxed),
            self.cursor.load(Ordering::Relaxed)
        )
    }
}

impl Default for Registry {
    fn default() -> Registry {
        Registry::new()
    }
}

impl Registry {
    pub fn new() -> Registry {
        Registry {
            epoch: AtomicU64::new(0),
            cursor: AtomicUsize::new(0),
            slots: OnceLock::new(),
            deps: Mutex::new(Vec::new()),
        }
    }

    /// Retire every live interval and open a fresh epoch.
    fn advance(&self) {
        self.epoch.fetch_add(1, Ordering::SeqCst);
        self.cursor.store(0, Ordering::SeqCst);
    }

    /// Publish one interval and scan for conflicting live ones.
    #[allow(clippy::too_many_arguments)]
    fn register(
        &self,
        abs_lo: usize,
        abs_hi: usize,
        lo: usize,
        hi: usize,
        task: u64,
        task_epoch: u64,
        site: &'static Location<'static>,
    ) {
        let now = self.epoch.load(Ordering::SeqCst);
        if task_epoch != now {
            panic!(
                "[audit] range_mut at {site}: {} materialized a view in phase \
                 epoch {now}, but its task scope was opened in epoch {task_epoch} \
                 — the view outlives its phase barrier (a worker ran past the \
                 pool drain)",
                task_label(task)
            );
        }
        let site_id = intern_site(site);
        let slots = self
            .slots
            .get_or_init(|| (0..SLOT_CAPACITY).map(|_| Slot::default()).collect());
        let idx = self.cursor.fetch_add(1, Ordering::SeqCst);
        assert!(
            idx < slots.len(),
            "[audit] interval tracker overflow: more than {SLOT_CAPACITY} \
             range_mut views in one phase"
        );
        let slot = &slots[idx];
        slot.lo.store(abs_lo, Ordering::Relaxed);
        slot.hi.store(abs_hi, Ordering::Relaxed);
        slot.task.store(task, Ordering::Relaxed);
        slot.site.store(site_id, Ordering::Relaxed);
        // SeqCst publish + SeqCst scan loads: of two concurrent
        // overlapping registrations, whichever epoch store is later in
        // the single total order is guaranteed to observe the other —
        // an overlap can never be missed both ways.
        slot.epoch.store(now, Ordering::SeqCst);

        let live = self.cursor.load(Ordering::SeqCst).min(slots.len());
        let deps = self.deps.lock().unwrap_or_else(|e| e.into_inner());
        for (j, other) in slots.iter().enumerate().take(live) {
            if j == idx || other.epoch.load(Ordering::SeqCst) != now {
                continue;
            }
            let (olo, ohi) = (
                other.lo.load(Ordering::Relaxed),
                other.hi.load(Ordering::Relaxed),
            );
            if ohi <= abs_lo || abs_hi <= olo {
                continue;
            }
            let other_task = other.task.load(Ordering::Relaxed);
            if other_task == task || deps_related(&deps, other_task, task) {
                continue;
            }
            let other_site = site_name(other.site.load(Ordering::Relaxed));
            panic!(
                "[audit] overlapping live range_mut views in phase epoch {now}: \
                 {} at {site} took elements {lo}..{hi} \
                 (bytes {abs_lo:#x}..{abs_hi:#x}), overlapping {} at {other_site} \
                 (bytes {olo:#x}..{ohi:#x}); the tasks are unrelated under the \
                 phase's dependency edges — the planner's disjointness contract \
                 is broken",
                task_label(task),
                task_label(other_task),
            );
        }
    }
}

fn task_label(task: u64) -> String {
    if task >= SLOT_TASK_BASE {
        format!("worker-slot scratch scope {}", task - SLOT_TASK_BASE)
    } else {
        format!("task {task}")
    }
}

/// True when `a` and `b` are ordered by the phase's dependency chain
/// (either is an ancestor of the other). Worker-slot scopes and ids
/// outside the queue have no edges.
fn deps_related(deps: &[usize], a: u64, b: u64) -> bool {
    ancestor_of(deps, a, b) || ancestor_of(deps, b, a)
}

fn ancestor_of(deps: &[usize], anc: u64, desc: u64) -> bool {
    let (anc, mut cur) = (anc as usize, desc as usize);
    if anc >= deps.len() || cur >= deps.len() {
        return false;
    }
    // Each task has at most one predecessor and `deps[i] < i`, so the
    // walk strictly decreases and terminates.
    loop {
        let p = deps[cur];
        if p == NO_DEP {
            return false;
        }
        if p == anc {
            return true;
        }
        cur = p;
    }
}

// ---------------------------------------------------------------------
// Call-site interning. The table is process-global (slot records hold a
// u32, and ids must survive any one registry) with a thread-local cache
// keyed by the `Location`'s address so the warm path takes no lock.

fn global_sites() -> &'static Mutex<Vec<&'static Location<'static>>> {
    static SITES: OnceLock<Mutex<Vec<&'static Location<'static>>>> = OnceLock::new();
    SITES.get_or_init(|| Mutex::new(Vec::new()))
}

thread_local! {
    static SITE_CACHE: RefCell<HashMap<usize, u32>> = RefCell::new(HashMap::new());
}

fn intern_site(site: &'static Location<'static>) -> u32 {
    let key = site as *const Location<'static> as usize;
    SITE_CACHE.with(|cache| {
        if let Some(&id) = cache.borrow().get(&key) {
            return id;
        }
        let mut table = global_sites().lock().unwrap_or_else(|e| e.into_inner());
        let id = match table.iter().position(|s| std::ptr::eq(*s, site)) {
            Some(i) => i as u32,
            None => {
                table.push(site);
                (table.len() - 1) as u32
            }
        };
        drop(table);
        cache.borrow_mut().insert(key, id);
        id
    })
}

fn site_name(id: u32) -> String {
    let table = global_sites().lock().unwrap_or_else(|e| e.into_inner());
    match table.get(id as usize) {
        Some(loc) => loc.to_string(),
        None => format!("<unknown site {id}>"),
    }
}

// ---------------------------------------------------------------------
// Thread-local task context. A stack, because scopes nest: a worker
// holds its slot-scratch scope for the whole broadcast while each
// claimed task pushes its own scope on top.

struct TaskCtx {
    reg: Arc<Registry>,
    task: u64,
    epoch: u64,
}

thread_local! {
    static TASKS: RefCell<Vec<TaskCtx>> = const { RefCell::new(Vec::new()) };
}

/// Open a phase: install this phase's dependency edges (if any), retire
/// all intervals of the previous phase, and hand back a guard that
/// retires this phase's intervals when dropped (i.e. once the pool has
/// drained and the `run_tasks*` call returns).
pub fn phase_scope(reg: &Arc<Registry>, deps: Option<&[Option<usize>]>) -> PhaseGuard {
    {
        let mut d = reg.deps.lock().unwrap_or_else(|e| e.into_inner());
        d.clear();
        if let Some(deps) = deps {
            d.extend(deps.iter().map(|o| o.unwrap_or(NO_DEP)));
        }
    }
    reg.advance();
    PhaseGuard {
        reg: Arc::clone(reg),
    }
}

pub struct PhaseGuard {
    reg: Arc<Registry>,
}

impl Drop for PhaseGuard {
    fn drop(&mut self) {
        self.reg.advance();
        self.reg
            .deps
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .clear();
    }
}

/// Enter a task (or worker-slot) scope on the current thread: every
/// `range_mut` until the guard drops is attributed to `task` in `reg`'s
/// current epoch.
pub fn task_scope(reg: &Arc<Registry>, task: u64) -> TaskGuard {
    let epoch = reg.epoch.load(Ordering::SeqCst);
    TASKS.with(|t| {
        t.borrow_mut().push(TaskCtx {
            reg: Arc::clone(reg),
            task,
            epoch,
        })
    });
    TaskGuard { _priv: () }
}

pub struct TaskGuard {
    _priv: (),
}

impl Drop for TaskGuard {
    fn drop(&mut self) {
        TASKS.with(|t| {
            t.borrow_mut().pop();
        });
    }
}

/// The hook `SharedSlice::range_mut` calls under `--features audit`.
/// `base` is the view's base address, `elem_size` the element size in
/// bytes, `len` the full view length in elements, `lo..hi` the
/// requested element range.
#[track_caller]
pub fn check_range(base: usize, elem_size: usize, len: usize, lo: usize, hi: usize) {
    let site = Location::caller();
    if lo > hi || hi > len {
        panic!("[audit] out-of-bounds range_mut at {site}: {lo}..{hi} of a {len}-element view");
    }
    if lo == hi || elem_size == 0 {
        // Empty byte intervals (including all views of zero-sized
        // types) cannot alias anything.
        return;
    }
    TASKS.with(|t| {
        let stack = t.borrow();
        // No task scope on this thread: an ambient access with no
        // disjointness claim to check. Bounds were verified above.
        let Some(ctx) = stack.last() else { return };
        let abs_lo = base + lo * elem_size;
        let abs_hi = base + hi * elem_size;
        ctx.reg
            .register(abs_lo, abs_hi, lo, hi, ctx.task, ctx.epoch, site);
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ancestor_walks_the_chain() {
        // 0 <- 1 <- 2, 3 isolated.
        let deps = vec![NO_DEP, 0, 1, NO_DEP];
        assert!(ancestor_of(&deps, 0, 2));
        assert!(ancestor_of(&deps, 1, 2));
        assert!(!ancestor_of(&deps, 2, 0));
        assert!(deps_related(&deps, 2, 0));
        assert!(!deps_related(&deps, 3, 2));
        assert!(!deps_related(&deps, SLOT_TASK_BASE, 1));
    }

    #[test]
    fn epoch_retires_intervals() {
        let reg = Arc::new(Registry::new());
        let base = 0x1000usize;
        {
            let _p = phase_scope(&reg, None);
            let _t = task_scope(&reg, 0);
            check_range(base, 4, 16, 0, 16);
        }
        // Same bytes, new phase, different task: no conflict.
        let _p = phase_scope(&reg, None);
        let _t = task_scope(&reg, 1);
        check_range(base, 4, 16, 0, 16);
    }

    #[test]
    fn overlap_within_a_phase_panics() {
        let reg = Arc::new(Registry::new());
        let _p = phase_scope(&reg, None);
        let base = 0x2000usize;
        {
            let _t = task_scope(&reg, 0);
            check_range(base, 4, 16, 0, 8);
        }
        let _t = task_scope(&reg, 1);
        let err = std::panic::catch_unwind(|| check_range(base, 4, 16, 4, 12))
            .expect_err("overlap must panic");
        let msg = err.downcast_ref::<String>().cloned().unwrap_or_default();
        assert!(msg.contains("overlapping live range_mut"), "{msg}");
    }
}
