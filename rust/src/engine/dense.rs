//! Dense-baseline step executors: fp32 AdamW, SGDM, SM3 and Adafactor's
//! elementwise portion, all running on the shard plan of [`super::plan`]
//! through [`StepEngine::run_tasks`].
//!
//! Before this module the dense baselines stepped sequentially while the
//! compressed optimizer enjoyed the shard-parallel engine, which made the
//! Tab. 4 speed comparison apples-to-oranges at every thread count. Here
//! the baselines shard under the *same* determinism contract (see the
//! module docs in `mod.rs`):
//!
//! * planning is thread-blind (identical plans at every worker count);
//! * no RNG is consumed (the dense updates are deterministic), so the
//!   per-shard stream rule is trivially satisfied;
//! * all cross-shard statistics reduce sequentially in shard order.
//!
//! Every executor takes a cached [`StepContext`]: the metadata, plan and
//! stat slots are built once and revalidated allocation-free per step
//! (see `ctx.rs`), so the steady-state step is construction-free.
//!
//! Exactness notes, relied on by `rust/tests/engine_parity.rs`:
//!
//! * **AdamW / SGDM** are purely elementwise — the sharded update is
//!   bit-identical to the sequential per-tensor loop at any thread count
//!   and any shard size.
//! * **SM3**'s cross-shard statistic is a max-reduction, which is exact
//!   under any grouping — also bit-identical to the sequential loop.
//! * **Adafactor** reduces float *sums* (factored column statistics and
//!   the update-RMS for clipping; row sums are shard-local because
//!   shards are row-aligned). Both this executor and the sequential
//!   reference ([`crate::optim::factor::FactoredSecond::update`],
//!   `Adafactor`'s RMS loop) accumulate them with compensated
//!   Kahan–Babuška–Neumaier f64 summation, each shard carrying a
//!   `(sum, comp)` partial merged in shard order. Single-shard tensors
//!   reproduce the sequential element-order sum *exactly* (the merge of
//!   one `(sum, comp)` pair is the identity up to correct rounding);
//!   multi-shard groupings agree with it to the last f64 rounding of a
//!   compensated sum — second-order in the f64 epsilon, far below the
//!   f32 state granularity — so the parity suite checks bitwise
//!   equality at every shard size.

use super::ctx::StepContext;
use super::plan::{MetaSpec, StateLayout};
use super::shared::SharedSlice;
use super::StepEngine;
#[cfg(feature = "trace")]
use crate::obs::trace::{
    now, P_DENSE_ADAMW32, P_DENSE_AF_F, P_DENSE_AF_REDUCE, P_DENSE_AF_RMS, P_DENSE_AF_U,
    P_DENSE_AF_W, P_DENSE_SGDM, P_DENSE_SM3, P_DENSE_SM3_REDUCE, TASK_NONE,
};
use crate::optim::adafactor::Second;
use crate::optim::sm3::Accum;
use crate::optim::{Hyper, Param};
use crate::tensor::Tensor;
use crate::util::stats::neumaier_add;

/// The fp32 AdamW elementwise update for one piece's shard-local slices
/// — shared verbatim by the in-memory executor below and the offload
/// pipeline (which runs it against staged copies of host-resident
/// moments), so both mirror
/// [`crate::optim::adamw::adamw_update_tensor`] bit-exactly per element.
#[inline]
#[allow(clippy::too_many_arguments)]
pub(crate) fn adamw32_piece(
    w: &mut [f32],
    mm: &mut [f32],
    vv: &mut [f32],
    g: &[f32],
    hp: &Hyper,
    bc1: f32,
    bc2: f32,
    lr: f32,
) {
    let b1 = hp.beta1;
    let b2 = hp.beta2;
    let eps = hp.eps;
    let wd = hp.weight_decay;
    for k in 0..g.len() {
        let gi = g[k];
        let mi = b1 * mm[k] + (1.0 - b1) * gi;
        let vi = b2 * vv[k] + (1.0 - b2) * gi * gi;
        mm[k] = mi;
        vv[k] = vi;
        let mhat = mi / bc1;
        let vhat = vi / bc2;
        w[k] -= lr * (mhat / (vhat.sqrt() + eps) + wd * w[k]);
    }
}

/// One fp32 AdamW step on the shard plan. Mirrors
/// [`crate::optim::adamw::adamw_update_tensor`] exactly per element.
#[allow(clippy::too_many_arguments)]
pub fn adamw32_step(
    eng: &StepEngine,
    ctx: &mut StepContext,
    hp: &Hyper,
    t: usize,
    lr: f32,
    params: &mut [Param],
    grads: &[Tensor],
    m: &mut [Tensor],
    v: &mut [Tensor],
) {
    let n = params.len();
    debug_assert_eq!(grads.len(), n);
    debug_assert_eq!(m.len(), n);
    debug_assert_eq!(v.len(), n);
    {
        let params_ref: &[Param] = &*params;
        ctx.ensure(eng.shard_elems(), n, |i| {
            MetaSpec::elementwise(params_ref[i].tensor.numel(), &params_ref[i].tensor.shape)
        });
    }
    if ctx.plan.tasks.is_empty() {
        return;
    }
    let threads = eng.resolve_threads(ctx.plan.tasks.len(), ctx.plan.total_elems);
    // The dense update itself needs no scratch; the per-worker slots
    // carry the trace rings (and stay untouched when tracing is off).
    ctx.ensure_scratch(threads);
    let plan = &ctx.plan;
    let arena = &ctx.arena;
    let bc1 = 1.0 - hp.beta1.powi(t as i32);
    let bc2 = 1.0 - hp.beta2.powi(t as i32);

    let mut ws = arena.lease();
    ws.extend(params.iter_mut().map(|p| SharedSlice::new(p.tensor.data.as_mut_slice())));
    let mut ms = arena.lease();
    ms.extend(m.iter_mut().map(|t| SharedSlice::new(t.data.as_mut_slice())));
    let mut vs = arena.lease();
    vs.extend(v.iter_mut().map(|t| SharedSlice::new(t.data.as_mut_slice())));
    let (ws, ms, vs) = (ws.as_slice(), ms.as_slice(), vs.as_slice());
    let plan_ref = plan;
    #[cfg(feature = "trace")]
    let _t0 = now();
    eng.run_tasks_with_in(
        threads,
        plan.tasks.len(),
        &mut ctx.affinity,
        &mut ctx.scratch[..],
        move |ti, _s| {
            #[cfg(feature = "trace")]
            let _ts = now();
            for piece in &plan_ref.tasks[ti].pieces {
                let (lo, hi) = (piece.lo, piece.hi);
                // SAFETY: pieces partition each tensor disjointly (plan
                // invariant), so this task is the sole writer of [lo, hi).
                let w = unsafe { ws[piece.tensor].range_mut(lo, hi) };
                // SAFETY: same disjoint piece range, moment buffer.
                let mm = unsafe { ms[piece.tensor].range_mut(lo, hi) };
                // SAFETY: same disjoint piece range, second-moment buffer.
                let vv = unsafe { vs[piece.tensor].range_mut(lo, hi) };
                let g = &grads[piece.tensor].data[lo..hi];
                adamw32_piece(w, mm, vv, g, hp, bc1, bc2, lr);
            }
            #[cfg(feature = "trace")]
            _s.ring.record(P_DENSE_ADAMW32, ti as u32, _ts);
        },
    );
    #[cfg(feature = "trace")]
    ctx.trace.record(P_DENSE_ADAMW32, TASK_NONE, _t0);
}

/// One dense-momentum SGDM step on the shard plan (paper Alg. 2 with the
/// momentum kept fp32). Mirrors the sequential loop in
/// [`crate::optim::sgdm::Sgdm`] exactly per element.
pub fn sgdm_step(
    eng: &StepEngine,
    ctx: &mut StepContext,
    hp: &Hyper,
    lr: f32,
    params: &mut [Param],
    grads: &[Tensor],
    m: &mut [&mut Tensor],
) {
    let n = params.len();
    debug_assert_eq!(grads.len(), n);
    debug_assert_eq!(m.len(), n);
    {
        let params_ref: &[Param] = &*params;
        ctx.ensure(eng.shard_elems(), n, |i| {
            MetaSpec::elementwise(params_ref[i].tensor.numel(), &params_ref[i].tensor.shape)
        });
    }
    if ctx.plan.tasks.is_empty() {
        return;
    }
    let plan = &ctx.plan;
    let arena = &ctx.arena;
    let threads = eng.resolve_threads(plan.tasks.len(), plan.total_elems);
    let beta = hp.beta1;
    let wd = hp.weight_decay;

    let mut ws = arena.lease();
    ws.extend(params.iter_mut().map(|p| SharedSlice::new(p.tensor.data.as_mut_slice())));
    let mut ms = arena.lease();
    ms.extend(m.iter_mut().map(|t| SharedSlice::new(t.data.as_mut_slice())));
    let (ws, ms) = (ws.as_slice(), ms.as_slice());
    let plan_ref = plan;
    #[cfg(feature = "trace")]
    let _t0 = now();
    eng.run_tasks_in::<(), _>(threads, plan.tasks.len(), &mut ctx.affinity, move |ti, _| {
        for piece in &plan_ref.tasks[ti].pieces {
            let (lo, hi) = (piece.lo, piece.hi);
            // SAFETY: disjoint shard ranges (plan invariant).
            let w = unsafe { ws[piece.tensor].range_mut(lo, hi) };
            // SAFETY: same disjoint piece range, momentum buffer.
            let mm = unsafe { ms[piece.tensor].range_mut(lo, hi) };
            let g = &grads[piece.tensor].data[lo..hi];
            for k in 0..g.len() {
                let mi = beta * mm[k] + g[k];
                mm[k] = mi;
                w[k] -= lr * (mi + wd * w[k]);
            }
        }
    });
    #[cfg(feature = "trace")]
    ctx.trace.record(P_DENSE_SGDM, TASK_NONE, _t0);
}

/// Per-tensor route of the SM3 executor: cover accumulators (read-only
/// during the parallel phase; per-shard maxima go to stat slots) or a
/// dense AdaGrad accumulator updated in place.
enum Sm3Route<'a> {
    Cover {
        rows: usize,
        cols: usize,
        mu_row: &'a [f32],
        mu_col: &'a [f32],
    },
    Dense(SharedSlice<'a, f32>),
}

/// One SM3 step on the shard plan. The per-element update reads the
/// *old* cover accumulators; fresh accumulators are max-reduced from
/// per-shard partial maxima in shard order after the parallel phase —
/// max is exact under any grouping, so this is bit-identical to the
/// sequential loop in [`crate::optim::sm3::Sm3`].
#[allow(clippy::too_many_arguments)]
pub fn sm3_step(
    eng: &StepEngine,
    ctx: &mut StepContext,
    hp: &Hyper,
    lr: f32,
    params: &mut [Param],
    grads: &[Tensor],
    acc: &mut [Accum],
    m: &mut [Tensor],
) {
    let n = params.len();
    debug_assert_eq!(grads.len(), n);
    debug_assert_eq!(acc.len(), n);
    debug_assert_eq!(m.len(), n);
    {
        let params_ref: &[Param] = &*params;
        let acc_ref: &[Accum] = &*acc;
        ctx.ensure(eng.shard_elems(), n, |i| {
            let p = &params_ref[i].tensor;
            match &acc_ref[i] {
                // Factored layout buys exactly what the cover needs: row
                // (slab) aligned shards + one rows+cols stat slot per piece.
                Accum::Cover { rows, cols, .. } => MetaSpec {
                    numel: p.numel(),
                    shape: &p.shape,
                    m: StateLayout::F32,
                    v: StateLayout::Factored,
                    m_stat_len: 0,
                    v_stat_len: rows + cols,
                },
                Accum::Dense(_) => MetaSpec::elementwise(p.numel(), &p.shape),
            }
        });
    }
    if ctx.plan.tasks.is_empty() {
        return;
    }
    ctx.begin_step();
    let plan = &ctx.plan;
    let arena = &ctx.arena;
    let threads = eng.resolve_threads(plan.tasks.len(), plan.total_elems);
    let b1 = hp.beta1;
    let eps = hp.eps;
    let wd = hp.weight_decay;

    {
        #[cfg(feature = "trace")]
        let _t0 = now();
        let mut routes = arena.lease();
        routes.extend(acc.iter_mut().map(|a| match a {
            Accum::Cover {
                rows,
                cols,
                mu_row,
                mu_col,
            } => Sm3Route::Cover {
                rows: *rows,
                cols: *cols,
                mu_row: mu_row.as_slice(),
                mu_col: mu_col.as_slice(),
            },
            Accum::Dense(t) => Sm3Route::Dense(SharedSlice::new(t.data.as_mut_slice())),
        }));
        let mut ws = arena.lease();
        ws.extend(params.iter_mut().map(|p| SharedSlice::new(p.tensor.data.as_mut_slice())));
        let mut ms = arena.lease();
        ms.extend(m.iter_mut().map(|t| SharedSlice::new(t.data.as_mut_slice())));
        let mut slot_views = arena.lease();
        slot_views.extend(ctx.slots.iter_mut().map(|s| SharedSlice::new(s.as_mut_slice())));
        let (routes, ws, ms) = (routes.as_slice(), ws.as_slice(), ms.as_slice());
        let slot_views = slot_views.as_slice();
        let plan_ref = plan;
        eng.run_tasks_in::<(), _>(threads, plan.tasks.len(), &mut ctx.affinity, move |ti, _| {
            for piece in &plan_ref.tasks[ti].pieces {
                let (lo, hi) = (piece.lo, piece.hi);
                // SAFETY: disjoint shard ranges (plan invariant).
                let w = unsafe { ws[piece.tensor].range_mut(lo, hi) };
                // SAFETY: same disjoint piece range, accumulator buffer.
                let mv = unsafe { ms[piece.tensor].range_mut(lo, hi) };
                let g = &grads[piece.tensor].data[lo..hi];
                match &routes[piece.tensor] {
                    Sm3Route::Cover {
                        rows,
                        cols,
                        mu_row,
                        mu_col,
                    } => {
                        let slot_id = piece.v_slot.expect("cover piece has a stat slot");
                        // SAFETY: one stat slot per piece (plan invariant).
                        let slot = unsafe {
                            slot_views[slot_id].range_mut(0, slot_views[slot_id].len())
                        };
                        let (new_row, new_col) = slot.split_at_mut(*rows);
                        for k in 0..g.len() {
                            let idx = lo + k;
                            let (r, c) = (idx / cols, idx % cols);
                            let gv = g[k];
                            let nu = mu_row[r].min(mu_col[c]) + gv * gv;
                            let upd = gv / (nu.sqrt() + eps);
                            let mi = b1 * mv[k] + (1.0 - b1) * upd;
                            mv[k] = mi;
                            w[k] -= lr * (mi + wd * w[k]);
                            if nu > new_row[r] {
                                new_row[r] = nu;
                            }
                            if nu > new_col[c] {
                                new_col[c] = nu;
                            }
                        }
                    }
                    Sm3Route::Dense(vv) => {
                        // SAFETY: disjoint shard ranges (plan invariant).
                        let vs = unsafe { vv.range_mut(lo, hi) };
                        for k in 0..g.len() {
                            let gv = g[k];
                            vs[k] += gv * gv;
                            let upd = gv / (vs[k].sqrt() + eps);
                            let mi = b1 * mv[k] + (1.0 - b1) * upd;
                            mv[k] = mi;
                            w[k] -= lr * (mi + wd * w[k]);
                        }
                    }
                }
            }
        });
        #[cfg(feature = "trace")]
        ctx.trace.record(P_DENSE_SM3, TASK_NONE, _t0);
    }

    // Sequential max-reduce in shard order into the context's reduction
    // scratch, then committed in place: fresh cover accumulators.
    #[cfg(feature = "trace")]
    let _t0 = now();
    let red = &mut ctx.red;
    for i in 0..n {
        if let Accum::Cover {
            rows,
            mu_row,
            mu_col,
            ..
        } = &mut acc[i]
        {
            let rows = *rows;
            let cols = mu_col.len();
            let maxes = &mut red[..rows + cols];
            maxes.fill(0.0);
            for task in &plan.tasks {
                for p in task.pieces.iter().filter(|p| p.tensor == i) {
                    let s = &ctx.slots[p.v_slot.expect("cover slot")];
                    for (a, b) in maxes.iter_mut().zip(s.iter()) {
                        if *b > *a {
                            *a = *b;
                        }
                    }
                }
            }
            mu_row.copy_from_slice(&maxes[..rows]);
            mu_col.copy_from_slice(&maxes[rows..]);
        }
    }
    #[cfg(feature = "trace")]
    ctx.trace.record(P_DENSE_SM3_REDUCE, TASK_NONE, _t0);
}

/// Per-tensor route of the Adafactor executor: factored second moment
/// (read-only after the phase-F reduce) or a dense 1-D accumulator
/// updated in place during phase U.
enum AfRoute<'a> {
    Factored {
        f: &'a crate::optim::factor::FactoredSecond,
        row_mean: f32,
        cols: usize,
    },
    Dense(SharedSlice<'a, f32>),
}

/// One Adafactor step on the shard plan, as three phases:
///
/// * **F** (factored tensors): per-shard row sums of `g² + eps2` into
///   f32 stat slots (rows are shard-local) and compensated per-column
///   `(sum, comp)` f64 partials into the context's aux slots, reduced
///   in shard order into the factored EMA.
/// * **U**: per shard — update dense accumulators, form the
///   preconditioned update `u = g / (sqrt(v̂) + eps)` and accumulate the
///   per-shard `Σu²` partial as a compensated f64 pair (matching the
///   sequential reference's compensated RMS).
/// * **W**: after the RMS reduce fixes the per-tensor clip factor,
///   re-derive `u` (bit-identical — same inputs, same expression), clip,
///   apply optional momentum and write the weights.
#[allow(clippy::too_many_arguments)]
pub fn adafactor_step(
    eng: &StepEngine,
    ctx: &mut StepContext,
    hp: &Hyper,
    t: usize,
    lr: f32,
    clip_threshold: f32,
    eps2: f32,
    params: &mut [Param],
    grads: &[Tensor],
    m: &mut [Option<Tensor>],
    v: &mut [Second],
) {
    let n = params.len();
    debug_assert_eq!(grads.len(), n);
    debug_assert_eq!(m.len(), n);
    debug_assert_eq!(v.len(), n);
    // Adafactor's default decaying beta2 (as in the sequential path).
    let beta2 = 1.0 - (t as f32).powf(-0.8);
    let b1 = hp.beta1;
    let eps = hp.eps;
    let wd = hp.weight_decay;

    let rebuilt = {
        let params_ref: &[Param] = &*params;
        let v_ref: &[Second] = &*v;
        ctx.ensure(eng.shard_elems(), n, |i| {
            let p = &params_ref[i].tensor;
            // `m: Global` is planner shorthand for "one stat slot per
            // piece" — its aux pair carries the Σu² partial for the RMS
            // clip (the f32 slot itself is zero-length).
            match &v_ref[i] {
                Second::Factored(f) => MetaSpec {
                    numel: p.numel(),
                    shape: &p.shape,
                    m: StateLayout::Global,
                    v: StateLayout::Factored,
                    m_stat_len: 0,
                    v_stat_len: f.rows(),
                },
                Second::Dense(_) => MetaSpec {
                    numel: p.numel(),
                    shape: &p.shape,
                    m: StateLayout::Global,
                    v: StateLayout::F32,
                    m_stat_len: 0,
                    v_stat_len: 0,
                },
            }
        })
    };
    if rebuilt {
        // Size the f64 aux slots: a compensated (sum, comp) pair per
        // piece for the RMS partial, and per-column pair vectors for
        // factored tensors.
        let mut lens = vec![0usize; ctx.plan.slot_lens.len()];
        let mut max_cols2 = 0usize;
        for task in &ctx.plan.tasks {
            for p in &task.pieces {
                if let Some(s) = p.m_slot {
                    lens[s] = 2;
                }
                if let Some(s) = p.v_slot {
                    let meta = &ctx.metas[p.tensor];
                    if meta.v == StateLayout::Factored {
                        let cols = meta.numel / meta.shape[0];
                        lens[s] = 2 * cols;
                        max_cols2 = max_cols2.max(2 * cols);
                    }
                }
            }
        }
        ctx.aux = lens.iter().map(|&l| vec![0.0f64; l]).collect();
        ctx.red64 = vec![0.0f64; max_cols2];
    }
    if ctx.plan.tasks.is_empty() {
        return;
    }
    ctx.begin_step();
    let threads = eng.resolve_threads(ctx.plan.tasks.len(), ctx.plan.total_elems);

    // ---------------- Phase F: factored statistics -------------------
    if ctx.metas.iter().any(|mt| mt.v == StateLayout::Factored) {
        {
            #[cfg(feature = "trace")]
            let _t0 = now();
            let plan = &ctx.plan;
            let metas = &ctx.metas;
            let arena = &ctx.arena;
            let mut slot_views = arena.lease();
            slot_views.extend(ctx.slots.iter_mut().map(|s| SharedSlice::new(s.as_mut_slice())));
            let mut aux_views = arena.lease();
            aux_views.extend(ctx.aux.iter_mut().map(|a| SharedSlice::new(a.as_mut_slice())));
            let slot_views = slot_views.as_slice();
            let aux_views = aux_views.as_slice();
            eng.run_tasks_in::<(), _>(threads, plan.tasks.len(), &mut ctx.affinity, move |ti, _| {
                for piece in &plan.tasks[ti].pieces {
                    let meta = &metas[piece.tensor];
                    if meta.v != StateLayout::Factored {
                        continue;
                    }
                    let rows_total = meta.shape[0];
                    let cols = meta.numel / rows_total;
                    let slot_id = piece.v_slot.expect("factored piece has a stat slot");
                    // SAFETY: each piece owns its stat + aux slots
                    // exclusively (plan assigns one slot per piece).
                    let rsum = unsafe { slot_views[slot_id].range_mut(0, rows_total) };
                    // SAFETY: same exclusive slot id, aux arena.
                    let aux = unsafe { aux_views[slot_id].range_mut(0, 2 * cols) };
                    let (cs, cc) = aux.split_at_mut(cols);
                    let g = &grads[piece.tensor].data[piece.lo..piece.hi];
                    let row0 = piece.lo / cols;
                    for (ri, grow) in g.chunks(cols).enumerate() {
                        let mut acc = 0.0f32;
                        for (j, &gv) in grow.iter().enumerate() {
                            let sq = gv * gv + eps2;
                            acc += sq;
                            neumaier_add(&mut cs[j], &mut cc[j], sq as f64);
                        }
                        rsum[row0 + ri] = acc;
                    }
                }
            });
            #[cfg(feature = "trace")]
            ctx.trace.record(P_DENSE_AF_F, TASK_NONE, _t0);
        }
        // Sequential reduce in shard order + EMA (matches
        // FactoredSecond::update bit-for-bit when a tensor is a single
        // shard; see the module docs for the multi-shard contract).
        #[cfg(feature = "trace")]
        let _t0 = now();
        let plan = &ctx.plan;
        let metas = &ctx.metas;
        let red = &mut ctx.red;
        let red64 = &mut ctx.red64;
        for i in 0..n {
            if metas[i].v != StateLayout::Factored {
                continue;
            }
            let f = match &mut v[i] {
                Second::Factored(f) => f,
                _ => unreachable!("meta says factored"),
            };
            let rows = f.rows();
            let cols = f.cols();
            let rsum = &mut red[..rows];
            rsum.fill(0.0);
            let (cs, cc) = red64[..2 * cols].split_at_mut(cols);
            cs.fill(0.0);
            cc.fill(0.0);
            for task in &plan.tasks {
                for p in task.pieces.iter().filter(|p| p.tensor == i) {
                    let slot = p.v_slot.expect("factored slot");
                    let s = &ctx.slots[slot];
                    for (a, b) in rsum.iter_mut().zip(s.iter()) {
                        *a += *b;
                    }
                    let aux = &ctx.aux[slot];
                    for j in 0..cols {
                        neumaier_add(&mut cs[j], &mut cc[j], aux[j]);
                        neumaier_add(&mut cs[j], &mut cc[j], aux[cols + j]);
                    }
                }
            }
            for (ri, r) in f.row.iter_mut().enumerate() {
                *r = beta2 * *r + (1.0 - beta2) * (rsum[ri] / cols as f32);
            }
            for (cj, c) in f.col.iter_mut().enumerate() {
                let total = cs[cj] + cc[cj];
                *c = beta2 * *c + (1.0 - beta2) * ((total / rows as f64) as f32);
            }
        }
        #[cfg(feature = "trace")]
        ctx.trace.record(P_DENSE_AF_REDUCE, TASK_NONE, _t0);
    }

    {
        let plan = &ctx.plan;
        let metas = &ctx.metas;
        let arena = &ctx.arena;
        let mut ws = arena.lease();
        ws.extend(params.iter_mut().map(|p| SharedSlice::new(p.tensor.data.as_mut_slice())));
        let mut ms = arena.lease();
        ms.extend(
            m.iter_mut()
                .map(|o| o.as_mut().map(|t| SharedSlice::new(t.data.as_mut_slice()))),
        );
        let mut routes = arena.lease();
        routes.extend(v.iter_mut().map(|s| match s {
            Second::Factored(f) => {
                // Phase F has already applied the EMA: this is the
                // post-update row mean, as the update formula needs.
                let row_mean = f.row_mean();
                AfRoute::Factored {
                    cols: f.cols(),
                    row_mean,
                    f: &*f,
                }
            }
            Second::Dense(t) => AfRoute::Dense(SharedSlice::new(t.data.as_mut_slice())),
        }));
        let ws = ws.as_slice();
        let ms = ms.as_slice();
        let routes = routes.as_slice();
        let plan_ref = plan;

        // ------------- Phase U: update v, accumulate Σu² -------------
        {
            #[cfg(feature = "trace")]
            let _t0 = now();
            let mut aux_views = arena.lease();
            aux_views.extend(ctx.aux.iter_mut().map(|a| SharedSlice::new(a.as_mut_slice())));
            let aux_views = aux_views.as_slice();
            eng.run_tasks_in::<(), _>(threads, plan.tasks.len(), &mut ctx.affinity, move |ti, _| {
                for piece in &plan_ref.tasks[ti].pieces {
                    let (lo, hi) = (piece.lo, piece.hi);
                    let g = &grads[piece.tensor].data[lo..hi];
                    let slot_id = piece.m_slot.expect("adafactor piece has an rms slot");
                    let (mut ps, mut pc) = (0.0f64, 0.0f64);
                    match &routes[piece.tensor] {
                        AfRoute::Factored { f, row_mean, cols } => {
                            for (k, &gv) in g.iter().enumerate() {
                                let idx = lo + k;
                                let vhat = f.reconstruct_at(idx / cols, idx % cols, *row_mean);
                                let u = gv / (vhat.sqrt() + eps);
                                neumaier_add(&mut ps, &mut pc, (u as f64) * (u as f64));
                            }
                        }
                        AfRoute::Dense(vv) => {
                            // SAFETY: disjoint shard ranges (plan invariant).
                            let vs = unsafe { vv.range_mut(lo, hi) };
                            for (k, &gv) in g.iter().enumerate() {
                                let vi = beta2 * vs[k] + (1.0 - beta2) * (gv * gv + eps2);
                                vs[k] = vi;
                                let u = gv / (vi.sqrt() + eps);
                                neumaier_add(&mut ps, &mut pc, (u as f64) * (u as f64));
                            }
                        }
                    }
                    // SAFETY: one aux slot per piece (plan invariant).
                    let out = unsafe { aux_views[slot_id].range_mut(0, 2) };
                    out[0] = ps;
                    out[1] = pc;
                }
            });
            #[cfg(feature = "trace")]
            ctx.trace.record(P_DENSE_AF_U, TASK_NONE, _t0);
        }

        // ------- Reduce: per-tensor RMS → clip factor (Alg. 4) -------
        #[cfg(feature = "trace")]
        let _t0 = now();
        let invs = &mut ctx.invs;
        invs.fill(None);
        for (i, inv) in invs.iter_mut().enumerate() {
            let numel = metas[i].numel;
            if numel == 0 {
                continue;
            }
            let (mut s, mut c) = (0.0f64, 0.0f64);
            for task in &plan.tasks {
                for p in task.pieces.iter().filter(|p| p.tensor == i) {
                    let aux = &ctx.aux[p.m_slot.expect("rms slot")];
                    neumaier_add(&mut s, &mut c, aux[0]);
                    neumaier_add(&mut s, &mut c, aux[1]);
                }
            }
            let total = s + c;
            let rms = (total / numel as f64).sqrt() as f32;
            let denom = (rms / clip_threshold).max(1.0);
            if denom > 1.0 {
                *inv = Some(1.0 / denom);
            }
        }
        let invs: &[Option<f32>] = invs;
        #[cfg(feature = "trace")]
        ctx.trace.record(P_DENSE_AF_RMS, TASK_NONE, _t0);

        // ---------- Phase W: clip, momentum, weight update -----------
        #[cfg(feature = "trace")]
        let _t0 = now();
        eng.run_tasks_in::<(), _>(threads, plan.tasks.len(), &mut ctx.affinity, move |ti, _| {
            for piece in &plan_ref.tasks[ti].pieces {
                let (lo, hi) = (piece.lo, piece.hi);
                let g = &grads[piece.tensor].data[lo..hi];
                // SAFETY: disjoint shard ranges (plan invariant).
                let w = unsafe { ws[piece.tensor].range_mut(lo, hi) };
                let mut mm = ms[piece.tensor]
                    .as_ref()
                    // SAFETY: disjoint shard ranges (plan invariant).
                    .map(|s| unsafe { s.range_mut(lo, hi) });
                let inv = invs[piece.tensor];
                let route = &routes[piece.tensor];
                let dense_vs: Option<&[f32]> = match route {
                    // SAFETY: read of this task's own disjoint range; the
                    // phase-U borrow of the same range has ended.
                    AfRoute::Dense(vv) => Some(unsafe { vv.range_mut(lo, hi) }),
                    AfRoute::Factored { .. } => None,
                };
                for (k, &gv) in g.iter().enumerate() {
                    // Re-derive u — same inputs and expression as phase
                    // U, hence bit-identical.
                    let vhat = match route {
                        AfRoute::Factored { f, row_mean, cols } => {
                            let idx = lo + k;
                            f.reconstruct_at(idx / cols, idx % cols, *row_mean)
                        }
                        AfRoute::Dense(_) => dense_vs.expect("dense route has v")[k],
                    };
                    let mut u = gv / (vhat.sqrt() + eps);
                    if let Some(iv) = inv {
                        u *= iv;
                    }
                    if let Some(mslice) = mm.as_mut() {
                        let mi = b1 * mslice[k] + (1.0 - b1) * u;
                        mslice[k] = mi;
                        u = mi;
                    }
                    w[k] -= lr * (u + wd * w[k]);
                }
            }
        });
        #[cfg(feature = "trace")]
        ctx.trace.record(P_DENSE_AF_W, TASK_NONE, _t0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::adamw::adamw_update_tensor;
    use crate::optim::ParamKind;
    use crate::util::rng::Pcg64;

    #[test]
    fn sharded_adamw_matches_reference_loop_bitwise() {
        let hp = Hyper::default();
        let mut rng = Pcg64::seeded(42);
        let shapes: Vec<Vec<usize>> = vec![vec![13, 24], vec![700], vec![5]];
        let mk = |rng: &mut Pcg64| -> (Vec<Param>, Vec<Tensor>, Vec<Tensor>) {
            let params: Vec<Param> = shapes
                .iter()
                .enumerate()
                .map(|(i, s)| {
                    Param::new(&format!("p{i}"), ParamKind::Weight, Tensor::randn(s, 0.5, rng))
                })
                .collect();
            let m = shapes.iter().map(|s| Tensor::randn(s, 0.1, rng)).collect();
            let v = shapes
                .iter()
                .map(|s| {
                    let mut t = Tensor::randn(s, 0.1, rng);
                    for x in t.data.iter_mut() {
                        *x = x.abs();
                    }
                    t
                })
                .collect();
            (params, m, v)
        };
        let (mut p_ref, mut m_ref, mut v_ref) = mk(&mut rng);
        let mut rng2 = Pcg64::seeded(42);
        let (mut p_eng, mut m_eng, mut v_eng) = mk(&mut rng2);
        let mut grng = Pcg64::seeded(7);
        let grads: Vec<Tensor> = shapes.iter().map(|s| Tensor::randn(s, 0.1, &mut grng)).collect();

        for (i, g) in grads.iter().enumerate() {
            adamw_update_tensor(
                &mut p_ref[i].tensor,
                &mut m_ref[i],
                &mut v_ref[i],
                g,
                &hp,
                1e-2,
                3,
            );
        }
        // Small shards + multiple workers: a genuinely parallel schedule.
        let eng = StepEngine::new().with_threads(3).with_shard_elems(64);
        let mut ctx = StepContext::new();
        adamw32_step(
            &eng, &mut ctx, &hp, 3, 1e-2, &mut p_eng, &grads, &mut m_eng, &mut v_eng,
        );

        for i in 0..shapes.len() {
            assert_eq!(p_ref[i].tensor.data, p_eng[i].tensor.data, "w[{i}]");
            assert_eq!(m_ref[i].data, m_eng[i].data, "m[{i}]");
            assert_eq!(v_ref[i].data, v_eng[i].data, "v[{i}]");
        }
    }
}
