//! Dense-baseline step executors: fp32 AdamW, SGDM, SM3 and Adafactor's
//! elementwise portion, all running on the shard plan of [`super::plan`]
//! through [`StepEngine::run_tasks`].
//!
//! Before this module the dense baselines stepped sequentially while the
//! compressed optimizer enjoyed the shard-parallel engine, which made the
//! Tab. 4 speed comparison apples-to-oranges at every thread count. Here
//! the baselines shard under the *same* determinism contract (see the
//! module docs in `mod.rs`):
//!
//! * planning is thread-blind (identical plans at every worker count);
//! * no RNG is consumed (the dense updates are deterministic), so the
//!   per-shard stream rule is trivially satisfied;
//! * all cross-shard statistics reduce sequentially in shard order.
//!
//! Exactness notes, relied on by `rust/tests/engine_parity.rs`:
//!
//! * **AdamW / SGDM** are purely elementwise — the sharded update is
//!   bit-identical to the sequential per-tensor loop at any thread count
//!   and any shard size.
//! * **SM3**'s cross-shard statistic is a max-reduction, which is exact
//!   under any grouping — also bit-identical to the sequential loop.
//! * **Adafactor** reduces float *sums* (factored row/col statistics and
//!   the update-RMS for clipping). Summation order is fixed by the plan,
//!   not the thread count, so results are bit-identical across thread
//!   counts; versus the sequential reference they are bit-identical
//!   exactly when each tensor fits in one shard (one partial per sum)
//!   and agree to float-rounding otherwise.

use super::plan::{build_plan, StateLayout, TensorMeta};
use super::shared::SharedSlice;
use super::StepEngine;
use crate::optim::adafactor::Second;
use crate::optim::sm3::Accum;
use crate::optim::{Hyper, Param};
use crate::tensor::Tensor;

fn elementwise_metas(params: &[Param]) -> Vec<TensorMeta> {
    params
        .iter()
        .map(|p| TensorMeta {
            numel: p.tensor.numel(),
            shape: p.tensor.shape.clone(),
            m: StateLayout::F32,
            v: StateLayout::F32,
            m_stat_len: 0,
            v_stat_len: 0,
        })
        .collect()
}

fn weight_views(params: &mut [Param]) -> Vec<SharedSlice<'_, f32>> {
    params
        .iter_mut()
        .map(|p| SharedSlice::new(p.tensor.data.as_mut_slice()))
        .collect()
}

fn tensor_views(ts: &mut [Tensor]) -> Vec<SharedSlice<'_, f32>> {
    ts.iter_mut()
        .map(|t| SharedSlice::new(t.data.as_mut_slice()))
        .collect()
}

/// One fp32 AdamW step on the shard plan. Mirrors
/// [`crate::optim::adamw::adamw_update_tensor`] exactly per element.
#[allow(clippy::too_many_arguments)]
pub fn adamw32_step(
    eng: &StepEngine,
    hp: &Hyper,
    t: usize,
    lr: f32,
    params: &mut [Param],
    grads: &[Tensor],
    m: &mut [Tensor],
    v: &mut [Tensor],
) {
    let n = params.len();
    debug_assert_eq!(grads.len(), n);
    debug_assert_eq!(m.len(), n);
    debug_assert_eq!(v.len(), n);
    let metas = elementwise_metas(params);
    let plan = build_plan(&metas, eng.shard_elems());
    if plan.tasks.is_empty() {
        return;
    }
    let threads = eng.resolve_threads(plan.tasks.len(), plan.total_elems);
    let b1 = hp.beta1;
    let b2 = hp.beta2;
    let bc1 = 1.0 - b1.powi(t as i32);
    let bc2 = 1.0 - b2.powi(t as i32);
    let eps = hp.eps;
    let wd = hp.weight_decay;

    let ws = weight_views(params);
    let ms = tensor_views(m);
    let vs = tensor_views(v);
    let (ws, ms, vs) = (&ws, &ms, &vs);
    let plan_ref = &plan;
    eng.run_tasks::<(), _>(threads, plan.tasks.len(), move |ti, _| {
        for piece in &plan_ref.tasks[ti].pieces {
            let (lo, hi) = (piece.lo, piece.hi);
            // SAFETY: pieces partition each tensor disjointly (plan
            // invariant), so this task is the sole writer of [lo, hi).
            let w = unsafe { ws[piece.tensor].range_mut(lo, hi) };
            let mm = unsafe { ms[piece.tensor].range_mut(lo, hi) };
            let vv = unsafe { vs[piece.tensor].range_mut(lo, hi) };
            let g = &grads[piece.tensor].data[lo..hi];
            for k in 0..g.len() {
                let gi = g[k];
                let mi = b1 * mm[k] + (1.0 - b1) * gi;
                let vi = b2 * vv[k] + (1.0 - b2) * gi * gi;
                mm[k] = mi;
                vv[k] = vi;
                let mhat = mi / bc1;
                let vhat = vi / bc2;
                w[k] -= lr * (mhat / (vhat.sqrt() + eps) + wd * w[k]);
            }
        }
    });
}

/// One dense-momentum SGDM step on the shard plan (paper Alg. 2 with the
/// momentum kept fp32). Mirrors the sequential loop in
/// [`crate::optim::sgdm::Sgdm`] exactly per element.
pub fn sgdm_step(
    eng: &StepEngine,
    hp: &Hyper,
    lr: f32,
    params: &mut [Param],
    grads: &[Tensor],
    m: &mut [&mut Tensor],
) {
    let n = params.len();
    debug_assert_eq!(grads.len(), n);
    debug_assert_eq!(m.len(), n);
    let metas = elementwise_metas(params);
    let plan = build_plan(&metas, eng.shard_elems());
    if plan.tasks.is_empty() {
        return;
    }
    let threads = eng.resolve_threads(plan.tasks.len(), plan.total_elems);
    let beta = hp.beta1;
    let wd = hp.weight_decay;

    let ws = weight_views(params);
    let ms: Vec<SharedSlice<f32>> = m
        .iter_mut()
        .map(|t| SharedSlice::new(t.data.as_mut_slice()))
        .collect();
    let (ws, ms) = (&ws, &ms);
    let plan_ref = &plan;
    eng.run_tasks::<(), _>(threads, plan.tasks.len(), move |ti, _| {
        for piece in &plan_ref.tasks[ti].pieces {
            let (lo, hi) = (piece.lo, piece.hi);
            // SAFETY: disjoint shard ranges (plan invariant).
            let w = unsafe { ws[piece.tensor].range_mut(lo, hi) };
            let mm = unsafe { ms[piece.tensor].range_mut(lo, hi) };
            let g = &grads[piece.tensor].data[lo..hi];
            for k in 0..g.len() {
                let mi = beta * mm[k] + g[k];
                mm[k] = mi;
                w[k] -= lr * (mi + wd * w[k]);
            }
        }
    });
}

/// Per-tensor route of the SM3 executor: cover accumulators (read-only
/// during the parallel phase; per-shard maxima go to stat slots) or a
/// dense AdaGrad accumulator updated in place.
enum Sm3Route<'a> {
    Cover {
        rows: usize,
        cols: usize,
        mu_row: &'a [f32],
        mu_col: &'a [f32],
    },
    Dense(SharedSlice<'a, f32>),
}

/// One SM3 step on the shard plan. The per-element update reads the
/// *old* cover accumulators; fresh accumulators are max-reduced from
/// per-shard partial maxima in shard order after the parallel phase —
/// max is exact under any grouping, so this is bit-identical to the
/// sequential loop in [`crate::optim::sm3::Sm3`].
#[allow(clippy::too_many_arguments)]
pub fn sm3_step(
    eng: &StepEngine,
    hp: &Hyper,
    lr: f32,
    params: &mut [Param],
    grads: &[Tensor],
    acc: &mut [Accum],
    m: &mut [Tensor],
) {
    let n = params.len();
    debug_assert_eq!(grads.len(), n);
    debug_assert_eq!(acc.len(), n);
    debug_assert_eq!(m.len(), n);
    let metas: Vec<TensorMeta> = (0..n)
        .map(|i| {
            let shape = params[i].tensor.shape.clone();
            let numel = params[i].tensor.numel();
            match &acc[i] {
                // Factored layout buys exactly what the cover needs: row
                // (slab) aligned shards + one rows+cols stat slot per piece.
                Accum::Cover { rows, cols, .. } => TensorMeta {
                    numel,
                    shape,
                    m: StateLayout::F32,
                    v: StateLayout::Factored,
                    m_stat_len: 0,
                    v_stat_len: rows + cols,
                },
                Accum::Dense(_) => TensorMeta {
                    numel,
                    shape,
                    m: StateLayout::F32,
                    v: StateLayout::F32,
                    m_stat_len: 0,
                    v_stat_len: 0,
                },
            }
        })
        .collect();
    let plan = build_plan(&metas, eng.shard_elems());
    if plan.tasks.is_empty() {
        return;
    }
    let threads = eng.resolve_threads(plan.tasks.len(), plan.total_elems);
    let b1 = hp.beta1;
    let eps = hp.eps;
    let wd = hp.weight_decay;
    let mut slots: Vec<Vec<f32>> = plan.slot_lens.iter().map(|&l| vec![0.0f32; l]).collect();

    {
        let routes: Vec<Sm3Route> = acc
            .iter_mut()
            .map(|a| match a {
                Accum::Cover {
                    rows,
                    cols,
                    mu_row,
                    mu_col,
                } => Sm3Route::Cover {
                    rows: *rows,
                    cols: *cols,
                    mu_row: mu_row.as_slice(),
                    mu_col: mu_col.as_slice(),
                },
                Accum::Dense(t) => Sm3Route::Dense(SharedSlice::new(t.data.as_mut_slice())),
            })
            .collect();
        let ws = weight_views(params);
        let ms = tensor_views(m);
        let slot_views: Vec<SharedSlice<f32>> = slots
            .iter_mut()
            .map(|s| SharedSlice::new(s.as_mut_slice()))
            .collect();
        let (routes, ws, ms, slot_views) = (&routes, &ws, &ms, &slot_views);
        let plan_ref = &plan;
        eng.run_tasks::<(), _>(threads, plan.tasks.len(), move |ti, _| {
            for piece in &plan_ref.tasks[ti].pieces {
                let (lo, hi) = (piece.lo, piece.hi);
                // SAFETY: disjoint shard ranges (plan invariant).
                let w = unsafe { ws[piece.tensor].range_mut(lo, hi) };
                let mv = unsafe { ms[piece.tensor].range_mut(lo, hi) };
                let g = &grads[piece.tensor].data[lo..hi];
                match &routes[piece.tensor] {
                    Sm3Route::Cover {
                        rows,
                        cols,
                        mu_row,
                        mu_col,
                    } => {
                        let slot_id = piece.v_slot.expect("cover piece has a stat slot");
                        // SAFETY: one stat slot per piece (plan invariant).
                        let slot = unsafe {
                            slot_views[slot_id].range_mut(0, slot_views[slot_id].len())
                        };
                        let (new_row, new_col) = slot.split_at_mut(*rows);
                        for k in 0..g.len() {
                            let idx = lo + k;
                            let (r, c) = (idx / cols, idx % cols);
                            let gv = g[k];
                            let nu = mu_row[r].min(mu_col[c]) + gv * gv;
                            let upd = gv / (nu.sqrt() + eps);
                            let mi = b1 * mv[k] + (1.0 - b1) * upd;
                            mv[k] = mi;
                            w[k] -= lr * (mi + wd * w[k]);
                            if nu > new_row[r] {
                                new_row[r] = nu;
                            }
                            if nu > new_col[c] {
                                new_col[c] = nu;
                            }
                        }
                    }
                    Sm3Route::Dense(vv) => {
                        // SAFETY: disjoint shard ranges (plan invariant).
                        let vs = unsafe { vv.range_mut(lo, hi) };
                        for k in 0..g.len() {
                            let gv = g[k];
                            vs[k] += gv * gv;
                            let upd = gv / (vs[k].sqrt() + eps);
                            let mi = b1 * mv[k] + (1.0 - b1) * upd;
                            mv[k] = mi;
                            w[k] -= lr * (mi + wd * w[k]);
                        }
                    }
                }
            }
        });
    }

    // Sequential max-reduce in shard order: fresh cover accumulators.
    for i in 0..n {
        if let Accum::Cover {
            rows,
            mu_row,
            mu_col,
            ..
        } = &mut acc[i]
        {
            let rows = *rows;
            let mut new_row = vec![0.0f32; mu_row.len()];
            let mut new_col = vec![0.0f32; mu_col.len()];
            for task in &plan.tasks {
                for p in task.pieces.iter().filter(|p| p.tensor == i) {
                    let s = &slots[p.v_slot.expect("cover slot")];
                    for (a, b) in new_row.iter_mut().zip(&s[..rows]) {
                        if *b > *a {
                            *a = *b;
                        }
                    }
                    for (a, b) in new_col.iter_mut().zip(&s[rows..]) {
                        if *b > *a {
                            *a = *b;
                        }
                    }
                }
            }
            *mu_row = new_row;
            *mu_col = new_col;
        }
    }
}

/// Per-tensor route of the Adafactor executor: factored second moment
/// (read-only after the phase-F reduce) or a dense 1-D accumulator
/// updated in place during phase U.
enum AfRoute<'a> {
    Factored {
        f: &'a crate::optim::factor::FactoredSecond,
        row_mean: f32,
        cols: usize,
    },
    Dense(SharedSlice<'a, f32>),
}

/// One Adafactor step on the shard plan, as three phases:
///
/// * **F** (factored tensors): per-shard row/col partial sums of
///   `g² + eps2`, reduced in shard order into the factored EMA.
/// * **U**: per shard — update dense accumulators, form the
///   preconditioned update `u = g / (sqrt(v̂) + eps)` and accumulate the
///   per-shard `Σu²` partial (f64, matching [`Tensor::rms`]).
/// * **W**: after the RMS reduce fixes the per-tensor clip factor,
///   re-derive `u` (bit-identical — same inputs, same expression), clip,
///   apply optional momentum and write the weights.
#[allow(clippy::too_many_arguments)]
pub fn adafactor_step(
    eng: &StepEngine,
    hp: &Hyper,
    t: usize,
    lr: f32,
    clip_threshold: f32,
    eps2: f32,
    params: &mut [Param],
    grads: &[Tensor],
    m: &mut [Option<Tensor>],
    v: &mut [Second],
) {
    let n = params.len();
    debug_assert_eq!(grads.len(), n);
    debug_assert_eq!(m.len(), n);
    debug_assert_eq!(v.len(), n);
    // Adafactor's default decaying beta2 (as in the sequential path).
    let beta2 = 1.0 - (t as f32).powf(-0.8);
    let b1 = hp.beta1;
    let eps = hp.eps;
    let wd = hp.weight_decay;

    let metas: Vec<TensorMeta> = (0..n)
        .map(|i| {
            let shape = params[i].tensor.shape.clone();
            let numel = params[i].tensor.numel();
            // `m: Global` is planner shorthand for "one stat slot per
            // piece" — it carries the f64 Σu² partial for the RMS clip.
            match &v[i] {
                Second::Factored(f) => TensorMeta {
                    numel,
                    shape,
                    m: StateLayout::Global,
                    v: StateLayout::Factored,
                    m_stat_len: 1,
                    v_stat_len: f.rows() + f.cols(),
                },
                Second::Dense(_) => TensorMeta {
                    numel,
                    shape,
                    m: StateLayout::Global,
                    v: StateLayout::F32,
                    m_stat_len: 1,
                    v_stat_len: 0,
                },
            }
        })
        .collect();
    let plan = build_plan(&metas, eng.shard_elems());
    if plan.tasks.is_empty() {
        return;
    }
    let threads = eng.resolve_threads(plan.tasks.len(), plan.total_elems);
    let mut slots: Vec<Vec<f32>> = plan.slot_lens.iter().map(|&l| vec![0.0f32; l]).collect();
    // Σu² partials, one per piece, indexed by `m_slot` (f64 to mirror
    // the sequential `Tensor::rms` accumulation exactly).
    let mut rms_partials: Vec<f64> = vec![0.0; plan.slot_lens.len()];

    // ---------------- Phase F: factored statistics -------------------
    if metas.iter().any(|mt| mt.v == StateLayout::Factored) {
        {
            let slot_views: Vec<SharedSlice<f32>> = slots
                .iter_mut()
                .map(|s| SharedSlice::new(s.as_mut_slice()))
                .collect();
            let slot_views = &slot_views;
            let plan_ref = &plan;
            let metas_ref = &metas;
            eng.run_tasks::<(), _>(threads, plan.tasks.len(), move |ti, _| {
                for piece in &plan_ref.tasks[ti].pieces {
                    let meta = &metas_ref[piece.tensor];
                    if meta.v != StateLayout::Factored {
                        continue;
                    }
                    let rows_total = meta.shape[0];
                    let cols = meta.numel / rows_total;
                    let slot_id = piece.v_slot.expect("factored piece has a stat slot");
                    // SAFETY: one stat slot per piece (plan invariant).
                    let slot =
                        unsafe { slot_views[slot_id].range_mut(0, plan_ref.slot_lens[slot_id]) };
                    let (rsum, csum) = slot.split_at_mut(rows_total);
                    let g = &grads[piece.tensor].data[piece.lo..piece.hi];
                    let row0 = piece.lo / cols;
                    for (ri, grow) in g.chunks(cols).enumerate() {
                        let mut acc = 0.0f32;
                        for (j, &gv) in grow.iter().enumerate() {
                            let sq = gv * gv + eps2;
                            acc += sq;
                            csum[j] += sq;
                        }
                        rsum[row0 + ri] = acc;
                    }
                }
            });
        }
        // Sequential reduce in shard order + EMA (mirrors
        // FactoredSecond::update).
        for i in 0..n {
            if metas[i].v != StateLayout::Factored {
                continue;
            }
            let f = match &mut v[i] {
                Second::Factored(f) => f,
                _ => unreachable!("meta says factored"),
            };
            let rows = f.rows();
            let cols = f.cols();
            let mut rsum = vec![0.0f32; rows];
            let mut csum = vec![0.0f32; cols];
            for task in &plan.tasks {
                for p in task.pieces.iter().filter(|p| p.tensor == i) {
                    let s = &slots[p.v_slot.expect("factored slot")];
                    for (a, b) in rsum.iter_mut().zip(&s[..rows]) {
                        *a += *b;
                    }
                    for (a, b) in csum.iter_mut().zip(&s[rows..]) {
                        *a += *b;
                    }
                }
            }
            for (ri, r) in f.row.iter_mut().enumerate() {
                *r = beta2 * *r + (1.0 - beta2) * (rsum[ri] / cols as f32);
            }
            for (cj, c) in f.col.iter_mut().enumerate() {
                *c = beta2 * *c + (1.0 - beta2) * (csum[cj] / rows as f32);
            }
        }
    }
    let rowmeans: Vec<f32> = v
        .iter()
        .map(|s| match s {
            Second::Factored(f) => f.row_mean(),
            Second::Dense(_) => 0.0,
        })
        .collect();

    {
        let ws = weight_views(params);
        let ms: Vec<Option<SharedSlice<f32>>> = m
            .iter_mut()
            .map(|o| o.as_mut().map(|t| SharedSlice::new(t.data.as_mut_slice())))
            .collect();
        let routes: Vec<AfRoute> = v
            .iter_mut()
            .enumerate()
            .map(|(i, s)| match s {
                Second::Factored(f) => AfRoute::Factored {
                    cols: f.cols(),
                    row_mean: rowmeans[i],
                    f: &*f,
                },
                Second::Dense(t) => AfRoute::Dense(SharedSlice::new(t.data.as_mut_slice())),
            })
            .collect();
        let (ws, ms, routes) = (&ws, &ms, &routes);
        let plan_ref = &plan;

        // ------------- Phase U: update v, accumulate Σu² -------------
        {
            let rms_view = SharedSlice::new(rms_partials.as_mut_slice());
            let rms_view = &rms_view;
            eng.run_tasks::<(), _>(threads, plan.tasks.len(), move |ti, _| {
                for piece in &plan_ref.tasks[ti].pieces {
                    let (lo, hi) = (piece.lo, piece.hi);
                    let g = &grads[piece.tensor].data[lo..hi];
                    let slot_id = piece.m_slot.expect("adafactor piece has an rms slot");
                    let mut partial = 0.0f64;
                    match &routes[piece.tensor] {
                        AfRoute::Factored { f, row_mean, cols } => {
                            for (k, &gv) in g.iter().enumerate() {
                                let idx = lo + k;
                                let vhat = f.reconstruct_at(idx / cols, idx % cols, *row_mean);
                                let u = gv / (vhat.sqrt() + eps);
                                partial += (u as f64) * (u as f64);
                            }
                        }
                        AfRoute::Dense(vv) => {
                            // SAFETY: disjoint shard ranges (plan invariant).
                            let vs = unsafe { vv.range_mut(lo, hi) };
                            for (k, &gv) in g.iter().enumerate() {
                                let vi = beta2 * vs[k] + (1.0 - beta2) * (gv * gv + eps2);
                                vs[k] = vi;
                                let u = gv / (vi.sqrt() + eps);
                                partial += (u as f64) * (u as f64);
                            }
                        }
                    }
                    // SAFETY: one rms slot per piece (plan invariant).
                    unsafe { rms_view.range_mut(slot_id, slot_id + 1) }[0] = partial;
                }
            });
        }

        // ------- Reduce: per-tensor RMS → clip factor (Alg. 4) -------
        let mut invs: Vec<Option<f32>> = vec![None; n];
        for (i, inv) in invs.iter_mut().enumerate() {
            let numel = metas[i].numel;
            if numel == 0 {
                continue;
            }
            let mut total = 0.0f64;
            for task in &plan.tasks {
                for p in task.pieces.iter().filter(|p| p.tensor == i) {
                    total += rms_partials[p.m_slot.expect("rms slot")];
                }
            }
            let rms = (total / numel as f64).sqrt() as f32;
            let denom = (rms / clip_threshold).max(1.0);
            if denom > 1.0 {
                *inv = Some(1.0 / denom);
            }
        }
        let invs = &invs;

        // ---------- Phase W: clip, momentum, weight update -----------
        eng.run_tasks::<(), _>(threads, plan.tasks.len(), move |ti, _| {
            for piece in &plan_ref.tasks[ti].pieces {
                let (lo, hi) = (piece.lo, piece.hi);
                let g = &grads[piece.tensor].data[lo..hi];
                // SAFETY: disjoint shard ranges (plan invariant).
                let w = unsafe { ws[piece.tensor].range_mut(lo, hi) };
                let mut mm = ms[piece.tensor]
                    .as_ref()
                    // SAFETY: disjoint shard ranges (plan invariant).
                    .map(|s| unsafe { s.range_mut(lo, hi) });
                let inv = invs[piece.tensor];
                let route = &routes[piece.tensor];
                let dense_vs: Option<&[f32]> = match route {
                    // SAFETY: read of this task's own disjoint range; the
                    // phase-U borrow of the same range has ended.
                    AfRoute::Dense(vv) => Some(unsafe { vv.range_mut(lo, hi) }),
                    AfRoute::Factored { .. } => None,
                };
                for (k, &gv) in g.iter().enumerate() {
                    // Re-derive u — same inputs and expression as phase
                    // U, hence bit-identical.
                    let vhat = match route {
                        AfRoute::Factored { f, row_mean, cols } => {
                            let idx = lo + k;
                            f.reconstruct_at(idx / cols, idx % cols, *row_mean)
                        }
                        AfRoute::Dense(_) => dense_vs.expect("dense route has v")[k],
                    };
                    let mut u = gv / (vhat.sqrt() + eps);
                    if let Some(iv) = inv {
                        u *= iv;
                    }
                    if let Some(mslice) = mm.as_mut() {
                        let mi = b1 * mslice[k] + (1.0 - b1) * u;
                        mslice[k] = mi;
                        u = mi;
                    }
                    w[k] -= lr * (u + wd * w[k]);
                }
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::adamw::adamw_update_tensor;
    use crate::optim::ParamKind;
    use crate::util::rng::Pcg64;

    #[test]
    fn sharded_adamw_matches_reference_loop_bitwise() {
        let hp = Hyper::default();
        let mut rng = Pcg64::seeded(42);
        let shapes: Vec<Vec<usize>> = vec![vec![13, 24], vec![700], vec![5]];
        let mk = |rng: &mut Pcg64| -> (Vec<Param>, Vec<Tensor>, Vec<Tensor>) {
            let params: Vec<Param> = shapes
                .iter()
                .enumerate()
                .map(|(i, s)| {
                    Param::new(&format!("p{i}"), ParamKind::Weight, Tensor::randn(s, 0.5, rng))
                })
                .collect();
            let m = shapes.iter().map(|s| Tensor::randn(s, 0.1, rng)).collect();
            let v = shapes
                .iter()
                .map(|s| {
                    let mut t = Tensor::randn(s, 0.1, rng);
                    for x in t.data.iter_mut() {
                        *x = x.abs();
                    }
                    t
                })
                .collect();
            (params, m, v)
        };
        let (mut p_ref, mut m_ref, mut v_ref) = mk(&mut rng);
        let mut rng2 = Pcg64::seeded(42);
        let (mut p_eng, mut m_eng, mut v_eng) = mk(&mut rng2);
        let mut grng = Pcg64::seeded(7);
        let grads: Vec<Tensor> = shapes.iter().map(|s| Tensor::randn(s, 0.1, &mut grng)).collect();

        for (i, g) in grads.iter().enumerate() {
            adamw_update_tensor(
                &mut p_ref[i].tensor,
                &mut m_ref[i],
                &mut v_ref[i],
                g,
                &hp,
                1e-2,
                3,
            );
        }
        // Small shards + multiple workers: a genuinely parallel schedule.
        let eng = StepEngine::new().with_threads(3).with_shard_elems(64);
        adamw32_step(&eng, &hp, 3, 1e-2, &mut p_eng, &grads, &mut m_eng, &mut v_eng);

        for i in 0..shapes.len() {
            assert_eq!(p_ref[i].tensor.data, p_eng[i].tensor.data, "w[{i}]");
            assert_eq!(m_ref[i].data, m_eng[i].data, "m[{i}]");
            assert_eq!(v_ref[i].data, v_eng[i].data, "v[{i}]");
        }
    }
}
