//! The shard-parallel optimizer step engine.
//!
//! The paper's headline speed numbers (Tab. 4 "(fused)" rows) exist
//! because a naive decompress → AdamW → recompress loop makes quantized
//! optimizers *slower* than fp32 ones. On CPU, the analogue of the fused
//! GPU kernel is this engine: the parameter set is partitioned into
//! block-aligned shards ([`plan`]) and each step runs
//! dequantize → update → requantize shard-parallel on a persistent
//! worker pool ([`pool`]), with shard-local scratch buffers instead of
//! per-tensor allocations ([`adamw4`]).
//!
//! The dense baselines run on the same substrate: [`dense`] executes
//! fp32 AdamW, SGDM, SM3 and Adafactor's elementwise portion over the
//! identical plan/slot machinery, so the Tab. 4 speed comparison is
//! apples-to-apples at every thread count.
//!
//! # Determinism contract
//!
//! The engine is **bit-identical at every thread count**, including
//! stochastic rounding. Three rules make that hold:
//!
//! 1. **Planning is thread-blind.** The shard decomposition is a pure
//!    function of tensor shapes, state layouts and the configured shard
//!    size (`plan::build_plan`); worker count only decides who executes
//!    a task, never what the task is.
//! 2. **One RNG stream per shard.** Task `i` of step `t` draws from
//!    `Pcg64::new(step_seed(t), stream_id)` — the splittable streams from
//!    [`crate::util::rng`] — so stochastic rounding consumes the same
//!    random sequence no matter which worker runs the task or in which
//!    order tasks complete. Phase C re-encode streams live in a disjoint
//!    stream-id range from phase A/F streams.
//! 3. **Reductions run in shard order.** Cross-shard statistics (rank-1
//!    scale maxima, factored row/col sums) are combined sequentially in
//!    ascending shard order between phases, so float rounding does not
//!    depend on completion order.
//!
//! Under these rules "sequential" is just the 1-thread schedule of the
//! same plan, which is what the parity suite
//! (`rust/tests/engine_parity.rs`) checks at thread counts 1, 2 and 7.
//!
//! # Phases
//!
//! A step of the compressed optimizer runs up to three parallel phases
//! with cheap sequential reductions between them:
//!
//! * **F** (factored tensors only): accumulate per-shard row/col partial
//!   sums of `g²`; reduce into the factored EMA state.
//! * **A**: per shard — decompress states, run the exact AdamW update,
//!   requantize block-local states in place, and accumulate scale
//!   statistics for globally-normalized states (rank-1 / per-tensor).
//! * **C** (globally-normalized states only): after the scale reduction,
//!   re-derive the updated state values and encode them against the new
//!   global scales into fresh packed buffers.
//!
//! The dense executors in [`dense`] follow the same shape with their own
//! phase sets: fp32 AdamW and SGDM are a single update phase; SM3 runs
//! update + per-shard accumulator maxima with a sequential max-reduce;
//! Adafactor runs factored-statistics → update-RMS → clipped-write with
//! two reductions in between. Every parallel phase goes through
//! [`StepEngine::run_tasks`], so all of them share the pool and the
//! determinism contract above.
//!
//! # Plan and context lifecycle
//!
//! Planning is expensive relative to a small step, so it is **cached**,
//! not repeated: each optimizer owns a [`ctx::StepContext`] holding the
//! `TensorMeta`s, the shard [`plan::Plan`], the stat-slot buffers and
//! every reusable scratch/re-encode arena. On each step the executor
//! calls [`StepContext::ensure`], which revalidates the cache against the
//! live layout (an allocation-free per-tensor comparison) and rebuilds
//! only when the param set, a state layout, or the shard size actually
//! changed; the optimizer builder setters (`with_threads` /
//! `with_shard_elems`) additionally invalidate it outright. Both the
//! compressed and the dense executors derive their metadata through the
//! same [`plan::MetaSpec`] path, so there is exactly one meta/plan
//! construction route in the engine. A warmed-up step is therefore
//! construction-free and (at one thread) allocation-free — pinned by the
//! counting-allocator test in `rust/tests/ctx_cache.rs`. Caching never
//! affects results: a rebuilt context replays the identical pure plan,
//! so warm and cold steps are bit-identical.
//!
//! # Transfer tasks and the dependency contract
//!
//! The offload pipeline ([`crate::offload::pipeline`]) interleaves
//! *heterogeneous* task kinds — stage-in transfers, shard computes and
//! writeback transfers — into one queue executed by
//! [`StepEngine::run_tasks_dep`] on the same worker pool. The contract:
//!
//! 1. **Single backward dependency.** Each queue entry names at most one
//!    predecessor entry (`deps[i] < i`) that must complete before it
//!    runs: a compute depends on its shard's stage-in, a writeback on
//!    its compute, and a stage-in on the writeback that frees its
//!    scratch slot. Because every dependency points strictly backwards
//!    and workers claim entries in queue order, the smallest unfinished
//!    entry is always runnable — no deadlock at any worker count.
//! 2. **Queue order is a schedule.** The caller emits entries in a
//!    topologically valid order (prefetch prologue, then
//!    compute/writeback/next-prefetch per shard), so one thread simply
//!    runs the queue front to back — the 1-thread schedule stays the
//!    determinism baseline exactly as for homogeneous phases.
//! 3. **Determinism is data-level, not schedule-level.** Transfers copy
//!    between disjoint host ranges and exclusive scratch slots; computes
//!    use the same per-plan-task RNG streams as in-memory execution.
//!    Which worker runs what, and when, never affects the bytes
//!    produced — offloaded steps are bit-identical to in-memory steps at
//!    every thread count and every prefetch depth
//!    (`rust/tests/offload_pipeline.rs`).
//!
//! # Scheduler
//!
//! Parallel phases run under one of two schedulers, resolved once per
//! process from `LOWBIT_ENGINE_SCHED=queue|sticky|auto` (mirroring
//! `LOWBIT_KERNEL_TIER`; unknown values are a hard error) or overridden
//! per engine with [`StepEngine::with_sched`]:
//!
//! * **`queue`** — the reference scheduler: workers pull task indices
//!   off one shared atomic counter in plan order. Simple, fair, and the
//!   baseline the parity suites compare against.
//! * **`sticky`** (the `auto` default) — locality-aware per-worker
//!   claim queues driven by an [`Affinity`] table, so a warmed-up step
//!   re-claims the same shards on the same workers and each worker's
//!   4-bit state tiles stay hot in its local cache slice.
//!
//! **Affinity lifecycle.** The table records, per task id, the worker
//! slot that last ran it. A task with no recorded owner is seeded by
//! contiguous range partition (task `i` of `n` on `t` workers → slot
//! `i·t/n` — the plan emits tasks in address order, so the seed is a
//! contiguous address-space split); owners recorded under a larger
//! worker count are remapped by `% threads`. Ownership is updated from
//! who *actually* ran each task, stealers included. The executors keep
//! one table per optimizer inside [`ctx::StepContext`] and pass it to
//! the `run_tasks*_in` entry points, so it persists across phases and
//! steps; the plain `run_tasks*` methods use a throwaway table. The
//! table is grow-only and [`Affinity::prepare`] rebuilds the claim
//! blocks in place, so a warmed-up step allocates nothing
//! (`ctx_cache.rs` pins this, sticky mode included). A context rebuild
//! resets the table — task ids renumber with the plan. Sharing one
//! table across phases with different task counts (phase A vs the
//! offload queue) is deliberate: affinity is purely a locality
//! heuristic, so a stale or remapped owner can cost a steal but never
//! changes results.
//!
//! **Stealing bounds.** Each phase, `prepare` groups the task ids into
//! one contiguous block per worker (a stable counting sort — ascending
//! task order *within* each block) and workers claim from their own
//! block through a per-worker cursor. Only when the local block is
//! drained does a worker steal: victims are visited deterministically
//! by ascending slot distance (`(slot + d) % threads`, `d = 1..t`),
//! each victim's remaining block is drained from the *front*, and after
//! one full pass over the victims the worker exits the phase.
//!
//! **Why determinism survives.** Scheduling decides only *who* runs a
//! task and *when* — never what the task is (rule 1), what randomness
//! it draws (rule 2), or how cross-shard reductions combine (rule 3).
//! So any claim order — local, stolen, or re-randomized — produces
//! bit-identical bytes, and `queue` vs `sticky` is pinned bitwise by
//! `engine_parity.rs` at threads 1/2/7. For dependency queues
//! (`run_tasks_dep`) the deadlock-freedom argument survives stealing:
//! consider the smallest unfinished entry `m`, owned by slot `v`.
//! Every entry before `m` in `v`'s block is smaller (ascending blocks),
//! hence finished — so `v` is not parked on a dependency (anything it
//! claimed earlier is finished) and `v`'s next local claim is `m`
//! itself, unless a stealer already took `m` off the block front. In
//! either case `m`'s dependency (`< m`) is finished, so whoever holds
//! `m` runs it immediately: progress at every worker count.
//!
//! **Dependency waits.** An unfinished dependency is awaited in three
//! stages: a bounded spin (covers the common near-miss), a bounded run
//! of yields, then a parked condvar wait with a short timeout — a long
//! link-stage wait in the offload pipeline stops burning a core. A
//! completion store-releases the done flag, then fences (SeqCst) and
//! checks the waiter count before notifying — Dekker-style pairing with
//! the waiter's SeqCst registration, so a wakeup is never lost; the
//! timeout converts any missed edge into bounded latency, not a hang.
//!
//! **Telemetry.** Per-worker claim / steal / affinity-hit counters
//! (relaxed atomics, negligible next to a shard's work) accumulate in
//! the `Affinity` table, surface through [`Affinity::stats`] and
//! `Optimizer::sched_stats`, and land in the bench JSON trajectories
//! (`BENCH_engine.json` / `BENCH_offload.json`) tagged with the active
//! scheduler mode.
//!
//! # Pool lifecycle
//!
//! Worker threads are **persistent**, not spawned per phase: the first
//! parallel phase lazily creates a [`pool::WorkerPool`] sized to the
//! resolved worker count, and every later phase of every later step
//! reuses it (the pool is grown — recreated larger — if a step ever
//! resolves to more workers). The pool is shared by clones of the engine
//! and is shut down (workers joined) when the owning optimizer drops.
//! Call sites keep the borrow-friendly scoped API: `run_tasks` /
//! `run_tasks_with` block until the phase has drained, so task closures
//! may borrow the step's plan and tensor views exactly as they could
//! with scoped spawns.
//!
//! The auto-thread override `LOWBIT_ENGINE_THREADS` is read **once per
//! process** (cached in a `OnceLock`) and consulted on the hot path from
//! that cache; `ci.sh`'s two-count test runs keep working by
//! construction because each `cargo test` invocation is its own process
//! with its own environment.
//!
//! # The audited unsafe boundary
//!
//! Every `unsafe` in the engine is a [`SharedSlice::range_mut`] call (or
//! supports one), and the disjointness those calls rely on is
//! **machine-checked**, not merely asserted, on two axes:
//!
//! * **Statically**: `rust/src/bin/lint.rs` (tier-1 test `unsafe_lint`)
//!   confines `unsafe` to the engine/offload/checkpoint allowlist,
//!   requires an adjacent `// SAFETY:` comment at every site, and keeps
//!   `#![forbid(unsafe_code)]` stamped on everything else.
//! * **Dynamically** (`--features audit`): each engine owns an
//!   [`audit::Registry`]; every `run_tasks{,_with,_dep}` call is one
//!   *phase* that advances the registry's epoch on entry and again
//!   after the pool drains, and every task body runs inside a task
//!   scope. `range_mut` then registers each materialized view's byte
//!   interval, and the auditor aborts — naming both call sites — on any
//!   overlap between views of *different* tasks in one phase that the
//!   phase's dependency edges (`run_tasks_dep`) do not order, on any
//!   out-of-bounds range, and on any view materialized after its
//!   phase's barrier (epoch mismatch — i.e. a worker escaped the pool
//!   drain). Worker-slot scratch (`run_tasks_with` / `run_tasks_dep`)
//!   registers under a per-slot scope in a disjoint id namespace, so
//!   slot exclusivity is audited by the same overlap rule.
//!
//! Epoch/phase rules, in short: *a view is live from its `range_mut`
//! until its phase's barrier*, and two live views may overlap only if
//! they belong to one task or to dependency-ordered tasks. Accesses
//! outside any phase (setup code, direct unit tests) are bounds-checked
//! but make no disjointness claim. When adding a new unsafe site: route
//! it through `range_mut` inside a task body of one of the `run_tasks*`
//! entry points, keep the touched range inside the task's plan pieces
//! (or its exclusive scratch slot), put a `// SAFETY:` comment on the
//! line above citing the plan invariant relied upon, and keep the file
//! inside the lint's allowlist — then `cargo test --features audit`
//! checks the claim on every schedule the suite runs.
//!
//! Audit-mode registries are engine-wide but reached through a
//! thread-local task scope, so concurrently running engines (e.g. the
//! test harness's parallel tests) never cross-talk.
//!
//! # Observability
//!
//! The engine feeds the [`crate::obs`] telemetry subsystem on three
//! channels, all designed to keep the hot path untouched:
//!
//! * **Span tracing** (`--features trace`, mirroring the `audit`
//!   feature's gating): every executor phase (F/A/reduce/C/commit, the
//!   dense per-preset phases, and the offload queue/in/compute/out
//!   stages) and every worker task records a span into preallocated
//!   rings owned by [`StepContext`] — the coordinator's ring plus one
//!   per scratch slot, sized on the cold `ensure`/`ensure_scratch`
//!   paths so warm-step recording is a wrapping indexed store with zero
//!   allocations (the `ctx_cache` zero-alloc pins also run with the
//!   feature on). With the feature off every record site compiles away.
//!   Export as chrome://tracing JSON via `Optimizer::export_trace`,
//!   `LOWBIT_TRACE=path.json` on any training run, or the `lowbit
//!   trace` subcommand.
//! * **Quantization-quality metrics** (runtime-gated, no feature):
//!   armed per-optimizer via `with_quant_metrics(true)`, phase C taps
//!   the fresh codes while the data is already in cache and accumulates
//!   per-moment RMSE / max-abs / relative error, nibble-code occupancy
//!   histograms and outlier counters into per-worker accumulators,
//!   merged in slot order at commit. Metered steps route through the
//!   unfused phase-C arm, which is bit-identical (RNG draws included)
//!   to the fused default.
//! * **Unified reporting**: scheduler telemetry ([`SchedStats`]),
//!   offload totals, span summaries and quant metrics surface through
//!   one `Optimizer::step_report` accessor (`obs::report::StepReport`),
//!   printed by the trainer at a configurable cadence and appended as
//!   summary percentiles to the bench JSON artifacts.
//!
//! # Failure semantics
//!
//! What happens when a task body panics mid-phase (a bug, or an
//! injected fault from [`crate::fault`]):
//!
//! * **The phase aborts, the step's results are void.** The pool
//!   catches the unwind on the worker, records the panicked broadcast
//!   sequence, lets the phase drain, and **re-panics on the submitter**
//!   ("engine worker panicked during a broadcast task") once every
//!   worker has returned. The pool and its threads stay reusable — the
//!   next broadcast runs normally (`pool.rs` pins this).
//! * **Dependents are released, not stranded.** In dependency-ordered
//!   phases (`run_tasks_dep`) each task's done flag is set by an
//!   unwind-safe guard ([`DoneGuard`]), so entries depending on a
//!   panicked task run instead of parking in [`DepWait`] forever.
//!   They may read a partially-written scratch slot: memory-safe (the
//!   disjointness contract is about ranges, not values — the auditor
//!   stays false-alarm-free under injected faults), numerically
//!   garbage. That is acceptable *because* the step as a whole aborts.
//! * **Recovery is the caller's transaction.** Nothing in the engine
//!   rolls state back; `Optimizer::try_step` snapshots the in-place
//!   mutated state (packed bufs / scales / weights / `t`) before the
//!   step, catches the submitter re-panic, restores the snapshot and
//!   invalidates the step context — a post-abort retry is bit-identical
//!   to a never-faulted step. Plain `step` keeps the old contract: a
//!   worker panic propagates and optimizer state is undefined.
//! * **Fatal, by design:** panics outside a broadcast body (planning,
//!   reductions on the submitter) and poisoned pool mutexes — both mean
//!   the submitter itself is unwinding, and there is nothing coherent
//!   to hand back.
//!
//! Transfer-level faults (link failures, payload corruption) never
//! reach the engine: the offload pipeline detects and retries them at
//! the staging boundary — see the offload module's "Failure semantics".

pub mod adamw4;
#[cfg(feature = "audit")]
pub mod audit;
pub mod ctx;
pub mod dense;
pub mod plan;
pub mod pool;
pub mod shared;

pub use adamw4::{compressed_step, StepParams};
pub use ctx::{ArenaVec, StepContext, StepScratch, VecArena};
pub use plan::{build_plan, MetaSpec, Plan, StateLayout, TensorMeta};
pub use shared::SharedSlice;

use pool::WorkerPool;
use std::sync::atomic::{fence, AtomicBool, AtomicU32, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::time::Duration;

/// Default shard size in elements (~256 KB of f32 values per shard).
pub const DEFAULT_SHARD_ELEMS: usize = 1 << 16;

/// Below this much total work an auto-threaded engine stays sequential —
/// spawn overhead would dominate. Explicit thread counts are honored
/// regardless (the parity suite relies on that).
pub const MIN_PARALLEL_ELEMS: usize = 1 << 15;

/// Lazily created, grow-on-demand handle to the engine's persistent
/// [`WorkerPool`]. Clones of a `StepEngine` share one cell (and thus one
/// pool); the pool is created by the first parallel phase and replaced
/// with a larger one only if a later phase resolves to more workers.
struct PoolCell {
    inner: Mutex<Option<Arc<WorkerPool>>>,
}

impl PoolCell {
    fn ensure(&self, workers: usize) -> Arc<WorkerPool> {
        let mut guard = self.inner.lock().unwrap();
        match guard.as_ref() {
            Some(p) if p.workers() >= workers => Arc::clone(p),
            _ => {
                let p = Arc::new(WorkerPool::new(workers));
                *guard = Some(Arc::clone(&p));
                p
            }
        }
    }
}

impl std::fmt::Debug for PoolCell {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let workers = self
            .inner
            .lock()
            .ok()
            .and_then(|g| g.as_ref().map(|p| p.workers()));
        write!(f, "PoolCell({workers:?})")
    }
}

/// Task scheduler selection — see the module docs' "Scheduler" section.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SchedMode {
    /// Reference scheduler: one shared atomic claim counter.
    Queue,
    /// Locality-aware scheduler: per-worker claim queues seeded from the
    /// [`Affinity`] table, with bounded work stealing.
    Sticky,
}

impl SchedMode {
    pub fn name(self) -> &'static str {
        match self {
            SchedMode::Queue => "queue",
            SchedMode::Sticky => "sticky",
        }
    }
}

/// The pure scheduler-resolution rule behind [`active_sched`], split out
/// so tests can pin every arm without touching the process environment.
/// `over` is the `LOWBIT_ENGINE_SCHED` value, if set. Unknown values are
/// a hard error — a typo silently falling back to a default would make
/// A/B runs lie.
pub fn resolve_sched(over: Option<&str>) -> SchedMode {
    match over {
        None | Some("auto") => SchedMode::Sticky,
        Some("queue") => SchedMode::Queue,
        Some("sticky") => SchedMode::Sticky,
        Some(other) => panic!(
            "LOWBIT_ENGINE_SCHED={other:?} is not a scheduler (expected queue|sticky|auto)"
        ),
    }
}

/// The process-wide scheduler mode: `LOWBIT_ENGINE_SCHED` when set, else
/// `sticky`. Read **once per process** and cached, exactly like
/// [`auto_threads`] / `LOWBIT_KERNEL_TIER` — each `ci.sh` test run is its
/// own process, so the `queue` pass genuinely flips the whole suite to
/// the reference scheduler. Per-engine [`StepEngine::with_sched`]
/// overrides bypass it (the parity suite compares both modes in one
/// process that way).
pub fn active_sched() -> SchedMode {
    static ACTIVE: OnceLock<SchedMode> = OnceLock::new();
    *ACTIVE.get_or_init(|| {
        let over = std::env::var("LOWBIT_ENGINE_SCHED").ok();
        resolve_sched(over.as_deref())
    })
}

/// Scheduler telemetry totals, summed over workers — the claims include
/// the steals, and the affinity hits are the claims whose task was
/// re-run by the worker that ran it last time.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SchedStats {
    pub mode: SchedMode,
    pub claims: u64,
    pub steals: u64,
    pub affinity_hits: u64,
}

/// Owner entry for a task nobody has run yet.
const UNSEEDED: u32 = u32::MAX;

/// The sticky scheduler's state: the persistent task→worker ownership
/// map, the per-phase claim blocks built from it, and the telemetry
/// counters. One table lives in each optimizer's `StepContext` (passed
/// to the `run_tasks*_in` entry points); the plain `run_tasks*` methods
/// use a throwaway one. Everything is grow-only, so a warmed-up phase
/// prepares and runs with zero allocations. See the module docs'
/// "Scheduler" section for the lifecycle and the stealing bounds.
#[derive(Default)]
pub struct Affinity {
    /// Worker slot that last ran each task id; [`UNSEEDED`] until then.
    owner: Vec<AtomicU32>,
    /// This phase's task ids, grouped into one contiguous block per
    /// worker, ascending task order within each block.
    queue: Vec<u32>,
    /// Per-worker claim cursor into `queue`. Stealers bump their
    /// victim's cursor too, so a block drains exactly once.
    cursors: Vec<AtomicUsize>,
    /// Exclusive end of each worker's block in `queue`.
    ends: Vec<usize>,
    /// Counting-sort scratch (block write positions).
    counts: Vec<usize>,
    /// Telemetry, per worker slot (relaxed; read by [`Self::stats`]).
    claims: Vec<AtomicU64>,
    steals: Vec<AtomicU64>,
    hits: Vec<AtomicU64>,
}

impl Affinity {
    pub fn new() -> Affinity {
        Affinity::default()
    }

    /// Drop the learned task→worker map (the plan was rebuilt, so task
    /// ids renumbered). Telemetry totals are kept — they count the
    /// process, not one plan.
    pub fn reset(&mut self) {
        self.owner.clear();
    }

    /// Record `slot` as `task`'s owner, as if that worker had just run
    /// it. Public for the forced-steal schedule tests (`audit_stress`):
    /// parking every task on one slot makes every other worker's local
    /// queue empty, so the phase runs entirely on steals.
    pub fn force_owner(&mut self, task: usize, slot: u32) {
        if self.owner.len() <= task {
            self.owner.resize_with(task + 1, || AtomicU32::new(UNSEEDED));
        }
        self.owner[task].store(slot, Ordering::Relaxed);
    }

    /// Telemetry totals so far, summed over workers.
    pub fn stats(&self, mode: SchedMode) -> SchedStats {
        let sum = |v: &[AtomicU64]| v.iter().map(|c| c.load(Ordering::Relaxed)).sum();
        SchedStats {
            mode,
            claims: sum(&self.claims),
            steals: sum(&self.steals),
            affinity_hits: sum(&self.hits),
        }
    }

    /// Grow the per-worker tables (cursors, block bounds, counters) to
    /// `threads` entries. Grow-only; allocation-free once warm.
    fn ensure_workers(&mut self, threads: usize) {
        if self.cursors.len() < threads {
            self.cursors.resize_with(threads, || AtomicUsize::new(0));
            self.ends.resize(threads, 0);
            self.counts.resize(threads, 0);
            self.claims.resize_with(threads, || AtomicU64::new(0));
            self.steals.resize_with(threads, || AtomicU64::new(0));
            self.hits.resize_with(threads, || AtomicU64::new(0));
        }
    }

    /// Grow the ownership map to `n_tasks` entries. Grow-only.
    fn ensure_tasks(&mut self, n_tasks: usize) {
        if self.owner.len() < n_tasks {
            self.owner.resize_with(n_tasks, || AtomicU32::new(UNSEEDED));
        }
    }

    /// Block assignment for task `i`: its recorded owner when it has
    /// one (remapped by `% threads` if it was recorded under a larger
    /// worker count), else the contiguous range-partition seed.
    fn home_slot(&self, i: usize, threads: usize, n_tasks: usize) -> usize {
        let o = self.owner[i].load(Ordering::Relaxed);
        if o == UNSEEDED {
            i * threads / n_tasks
        } else {
            (o as usize) % threads
        }
    }

    /// Build this phase's claim blocks: a stable counting sort of the
    /// task ids by home slot (ascending task order within each block —
    /// the dependency-queue progress proof relies on that), then reset
    /// every cursor to its block start. In-place and allocation-free
    /// once the tables are grown.
    fn prepare(&mut self, threads: usize, n_tasks: usize) {
        self.ensure_workers(threads);
        self.ensure_tasks(n_tasks);
        if self.queue.len() < n_tasks {
            self.queue.resize(n_tasks, 0);
        }
        self.counts[..threads].fill(0);
        for i in 0..n_tasks {
            self.counts[self.home_slot(i, threads, n_tasks)] += 1;
        }
        let mut start = 0usize;
        for s in 0..threads {
            let c = self.counts[s];
            self.counts[s] = start; // becomes the block write position
            self.cursors[s].store(start, Ordering::Relaxed);
            start += c;
            self.ends[s] = start;
        }
        for i in 0..n_tasks {
            let s = self.home_slot(i, threads, n_tasks);
            let pos = self.counts[s];
            self.queue[pos] = i as u32;
            self.counts[s] = pos + 1;
        }
    }

    /// Sticky claim loop for worker `slot`: drain the local block, then
    /// steal by ascending slot distance, draining each victim's
    /// remaining block from the front (see the module docs' "Stealing
    /// bounds"). `run` is invoked with claimed task ids.
    fn run_worker(&self, slot: usize, threads: usize, mut run: impl FnMut(usize)) {
        let end = self.ends[slot];
        loop {
            let pos = self.cursors[slot].fetch_add(1, Ordering::Relaxed);
            if pos >= end {
                break;
            }
            let i = self.queue[pos] as usize;
            self.claims[slot].fetch_add(1, Ordering::Relaxed);
            if self.owner[i].load(Ordering::Relaxed) == slot as u32 {
                self.hits[slot].fetch_add(1, Ordering::Relaxed);
            } else {
                self.owner[i].store(slot as u32, Ordering::Relaxed);
            }
            run(i);
        }
        for d in 1..threads {
            let v = (slot + d) % threads;
            let vend = self.ends[v];
            loop {
                let pos = self.cursors[v].fetch_add(1, Ordering::Relaxed);
                if pos >= vend {
                    break;
                }
                let i = self.queue[pos] as usize;
                self.claims[slot].fetch_add(1, Ordering::Relaxed);
                self.steals[slot].fetch_add(1, Ordering::Relaxed);
                self.owner[i].store(slot as u32, Ordering::Relaxed);
                run(i);
            }
        }
    }

    /// Queue-mode claim loop (the reference scheduler) with the same
    /// telemetry and ownership updates, so switching an engine to
    /// sticky mid-process starts from a live map.
    fn run_worker_queue(
        &self,
        slot: usize,
        next: &AtomicUsize,
        n_tasks: usize,
        mut run: impl FnMut(usize),
    ) {
        loop {
            let i = next.fetch_add(1, Ordering::Relaxed);
            if i >= n_tasks {
                break;
            }
            self.claims[slot].fetch_add(1, Ordering::Relaxed);
            if self.owner[i].load(Ordering::Relaxed) == slot as u32 {
                self.hits[slot].fetch_add(1, Ordering::Relaxed);
            } else {
                self.owner[i].store(slot as u32, Ordering::Relaxed);
            }
            run(i);
        }
    }
}

/// Dependency-wait backoff for `run_tasks_dep`: bounded spin → bounded
/// yields → parked condvar wait with a timeout. Stack-allocated per
/// phase (Linux `Mutex`/`Condvar` are futex-based and heap-free). See
/// the module docs' "Dependency waits" for the wakeup protocol.
struct DepWait {
    lock: Mutex<()>,
    cv: Condvar,
    /// Workers currently parked (or committed to parking). SeqCst so the
    /// completer's fence+load pairs with the waiter's registration.
    waiters: AtomicUsize,
}

/// Spin iterations before yielding, then yields before parking. Tuned
/// loosely: spins cover a compute task finishing, yields cover a short
/// link transfer, parking covers everything longer.
const DEP_SPINS: usize = 128;
const DEP_YIELDS: usize = 32;
/// Park timeout: converts any (theoretically impossible, see `notify`)
/// missed wakeup into bounded latency instead of a hang.
const DEP_PARK: Duration = Duration::from_millis(5);

impl DepWait {
    fn new() -> DepWait {
        DepWait {
            lock: Mutex::new(()),
            cv: Condvar::new(),
            waiters: AtomicUsize::new(0),
        }
    }

    /// Block until `done` reads true.
    fn wait(&self, done: &AtomicBool) {
        for _ in 0..DEP_SPINS {
            if done.load(Ordering::Acquire) {
                return;
            }
            std::hint::spin_loop();
        }
        for _ in 0..DEP_YIELDS {
            if done.load(Ordering::Acquire) {
                return;
            }
            std::thread::yield_now();
        }
        self.waiters.fetch_add(1, Ordering::SeqCst);
        let mut guard = self.lock.lock().unwrap();
        // Re-check under the lock: `notify` takes the lock before
        // notifying, so a completion between this check and the wait
        // cannot slip a notification past us.
        while !done.load(Ordering::Acquire) {
            let (g, _) = self.cv.wait_timeout(guard, DEP_PARK).unwrap();
            guard = g;
        }
        drop(guard);
        self.waiters.fetch_sub(1, Ordering::SeqCst);
    }

    /// Wake parked workers after a completion. The caller has already
    /// store-released the done flag; the SeqCst fence orders that store
    /// before the waiter-count load, pairing with the waiter's SeqCst
    /// registration (Dekker): either we observe the waiter and notify,
    /// or the waiter's re-check observes the done flag.
    fn notify(&self) {
        fence(Ordering::SeqCst);
        if self.waiters.load(Ordering::Relaxed) > 0 {
            drop(self.lock.lock().unwrap());
            self.cv.notify_all();
        }
    }
}

/// Unwind-safe completion marker for one dependency-ordered task: marks
/// the task's done flag and wakes [`DepWait`] parkers on drop, so a
/// panicking task body cannot strand dependents parked on it (they
/// would otherwise re-check only every [`DEP_PARK`] — or spin forever
/// if the panicking worker was the one destined to run their dep).
/// Dependents released this way may read a partially-written scratch
/// slot — memory-safe (disjoint ranges), numerically garbage — which is
/// why a panicked broadcast re-panics on the submitter and transactional
/// callers ([`crate::optim::Optimizer::try_step`]) roll the whole step
/// back. See the module docs' "Failure semantics".
struct DoneGuard<'a> {
    done: &'a AtomicBool,
    wait: &'a DepWait,
}

impl Drop for DoneGuard<'_> {
    fn drop(&mut self) {
        self.done.store(true, Ordering::Release);
        self.wait.notify();
    }
}

/// The task scheduler: each phase runs its tasks on the engine's
/// persistent worker pool, workers claiming task indices through the
/// resolved scheduler mode — the shared atomic queue (`queue`) or the
/// affinity-seeded per-worker claim queues with bounded stealing
/// (`sticky`; see the module docs' "Scheduler" section). Execution
/// *order* is nondeterministic; results are not, because each task is
/// self-contained (see the module docs).
///
/// The pool outlives phases and steps (see the module docs' "Pool
/// lifecycle"), removing the former per-phase spawn tax; tiny workloads
/// still stay sequential via [`MIN_PARALLEL_ELEMS`] and never touch the
/// pool at all.
#[derive(Clone, Debug)]
pub struct StepEngine {
    /// Worker threads; 0 = auto (available parallelism).
    threads: usize,
    /// Target shard size in elements.
    shard_elems: usize,
    /// Scheduler override; `None` defers to the process-wide
    /// [`active_sched`] resolution.
    sched: Option<SchedMode>,
    /// Persistent worker pool, shared by clones of this engine.
    pool: Arc<PoolCell>,
    /// Aliasing-auditor interval tracker, shared by clones of this
    /// engine (clones share the pool, so they share phases too).
    #[cfg(feature = "audit")]
    audit: Arc<audit::Registry>,
}

impl Default for StepEngine {
    fn default() -> StepEngine {
        StepEngine::new()
    }
}

impl StepEngine {
    pub fn new() -> StepEngine {
        StepEngine {
            threads: 0,
            shard_elems: DEFAULT_SHARD_ELEMS,
            sched: None,
            pool: Arc::new(PoolCell {
                inner: Mutex::new(None),
            }),
            #[cfg(feature = "audit")]
            audit: Arc::new(audit::Registry::new()),
        }
    }

    /// Set the worker count (0 = auto).
    pub fn with_threads(mut self, threads: usize) -> StepEngine {
        self.threads = threads;
        self
    }

    /// Set the target shard size in elements (tests use small values to
    /// force multi-shard plans on small tensors).
    pub fn with_shard_elems(mut self, shard_elems: usize) -> StepEngine {
        assert!(shard_elems >= 2, "shard_elems must be at least 2");
        self.shard_elems = shard_elems;
        self
    }

    /// Pin this engine to a scheduler mode, overriding the process-wide
    /// `LOWBIT_ENGINE_SCHED` resolution — how the parity suite compares
    /// `queue` against `sticky` inside one process.
    pub fn with_sched(mut self, sched: SchedMode) -> StepEngine {
        self.sched = Some(sched);
        self
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    pub fn shard_elems(&self) -> usize {
        self.shard_elems
    }

    /// The scheduler this engine's parallel phases run under.
    pub fn sched(&self) -> SchedMode {
        self.sched.unwrap_or_else(active_sched)
    }

    /// Worker count for a workload of `n_tasks` tasks over `total_elems`
    /// elements. Auto mode (threads = 0) stays sequential for small
    /// workloads; explicit counts are only clamped to the task count.
    pub fn resolve_threads(&self, n_tasks: usize, total_elems: usize) -> usize {
        let t = match self.threads {
            0 => {
                if total_elems < MIN_PARALLEL_ELEMS {
                    1
                } else {
                    auto_threads()
                }
            }
            n => n,
        };
        t.max(1).min(n_tasks.max(1))
    }

    /// Execute `f(task_index, scratch)` for every task index in
    /// `0..n_tasks` on `threads` workers. Each worker owns one scratch
    /// value (`S::default()`), reused across the tasks it runs. With
    /// `threads <= 1` this is a plain loop on the calling thread;
    /// otherwise the tasks run on the engine's persistent pool, and this
    /// call blocks until the phase has drained (so `f` may borrow the
    /// caller's stack exactly as under the old scoped spawns).
    pub fn run_tasks<S, F>(&self, threads: usize, n_tasks: usize, f: F)
    where
        S: Default + Send,
        F: Fn(usize, &mut S) + Sync,
    {
        self.run_tasks_in(threads, n_tasks, &mut Affinity::new(), f)
    }

    /// [`Self::run_tasks`] against a caller-owned [`Affinity`] table, so
    /// the learned shard→worker map (and the telemetry) persists across
    /// phases and steps — the executors pass their `StepContext`'s
    /// table. The plain method uses a throwaway table instead.
    pub fn run_tasks_in<S, F>(&self, threads: usize, n_tasks: usize, aff: &mut Affinity, f: F)
    where
        S: Default + Send,
        F: Fn(usize, &mut S) + Sync,
    {
        if n_tasks == 0 {
            return;
        }
        // One `run_tasks*` call = one auditor phase: the guard opens a
        // fresh epoch now and retires every interval at return (i.e.
        // after the pool drained). See the module docs, "The audited
        // unsafe boundary".
        #[cfg(feature = "audit")]
        let _phase = audit::phase_scope(&self.audit, None);
        if threads <= 1 {
            let mut scratch = S::default();
            for i in 0..n_tasks {
                #[cfg(feature = "audit")]
                let _task = audit::task_scope(&self.audit, i as u64);
                f(i, &mut scratch);
            }
            return;
        }
        let sched = self.sched();
        match sched {
            SchedMode::Sticky => aff.prepare(threads, n_tasks),
            SchedMode::Queue => {
                aff.ensure_workers(threads);
                aff.ensure_tasks(n_tasks);
            }
        }
        let next = AtomicUsize::new(0);
        let next = &next;
        let f = &f;
        let aff = &*aff;
        #[cfg(feature = "audit")]
        let audit_reg = &self.audit;
        let body = move |slot: usize| {
            let mut scratch = S::default();
            let run = |i: usize| {
                #[cfg(feature = "audit")]
                let _task = audit::task_scope(audit_reg, i as u64);
                f(i, &mut scratch);
            };
            match sched {
                SchedMode::Sticky => aff.run_worker(slot, threads, run),
                SchedMode::Queue => aff.run_worker_queue(slot, next, n_tasks, run),
            }
        };
        self.pool.ensure(threads).broadcast(threads, &body);
    }

    /// Execute an *interleaved* task queue with single-predecessor
    /// dependencies — the offload pipeline's transfer/compute discipline
    /// (see the module docs' "Transfer tasks and the dependency
    /// contract"). `deps[i]` names the queue entry that must complete
    /// before entry `i` may run; it must be `< i`, so the queue order is
    /// itself a valid sequential schedule (`threads <= 1` just runs the
    /// loop). On the pool, workers claim indices in order and spin-wait
    /// (with yields) on an unfinished dependency; because every
    /// dependency points at an earlier — hence already claimed — entry,
    /// the smallest unfinished entry is always runnable and the queue
    /// cannot deadlock at any worker count.
    ///
    /// Worker slot `w` exclusively uses `scratch[w]`, exactly as in
    /// [`Self::run_tasks_with`].
    pub fn run_tasks_dep<S, F>(
        &self,
        threads: usize,
        deps: &[Option<usize>],
        scratch: &mut [S],
        f: F,
    ) where
        S: Send,
        F: Fn(usize, &mut S) + Sync,
    {
        self.run_tasks_dep_in(threads, deps, &mut Affinity::new(), scratch, f)
    }

    /// [`Self::run_tasks_dep`] against a caller-owned [`Affinity`] table
    /// (see [`Self::run_tasks_in`]). Under the sticky scheduler the
    /// claim blocks keep ascending entry order and stealers take the
    /// front of a victim's remaining block, which preserves the
    /// "smallest unfinished entry is always runnable" progress proof —
    /// see the module docs' "Scheduler" section.
    pub fn run_tasks_dep_in<S, F>(
        &self,
        threads: usize,
        deps: &[Option<usize>],
        aff: &mut Affinity,
        scratch: &mut [S],
        f: F,
    ) where
        S: Send,
        F: Fn(usize, &mut S) + Sync,
    {
        let n_tasks = deps.len();
        if n_tasks == 0 {
            return;
        }
        for (i, d) in deps.iter().enumerate() {
            if let Some(d) = *d {
                assert!(d < i, "dependency {d} of queue entry {i} must precede it");
            }
        }
        // Dependency-ordered phase: the auditor receives the edges so
        // that ordered entries may legally reuse a scratch range.
        #[cfg(feature = "audit")]
        let _phase = audit::phase_scope(&self.audit, Some(deps));
        if threads <= 1 {
            let s = &mut scratch[0];
            for i in 0..n_tasks {
                #[cfg(feature = "audit")]
                let _task = audit::task_scope(&self.audit, i as u64);
                f(i, &mut *s);
            }
            return;
        }
        assert!(
            scratch.len() >= threads,
            "scratch pool ({}) smaller than the worker count ({threads})",
            scratch.len()
        );
        let sched = self.sched();
        match sched {
            SchedMode::Sticky => aff.prepare(threads, n_tasks),
            SchedMode::Queue => {
                aff.ensure_workers(threads);
                aff.ensure_tasks(n_tasks);
            }
        }
        let done: Vec<AtomicBool> = (0..n_tasks).map(|_| AtomicBool::new(false)).collect();
        let done = &done[..];
        let wait = DepWait::new();
        let wait = &wait;
        let next = AtomicUsize::new(0);
        let next = &next;
        let f = &f;
        let deps = &deps[..];
        let scratch_view = SharedSlice::new(scratch);
        let scratch_view = &scratch_view;
        let aff = &*aff;
        #[cfg(feature = "audit")]
        let audit_reg = &self.audit;
        let body = move |slot: usize| {
            #[cfg(feature = "audit")]
            let _worker = audit::task_scope(audit_reg, audit::SLOT_TASK_BASE + slot as u64);
            // SAFETY: the pool hands each broadcast participant a
            // distinct slot in 0..threads, so scratch entries have a
            // single owner.
            let slot_scratch = unsafe { scratch_view.range_mut(slot, slot + 1) };
            let s = &mut slot_scratch[0];
            let run = |i: usize| {
                if let Some(d) = deps[i] {
                    // Whoever holds the dependency makes progress (the
                    // smallest unfinished entry never waits — deps point
                    // strictly backwards), so this wait terminates.
                    wait.wait(&done[d]);
                }
                // Declared before the audit scope so the scope closes
                // first on unwind, then the guard marks done + notifies
                // (the same order as the straight-line path below).
                let guard = DoneGuard { done: &done[i], wait };
                #[cfg(feature = "audit")]
                let _task = audit::task_scope(audit_reg, i as u64);
                f(i, &mut *s);
                #[cfg(feature = "audit")]
                drop(_task);
                drop(guard);
            };
            match sched {
                SchedMode::Sticky => aff.run_worker(slot, threads, run),
                SchedMode::Queue => aff.run_worker_queue(slot, next, n_tasks, run),
            }
        };
        self.pool.ensure(threads).broadcast(threads, &body);
    }

    /// [`Self::run_tasks`] with caller-owned per-worker scratch: worker
    /// slot `w` exclusively uses `scratch[w]`, so the buffers persist
    /// across phases and steps (the compressed executor keeps them in
    /// its [`StepContext`], making the steady-state step allocation-
    /// free). `scratch` must hold at least `threads` entries.
    pub fn run_tasks_with<S, F>(&self, threads: usize, n_tasks: usize, scratch: &mut [S], f: F)
    where
        S: Send,
        F: Fn(usize, &mut S) + Sync,
    {
        self.run_tasks_with_in(threads, n_tasks, &mut Affinity::new(), scratch, f)
    }

    /// [`Self::run_tasks_with`] against a caller-owned [`Affinity`]
    /// table (see [`Self::run_tasks_in`]).
    pub fn run_tasks_with_in<S, F>(
        &self,
        threads: usize,
        n_tasks: usize,
        aff: &mut Affinity,
        scratch: &mut [S],
        f: F,
    ) where
        S: Send,
        F: Fn(usize, &mut S) + Sync,
    {
        if n_tasks == 0 {
            return;
        }
        #[cfg(feature = "audit")]
        let _phase = audit::phase_scope(&self.audit, None);
        if threads <= 1 {
            let s = &mut scratch[0];
            for i in 0..n_tasks {
                #[cfg(feature = "audit")]
                let _task = audit::task_scope(&self.audit, i as u64);
                f(i, &mut *s);
            }
            return;
        }
        assert!(
            scratch.len() >= threads,
            "scratch pool ({}) smaller than the worker count ({threads})",
            scratch.len()
        );
        let sched = self.sched();
        match sched {
            SchedMode::Sticky => aff.prepare(threads, n_tasks),
            SchedMode::Queue => {
                aff.ensure_workers(threads);
                aff.ensure_tasks(n_tasks);
            }
        }
        let next = AtomicUsize::new(0);
        let next = &next;
        let f = &f;
        let scratch_view = SharedSlice::new(scratch);
        let scratch_view = &scratch_view;
        let aff = &*aff;
        #[cfg(feature = "audit")]
        let audit_reg = &self.audit;
        let body = move |slot: usize| {
            #[cfg(feature = "audit")]
            let _worker = audit::task_scope(audit_reg, audit::SLOT_TASK_BASE + slot as u64);
            // SAFETY: the pool hands each broadcast participant a
            // distinct slot in 0..threads, so scratch entries have a
            // single owner.
            let slot_scratch = unsafe { scratch_view.range_mut(slot, slot + 1) };
            let s = &mut slot_scratch[0];
            let run = |i: usize| {
                #[cfg(feature = "audit")]
                let _task = audit::task_scope(audit_reg, i as u64);
                f(i, &mut *s);
            };
            match sched {
                SchedMode::Sticky => aff.run_worker(slot, threads, run),
                SchedMode::Queue => aff.run_worker_queue(slot, next, n_tasks, run),
            }
        };
        self.pool.ensure(threads).broadcast(threads, &body);
    }
}

/// Auto worker count: `LOWBIT_ENGINE_THREADS` when set (CI pins it to run
/// the whole test suite at a fixed count — see `ci.sh`), else the
/// machine's available parallelism. The override is read **once per
/// process** and cached — re-reading the environment on every
/// `resolve_threads` call put a syscall + allocation on the hot path.
/// Per-process semantics are exactly what `ci.sh` needs: each of its two
/// test runs is a separate process with its own environment. Only
/// consulted for workloads above [`MIN_PARALLEL_ELEMS`]; explicit
/// `with_threads` counts bypass it.
fn auto_threads() -> usize {
    static AUTO: std::sync::OnceLock<usize> = std::sync::OnceLock::new();
    *AUTO.get_or_init(|| {
        std::env::var("LOWBIT_ENGINE_THREADS")
            .ok()
            .and_then(|s| s.parse::<usize>().ok())
            .filter(|&n| n > 0)
            .unwrap_or_else(|| {
                std::thread::available_parallelism()
                    .map(|n| n.get())
                    .unwrap_or(1)
            })
    })
}

/// Per-step seed mixing: derives the seed for step `t` from the
/// optimizer's base seed so every step draws fresh per-shard streams
/// while staying reproducible.
pub fn step_seed(base: u64, t: u64) -> u64 {
    base ^ t.wrapping_mul(0x9e37_79b9_7f4a_7c15)
}

/// Stream-id namespace for phase C (re-encode) tasks, disjoint from the
/// phase A/F namespace which uses plain task indices.
pub const PHASE_C_STREAM_BASE: u64 = 1 << 32;

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn run_tasks_covers_every_index_once() {
        for sched in [SchedMode::Queue, SchedMode::Sticky] {
            for threads in [1, 2, 7] {
                let hits: Vec<AtomicU64> = (0..100).map(|_| AtomicU64::new(0)).collect();
                let eng = StepEngine::new().with_sched(sched);
                eng.run_tasks::<(), _>(threads, 100, |i, _| {
                    hits[i].fetch_add(1, Ordering::Relaxed);
                });
                for (i, h) in hits.iter().enumerate() {
                    assert_eq!(
                        h.load(Ordering::Relaxed),
                        1,
                        "task {i} at {threads} threads ({})",
                        sched.name()
                    );
                }
            }
        }
    }

    #[test]
    fn resolve_sched_rules() {
        assert_eq!(resolve_sched(None), SchedMode::Sticky, "unset = auto");
        assert_eq!(resolve_sched(Some("auto")), SchedMode::Sticky);
        assert_eq!(resolve_sched(Some("sticky")), SchedMode::Sticky);
        assert_eq!(resolve_sched(Some("queue")), SchedMode::Queue);
        assert_eq!(SchedMode::Queue.name(), "queue");
        assert_eq!(SchedMode::Sticky.name(), "sticky");
        let eng = StepEngine::new().with_sched(SchedMode::Queue);
        assert_eq!(eng.sched(), SchedMode::Queue, "per-engine override wins");
    }

    #[test]
    #[should_panic(expected = "not a scheduler")]
    fn resolve_sched_rejects_unknown_values() {
        resolve_sched(Some("stickyy"));
    }

    #[test]
    fn sticky_warm_rerun_is_all_affinity_hits() {
        // Two tasks, two workers, each task gated on a 2-party barrier
        // so both workers participate and neither can drain its block
        // and start stealing while the other still owns unclaimed work —
        // the schedule is pinned. Phase 1 seeds the range partition
        // (task i → slot i) and records the owners; phase 2 re-claims
        // every task on its recorded owner, so every phase-2 claim is an
        // affinity hit and nothing is ever stolen.
        let eng = StepEngine::new().with_sched(SchedMode::Sticky);
        let mut aff = Affinity::new();
        for _ in 0..2 {
            let barrier = std::sync::Barrier::new(2);
            eng.run_tasks_in::<(), _>(2, 2, &mut aff, |_i, _| {
                barrier.wait();
            });
        }
        let s = aff.stats(SchedMode::Sticky);
        assert_eq!(s.claims, 4, "every task claimed exactly once per phase");
        assert_eq!(s.steals, 0, "disjoint warm blocks leave nothing to steal");
        assert_eq!(s.affinity_hits, 2, "the warm rerun re-claims both tasks in place");
    }

    #[test]
    fn sticky_steals_when_local_queue_is_empty() {
        // Both tasks are parked on slot 1, and each task blocks on a
        // 2-party barrier — so they *must* run on different workers.
        // Slot 0's local block is empty, hence its task was a steal.
        let threads = 2;
        let eng = StepEngine::new().with_sched(SchedMode::Sticky);
        let mut aff = Affinity::new();
        aff.force_owner(0, 1);
        aff.force_owner(1, 1);
        let barrier = std::sync::Barrier::new(2);
        eng.run_tasks_in::<(), _>(threads, 2, &mut aff, |_i, _| {
            barrier.wait();
        });
        let s = aff.stats(SchedMode::Sticky);
        assert_eq!(s.claims, 2);
        assert_eq!(s.steals, 1, "exactly one task crossed to the idle worker");
    }

    #[test]
    fn queue_mode_counts_claims_in_shared_table() {
        let eng = StepEngine::new().with_sched(SchedMode::Queue);
        let mut aff = Affinity::new();
        eng.run_tasks_in::<(), _>(3, 50, &mut aff, |_i, _| {});
        let s = aff.stats(SchedMode::Queue);
        assert_eq!(s.mode, SchedMode::Queue);
        assert_eq!(s.claims, 50);
        assert_eq!(s.steals, 0, "the reference scheduler never steals");
    }

    #[test]
    fn affinity_prepare_partitions_and_reset_clears_owners() {
        let mut aff = Affinity::new();
        aff.prepare(4, 8);
        // Unseeded: contiguous range partition, two tasks per block,
        // ascending within each block.
        assert_eq!(&aff.queue[..8], &[0, 1, 2, 3, 4, 5, 6, 7]);
        assert_eq!(aff.ends, vec![2, 4, 6, 8]);
        // A recorded owner moves its task; stale owners remap % threads.
        aff.force_owner(0, 3);
        aff.force_owner(7, 9); // 9 % 4 == 1
        aff.prepare(4, 8);
        assert_eq!(&aff.queue[..8], &[1, 2, 3, 7, 4, 5, 0, 6]);
        assert_eq!(aff.ends, vec![1, 4, 6, 8]);
        aff.reset();
        aff.prepare(4, 8);
        assert_eq!(&aff.queue[..8], &[0, 1, 2, 3, 4, 5, 6, 7], "reset forgot the owners");
    }

    #[test]
    fn run_tasks_dep_waits_park_and_wake() {
        // The dependency outlasts the spin+yield budget, forcing the
        // condvar path: entry 1 waits on entry 0, whose body sleeps well
        // past any reasonable spin. Ordering must still hold.
        let eng = StepEngine::new().with_threads(2);
        for sched in [SchedMode::Queue, SchedMode::Sticky] {
            let eng = eng.clone().with_sched(sched);
            let order: Mutex<Vec<usize>> = Mutex::new(Vec::new());
            let mut scratch = vec![(); 2];
            eng.run_tasks_dep(2, &[None, Some(0)], &mut scratch, |i, _: &mut ()| {
                if i == 0 {
                    std::thread::sleep(Duration::from_millis(30));
                }
                order.lock().unwrap().push(i);
            });
            assert_eq!(*order.lock().unwrap(), vec![0, 1], "{}", sched.name());
        }
    }

    #[test]
    fn resolve_threads_policy() {
        let eng = StepEngine::new(); // auto
        assert_eq!(eng.resolve_threads(10, 100), 1, "tiny work stays sequential");
        let eng2 = StepEngine::new().with_threads(7);
        assert_eq!(eng2.resolve_threads(3, 100), 3, "clamped to task count");
        assert_eq!(eng2.resolve_threads(100, 100), 7, "explicit count honored");
        assert_eq!(eng2.resolve_threads(0, 0), 1);
    }

    #[test]
    fn step_seed_varies_per_step() {
        assert_ne!(step_seed(1, 1), step_seed(1, 2));
        assert_eq!(step_seed(5, 3), step_seed(5, 3));
    }

    #[test]
    fn run_tasks_reuses_one_pool_across_phases() {
        // Many back-to-back parallel phases on one engine: the pool is
        // created once and reused (this is the spawn-tax fix; it also
        // stress-tests the broadcast protocol under reuse).
        let eng = StepEngine::new().with_threads(4);
        for round in 0..50 {
            let hits: Vec<AtomicU64> = (0..37).map(|_| AtomicU64::new(0)).collect();
            eng.run_tasks::<(), _>(4, 37, |i, _| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
            for (i, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::Relaxed), 1, "round {round} task {i}");
            }
        }
        let workers = eng.pool.inner.lock().unwrap().as_ref().map(|p| p.workers());
        assert_eq!(workers, Some(4), "pool created once with 4 workers");
    }

    #[test]
    fn run_tasks_with_gives_each_worker_its_own_scratch() {
        // Every task bumps its worker's scratch counter; the per-slot
        // totals must add up to the task count with no cross-talk, and
        // the caller keeps the scratch (persistent across phases).
        for threads in [1usize, 2, 5] {
            let eng = StepEngine::new().with_threads(threads);
            let mut scratch = vec![0usize; threads];
            let hits: Vec<AtomicU64> = (0..83).map(|_| AtomicU64::new(0)).collect();
            for _phase in 0..3 {
                eng.run_tasks_with(threads, 83, &mut scratch, |i, s: &mut usize| {
                    *s += 1;
                    hits[i].fetch_add(1, Ordering::Relaxed);
                });
            }
            assert_eq!(scratch.iter().sum::<usize>(), 3 * 83, "{threads} threads");
            for (i, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::Relaxed), 3, "task {i} at {threads} threads");
            }
        }
    }

    #[test]
    fn run_tasks_dep_honors_dependencies() {
        // Chain i -> i-3 (a depth-3 slot-reuse pattern): when a task
        // runs, its dependency must already have run, at every thread
        // count, and every entry runs exactly once.
        for threads in [1usize, 2, 7] {
            let n = 40;
            let deps: Vec<Option<usize>> =
                (0..n).map(|i| if i >= 3 { Some(i - 3) } else { None }).collect();
            let done: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
            let violations = AtomicU64::new(0);
            let eng = StepEngine::new().with_threads(threads);
            let mut scratch = vec![(); threads];
            eng.run_tasks_dep(threads, &deps, &mut scratch, |i, _: &mut ()| {
                if let Some(d) = deps[i] {
                    if done[d].load(Ordering::Acquire) == 0 {
                        violations.fetch_add(1, Ordering::Relaxed);
                    }
                }
                done[i].fetch_add(1, Ordering::Release);
            });
            assert_eq!(violations.load(Ordering::Relaxed), 0, "{threads} threads");
            for (i, d) in done.iter().enumerate() {
                assert_eq!(d.load(Ordering::Relaxed), 1, "entry {i} at {threads} threads");
            }
        }
    }

    #[test]
    #[should_panic(expected = "must precede it")]
    fn run_tasks_dep_rejects_forward_dependency() {
        let eng = StepEngine::new().with_threads(2);
        let mut scratch = vec![(); 2];
        eng.run_tasks_dep(2, &[Some(1), None], &mut scratch, |_i, _: &mut ()| {});
    }

    #[test]
    fn run_tasks_dep_panic_releases_parked_dependents() {
        // Regression (the DoneGuard fix): entry 1 parks in DepWait on
        // entry 0, whose body sleeps past the spin+yield budget and then
        // panics. Without the unwind-safe done marker the dependent
        // would re-check only on the park timeout — and if it were
        // *spinning* on a dependency whose owner died, it would never
        // see completion at all (`active` never drains and the
        // broadcast hangs). The phase must instead drain, re-panic on
        // the submitter, and leave the engine reusable.
        for threads in [2usize, 7] {
            let eng = StepEngine::new().with_threads(threads);
            let released = AtomicU64::new(0);
            let mut scratch = vec![(); threads];
            let t0 = std::time::Instant::now();
            let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                eng.run_tasks_dep(threads, &[None, Some(0)], &mut scratch, |i, _: &mut ()| {
                    if i == 0 {
                        std::thread::sleep(Duration::from_millis(30));
                        panic!("injected: dep producer dies");
                    }
                    released.fetch_add(1, Ordering::Relaxed);
                });
            }));
            assert!(r.is_err(), "submitter must observe the worker panic");
            assert_eq!(
                released.load(Ordering::Relaxed),
                1,
                "dependent must be released, not stranded ({threads} threads)"
            );
            assert!(
                t0.elapsed() < Duration::from_secs(5),
                "release must not hang ({threads} threads)"
            );
            // The pool survives: the next dependency phase runs clean.
            let order: Mutex<Vec<usize>> = Mutex::new(Vec::new());
            eng.run_tasks_dep(threads, &[None, Some(0)], &mut scratch, |i, _: &mut ()| {
                order.lock().unwrap().push(i);
            });
            assert_eq!(*order.lock().unwrap(), vec![0, 1], "{threads} threads after abort");
        }
    }

    #[test]
    fn pool_grows_when_more_workers_are_requested() {
        let eng = StepEngine::new();
        eng.run_tasks::<(), _>(2, 16, |_i, _| {});
        eng.run_tasks::<(), _>(6, 16, |_i, _| {});
        let workers = eng.pool.inner.lock().unwrap().as_ref().map(|p| p.workers());
        assert_eq!(workers, Some(6), "pool grown to the largest request");
        // Shrinking requests keep the larger pool.
        eng.run_tasks::<(), _>(2, 16, |_i, _| {});
        let workers = eng.pool.inner.lock().unwrap().as_ref().map(|p| p.workers());
        assert_eq!(workers, Some(6));
    }
}
