//! The shard-parallel optimizer step engine.
//!
//! The paper's headline speed numbers (Tab. 4 "(fused)" rows) exist
//! because a naive decompress → AdamW → recompress loop makes quantized
//! optimizers *slower* than fp32 ones. On CPU, the analogue of the fused
//! GPU kernel is this engine: the parameter set is partitioned into
//! block-aligned shards ([`plan`]) and each step runs
//! dequantize → update → requantize shard-parallel on a persistent
//! worker pool ([`pool`]), with shard-local scratch buffers instead of
//! per-tensor allocations ([`adamw4`]).
//!
//! The dense baselines run on the same substrate: [`dense`] executes
//! fp32 AdamW, SGDM, SM3 and Adafactor's elementwise portion over the
//! identical plan/slot machinery, so the Tab. 4 speed comparison is
//! apples-to-apples at every thread count.
//!
//! # Determinism contract
//!
//! The engine is **bit-identical at every thread count**, including
//! stochastic rounding. Three rules make that hold:
//!
//! 1. **Planning is thread-blind.** The shard decomposition is a pure
//!    function of tensor shapes, state layouts and the configured shard
//!    size (`plan::build_plan`); worker count only decides who executes
//!    a task, never what the task is.
//! 2. **One RNG stream per shard.** Task `i` of step `t` draws from
//!    `Pcg64::new(step_seed(t), stream_id)` — the splittable streams from
//!    [`crate::util::rng`] — so stochastic rounding consumes the same
//!    random sequence no matter which worker runs the task or in which
//!    order tasks complete. Phase C re-encode streams live in a disjoint
//!    stream-id range from phase A/F streams.
//! 3. **Reductions run in shard order.** Cross-shard statistics (rank-1
//!    scale maxima, factored row/col sums) are combined sequentially in
//!    ascending shard order between phases, so float rounding does not
//!    depend on completion order.
//!
//! Under these rules "sequential" is just the 1-thread schedule of the
//! same plan, which is what the parity suite
//! (`rust/tests/engine_parity.rs`) checks at thread counts 1, 2 and 7.
//!
//! # Phases
//!
//! A step of the compressed optimizer runs up to three parallel phases
//! with cheap sequential reductions between them:
//!
//! * **F** (factored tensors only): accumulate per-shard row/col partial
//!   sums of `g²`; reduce into the factored EMA state.
//! * **A**: per shard — decompress states, run the exact AdamW update,
//!   requantize block-local states in place, and accumulate scale
//!   statistics for globally-normalized states (rank-1 / per-tensor).
//! * **C** (globally-normalized states only): after the scale reduction,
//!   re-derive the updated state values and encode them against the new
//!   global scales into fresh packed buffers.
//!
//! The dense executors in [`dense`] follow the same shape with their own
//! phase sets: fp32 AdamW and SGDM are a single update phase; SM3 runs
//! update + per-shard accumulator maxima with a sequential max-reduce;
//! Adafactor runs factored-statistics → update-RMS → clipped-write with
//! two reductions in between. Every parallel phase goes through
//! [`StepEngine::run_tasks`], so all of them share the pool and the
//! determinism contract above.
//!
//! # Plan and context lifecycle
//!
//! Planning is expensive relative to a small step, so it is **cached**,
//! not repeated: each optimizer owns a [`ctx::StepContext`] holding the
//! `TensorMeta`s, the shard [`plan::Plan`], the stat-slot buffers and
//! every reusable scratch/re-encode arena. On each step the executor
//! calls [`StepContext::ensure`], which revalidates the cache against the
//! live layout (an allocation-free per-tensor comparison) and rebuilds
//! only when the param set, a state layout, or the shard size actually
//! changed; the optimizer builder setters (`with_threads` /
//! `with_shard_elems`) additionally invalidate it outright. Both the
//! compressed and the dense executors derive their metadata through the
//! same [`plan::MetaSpec`] path, so there is exactly one meta/plan
//! construction route in the engine. A warmed-up step is therefore
//! construction-free and (at one thread) allocation-free — pinned by the
//! counting-allocator test in `rust/tests/ctx_cache.rs`. Caching never
//! affects results: a rebuilt context replays the identical pure plan,
//! so warm and cold steps are bit-identical.
//!
//! # Transfer tasks and the dependency contract
//!
//! The offload pipeline ([`crate::offload::pipeline`]) interleaves
//! *heterogeneous* task kinds — stage-in transfers, shard computes and
//! writeback transfers — into one queue executed by
//! [`StepEngine::run_tasks_dep`] on the same worker pool. The contract:
//!
//! 1. **Single backward dependency.** Each queue entry names at most one
//!    predecessor entry (`deps[i] < i`) that must complete before it
//!    runs: a compute depends on its shard's stage-in, a writeback on
//!    its compute, and a stage-in on the writeback that frees its
//!    scratch slot. Because every dependency points strictly backwards
//!    and workers claim entries in queue order, the smallest unfinished
//!    entry is always runnable — no deadlock at any worker count.
//! 2. **Queue order is a schedule.** The caller emits entries in a
//!    topologically valid order (prefetch prologue, then
//!    compute/writeback/next-prefetch per shard), so one thread simply
//!    runs the queue front to back — the 1-thread schedule stays the
//!    determinism baseline exactly as for homogeneous phases.
//! 3. **Determinism is data-level, not schedule-level.** Transfers copy
//!    between disjoint host ranges and exclusive scratch slots; computes
//!    use the same per-plan-task RNG streams as in-memory execution.
//!    Which worker runs what, and when, never affects the bytes
//!    produced — offloaded steps are bit-identical to in-memory steps at
//!    every thread count and every prefetch depth
//!    (`rust/tests/offload_pipeline.rs`).
//!
//! # Pool lifecycle
//!
//! Worker threads are **persistent**, not spawned per phase: the first
//! parallel phase lazily creates a [`pool::WorkerPool`] sized to the
//! resolved worker count, and every later phase of every later step
//! reuses it (the pool is grown — recreated larger — if a step ever
//! resolves to more workers). The pool is shared by clones of the engine
//! and is shut down (workers joined) when the owning optimizer drops.
//! Call sites keep the borrow-friendly scoped API: `run_tasks` /
//! `run_tasks_with` block until the phase has drained, so task closures
//! may borrow the step's plan and tensor views exactly as they could
//! with scoped spawns.
//!
//! The auto-thread override `LOWBIT_ENGINE_THREADS` is read **once per
//! process** (cached in a `OnceLock`) and consulted on the hot path from
//! that cache; `ci.sh`'s two-count test runs keep working by
//! construction because each `cargo test` invocation is its own process
//! with its own environment.
//!
//! # The audited unsafe boundary
//!
//! Every `unsafe` in the engine is a [`SharedSlice::range_mut`] call (or
//! supports one), and the disjointness those calls rely on is
//! **machine-checked**, not merely asserted, on two axes:
//!
//! * **Statically**: `rust/src/bin/lint.rs` (tier-1 test `unsafe_lint`)
//!   confines `unsafe` to the engine/offload/checkpoint allowlist,
//!   requires an adjacent `// SAFETY:` comment at every site, and keeps
//!   `#![forbid(unsafe_code)]` stamped on everything else.
//! * **Dynamically** (`--features audit`): each engine owns an
//!   [`audit::Registry`]; every `run_tasks{,_with,_dep}` call is one
//!   *phase* that advances the registry's epoch on entry and again
//!   after the pool drains, and every task body runs inside a task
//!   scope. `range_mut` then registers each materialized view's byte
//!   interval, and the auditor aborts — naming both call sites — on any
//!   overlap between views of *different* tasks in one phase that the
//!   phase's dependency edges (`run_tasks_dep`) do not order, on any
//!   out-of-bounds range, and on any view materialized after its
//!   phase's barrier (epoch mismatch — i.e. a worker escaped the pool
//!   drain). Worker-slot scratch (`run_tasks_with` / `run_tasks_dep`)
//!   registers under a per-slot scope in a disjoint id namespace, so
//!   slot exclusivity is audited by the same overlap rule.
//!
//! Epoch/phase rules, in short: *a view is live from its `range_mut`
//! until its phase's barrier*, and two live views may overlap only if
//! they belong to one task or to dependency-ordered tasks. Accesses
//! outside any phase (setup code, direct unit tests) are bounds-checked
//! but make no disjointness claim. When adding a new unsafe site: route
//! it through `range_mut` inside a task body of one of the `run_tasks*`
//! entry points, keep the touched range inside the task's plan pieces
//! (or its exclusive scratch slot), put a `// SAFETY:` comment on the
//! line above citing the plan invariant relied upon, and keep the file
//! inside the lint's allowlist — then `cargo test --features audit`
//! checks the claim on every schedule the suite runs.
//!
//! Audit-mode registries are engine-wide but reached through a
//! thread-local task scope, so concurrently running engines (e.g. the
//! test harness's parallel tests) never cross-talk.

pub mod adamw4;
#[cfg(feature = "audit")]
pub mod audit;
pub mod ctx;
pub mod dense;
pub mod plan;
pub mod pool;
pub mod shared;

pub use adamw4::{compressed_step, StepParams};
pub use ctx::{ArenaVec, StepContext, StepScratch, VecArena};
pub use plan::{build_plan, MetaSpec, Plan, StateLayout, TensorMeta};
pub use shared::SharedSlice;

use pool::WorkerPool;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// Default shard size in elements (~256 KB of f32 values per shard).
pub const DEFAULT_SHARD_ELEMS: usize = 1 << 16;

/// Below this much total work an auto-threaded engine stays sequential —
/// spawn overhead would dominate. Explicit thread counts are honored
/// regardless (the parity suite relies on that).
pub const MIN_PARALLEL_ELEMS: usize = 1 << 15;

/// Lazily created, grow-on-demand handle to the engine's persistent
/// [`WorkerPool`]. Clones of a `StepEngine` share one cell (and thus one
/// pool); the pool is created by the first parallel phase and replaced
/// with a larger one only if a later phase resolves to more workers.
struct PoolCell {
    inner: Mutex<Option<Arc<WorkerPool>>>,
}

impl PoolCell {
    fn ensure(&self, workers: usize) -> Arc<WorkerPool> {
        let mut guard = self.inner.lock().unwrap();
        match guard.as_ref() {
            Some(p) if p.workers() >= workers => Arc::clone(p),
            _ => {
                let p = Arc::new(WorkerPool::new(workers));
                *guard = Some(Arc::clone(&p));
                p
            }
        }
    }
}

impl std::fmt::Debug for PoolCell {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let workers = self
            .inner
            .lock()
            .ok()
            .and_then(|g| g.as_ref().map(|p| p.workers()));
        write!(f, "PoolCell({workers:?})")
    }
}

/// The task scheduler: each phase runs its tasks on the engine's
/// persistent worker pool, workers pulling task indices off an atomic
/// queue. Execution *order* is nondeterministic; results are not,
/// because each task is self-contained (see the module docs).
///
/// The pool outlives phases and steps (see the module docs' "Pool
/// lifecycle"), removing the former per-phase spawn tax; tiny workloads
/// still stay sequential via [`MIN_PARALLEL_ELEMS`] and never touch the
/// pool at all.
#[derive(Clone, Debug)]
pub struct StepEngine {
    /// Worker threads; 0 = auto (available parallelism).
    threads: usize,
    /// Target shard size in elements.
    shard_elems: usize,
    /// Persistent worker pool, shared by clones of this engine.
    pool: Arc<PoolCell>,
    /// Aliasing-auditor interval tracker, shared by clones of this
    /// engine (clones share the pool, so they share phases too).
    #[cfg(feature = "audit")]
    audit: Arc<audit::Registry>,
}

impl Default for StepEngine {
    fn default() -> StepEngine {
        StepEngine::new()
    }
}

impl StepEngine {
    pub fn new() -> StepEngine {
        StepEngine {
            threads: 0,
            shard_elems: DEFAULT_SHARD_ELEMS,
            pool: Arc::new(PoolCell {
                inner: Mutex::new(None),
            }),
            #[cfg(feature = "audit")]
            audit: Arc::new(audit::Registry::new()),
        }
    }

    /// Set the worker count (0 = auto).
    pub fn with_threads(mut self, threads: usize) -> StepEngine {
        self.threads = threads;
        self
    }

    /// Set the target shard size in elements (tests use small values to
    /// force multi-shard plans on small tensors).
    pub fn with_shard_elems(mut self, shard_elems: usize) -> StepEngine {
        assert!(shard_elems >= 2, "shard_elems must be at least 2");
        self.shard_elems = shard_elems;
        self
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    pub fn shard_elems(&self) -> usize {
        self.shard_elems
    }

    /// Worker count for a workload of `n_tasks` tasks over `total_elems`
    /// elements. Auto mode (threads = 0) stays sequential for small
    /// workloads; explicit counts are only clamped to the task count.
    pub fn resolve_threads(&self, n_tasks: usize, total_elems: usize) -> usize {
        let t = match self.threads {
            0 => {
                if total_elems < MIN_PARALLEL_ELEMS {
                    1
                } else {
                    auto_threads()
                }
            }
            n => n,
        };
        t.max(1).min(n_tasks.max(1))
    }

    /// Execute `f(task_index, scratch)` for every task index in
    /// `0..n_tasks` on `threads` workers. Each worker owns one scratch
    /// value (`S::default()`), reused across the tasks it runs. With
    /// `threads <= 1` this is a plain loop on the calling thread;
    /// otherwise the tasks run on the engine's persistent pool, and this
    /// call blocks until the phase has drained (so `f` may borrow the
    /// caller's stack exactly as under the old scoped spawns).
    pub fn run_tasks<S, F>(&self, threads: usize, n_tasks: usize, f: F)
    where
        S: Default + Send,
        F: Fn(usize, &mut S) + Sync,
    {
        if n_tasks == 0 {
            return;
        }
        // One `run_tasks*` call = one auditor phase: the guard opens a
        // fresh epoch now and retires every interval at return (i.e.
        // after the pool drained). See the module docs, "The audited
        // unsafe boundary".
        #[cfg(feature = "audit")]
        let _phase = audit::phase_scope(&self.audit, None);
        if threads <= 1 {
            let mut scratch = S::default();
            for i in 0..n_tasks {
                #[cfg(feature = "audit")]
                let _task = audit::task_scope(&self.audit, i as u64);
                f(i, &mut scratch);
            }
            return;
        }
        let next = AtomicUsize::new(0);
        let next = &next;
        let f = &f;
        #[cfg(feature = "audit")]
        let audit_reg = &self.audit;
        let body = move |_slot: usize| {
            let mut scratch = S::default();
            loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n_tasks {
                    break;
                }
                #[cfg(feature = "audit")]
                let _task = audit::task_scope(audit_reg, i as u64);
                f(i, &mut scratch);
            }
        };
        self.pool.ensure(threads).broadcast(threads, &body);
    }

    /// Execute an *interleaved* task queue with single-predecessor
    /// dependencies — the offload pipeline's transfer/compute discipline
    /// (see the module docs' "Transfer tasks and the dependency
    /// contract"). `deps[i]` names the queue entry that must complete
    /// before entry `i` may run; it must be `< i`, so the queue order is
    /// itself a valid sequential schedule (`threads <= 1` just runs the
    /// loop). On the pool, workers claim indices in order and spin-wait
    /// (with yields) on an unfinished dependency; because every
    /// dependency points at an earlier — hence already claimed — entry,
    /// the smallest unfinished entry is always runnable and the queue
    /// cannot deadlock at any worker count.
    ///
    /// Worker slot `w` exclusively uses `scratch[w]`, exactly as in
    /// [`Self::run_tasks_with`].
    pub fn run_tasks_dep<S, F>(
        &self,
        threads: usize,
        deps: &[Option<usize>],
        scratch: &mut [S],
        f: F,
    ) where
        S: Send,
        F: Fn(usize, &mut S) + Sync,
    {
        let n_tasks = deps.len();
        if n_tasks == 0 {
            return;
        }
        for (i, d) in deps.iter().enumerate() {
            if let Some(d) = *d {
                assert!(d < i, "dependency {d} of queue entry {i} must precede it");
            }
        }
        // Dependency-ordered phase: the auditor receives the edges so
        // that ordered entries may legally reuse a scratch range.
        #[cfg(feature = "audit")]
        let _phase = audit::phase_scope(&self.audit, Some(deps));
        if threads <= 1 {
            let s = &mut scratch[0];
            for i in 0..n_tasks {
                #[cfg(feature = "audit")]
                let _task = audit::task_scope(&self.audit, i as u64);
                f(i, &mut *s);
            }
            return;
        }
        assert!(
            scratch.len() >= threads,
            "scratch pool ({}) smaller than the worker count ({threads})",
            scratch.len()
        );
        let done: Vec<AtomicBool> = (0..n_tasks).map(|_| AtomicBool::new(false)).collect();
        let done = &done[..];
        let next = AtomicUsize::new(0);
        let next = &next;
        let f = &f;
        let deps = &deps[..];
        let scratch_view = SharedSlice::new(scratch);
        let scratch_view = &scratch_view;
        #[cfg(feature = "audit")]
        let audit_reg = &self.audit;
        let body = move |slot: usize| {
            #[cfg(feature = "audit")]
            let _worker = audit::task_scope(audit_reg, audit::SLOT_TASK_BASE + slot as u64);
            // SAFETY: the pool hands each broadcast participant a
            // distinct slot in 0..threads, so scratch entries have a
            // single owner.
            let slot_scratch = unsafe { scratch_view.range_mut(slot, slot + 1) };
            let s = &mut slot_scratch[0];
            loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n_tasks {
                    break;
                }
                if let Some(d) = deps[i] {
                    // The dependency was claimed before `i` (in-order
                    // claiming); its worker makes progress because the
                    // smallest unfinished entry never waits (deps point
                    // strictly backwards), so this spin terminates.
                    while !done[d].load(Ordering::Acquire) {
                        std::hint::spin_loop();
                        std::thread::yield_now();
                    }
                }
                #[cfg(feature = "audit")]
                let _task = audit::task_scope(audit_reg, i as u64);
                f(i, &mut *s);
                #[cfg(feature = "audit")]
                drop(_task);
                done[i].store(true, Ordering::Release);
            }
        };
        self.pool.ensure(threads).broadcast(threads, &body);
    }

    /// [`Self::run_tasks`] with caller-owned per-worker scratch: worker
    /// slot `w` exclusively uses `scratch[w]`, so the buffers persist
    /// across phases and steps (the compressed executor keeps them in
    /// its [`StepContext`], making the steady-state step allocation-
    /// free). `scratch` must hold at least `threads` entries.
    pub fn run_tasks_with<S, F>(&self, threads: usize, n_tasks: usize, scratch: &mut [S], f: F)
    where
        S: Send,
        F: Fn(usize, &mut S) + Sync,
    {
        if n_tasks == 0 {
            return;
        }
        #[cfg(feature = "audit")]
        let _phase = audit::phase_scope(&self.audit, None);
        if threads <= 1 {
            let s = &mut scratch[0];
            for i in 0..n_tasks {
                #[cfg(feature = "audit")]
                let _task = audit::task_scope(&self.audit, i as u64);
                f(i, &mut *s);
            }
            return;
        }
        assert!(
            scratch.len() >= threads,
            "scratch pool ({}) smaller than the worker count ({threads})",
            scratch.len()
        );
        let next = AtomicUsize::new(0);
        let next = &next;
        let f = &f;
        let scratch_view = SharedSlice::new(scratch);
        let scratch_view = &scratch_view;
        #[cfg(feature = "audit")]
        let audit_reg = &self.audit;
        let body = move |slot: usize| {
            #[cfg(feature = "audit")]
            let _worker = audit::task_scope(audit_reg, audit::SLOT_TASK_BASE + slot as u64);
            // SAFETY: the pool hands each broadcast participant a
            // distinct slot in 0..threads, so scratch entries have a
            // single owner.
            let slot_scratch = unsafe { scratch_view.range_mut(slot, slot + 1) };
            let s = &mut slot_scratch[0];
            loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n_tasks {
                    break;
                }
                #[cfg(feature = "audit")]
                let _task = audit::task_scope(audit_reg, i as u64);
                f(i, &mut *s);
            }
        };
        self.pool.ensure(threads).broadcast(threads, &body);
    }
}

/// Auto worker count: `LOWBIT_ENGINE_THREADS` when set (CI pins it to run
/// the whole test suite at a fixed count — see `ci.sh`), else the
/// machine's available parallelism. The override is read **once per
/// process** and cached — re-reading the environment on every
/// `resolve_threads` call put a syscall + allocation on the hot path.
/// Per-process semantics are exactly what `ci.sh` needs: each of its two
/// test runs is a separate process with its own environment. Only
/// consulted for workloads above [`MIN_PARALLEL_ELEMS`]; explicit
/// `with_threads` counts bypass it.
fn auto_threads() -> usize {
    static AUTO: std::sync::OnceLock<usize> = std::sync::OnceLock::new();
    *AUTO.get_or_init(|| {
        std::env::var("LOWBIT_ENGINE_THREADS")
            .ok()
            .and_then(|s| s.parse::<usize>().ok())
            .filter(|&n| n > 0)
            .unwrap_or_else(|| {
                std::thread::available_parallelism()
                    .map(|n| n.get())
                    .unwrap_or(1)
            })
    })
}

/// Per-step seed mixing: derives the seed for step `t` from the
/// optimizer's base seed so every step draws fresh per-shard streams
/// while staying reproducible.
pub fn step_seed(base: u64, t: u64) -> u64 {
    base ^ t.wrapping_mul(0x9e37_79b9_7f4a_7c15)
}

/// Stream-id namespace for phase C (re-encode) tasks, disjoint from the
/// phase A/F namespace which uses plain task indices.
pub const PHASE_C_STREAM_BASE: u64 = 1 << 32;

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn run_tasks_covers_every_index_once() {
        for threads in [1, 2, 7] {
            let hits: Vec<AtomicU64> = (0..100).map(|_| AtomicU64::new(0)).collect();
            let eng = StepEngine::new();
            eng.run_tasks::<(), _>(threads, 100, |i, _| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
            for (i, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::Relaxed), 1, "task {i} at {threads} threads");
            }
        }
    }

    #[test]
    fn resolve_threads_policy() {
        let eng = StepEngine::new(); // auto
        assert_eq!(eng.resolve_threads(10, 100), 1, "tiny work stays sequential");
        let eng2 = StepEngine::new().with_threads(7);
        assert_eq!(eng2.resolve_threads(3, 100), 3, "clamped to task count");
        assert_eq!(eng2.resolve_threads(100, 100), 7, "explicit count honored");
        assert_eq!(eng2.resolve_threads(0, 0), 1);
    }

    #[test]
    fn step_seed_varies_per_step() {
        assert_ne!(step_seed(1, 1), step_seed(1, 2));
        assert_eq!(step_seed(5, 3), step_seed(5, 3));
    }

    #[test]
    fn run_tasks_reuses_one_pool_across_phases() {
        // Many back-to-back parallel phases on one engine: the pool is
        // created once and reused (this is the spawn-tax fix; it also
        // stress-tests the broadcast protocol under reuse).
        let eng = StepEngine::new().with_threads(4);
        for round in 0..50 {
            let hits: Vec<AtomicU64> = (0..37).map(|_| AtomicU64::new(0)).collect();
            eng.run_tasks::<(), _>(4, 37, |i, _| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
            for (i, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::Relaxed), 1, "round {round} task {i}");
            }
        }
        let workers = eng.pool.inner.lock().unwrap().as_ref().map(|p| p.workers());
        assert_eq!(workers, Some(4), "pool created once with 4 workers");
    }

    #[test]
    fn run_tasks_with_gives_each_worker_its_own_scratch() {
        // Every task bumps its worker's scratch counter; the per-slot
        // totals must add up to the task count with no cross-talk, and
        // the caller keeps the scratch (persistent across phases).
        for threads in [1usize, 2, 5] {
            let eng = StepEngine::new().with_threads(threads);
            let mut scratch = vec![0usize; threads];
            let hits: Vec<AtomicU64> = (0..83).map(|_| AtomicU64::new(0)).collect();
            for _phase in 0..3 {
                eng.run_tasks_with(threads, 83, &mut scratch, |i, s: &mut usize| {
                    *s += 1;
                    hits[i].fetch_add(1, Ordering::Relaxed);
                });
            }
            assert_eq!(scratch.iter().sum::<usize>(), 3 * 83, "{threads} threads");
            for (i, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::Relaxed), 3, "task {i} at {threads} threads");
            }
        }
    }

    #[test]
    fn run_tasks_dep_honors_dependencies() {
        // Chain i -> i-3 (a depth-3 slot-reuse pattern): when a task
        // runs, its dependency must already have run, at every thread
        // count, and every entry runs exactly once.
        for threads in [1usize, 2, 7] {
            let n = 40;
            let deps: Vec<Option<usize>> =
                (0..n).map(|i| if i >= 3 { Some(i - 3) } else { None }).collect();
            let done: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
            let violations = AtomicU64::new(0);
            let eng = StepEngine::new().with_threads(threads);
            let mut scratch = vec![(); threads];
            eng.run_tasks_dep(threads, &deps, &mut scratch, |i, _: &mut ()| {
                if let Some(d) = deps[i] {
                    if done[d].load(Ordering::Acquire) == 0 {
                        violations.fetch_add(1, Ordering::Relaxed);
                    }
                }
                done[i].fetch_add(1, Ordering::Release);
            });
            assert_eq!(violations.load(Ordering::Relaxed), 0, "{threads} threads");
            for (i, d) in done.iter().enumerate() {
                assert_eq!(d.load(Ordering::Relaxed), 1, "entry {i} at {threads} threads");
            }
        }
    }

    #[test]
    #[should_panic(expected = "must precede it")]
    fn run_tasks_dep_rejects_forward_dependency() {
        let eng = StepEngine::new().with_threads(2);
        let mut scratch = vec![(); 2];
        eng.run_tasks_dep(2, &[Some(1), None], &mut scratch, |_i, _: &mut ()| {});
    }

    #[test]
    fn pool_grows_when_more_workers_are_requested() {
        let eng = StepEngine::new();
        eng.run_tasks::<(), _>(2, 16, |_i, _| {});
        eng.run_tasks::<(), _>(6, 16, |_i, _| {});
        let workers = eng.pool.inner.lock().unwrap().as_ref().map(|p| p.workers());
        assert_eq!(workers, Some(6), "pool grown to the largest request");
        // Shrinking requests keep the larger pool.
        eng.run_tasks::<(), _>(2, 16, |_i, _| {});
        let workers = eng.pool.inner.lock().unwrap().as_ref().map(|p| p.workers());
        assert_eq!(workers, Some(6));
    }
}
