//! The compressed-AdamW step executor: runs one optimizer step of
//! [`crate::optim::lowbit::CompressedAdamW`] on the shard plan.
//!
//! Responsibilities per phase (see the module docs in `mod.rs` for the
//! determinism contract):
//!
//! * **Phase F** — factored-v tensors: accumulate per-shard row/col
//!   partial sums of `g²` into stat slots; a sequential reduce applies
//!   the Adafactor EMA to the `FactoredSecond` state.
//! * **Phase A** — every shard: decompress its slice of m (and v),
//!   run the exact AdamW update in place on the weights, requantize
//!   block-normalized states shard-locally, and accumulate per-axis /
//!   per-tensor max-magnitude statistics for globally-normalized states.
//! * **Phase C** — globally-normalized (rank-1 / per-tensor) states:
//!   after the scale reduction, re-derive the updated state values from
//!   the *old* codes + gradient (bit-identical to what phase A computed)
//!   and encode them against the new global scales into the context's
//!   double-buffered packed arenas, which are swapped into the state
//!   vector at the end (the displaced buffers become next step's
//!   arenas).
//!
//! The per-piece math lives in **shard-local kernels** ([`update_piece`],
//! [`decode_ema_piece`]) that take plain slices covering exactly one
//! piece's data; their quantizer inner loops (decode, normalize, encode,
//! pack) run on the nibble-granular kernel layer of
//! [`crate::quant::kernels`] — pair-LUT decode, LUT/closed-form encode,
//! fused byte-at-a-time packing — which is bit-exact to the scalar
//! reference paths by the differential tests pinning that layer. The in-memory executor derives those slices from
//! absolute [`SharedSlice`] views over the resident state buffers; the
//! offload pipeline ([`crate::offload::pipeline`]) derives them from
//! *staged* device-scratch copies of host-resident state. Because both
//! paths run the same kernels with the same per-task RNG streams,
//! offloaded execution is bit-identical to in-memory execution by
//! construction.
//!
//! All cross-thread mutation goes through [`SharedSlice`] views over
//! disjoint shard ranges; every `unsafe` block names the plan invariant
//! (block / row / byte alignment) it relies on. The plan, metadata and
//! every reusable buffer live in the caller's [`StepContext`]; the
//! steady-state step is allocation-free (see `ctx.rs`).

use super::ctx::{GlobalSlot, StepContext, StepScratch, VecArena};
use super::plan::{MetaSpec, Piece, Plan, StateLayout, TensorMeta};
use super::shared::SharedSlice;
use super::{step_seed, Affinity, StepEngine, PHASE_C_STREAM_BASE};
use crate::obs::quant::QuantAccum;
#[cfg(feature = "trace")]
use crate::obs::trace::{
    now, P_ENGINE_A, P_ENGINE_C, P_ENGINE_COMMIT, P_ENGINE_F, P_ENGINE_REDUCE, TASK_NONE,
};
use crate::optim::factor::FactoredSecond;
use crate::optim::state::{MomentState, SecondState};
use crate::optim::{Hyper, Param};
use crate::quant::{
    dequantize_packed_range_into, kernels, packing, NormKind, QuantMap, QuantizedTensor,
    Quantizer, Scales,
};
use crate::tensor::Tensor;
use crate::util::rng::Pcg64;

/// Immutable per-step inputs threaded through the executor.
pub struct StepParams<'a> {
    pub hp: Hyper,
    /// 1-based step counter (bias correction).
    pub t: usize,
    pub lr: f32,
    /// Optimizer base seed; per-shard streams derive from
    /// `step_seed(base_seed, t)`.
    pub base_seed: u64,
    /// Cached decode tables (built once by the optimizer, borrowed here —
    /// never cloned on the hot path).
    pub m_map: Option<&'a QuantMap>,
    pub v_map: Option<&'a QuantMap>,
    pub v1_map: Option<&'a QuantMap>,
}

/// How a shard reaches one tensor's first-moment state.
///
/// Deliberately kept in lockstep with [`VRoute`] (which adds only the
/// `Factored` arm): any change to the Block/Global routing here must be
/// mirrored there and in both construction sites in `compressed_step`.
enum MRoute<'a> {
    F32(SharedSlice<'a, f32>),
    Block {
        q: Quantizer,
        map: &'a QuantMap,
        block: usize,
        packed: SharedSlice<'a, u8>,
        scales: SharedSlice<'a, f32>,
    },
    Global {
        q: Quantizer,
        map: &'a QuantMap,
        old: &'a QuantizedTensor,
        new_packed: SharedSlice<'a, u8>,
        buf: usize,
    },
}

/// How a shard reaches one tensor's second-moment state.
enum VRoute<'a> {
    F32(SharedSlice<'a, f32>),
    Block {
        q: Quantizer,
        map: &'a QuantMap,
        block: usize,
        packed: SharedSlice<'a, u8>,
        scales: SharedSlice<'a, f32>,
    },
    Global {
        q: Quantizer,
        map: &'a QuantMap,
        old: &'a QuantizedTensor,
        new_packed: SharedSlice<'a, u8>,
        buf: usize,
    },
    Factored {
        f: &'a FactoredSecond,
        row_mean: f32,
    },
}

/// Shared per-tensor context for the parallel phases.
struct TensorCtx<'a> {
    shape: &'a [usize],
    /// Trailing-axes slab size (`numel / shape[0]` for ≥2-D, else numel).
    cols: usize,
    w: SharedSlice<'a, f32>,
    g: &'a [f32],
    m: MRoute<'a>,
    v: VRoute<'a>,
}

/// Byte range of the packed code buffer holding elements `[lo, hi)`.
#[inline]
pub(crate) fn packed_range(bits: u8, lo: usize, hi: usize) -> (usize, usize) {
    if bits == 4 {
        (lo / 2, hi.div_ceil(2))
    } else {
        (lo, hi)
    }
}

/// Planner layout + stat-slot length for one quantized state.
fn layout_of(q: &Quantizer, shape: &[usize]) -> (StateLayout, usize) {
    match q.norm {
        NormKind::Block(b) => (StateLayout::Block(b), 0),
        NormKind::Rank1 if shape.len() >= 2 => (StateLayout::Global, shape.iter().sum()),
        // Per-tensor normalization, incl. rank-1's 1-D fallback.
        _ => (StateLayout::Global, 1),
    }
}

// ---------------------------------------------------------------------
// Shard-local piece kernels (shared with the offload pipeline).
// ---------------------------------------------------------------------

/// Shard-local view of one piece's first-moment storage, consumed by
/// [`update_piece`]. Every slice covers exactly the piece's own elements
/// (codes start at the piece's first element, scales at its first
/// block); only `stat` and the global `scales` are tensor-wide.
pub(crate) enum MSrc<'a> {
    F32(&'a mut [f32]),
    Block {
        q: Quantizer,
        map: &'a QuantMap,
        block: usize,
        /// Packed codes of exactly this piece's elements.
        packed: &'a mut [u8],
        /// Block scales of exactly this piece's blocks.
        scales: &'a mut [f32],
    },
    Global {
        q: Quantizer,
        map: &'a QuantMap,
        /// Old codes of exactly this piece's elements (read-only; the
        /// re-encode happens in phase C).
        packed: &'a [u8],
        /// The tensor's resident global scales.
        scales: &'a Scales,
        /// This piece's scale-statistics slot.
        stat: &'a mut [f32],
    },
}

/// Shard-local view of one piece's second-moment storage (adds the
/// factored arm to [`MSrc`]).
pub(crate) enum VSrc<'a> {
    F32(&'a mut [f32]),
    Block {
        q: Quantizer,
        map: &'a QuantMap,
        block: usize,
        packed: &'a mut [u8],
        scales: &'a mut [f32],
    },
    Global {
        q: Quantizer,
        map: &'a QuantMap,
        packed: &'a [u8],
        scales: &'a Scales,
        stat: &'a mut [f32],
    },
    Factored {
        f: &'a FactoredSecond,
        row_mean: f32,
    },
}

/// Post-update bookkeeping for one moment source: what [`update_piece`]
/// must do with the freshly updated values.
enum Requant<'a> {
    /// Dense f32 state was updated in place — nothing left to do.
    None,
    /// Block-normalized: requantize the piece in place.
    Block {
        q: Quantizer,
        map: &'a QuantMap,
        block: usize,
        packed: &'a mut [u8],
        scales: &'a mut [f32],
    },
    /// Globally-normalized: accumulate scale statistics; phase C encodes.
    Stats(&'a mut [f32]),
}

/// Phase-A update for one piece on shard-local data: decompress m (and
/// v), run the exact AdamW update on `w`, requantize block-normalized
/// states in place and accumulate scale statistics for the
/// globally-normalized ones. `lo` is the piece's flat element offset in
/// its tensor — the rank-1 statistics and factored reconstruction need
/// absolute coordinates even though every data slice is local.
///
/// RNG consumption order is fixed (v encode, then m encode), so the
/// in-memory executor and the offload pipeline draw identical
/// stochastic-rounding streams — the foundation of the offloaded
/// bit-identity guarantee.
#[allow(clippy::too_many_arguments)]
pub(crate) fn update_piece(
    tensor: usize,
    lo: usize,
    shape: &[usize],
    cols: usize,
    w: &mut [f32],
    g: &[f32],
    m: MSrc<'_>,
    v: VSrc<'_>,
    hp: &Hyper,
    t: usize,
    lr: f32,
    scratch: &mut StepScratch,
    rng: &mut Pcg64,
) {
    let len = g.len();
    debug_assert_eq!(w.len(), len);
    let hi = lo + len;
    let StepScratch {
        m: sm,
        v: sv,
        quant,
        ..
    } = scratch;

    // ---- load the first moment ----
    let (m_vals, m_re): (&mut [f32], Requant<'_>) = match m {
        MSrc::F32(s) => (s, Requant::None),
        MSrc::Block {
            q,
            map,
            block,
            packed,
            scales,
        } => {
            sm.resize(len, 0.0);
            dequant_block_slice(map, q.bits, block, packed, scales, &mut sm[..len]);
            (
                &mut sm[..len],
                Requant::Block {
                    q,
                    map,
                    block,
                    packed,
                    scales,
                },
            )
        }
        MSrc::Global {
            q,
            map,
            packed,
            scales,
            stat,
        } => {
            sm.resize(len, 0.0);
            dequantize_packed_range_into(
                map,
                q.bits,
                packed,
                lo,
                scales,
                shape,
                lo,
                hi,
                &mut sm[..len],
            );
            (&mut sm[..len], Requant::Stats(stat))
        }
    };

    let b1 = hp.beta1;
    let b2 = hp.beta2;
    let bc1 = 1.0 - b1.powi(t as i32);
    let bc2 = 1.0 - b2.powi(t as i32);

    // ---- update (exact AdamW; mirrors adamw_update_tensor) ----
    match v {
        VSrc::Factored { f, row_mean } => {
            for k in 0..len {
                let gi = g[k];
                let mi = b1 * m_vals[k] + (1.0 - b1) * gi;
                m_vals[k] = mi;
                let idx = lo + k;
                let vhat = f.reconstruct_at(idx / cols, idx % cols, row_mean) / bc2;
                let wi = w[k];
                let upd = (mi / bc1) / (vhat.sqrt() + hp.eps) + hp.weight_decay * wi;
                w[k] = wi - lr * upd;
            }
        }
        v_src => {
            let (v_vals, v_re): (&mut [f32], Requant<'_>) = match v_src {
                VSrc::F32(s) => (s, Requant::None),
                VSrc::Block {
                    q,
                    map,
                    block,
                    packed,
                    scales,
                } => {
                    sv.resize(len, 0.0);
                    dequant_block_slice(map, q.bits, block, packed, scales, &mut sv[..len]);
                    (
                        &mut sv[..len],
                        Requant::Block {
                            q,
                            map,
                            block,
                            packed,
                            scales,
                        },
                    )
                }
                VSrc::Global {
                    q,
                    map,
                    packed,
                    scales,
                    stat,
                } => {
                    sv.resize(len, 0.0);
                    dequantize_packed_range_into(
                        map,
                        q.bits,
                        packed,
                        lo,
                        scales,
                        shape,
                        lo,
                        hi,
                        &mut sv[..len],
                    );
                    (&mut sv[..len], Requant::Stats(stat))
                }
                VSrc::Factored { .. } => unreachable!(),
            };
            for k in 0..len {
                let gi = g[k];
                let mi = b1 * m_vals[k] + (1.0 - b1) * gi;
                let vi = b2 * v_vals[k] + (1.0 - b2) * gi * gi;
                m_vals[k] = mi;
                v_vals[k] = vi;
                let mhat = mi / bc1;
                let vhat = vi / bc2;
                let wi = w[k];
                w[k] = wi - lr * (mhat / (vhat.sqrt() + hp.eps) + hp.weight_decay * wi);
            }
            // ---- requantize / accumulate v ----
            match v_re {
                Requant::None => {}
                Requant::Block {
                    q,
                    map,
                    block,
                    packed,
                    scales,
                } => {
                    q.encode_block_range(map, v_vals, block, scales, packed, rng);
                    if let Some(acc) = quant.as_mut() {
                        observe_block_encode(
                            acc, true, tensor, v_vals, block, packed, scales, q.bits, map,
                        );
                    }
                }
                Requant::Stats(stat) => {
                    accumulate_scale_stats(v_vals, lo, shape, stat);
                }
            }
        }
    }

    // ---- requantize / accumulate m ----
    match m_re {
        Requant::None => {}
        Requant::Block {
            q,
            map,
            block,
            packed,
            scales,
        } => {
            q.encode_block_range(map, m_vals, block, scales, packed, rng);
            if let Some(acc) = quant.as_mut() {
                observe_block_encode(acc, false, tensor, m_vals, block, packed, scales, q.bits, map);
            }
        }
        Requant::Stats(stat) => {
            accumulate_scale_stats(m_vals, lo, shape, stat);
        }
    }
}

/// Quant-metrics tap for a block-normalized piece encode: re-derive each
/// emitted code's decoded value from the map's value table (the same
/// source the decode LUTs are built from, so `x̂` is bit-identical to a
/// real decode) and feed the per-worker accumulator. Observational only
/// — runs after the encode and never touches the RNG.
#[allow(clippy::too_many_arguments)]
fn observe_block_encode(
    acc: &mut QuantAccum,
    second: bool,
    tensor: usize,
    vals: &[f32],
    block: usize,
    packed: &[u8],
    scales: &[f32],
    bits: u8,
    map: &QuantMap,
) {
    let zc = map.zero_code();
    for (k, &x) in vals.iter().enumerate() {
        let code = packing::get(packed, k, bits);
        let s = scales[k / block];
        let xhat = map.values[code as usize] * s;
        if second {
            acc.observe_v(tensor, x, xhat, s);
            acc.v.observe_code(code, bits, zc);
        } else {
            acc.observe_m(tensor, x, xhat, s);
            acc.m.observe_code(code, bits, zc);
        }
    }
}

/// Quant-metrics tap for a globally-normalized phase-C piece encode:
/// `vals` are the pre-encode fp32 values, `decoded` the round-tripped
/// post-encode values (decoded through the canonical
/// [`dequantize_packed_range_into`] path), `packed` the freshly encoded
/// piece-local codes.
#[allow(clippy::too_many_arguments)]
fn observe_global_encode(
    acc: &mut QuantAccum,
    second: bool,
    tensor: usize,
    vals: &[f32],
    decoded: &[f32],
    packed: &[u8],
    lo: usize,
    shape: &[usize],
    scales: &Scales,
    bits: u8,
    map: &QuantMap,
) {
    let zc = map.zero_code();
    for (k, (&x, &xhat)) in vals.iter().zip(decoded.iter()).enumerate() {
        // Piece-local index k addresses the piece-local packed slice
        // directly: shard boundaries are byte-aligned, so lo is even
        // for 4-bit codes and nibble parity is preserved.
        let code = packing::get(packed, k, bits);
        let s = scales.scale_at(lo + k, shape);
        if second {
            acc.observe_v(tensor, x, xhat, s);
            acc.v.observe_code(code, bits, zc);
        } else {
            acc.observe_m(tensor, x, xhat, s);
            acc.m.observe_code(code, bits, zc);
        }
    }
}

/// Phase-C value re-derivation for one globally-normalized state piece:
/// decode the *old* codes of elements `[lo, lo + g.len())` from a
/// shard-local slice and apply the moment EMA with the gradient —
/// bit-identical to the value phase A computed from the same inputs.
/// `second` selects the `g²` (second-moment) form. The caller encodes
/// `out` against the reduced global scales afterwards
/// ([`Quantizer::encode_range_with_scales`]); splitting decode from
/// encode lets the offload pipeline re-encode *in place* over the staged
/// slot that held the old codes.
#[allow(clippy::too_many_arguments)]
pub(crate) fn decode_ema_piece(
    bits: u8,
    map: &QuantMap,
    old_packed: &[u8],
    old_scales: &Scales,
    lo: usize,
    shape: &[usize],
    g: &[f32],
    beta: f32,
    second: bool,
    out: &mut Vec<f32>,
) {
    let len = g.len();
    out.resize(len, 0.0);
    dequantize_packed_range_into(
        map,
        bits,
        old_packed,
        lo,
        old_scales,
        shape,
        lo,
        lo + len,
        &mut out[..len],
    );
    if second {
        for (vv, &gv) in out[..len].iter_mut().zip(g.iter()) {
            *vv = beta * *vv + (1.0 - beta) * gv * gv;
        }
    } else {
        for (mv, &gv) in out[..len].iter_mut().zip(g.iter()) {
            *mv = beta * *mv + (1.0 - beta) * gv;
        }
    }
}

// ---------------------------------------------------------------------
// Shared context construction, phase F, reductions and commit.
// ---------------------------------------------------------------------

/// Validate/rebuild the cached step context against the live compressed
/// states — the single meta/plan construction route shared by the
/// in-memory executor and the offload pipeline — including the
/// globally-normalized state bookkeeping on a rebuild. With
/// `alloc_reencode_bufs` the phase-C double-buffer arenas are allocated
/// too (the in-memory executor swap-commits through them; the offload
/// pipeline re-encodes in place at the host tier and leaves them empty).
pub(crate) fn ensure_compressed_ctx(
    ctx: &mut StepContext,
    shard_elems: usize,
    params: &[Param],
    m_states: &[MomentState],
    v_states: &[SecondState],
    alloc_reencode_bufs: bool,
) -> bool {
    let n = params.len();
    let rebuilt = ctx.ensure(shard_elems, n, |i| {
        let shape: &[usize] = &params[i].tensor.shape;
        let (m, m_stat_len) = match &m_states[i] {
            MomentState::F32(_) => (StateLayout::F32, 0),
            MomentState::Quant(q) => layout_of(&q.quantizer, shape),
        };
        let (v, v_stat_len) = match &v_states[i] {
            SecondState::F32(_) => (StateLayout::F32, 0),
            SecondState::Quant(q) => layout_of(&q.quantizer, shape),
            SecondState::Factored(f) => (StateLayout::Factored, f.rows() + f.cols()),
        };
        MetaSpec {
            numel: params[i].tensor.numel(),
            shape,
            m,
            v,
            m_stat_len,
            v_stat_len,
        }
    });
    if rebuilt {
        // Re-derive the globally-normalized state bookkeeping: buffer
        // maps and zeroed double-buffer arenas (the per-step encode
        // overwrites every byte its pieces cover, so arena contents
        // never leak between steps).
        ctx.m_buf_of.resize(n, usize::MAX);
        ctx.v_buf_of.resize(n, usize::MAX);
        for i in 0..n {
            for is_m in [true, false] {
                let layout = if is_m { ctx.metas[i].m } else { ctx.metas[i].v };
                if layout != StateLayout::Global {
                    continue;
                }
                let q = if is_m {
                    match &m_states[i] {
                        MomentState::Quant(qt) => qt.quantizer,
                        _ => unreachable!("meta says quantized m"),
                    }
                } else {
                    match &v_states[i] {
                        SecondState::Quant(qt) => qt.quantizer,
                        _ => unreachable!("meta says quantized v"),
                    }
                };
                let buf = ctx.new_bufs.len();
                if is_m {
                    ctx.m_buf_of[i] = buf;
                } else {
                    ctx.v_buf_of[i] = buf;
                }
                ctx.globals.push(GlobalSlot {
                    tensor: i,
                    is_m,
                    q,
                    buf,
                });
                ctx.new_bufs.push(if alloc_reencode_bufs {
                    vec![0u8; packing::packed_len(ctx.metas[i].numel, q.bits)]
                } else {
                    Vec::new()
                });
                ctx.new_scales.push(None);
            }
        }
    }
    rebuilt
}

/// Phase F: factored-v statistics. Parallel per-shard row/col partial
/// sums of `g²` into stat slots, then the sequential shard-order reduce
/// + Adafactor EMA (mirrors `FactoredSecond::update` with eps2 = 0).
/// Shared by the in-memory executor and the offload pipeline — factored
/// statistics are sublinear in the tensor size, so they stay
/// device-resident under offload.
#[allow(clippy::too_many_arguments)]
pub(crate) fn phase_f(
    eng: &StepEngine,
    threads: usize,
    plan: &Plan,
    metas: &[TensorMeta],
    slots: &mut [Vec<f32>],
    red: &mut [f32],
    arena: &VecArena,
    grads: &[Tensor],
    hp: &Hyper,
    v_states: &mut [SecondState],
    aff: &mut Affinity,
) {
    {
        let mut slot_views = arena.lease::<SharedSlice<f32>>();
        slot_views.extend(slots.iter_mut().map(|s| SharedSlice::new(s.as_mut_slice())));
        let slot_views = slot_views.as_slice();
        let plan_ref = plan;
        let metas_ref = metas;
        eng.run_tasks_in::<(), _>(threads, plan.tasks.len(), aff, |ti, _| {
            for piece in &plan_ref.tasks[ti].pieces {
                let meta = &metas_ref[piece.tensor];
                if meta.v != StateLayout::Factored {
                    continue;
                }
                let rows_total = meta.shape[0];
                let cols = meta.numel / rows_total;
                let slot_id = piece.v_slot.expect("factored piece has a stat slot");
                // SAFETY: each piece owns its stat slot exclusively
                // (plan assigns one slot per piece).
                let slot = unsafe { slot_views[slot_id].range_mut(0, plan_ref.slot_lens[slot_id]) };
                let (rsum, csum) = slot.split_at_mut(rows_total);
                let g = &grads[piece.tensor].data[piece.lo..piece.hi];
                let row0 = piece.lo / cols;
                for (ri, grow) in g.chunks(cols).enumerate() {
                    let mut acc = 0.0f32;
                    for (j, &gv) in grow.iter().enumerate() {
                        let sq = gv * gv;
                        acc += sq;
                        csum[j] += sq;
                    }
                    rsum[row0 + ri] = acc;
                }
            }
        });
    }
    // Sequential reduce in shard order + Adafactor EMA, accumulated in
    // the context's reusable reduction scratch.
    for i in 0..metas.len() {
        if metas[i].v != StateLayout::Factored {
            continue;
        }
        let f = match &mut v_states[i] {
            SecondState::Factored(f) => f,
            _ => unreachable!("meta says factored"),
        };
        let rows = f.rows();
        let cols = f.cols();
        let (rsum, csum) = red[..rows + cols].split_at_mut(rows);
        rsum.fill(0.0);
        csum.fill(0.0);
        for task in &plan.tasks {
            for p in task.pieces.iter().filter(|p| p.tensor == i) {
                let s = &slots[p.v_slot.expect("factored slot")];
                for (a, b) in rsum.iter_mut().zip(&s[..rows]) {
                    *a += *b;
                }
                for (a, b) in csum.iter_mut().zip(&s[rows..]) {
                    *a += *b;
                }
            }
        }
        for (ri, r) in f.row.iter_mut().enumerate() {
            *r = hp.beta2 * *r + (1.0 - hp.beta2) * (rsum[ri] / cols as f32);
        }
        for (cj, c) in f.col.iter_mut().enumerate() {
            *c = hp.beta2 * *c + (1.0 - hp.beta2) * (csum[cj] / rows as f32);
        }
    }
}

/// Reduce phase-A scale statistics across shards (sequentially, in shard
/// order) into recycled `Scales` values for every globally-normalized
/// state. The reduced scales overwrite the *recycled* storage swapped
/// out of the states by the previous step's commit, so the steady state
/// builds no fresh scale vectors.
pub(crate) fn reduce_global_scales(
    plan: &Plan,
    metas: &[TensorMeta],
    globals: &[GlobalSlot],
    slots: &[Vec<f32>],
    red: &mut [f32],
    new_scales: &mut [Option<Scales>],
) {
    for gs in globals {
        let meta = &metas[gs.tensor];
        let stat_len = if gs.is_m {
            meta.m_stat_len
        } else {
            meta.v_stat_len
        };
        let acc = &mut red[..stat_len];
        acc.fill(0.0);
        for task in &plan.tasks {
            for p in task.pieces.iter().filter(|p| p.tensor == gs.tensor) {
                let slot_id = if gs.is_m { p.m_slot } else { p.v_slot };
                let s = &slots[slot_id.expect("global state has a slot")];
                for (a, b) in acc.iter_mut().zip(s.iter()) {
                    if *b > *a {
                        *a = *b;
                    }
                }
            }
        }
        write_scales(&mut new_scales[gs.buf], acc, &meta.shape);
    }
}

/// Commit the reduced scales (and, when `new_bufs` is given, the
/// freshly encoded packed double buffers) into the quantized states by
/// swapping — the displaced storage returns to the context to be
/// overwritten next step. The offload pipeline passes `None`: it has
/// already written the fresh codes back to the host buffers in place.
pub(crate) fn commit_globals(
    globals: &[GlobalSlot],
    mut new_bufs: Option<&mut [Vec<u8>]>,
    new_scales: &mut [Option<Scales>],
    m_states: &mut [MomentState],
    v_states: &mut [SecondState],
) {
    for gs in globals {
        let qt = if gs.is_m {
            match &mut m_states[gs.tensor] {
                MomentState::Quant(qt) => qt,
                _ => unreachable!("meta says quantized m"),
            }
        } else {
            match &mut v_states[gs.tensor] {
                SecondState::Quant(qt) => qt,
                _ => unreachable!("meta says quantized v"),
            }
        };
        if let Some(bufs) = new_bufs.as_mut() {
            std::mem::swap(&mut qt.packed, &mut bufs[gs.buf]);
        }
        let ns = new_scales[gs.buf].as_mut().expect("reduced scales");
        std::mem::swap(&mut qt.scales, ns);
    }
}

/// One optimizer step, shard-parallel. `m_states` / `v_states` must be
/// initialized (one entry per parameter, as after `lazy_init`). The
/// plan, metadata, stat slots, per-worker scratch and the re-encode
/// double buffers all live in `ctx` and are reused across steps; a
/// layout or shard-size change rebuilds them (see `ctx.rs`).
pub fn compressed_step(
    eng: &StepEngine,
    ctx: &mut StepContext,
    sp: &StepParams,
    params: &mut [Param],
    grads: &[Tensor],
    m_states: &mut [MomentState],
    v_states: &mut [SecondState],
) {
    let n = params.len();
    debug_assert_eq!(grads.len(), n);
    debug_assert_eq!(m_states.len(), n);
    debug_assert_eq!(v_states.len(), n);

    ensure_compressed_ctx(ctx, eng.shard_elems(), params, m_states, v_states, true);
    if ctx.plan.tasks.is_empty() {
        return;
    }
    ctx.begin_step();
    let threads = eng.resolve_threads(ctx.plan.tasks.len(), ctx.plan.total_elems);
    ctx.ensure_scratch(threads);

    // Split the context into disjoint field borrows for the phases.
    let StepContext {
        metas,
        plan,
        slots,
        scratch,
        red,
        globals,
        new_bufs,
        new_scales,
        m_buf_of,
        v_buf_of,
        arena,
        affinity,
        quant,
        #[cfg(feature = "trace")]
        trace,
        ..
    } = ctx;
    let plan = &*plan;
    let metas = &*metas;
    let globals = &*globals;
    let (m_buf_of, v_buf_of) = (&*m_buf_of, &*v_buf_of);

    // Arm the per-worker quant accumulators (runtime-gated). The
    // `get_or_insert_with` allocates only on the first metered step;
    // warm steps clear in place and `ensure_tensors` is grow-only.
    let metrics_on = quant.is_some();
    if metrics_on {
        for s in scratch[..threads].iter_mut() {
            let acc = s.quant.get_or_insert_with(QuantAccum::default);
            acc.ensure_tensors(n);
            acc.clear();
        }
    }

    let seed = step_seed(sp.base_seed, sp.t as u64);
    let hp = sp.hp;

    // ---------------- Phase F: factored-v statistics -----------------
    if metas.iter().any(|m| m.v == StateLayout::Factored) {
        #[cfg(feature = "trace")]
        let _t0 = now();
        phase_f(eng, threads, plan, metas, slots, red, arena, grads, &hp, v_states, affinity);
        #[cfg(feature = "trace")]
        trace.record(P_ENGINE_F, TASK_NONE, _t0);
    }

    {
        let mut buf_views = arena.lease::<SharedSlice<u8>>();
        buf_views.extend(new_bufs.iter_mut().map(|b| SharedSlice::new(b.as_mut_slice())));
        let buf_views = buf_views.as_slice();

        // Per-tensor contexts: disjoint &mut borrows of weights and
        // states, wrapped in shared views for the task closures. These
        // borrow the step's params/states, so only their heap capacity
        // is reused (leased from the context's arena).
        let mut ctxs = arena.lease::<TensorCtx>();
        ctxs.reserve(n);
        for (i, ((p, ms), vs)) in params
            .iter_mut()
            .zip(m_states.iter_mut())
            .zip(v_states.iter_mut())
            .enumerate()
        {
            let shape: &[usize] = &metas[i].shape;
            let cols = if shape.len() >= 2 {
                metas[i].numel / shape[0]
            } else {
                metas[i].numel
            };
            let m_route = match ms {
                MomentState::F32(tns) => MRoute::F32(SharedSlice::new(tns.data.as_mut_slice())),
                MomentState::Quant(qt) => {
                    let q = qt.quantizer;
                    let map = sp.m_map.expect("cached m map exists for quantized m");
                    if let NormKind::Block(b) = q.norm {
                        let QuantizedTensor { packed, scales, .. } = qt;
                        let sc = match scales {
                            Scales::Block { scales, .. } => scales,
                            _ => unreachable!("block-normed state carries block scales"),
                        };
                        MRoute::Block {
                            q,
                            map,
                            block: b,
                            packed: SharedSlice::new(packed.as_mut_slice()),
                            scales: SharedSlice::new(sc.as_mut_slice()),
                        }
                    } else {
                        MRoute::Global {
                            q,
                            map,
                            old: &*qt,
                            new_packed: buf_views[m_buf_of[i]],
                            buf: m_buf_of[i],
                        }
                    }
                }
            };
            let v_route = match vs {
                SecondState::F32(tns) => VRoute::F32(SharedSlice::new(tns.data.as_mut_slice())),
                SecondState::Factored(f) => {
                    // Phase F has already applied the EMA, so this is the
                    // post-update row mean (as the update formula needs).
                    let row_mean = f.row_mean();
                    VRoute::Factored { f: &*f, row_mean }
                }
                SecondState::Quant(qt) => {
                    let q = qt.quantizer;
                    let map = if shape.len() >= 2 { sp.v_map } else { sp.v1_map }
                        .expect("cached v map exists for quantized v");
                    if let NormKind::Block(b) = q.norm {
                        let QuantizedTensor { packed, scales, .. } = qt;
                        let sc = match scales {
                            Scales::Block { scales, .. } => scales,
                            _ => unreachable!("block-normed state carries block scales"),
                        };
                        VRoute::Block {
                            q,
                            map,
                            block: b,
                            packed: SharedSlice::new(packed.as_mut_slice()),
                            scales: SharedSlice::new(sc.as_mut_slice()),
                        }
                    } else {
                        VRoute::Global {
                            q,
                            map,
                            old: &*qt,
                            new_packed: buf_views[v_buf_of[i]],
                            buf: v_buf_of[i],
                        }
                    }
                }
            };
            ctxs.push(TensorCtx {
                shape,
                cols,
                w: SharedSlice::new(p.tensor.data.as_mut_slice()),
                g: &grads[i].data,
                m: m_route,
                v: v_route,
            });
        }
        let ctxs = ctxs.as_slice();

        // -------------------- Phase A: the update --------------------
        {
            let mut slot_views = arena.lease::<SharedSlice<f32>>();
            slot_views.extend(slots.iter_mut().map(|s| SharedSlice::new(s.as_mut_slice())));
            let slot_views = slot_views.as_slice();
            let plan_ref = plan;
            #[cfg(feature = "trace")]
            let _t0 = now();
            eng.run_tasks_with_in(
                threads,
                plan.tasks.len(),
                affinity,
                &mut scratch[..],
                |ti, scratch| {
                    #[cfg(feature = "trace")]
                    let _ts = now();
                    let mut rng = Pcg64::new(seed, ti as u64);
                    for piece in &plan_ref.tasks[ti].pieces {
                        phase_a_piece(piece, ctxs, slot_views, &hp, sp.t, sp.lr, scratch, &mut rng);
                    }
                    #[cfg(feature = "trace")]
                    scratch.ring.record(P_ENGINE_A, ti as u32, _ts);
                },
            );
            #[cfg(feature = "trace")]
            trace.record(P_ENGINE_A, TASK_NONE, _t0);
        }

        // ---------- Reduce A→C: combine scale statistics -------------
        {
            #[cfg(feature = "trace")]
            let _t0 = now();
            reduce_global_scales(plan, metas, globals, slots, red, new_scales);
            #[cfg(feature = "trace")]
            trace.record(P_ENGINE_REDUCE, TASK_NONE, _t0);
        }

        // --------------- Phase C: global re-encode -------------------
        if !globals.is_empty() {
            let plan_ref = plan;
            let new_scales_ref: &[Option<Scales>] = &new_scales[..];
            #[cfg(feature = "trace")]
            let _t0 = now();
            eng.run_tasks_with_in(
                threads,
                plan.tasks.len(),
                affinity,
                &mut scratch[..],
                |ti, scratch| {
                    #[cfg(feature = "trace")]
                    let _ts = now();
                    let mut rng = Pcg64::new(seed, PHASE_C_STREAM_BASE + ti as u64);
                    for piece in &plan_ref.tasks[ti].pieces {
                        phase_c_piece(piece, ctxs, new_scales_ref, &hp, scratch, &mut rng);
                    }
                    #[cfg(feature = "trace")]
                    scratch.ring.record(P_ENGINE_C, ti as u32, _ts);
                },
            );
            #[cfg(feature = "trace")]
            trace.record(P_ENGINE_C, TASK_NONE, _t0);
        }
    }

    // ------------------ Commit re-encoded states ---------------------
    // Double-buffer swap: the freshly encoded packed bytes and reduced
    // scales move into the state, and the state's previous buffers move
    // back into the context to be overwritten next step. No allocation,
    // no copy.
    {
        #[cfg(feature = "trace")]
        let _t0 = now();
        commit_globals(globals, Some(&mut new_bufs[..]), new_scales, m_states, v_states);
        #[cfg(feature = "trace")]
        trace.record(P_ENGINE_COMMIT, TASK_NONE, _t0);
    }

    // Fold the per-worker quant accumulators into the context's merged
    // one, in worker-slot order. Integer counters are order-independent;
    // the f64 error sums are slot-order deterministic (see obs::quant).
    if metrics_on {
        let total = quant.as_mut().expect("metrics_on implies an armed accumulator");
        total.ensure_tensors(n);
        total.clear();
        for s in scratch[..threads].iter() {
            if let Some(acc) = &s.quant {
                total.merge(acc);
            }
        }
    }
}

/// Write the reduced scale statistics into a (possibly recycled)
/// `Scales` value: reuse the previous step's storage when its layout
/// matches, build it fresh otherwise (first step after a rebuild).
fn write_scales(dst: &mut Option<Scales>, acc: &[f32], shape: &[usize]) {
    if acc.len() == 1 {
        match dst {
            Some(Scales::PerTensor(x)) => *x = acc[0],
            _ => *dst = Some(Scales::PerTensor(acc[0])),
        }
        return;
    }
    if let Some(Scales::Rank1 { per_axis }) = dst {
        if per_axis.len() == shape.len()
            && per_axis.iter().zip(shape.iter()).all(|(v, &d)| v.len() == d)
        {
            let mut off = 0;
            for (v, &d) in per_axis.iter_mut().zip(shape.iter()) {
                v.copy_from_slice(&acc[off..off + d]);
                off += d;
            }
            return;
        }
    }
    let mut per_axis = Vec::with_capacity(shape.len());
    let mut off = 0;
    for &d in shape {
        per_axis.push(acc[off..off + d].to_vec());
        off += d;
    }
    *dst = Some(Scales::Rank1 { per_axis });
}

/// Decompress block-quantized elements `[lo, lo + out.len())` from local
/// packed/scale slices (both starting at the shard boundary). Shard
/// boundaries are block-aligned (plan invariant), so every chunk is one
/// constant-scale fused pair-LUT run (§Perf, `quant::kernels`) — no
/// per-element unpack, parity branch, or `k / block`.
fn dequant_block_slice(
    map: &QuantMap,
    bits: u8,
    block: usize,
    packed: &[u8],
    scales: &[f32],
    out: &mut [f32],
) {
    for (bi, chunk) in out.chunks_mut(block).enumerate() {
        kernels::decode_run_scaled(map, bits, packed, bi * block, scales[bi], chunk);
    }
}

/// Accumulate max-magnitude scale statistics of `vals` (elements starting
/// at flat offset `lo` of a tensor with `shape`) into a stat slot:
/// one f32 for per-tensor scales, concatenated per-axis vectors for
/// rank-1.
fn accumulate_scale_stats(vals: &[f32], lo: usize, shape: &[usize], slot: &mut [f32]) {
    if slot.len() == 1 {
        let mut m = slot[0];
        for &v in vals {
            let a = v.abs();
            if a > m {
                m = a;
            }
        }
        slot[0] = m;
        return;
    }
    if shape.len() == 2 {
        let cols = shape[1];
        let (rs, cs) = slot.split_at_mut(shape[0]);
        let hi = lo + vals.len();
        let mut i = lo;
        while i < hi {
            let row = i / cols;
            let row_start = row * cols;
            let row_end = (row_start + cols).min(hi);
            let mut rmax = rs[row];
            for j in i..row_end {
                let a = vals[j - lo].abs();
                if a > rmax {
                    rmax = a;
                }
                let c = &mut cs[j - row_start];
                if a > *c {
                    *c = a;
                }
            }
            rs[row] = rmax;
            i = row_end;
        }
        return;
    }
    // Generic N-d: walk row-major coordinates incrementally.
    let mut coords = vec![0usize; shape.len()];
    let mut idx = lo;
    for (axis, &d) in shape.iter().enumerate().rev() {
        coords[axis] = idx % d;
        idx /= d;
    }
    for &v in vals {
        let a = v.abs();
        let mut off = 0;
        for (axis, &d) in shape.iter().enumerate() {
            let s = &mut slot[off + coords[axis]];
            if a > *s {
                *s = a;
            }
            off += d;
        }
        for axis in (0..shape.len()).rev() {
            coords[axis] += 1;
            if coords[axis] < shape[axis] {
                break;
            }
            coords[axis] = 0;
        }
    }
}

/// Phase A for one piece: derive the shard-local slices from the
/// absolute views and run the shared [`update_piece`] kernel.
#[allow(clippy::too_many_arguments)]
fn phase_a_piece(
    piece: &Piece,
    ctxs: &[TensorCtx<'_>],
    slot_views: &[SharedSlice<'_, f32>],
    hp: &Hyper,
    t: usize,
    lr: f32,
    scratch: &mut StepScratch,
    rng: &mut Pcg64,
) {
    let tc = &ctxs[piece.tensor];
    let (lo, hi) = (piece.lo, piece.hi);
    let g = &tc.g[lo..hi];
    // SAFETY: pieces partition each tensor disjointly (plan invariant),
    // so this shard is the only writer of w[lo..hi).
    let w = unsafe { tc.w.range_mut(lo, hi) };

    let m_src = match &tc.m {
        // SAFETY: disjoint shard ranges (plan invariant).
        MRoute::F32(s) => MSrc::F32(unsafe { s.range_mut(lo, hi) }),
        MRoute::Block {
            q,
            map,
            block,
            packed,
            scales,
        } => {
            let (b0, b1) = packed_range(q.bits, lo, hi);
            // SAFETY: shard boundaries are block- and byte-aligned, so
            // the packed bytes and block scales of [lo, hi) have a
            // single owner (this task).
            MSrc::Block {
                q: *q,
                map: *map,
                block: *block,
                // SAFETY: byte-aligned disjoint packed span (alignment
                // keeps shard boundaries on even nibble pairs).
                packed: unsafe { packed.range_mut(b0, b1) },
                // SAFETY: block-aligned shard boundaries give each task
                // a disjoint scale range.
                scales: unsafe { scales.range_mut(lo / block, hi.div_ceil(*block)) },
            }
        }
        MRoute::Global { q, map, old, .. } => {
            let (b0, b1) = packed_range(q.bits, lo, hi);
            let slot_id = piece.m_slot.expect("global m has a slot");
            // SAFETY: one stat slot per piece (plan invariant).
            let stat = unsafe { slot_views[slot_id].range_mut(0, slot_views[slot_id].len()) };
            MSrc::Global {
                q: *q,
                map: *map,
                packed: &old.packed[b0..b1],
                scales: &old.scales,
                stat,
            }
        }
    };
    let v_src = match &tc.v {
        // SAFETY: disjoint shard ranges (plan invariant).
        VRoute::F32(s) => VSrc::F32(unsafe { s.range_mut(lo, hi) }),
        VRoute::Block {
            q,
            map,
            block,
            packed,
            scales,
        } => {
            let (b0, b1) = packed_range(q.bits, lo, hi);
            VSrc::Block {
                q: *q,
                map: *map,
                block: *block,
                // SAFETY: byte-aligned disjoint packed span (alignment
                // keeps shard boundaries on even nibble pairs).
                packed: unsafe { packed.range_mut(b0, b1) },
                // SAFETY: block-aligned shard boundaries give each task
                // a disjoint scale range.
                scales: unsafe { scales.range_mut(lo / block, hi.div_ceil(*block)) },
            }
        }
        VRoute::Global { q, map, old, .. } => {
            let (b0, b1) = packed_range(q.bits, lo, hi);
            let slot_id = piece.v_slot.expect("global v has a slot");
            // SAFETY: one stat slot per piece (plan invariant).
            let stat = unsafe { slot_views[slot_id].range_mut(0, slot_views[slot_id].len()) };
            VSrc::Global {
                q: *q,
                map: *map,
                packed: &old.packed[b0..b1],
                scales: &old.scales,
                stat,
            }
        }
        VRoute::Factored { f, row_mean } => VSrc::Factored {
            f,
            row_mean: *row_mean,
        },
    };
    update_piece(
        piece.tensor,
        lo,
        tc.shape,
        tc.cols,
        w,
        g,
        m_src,
        v_src,
        hp,
        t,
        lr,
        scratch,
        rng,
    );
}

/// Phase C for one piece: re-derive updated state values from the old
/// codes + gradient and encode against the reduced global scales into
/// the double buffers. The hot arm is the fused in-place
/// [`Quantizer::ema_reencode_range`] pass (§Perf: old bytes are copied
/// into the fresh buffer and decoded → EMA'd → re-encoded byte-by-byte
/// through the kernel tier, no f32 staging); layouts it rejects fall
/// back to the unfused [`decode_ema_piece`] + range-encode pair, which
/// it matches bit for bit — packed bytes and RNG draws alike.
fn phase_c_piece(
    piece: &Piece,
    ctxs: &[TensorCtx<'_>],
    new_scales: &[Option<Scales>],
    hp: &Hyper,
    scratch: &mut StepScratch,
    rng: &mut Pcg64,
) {
    let tc = &ctxs[piece.tensor];
    let (lo, hi) = (piece.lo, piece.hi);
    let len = hi - lo;
    let g = &tc.g[lo..hi];
    let StepScratch {
        m: sm,
        v: sv,
        quant,
        ..
    } = scratch;
    // With quant metrics armed, take the unfused reference arm
    // unconditionally: it materializes the pre-encode fp32 values in
    // scratch (the fused pass never does) and is bit-identical to the
    // fused arm — packed bytes and RNG draws alike — so metering a step
    // never changes its result.
    let metrics = quant.is_some();

    if let MRoute::Global {
        q,
        map,
        old,
        new_packed,
        buf,
    } = &tc.m
    {
        let (b0, b1) = packed_range(q.bits, lo, hi);
        let scales = new_scales[*buf].as_ref().expect("reduced m scales");
        // SAFETY: byte-aligned disjoint shard ranges of the fresh buffer.
        let dst = unsafe { new_packed.range_mut(b0, b1) };
        dst.copy_from_slice(&old.packed[b0..b1]);
        let fused = !metrics
            && q.ema_reencode_range(
                map, dst, lo, tc.shape, &old.scales, scales, g, hp.beta1, false, rng,
            );
        if !fused {
            decode_ema_piece(
                q.bits,
                map,
                &old.packed[b0..b1],
                &old.scales,
                lo,
                tc.shape,
                g,
                hp.beta1,
                false,
                sm,
            );
            q.encode_range_with_scales(map, &sm[..len], lo, tc.shape, scales, dst, rng);
            if let Some(acc) = quant.as_mut() {
                // Round-trip the fresh codes through the canonical decode
                // into the (currently free) v scratch buffer.
                sv.resize(len, 0.0);
                dequantize_packed_range_into(
                    map, q.bits, dst, lo, scales, tc.shape, lo, hi, &mut sv[..len],
                );
                observe_global_encode(
                    acc,
                    false,
                    piece.tensor,
                    &sm[..len],
                    &sv[..len],
                    dst,
                    lo,
                    tc.shape,
                    scales,
                    q.bits,
                    map,
                );
            }
        }
    }

    if let VRoute::Global {
        q,
        map,
        old,
        new_packed,
        buf,
    } = &tc.v
    {
        let (b0, b1) = packed_range(q.bits, lo, hi);
        let scales = new_scales[*buf].as_ref().expect("reduced v scales");
        // SAFETY: byte-aligned disjoint shard ranges of the fresh buffer.
        let dst = unsafe { new_packed.range_mut(b0, b1) };
        dst.copy_from_slice(&old.packed[b0..b1]);
        let fused = !metrics
            && q.ema_reencode_range(
                map, dst, lo, tc.shape, &old.scales, scales, g, hp.beta2, true, rng,
            );
        if !fused {
            decode_ema_piece(
                q.bits,
                map,
                &old.packed[b0..b1],
                &old.scales,
                lo,
                tc.shape,
                g,
                hp.beta2,
                true,
                sv,
            );
            q.encode_range_with_scales(map, &sv[..len], lo, tc.shape, scales, dst, rng);
            if let Some(acc) = quant.as_mut() {
                // m scratch is free by now (the m arm, if any, is done).
                sm.resize(len, 0.0);
                dequantize_packed_range_into(
                    map, q.bits, dst, lo, scales, tc.shape, lo, hi, &mut sm[..len],
                );
                observe_global_encode(
                    acc,
                    true,
                    piece.tensor,
                    &sv[..len],
                    &sm[..len],
                    dst,
                    lo,
                    tc.shape,
                    scales,
                    q.bits,
                    map,
                );
            }
        }
    }
}
