//! The persistent worker pool behind [`super::StepEngine::run_tasks`].
//!
//! The engine used to spawn fresh scoped threads for every phase — up to
//! three spawns per optimizer step, a fixed ~100–300 µs tax that dominates
//! in the high-step-rate small-model regime. The pool keeps long-lived
//! workers parked on a condvar and hands them one *broadcast job* at a
//! time: a borrowed closure executed once per claimed worker slot.
//!
//! The call-site API stays scoped: [`WorkerPool::broadcast`] blocks the
//! submitting thread until every participant has finished, so the closure
//! (and everything it borrows — the step plan, tensor views, scratch
//! state) provably outlives all worker accesses. That blocking wait is
//! what lets us erase the closure's lifetime with a raw pointer instead
//! of requiring `'static` jobs like a conventional thread pool.
//!
//! Jobs never overlap: a second submitter blocks until the slot is free.
//! That is exactly the engine's usage (phases are sequential within a
//! step), and it keeps the protocol small enough to audit. Re-entrant
//! submission from inside a task would deadlock — don't call back into
//! the same pool from a task body.
//!
//! Each broadcast participant receives a distinct slot in `0..workers`,
//! and a worker thread keeps its slot for its lifetime. That slot
//! identity is the key of the sticky scheduler's affinity table (see
//! the engine module docs' "Scheduler" section): "the worker that ran
//! this shard last step" is meaningful across steps precisely because
//! slots are stable on the persistent pool.
//!
//! The pool itself records no telemetry. Span tracing (`--features
//! trace`, see the engine module docs' "Observability" section) lives
//! one level up in the executors: task bodies record into their
//! exclusive `StepScratch` slot's ring, keyed by the same stable slot
//! index, so the pool's broadcast protocol stays free of instrumentation
//! branches.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// One in-flight broadcast: a lifetime-erased pointer to the submitter's
/// closure plus the claim/completion counters.
struct Job {
    body: *const (dyn Fn(usize) + Sync),
    /// Worker slots still unclaimed.
    tickets: usize,
    /// Next slot index to hand out (`0..workers`).
    next_slot: usize,
    /// Participants that have not finished yet.
    active: usize,
}

// SAFETY: the raw closure pointer is only dereferenced by workers while
// the submitting thread is blocked in `broadcast` waiting for `active`
// to reach zero, so the pointee is alive for every dereference.
unsafe impl Send for Job {}

struct PoolState {
    /// Monotonic id of the most recently installed job; workers use it to
    /// avoid re-entering a job they already served (or skipped).
    seq: u64,
    job: Option<Job>,
    /// Job id whose body panicked on some worker (re-raised by the
    /// submitter so failures propagate like scoped-thread panics did).
    panicked: Option<u64>,
    shutdown: bool,
}

struct PoolShared {
    state: Mutex<PoolState>,
    /// Workers wait here for a new job (or shutdown).
    work_cv: Condvar,
    /// Submitters wait here for completion and for the job slot.
    done_cv: Condvar,
}

/// A fixed-size pool of parked worker threads executing broadcast jobs.
/// Dropping the pool shuts the workers down and joins them.
pub struct WorkerPool {
    shared: Arc<PoolShared>,
    handles: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    pub fn new(workers: usize) -> WorkerPool {
        let workers = workers.max(1);
        let shared = Arc::new(PoolShared {
            state: Mutex::new(PoolState {
                seq: 0,
                job: None,
                panicked: None,
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
        });
        let handles = (0..workers)
            .map(|i| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("lowbit-engine-{i}"))
                    .spawn(move || worker_loop(&shared))
                    .expect("spawn engine worker")
            })
            .collect();
        WorkerPool { shared, handles }
    }

    pub fn workers(&self) -> usize {
        self.handles.len()
    }

    /// Run `body(slot)` for every slot in `0..workers` on pool threads and
    /// block until all of them have finished. `body` may freely borrow the
    /// caller's stack — the blocking wait is the scope. Panics in `body`
    /// are re-raised here after the job has fully drained.
    pub fn broadcast(&self, workers: usize, body: &(dyn Fn(usize) + Sync)) {
        assert!(workers >= 1, "broadcast needs at least one worker");
        assert!(
            workers <= self.workers(),
            "broadcast of {workers} workers on a {}-worker pool",
            self.workers()
        );
        let body_ptr = body as *const (dyn Fn(usize) + Sync);
        let mut st = self.shared.state.lock().unwrap();
        // Claim the job slot (jobs never overlap).
        while st.job.is_some() {
            st = self.shared.done_cv.wait(st).unwrap();
        }
        st.seq += 1;
        let my_seq = st.seq;
        st.job = Some(Job {
            body: body_ptr,
            tickets: workers,
            next_slot: 0,
            active: workers,
        });
        self.shared.work_cv.notify_all();
        while st.seq == my_seq && st.job.is_some() {
            st = self.shared.done_cv.wait(st).unwrap();
        }
        let poisoned = st.panicked == Some(my_seq);
        if poisoned {
            st.panicked = None;
        }
        drop(st);
        if poisoned {
            panic!("engine worker panicked during a broadcast task");
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
            self.shared.work_cv.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(shared: &PoolShared) {
    let mut last_seq = 0u64;
    loop {
        // Claim a slot in a job we have not inspected yet.
        let (body, slot, seq) = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                if st.seq != last_seq {
                    last_seq = st.seq;
                    if let Some(job) = st.job.as_mut() {
                        if job.tickets > 0 {
                            job.tickets -= 1;
                            let slot = job.next_slot;
                            job.next_slot += 1;
                            break (job.body, slot, st.seq);
                        }
                    }
                }
                st = shared.work_cv.wait(st).unwrap();
            }
        };
        // SAFETY: the submitter is blocked in `broadcast` until this job's
        // `active` count reaches zero, so the closure is still alive.
        let body_ref: &(dyn Fn(usize) + Sync) = unsafe { &*body };
        let ok = catch_unwind(AssertUnwindSafe(|| body_ref(slot))).is_ok();
        let mut st = shared.state.lock().unwrap();
        if !ok {
            st.panicked = Some(seq);
        }
        if let Some(job) = st.job.as_mut() {
            job.active -= 1;
            if job.active == 0 {
                st.job = None;
                shared.done_cv.notify_all();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn broadcast_runs_every_slot_once() {
        let pool = WorkerPool::new(4);
        let hits: Vec<AtomicUsize> = (0..4).map(|_| AtomicUsize::new(0)).collect();
        pool.broadcast(4, &|slot| {
            hits[slot].fetch_add(1, Ordering::Relaxed);
        });
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::Relaxed), 1, "slot {i}");
        }
    }

    #[test]
    fn pool_is_reused_across_many_jobs() {
        let pool = WorkerPool::new(3);
        let count = AtomicUsize::new(0);
        for _ in 0..200 {
            pool.broadcast(3, &|_| {
                count.fetch_add(1, Ordering::Relaxed);
            });
        }
        assert_eq!(count.load(Ordering::Relaxed), 600);
    }

    #[test]
    fn partial_broadcast_uses_a_subset_of_workers() {
        let pool = WorkerPool::new(8);
        let count = AtomicUsize::new(0);
        pool.broadcast(2, &|slot| {
            assert!(slot < 2);
            count.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(count.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn borrowed_state_is_visible_after_broadcast() {
        let pool = WorkerPool::new(4);
        let mut data = vec![0u32; 64];
        {
            let view = crate::engine::SharedSlice::new(&mut data);
            pool.broadcast(4, &|slot| {
                // SAFETY: each slot writes its own disjoint 16-element range.
                let part = unsafe { view.range_mut(slot * 16, (slot + 1) * 16) };
                for (i, v) in part.iter_mut().enumerate() {
                    *v = (slot * 16 + i) as u32;
                }
            });
        }
        assert_eq!(data, (0..64).collect::<Vec<u32>>());
    }

    #[test]
    fn worker_panic_propagates_and_pool_survives() {
        let pool = WorkerPool::new(2);
        let res = catch_unwind(AssertUnwindSafe(|| {
            pool.broadcast(2, &|slot| {
                if slot == 0 {
                    panic!("boom");
                }
            });
        }));
        assert!(res.is_err(), "panic must reach the submitter");
        // The pool still works after a panicked job.
        let count = AtomicUsize::new(0);
        pool.broadcast(2, &|_| {
            count.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(count.load(Ordering::Relaxed), 2);
    }
}
