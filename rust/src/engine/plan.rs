#![forbid(unsafe_code)]
//! Shard planning: cut the parameter set into block-aligned pieces and
//! group them into balanced tasks.
//!
//! The plan is a pure function of the tensor metadata and the configured
//! shard size — never of the thread count. That is the first half of the
//! engine's determinism contract (see the module docs in `mod.rs`): any
//! number of workers executes the *same* tasks over the *same* ranges
//! with the *same* per-task RNG streams. The plan's task order is also
//! what the sticky scheduler's seed partition follows: unseeded tasks
//! are range-partitioned contiguously by task index, so neighbouring
//! shards (usually neighbouring memory) start on the same worker.
//!
//! Alignment rules per tensor (all boundaries are element offsets):
//!
//! * block-quantized states: boundaries are multiples of every block
//!   size involved, so each shard owns whole blocks (scales + codes);
//! * rank-1 / factored states on ≥2-D tensors: boundaries additionally
//!   fall on axis-0 slab (row) boundaries, so row statistics have a
//!   single writer;
//! * 4-bit packing: boundaries are even, so each shard owns whole bytes
//!   of the nibble-packed code buffer.
//!
//! Large tensors are split into roughly `shard_elems`-sized pieces (one
//! task each); small tensors are coalesced, several whole-tensor pieces
//! per task, so a model with many tiny biases/norms does not drown the
//! queue in sub-microsecond tasks.

/// How one optimizer-state tensor is stored, from the planner's view.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StateLayout {
    /// Dense f32, updated in place by the shard.
    F32,
    /// Block-quantized with the given block size: fully shard-local
    /// (decompress → update → requantize inside one task).
    Block(usize),
    /// Globally-scaled quantization (rank-1 / per-tensor): shards
    /// accumulate scale statistics in phase A and encode in phase C
    /// after a deterministic reduction.
    Global,
    /// Factored second moment (Adafactor-style row/col statistics):
    /// shards accumulate partial sums in phase F; the reduced factors
    /// are read-only during phase A.
    Factored,
}

/// Planner-relevant description of one parameter tensor.
#[derive(Clone, Debug)]
pub struct TensorMeta {
    pub numel: usize,
    pub shape: Vec<usize>,
    pub m: StateLayout,
    pub v: StateLayout,
    /// Length of the stat slot a shard needs for the first moment
    /// (0 unless `m` is `Global`).
    pub m_stat_len: usize,
    /// Length of the stat slot for the second moment (`Global`: scale
    /// stats; `Factored`: executor-chosen partial-sum length; else 0).
    pub v_stat_len: usize,
}

/// A borrowed, allocation-free view of one tensor's planner layout —
/// what an executor derives from its live params/states each step. This
/// is the single meta-construction path shared by the compressed and
/// dense executors: [`crate::engine::StepContext::ensure`] compares
/// specs against its cached [`TensorMeta`]s to detect layout changes
/// without allocating, and materializes them (shape cloned) only on a
/// rebuild.
#[derive(Clone, Copy, Debug)]
pub struct MetaSpec<'a> {
    pub numel: usize,
    pub shape: &'a [usize],
    pub m: StateLayout,
    pub v: StateLayout,
    pub m_stat_len: usize,
    pub v_stat_len: usize,
}

impl<'a> MetaSpec<'a> {
    /// Layout of a purely elementwise optimizer (dense f32 states, no
    /// stat slots) — AdamW-32 and SGDM.
    pub fn elementwise(numel: usize, shape: &'a [usize]) -> MetaSpec<'a> {
        MetaSpec {
            numel,
            shape,
            m: StateLayout::F32,
            v: StateLayout::F32,
            m_stat_len: 0,
            v_stat_len: 0,
        }
    }

    /// Materialize the borrowed spec into an owned cache entry.
    pub fn to_meta(self) -> TensorMeta {
        TensorMeta {
            numel: self.numel,
            shape: self.shape.to_vec(),
            m: self.m,
            v: self.v,
            m_stat_len: self.m_stat_len,
            v_stat_len: self.v_stat_len,
        }
    }
}

impl TensorMeta {
    /// Allocation-free equality against a live layout spec (the cache
    /// validity check on the steady-state step path).
    pub fn matches(&self, s: &MetaSpec<'_>) -> bool {
        self.numel == s.numel
            && self.m == s.m
            && self.v == s.v
            && self.m_stat_len == s.m_stat_len
            && self.v_stat_len == s.v_stat_len
            && self.shape == s.shape
    }
}

/// A contiguous element range of one tensor, owned by exactly one task.
#[derive(Clone, Debug)]
pub struct Piece {
    pub tensor: usize,
    pub lo: usize,
    pub hi: usize,
    /// Stat slot index for the first moment (when `m` is `Global`).
    pub m_slot: Option<usize>,
    /// Stat slot index for the second moment (`Global` or `Factored`).
    pub v_slot: Option<usize>,
}

impl Piece {
    /// Elements covered by this piece (used by the offload tier when
    /// laying out fp32 staging segments).
    pub fn len(&self) -> usize {
        self.hi - self.lo
    }

    pub fn is_empty(&self) -> bool {
        self.hi == self.lo
    }
}

/// One unit of work: a few pieces executed back-to-back by one worker,
/// with one RNG stream.
#[derive(Clone, Debug, Default)]
pub struct Task {
    pub pieces: Vec<Piece>,
}

/// The full step plan.
#[derive(Clone, Debug, Default)]
pub struct Plan {
    pub tasks: Vec<Task>,
    /// Length of each stat slot, indexed by `Piece::{m_slot, v_slot}`.
    pub slot_lens: Vec<usize>,
    pub total_elems: usize,
}

fn gcd(mut a: usize, mut b: usize) -> usize {
    while b != 0 {
        let t = a % b;
        a = b;
        b = t;
    }
    a
}

fn lcm(a: usize, b: usize) -> usize {
    if a == 0 || b == 0 {
        return a.max(b);
    }
    a / gcd(a, b) * b
}

/// Shard-boundary alignment (in elements) for one tensor.
pub fn alignment(meta: &TensorMeta) -> usize {
    // Nibble packing: shards own whole bytes of 4-bit code buffers.
    let mut a = 2usize;
    if let StateLayout::Block(b) = meta.m {
        a = lcm(a, b);
    }
    if let StateLayout::Block(b) = meta.v {
        a = lcm(a, b);
    }
    let needs_rows = meta.shape.len() >= 2
        && (matches!(meta.v, StateLayout::Global | StateLayout::Factored)
            || matches!(meta.m, StateLayout::Global));
    if needs_rows {
        let slab: usize = meta.shape[1..].iter().product();
        a = lcm(a, slab);
    }
    a
}

/// Build the step plan. Pure in (metas, shard_elems) — thread count never
/// enters here.
pub fn build_plan(metas: &[TensorMeta], shard_elems: usize) -> Plan {
    let target = shard_elems.max(2);
    let mut tasks: Vec<Task> = Vec::new();
    let mut slot_lens: Vec<usize> = Vec::new();
    let mut pending: Vec<Piece> = Vec::new();
    let mut pending_elems = 0usize;
    let mut total_elems = 0usize;

    let mk_piece = |tensor: usize, lo: usize, hi: usize, slot_lens: &mut Vec<usize>| {
        let meta = &metas[tensor];
        let m_slot = if meta.m == StateLayout::Global {
            slot_lens.push(meta.m_stat_len);
            Some(slot_lens.len() - 1)
        } else {
            None
        };
        let v_slot = if matches!(meta.v, StateLayout::Global | StateLayout::Factored) {
            slot_lens.push(meta.v_stat_len);
            Some(slot_lens.len() - 1)
        } else {
            None
        };
        Piece {
            tensor,
            lo,
            hi,
            m_slot,
            v_slot,
        }
    };

    for (ti, meta) in metas.iter().enumerate() {
        let n = meta.numel;
        total_elems += n;
        if n == 0 {
            continue;
        }
        if n > target {
            let align = alignment(meta);
            if align >= n {
                // Unsplittable (alignment unit spans the tensor).
                tasks.push(Task {
                    pieces: vec![mk_piece(ti, 0, n, &mut slot_lens)],
                });
            } else {
                let units = n.div_ceil(align);
                let shards = n.div_ceil(target).min(units);
                let units_per = units.div_ceil(shards);
                let mut lo = 0;
                while lo < n {
                    let hi = (lo + units_per * align).min(n);
                    tasks.push(Task {
                        pieces: vec![mk_piece(ti, lo, hi, &mut slot_lens)],
                    });
                    lo = hi;
                }
            }
        } else {
            // Coalesce small tensors into one task.
            pending_elems += n;
            pending.push(mk_piece(ti, 0, n, &mut slot_lens));
            if pending_elems >= target {
                tasks.push(Task {
                    pieces: std::mem::take(&mut pending),
                });
                pending_elems = 0;
            }
        }
    }
    if !pending.is_empty() {
        tasks.push(Task { pieces: pending });
    }
    Plan {
        tasks,
        slot_lens,
        total_elems,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta(numel: usize, shape: &[usize], m: StateLayout, v: StateLayout) -> TensorMeta {
        TensorMeta {
            numel,
            shape: shape.to_vec(),
            m,
            v,
            m_stat_len: if m == StateLayout::Global { 1 } else { 0 },
            v_stat_len: match v {
                StateLayout::Global => shape.iter().sum(),
                StateLayout::Factored => shape.iter().sum(),
                _ => 0,
            },
        }
    }

    #[test]
    fn alignment_combines_blocks_and_rows() {
        let m = meta(
            1024 * 96,
            &[1024, 96],
            StateLayout::Block(128),
            StateLayout::Global,
        );
        // lcm(2, 128, 96) = 384 elements = 4 rows.
        assert_eq!(alignment(&m), 384);
        let m1d = meta(8192, &[8192], StateLayout::Block(128), StateLayout::Block(128));
        assert_eq!(alignment(&m1d), 128);
        let f32s = meta(100, &[100], StateLayout::F32, StateLayout::F32);
        assert_eq!(alignment(&f32s), 2);
    }

    #[test]
    fn plan_covers_disjointly_and_aligned() {
        let metas = vec![
            meta(
                512 * 96,
                &[512, 96],
                StateLayout::Block(128),
                StateLayout::Global,
            ),
            meta(4096, &[4096], StateLayout::Block(128), StateLayout::Block(128)),
            meta(100, &[100], StateLayout::F32, StateLayout::F32),
            meta(60, &[60], StateLayout::F32, StateLayout::F32),
        ];
        let plan = build_plan(&metas, 4096);
        assert_eq!(plan.total_elems, 512 * 96 + 4096 + 160);
        // Every tensor is exactly covered by its pieces, in order.
        for (ti, m) in metas.iter().enumerate() {
            let mut cursor = 0;
            let align = alignment(m);
            for t in &plan.tasks {
                for p in t.pieces.iter().filter(|p| p.tensor == ti) {
                    assert_eq!(p.lo, cursor, "tensor {ti} gap");
                    assert!(p.hi > p.lo && p.hi <= m.numel);
                    assert!(
                        p.lo % align == 0,
                        "tensor {ti} piece lo {} misaligned ({align})",
                        p.lo
                    );
                    assert!(p.hi == m.numel || p.hi % align == 0);
                    cursor = p.hi;
                }
            }
            assert_eq!(cursor, m.numel, "tensor {ti} not fully covered");
        }
        // The big tensor was split into several tasks.
        let big_tasks = plan
            .tasks
            .iter()
            .filter(|t| t.pieces.iter().any(|p| p.tensor == 0))
            .count();
        assert!(big_tasks >= 8, "expected a real split, got {big_tasks}");
        // The two tiny tensors were coalesced into one task.
        let tiny_task = plan
            .tasks
            .iter()
            .find(|t| t.pieces.iter().any(|p| p.tensor == 2))
            .unwrap();
        assert!(tiny_task.pieces.iter().any(|p| p.tensor == 3));
    }

    #[test]
    fn plan_is_independent_of_nothing_but_inputs() {
        let metas = vec![meta(
            1 << 18,
            &[512, 512],
            StateLayout::Block(128),
            StateLayout::Global,
        )];
        let a = build_plan(&metas, 1 << 14);
        let b = build_plan(&metas, 1 << 14);
        assert_eq!(a.tasks.len(), b.tasks.len());
        for (x, y) in a.tasks.iter().zip(b.tasks.iter()) {
            assert_eq!(x.pieces.len(), y.pieces.len());
            for (p, q) in x.pieces.iter().zip(y.pieces.iter()) {
                assert_eq!((p.tensor, p.lo, p.hi), (q.tensor, q.lo, q.hi));
            }
        }
    }

    #[test]
    fn meta_spec_roundtrip_and_match() {
        let shape = vec![16usize, 8];
        let spec = MetaSpec {
            numel: 128,
            shape: &shape,
            m: StateLayout::Block(128),
            v: StateLayout::Global,
            m_stat_len: 0,
            v_stat_len: 24,
        };
        let meta = spec.to_meta();
        assert!(meta.matches(&spec), "roundtrip must match");
        let other_shape = vec![8usize, 16];
        assert!(!meta.matches(&MetaSpec {
            shape: &other_shape,
            ..spec
        }));
        assert!(!meta.matches(&MetaSpec {
            v: StateLayout::F32,
            ..spec
        }));
        assert!(!meta.matches(&MetaSpec {
            v_stat_len: 25,
            ..spec
        }));
        let ew = MetaSpec::elementwise(100, &shape[..1]);
        assert_eq!(ew.m, StateLayout::F32);
        assert_eq!(ew.v_stat_len, 0);
    }

    #[test]
    fn stat_slots_assigned_per_global_piece() {
        let metas = vec![meta(
            256 * 96,
            &[256, 96],
            StateLayout::Block(128),
            StateLayout::Global,
        )];
        let plan = build_plan(&metas, 4096);
        let mut seen = std::collections::BTreeSet::new();
        for t in &plan.tasks {
            for p in &t.pieces {
                assert!(p.m_slot.is_none());
                let slot = p.v_slot.expect("global v needs a slot");
                assert!(seen.insert(slot), "slot reused");
                assert_eq!(plan.slot_lens[slot], 256 + 96);
            }
        }
        assert_eq!(seen.len(), plan.slot_lens.len());
    }
}
