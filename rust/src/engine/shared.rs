//! Unsafe-but-contained shared-memory primitives for the step engine.
//!
//! A step's shard tasks mutate *disjoint* regions of shared buffers
//! (parameter data, packed state codes, block scales, stat slots). Rust's
//! borrow checker cannot express "disjoint ranges handed to different
//! scoped threads", so the engine routes those accesses through
//! [`SharedSlice`], which carries the base pointer and defers the
//! disjointness proof to the *planner*: shard ranges are constructed
//! non-overlapping and byte-aligned (see `plan.rs`), and every unsafe
//! access site states which plan invariant it relies on.
//!
//! # The machine-checked contract
//!
//! The contract is no longer assumption-only; it is verified on two
//! independent axes:
//!
//! * **Statically**, `rust/src/bin/lint.rs` (tier-1 test `unsafe_lint`)
//!   confines `unsafe` to an explicit module allowlist and requires a
//!   `// SAFETY:` comment at every site — a new call site of
//!   [`SharedSlice::range_mut`] outside the audited modules does not
//!   compile past CI.
//! * **Dynamically**, under `--features audit` every `range_mut` call
//!   reports its `(base, byte range, task, phase epoch)` to the
//!   engine's aliasing auditor ([`crate::engine::audit`]). Out-of-bounds
//!   ranges always abort; ranges materialized after their phase's
//!   barrier abort; and two overlapping ranges from different tasks of
//!   one phase abort with both call sites named, unless the phase's
//!   dependency edges order the tasks. The epoch/phase rules are
//!   documented in `engine/mod.rs` ("The audited unsafe boundary").
//!
//! With the feature disabled the hook compiles away and `range_mut` is
//! exactly the one-line pointer arithmetic it always was.

use std::marker::PhantomData;

/// A length-checked shared view over a `&mut [T]` that can be sliced into
/// disjoint mutable ranges from multiple threads.
///
/// Constructing one borrows the underlying slice mutably for lifetime
/// `'a`, so no *safe* alias can exist while tasks run. All mutation goes
/// through [`SharedSlice::range_mut`], whose caller must guarantee range
/// disjointness across concurrently running tasks.
pub struct SharedSlice<'a, T> {
    ptr: *mut T,
    len: usize,
    _lt: PhantomData<&'a mut [T]>,
}

// SAFETY: the wrapper only hands out raw-derived references through
// `range_mut`, whose contract requires disjoint ranges per concurrent
// task; with disjoint ranges, sending the view to another thread is
// equivalent to sending disjoint `&mut [T]` sub-slices.
unsafe impl<T: Send> Send for SharedSlice<'_, T> {}
// SAFETY: a shared `&SharedSlice` yields nothing beyond further
// `range_mut` views under the same per-task disjointness contract, so
// sharing across threads adds no aliasing that `Send` did not already
// permit.
unsafe impl<T: Send> Sync for SharedSlice<'_, T> {}

impl<'a, T> SharedSlice<'a, T> {
    pub fn new(slice: &'a mut [T]) -> SharedSlice<'a, T> {
        SharedSlice {
            ptr: slice.as_mut_ptr(),
            len: slice.len(),
            _lt: PhantomData,
        }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Mutable view of elements `[lo, hi)`.
    ///
    /// # Safety
    /// `lo <= hi <= len`, and ranges obtained from different tasks of
    /// one engine phase must be pairwise disjoint unless the phase's
    /// dependency edges order the tasks (`run_tasks_dep`). Within one
    /// task, no range may be re-materialized while an earlier `&mut`
    /// for an overlapping region is still alive. Under
    /// `--features audit` this exact contract is checked at runtime
    /// and any violation aborts with both call sites named.
    #[inline]
    #[allow(clippy::mut_from_ref)]
    #[cfg_attr(feature = "audit", track_caller)]
    pub unsafe fn range_mut(&self, lo: usize, hi: usize) -> &mut [T] {
        #[cfg(feature = "audit")]
        crate::engine::audit::check_range(
            self.ptr as usize,
            std::mem::size_of::<T>(),
            self.len,
            lo,
            hi,
        );
        debug_assert!(lo <= hi && hi <= self.len, "range {lo}..{hi} of {}", self.len);
        // SAFETY: in bounds by the debug_assert (and, under the audit
        // feature, by the auditor's unconditional bounds check);
        // aliasing is the caller's contract, restated above.
        unsafe { std::slice::from_raw_parts_mut(self.ptr.add(lo), hi - lo) }
    }
}

impl<T> Clone for SharedSlice<'_, T> {
    fn clone(&self) -> Self {
        *self
    }
}

impl<T> Copy for SharedSlice<'_, T> {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disjoint_ranges_mutate_independently() {
        let mut data = vec![0u32; 64];
        let view = SharedSlice::new(&mut data);
        std::thread::scope(|s| {
            for w in 0..4 {
                let view = view;
                s.spawn(move || {
                    // SAFETY: each worker writes its own 16-element range.
                    let part = unsafe { view.range_mut(w * 16, (w + 1) * 16) };
                    for (i, v) in part.iter_mut().enumerate() {
                        *v = (w * 16 + i) as u32;
                    }
                });
            }
        });
        assert_eq!(data, (0..64).collect::<Vec<u32>>());
    }
}
