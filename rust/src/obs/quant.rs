#![forbid(unsafe_code)]
//! Quantization-quality accumulators (runtime-gated, see the
//! [module docs](super)). The engine's phase-A/phase-C encode sites feed
//! per-worker [`QuantAccum`]s living in shard-local scratch; after the
//! step they are merged in worker-slot order into one accumulator the
//! report layer summarizes.
//!
//! Integer counters (element counts, code histograms, zero-code /
//! outlier / zero-value counts) are exact and order-independent, so they
//! are bit-identical across thread counts and scheduler modes. The f64
//! error sums are merged in slot order — deterministic for a fixed
//! schedule, but float rounding may differ across scheduler modes; the
//! determinism suite pins only the exact counters.

/// Number of histogram buckets. 4-bit codes map 1:1; wider codes are
/// bucketed by their top 4 bits.
pub const CODE_BUCKETS: usize = 16;

/// Error/occupancy statistics for one moment kind (m or v), accumulated
/// over every element that went through a quantizing encode this step.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct MomentAccum {
    /// Elements observed with pre/post-encode values.
    pub count: u64,
    /// Σ (x − x̂)² — RMSE numerator.
    pub sq_err: f64,
    /// Σ |x − x̂| — relative-error numerator.
    pub abs_err_sum: f64,
    /// Σ |x| — relative-error denominator.
    pub abs_sum: f64,
    /// max |x − x̂|.
    pub max_abs_err: f64,
    /// max |x| (pre-encode dynamic range).
    pub abs_max: f64,
    /// Pre-encode exact zeros.
    pub zero_vals: u64,
    /// Elements in the top half of their quantization scale
    /// (|x| ≥ scale/2) — the block-max-dominating outliers of the
    /// paper's §3 analysis.
    pub outliers: u64,
    /// Codes observed in the occupancy histogram (= Σ hist).
    pub code_count: u64,
    /// Codes that decode to exactly 0.0 (the zero-point diagnostic).
    pub zero_codes: u64,
    /// Code occupancy, 4-bit resolution (see [`CODE_BUCKETS`]).
    pub hist: [u64; CODE_BUCKETS],
}

impl MomentAccum {
    pub fn clear(&mut self) {
        *self = MomentAccum::default();
    }

    pub fn merge(&mut self, o: &MomentAccum) {
        self.count += o.count;
        self.sq_err += o.sq_err;
        self.abs_err_sum += o.abs_err_sum;
        self.abs_sum += o.abs_sum;
        self.max_abs_err = self.max_abs_err.max(o.max_abs_err);
        self.abs_max = self.abs_max.max(o.abs_max);
        self.zero_vals += o.zero_vals;
        self.outliers += o.outliers;
        self.code_count += o.code_count;
        self.zero_codes += o.zero_codes;
        for (a, b) in self.hist.iter_mut().zip(o.hist.iter()) {
            *a += *b;
        }
    }

    /// Observe one element: pre-encode value `x`, decoded post-encode
    /// value `xhat`, and the quantization scale at its position (0 for
    /// an all-zero block — no outlier claim possible).
    #[inline]
    pub fn observe(&mut self, x: f32, xhat: f32, scale: f32) {
        let xd = x as f64;
        let e = (xd - xhat as f64).abs();
        let ax = xd.abs();
        self.count += 1;
        self.sq_err += e * e;
        self.abs_err_sum += e;
        self.abs_sum += ax;
        if e > self.max_abs_err {
            self.max_abs_err = e;
        }
        if ax > self.abs_max {
            self.abs_max = ax;
        }
        if x == 0.0 {
            self.zero_vals += 1;
        }
        if scale > 0.0 && ax >= 0.5 * scale as f64 {
            self.outliers += 1;
        }
    }

    /// Observe one emitted code of width `bits`; `zero_code` is the code
    /// that decodes to exactly 0.0, if the map has one.
    #[inline]
    pub fn observe_code(&mut self, code: u8, bits: u8, zero_code: Option<u8>) {
        let bucket = if bits <= 4 {
            code as usize
        } else {
            (code >> (bits - 4)) as usize
        };
        self.hist[bucket & (CODE_BUCKETS - 1)] += 1;
        self.code_count += 1;
        if zero_code == Some(code) {
            self.zero_codes += 1;
        }
    }

    /// √(Σ(x−x̂)²/n).
    pub fn rmse(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            (self.sq_err / self.count as f64).sqrt()
        }
    }

    /// Σ|x−x̂| / Σ|x| (0 when nothing non-zero was observed).
    pub fn rel_err(&self) -> f64 {
        if self.abs_sum > 0.0 {
            self.abs_err_sum / self.abs_sum
        } else {
            0.0
        }
    }

    /// Fraction of emitted codes that decode to exactly 0.
    pub fn zero_code_frac(&self) -> f64 {
        if self.code_count == 0 {
            0.0
        } else {
            self.zero_codes as f64 / self.code_count as f64
        }
    }
}

/// Per-tensor dynamic-range / outlier counters.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct TensorAccum {
    /// max |m| pre-encode.
    pub m_abs_max: f64,
    /// max |v| pre-encode.
    pub v_abs_max: f64,
    /// Top-of-range outliers (|x| ≥ scale/2), both moments.
    pub outliers: u64,
}

impl TensorAccum {
    pub fn merge(&mut self, o: &TensorAccum) {
        self.m_abs_max = self.m_abs_max.max(o.m_abs_max);
        self.v_abs_max = self.v_abs_max.max(o.v_abs_max);
        self.outliers += o.outliers;
    }
}

/// One worker's (or the merged) quant-quality accumulator for a step.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct QuantAccum {
    pub m: MomentAccum,
    pub v: MomentAccum,
    pub tensors: Vec<TensorAccum>,
}

impl QuantAccum {
    /// Size the per-tensor table (cold path; grow-only).
    pub fn ensure_tensors(&mut self, n: usize) {
        if self.tensors.len() < n {
            self.tensors.resize(n, TensorAccum::default());
        }
    }

    /// Reset every counter, keeping the per-tensor table's storage.
    pub fn clear(&mut self) {
        self.m.clear();
        self.v.clear();
        for t in &mut self.tensors {
            *t = TensorAccum::default();
        }
    }

    /// Fold another accumulator in (per-worker → merged, slot order).
    pub fn merge(&mut self, o: &QuantAccum) {
        self.m.merge(&o.m);
        self.v.merge(&o.v);
        self.ensure_tensors(o.tensors.len());
        for (a, b) in self.tensors.iter_mut().zip(o.tensors.iter()) {
            a.merge(b);
        }
    }

    /// Observe one first-moment element of tensor `tensor`.
    #[inline]
    pub fn observe_m(&mut self, tensor: usize, x: f32, xhat: f32, scale: f32) {
        self.m.observe(x, xhat, scale);
        if let Some(t) = self.tensors.get_mut(tensor) {
            let ax = (x as f64).abs();
            if ax > t.m_abs_max {
                t.m_abs_max = ax;
            }
            if scale > 0.0 && ax >= 0.5 * scale as f64 {
                t.outliers += 1;
            }
        }
    }

    /// Observe one second-moment element of tensor `tensor`.
    #[inline]
    pub fn observe_v(&mut self, tensor: usize, x: f32, xhat: f32, scale: f32) {
        self.v.observe(x, xhat, scale);
        if let Some(t) = self.tensors.get_mut(tensor) {
            let ax = (x as f64).abs();
            if ax > t.v_abs_max {
                t.v_abs_max = ax;
            }
            if scale > 0.0 && ax >= 0.5 * scale as f64 {
                t.outliers += 1;
            }
        }
    }

    /// Anything observed this step?
    pub fn is_empty(&self) -> bool {
        self.m.count == 0 && self.v.count == 0 && self.m.code_count == 0 && self.v.code_count == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn observe_accumulates_error_stats() {
        let mut a = MomentAccum::default();
        a.observe(1.0, 0.75, 1.0); // err .25, outlier (|x| >= .5)
        a.observe(0.0, 0.0, 1.0); // exact zero
        a.observe(-0.1, -0.2, 1.0); // err .1, not outlier
        assert_eq!(a.count, 3);
        assert_eq!(a.zero_vals, 1);
        assert_eq!(a.outliers, 1);
        assert!((a.max_abs_err - 0.25).abs() < 1e-12);
        assert!((a.abs_max - 1.0).abs() < 1e-12);
        let expect_rmse = ((0.25f64 * 0.25
            + (-0.1f64 - -0.2f64).abs().powi(2))
            / 3.0)
            .sqrt();
        assert!((a.rmse() - expect_rmse).abs() < 1e-9);
        assert!((a.rel_err() - 0.35 / 1.1).abs() < 1e-6);
    }

    #[test]
    fn observe_code_buckets_and_zero_codes() {
        let mut a = MomentAccum::default();
        a.observe_code(0, 4, Some(0));
        a.observe_code(0, 4, Some(0));
        a.observe_code(15, 4, Some(0));
        a.observe_code(0x80, 8, None); // top-4-bit bucket 8
        assert_eq!(a.hist[0], 2);
        assert_eq!(a.hist[15], 1);
        assert_eq!(a.hist[8], 1);
        assert_eq!(a.code_count, 4);
        assert_eq!(a.zero_codes, 2);
        assert!((a.zero_code_frac() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn merge_is_addition() {
        let mut a = QuantAccum::default();
        a.ensure_tensors(2);
        a.observe_m(0, 0.5, 0.5, 1.0);
        a.observe_v(1, 0.9, 0.8, 1.0);
        let mut b = QuantAccum::default();
        b.ensure_tensors(2);
        b.observe_m(0, -2.0, -1.9, 2.0);
        let mut merged = a.clone();
        merged.merge(&b);
        assert_eq!(merged.m.count, 2);
        assert_eq!(merged.v.count, 1);
        assert!((merged.m.abs_max - 2.0).abs() < 1e-12);
        assert!((merged.tensors[0].m_abs_max - 2.0).abs() < 1e-12);
        // Both observed elements of tensor 0 are outliers (|x| >= scale/2).
        assert_eq!(merged.tensors[0].outliers, 2);
        assert!(!merged.is_empty());
        merged.clear();
        assert!(merged.is_empty());
        assert_eq!(merged.tensors.len(), 2, "clear keeps the table");
    }

    #[test]
    fn empty_accum_reports_zeros() {
        let a = MomentAccum::default();
        assert_eq!(a.rmse(), 0.0);
        assert_eq!(a.rel_err(), 0.0);
        assert_eq!(a.zero_code_frac(), 0.0);
    }
}
