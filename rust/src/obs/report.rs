#![forbid(unsafe_code)]
//! Unified step reporting: summaries of span rings and quant
//! accumulators, bundled with the scheduler and offload telemetry into
//! one [`StepReport`] behind `Optimizer::step_report()`. Summaries carry
//! per-phase percentiles — never raw spans — so appending them to the
//! bench JSON trajectories stays cheap and schema-stable.

use super::quant::QuantAccum;
use super::trace::{phase_name, Ring, PHASE_NAMES};
use crate::engine::SchedStats;
use crate::offload::OffloadReport;
use crate::util::json::Json;
use crate::util::stats::percentile;

/// Timing summary of one phase over the spans currently in the rings.
#[derive(Clone, Debug, PartialEq)]
pub struct PhaseSummary {
    pub name: &'static str,
    /// Spans (phase spans for the coordinator row of a phase, task spans
    /// for its workers — both aggregate here under the one phase name).
    pub count: u64,
    pub total_us: f64,
    pub p50_us: f64,
    pub p95_us: f64,
    pub max_us: f64,
}

/// Per-phase summaries plus the total span-drop count across rings.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SpanSummary {
    pub phases: Vec<PhaseSummary>,
    pub dropped: u64,
}

impl SpanSummary {
    /// Summarize whatever the rings currently hold. Export-time only —
    /// allocates freely.
    pub fn from_rings(rings: &[(u32, &Ring)]) -> SpanSummary {
        let mut durs: Vec<Vec<f64>> = vec![Vec::new(); PHASE_NAMES.len()];
        let mut dropped = 0u64;
        for &(_tid, ring) in rings {
            dropped += ring.dropped();
            for s in ring.iter() {
                if let Some(d) = durs.get_mut(s.phase as usize) {
                    d.push(s.dur_ns() as f64 / 1e3);
                }
            }
        }
        let phases = durs
            .iter()
            .enumerate()
            .filter(|(_, d)| !d.is_empty())
            .map(|(id, d)| PhaseSummary {
                name: phase_name(id as u16),
                count: d.len() as u64,
                total_us: d.iter().sum(),
                p50_us: percentile(d, 50.0),
                p95_us: percentile(d, 95.0),
                max_us: percentile(d, 100.0),
            })
            .collect();
        SpanSummary { phases, dropped }
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("enabled", Json::Bool(true))
            .set("dropped", Json::Num(self.dropped as f64));
        let mut phases = Json::obj();
        for p in &self.phases {
            let mut e = Json::obj();
            e.set("count", Json::Num(p.count as f64))
                .set("total_us", Json::Num(p.total_us))
                .set("p50_us", Json::Num(p.p50_us))
                .set("p95_us", Json::Num(p.p95_us))
                .set("max_us", Json::Num(p.max_us));
            phases.set(p.name, e);
        }
        o.set("phases", phases);
        o
    }

    /// The placeholder recorded when span tracing is compiled out (the
    /// `trace` feature is off) — keeps the bench JSON schema stable.
    pub fn disabled_json() -> Json {
        let mut o = Json::obj();
        o.set("enabled", Json::Bool(false));
        o
    }
}

/// Summary of one moment kind's quant-quality accumulator.
#[derive(Clone, Debug, PartialEq)]
pub struct MomentReport {
    pub count: u64,
    pub rmse: f64,
    pub max_abs_err: f64,
    pub rel_err: f64,
    pub abs_max: f64,
    pub zero_vals: u64,
    pub outliers: u64,
    pub zero_code_frac: f64,
    pub hist: [u64; super::quant::CODE_BUCKETS],
}

impl MomentReport {
    fn from_accum(a: &super::quant::MomentAccum) -> MomentReport {
        MomentReport {
            count: a.count,
            rmse: a.rmse(),
            max_abs_err: a.max_abs_err,
            rel_err: a.rel_err(),
            abs_max: a.abs_max,
            zero_vals: a.zero_vals,
            outliers: a.outliers,
            zero_code_frac: a.zero_code_frac(),
            hist: a.hist,
        }
    }

    fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("count", Json::Num(self.count as f64))
            .set("rmse", Json::Num(self.rmse))
            .set("max_abs_err", Json::Num(self.max_abs_err))
            .set("rel_err", Json::Num(self.rel_err))
            .set("abs_max", Json::Num(self.abs_max))
            .set("zero_vals", Json::Num(self.zero_vals as f64))
            .set("outliers", Json::Num(self.outliers as f64))
            .set("zero_code_frac", Json::Num(self.zero_code_frac))
            .set(
                "hist",
                Json::Arr(self.hist.iter().map(|&c| Json::Num(c as f64)).collect()),
            );
        o
    }
}

/// Quantization-quality report for one step (merged over workers).
#[derive(Clone, Debug, PartialEq)]
pub struct QuantReport {
    pub m: MomentReport,
    pub v: MomentReport,
    /// Per-tensor `(m_abs_max, v_abs_max, outliers)` dynamic-range rows.
    pub tensors: Vec<(f64, f64, u64)>,
}

impl QuantReport {
    pub fn from_accum(a: &QuantAccum) -> QuantReport {
        QuantReport {
            m: MomentReport::from_accum(&a.m),
            v: MomentReport::from_accum(&a.v),
            tensors: a
                .tensors
                .iter()
                .map(|t| (t.m_abs_max, t.v_abs_max, t.outliers))
                .collect(),
        }
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("m", self.m.to_json()).set("v", self.v.to_json());
        let tensors = self
            .tensors
            .iter()
            .map(|&(m, v, out)| {
                let mut t = Json::obj();
                t.set("m_abs_max", Json::Num(m))
                    .set("v_abs_max", Json::Num(v))
                    .set("outliers", Json::Num(out as f64));
                t
            })
            .collect();
        o.set("tensors", Json::Arr(tensors));
        o
    }
}

/// Fault-injection / recovery counters for one optimizer's lifetime:
/// link retries broken down by cause, the virtual time those retries
/// cost, and the number of aborted-then-rolled-back steps (see
/// `offload/mod.rs` "Failure semantics"). All zeros on a clean run.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct FaultCounters {
    /// Transfers replayed because the link dropped them.
    pub link_fail_retries: u64,
    /// Transfers replayed because the staged payload failed its CRC-32.
    pub link_corrupt_retries: u64,
    /// Virtual seconds the retries added (backoff + re-transfer, charged
    /// serially — see `offload::link::RetryPolicy`).
    pub retry_virtual_seconds: f64,
    /// Steps that aborted mid-flight and were rolled back by `try_step`.
    pub rollbacks: u64,
}

impl FaultCounters {
    pub fn retries(&self) -> u64 {
        self.link_fail_retries + self.link_corrupt_retries
    }

    pub fn any(&self) -> bool {
        self.retries() > 0 || self.rollbacks > 0
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("retries", Json::Num(self.retries() as f64))
            .set("link_fail_retries", Json::Num(self.link_fail_retries as f64))
            .set(
                "link_corrupt_retries",
                Json::Num(self.link_corrupt_retries as f64),
            )
            .set("retry_virtual_s", Json::Num(self.retry_virtual_seconds))
            .set("rollbacks", Json::Num(self.rollbacks as f64));
        o
    }
}

/// Everything one step's telemetry has to say, from one accessor.
#[derive(Clone, Debug, Default)]
pub struct StepReport {
    /// Optimizer step counter at report time.
    pub step: usize,
    pub sched: Option<SchedStats>,
    pub offload: Option<OffloadReport>,
    /// `None` when the `trace` feature is off or nothing recorded yet.
    pub spans: Option<SpanSummary>,
    /// `None` unless quant metrics are enabled on the optimizer.
    pub quant: Option<QuantReport>,
    /// Fault/retry/rollback counters; `None` for optimizers without the
    /// fault-injection layer wired in.
    pub faults: Option<FaultCounters>,
}

impl StepReport {
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("step", Json::Num(self.step as f64));
        if let Some(s) = &self.sched {
            let mut j = Json::obj();
            j.set("mode", Json::Str(s.mode.name().to_string()))
                .set("claims", Json::Num(s.claims as f64))
                .set("steals", Json::Num(s.steals as f64))
                .set("affinity_hits", Json::Num(s.affinity_hits as f64));
            o.set("sched", j);
        }
        if let Some(r) = &self.offload {
            let mut j = Json::obj();
            j.set("steps", Json::Num(r.steps as f64))
                .set("bytes_down", Json::Num(r.bytes_down as f64))
                .set("bytes_up", Json::Num(r.bytes_up as f64))
                .set("transfers", Json::Num(r.transfers as f64))
                .set("virtual_step_s", Json::Num(r.step_seconds()))
                .set("overlap_fraction", Json::Num(r.overlap_fraction()));
            o.set("offload", j);
        }
        o.set(
            "trace_summary",
            match &self.spans {
                Some(s) => s.to_json(),
                None => SpanSummary::disabled_json(),
            },
        );
        if let Some(q) = &self.quant {
            o.set("quant", q.to_json());
        }
        if let Some(f) = &self.faults {
            o.set("faults", f.to_json());
        }
        o
    }

    /// Compact human rendering for the trainer's cadence printing.
    pub fn render(&self) -> String {
        let mut out = format!("[step {}]", self.step);
        if let Some(s) = &self.sched {
            out.push_str(&format!(
                " sched={} claims={} steals={} hits={}",
                s.mode.name(),
                s.claims,
                s.steals,
                s.affinity_hits
            ));
        }
        if let Some(r) = &self.offload {
            out.push_str(&format!(
                " offload: {:.1} us/step virtual, overlap {:.0}%",
                r.step_seconds() * 1e6,
                r.overlap_fraction() * 100.0
            ));
        }
        if let Some(sp) = &self.spans {
            for p in &sp.phases {
                out.push_str(&format!(
                    "\n  {:<16} n={:<6} total={:>9.1}us p50={:>7.1}us p95={:>7.1}us max={:>7.1}us",
                    p.name, p.count, p.total_us, p.p50_us, p.p95_us, p.max_us
                ));
            }
            if sp.dropped > 0 {
                out.push_str(&format!("\n  (dropped {} spans)", sp.dropped));
            }
        }
        if let Some(f) = &self.faults {
            if f.any() {
                out.push_str(&format!(
                    " faults: retries={} (fail={} corrupt={}) retry_virtual={:.1}us rollbacks={}",
                    f.retries(),
                    f.link_fail_retries,
                    f.link_corrupt_retries,
                    f.retry_virtual_seconds * 1e6,
                    f.rollbacks
                ));
            }
        }
        if let Some(q) = &self.quant {
            out.push_str(&format!(
                "\n  quant m: rmse={:.3e} rel={:.3e} max={:.3e} zero-code={:.1}% outliers={}",
                q.m.rmse,
                q.m.rel_err,
                q.m.max_abs_err,
                q.m.zero_code_frac * 100.0,
                q.m.outliers
            ));
            out.push_str(&format!(
                "\n  quant v: rmse={:.3e} rel={:.3e} max={:.3e} zero-code={:.1}% outliers={}",
                q.v.rmse,
                q.v.rel_err,
                q.v.max_abs_err,
                q.v.zero_code_frac * 100.0,
                q.v.outliers
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::trace::{Span, P_ENGINE_A, P_ENGINE_C, TASK_NONE};

    fn ring_with(spans: &[(u16, u32, u64, u64)]) -> Ring {
        let mut r = Ring::default();
        r.ensure_cap(32);
        for &(p, t, a, b) in spans {
            r.push(Span {
                phase: p,
                task: t,
                t0: a,
                t1: b,
            });
        }
        r
    }

    #[test]
    fn span_summary_percentiles() {
        let coord = ring_with(&[(P_ENGINE_A, TASK_NONE, 0, 10_000)]);
        let w = ring_with(&[
            (P_ENGINE_A, 0, 0, 1_000),
            (P_ENGINE_A, 1, 0, 3_000),
            (P_ENGINE_C, 0, 0, 2_000),
        ]);
        let s = SpanSummary::from_rings(&[(0, &coord), (1, &w)]);
        assert_eq!(s.phases.len(), 2);
        let a = s.phases.iter().find(|p| p.name == "engine.A").unwrap();
        assert_eq!(a.count, 3);
        assert!((a.total_us - 14.0).abs() < 1e-9);
        assert!((a.max_us - 10.0).abs() < 1e-9);
        assert!((a.p50_us - 3.0).abs() < 1e-9);
        let c = s.phases.iter().find(|p| p.name == "engine.C").unwrap();
        assert_eq!(c.count, 1);
    }

    #[test]
    fn step_report_json_always_has_trace_summary() {
        let r = StepReport {
            step: 7,
            ..StepReport::default()
        };
        let j = r.to_json();
        let ts = j.get("trace_summary").expect("key must always exist");
        assert_eq!(ts.get("enabled").unwrap().as_bool(), Some(false));
        // With spans present it flips to enabled with phase entries.
        let coord = ring_with(&[(P_ENGINE_A, TASK_NONE, 0, 5_000)]);
        let r2 = StepReport {
            step: 8,
            spans: Some(SpanSummary::from_rings(&[(0, &coord)])),
            ..StepReport::default()
        };
        let j2 = r2.to_json();
        let ts2 = j2.get("trace_summary").unwrap();
        assert_eq!(ts2.get("enabled").unwrap().as_bool(), Some(true));
        assert!(ts2.get("phases").unwrap().get("engine.A").is_some());
        // And the whole report survives a serialize → parse roundtrip.
        let back = Json::parse(&j2.to_string()).unwrap();
        assert_eq!(back.get("step").unwrap().as_f64(), Some(8.0));
    }

    #[test]
    fn render_mentions_phases_and_quant() {
        let coord = ring_with(&[(P_ENGINE_C, TASK_NONE, 0, 4_000)]);
        let mut acc = QuantAccum::default();
        acc.ensure_tensors(1);
        acc.observe_v(0, 0.5, 0.4, 1.0);
        acc.v.observe_code(0, 4, Some(0));
        let rep = StepReport {
            step: 3,
            spans: Some(SpanSummary::from_rings(&[(0, &coord)])),
            quant: Some(QuantReport::from_accum(&acc)),
            ..StepReport::default()
        };
        let text = rep.render();
        assert!(text.contains("engine.C"));
        assert!(text.contains("quant v"));
        assert!(text.contains("zero-code=100.0%"));
    }
}
