#![forbid(unsafe_code)]
//! Span tracing primitives: phase-id table, the preallocated [`Ring`]
//! buffer, chrome-trace export and the schedule-independent
//! [`fingerprint`]. See the [module docs](super) for the overhead
//! contract; the recording *call sites* (and the ring storage) live in
//! the engine/offload executors behind `#[cfg(feature = "trace")]` —
//! this file is feature-independent so exports and tests always compile.

use crate::util::json::Json;
use std::sync::OnceLock;
use std::time::Instant;

/// Task id used by coordinator-side phase spans (no single task).
pub const TASK_NONE: u32 = u32::MAX;

// Phase ids. Keep `PHASE_NAMES` in sync — `phase_name` indexes it.
/// Compressed executor: factored-statistics phase (factored tensors only).
pub const P_ENGINE_F: u16 = 0;
/// Compressed executor: decompress → AdamW → block requantize.
pub const P_ENGINE_A: u16 = 1;
/// Compressed executor: sequential global-scale reduction between A and C.
pub const P_ENGINE_REDUCE: u16 = 2;
/// Compressed executor: global re-encode against the reduced scales.
pub const P_ENGINE_C: u16 = 3;
/// Compressed executor: commit of the re-encoded buffers/scales.
pub const P_ENGINE_COMMIT: u16 = 4;
/// Dense fp32 AdamW single elementwise phase.
pub const P_DENSE_ADAMW32: u16 = 5;
/// Dense SGDM single elementwise phase.
pub const P_DENSE_SGDM: u16 = 6;
/// SM3 update phase (per-shard cover maxima accumulate alongside).
pub const P_DENSE_SM3: u16 = 7;
/// SM3 sequential max-reduce.
pub const P_DENSE_SM3_REDUCE: u16 = 8;
/// Adafactor factored-statistics phase.
pub const P_DENSE_AF_F: u16 = 9;
/// Adafactor sequential row/col reduction.
pub const P_DENSE_AF_REDUCE: u16 = 10;
/// Adafactor update-RMS phase.
pub const P_DENSE_AF_U: u16 = 11;
/// Adafactor sequential RMS reduction.
pub const P_DENSE_AF_RMS: u16 = 12;
/// Adafactor clipped-write phase.
pub const P_DENSE_AF_W: u16 = 13;
/// Offload pipeline: one interleaved prefetch/compute/writeback queue.
pub const P_OFF_QUEUE: u16 = 14;
/// Offload pipeline: stage-in (prefetch) transfer task.
pub const P_OFF_IN: u16 = 15;
/// Offload pipeline: staged shard compute task.
pub const P_OFF_COMPUTE: u16 = 16;
/// Offload pipeline: writeback transfer task.
pub const P_OFF_OUT: u16 = 17;

/// Phase display names, indexed by phase id.
pub const PHASE_NAMES: [&str; 18] = [
    "engine.F",
    "engine.A",
    "engine.reduce",
    "engine.C",
    "engine.commit",
    "dense.adamw32",
    "dense.sgdm",
    "dense.sm3",
    "dense.sm3.reduce",
    "dense.af.F",
    "dense.af.reduce",
    "dense.af.U",
    "dense.af.rms",
    "dense.af.W",
    "offload.queue",
    "offload.in",
    "offload.compute",
    "offload.out",
];

/// Display name of a phase id (`"?"` for out-of-table ids).
pub fn phase_name(id: u16) -> &'static str {
    PHASE_NAMES.get(id as usize).copied().unwrap_or("?")
}

/// Nanoseconds since the process-global trace epoch (first call). One
/// shared epoch keeps coordinator and worker timestamps on a single
/// timeline for the chrome export. Allocation-free.
#[inline]
pub fn now() -> u64 {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    EPOCH.get_or_init(Instant::now).elapsed().as_nanos() as u64
}

/// One recorded span: a phase id, the task id within the phase
/// ([`TASK_NONE`] for coordinator phase spans) and the start/end
/// timestamps from [`now`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Span {
    pub phase: u16,
    pub task: u32,
    pub t0: u64,
    pub t1: u64,
}

impl Span {
    /// Duration in nanoseconds (0 for a clock hiccup, never negative).
    #[inline]
    pub fn dur_ns(&self) -> u64 {
        self.t1.saturating_sub(self.t0)
    }
}

/// Default ring capacity (spans). 16 bytes per span ⇒ 32 KiB per ring.
pub const DEFAULT_RING_CAP: usize = 2048;

/// Fixed-capacity span ring. All storage is allocated up front by
/// [`Ring::ensure_cap`] (the executors call it on the cold `ensure`
/// path); [`Ring::record`] is a wrapping indexed store — no allocation,
/// no branch on capacity growth. When full the oldest span is
/// overwritten and counted in [`Ring::dropped`].
#[derive(Debug, Default)]
pub struct Ring {
    spans: Vec<Span>,
    /// Next write index.
    head: usize,
    /// Number of live spans (≤ capacity).
    len: usize,
    /// Spans overwritten after the ring filled.
    dropped: u64,
}

impl Ring {
    /// Grow the preallocated storage to at least `cap` spans. Cold path;
    /// idempotent and grow-only, so warmed-up steps never re-enter the
    /// allocator. Existing contents are reset (capacity growth renumbers
    /// the wrap point; rings are cleared per warm-up anyway).
    pub fn ensure_cap(&mut self, cap: usize) {
        if self.spans.len() < cap {
            self.spans = vec![Span::default(); cap];
            self.head = 0;
            self.len = 0;
        }
    }

    /// Forget all recorded spans (storage is kept).
    pub fn clear(&mut self) {
        self.head = 0;
        self.len = 0;
        self.dropped = 0;
    }

    /// Record a span that started at `t0` (from [`now`]) and ends now.
    #[inline]
    pub fn record(&mut self, phase: u16, task: u32, t0: u64) {
        self.push(Span {
            phase,
            task,
            t0,
            t1: now(),
        });
    }

    /// Append a fully-formed span (wrapping; drops into `dropped` when
    /// the ring was never given capacity).
    #[inline]
    pub fn push(&mut self, s: Span) {
        let cap = self.spans.len();
        if cap == 0 {
            self.dropped += 1;
            return;
        }
        self.spans[self.head] = s;
        self.head += 1;
        if self.head == cap {
            self.head = 0;
        }
        if self.len < cap {
            self.len += 1;
        } else {
            self.dropped += 1;
        }
    }

    /// Live span count.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Spans overwritten because the ring was full (or had no capacity).
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Iterate the live spans oldest → newest.
    pub fn iter(&self) -> impl Iterator<Item = &Span> {
        let cap = self.spans.len();
        let start = if self.len < cap || cap == 0 {
            0
        } else {
            self.head
        };
        (0..self.len).map(move |i| {
            let idx = if cap == 0 { 0 } else { (start + i) % cap };
            &self.spans[idx]
        })
    }
}

/// Render rings as chrome://tracing "trace event format" JSON. `rings`
/// pairs a display thread id (0 = coordinator, `1 + slot` = pool worker)
/// with its ring; export allocates freely (it is never on the step hot
/// path).
pub fn chrome_trace(rings: &[(u32, &Ring)]) -> Json {
    let mut events = Vec::new();
    for &(tid, ring) in rings {
        for s in ring.iter() {
            let mut e = Json::obj();
            e.set("name", Json::Str(phase_name(s.phase).to_string()))
                .set("cat", Json::Str("lowbit".to_string()))
                .set("ph", Json::Str("X".to_string()))
                .set("ts", Json::Num(s.t0 as f64 / 1e3))
                .set("dur", Json::Num(s.dur_ns() as f64 / 1e3))
                .set("pid", Json::Num(1.0))
                .set("tid", Json::Num(tid as f64));
            if s.task != TASK_NONE {
                let mut args = Json::obj();
                args.set("task", Json::Num(s.task as f64));
                e.set("args", args);
            }
            events.push(e);
        }
    }
    let mut doc = Json::obj();
    doc.set("traceEvents", Json::Arr(events))
        .set("displayTimeUnit", Json::Str("ns".to_string()));
    doc
}

/// The schedule-independent part of a trace: the coordinator's phase-id
/// sequence in recorded order, plus the multiset of worker `(phase,
/// task)` pairs sorted canonically (which *worker* ran a task and every
/// timestamp are schedule-dependent and excluded). Identical seeds ⇒
/// identical fingerprints across runs, thread counts and scheduler
/// modes — pinned by `rust/tests/obs_trace.rs`.
pub fn fingerprint(rings: &[(u32, &Ring)]) -> (Vec<u16>, Vec<(u16, u32)>) {
    let mut coord = Vec::new();
    let mut tasks = Vec::new();
    for &(tid, ring) in rings {
        for s in ring.iter() {
            if tid == 0 {
                coord.push(s.phase);
            } else {
                tasks.push((s.phase, s.task));
            }
        }
    }
    tasks.sort_unstable();
    (coord, tasks)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_wraps_and_counts_drops() {
        let mut r = Ring::default();
        // No capacity: everything drops.
        r.record(P_ENGINE_A, 0, now());
        assert_eq!(r.len(), 0);
        assert_eq!(r.dropped(), 1);
        r.clear();
        r.ensure_cap(4);
        for i in 0..6u32 {
            r.push(Span {
                phase: P_ENGINE_A,
                task: i,
                t0: i as u64,
                t1: i as u64 + 1,
            });
        }
        assert_eq!(r.len(), 4);
        assert_eq!(r.dropped(), 2);
        // Oldest → newest after wrap: tasks 2, 3, 4, 5.
        let tasks: Vec<u32> = r.iter().map(|s| s.task).collect();
        assert_eq!(tasks, vec![2, 3, 4, 5]);
    }

    #[test]
    fn ensure_cap_is_grow_only_and_idempotent() {
        let mut r = Ring::default();
        r.ensure_cap(8);
        r.record(P_ENGINE_C, 1, now());
        r.ensure_cap(8); // no-op: contents survive
        assert_eq!(r.len(), 1);
        r.ensure_cap(4); // shrink request: no-op
        assert_eq!(r.len(), 1);
    }

    #[test]
    fn chrome_trace_shape() {
        let mut r = Ring::default();
        r.ensure_cap(8);
        r.push(Span {
            phase: P_ENGINE_A,
            task: 3,
            t0: 1000,
            t1: 3500,
        });
        let mut coord = Ring::default();
        coord.ensure_cap(8);
        coord.push(Span {
            phase: P_ENGINE_REDUCE,
            task: TASK_NONE,
            t0: 0,
            t1: 9000,
        });
        let doc = chrome_trace(&[(0, &coord), (1, &r)]);
        let text = doc.to_string();
        let back = Json::parse(&text).expect("chrome trace must be valid JSON");
        let events = back.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(events.len(), 2);
        let e0 = &events[0];
        assert_eq!(e0.get("name").unwrap().as_str(), Some("engine.reduce"));
        assert_eq!(e0.get("ph").unwrap().as_str(), Some("X"));
        assert!(e0.get("args").is_none(), "phase spans carry no task arg");
        let e1 = &events[1];
        assert_eq!(e1.get("name").unwrap().as_str(), Some("engine.A"));
        assert_eq!(
            e1.get("args").unwrap().get("task").unwrap().as_f64(),
            Some(3.0)
        );
        assert_eq!(e1.get("ts").unwrap().as_f64(), Some(1.0));
        assert_eq!(e1.get("dur").unwrap().as_f64(), Some(2.5));
    }

    #[test]
    fn fingerprint_ignores_worker_assignment_and_time() {
        let mk = |spans: &[(u16, u32)]| {
            let mut r = Ring::default();
            r.ensure_cap(16);
            for (i, &(p, t)) in spans.iter().enumerate() {
                r.push(Span {
                    phase: p,
                    task: t,
                    t0: i as u64 * 10,
                    t1: i as u64 * 10 + 5,
                });
            }
            r
        };
        let coord = mk(&[(P_ENGINE_A, TASK_NONE), (P_ENGINE_C, TASK_NONE)]);
        // Same tasks split across workers differently, different times.
        let w1a = mk(&[(P_ENGINE_A, 0), (P_ENGINE_A, 2)]);
        let w2a = mk(&[(P_ENGINE_A, 1)]);
        let w1b = mk(&[(P_ENGINE_A, 1), (P_ENGINE_A, 0)]);
        let w2b = mk(&[(P_ENGINE_A, 2)]);
        let fa = fingerprint(&[(0, &coord), (1, &w1a), (2, &w2a)]);
        let fb = fingerprint(&[(0, &coord), (1, &w1b), (2, &w2b)]);
        assert_eq!(fa, fb);
        assert_eq!(fa.0, vec![P_ENGINE_A, P_ENGINE_C]);
    }

    #[test]
    fn phase_names_cover_ids() {
        for (i, name) in PHASE_NAMES.iter().enumerate() {
            assert_eq!(phase_name(i as u16), *name);
            assert!(!name.is_empty());
        }
        assert_eq!(phase_name(999), "?");
    }
}
