#![forbid(unsafe_code)]
//! Observability: structured, low-overhead telemetry for the step engine,
//! the offload pipeline and the quantizer.
//!
//! Three layers, one report:
//!
//! * [`trace`] — span tracing. Every engine/offload phase and (where the
//!   executor threads worker scratch through the pool) every worker task
//!   records a `(phase, task, t0, t1)` [`trace::Span`] into a
//!   preallocated ring buffer. The coordinator ring and the per-worker
//!   rings are owned by the optimizer's cached
//!   [`crate::engine::StepContext`] (the coordinator ring directly, the
//!   worker rings inside each [`crate::engine::StepScratch`] slot), so a
//!   warmed-up traced step performs **zero heap allocations** — the same
//!   contract `rust/tests/ctx_cache.rs` pins for the untraced step.
//! * [`quant`] — quantization-quality metrics. Optional (runtime-gated,
//!   see below) per-step accumulators of quantization error (RMSE /
//!   max-abs / relative) of the first and second moments against their
//!   pre-encode fp32 values, nibble-code occupancy histograms (the
//!   zero-point diagnostic: how often a map's zero code fires), and
//!   per-tensor dynamic-range / top-of-range outlier counters.
//! * [`report`] — unified reporting. [`report::StepReport`] bundles
//!   scheduler telemetry ([`crate::engine::SchedStats`]), the offload
//!   pipeline's [`crate::offload::OffloadReport`], span summaries
//!   (per-phase count/total/p50/p95/max percentiles — never raw spans)
//!   and the quant metrics behind one `Optimizer::step_report()`
//!   accessor; `train/trainer.rs` prints it at a configurable cadence
//!   and the benches append its summary to `BENCH_engine.json` /
//!   `BENCH_offload.json`.
//!
//! # Overhead contract
//!
//! * **Feature-gated spans.** Span *recording* compiles to nothing
//!   without the `trace` cargo feature, mirroring `engine/audit.rs`: the
//!   ring fields on `StepContext` / `StepScratch` and every record call
//!   are behind `#[cfg(feature = "trace")]`, so the hot paths are
//!   untouched no-ops when the feature is off. The types in this module
//!   always compile (reports still carry sched/offload/quant data).
//! * **Zero steady-state allocations.** Rings are preallocated to a
//!   fixed capacity on the cold (`ensure`) path and recording is a plain
//!   indexed store plus one monotonic-clock read; when a ring is full it
//!   wraps, overwriting the oldest span and counting the overwrite in
//!   `dropped`. `ctx_cache.rs` runs its allocation pins with
//!   `--features trace` in CI.
//! * **Runtime-gated quant metrics.** Quant-quality accumulation is off
//!   by default and enabled per optimizer
//!   (`CompressedAdamW::with_quant_metrics`); it re-reads state the
//!   phase-C / phase-A encode just produced while the pre-encode fp32
//!   values are still resident in shard-local scratch, and never
//!   perturbs results (no extra RNG draws — metrics ride the unfused
//!   reference re-encode arm, which is bit-identical to the fused one).
//!
//! # Export format
//!
//! [`trace::chrome_trace`] renders the rings as chrome://tracing /
//! Perfetto "trace event" JSON: one complete event (`"ph": "X"`) per
//! span with `ts`/`dur` in microseconds, `tid` 0 for the coordinator and
//! `1 + worker slot` for pool workers, and the task id under `args`.
//! Write it via `LOWBIT_TRACE=path.json` (exported by the trainer at the
//! end of a run) or the `lowbit trace` CLI subcommand, then load it in
//! `chrome://tracing` or `ui.perfetto.dev`.
//!
//! # Determinism
//!
//! Which worker records a task span (and every timestamp) is
//! schedule-dependent; everything else — which spans exist, their phase
//! ids, their task ids, the coordinator's phase order — is a pure
//! function of the plan and therefore identical across runs, thread
//! counts and scheduler modes. [`trace::fingerprint`] extracts exactly
//! that schedule-independent part; `rust/tests/obs_trace.rs` pins it.

pub mod quant;
pub mod report;
pub mod trace;

pub use quant::{MomentAccum, QuantAccum};
pub use report::{PhaseSummary, QuantReport, SpanSummary, StepReport};
pub use trace::{chrome_trace, fingerprint, Ring, Span};
