//! The executable offload pipeline: real optimizer steps against the
//! host-resident state tier, with prefetch/compute/writeback overlap.
//!
//! Each shard task of the engine's plan becomes a three-entry chain in
//! one interleaved queue — **stage-in** (copy the task's state segments
//! from the host tier into a device-scratch slot), **compute** (run the
//! exact same per-piece kernels as in-memory execution, against the
//! staged copies), **writeback** (copy the mutated segments home). A
//! prefetch depth of `D` gives `D` scratch slots, so up to `D` tasks'
//! state is in flight while earlier tasks compute; stage-in of task
//! `k + D` waits only for the writeback of task `k` (its slot's previous
//! tenant). The whole queue runs on the engine's persistent worker pool
//! through [`StepEngine::run_tasks_dep`] — see the "Transfer tasks and
//! the dependency contract" section of the engine docs.
//!
//! **Bit-identity.** Compute entries call the kernels shared with the
//! in-memory executor (`engine::adamw4::update_piece` /
//! `decode_ema_piece`, `engine::dense::adamw32_piece`) with the same
//! per-plan-task RNG streams, the cross-shard reductions are the same
//! sequential shard-order code, and staging is byte-exact copying — so
//! offloaded steps equal in-memory steps bit-for-bit at every thread
//! count and every prefetch depth (pinned by
//! `rust/tests/offload_pipeline.rs`).
//!
//! **Virtual time.** Transfers move real bytes but are *charged*, not
//! timed: the per-task byte counts from the tier plan are folded by
//! [`ThrottledLink::step_totals`] into deterministic overlapped/serial
//! totals (no wall-clock sleeps, no schedule dependence). The analytic
//! model in [`super`] is the convergence oracle for these totals.
//!
//! **Traffic shape.** fp32 and block-normalized states cross the link
//! exactly twice per step (down + up). Globally-normalized states cross
//! **three** times: phase A stages their codes down for the update and
//! scale statistics, and phase C stages them down again to re-encode
//! against the reduced scales, writing the fresh codes back. That extra
//! down-pass is the honest price of global normalization under offload;
//! it is fully accounted in the link totals (and is hidden under
//! compute in every realistic profile). Phase C re-encodes *in place* in
//! the scratch slot, so no double-buffer arenas are allocated for
//! offloaded execution.

use super::link::{LinkTotals, RetryPolicy, ThrottledLink};
use super::tier::{self, TierPlan};
use super::LinkModel;
use crate::engine::adamw4::{
    commit_globals, decode_ema_piece, ensure_compressed_ctx, phase_f, reduce_global_scales,
    update_piece, MSrc, StepParams, VSrc,
};
use crate::engine::ctx::{StepContext, StepScratch};
use crate::engine::plan::{MetaSpec, StateLayout};
use crate::engine::{dense, step_seed, Affinity, SharedSlice, StepEngine, PHASE_C_STREAM_BASE};
use crate::fault::{self, Crc32, FaultPlan, TransferFault};
#[cfg(feature = "trace")]
use crate::obs::trace::{now, P_OFF_COMPUTE, P_OFF_IN, P_OFF_OUT, P_OFF_QUEUE, TASK_NONE};
use crate::optim::state::{MomentState, SecondState};
use crate::optim::{Hyper, Param};
use crate::quant::{QuantMap, Scales};
use crate::tensor::Tensor;
use crate::util::rng::Pcg64;
use std::sync::atomic::{AtomicU32, Ordering};

/// Offload-execution configuration: the link profile to charge and the
/// prefetch depth (number of device-scratch slots).
#[derive(Clone, Copy, Debug)]
pub struct OffloadConfig {
    pub link: LinkModel,
    /// 1 = strictly serial stage-in → compute → writeback per task;
    /// ≥ 2 prefetches ahead, overlapping transfers with compute.
    pub depth: usize,
}

impl OffloadConfig {
    pub fn new(link: LinkModel, depth: usize) -> OffloadConfig {
        assert!(depth >= 1, "prefetch depth must be at least 1");
        OffloadConfig { link, depth }
    }
}

/// Accumulated virtual-time measurements of offloaded steps.
#[derive(Clone, Copy, Debug, Default)]
pub struct OffloadReport {
    pub steps: u64,
    pub bytes_down: u64,
    pub bytes_up: u64,
    pub transfers: u64,
    pub comm_seconds: f64,
    pub hidden_seconds: f64,
    pub compute_seconds: f64,
    /// Σ per-step virtual wall time (compute + serial communication).
    pub virtual_seconds: f64,
    /// Transfer attempts replayed after an injected transient failure.
    pub fail_retries: u64,
    /// Transfer attempts replayed after checksum-detected corruption.
    pub corrupt_retries: u64,
    /// Virtual time the retries cost (re-transfer + backoff), already
    /// folded into `comm`/`virtual` via [`LinkTotals::charge_retries`].
    pub retry_seconds: f64,
}

impl OffloadReport {
    /// Mean virtual step time.
    pub fn step_seconds(&self) -> f64 {
        self.virtual_seconds / self.steps.max(1) as f64
    }

    /// Total transfer attempts that were replayed (failures + detected
    /// corruption). Zero on any unarmed run.
    pub fn retries(&self) -> u64 {
        self.fail_retries + self.corrupt_retries
    }

    /// Fraction of link time hidden behind compute, in `[0, 1]`.
    ///
    /// Degenerate steps are absorbed cleanly rather than poisoning the
    /// ratio: an empty plan never reaches [`OffloadReport::absorb`] at
    /// all, and a zero-transfer step (every staged segment empty)
    /// contributes `comm_seconds == 0`, for which this reports `0.0`
    /// instead of `0/0 = NaN`. The clamp covers accumulated rounding in
    /// long runs — by construction `hidden ≤ comm` per step.
    pub fn overlap_fraction(&self) -> f64 {
        if self.comm_seconds > 0.0 {
            (self.hidden_seconds / self.comm_seconds).clamp(0.0, 1.0)
        } else {
            0.0
        }
    }

    fn absorb(&mut self, t: &LinkTotals, compute: f64) {
        self.steps += 1;
        self.bytes_down += t.bytes_down;
        self.bytes_up += t.bytes_up;
        self.transfers += t.transfers;
        self.comm_seconds += t.comm_seconds;
        self.hidden_seconds += t.hidden_seconds;
        self.compute_seconds += compute;
        self.virtual_seconds += t.step_seconds;
        self.retry_seconds += t.retry_seconds;
    }
}

/// One entry of the interleaved queue; the payload indexes the phase's
/// staging list.
#[derive(Clone, Copy, Debug)]
enum Entry {
    In(usize),
    Compute(usize),
    Out(usize),
}

type Queue = (Vec<Entry>, Vec<Option<usize>>);

/// Emit the interleaved queue for `n` staged tasks at prefetch depth
/// `d`: a prologue of `min(d, n)` stage-ins, then per task its compute,
/// its writeback, and the stage-in of the task that reuses its slot.
/// The order is a valid sequential schedule and every dependency points
/// backwards (the engine asserts both).
fn build_queue(n: usize, depth: usize) -> Queue {
    let d = depth.max(1);
    let mut entries = Vec::with_capacity(3 * n);
    let mut deps = Vec::with_capacity(3 * n);
    let mut idx_in = vec![usize::MAX; n];
    for p in 0..d.min(n) {
        idx_in[p] = entries.len();
        entries.push(Entry::In(p));
        deps.push(None);
    }
    for p in 0..n {
        let compute_idx = entries.len();
        entries.push(Entry::Compute(p));
        deps.push(Some(idx_in[p]));
        let out_idx = entries.len();
        entries.push(Entry::Out(p));
        deps.push(Some(compute_idx));
        let q = p + d;
        if q < n {
            // Task q reuses task p's slot (q ≡ p mod d): prefetch as
            // soon as the slot drains.
            idx_in[q] = entries.len();
            entries.push(Entry::In(q));
            deps.push(Some(out_idx));
        }
    }
    (entries, deps)
}

/// Per-optimizer offload execution state: the configuration, the
/// accumulated report, and the cached tier plan + queues (rebuilt when
/// the step context's generation changes — i.e. exactly when the shard
/// plan itself was rebuilt).
pub struct OffloadState {
    pub cfg: OffloadConfig,
    pub report: OffloadReport,
    /// Fault-plan override. `None` defers to the process-wide
    /// env-armed plan ([`fault::active`]); `Some` wins outright, so an
    /// inert [`FaultPlan::none`] pins a run fault-free even under
    /// `LOWBIT_FAULTS`.
    pub faults: Option<FaultPlan>,
    tier: Option<TierPlan>,
    queue_a: Queue,
    queue_c: Queue,
    generation: u64,
}

impl OffloadState {
    pub fn new(cfg: OffloadConfig) -> OffloadState {
        OffloadState {
            cfg,
            report: OffloadReport::default(),
            faults: None,
            tier: None,
            queue_a: (Vec::new(), Vec::new()),
            queue_c: (Vec::new(), Vec::new()),
            generation: 0,
        }
    }

    /// The plan this run injects from, if any: the per-run override,
    /// else the env-armed plan; unarmed plans resolve to `None` so the
    /// hot path stays on the exact pre-fault code.
    fn fault_plan(&self) -> Option<&FaultPlan> {
        match &self.faults {
            Some(p) => Some(p).filter(|p| p.armed()),
            None => fault::active().filter(|p| p.armed()),
        }
    }
}

/// Per-staged-task retry counters, written by whichever worker runs the
/// transfer entry and folded **serially in task order** after the phase
/// drains — so the virtual-time retry charges are schedule-independent.
#[derive(Default)]
struct FaultCell {
    fail_down: AtomicU32,
    corrupt_down: AtomicU32,
    fail_up: AtomicU32,
}

fn fault_cells(n: usize, armed: bool) -> Vec<FaultCell> {
    if armed {
        (0..n).map(|_| FaultCell::default()).collect()
    } else {
        Vec::new()
    }
}

/// Execute one staged transfer under an armed fault plan: replay the
/// identical [`tier::copy_task_segments`] call (copies are idempotent,
/// so retries preserve bit-identity) until the payload lands clean.
///
/// Stage-in integrity: after a clean copy the staged payload's CRC-32
/// is the sender-side checksum; the modeled link may then corrupt a
/// deterministic staged byte, and the receiver-side re-verify catches
/// the mismatch *before any compute entry reads the slot* (the compute
/// depends on this transfer entry). Transient failures re-roll on their
/// attempt index, so a retry is not doomed to repeat its fault.
/// Exhausting [`RetryPolicy::max_attempts`] is fatal-by-panic, which
/// `Optimizer::try_step` converts into a rolled-back step.
#[allow(clippy::too_many_arguments)]
fn transfer_with_faults(
    plan: &FaultPlan,
    phase: fault::Phase,
    step: u64,
    ts: &tier::TaskStaging,
    cell: &FaultCell,
    sb: SharedSlice<u8>,
    sv: SharedSlice<f32>,
    to_device: bool,
    copy: &dyn Fn(),
) {
    let max = RetryPolicy::default().max_attempts;
    // A direction that moves no bytes issues no DMA — nothing to fault.
    if (to_device && ts.down_bytes == 0) || (!to_device && ts.up_bytes == 0) {
        copy();
        return;
    }
    let mut attempt = 0u32;
    loop {
        assert!(
            attempt < max,
            "offload link: task {} transfer ({:?}, step {step}) still faulted after {max} attempts",
            ts.task,
            phase,
        );
        copy();
        if !to_device {
            // Writeback: corruption degrades to replay-from-staging
            // (the staged source is intact), so any fault is a redo.
            match plan.transfer_fault(step, phase, ts.task, true, attempt) {
                Some(_) => {
                    cell.fail_up.fetch_add(1, Ordering::Relaxed);
                    attempt += 1;
                }
                None => return,
            }
            continue;
        }
        // Stage-in: checksum, maybe corrupt, verify.
        // SAFETY: the slot is exclusive to this transfer entry until
        // its dependent compute runs (dependency discipline), and this
        // task may hold overlapping views of its own slot.
        let bytes: &mut [u8] = unsafe { sb.range_mut(0, ts.bytes_len) };
        // SAFETY: same exclusive slot, the disjoint f32 arena.
        let vals: &mut [f32] = unsafe { sv.range_mut(0, ts.vals_len) };
        let mut sender = Crc32::new();
        sender.update(bytes);
        sender.update_f32s(vals);
        let expected = sender.finish();
        match plan.transfer_fault(step, phase, ts.task, false, attempt) {
            Some(TransferFault::Fail) => {
                cell.fail_down.fetch_add(1, Ordering::Relaxed);
                attempt += 1;
                continue;
            }
            Some(TransferFault::Corrupt) => {
                // The link flips a deterministic staged byte (or an f32
                // bit when this task stages no packed bytes).
                if ts.bytes_len > 0 {
                    let off = plan.corrupt_offset(step, phase, ts.task, attempt, ts.bytes_len);
                    bytes[off] ^= 0xFF;
                } else if ts.vals_len > 0 {
                    let off = plan.corrupt_offset(step, phase, ts.task, attempt, ts.vals_len);
                    vals[off] = f32::from_bits(vals[off].to_bits() ^ 1);
                }
            }
            None => {}
        }
        let mut receiver = Crc32::new();
        receiver.update(bytes);
        receiver.update_f32s(vals);
        if receiver.finish() != expected {
            cell.corrupt_down.fetch_add(1, Ordering::Relaxed);
            attempt += 1;
            continue;
        }
        return;
    }
}

/// Fold a phase's retry cells into the step totals and the report
/// counters — serially, in staged-task order, so the charges are
/// bit-reproducible at any worker count.
fn charge_fault_cells(
    link: &ThrottledLink,
    policy: &RetryPolicy,
    cells: &[FaultCell],
    stagings: &[tier::TaskStaging],
    totals: &mut LinkTotals,
    report: &mut OffloadReport,
) {
    for (cell, ts) in cells.iter().zip(stagings.iter()) {
        let fd = cell.fail_down.load(Ordering::Relaxed);
        let cd = cell.corrupt_down.load(Ordering::Relaxed);
        let fu = cell.fail_up.load(Ordering::Relaxed);
        if fd + cd + fu == 0 {
            continue;
        }
        let secs = link.retry_seconds(ts.down_bytes, fd + cd, policy)
            + link.retry_seconds(ts.up_bytes, fu, policy);
        totals.charge_retries((fd + cd + fu) as u64, secs);
        report.fail_retries += (fd + fu) as u64;
        report.corrupt_retries += cd as u64;
    }
}

/// Run one interleaved queue on the engine: transfers and computes drain
/// from the same worker pool under the dependency discipline.
fn run_queue<T, C>(
    eng: &StepEngine,
    threads: usize,
    queue: &Queue,
    aff: &mut Affinity,
    scratch: &mut [StepScratch],
    transfer: &T,
    compute: &C,
) where
    T: Fn(usize, bool) + Sync,
    C: Fn(usize, &mut StepScratch) + Sync,
{
    let (entries, deps) = queue;
    let entries = &entries[..];
    eng.run_tasks_dep_in(threads, deps, aff, scratch, |qi, s: &mut StepScratch| {
        #[cfg(feature = "trace")]
        let _ts = now();
        match entries[qi] {
            Entry::In(p) => {
                transfer(p, true);
                #[cfg(feature = "trace")]
                s.ring.record(P_OFF_IN, p as u32, _ts);
            }
            Entry::Out(p) => {
                transfer(p, false);
                #[cfg(feature = "trace")]
                s.ring.record(P_OFF_OUT, p as u32, _ts);
            }
            Entry::Compute(p) => {
                compute(p, s);
                #[cfg(feature = "trace")]
                s.ring.record(P_OFF_COMPUTE, p as u32, _ts);
            }
        }
    });
}

/// Per-tensor device-resident context (weights and gradients are not
/// offloaded; only optimizer state is).
struct OffTensor<'a> {
    shape: &'a [usize],
    cols: usize,
    w: SharedSlice<'a, f32>,
    g: &'a [f32],
}

fn v_map_of<'a>(sp: &StepParams<'a>, ndim: usize) -> &'a QuantMap {
    if ndim >= 2 { sp.v_map } else { sp.v1_map }.expect("cached v map exists for quantized v")
}

/// One offloaded step of the compressed optimizer — the staged
/// counterpart of [`crate::engine::compressed_step`], bit-identical to
/// it at every thread count and prefetch depth.
#[allow(clippy::too_many_arguments)]
pub fn compressed_offloaded_step(
    eng: &StepEngine,
    ctx: &mut StepContext,
    os: &mut OffloadState,
    sp: &StepParams,
    params: &mut [Param],
    grads: &[Tensor],
    m_states: &mut [MomentState],
    v_states: &mut [SecondState],
) {
    let n = params.len();
    debug_assert_eq!(grads.len(), n);
    debug_assert_eq!(m_states.len(), n);
    debug_assert_eq!(v_states.len(), n);

    ensure_compressed_ctx(ctx, eng.shard_elems(), params, m_states, v_states, false);
    if ctx.plan.tasks.is_empty() {
        return;
    }
    if os.tier.is_none() || os.generation != ctx.generation() {
        let tp = tier::build_tier_plan(&ctx.plan, &ctx.metas, m_states, v_states);
        os.queue_a = build_queue(tp.a.len(), os.cfg.depth);
        os.queue_c = build_queue(tp.c.len(), os.cfg.depth);
        os.tier = Some(tp);
        os.generation = ctx.generation();
    }
    ctx.begin_step();
    let threads = eng.resolve_threads(ctx.plan.tasks.len(), ctx.plan.total_elems);
    ctx.ensure_scratch(threads);
    // Quant-quality metrics are an in-memory-executor feature (see
    // `obs::quant`): the staged path shares `update_piece`, whose taps
    // key off the per-worker accumulator, so disarm anything a prior
    // metered in-memory step left behind. No-op on steady offload runs.
    for s in ctx.scratch.iter_mut() {
        s.quant = None;
    }
    let depth = os.cfg.depth.max(1);
    {
        let tp = os.tier.as_ref().expect("tier plan built above");
        ctx.ensure_stage(depth, tp.slot_bytes, tp.slot_vals);
    }
    let tp = os.tier.as_ref().expect("tier plan built above");

    let StepContext {
        metas,
        plan,
        slots,
        scratch,
        red,
        globals,
        new_scales,
        m_buf_of,
        v_buf_of,
        arena,
        stage_bytes,
        stage_vals,
        affinity,
        #[cfg(feature = "trace")]
        trace,
        ..
    } = ctx;
    let plan = &*plan;
    let metas = &*metas;
    let globals = &*globals;
    let (m_buf_of, v_buf_of) = (&*m_buf_of, &*v_buf_of);

    let seed = step_seed(sp.base_seed, sp.t as u64);
    let hp = sp.hp;
    let step_u = sp.t as u64;
    let faults = os.fault_plan();
    let cells_a = fault_cells(tp.a.len(), faults.is_some());
    let cells_c = fault_cells(tp.c.len(), faults.is_some());

    // ---------------- Phase F: factored-v statistics -----------------
    // Gradients are device-resident and factored stats stay resident,
    // so phase F runs exactly as in memory — no staging involved.
    if metas.iter().any(|m| m.v == StateLayout::Factored) {
        phase_f(eng, threads, plan, metas, slots, red, arena, grads, &hp, v_states, affinity);
    }

    {
        // Host views over the optimizer's state buffers (the tier) and
        // device views over params/grads and the scratch slots.
        let mut m_hosts = arena.lease::<tier::HostMoment>();
        m_hosts.extend(m_states.iter_mut().map(tier::host_m));
        let mut v_hosts = arena.lease::<tier::HostMoment>();
        v_hosts.extend(v_states.iter_mut().map(tier::host_v));
        let (m_hosts, v_hosts) = (m_hosts.as_slice(), v_hosts.as_slice());
        let mut tens = arena.lease::<OffTensor>();
        tens.extend(params.iter_mut().zip(grads.iter()).enumerate().map(|(i, (p, g))| {
            let shape: &[usize] = &metas[i].shape;
            let cols = if shape.len() >= 2 {
                metas[i].numel / shape[0]
            } else {
                metas[i].numel
            };
            OffTensor {
                shape,
                cols,
                w: SharedSlice::new(p.tensor.data.as_mut_slice()),
                g: &g.data,
            }
        }));
        let tens = tens.as_slice();
        let mut sb_views = arena.lease::<SharedSlice<u8>>();
        sb_views.extend(
            stage_bytes[..depth].iter_mut().map(|b| SharedSlice::new(b.as_mut_slice())),
        );
        let sb_views = sb_views.as_slice();
        let mut sv_views = arena.lease::<SharedSlice<f32>>();
        sv_views.extend(stage_vals[..depth].iter_mut().map(|v| SharedSlice::new(v.as_mut_slice())));
        let sv_views = sv_views.as_slice();

        // ------- Phase A: staged prefetch / update / writeback -------
        {
            let mut slot_views = arena.lease::<SharedSlice<f32>>();
            slot_views.extend(slots.iter_mut().map(|s| SharedSlice::new(s.as_mut_slice())));
            let slot_views = slot_views.as_slice();
            let stagings = &tp.a[..];
            let transfer = |pos: usize, to_device: bool| {
                let ts = &stagings[pos];
                let copy = || {
                    tier::copy_task_segments(
                        ts,
                        &plan.tasks[ts.task].pieces,
                        m_hosts,
                        v_hosts,
                        sb_views[pos % depth],
                        sv_views[pos % depth],
                        to_device,
                        !to_device,
                    );
                };
                match faults {
                    None => copy(),
                    Some(p) => transfer_with_faults(
                        p,
                        fault::Phase::A,
                        step_u,
                        ts,
                        &cells_a[pos],
                        sb_views[pos % depth],
                        sv_views[pos % depth],
                        to_device,
                        &copy,
                    ),
                }
            };
            let compute = |pos: usize, scratch: &mut StepScratch| {
                let ts = &stagings[pos];
                if let Some(p) = faults {
                    if p.should_panic(step_u, fault::Phase::A, ts.task) {
                        panic!("injected fault: worker panic at phase A task {}", ts.task);
                    }
                }
                let sb = sb_views[pos % depth];
                let sv = sv_views[pos % depth];
                let pieces = &plan.tasks[ts.task].pieces;
                let mut rng = Pcg64::new(seed, ts.task as u64);
                for (ps, piece) in ts.pieces.iter().zip(pieces.iter()) {
                    let (lo, hi) = (piece.lo, piece.hi);
                    let tc = &tens[piece.tensor];
                    // SAFETY: pieces partition each tensor disjointly
                    // (plan invariant), so this task is the sole writer
                    // of w[lo..hi).
                    let w = unsafe { tc.w.range_mut(lo, hi) };
                    let g = &tc.g[lo..hi];
                    let m_src = match (&m_hosts[piece.tensor], &ps.m) {
                        (tier::HostMoment::F32(_), Some(seg)) => {
                            // SAFETY: the slot is exclusive to this task
                            // between its stage-in and writeback
                            // (dependency discipline).
                            MSrc::F32(unsafe {
                                sv.range_mut(seg.vals_off, seg.vals_off + seg.vals_len)
                            })
                        }
                        (tier::HostMoment::Block { q, block, .. }, Some(seg)) => MSrc::Block {
                            q: *q,
                            map: sp.m_map.expect("cached m map exists for quantized m"),
                            block: *block,
                            // SAFETY: exclusive slot (dependency
                            // discipline).
                            packed: unsafe {
                                sb.range_mut(seg.bytes_off, seg.bytes_off + seg.bytes_len)
                            },
                            // SAFETY: same exclusive slot, disjoint
                            // f32 sub-range of the vals arena.
                            scales: unsafe {
                                sv.range_mut(seg.vals_off, seg.vals_off + seg.vals_len)
                            },
                        },
                        (tier::HostMoment::Global { q, scales, .. }, Some(seg)) => {
                            let slot_id = piece.m_slot.expect("global m has a slot");
                            // SAFETY: one stat slot per piece (plan
                            // invariant); exclusive scratch slot.
                            let stat = unsafe {
                                slot_views[slot_id].range_mut(0, slot_views[slot_id].len())
                            };
                            // SAFETY: exclusive slot (dependency
                            // discipline); read-only staged codes.
                            let pk: &[u8] = unsafe {
                                sb.range_mut(seg.bytes_off, seg.bytes_off + seg.bytes_len)
                            };
                            MSrc::Global {
                                q: *q,
                                map: sp.m_map.expect("cached m map exists for quantized m"),
                                packed: pk,
                                scales: *scales,
                                stat,
                            }
                        }
                        _ => unreachable!("first moment is always staged in phase A"),
                    };
                    let v_src = match (&v_hosts[piece.tensor], &ps.v) {
                        (tier::HostMoment::F32(_), Some(seg)) => {
                            // SAFETY: exclusive slot (dependency
                            // discipline).
                            VSrc::F32(unsafe {
                                sv.range_mut(seg.vals_off, seg.vals_off + seg.vals_len)
                            })
                        }
                        (tier::HostMoment::Block { q, block, .. }, Some(seg)) => VSrc::Block {
                            q: *q,
                            map: v_map_of(sp, tc.shape.len()),
                            block: *block,
                            // SAFETY: exclusive slot (dependency
                            // discipline).
                            packed: unsafe {
                                sb.range_mut(seg.bytes_off, seg.bytes_off + seg.bytes_len)
                            },
                            // SAFETY: same exclusive slot, disjoint
                            // f32 sub-range of the vals arena.
                            scales: unsafe {
                                sv.range_mut(seg.vals_off, seg.vals_off + seg.vals_len)
                            },
                        },
                        (tier::HostMoment::Global { q, scales, .. }, Some(seg)) => {
                            let slot_id = piece.v_slot.expect("global v has a slot");
                            // SAFETY: one stat slot per piece (plan
                            // invariant); exclusive scratch slot.
                            let stat = unsafe {
                                slot_views[slot_id].range_mut(0, slot_views[slot_id].len())
                            };
                            // SAFETY: exclusive slot (dependency
                            // discipline); read-only staged codes.
                            let pk: &[u8] = unsafe {
                                sb.range_mut(seg.bytes_off, seg.bytes_off + seg.bytes_len)
                            };
                            VSrc::Global {
                                q: *q,
                                map: v_map_of(sp, tc.shape.len()),
                                packed: pk,
                                scales: *scales,
                                stat,
                            }
                        }
                        (tier::HostMoment::Factored { f, row_mean }, None) => VSrc::Factored {
                            f: *f,
                            row_mean: *row_mean,
                        },
                        _ => unreachable!("v staging matches its storage form"),
                    };
                    update_piece(
                        piece.tensor, lo, tc.shape, tc.cols, w, g, m_src, v_src, &hp, sp.t,
                        sp.lr, scratch, &mut rng,
                    );
                }
            };
            #[cfg(feature = "trace")]
            let _t0 = now();
            run_queue(eng, threads, &os.queue_a, affinity, &mut scratch[..], &transfer, &compute);
            #[cfg(feature = "trace")]
            trace.record(P_OFF_QUEUE, TASK_NONE, _t0);
        }

        // ---------- Reduce A→C: combine scale statistics -------------
        reduce_global_scales(plan, metas, globals, slots, red, new_scales);

        // --------------- Phase C: global re-encode -------------------
        if !tp.c.is_empty() {
            let stagings = &tp.c[..];
            let new_scales_ref: &[Option<Scales>] = &new_scales[..];
            let transfer = |pos: usize, to_device: bool| {
                let ts = &stagings[pos];
                let copy = || {
                    tier::copy_task_segments(
                        ts,
                        &plan.tasks[ts.task].pieces,
                        m_hosts,
                        v_hosts,
                        sb_views[pos % depth],
                        sv_views[pos % depth],
                        to_device,
                        !to_device,
                    );
                };
                match faults {
                    None => copy(),
                    Some(p) => transfer_with_faults(
                        p,
                        fault::Phase::C,
                        step_u,
                        ts,
                        &cells_c[pos],
                        sb_views[pos % depth],
                        sv_views[pos % depth],
                        to_device,
                        &copy,
                    ),
                }
            };
            let compute = |pos: usize, scratch: &mut StepScratch| {
                let ts = &stagings[pos];
                if let Some(p) = faults {
                    if p.should_panic(step_u, fault::Phase::C, ts.task) {
                        panic!("injected fault: worker panic at phase C task {}", ts.task);
                    }
                }
                let sb = sb_views[pos % depth];
                let pieces = &plan.tasks[ts.task].pieces;
                let mut rng = Pcg64::new(seed, PHASE_C_STREAM_BASE + ts.task as u64);
                for (ps, piece) in ts.pieces.iter().zip(pieces.iter()) {
                    let (lo, hi) = (piece.lo, piece.hi);
                    let tc = &tens[piece.tensor];
                    let g = &tc.g[lo..hi];
                    if let (tier::HostMoment::Global { q, scales, .. }, Some(seg)) =
                        (&m_hosts[piece.tensor], &ps.m)
                    {
                        let map = sp.m_map.expect("cached m map exists for quantized m");
                        let new_sc = new_scales_ref[m_buf_of[piece.tensor]]
                            .as_ref()
                            .expect("reduced m scales");
                        let (d0, d1) = (seg.bytes_off, seg.bytes_off + seg.bytes_len);
                        // SAFETY: exclusive slot (dependency discipline);
                        // the staged old codes are re-encoded in place.
                        let dst = unsafe { sb.range_mut(d0, d1) };
                        if !q.ema_reencode_range(
                            map, dst, lo, tc.shape, scales, new_sc, g, hp.beta1, false, &mut rng,
                        ) {
                            decode_ema_piece(
                                q.bits, map, dst, scales, lo, tc.shape, g, hp.beta1, false,
                                &mut scratch.m,
                            );
                            q.encode_range_with_scales(
                                map,
                                &scratch.m[..hi - lo],
                                lo,
                                tc.shape,
                                new_sc,
                                dst,
                                &mut rng,
                            );
                        }
                    }
                    if let (tier::HostMoment::Global { q, scales, .. }, Some(seg)) =
                        (&v_hosts[piece.tensor], &ps.v)
                    {
                        let map = v_map_of(sp, tc.shape.len());
                        let new_sc = new_scales_ref[v_buf_of[piece.tensor]]
                            .as_ref()
                            .expect("reduced v scales");
                        let (d0, d1) = (seg.bytes_off, seg.bytes_off + seg.bytes_len);
                        // SAFETY: exclusive slot (dependency discipline);
                        // the staged old codes are re-encoded in place.
                        let dst = unsafe { sb.range_mut(d0, d1) };
                        if !q.ema_reencode_range(
                            map, dst, lo, tc.shape, scales, new_sc, g, hp.beta2, true, &mut rng,
                        ) {
                            decode_ema_piece(
                                q.bits, map, dst, scales, lo, tc.shape, g, hp.beta2, true,
                                &mut scratch.v,
                            );
                            q.encode_range_with_scales(
                                map,
                                &scratch.v[..hi - lo],
                                lo,
                                tc.shape,
                                new_sc,
                                dst,
                                &mut rng,
                            );
                        }
                    }
                }
            };
            #[cfg(feature = "trace")]
            let _t0 = now();
            run_queue(eng, threads, &os.queue_c, affinity, &mut scratch[..], &transfer, &compute);
            #[cfg(feature = "trace")]
            trace.record(P_OFF_QUEUE, TASK_NONE, _t0);
        }
    }

    // Commit: the fresh codes are already home (phase C wrote back in
    // place); only the reduced scales swap in.
    commit_globals(globals, None, new_scales, m_states, v_states);

    // ------------------- Virtual-time accounting ---------------------
    let mut totals = {
        let mut pairs_a = arena.lease::<(u64, u64)>();
        pairs_a.extend(tp.a.iter().map(|ts| (ts.down_bytes, ts.up_bytes)));
        let mut pairs_c = arena.lease::<(u64, u64)>();
        pairs_c.extend(tp.c.iter().map(|ts| (ts.down_bytes, ts.up_bytes)));
        ThrottledLink::new(os.cfg.link)
            .step_totals(depth, &[pairs_a.as_slice(), pairs_c.as_slice()])
    };
    if !cells_a.is_empty() || !cells_c.is_empty() {
        let link = ThrottledLink::new(os.cfg.link);
        let policy = RetryPolicy::default();
        charge_fault_cells(&link, &policy, &cells_a, &tp.a, &mut totals, &mut os.report);
        charge_fault_cells(&link, &policy, &cells_c, &tp.c, &mut totals, &mut os.report);
    }
    os.report.absorb(&totals, os.cfg.link.compute_per_step);
}

/// One offloaded fp32-AdamW step — the staged counterpart of
/// [`crate::engine::dense::adamw32_step`], bit-identical to it (and to
/// the sequential reference loop) at every thread count and depth. Both
/// moments stage as fp32 segments, so the per-step traffic is exactly
/// `2 × state_bytes` — the analytic model's assumption, which makes this
/// the cleanest convergence check against the oracle.
#[allow(clippy::too_many_arguments)]
pub fn dense_offloaded_step(
    eng: &StepEngine,
    ctx: &mut StepContext,
    os: &mut OffloadState,
    hp: &Hyper,
    t: usize,
    lr: f32,
    params: &mut [Param],
    grads: &[Tensor],
    m: &mut [Tensor],
    v: &mut [Tensor],
) {
    let n = params.len();
    debug_assert_eq!(grads.len(), n);
    debug_assert_eq!(m.len(), n);
    debug_assert_eq!(v.len(), n);
    {
        let params_ref: &[Param] = &*params;
        ctx.ensure(eng.shard_elems(), n, |i| {
            MetaSpec::elementwise(params_ref[i].tensor.numel(), &params_ref[i].tensor.shape)
        });
    }
    if ctx.plan.tasks.is_empty() {
        return;
    }
    if os.tier.is_none() || os.generation != ctx.generation() {
        let tp = tier::build_dense_tier_plan(&ctx.plan);
        os.queue_a = build_queue(tp.a.len(), os.cfg.depth);
        os.queue_c = build_queue(0, os.cfg.depth);
        os.tier = Some(tp);
        os.generation = ctx.generation();
    }
    let threads = eng.resolve_threads(ctx.plan.tasks.len(), ctx.plan.total_elems);
    ctx.ensure_scratch(threads);
    let depth = os.cfg.depth.max(1);
    {
        let tp = os.tier.as_ref().expect("tier plan built above");
        ctx.ensure_stage(depth, tp.slot_bytes, tp.slot_vals);
    }
    let tp = os.tier.as_ref().expect("tier plan built above");

    let StepContext {
        plan,
        scratch,
        arena,
        stage_bytes,
        stage_vals,
        affinity,
        #[cfg(feature = "trace")]
        trace,
        ..
    } = ctx;
    let plan = &*plan;
    let bc1 = 1.0 - hp.beta1.powi(t as i32);
    let bc2 = 1.0 - hp.beta2.powi(t as i32);
    let step_u = t as u64;
    // Dense staging shares the transfer-level fault/retry machinery;
    // scheduled worker panics stay a compressed-path feature (they pair
    // with `CompressedAdamW::try_step`'s rollback).
    let faults = os.fault_plan();
    let cells = fault_cells(tp.a.len(), faults.is_some());

    {
        let mut m_hosts = arena.lease::<tier::HostMoment>();
        m_hosts.extend(
            m.iter_mut()
                .map(|t| tier::HostMoment::F32(SharedSlice::new(t.data.as_mut_slice()))),
        );
        let mut v_hosts = arena.lease::<tier::HostMoment>();
        v_hosts.extend(
            v.iter_mut()
                .map(|t| tier::HostMoment::F32(SharedSlice::new(t.data.as_mut_slice()))),
        );
        let (m_hosts, v_hosts) = (m_hosts.as_slice(), v_hosts.as_slice());
        let mut ws = arena.lease::<SharedSlice<f32>>();
        ws.extend(params.iter_mut().map(|p| SharedSlice::new(p.tensor.data.as_mut_slice())));
        let ws = ws.as_slice();
        let mut sv_views = arena.lease::<SharedSlice<f32>>();
        sv_views.extend(stage_vals[..depth].iter_mut().map(|s| SharedSlice::new(s.as_mut_slice())));
        let sv_views = sv_views.as_slice();
        let mut sb_views = arena.lease::<SharedSlice<u8>>();
        sb_views.extend(
            stage_bytes[..depth].iter_mut().map(|b| SharedSlice::new(b.as_mut_slice())),
        );
        let sb_views = sb_views.as_slice();

        let stagings = &tp.a[..];
        let transfer = |pos: usize, to_device: bool| {
            let ts = &stagings[pos];
            let copy = || {
                tier::copy_task_segments(
                    ts,
                    &plan.tasks[ts.task].pieces,
                    m_hosts,
                    v_hosts,
                    sb_views[pos % depth],
                    sv_views[pos % depth],
                    to_device,
                    !to_device,
                );
            };
            match faults {
                None => copy(),
                Some(p) => transfer_with_faults(
                    p,
                    fault::Phase::A,
                    step_u,
                    ts,
                    &cells[pos],
                    sb_views[pos % depth],
                    sv_views[pos % depth],
                    to_device,
                    &copy,
                ),
            }
        };
        let compute = |pos: usize, _s: &mut StepScratch| {
            let ts = &stagings[pos];
            let sv = sv_views[pos % depth];
            for (ps, piece) in ts.pieces.iter().zip(plan.tasks[ts.task].pieces.iter()) {
                let (lo, hi) = (piece.lo, piece.hi);
                // SAFETY: disjoint piece ranges (plan invariant).
                let w = unsafe { ws[piece.tensor].range_mut(lo, hi) };
                let g = &grads[piece.tensor].data[lo..hi];
                let (Some(msg), Some(vsg)) = (&ps.m, &ps.v) else {
                    unreachable!("dense states always stage")
                };
                // SAFETY: exclusive slot between stage-in and writeback
                // (dependency discipline); the two segments are disjoint
                // sub-ranges of the slot.
                let mm = unsafe { sv.range_mut(msg.vals_off, msg.vals_off + msg.vals_len) };
                // SAFETY: the second disjoint sub-range of the same
                // exclusive slot (see above).
                let vv = unsafe { sv.range_mut(vsg.vals_off, vsg.vals_off + vsg.vals_len) };
                dense::adamw32_piece(w, mm, vv, g, hp, bc1, bc2, lr);
            }
        };
        #[cfg(feature = "trace")]
        let _t0 = now();
        run_queue(eng, threads, &os.queue_a, affinity, &mut scratch[..], &transfer, &compute);
        #[cfg(feature = "trace")]
        trace.record(P_OFF_QUEUE, TASK_NONE, _t0);
    }

    let mut totals = {
        let mut pairs = arena.lease::<(u64, u64)>();
        pairs.extend(tp.a.iter().map(|ts| (ts.down_bytes, ts.up_bytes)));
        ThrottledLink::new(os.cfg.link).step_totals(depth, &[pairs.as_slice()])
    };
    if !cells.is_empty() {
        let link = ThrottledLink::new(os.cfg.link);
        let policy = RetryPolicy::default();
        charge_fault_cells(&link, &policy, &cells, &tp.a, &mut totals, &mut os.report);
    }
    os.report.absorb(&totals, os.cfg.link.compute_per_step);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn queue_shape_and_dependencies() {
        for (n, d) in [(0usize, 1usize), (1, 1), (5, 1), (5, 2), (7, 4), (3, 8)] {
            let (entries, deps) = build_queue(n, d);
            assert_eq!(entries.len(), 3 * n, "n={n} d={d}");
            assert_eq!(deps.len(), entries.len());
            let mut seen_in = vec![false; n];
            let mut seen_comp = vec![false; n];
            let mut seen_out = vec![false; n];
            for (i, e) in entries.iter().enumerate() {
                if let Some(dep) = deps[i] {
                    assert!(dep < i, "dep {dep} of entry {i} (n={n} d={d})");
                }
                // Queue order must be sequentially valid.
                match *e {
                    Entry::In(p) => {
                        assert!(!seen_in[p]);
                        seen_in[p] = true;
                    }
                    Entry::Compute(p) => {
                        assert!(seen_in[p], "compute {p} before stage-in (n={n} d={d})");
                        seen_comp[p] = true;
                    }
                    Entry::Out(p) => {
                        assert!(seen_comp[p], "writeback {p} before compute (n={n} d={d})");
                        seen_out[p] = true;
                    }
                }
            }
            assert!(seen_out.iter().all(|&x| x), "n={n} d={d}");
            // At most d stage-ins may precede the first compute.
            let first_comp = entries
                .iter()
                .position(|e| matches!(e, Entry::Compute(_)))
                .unwrap_or(0);
            assert!(first_comp <= d.min(n.max(1)), "n={n} d={d}");
        }
    }

    fn test_link() -> LinkModel {
        LinkModel {
            bandwidth: 1e9,
            latency: 0.0,
            compute_per_step: 1.0,
            overlap: 1.0,
        }
    }

    #[test]
    fn report_stays_finite_on_degenerate_steps() {
        // Fresh report: no steps, no transfers — every accessor must be
        // finite, not NaN.
        let r = OffloadReport::default();
        assert_eq!(r.overlap_fraction(), 0.0);
        assert_eq!(r.step_seconds(), 0.0);

        // A zero-transfer step (every staged segment empty) absorbs
        // comm == 0 without poisoning the overlap ratio.
        let mut r = OffloadReport::default();
        let totals = ThrottledLink::new(test_link()).step_totals(2, &[&[][..]]);
        r.absorb(&totals, test_link().compute_per_step);
        assert_eq!(r.steps, 1);
        assert_eq!(r.transfers, 0);
        assert_eq!(r.overlap_fraction(), 0.0);
        assert!(r.step_seconds().is_finite());
        assert!((r.step_seconds() - 1.0).abs() < 1e-12, "{}", r.step_seconds());
    }

    #[test]
    fn empty_model_offloaded_steps_are_no_ops() {
        // An empty parameter list produces an empty plan; both staged
        // steps must return before charging the link, leaving a report
        // whose accessors are all finite.
        let eng = StepEngine::new().with_threads(1);
        let mut ctx = StepContext::new();
        let mut os = OffloadState::new(OffloadConfig::new(test_link(), 2));
        let sp = StepParams {
            hp: Hyper::default(),
            t: 1,
            lr: 1e-3,
            base_seed: 7,
            m_map: None,
            v_map: None,
            v1_map: None,
        };
        compressed_offloaded_step(&eng, &mut ctx, &mut os, &sp, &mut [], &[], &mut [], &mut []);
        dense_offloaded_step(
            &eng,
            &mut ctx,
            &mut os,
            &Hyper::default(),
            1,
            1e-3,
            &mut [],
            &[],
            &mut [],
            &mut [],
        );
        assert_eq!(os.report.steps, 0);
        assert_eq!(os.report.overlap_fraction(), 0.0);
        assert_eq!(os.report.step_seconds(), 0.0);
    }

    #[test]
    fn queue_slot_exclusivity() {
        // Between task p's stage-in and writeback, no other task q with
        // q ≡ p (mod d) may stage in — slot reuse is serialized by the
        // dependency chain in queue order.
        let (n, d) = (9usize, 3usize);
        let (entries, _deps) = build_queue(n, d);
        let mut active: Vec<Option<usize>> = vec![None; d];
        for e in &entries {
            match *e {
                Entry::In(p) => {
                    assert_eq!(active[p % d], None, "slot {} busy at stage-in of {p}", p % d);
                    active[p % d] = Some(p);
                }
                Entry::Out(p) => {
                    assert_eq!(active[p % d], Some(p));
                    active[p % d] = None;
                }
                Entry::Compute(p) => assert_eq!(active[p % d], Some(p)),
            }
        }
    }
}
