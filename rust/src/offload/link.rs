#![forbid(unsafe_code)]
//! Deterministic virtual-time accounting for the offload pipeline's
//! host↔device link.
//!
//! The pipeline ([`super::pipeline`]) moves real bytes (staged memcpys),
//! but *time* is modeled, not measured: every transfer is charged
//! `latency + bytes / bandwidth` seconds against a [`ThrottledLink`],
//! and the step total is derived from the charge list by a pure
//! function of (link model, prefetch depth, per-task byte counts). No
//! wall-clock sleeps, no dependence on the actual thread schedule — the
//! virtual totals are bit-reproducible at any worker count, which keeps
//! the pipeline's timing tests fast and exact.
//!
//! Overlap semantics mirror the analytic oracle in [`super`]
//! (`simulate_step`), which is what the convergence property in
//! `rust/tests/offload_pipeline.rs` pins:
//!
//! * depth 1 is strictly serial — stage-in, compute, writeback never
//!   overlap, so the step is `compute + comm`;
//! * depth ≥ 2 pipelines transfers behind compute, but only a fraction
//!   `overlap` of the compute time has the bus available (the analytic
//!   model's knob), and each *phase's* edges — its first stage-in
//!   (nothing to overlap before it) and its last writeback (nothing
//!   after it inside the phase, whose boundary is a reduction barrier) —
//!   always stay serial. Phases are charged separately because the
//!   pipeline really does drain between them (the scale reduction runs
//!   on the coordinating thread). As the shard count grows the edges
//!   vanish and the totals converge to the analytic
//!   `compute + max(0, comm - overlap·compute)`.
//!
//! One deliberate divergence from the oracle: the oracle charges the
//! link latency **once per step**, the pipeline **once per transfer**.
//! With realistic shard sizes the latency term is a rounding error, and
//! the per-transfer accounting is the honest model of a pipeline that
//! actually issues one DMA per staged shard.
//!
//! Faulted transfers (injected by [`crate::fault`], detected by the
//! pipeline's per-transfer checksums) are charged through
//! [`ThrottledLink::retry_seconds`] + [`LinkTotals::charge_retries`]:
//! every retried attempt pays its full transfer charge plus a bounded
//! exponential backoff delay ([`RetryPolicy`]) — virtual seconds, no
//! wall-clock sleeps — and retries extend the step serially (a replay
//! stalls the slot it is replaying into). A fault-free step's totals
//! stay bit-identical to a link with no retry machinery at all.

use super::LinkModel;

/// The virtual link: charges transfers against a [`LinkModel`] and folds
/// a whole step's charge list into overlapped/serial totals.
#[derive(Clone, Copy, Debug)]
pub struct ThrottledLink {
    pub model: LinkModel,
}

/// Virtual-time totals of one pipelined step.
#[derive(Clone, Copy, Debug, Default)]
pub struct LinkTotals {
    /// Total link occupancy: Σ (latency + bytes/bandwidth) per transfer.
    pub comm_seconds: f64,
    /// Link time hidden behind compute.
    pub hidden_seconds: f64,
    /// Link time that extends the step (comm − hidden).
    pub serial_seconds: f64,
    /// `compute + serial` — the step's virtual wall time.
    pub step_seconds: f64,
    pub bytes_down: u64,
    pub bytes_up: u64,
    /// Number of non-empty transfers charged.
    pub transfers: u64,
    /// Transfer attempts that were retried (injected failures +
    /// checksum-detected corruption), charged via
    /// [`LinkTotals::charge_retries`].
    pub retries: u64,
    /// Virtual time the retries cost: re-transfer charges plus backoff
    /// delays. Already folded into `comm`/`serial`/`step`.
    pub retry_seconds: f64,
}

impl LinkTotals {
    /// Fraction of this step's link occupancy hidden behind compute
    /// (`0.0` for a step that moved no bytes — never `0/0`). The
    /// accumulated-run counterpart is
    /// [`super::pipeline::OffloadReport::overlap_fraction`].
    pub fn hidden_fraction(&self) -> f64 {
        if self.comm_seconds > 0.0 {
            (self.hidden_seconds / self.comm_seconds).clamp(0.0, 1.0)
        } else {
            0.0
        }
    }

    /// Charge `count` retried transfer attempts worth `seconds` of
    /// virtual time. Retries extend the step **serially**: a retry
    /// stalls the slot whose payload it is replaying, so the conservative
    /// model charges it outside the overlap window (a fault-free step —
    /// `count == 0, seconds == 0` — is charged identically to a link
    /// with no retry machinery at all).
    pub fn charge_retries(&mut self, count: u64, seconds: f64) {
        self.retries += count;
        self.retry_seconds += seconds;
        self.comm_seconds += seconds;
        self.serial_seconds += seconds;
        self.step_seconds += seconds;
    }
}

/// Bounded-exponential-backoff retry policy for faulted transfers. All
/// delays are *virtual* seconds — charged to the step totals, never
/// slept.
#[derive(Clone, Copy, Debug)]
pub struct RetryPolicy {
    /// Attempts before the transfer is declared fatally failed (the
    /// pipeline panics, which `Optimizer::try_step` converts into a
    /// rolled-back step). Rate-armed fault plans re-roll per attempt,
    /// so hitting this bound requires `rate^max_attempts` luck.
    pub max_attempts: u32,
    /// Backoff before retry `k` is `base · factor^k`, capped at `cap`.
    pub backoff_base: f64,
    pub backoff_factor: f64,
    pub backoff_cap: f64,
}

impl Default for RetryPolicy {
    fn default() -> RetryPolicy {
        RetryPolicy {
            max_attempts: 32,
            backoff_base: 50e-6,
            backoff_factor: 2.0,
            backoff_cap: 5e-3,
        }
    }
}

impl RetryPolicy {
    /// Virtual backoff delay before re-issuing attempt `attempt + 1`.
    pub fn backoff_seconds(&self, attempt: u32) -> f64 {
        (self.backoff_base * self.backoff_factor.powi(attempt.min(64) as i32)).min(self.backoff_cap)
    }
}

impl ThrottledLink {
    pub fn new(model: LinkModel) -> ThrottledLink {
        ThrottledLink { model }
    }

    /// Cost of one transfer of `bytes` (zero-byte transfers are skipped
    /// by the pipeline and cost nothing).
    pub fn transfer_seconds(&self, bytes: u64) -> f64 {
        if bytes == 0 {
            0.0
        } else {
            self.model.latency + bytes as f64 / self.model.bandwidth
        }
    }

    /// Virtual-time cost of `retries` faulted attempts of one
    /// `bytes`-sized transfer: each faulted attempt pays its full
    /// transfer charge (the bytes moved — or were re-requested — before
    /// the fault was detected) plus the bounded exponential backoff
    /// before the replay. The successful final attempt is *not* charged
    /// here — it is the transfer the plain [`Self::step_totals`]
    /// accounting already covers.
    pub fn retry_seconds(&self, bytes: u64, retries: u32, policy: &RetryPolicy) -> f64 {
        (0..retries)
            .map(|k| policy.backoff_seconds(k) + self.transfer_seconds(bytes))
            .sum()
    }

    /// Fold a step's transfers into virtual totals. `phases` holds one
    /// slice per *barrier-separated* pipeline phase (e.g. the compressed
    /// executor's staged phase A and phase C, with the scale reduction
    /// between them), each a `(down_bytes, up_bytes)` pair per pipelined
    /// task in schedule order. A phase's first stage-in and last
    /// writeback can never hide behind compute — the barrier means
    /// nothing is running across the phase boundary — so each phase
    /// contributes `max(0, comm_phase − edge_phase)` of hideable link
    /// time, capped overall by the overlappable compute.
    pub fn step_totals(&self, depth: usize, phases: &[&[(u64, u64)]]) -> LinkTotals {
        let mut t = LinkTotals::default();
        let mut hideable = 0.0f64;
        for tasks in phases {
            let mut comm_p = 0.0f64;
            let mut first_in = 0.0f64;
            let mut last_out = 0.0f64;
            for &(down, up) in *tasks {
                if down > 0 {
                    let c = self.transfer_seconds(down);
                    comm_p += c;
                    t.bytes_down += down;
                    t.transfers += 1;
                    if first_in == 0.0 {
                        first_in = c;
                    }
                }
                if up > 0 {
                    let c = self.transfer_seconds(up);
                    comm_p += c;
                    t.bytes_up += up;
                    t.transfers += 1;
                    last_out = c;
                }
            }
            t.comm_seconds += comm_p;
            hideable += (comm_p - first_in - last_out).max(0.0);
        }
        let compute = self.model.compute_per_step;
        t.hidden_seconds = if depth <= 1 {
            // Strictly serial staging: one slot, no prefetch ahead of
            // the running compute.
            0.0
        } else {
            hideable.min(self.model.overlap * compute)
        };
        t.serial_seconds = t.comm_seconds - t.hidden_seconds;
        t.step_seconds = compute + t.serial_seconds;
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn link(bandwidth: f64, latency: f64, compute: f64, overlap: f64) -> ThrottledLink {
        ThrottledLink::new(LinkModel {
            bandwidth,
            latency,
            compute_per_step: compute,
            overlap,
        })
    }

    #[test]
    fn charges_latency_plus_bytes_over_bandwidth() {
        let l = link(1e9, 1e-4, 0.0, 0.0);
        assert_eq!(l.transfer_seconds(0), 0.0);
        let c = l.transfer_seconds(1_000_000);
        assert!((c - (1e-4 + 1e-3)).abs() < 1e-12, "{c}");
    }

    #[test]
    fn depth_one_is_fully_serial() {
        let l = link(1e9, 0.0, 1.0, 1.0);
        let tasks = vec![(500_000u64, 500_000u64); 10];
        let t = l.step_totals(1, &[&tasks[..]]);
        assert_eq!(t.hidden_seconds, 0.0);
        assert!((t.step_seconds - (1.0 + 0.01)).abs() < 1e-9, "{}", t.step_seconds);
        assert_eq!(t.bytes_down, 5_000_000);
        assert_eq!(t.bytes_up, 5_000_000);
        assert_eq!(t.transfers, 20);
    }

    #[test]
    fn deep_pipeline_hides_all_but_the_edges() {
        // comm (10 ms) far below overlap·compute: only the first
        // stage-in and last writeback stay serial.
        let l = link(1e9, 0.0, 1.0, 1.0);
        let tasks = vec![(500_000u64, 500_000u64); 10];
        let t = l.step_totals(2, &[&tasks[..]]);
        let per = 5e-4;
        assert!((t.hidden_seconds - (0.01 - 2.0 * per)).abs() < 1e-9);
        assert!((t.step_seconds - (1.0 + 2.0 * per)).abs() < 1e-9);
    }

    #[test]
    fn overlap_fraction_caps_hiding() {
        // comm = 1 s, compute = 1 s, overlap = 0.5: only half the
        // compute can host transfers.
        let l = link(1e9, 0.0, 1.0, 0.5);
        let tasks = vec![(50_000_000u64, 50_000_000u64); 10];
        let t = l.step_totals(4, &[&tasks[..]]);
        assert!((t.comm_seconds - 1.0).abs() < 1e-9);
        assert!((t.hidden_seconds - 0.5).abs() < 1e-9);
        assert!((t.step_seconds - 1.5).abs() < 1e-9);
    }

    #[test]
    fn phase_barriers_charge_their_own_edges() {
        // The reduction barrier between phases drains the pipeline:
        // each phase pays its own first-in/last-out serial edges.
        let l = link(1e9, 0.0, 10.0, 1.0);
        let a = vec![(1_000_000u64, 1_000_000u64); 4];
        let c = vec![(500_000u64, 500_000u64); 4];
        let phased = l.step_totals(2, &[&a[..], &c[..]]);
        let merged: Vec<(u64, u64)> = a.iter().chain(c.iter()).copied().collect();
        let single = l.step_totals(2, &[&merged[..]]);
        assert!(phased.hidden_seconds < single.hidden_seconds);
        let edge_a = 1e-3 + 1e-3;
        let edge_c = 5e-4 + 5e-4;
        assert!((phased.serial_seconds - (edge_a + edge_c)).abs() < 1e-12);
    }

    #[test]
    fn backoff_is_bounded_exponential() {
        let p = RetryPolicy::default();
        assert!((p.backoff_seconds(0) - 50e-6).abs() < 1e-12);
        assert!((p.backoff_seconds(1) - 100e-6).abs() < 1e-12);
        assert_eq!(p.backoff_seconds(30), p.backoff_cap, "capped, not unbounded");
    }

    #[test]
    fn retry_charges_extend_the_step_serially() {
        let l = link(1e9, 1e-4, 1.0, 1.0);
        let p = RetryPolicy::default();
        assert_eq!(l.retry_seconds(1_000_000, 0, &p), 0.0, "fault-free is free");
        let one = l.retry_seconds(1_000_000, 1, &p);
        assert!((one - (p.backoff_seconds(0) + 1e-4 + 1e-3)).abs() < 1e-12, "{one}");
        let two = l.retry_seconds(1_000_000, 2, &p);
        assert!(two > 2.0 * one - 1e-12, "backoff grows across attempts");

        let tasks = vec![(500_000u64, 500_000u64); 4];
        let clean = l.step_totals(2, &[&tasks[..]]);
        let mut faulted = l.step_totals(2, &[&tasks[..]]);
        faulted.charge_retries(3, one);
        assert_eq!(faulted.retries, 3);
        assert!((faulted.step_seconds - (clean.step_seconds + one)).abs() < 1e-12);
        assert!((faulted.serial_seconds - (clean.serial_seconds + one)).abs() < 1e-12);
        assert_eq!(faulted.hidden_seconds, clean.hidden_seconds, "retries never hide");
        // Zero-retry charge leaves the totals bit-identical.
        let mut zero = l.step_totals(2, &[&tasks[..]]);
        zero.charge_retries(0, 0.0);
        assert_eq!(zero.step_seconds.to_bits(), clean.step_seconds.to_bits());
    }

    #[test]
    fn totals_are_schedule_shape_independent_for_same_bytes() {
        // Splitting the same traffic across more tasks only moves the
        // (zero-latency) edge terms, converging to the same total.
        let l = link(1e9, 0.0, 2.0, 1.0);
        let coarse = l.step_totals(2, &[&[(8_000_000, 8_000_000); 2][..]]);
        let fine = l.step_totals(2, &[&vec![(1_000_000, 1_000_000); 16][..]]);
        assert!((coarse.comm_seconds - fine.comm_seconds).abs() < 1e-12);
        assert!(fine.step_seconds <= coarse.step_seconds + 1e-12);
    }
}
