//! Offload / sharding communication simulator.
//!
//! The paper's Tab. 4 shows 4-bit states *speeding up* LLaMA fine-tuning
//! under FSDP because optimizer-state traffic shrinks. We cannot measure
//! two A100s here, so this module models the communication arithmetic:
//! per training step the optimizer states cross a link (PCIe for
//! ZeRO-Offload-style CPU offload, NVLink/IB for sharded updates), and the
//! step time is `max(compute, comm)` for the overlapped fraction plus the
//! serial remainder. The *relative* speedups between 32/8/4-bit states —
//! what the paper claims — fall out of the byte counts, which we take from
//! the exact accounting in [`crate::memory`].

use crate::memory::{model_state_bytes, StatePreset};
use crate::model::TransformerConfig;

/// Link + compute characteristics of a simulated node.
#[derive(Clone, Copy, Debug)]
pub struct LinkModel {
    /// Link bandwidth, bytes/second (e.g. PCIe 4.0 x16 ≈ 25e9 effective).
    pub bandwidth: f64,
    /// Per-transfer latency, seconds.
    pub latency: f64,
    /// Pure compute time per step, seconds (fwd + bwd + update math).
    pub compute_per_step: f64,
    /// Fraction of communication that overlaps compute (0 = fully serial,
    /// 1 = fully hidden).
    pub overlap: f64,
}

impl LinkModel {
    /// PCIe-offload profile roughly shaped on ZeRO-Offload numbers.
    pub fn pcie_offload(compute_per_step: f64) -> LinkModel {
        LinkModel {
            bandwidth: 25e9,
            latency: 30e-6,
            compute_per_step,
            overlap: 0.5,
        }
    }

    /// Sharded-update (FSDP) profile: faster link, better overlap.
    pub fn fsdp(compute_per_step: f64) -> LinkModel {
        LinkModel {
            bandwidth: 100e9,
            latency: 10e-6,
            compute_per_step,
            overlap: 0.7,
        }
    }
}

/// Result of simulating one configuration.
#[derive(Clone, Copy, Debug)]
pub struct StepEstimate {
    pub state_bytes: u64,
    pub comm_seconds: f64,
    pub step_seconds: f64,
}

/// Per-step time when optimizer states of `cfg` under `preset` must cross
/// the link once per step (down + up = 2x for offload round trip).
pub fn simulate_step(cfg: &TransformerConfig, preset: StatePreset, link: &LinkModel) -> StepEstimate {
    let state_bytes = model_state_bytes(cfg, preset);
    let comm = link.latency + (2 * state_bytes) as f64 / link.bandwidth;
    let hidden = comm.min(link.compute_per_step * link.overlap);
    let serial = comm - hidden;
    StepEstimate {
        state_bytes,
        comm_seconds: comm,
        step_seconds: link.compute_per_step + serial,
    }
}

/// Relative throughput of `preset` vs the fp32 baseline on the same link.
pub fn speedup_vs_fp32(cfg: &TransformerConfig, preset: StatePreset, link: &LinkModel) -> f64 {
    let base = simulate_step(cfg, StatePreset::AdamW32, link).step_seconds;
    let ours = simulate_step(cfg, preset, link).step_seconds;
    base / ours
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::llama_family;

    #[test]
    fn lower_bitwidth_is_never_slower() {
        let cfg = llama_family()[0].cfg;
        let link = LinkModel::pcie_offload(0.5);
        let t32 = simulate_step(&cfg, StatePreset::AdamW32, &link).step_seconds;
        let t8 = simulate_step(&cfg, StatePreset::AdamW8, &link).step_seconds;
        let t4 = simulate_step(&cfg, StatePreset::AdamW4, &link).step_seconds;
        assert!(t8 <= t32);
        assert!(t4 <= t8);
    }

    #[test]
    fn offload_speedup_shape_matches_paper() {
        // Paper Tab. 4: LLaMA-7B 3.35h (32-bit) -> 3.07h (4-bit), i.e.
        // ~1.09x from reduced communication under FSDP. On the FSDP link
        // profile the simulator should land in a plausible band (>1x,
        // <2x — communication is only part of the step).
        let cfg = llama_family()[0].cfg;
        let link = LinkModel::fsdp(1.0);
        let s = speedup_vs_fp32(&cfg, StatePreset::AdamW4, &link);
        assert!(s > 1.02 && s < 2.0, "speedup {s}");
    }

    #[test]
    fn fully_hidden_comm_gives_no_speedup() {
        let cfg = llama_family()[0].cfg;
        // Enormous compute per step: everything overlaps.
        let link = LinkModel {
            bandwidth: 25e9,
            latency: 0.0,
            compute_per_step: 1e4,
            overlap: 1.0,
        };
        let s = speedup_vs_fp32(&cfg, StatePreset::AdamW4, &link);
        assert!((s - 1.0).abs() < 1e-9);
    }

    #[test]
    fn comm_time_proportional_to_bytes() {
        let cfg = llama_family()[0].cfg;
        let link = LinkModel {
            bandwidth: 1e9,
            latency: 0.0,
            compute_per_step: 0.0,
            overlap: 0.0,
        };
        let e32 = simulate_step(&cfg, StatePreset::AdamW32, &link);
        let e4 = simulate_step(&cfg, StatePreset::AdamW4, &link);
        let byte_ratio = e32.state_bytes as f64 / e4.state_bytes as f64;
        let time_ratio = e32.comm_seconds / e4.comm_seconds;
        assert!((byte_ratio - time_ratio).abs() < 1e-9);
    }
}
