//! Offload: the host-resident state tier — an analytic oracle *and* an
//! executable pipeline.
//!
//! The paper's Tab. 4 shows 4-bit states *speeding up* LLaMA fine-tuning
//! under FSDP because optimizer-state traffic shrinks ~8×. This module
//! reproduces that claim at two levels of fidelity:
//!
//! 1. **The analytic model** (this file): per training step the
//!    optimizer states cross a link (PCIe for ZeRO-Offload-style CPU
//!    offload, NVLink/IB for sharded updates) once down and once up;
//!    the step time is the compute plus the communication that could
//!    not hide under the overlappable fraction of it. Byte counts come
//!    from the exact accounting in [`crate::memory`]. Cheap, closed
//!    form — and nothing moves.
//! 2. **The executable pipeline** ([`tier`], [`link`], [`pipeline`]):
//!    real optimizer steps run with their states *actually resident in
//!    a host tier*. Every shard task's state segments are staged
//!    through a bounded device-scratch budget (prefetch depth × slot
//!    size), the exact in-memory update kernels run against the staged
//!    copies (their decode/encode inner loops ride the nibble-granular
//!    kernel layer of `crate::quant::kernels` — pair-LUT decode and
//!    fused encode→pack — identically to the in-memory executor, so the
//!    staged path inherits both the speedup and the bit-exactness
//!    contract), and mutated segments are written back — all interleaved
//!    with compute on the step engine's worker pool under a dependency
//!    discipline (see `engine/mod.rs`, "Transfer tasks and the
//!    dependency contract"). Results are **bit-identical** to in-memory
//!    execution at every thread count and prefetch depth. Time is
//!    *virtual*: each transfer is charged `latency + bytes/bandwidth`
//!    and folded into deterministic overlapped/serial totals — no
//!    wall-clock sleeps, so the timing tests are fast and exact.
//!
//! The analytic model is the **convergence oracle** for the pipeline:
//! as the shard count grows (edge effects vanish) and the per-transfer
//! latency term stays negligible, the pipeline's virtual step time
//! approaches `simulate_step`'s estimate — pinned, preset by preset, in
//! `rust/tests/offload_pipeline.rs`. Two accounted divergences: the
//! pipeline charges latency per transfer (the oracle once per step),
//! and globally-normalized 4-bit states cross the link a third time for
//! the phase-C re-encode (see the [`pipeline`] docs).
//!
//! # Failure semantics
//!
//! The pipeline distinguishes three failure classes, from recoverable to
//! fatal. All fault injection is *deterministic* (seeded, keyed by
//! logical `(step, phase, task, direction, attempt)` coordinates — see
//! [`crate::fault`]) and disabled at zero cost unless a plan is armed
//! via [`OffloadState::faults`] or the `LOWBIT_FAULTS` env gate.
//!
//! * **Transient transfer failures** (the link "drops" a staging copy):
//!   retried in place with bounded exponential backoff. Each retry is
//!   charged in *virtual time* — `backoff + latency + bytes/bandwidth`,
//!   folded serially into the step total in task order, never hidden
//!   under overlap — so faulted runs are slower on the virtual clock but
//!   remain **bit-identical** to fault-free runs: host state is intact,
//!   and a replayed copy stages exactly the same bytes.
//! * **Payload corruption**: every stage-in carries a CRC-32 over the
//!   staged bytes, computed on the sender side and re-verified on the
//!   receiver side *before* any kernel reads the slot. A mismatch is
//!   handled like a transient failure — recopy from the intact host
//!   tier — so corruption can never leak into decode/encode or the
//!   phase-C re-encode.
//! * **Worker panics** mid-step: the engine aborts the phase and
//!   re-raises on the submitter (parked dependents are released, see
//!   `engine/mod.rs` "Failure semantics"). Recovery is the *caller's*
//!   transaction: `CompressedAdamW::try_step` snapshots weights and
//!   packed state, catches the unwind, rolls back, and a retried step is
//!   bit-identical to a never-faulted one.
//!
//! Fatal (by design, not retried): a transfer still faulting after
//! [`RetryPolicy::max_attempts`] (panics naming the task), and panics
//! escaping a caller that does not use `try_step`. Retry and rollback
//! counts surface through [`OffloadReport`] and
//! `obs::report::StepReport`.

pub mod link;
pub mod pipeline;
pub mod tier;

pub use link::{LinkTotals, RetryPolicy, ThrottledLink};
pub use pipeline::{OffloadConfig, OffloadReport, OffloadState};

use crate::memory::{model_state_bytes, StatePreset};
use crate::model::TransformerConfig;

/// Link + compute characteristics of a simulated node.
#[derive(Clone, Copy, Debug)]
pub struct LinkModel {
    /// Link bandwidth, bytes/second (e.g. PCIe 4.0 x16 ≈ 25e9 effective).
    pub bandwidth: f64,
    /// Per-transfer latency, seconds.
    pub latency: f64,
    /// Pure compute time per step, seconds (fwd + bwd + update math).
    pub compute_per_step: f64,
    /// Fraction of communication that overlaps compute (0 = fully serial,
    /// 1 = fully hidden).
    pub overlap: f64,
}

impl LinkModel {
    /// PCIe-offload profile roughly shaped on ZeRO-Offload numbers.
    pub fn pcie_offload(compute_per_step: f64) -> LinkModel {
        LinkModel {
            bandwidth: 25e9,
            latency: 30e-6,
            compute_per_step,
            overlap: 0.5,
        }
    }

    /// Sharded-update (FSDP) profile: faster link, better overlap.
    pub fn fsdp(compute_per_step: f64) -> LinkModel {
        LinkModel {
            bandwidth: 100e9,
            latency: 10e-6,
            compute_per_step,
            overlap: 0.7,
        }
    }
}

/// Result of simulating one configuration.
#[derive(Clone, Copy, Debug)]
pub struct StepEstimate {
    pub state_bytes: u64,
    pub comm_seconds: f64,
    pub step_seconds: f64,
}

/// Per-step time when optimizer states of `cfg` under `preset` must cross
/// the link once per step (down + up = 2x for offload round trip).
pub fn simulate_step(
    cfg: &TransformerConfig,
    preset: StatePreset,
    link: &LinkModel,
) -> StepEstimate {
    let state_bytes = model_state_bytes(cfg, preset);
    let comm = link.latency + (2 * state_bytes) as f64 / link.bandwidth;
    let hidden = comm.min(link.compute_per_step * link.overlap);
    let serial = comm - hidden;
    StepEstimate {
        state_bytes,
        comm_seconds: comm,
        step_seconds: link.compute_per_step + serial,
    }
}

/// Relative throughput of `preset` vs the fp32 baseline on the same link.
pub fn speedup_vs_fp32(cfg: &TransformerConfig, preset: StatePreset, link: &LinkModel) -> f64 {
    let base = simulate_step(cfg, StatePreset::AdamW32, link).step_seconds;
    let ours = simulate_step(cfg, preset, link).step_seconds;
    base / ours
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::llama_family;

    #[test]
    fn lower_bitwidth_is_never_slower() {
        let cfg = llama_family()[0].cfg;
        let link = LinkModel::pcie_offload(0.5);
        let t32 = simulate_step(&cfg, StatePreset::AdamW32, &link).step_seconds;
        let t8 = simulate_step(&cfg, StatePreset::AdamW8, &link).step_seconds;
        let t4 = simulate_step(&cfg, StatePreset::AdamW4, &link).step_seconds;
        assert!(t8 <= t32);
        assert!(t4 <= t8);
    }

    #[test]
    fn offload_speedup_shape_matches_paper() {
        // Paper Tab. 4: LLaMA-7B 3.35h (32-bit) -> 3.07h (4-bit), i.e.
        // ~1.09x from reduced communication under FSDP. On the FSDP link
        // profile the simulator should land in a plausible band (>1x,
        // <2x — communication is only part of the step).
        let cfg = llama_family()[0].cfg;
        let link = LinkModel::fsdp(1.0);
        let s = speedup_vs_fp32(&cfg, StatePreset::AdamW4, &link);
        assert!(s > 1.02 && s < 2.0, "speedup {s}");
    }

    #[test]
    fn fully_hidden_comm_gives_no_speedup() {
        let cfg = llama_family()[0].cfg;
        // Enormous compute per step: everything overlaps.
        let link = LinkModel {
            bandwidth: 25e9,
            latency: 0.0,
            compute_per_step: 1e4,
            overlap: 1.0,
        };
        let s = speedup_vs_fp32(&cfg, StatePreset::AdamW4, &link);
        assert!((s - 1.0).abs() < 1e-9);
    }

    #[test]
    fn comm_time_proportional_to_bytes() {
        let cfg = llama_family()[0].cfg;
        let link = LinkModel {
            bandwidth: 1e9,
            latency: 0.0,
            compute_per_step: 0.0,
            overlap: 0.0,
        };
        let e32 = simulate_step(&cfg, StatePreset::AdamW32, &link);
        let e4 = simulate_step(&cfg, StatePreset::AdamW4, &link);
        let byte_ratio = e32.state_bytes as f64 / e4.state_bytes as f64;
        let time_ratio = e32.comm_seconds / e4.comm_seconds;
        assert!((byte_ratio - time_ratio).abs() < 1e-9);
    }
}
