//! The host-resident optimizer-state tier.
//!
//! Under ZeRO-Offload-style training the optimizer states do not live in
//! device memory: they sit in host RAM and cross the PCIe link twice per
//! step. This module makes that arrangement *executable* for the step
//! engine: the optimizer's own state allocations (packed 4-bit/8-bit
//! codes, block scales, fp32 moments) are treated as the **host
//! buffers**, and every shard task's slice of them is staged through a
//! bounded device-scratch budget — the [`crate::engine::StepContext`]
//! staging slots — before compute touches it, then written back after.
//! Compute kernels never read or write host state directly; only the
//! transfer tasks do.
//!
//! What stays device-resident (documented, deliberate):
//!
//! * rank-1 / per-tensor quantization scales — a few f32 per axis, read
//!   by every shard's decode and rebuilt by the global reduction;
//! * factored second moments — sublinear row/col statistics;
//! * the parameters and gradients themselves (the tier offloads
//!   *optimizer state*, the paper's Tab. 4 setting).
//!
//! [`build_tier_plan`] derives, purely from the shard plan and the state
//! layouts, where each piece's staged bytes land inside a scratch slot,
//! which segments must be written back per phase, and the exact link
//! traffic each task generates — the byte counts the virtual-time
//! accounting ([`super::link`]) folds into step totals.

use crate::engine::adamw4::packed_range as packed_span;
use crate::engine::plan::{Piece, Plan, StateLayout, TensorMeta};
use crate::engine::SharedSlice;
use crate::optim::factor::FactoredSecond;
use crate::optim::state::{MomentState, SecondState};
use crate::quant::{NormKind, QuantizedTensor, Quantizer, Scales};

/// Host-side view of one tensor's moment state: where its bytes live in
/// the optimizer's host-resident storage, plus the decode metadata the
/// compute kernels need. One enum serves both moments (a first moment is
/// never `Factored`).
pub(crate) enum HostMoment<'a> {
    F32(SharedSlice<'a, f32>),
    Block {
        q: Quantizer,
        block: usize,
        packed: SharedSlice<'a, u8>,
        scales: SharedSlice<'a, f32>,
    },
    Global {
        q: Quantizer,
        packed: SharedSlice<'a, u8>,
        /// Device-resident global scales (tiny; see the module docs).
        scales: &'a Scales,
    },
    Factored {
        f: &'a FactoredSecond,
        row_mean: f32,
    },
}

/// Split a quantized state into its host views.
fn quant_views(qt: &mut QuantizedTensor) -> HostMoment<'_> {
    let q = qt.quantizer;
    if let NormKind::Block(b) = q.norm {
        let QuantizedTensor { packed, scales, .. } = qt;
        let sc = match scales {
            Scales::Block { scales, .. } => scales,
            _ => unreachable!("block-normed state carries block scales"),
        };
        HostMoment::Block {
            q,
            block: b,
            packed: SharedSlice::new(packed.as_mut_slice()),
            scales: SharedSlice::new(sc.as_mut_slice()),
        }
    } else {
        let QuantizedTensor { packed, scales, .. } = qt;
        HostMoment::Global {
            q,
            packed: SharedSlice::new(packed.as_mut_slice()),
            scales: &*scales,
        }
    }
}

/// Host view of one first-moment state.
pub(crate) fn host_m(ms: &mut MomentState) -> HostMoment<'_> {
    match ms {
        MomentState::F32(t) => HostMoment::F32(SharedSlice::new(t.data.as_mut_slice())),
        MomentState::Quant(qt) => quant_views(qt),
    }
}

/// Host view of one second-moment state. Call *after* phase F so the
/// factored row mean is the post-EMA value the update formula needs.
pub(crate) fn host_v(vs: &mut SecondState) -> HostMoment<'_> {
    match vs {
        SecondState::F32(t) => HostMoment::F32(SharedSlice::new(t.data.as_mut_slice())),
        SecondState::Quant(qt) => quant_views(qt),
        SecondState::Factored(f) => {
            let row_mean = f.row_mean();
            HostMoment::Factored { f: &*f, row_mean }
        }
    }
}

/// Where one piece's one state lands inside its task's scratch slot.
#[derive(Clone, Copy, Debug)]
pub struct StagedState {
    /// Offset/length in the slot's byte arena (staged packed codes).
    pub bytes_off: usize,
    pub bytes_len: usize,
    /// Offset/length in the slot's f32 arena (staged block scales or
    /// staged fp32 state values).
    pub vals_off: usize,
    pub vals_len: usize,
    /// Whether this phase mutates the staged copy (and must copy it
    /// back to the host buffer). Phase A mutates block/fp32 states in
    /// place but only *reads* globally-normalized codes; phase C
    /// re-encodes global codes in place and always writes back.
    pub writeback: bool,
}

/// Staging of one piece: first and second moment (either may be absent —
/// factored states stay resident, and phase C stages only globals).
#[derive(Clone, Copy, Debug, Default)]
pub struct PieceStaging {
    pub m: Option<StagedState>,
    pub v: Option<StagedState>,
}

/// Staging of one plan task for one phase.
#[derive(Clone, Debug)]
pub struct TaskStaging {
    /// Plan task index (also the task's RNG stream id).
    pub task: usize,
    /// Parallel to the plan task's pieces.
    pub pieces: Vec<PieceStaging>,
    /// Slot arena footprint of this task.
    pub bytes_len: usize,
    pub vals_len: usize,
    /// Link traffic: stage-in / writeback bytes.
    pub down_bytes: u64,
    pub up_bytes: u64,
}

/// The tier's per-step staging layout: phase-A stagings for every plan
/// task, phase-C stagings for the tasks that touch globally-normalized
/// states, and the scratch-slot budget that fits the largest task.
pub struct TierPlan {
    pub a: Vec<TaskStaging>,
    pub c: Vec<TaskStaging>,
    /// Per-slot arena sizes (the bounded device-scratch budget is
    /// `depth × (slot_bytes + 4·slot_vals)` bytes).
    pub slot_bytes: usize,
    pub slot_vals: usize,
}

impl TierPlan {
    /// Total staged link traffic of one step (both directions).
    pub fn step_traffic(&self) -> (u64, u64) {
        let mut down = 0;
        let mut up = 0;
        for ts in self.a.iter().chain(self.c.iter()) {
            down += ts.down_bytes;
            up += ts.up_bytes;
        }
        (down, up)
    }
}

/// How one state of one piece stages, derived from its storage form.
enum SegKind {
    F32,
    Block { bits: u8, block: usize },
    Global { bits: u8 },
    Resident,
}

fn m_kind(ms: &MomentState) -> SegKind {
    match ms {
        MomentState::F32(_) => SegKind::F32,
        MomentState::Quant(qt) => match qt.quantizer.norm {
            NormKind::Block(b) => SegKind::Block {
                bits: qt.quantizer.bits,
                block: b,
            },
            _ => SegKind::Global {
                bits: qt.quantizer.bits,
            },
        },
    }
}

fn v_kind(vs: &SecondState) -> SegKind {
    match vs {
        SecondState::F32(_) => SegKind::F32,
        SecondState::Quant(qt) => match qt.quantizer.norm {
            NormKind::Block(b) => SegKind::Block {
                bits: qt.quantizer.bits,
                block: b,
            },
            _ => SegKind::Global {
                bits: qt.quantizer.bits,
            },
        },
        SecondState::Factored(_) => SegKind::Resident,
    }
}

/// Lay out one piece's one state for one phase. Returns `None` when the
/// state is not staged in that phase.
fn seg_for(
    kind: &SegKind,
    piece: &Piece,
    phase_c: bool,
    bytes_cursor: &mut usize,
    vals_cursor: &mut usize,
    down: &mut u64,
    up: &mut u64,
) -> Option<StagedState> {
    let (lo, hi) = (piece.lo, piece.hi);
    match kind {
        SegKind::Resident => None,
        SegKind::F32 => {
            if phase_c {
                return None;
            }
            let vals_len = piece.len();
            let seg = StagedState {
                bytes_off: 0,
                bytes_len: 0,
                vals_off: *vals_cursor,
                vals_len,
                writeback: true,
            };
            *vals_cursor += vals_len;
            *down += 4 * vals_len as u64;
            *up += 4 * vals_len as u64;
            Some(seg)
        }
        SegKind::Block { bits, block } => {
            if phase_c {
                return None;
            }
            let (b0, b1) = packed_span(*bits, lo, hi);
            let bytes_len = b1 - b0;
            let vals_len = hi.div_ceil(*block) - lo / block;
            let seg = StagedState {
                bytes_off: *bytes_cursor,
                bytes_len,
                vals_off: *vals_cursor,
                vals_len,
                writeback: true,
            };
            *bytes_cursor += bytes_len;
            *vals_cursor += vals_len;
            let traffic = bytes_len as u64 + 4 * vals_len as u64;
            *down += traffic;
            *up += traffic;
            Some(seg)
        }
        SegKind::Global { bits } => {
            let (b0, b1) = packed_span(*bits, lo, hi);
            let bytes_len = b1 - b0;
            let seg = StagedState {
                bytes_off: *bytes_cursor,
                bytes_len,
                vals_off: 0,
                vals_len: 0,
                // Phase A only reads global codes (the re-encode is
                // phase C's); phase C writes the fresh codes back.
                writeback: phase_c,
            };
            *bytes_cursor += bytes_len;
            *down += bytes_len as u64;
            if phase_c {
                *up += bytes_len as u64;
            }
            Some(seg)
        }
    }
}

/// Build the tier's staging layout for one step — a pure function of
/// (plan, state layouts), like the plan itself.
pub fn build_tier_plan(
    plan: &Plan,
    metas: &[TensorMeta],
    m_states: &[MomentState],
    v_states: &[SecondState],
) -> TierPlan {
    let m_kinds: Vec<SegKind> = m_states.iter().map(m_kind).collect();
    let v_kinds: Vec<SegKind> = v_states.iter().map(v_kind).collect();
    let mut a = Vec::with_capacity(plan.tasks.len());
    let mut c = Vec::new();
    let mut slot_bytes = 0usize;
    let mut slot_vals = 0usize;
    for (ti, task) in plan.tasks.iter().enumerate() {
        for phase_c in [false, true] {
            if phase_c {
                let any_global = task.pieces.iter().any(|p| {
                    metas[p.tensor].m == StateLayout::Global
                        || metas[p.tensor].v == StateLayout::Global
                });
                if !any_global {
                    continue;
                }
            }
            let mut bytes_cursor = 0usize;
            let mut vals_cursor = 0usize;
            let mut down = 0u64;
            let mut up = 0u64;
            let mut pieces = Vec::with_capacity(task.pieces.len());
            for piece in &task.pieces {
                let m = seg_for(
                    &m_kinds[piece.tensor],
                    piece,
                    phase_c,
                    &mut bytes_cursor,
                    &mut vals_cursor,
                    &mut down,
                    &mut up,
                );
                let v = seg_for(
                    &v_kinds[piece.tensor],
                    piece,
                    phase_c,
                    &mut bytes_cursor,
                    &mut vals_cursor,
                    &mut down,
                    &mut up,
                );
                pieces.push(PieceStaging { m, v });
            }
            slot_bytes = slot_bytes.max(bytes_cursor);
            slot_vals = slot_vals.max(vals_cursor);
            let ts = TaskStaging {
                task: ti,
                pieces,
                bytes_len: bytes_cursor,
                vals_len: vals_cursor,
                down_bytes: down,
                up_bytes: up,
            };
            if phase_c {
                c.push(ts);
            } else {
                a.push(ts);
            }
        }
    }
    TierPlan {
        a,
        c,
        slot_bytes,
        slot_vals,
    }
}

/// Staging layout for the dense fp32 optimizers: both moments stage as
/// plain f32 segments (no codes, no phase C), so per-step traffic is
/// exactly `2 × state_bytes` — the analytic model's assumption.
pub fn build_dense_tier_plan(plan: &Plan) -> TierPlan {
    let mut a = Vec::with_capacity(plan.tasks.len());
    let mut slot_vals = 0usize;
    for (ti, task) in plan.tasks.iter().enumerate() {
        let mut bytes_cursor = 0usize;
        let mut vals_cursor = 0usize;
        let mut down = 0u64;
        let mut up = 0u64;
        let mut pieces = Vec::with_capacity(task.pieces.len());
        for piece in &task.pieces {
            let m = seg_for(
                &SegKind::F32,
                piece,
                false,
                &mut bytes_cursor,
                &mut vals_cursor,
                &mut down,
                &mut up,
            );
            let v = seg_for(
                &SegKind::F32,
                piece,
                false,
                &mut bytes_cursor,
                &mut vals_cursor,
                &mut down,
                &mut up,
            );
            pieces.push(PieceStaging { m, v });
        }
        slot_vals = slot_vals.max(vals_cursor);
        a.push(TaskStaging {
            task: ti,
            pieces,
            bytes_len: 0,
            vals_len: vals_cursor,
            down_bytes: down,
            up_bytes: up,
        });
    }
    TierPlan {
        a,
        c: Vec::new(),
        slot_bytes: 0,
        slot_vals,
    }
}

/// Copy one task's staged segments between host state and a scratch
/// slot. `to_device` selects direction; with `writeback_only` the pass
/// touches only segments the phase mutates (the writeback set).
///
/// # Safety-by-plan
/// All range materialization goes through [`SharedSlice::range_mut`].
/// The host ranges are disjoint across tasks (plan invariant: pieces
/// partition each tensor, and shard boundaries are block/byte aligned);
/// the slot is exclusive to this task while its transfer/compute chain
/// runs (the pipeline's dependency discipline — see `engine/mod.rs`).
#[allow(clippy::too_many_arguments)]
pub(crate) fn copy_task_segments(
    ts: &TaskStaging,
    pieces: &[Piece],
    m_hosts: &[HostMoment<'_>],
    v_hosts: &[HostMoment<'_>],
    slot_bytes: SharedSlice<'_, u8>,
    slot_vals: SharedSlice<'_, f32>,
    to_device: bool,
    writeback_only: bool,
) {
    debug_assert_eq!(ts.pieces.len(), pieces.len());
    for (ps, piece) in ts.pieces.iter().zip(pieces.iter()) {
        for (seg, host) in [
            (ps.m.as_ref(), &m_hosts[piece.tensor]),
            (ps.v.as_ref(), &v_hosts[piece.tensor]),
        ] {
            let Some(seg) = seg else { continue };
            if writeback_only && !seg.writeback {
                continue;
            }
            copy_segment(seg, piece, host, slot_bytes, slot_vals, to_device);
        }
    }
}

fn copy_segment(
    seg: &StagedState,
    piece: &Piece,
    host: &HostMoment<'_>,
    slot_bytes: SharedSlice<'_, u8>,
    slot_vals: SharedSlice<'_, f32>,
    to_device: bool,
) {
    let (lo, hi) = (piece.lo, piece.hi);
    match host {
        HostMoment::F32(data) => {
            // SAFETY: disjoint host piece ranges; exclusive slot (see
            // copy_task_segments).
            let h = unsafe { data.range_mut(lo, hi) };
            // SAFETY: this segment's exclusive sub-range of the slot.
            let d = unsafe { slot_vals.range_mut(seg.vals_off, seg.vals_off + seg.vals_len) };
            if to_device {
                d.copy_from_slice(h);
            } else {
                h.copy_from_slice(d);
            }
        }
        HostMoment::Block {
            q,
            block,
            packed,
            scales,
        } => {
            let (b0, b1) = packed_span(q.bits, lo, hi);
            // SAFETY: block/byte-aligned disjoint piece ranges;
            // exclusive slot.
            let hb = unsafe { packed.range_mut(b0, b1) };
            // SAFETY: this segment's exclusive byte sub-range of the slot.
            let db = unsafe { slot_bytes.range_mut(seg.bytes_off, seg.bytes_off + seg.bytes_len) };
            // SAFETY: block-aligned piece boundaries make scale ranges
            // disjoint across tasks.
            let hs = unsafe { scales.range_mut(lo / block, hi.div_ceil(*block)) };
            // SAFETY: this segment's exclusive f32 sub-range of the slot.
            let ds = unsafe { slot_vals.range_mut(seg.vals_off, seg.vals_off + seg.vals_len) };
            if to_device {
                db.copy_from_slice(hb);
                ds.copy_from_slice(hs);
            } else {
                hb.copy_from_slice(db);
                hs.copy_from_slice(ds);
            }
        }
        HostMoment::Global { q, packed, .. } => {
            let (b0, b1) = packed_span(q.bits, lo, hi);
            // SAFETY: byte-aligned disjoint piece ranges; exclusive slot.
            let hb = unsafe { packed.range_mut(b0, b1) };
            // SAFETY: this segment's exclusive byte sub-range of the slot.
            let db = unsafe { slot_bytes.range_mut(seg.bytes_off, seg.bytes_off + seg.bytes_len) };
            if to_device {
                db.copy_from_slice(hb);
            } else {
                hb.copy_from_slice(db);
            }
        }
        HostMoment::Factored { .. } => unreachable!("factored states are never staged"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::plan::build_plan;
    use crate::tensor::Tensor;
    use crate::util::rng::Pcg64;

    fn meta(numel: usize, shape: &[usize], m: StateLayout, v: StateLayout) -> TensorMeta {
        TensorMeta {
            numel,
            shape: shape.to_vec(),
            m,
            v,
            m_stat_len: 0,
            v_stat_len: match v {
                StateLayout::Global => shape.iter().sum(),
                _ => 0,
            },
        }
    }

    #[test]
    fn tier_plan_accounts_exact_traffic() {
        // One 2-D tensor: m B128 4-bit, v rank-1 4-bit — the adamw4
        // layout. Phase A: m codes+scales down+up, v codes down only.
        // Phase C: v codes down+up.
        let mut rng = Pcg64::seeded(1);
        let t = Tensor::randn(&[8, 128], 0.1, &mut rng);
        let q_m = Quantizer::first_moment_4bit();
        let q_v = Quantizer::second_moment_4bit();
        let m_states = vec![MomentState::Quant(q_m.quantize(&t, &mut rng))];
        let v_states = vec![SecondState::Quant(q_v.quantize(&t, &mut rng))];
        let metas = vec![meta(1024, &[8, 128], StateLayout::Block(128), StateLayout::Global)];
        let plan = build_plan(&metas, 256);
        assert!(plan.tasks.len() > 1, "want a multi-shard plan");
        let tp = build_tier_plan(&plan, &metas, &m_states, &v_states);
        assert_eq!(tp.a.len(), plan.tasks.len());
        assert_eq!(tp.c.len(), plan.tasks.len(), "every task has a global v");
        let (down, up) = tp.step_traffic();
        let m_codes = 1024 / 2;
        let m_scales = 4 * (1024 / 128);
        let v_codes = 1024 / 2;
        // A: (m_codes + m_scales) down+up, v_codes down. C: v_codes down+up.
        assert_eq!(down as usize, m_codes + m_scales + v_codes + v_codes);
        assert_eq!(up as usize, m_codes + m_scales + v_codes);
        // The slot budget bounds every task's staging.
        for ts in tp.a.iter().chain(tp.c.iter()) {
            assert!(ts.bytes_len <= tp.slot_bytes);
            assert!(ts.vals_len <= tp.slot_vals);
        }
    }

    #[test]
    fn factored_and_f32_states_stage_as_documented() {
        let mut rng = Pcg64::seeded(2);
        let t2 = Tensor::randn(&[4, 64], 0.1, &mut rng);
        let m_states = vec![MomentState::F32(t2.clone())];
        let v_states = vec![SecondState::Factored(FactoredSecond::zeros(&[4, 64]))];
        let metas = vec![meta(256, &[4, 64], StateLayout::F32, StateLayout::Factored)];
        let plan = build_plan(&metas, 128);
        let tp = build_tier_plan(&plan, &metas, &m_states, &v_states);
        assert!(tp.c.is_empty(), "no global states, no phase C staging");
        let (down, up) = tp.step_traffic();
        // Only the fp32 m moves: 4 bytes/elem each way.
        assert_eq!(down, 4 * 256);
        assert_eq!(up, 4 * 256);
        for ts in &tp.a {
            for ps in &ts.pieces {
                assert!(ps.v.is_none(), "factored v never staged");
            }
        }
    }

    #[test]
    fn copy_roundtrip_restores_host_bytes() {
        let mut rng = Pcg64::seeded(3);
        let t = Tensor::randn(&[4, 128], 0.3, &mut rng);
        let q_m = Quantizer::first_moment_4bit();
        let mut m_states = vec![MomentState::Quant(q_m.quantize(&t, &mut rng))];
        let mut v_states = vec![SecondState::F32(t.clone())];
        let metas = vec![meta(512, &[4, 128], StateLayout::Block(128), StateLayout::F32)];
        let plan = build_plan(&metas, 256);
        let tp = build_tier_plan(&plan, &metas, &m_states, &v_states);
        let before_packed = match &m_states[0] {
            MomentState::Quant(qt) => qt.packed.clone(),
            _ => unreachable!(),
        };
        let mut bytes = vec![0u8; tp.slot_bytes];
        let mut vals = vec![0.0f32; tp.slot_vals];
        {
            let m_hosts = vec![host_m(&mut m_states[0])];
            let v_hosts = vec![host_v(&mut v_states[0])];
            let sb = SharedSlice::new(bytes.as_mut_slice());
            let sv = SharedSlice::new(vals.as_mut_slice());
            for ts in &tp.a {
                let pieces = &plan.tasks[ts.task].pieces;
                copy_task_segments(ts, pieces, &m_hosts, &v_hosts, sb, sv, true, false);
                copy_task_segments(ts, pieces, &m_hosts, &v_hosts, sb, sv, false, true);
            }
        }
        match &m_states[0] {
            MomentState::Quant(qt) => assert_eq!(qt.packed, before_packed),
            _ => unreachable!(),
        }
        match &v_states[0] {
            SecondState::F32(tt) => assert_eq!(tt.data, t.data),
            _ => unreachable!(),
        }
    }
}
