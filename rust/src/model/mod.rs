#![forbid(unsafe_code)]
//! Model *specifications*: named, classified parameter inventories.
//!
//! A spec is enough to (a) allocate and initialize parameters for the
//! builtin engines, (b) size optimizer states exactly (the paper's memory
//! accounting), and (c) describe the shapes the AOT compile path lowers.
//! Includes the OPT / LLaMA family configs used by the Tab. 5 "largest
//! trainable model" search.

use crate::optim::{Param, ParamKind};
use crate::tensor::Tensor;
use crate::util::rng::Pcg64;

/// Transformer LM configuration (decoder-only, GPT-style).
#[derive(Clone, Copy, Debug)]
pub struct TransformerConfig {
    pub vocab: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub n_layers: usize,
    pub max_seq: usize,
}

impl TransformerConfig {
    /// Tiny config for unit tests and fast CPU experiments.
    pub fn tiny() -> TransformerConfig {
        TransformerConfig {
            vocab: 256,
            d_model: 64,
            n_heads: 4,
            d_ff: 256,
            n_layers: 2,
            max_seq: 32,
        }
    }

    /// Small config for the end-to-end example (few-M params).
    pub fn small() -> TransformerConfig {
        TransformerConfig {
            vocab: 512,
            d_model: 128,
            n_heads: 8,
            d_ff: 512,
            n_layers: 4,
            max_seq: 64,
        }
    }

    /// ~100M-parameter config (GPT-2-small-like); used by the AOT path
    /// sizing and the memory estimator, not by the builtin CPU engine.
    pub fn gpt2_small_like() -> TransformerConfig {
        TransformerConfig {
            vocab: 50257,
            d_model: 768,
            n_heads: 12,
            d_ff: 3072,
            n_layers: 12,
            max_seq: 1024,
        }
    }

    pub fn scaled(depth: usize, width: usize) -> TransformerConfig {
        TransformerConfig {
            vocab: 512,
            d_model: width,
            n_heads: (width / 16).max(1),
            d_ff: width * 4,
            n_layers: depth,
            max_seq: 64,
        }
    }

    /// Parameter inventory: (name, kind, shape). Matches the layout of the
    /// builtin transformer engine exactly (same order).
    pub fn param_specs(&self) -> Vec<(String, ParamKind, Vec<usize>)> {
        let d = self.d_model;
        let mut v: Vec<(String, ParamKind, Vec<usize>)> = Vec::new();
        v.push(("tok_emb".into(), ParamKind::Embedding, vec![self.vocab, d]));
        v.push(("pos_emb".into(), ParamKind::Embedding, vec![self.max_seq, d]));
        for l in 0..self.n_layers {
            let p = |s: &str| format!("layers.{l}.{s}");
            v.push((p("ln1.g"), ParamKind::Norm, vec![d]));
            v.push((p("ln1.b"), ParamKind::Norm, vec![d]));
            v.push((p("attn.wq"), ParamKind::Weight, vec![d, d]));
            v.push((p("attn.wk"), ParamKind::Weight, vec![d, d]));
            v.push((p("attn.wv"), ParamKind::Weight, vec![d, d]));
            v.push((p("attn.wo"), ParamKind::Weight, vec![d, d]));
            v.push((p("ln2.g"), ParamKind::Norm, vec![d]));
            v.push((p("ln2.b"), ParamKind::Norm, vec![d]));
            v.push((p("mlp.fc1"), ParamKind::Weight, vec![d, self.d_ff]));
            v.push((p("mlp.b1"), ParamKind::Bias, vec![self.d_ff]));
            v.push((p("mlp.fc2"), ParamKind::Weight, vec![self.d_ff, d]));
            v.push((p("mlp.b2"), ParamKind::Bias, vec![d]));
        }
        v.push(("ln_f.g".into(), ParamKind::Norm, vec![d]));
        v.push(("ln_f.b".into(), ParamKind::Norm, vec![d]));
        v.push(("lm_head".into(), ParamKind::Weight, vec![d, self.vocab]));
        v
    }

    /// Total parameter count.
    pub fn n_params(&self) -> usize {
        self.param_specs()
            .iter()
            .map(|(_, _, s)| s.iter().product::<usize>())
            .sum()
    }

    /// Allocate + initialize parameters (GPT-2-style init).
    pub fn init_params(&self, rng: &mut Pcg64) -> Vec<Param> {
        let std = 0.02f32;
        let resid_std = std / (2.0 * self.n_layers as f32).sqrt();
        self.param_specs()
            .into_iter()
            .map(|(name, kind, shape)| {
                let t = match kind {
                    ParamKind::Norm => {
                        if name.ends_with(".g") {
                            Tensor::full(&shape, 1.0)
                        } else {
                            Tensor::zeros(&shape)
                        }
                    }
                    ParamKind::Bias => Tensor::zeros(&shape),
                    _ => {
                        // Scaled init on residual-output projections.
                        let s = if name.contains("wo") || name.contains("fc2") {
                            resid_std
                        } else {
                            std
                        };
                        Tensor::randn(&shape, s, rng)
                    }
                };
                Param::new(&name, kind, t)
            })
            .collect()
    }
}

/// MLP classifier configuration (the CLS-task surrogate).
#[derive(Clone, Copy, Debug)]
pub struct MlpConfig {
    pub d_in: usize,
    pub d_hidden: usize,
    pub n_layers: usize,
    pub n_classes: usize,
}

impl MlpConfig {
    pub fn tiny() -> MlpConfig {
        MlpConfig {
            d_in: 32,
            d_hidden: 128,
            n_layers: 2,
            n_classes: 8,
        }
    }

    pub fn param_specs(&self) -> Vec<(String, ParamKind, Vec<usize>)> {
        let mut v = Vec::new();
        let mut prev = self.d_in;
        for l in 0..self.n_layers {
            v.push((
                format!("fc{l}.w"),
                ParamKind::Weight,
                vec![prev, self.d_hidden],
            ));
            v.push((format!("fc{l}.b"), ParamKind::Bias, vec![self.d_hidden]));
            prev = self.d_hidden;
        }
        v.push(("head.w".into(), ParamKind::Weight, vec![prev, self.n_classes]));
        v.push(("head.b".into(), ParamKind::Bias, vec![self.n_classes]));
        v
    }

    pub fn n_params(&self) -> usize {
        self.param_specs()
            .iter()
            .map(|(_, _, s)| s.iter().product::<usize>())
            .sum()
    }

    pub fn init_params(&self, rng: &mut Pcg64) -> Vec<Param> {
        self.param_specs()
            .into_iter()
            .map(|(name, kind, shape)| {
                let t = match kind {
                    ParamKind::Bias => Tensor::zeros(&shape),
                    _ => {
                        let fan_in = shape[0] as f32;
                        Tensor::randn(&shape, (2.0 / fan_in).sqrt(), rng)
                    }
                };
                Param::new(&name, kind, t)
            })
            .collect()
    }
}

/// A named large-model config for the Tab. 5 memory-budget search.
#[derive(Clone, Copy, Debug)]
pub struct NamedModel {
    pub name: &'static str,
    pub cfg: TransformerConfig,
}

/// The OPT family (Zhang et al. '22) sizes the paper's Tab. 5 sweeps.
pub fn opt_family() -> Vec<NamedModel> {
    let m = |name, d_model, n_heads, n_layers, d_ff| NamedModel {
        name,
        cfg: TransformerConfig {
            vocab: 50272,
            d_model,
            n_heads,
            d_ff,
            n_layers,
            max_seq: 2048,
        },
    };
    vec![
        m("OPT-125M", 768, 12, 12, 3072),
        m("OPT-350M", 1024, 16, 24, 4096),
        m("OPT-1.3B", 2048, 32, 24, 8192),
        m("OPT-2.7B", 2560, 32, 32, 10240),
        m("OPT-6.7B", 4096, 32, 32, 16384),
        m("OPT-13B", 5120, 40, 40, 20480),
    ]
}

/// LLaMA family (Touvron et al. '23).
pub fn llama_family() -> Vec<NamedModel> {
    let m = |name, d_model, n_heads, n_layers, d_ff| NamedModel {
        name,
        cfg: TransformerConfig {
            vocab: 32000,
            d_model,
            n_heads,
            d_ff,
            n_layers,
            max_seq: 2048,
        },
    };
    vec![
        m("LLaMA-7B", 4096, 32, 32, 11008),
        m("LLaMA-13B", 5120, 40, 40, 13824),
        m("LLaMA-33B", 6656, 52, 60, 17920),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_param_inventory_consistent() {
        let cfg = TransformerConfig::tiny();
        let mut rng = Pcg64::seeded(0);
        let params = cfg.init_params(&mut rng);
        let specs = cfg.param_specs();
        assert_eq!(params.len(), specs.len());
        for (p, (name, kind, shape)) in params.iter().zip(specs.iter()) {
            assert_eq!(&p.name, name);
            assert_eq!(p.kind, *kind);
            assert_eq!(&p.tensor.shape, shape);
        }
        assert_eq!(
            cfg.n_params(),
            params.iter().map(|p| p.tensor.numel()).sum::<usize>()
        );
    }

    #[test]
    fn gpt2_small_like_is_about_100m() {
        let n = TransformerConfig::gpt2_small_like().n_params();
        assert!(
            (100_000_000..180_000_000).contains(&n),
            "n_params = {n}"
        );
    }

    #[test]
    fn llama7b_param_count_plausible() {
        // LLaMA-7B has ~6.7B params; our GPT-style stand-in (learned pos
        // emb, 2-matrix MLP where LLaMA uses 3 incl. the gate) lands ~20%
        // below — close enough for memory-budget arithmetic.
        let n = llama_family()[0].cfg.n_params();
        assert!(
            (5_000_000_000..8_500_000_000u64).contains(&(n as u64)),
            "n = {n}"
        );
    }

    #[test]
    fn norm_params_initialized_to_identity() {
        let cfg = TransformerConfig::tiny();
        let mut rng = Pcg64::seeded(0);
        let params = cfg.init_params(&mut rng);
        let g = params.iter().find(|p| p.name == "ln_f.g").unwrap();
        assert!(g.tensor.data.iter().all(|&x| x == 1.0));
        let b = params.iter().find(|p| p.name == "ln_f.b").unwrap();
        assert!(b.tensor.data.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn mlp_inventory() {
        let cfg = MlpConfig::tiny();
        let mut rng = Pcg64::seeded(0);
        let params = cfg.init_params(&mut rng);
        assert_eq!(params.len(), 2 * cfg.n_layers + 2);
        assert_eq!(
            cfg.n_params(),
            params.iter().map(|p| p.tensor.numel()).sum::<usize>()
        );
    }

    #[test]
    fn families_listed() {
        assert_eq!(opt_family().len(), 6);
        assert_eq!(llama_family().len(), 3);
    }
}
