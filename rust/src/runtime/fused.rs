#![forbid(unsafe_code)]
//! The fused 4-bit AdamW optimizer backed by the AOT Pallas kernel
//! (`fused_adamw4_<chunk>.hlo.txt`) — the paper's "(fused)" rows in
//! Tab. 4 and its FSDP-packed mode (App. D: FSDP packs parameters into
//! 1-D arrays, where only block-wise quantization applies).
//!
//! Parameters are flattened into fixed-size chunks; each step sends
//! (w, g, m codes, m scales, v codes, v scales, hyper) through PJRT and
//! receives the updated weights and requantized states. Between steps the
//! codes are stored nibble-packed, so persistent memory matches the
//! native 4-bit optimizer exactly.

use super::{tensor_to_literal, u8_literal, Executable, Runtime};
use crate::optim::{Hyper, Optimizer, Param};
use crate::quant::packing;
use crate::tensor::Tensor;
use anyhow::{anyhow, Result};

struct ChunkState {
    /// Nibble-packed m codes (chunk/2 bytes).
    m_packed: Vec<u8>,
    m_scales: Vec<f32>,
    v_packed: Vec<u8>,
    v_scales: Vec<f32>,
}

pub struct FusedAdamW4 {
    hp: Hyper,
    t: usize,
    chunk: usize,
    block: usize,
    exec: Executable,
    /// Flat parameter image (padded to a chunk multiple).
    flat: Vec<f32>,
    chunks: Vec<ChunkState>,
    n_real: usize,
}

impl FusedAdamW4 {
    pub fn load(rt: &Runtime, dir: &str, hp: Hyper) -> Result<FusedAdamW4> {
        let manifest = super::ArtifactManifest::load(dir)?;
        if manifest.fused_chunk == 0 {
            return Err(anyhow!("manifest has no fused_adamw4 entry"));
        }
        let exec = rt.load(&format!(
            "{dir}/fused_adamw4_{}.hlo.txt",
            manifest.fused_chunk
        ))?;
        Ok(FusedAdamW4 {
            hp,
            t: 0,
            chunk: manifest.fused_chunk,
            block: manifest.fused_block,
            exec,
            flat: Vec::new(),
            chunks: Vec::new(),
            n_real: 0,
        })
    }

    fn lazy_init(&mut self, params: &[Param]) {
        if !self.chunks.is_empty() {
            return;
        }
        self.n_real = params.iter().map(|p| p.tensor.numel()).sum();
        let padded = self.n_real.div_ceil(self.chunk) * self.chunk;
        self.flat = vec![0.0; padded];
        let mut off = 0;
        for p in params {
            self.flat[off..off + p.tensor.numel()].copy_from_slice(&p.tensor.data);
            off += p.tensor.numel();
        }
        let n_chunks = padded / self.chunk;
        let scales_per_chunk = self.chunk / self.block;
        // Zero states: code for normalized 0 under each map. scale = 0.
        self.chunks = (0..n_chunks)
            .map(|_| ChunkState {
                m_packed: vec![0u8; packing::packed_len(self.chunk, 4)],
                m_scales: vec![0.0; scales_per_chunk],
                v_packed: vec![0u8; packing::packed_len(self.chunk, 4)],
                v_scales: vec![0.0; scales_per_chunk],
            })
            .collect();
        // The all-zeros code must decode to ~0 for both maps: for the
        // signed DE map, code 0 is the most-negative value, but scale 0
        // zeroes it out; dequant = T(code) * 0 = 0 regardless. OK.
    }

    /// One fused step over all chunks. `flat_grads` must be the gradient
    /// image in the same flattening order. Atomic on failure: updated
    /// chunk states are staged and only committed once every chunk has
    /// executed, and `self.t` is advanced by the caller afterwards — so a
    /// failed dispatch leaves states and bias correction untouched.
    fn step_flat(&mut self, flat_grads: &[f32], lr: f32) -> Result<()> {
        let hp = self.hp;
        let t_next = self.t + 1;
        let bc1 = 1.0 - hp.beta1.powi(t_next as i32);
        let bc2 = 1.0 - hp.beta2.powi(t_next as i32);
        let hyper = [
            lr,
            hp.beta1,
            hp.beta2,
            hp.eps,
            hp.weight_decay,
            bc1,
            bc2,
            0.0,
        ];
        let n_chunks = self.chunks.len();
        let scales_per_chunk = self.chunk / self.block;
        let mut staged: Vec<ChunkState> = Vec::with_capacity(n_chunks);
        for ci in 0..n_chunks {
            let lo = ci * self.chunk;
            let hi = lo + self.chunk;
            let w = Tensor::from_vec(&[self.chunk], self.flat[lo..hi].to_vec());
            let mut g = vec![0.0f32; self.chunk];
            let avail = flat_grads.len().saturating_sub(lo).min(self.chunk);
            g[..avail].copy_from_slice(&flat_grads[lo..lo + avail]);
            let g = Tensor::from_vec(&[self.chunk], g);
            let st = &self.chunks[ci];
            let m_codes = packing::unpack(&st.m_packed, self.chunk, 4);
            let v_codes = packing::unpack(&st.v_packed, self.chunk, 4);
            let inputs = vec![
                tensor_to_literal(&w)?,
                tensor_to_literal(&g)?,
                u8_literal(&m_codes, &[self.chunk])?,
                tensor_to_literal(&Tensor::from_vec(
                    &[scales_per_chunk],
                    st.m_scales.clone(),
                ))?,
                u8_literal(&v_codes, &[self.chunk])?,
                tensor_to_literal(&Tensor::from_vec(
                    &[scales_per_chunk],
                    st.v_scales.clone(),
                ))?,
                tensor_to_literal(&Tensor::from_vec(&[8], hyper.to_vec()))?,
            ];
            let outs = self.exec.run(&inputs)?;
            if outs.len() != 5 {
                return Err(anyhow!("fused artifact returned {} outputs", outs.len()));
            }
            let new_w = outs[0]
                .to_vec::<f32>()
                .map_err(|e| anyhow!("fused w out: {e:?}"))?;
            self.flat[lo..hi].copy_from_slice(&new_w);
            let m_codes = outs[1]
                .to_vec::<u8>()
                .map_err(|e| anyhow!("fused m codes: {e:?}"))?;
            let m_scales = outs[2]
                .to_vec::<f32>()
                .map_err(|e| anyhow!("fused m scales: {e:?}"))?;
            let v_codes = outs[3]
                .to_vec::<u8>()
                .map_err(|e| anyhow!("fused v codes: {e:?}"))?;
            let v_scales = outs[4]
                .to_vec::<f32>()
                .map_err(|e| anyhow!("fused v scales: {e:?}"))?;
            staged.push(ChunkState {
                m_packed: packing::pack(&m_codes, 4),
                m_scales,
                v_packed: packing::pack(&v_codes, 4),
                v_scales,
            });
        }
        self.chunks = staged;
        Ok(())
    }

    /// Loss hook for parity checks: dequantized moments of the flat image.
    pub fn chunk_count(&self) -> usize {
        self.chunks.len()
    }

    /// Dequantized (m, v) images for parity tests/analysis, truncated to
    /// the real (unpadded) parameter count — the zero-padded tail past
    /// `n_real` is a chunking artifact and must not enter parity checks.
    pub fn debug_moments(&self) -> (Vec<f32>, Vec<f32>) {
        use crate::quant::{MapKind, QuantMap};
        let m_map = QuantMap::new(MapKind::DynExp, 4, true);
        let v_map = QuantMap::new(MapKind::Linear, 4, false);
        let mut m = Vec::with_capacity(self.n_real);
        let mut v = Vec::with_capacity(self.n_real);
        'outer: for st in &self.chunks {
            for i in 0..self.chunk {
                if m.len() == self.n_real {
                    break 'outer;
                }
                let mc = packing::get(&st.m_packed, i, 4);
                let vc = packing::get(&st.v_packed, i, 4);
                m.push(m_map.decode(mc) * st.m_scales[i / self.block]);
                v.push(v_map.decode(vc) * st.v_scales[i / self.block]);
            }
        }
        (m, v)
    }

    pub fn flat_params(&self) -> &[f32] {
        &self.flat[..self.n_real]
    }

    /// One optimizer step with an error channel. Atomic on failure: the
    /// step counter, the quantized m/v states and the parameters are all
    /// left untouched (updated chunk states are staged and committed only
    /// after every chunk executed), so callers can safely retry or abort
    /// without bias-correction drift or double-applied EMA updates.
    pub fn try_step(&mut self, params: &mut [Param], grads: &[Tensor], lr: f32) -> Result<()> {
        assert_eq!(params.len(), grads.len());
        self.lazy_init(params);
        // Gather grads into the flat image order.
        let mut flat_g = vec![0.0f32; self.n_real];
        let mut off = 0;
        for g in grads {
            flat_g[off..off + g.numel()].copy_from_slice(&g.data);
            off += g.numel();
        }
        // Scatter current params in (they may have been mutated elsewhere).
        let mut off_w = 0;
        for p in params.iter() {
            self.flat[off_w..off_w + p.tensor.numel()].copy_from_slice(&p.tensor.data);
            off_w += p.tensor.numel();
        }
        self.step_flat(&flat_g, lr)?;
        self.t += 1;
        // Scatter updated weights back.
        let mut off = 0;
        for p in params.iter_mut() {
            let n = p.tensor.numel();
            p.tensor.data.copy_from_slice(&self.flat[off..off + n]);
            off += n;
        }
        Ok(())
    }
}

impl Optimizer for FusedAdamW4 {
    fn step(&mut self, params: &mut [Param], grads: &[Tensor], lr: f32) {
        // The trait has no error channel; a failed PJRT dispatch must not
        // be silently swallowed (weights would freeze while the trainer
        // keeps feeding batches), so surface it loudly.
        if let Err(e) = self.try_step(params, grads, lr) {
            panic!("FusedAdamW4::step failed (step counter not advanced): {e}");
        }
    }

    fn state_bytes(&self) -> usize {
        self.chunks
            .iter()
            .map(|c| {
                c.m_packed.len()
                    + c.v_packed.len()
                    + 4 * (c.m_scales.len() + c.v_scales.len())
            })
            .sum()
    }

    fn name(&self) -> String {
        "4-bit AdamW (fused)".to_string()
    }

    fn t(&self) -> usize {
        self.t
    }
}
