#![forbid(unsafe_code)]
//! The artifact manifest (`artifacts/manifest.json`, written by aot.py)
//! and the PJRT-backed gradient engine built from it.

use super::{i32_literal, literal_to_f32, literal_to_tensor, tensor_to_literal, Executable, Runtime};
use crate::data::LmBatch;
use crate::model::TransformerConfig;
use crate::optim::Param;
use crate::tensor::Tensor;
use crate::train::trainer::GradEngine;
use crate::util::json::Json;
use anyhow::{anyhow, Context, Result};

/// One lowered model's description.
#[derive(Clone, Debug)]
pub struct ModelEntry {
    pub name: String,
    pub batch: usize,
    pub cfg: TransformerConfig,
    /// (name, shape) in HLO parameter order (after the tokens input).
    pub params: Vec<(String, Vec<usize>)>,
}

/// Parsed manifest.json.
#[derive(Clone, Debug, Default)]
pub struct ArtifactManifest {
    pub models: Vec<ModelEntry>,
    pub fused_chunk: usize,
    pub fused_block: usize,
}

impl ArtifactManifest {
    pub fn load(dir: &str) -> Result<ArtifactManifest> {
        let path = format!("{dir}/manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path} — run `make artifacts`"))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("parsing {path}: {e}"))?;
        let mut m = ArtifactManifest::default();
        if let Json::Obj(obj) = &j {
            for (name, entry) in obj {
                if name == "fused_adamw4" {
                    m.fused_chunk = entry.get("chunk").and_then(|x| x.as_usize()).unwrap_or(0);
                    m.fused_block = entry.get("block").and_then(|x| x.as_usize()).unwrap_or(128);
                    continue;
                }
                let get = |k: &str| -> Result<usize> {
                    entry
                        .get(k)
                        .and_then(|x| x.as_usize())
                        .ok_or_else(|| anyhow!("manifest {name}: missing {k}"))
                };
                let cfg = TransformerConfig {
                    vocab: get("vocab")?,
                    d_model: get("d_model")?,
                    n_heads: get("n_heads")?,
                    d_ff: get("d_ff")?,
                    n_layers: get("n_layers")?,
                    max_seq: get("max_seq")?,
                };
                let params = entry
                    .get("params")
                    .and_then(|p| p.as_arr())
                    .ok_or_else(|| anyhow!("manifest {name}: missing params"))?
                    .iter()
                    .map(|p| {
                        let nm = p.get("name").and_then(|x| x.as_str()).unwrap_or("?");
                        let sh = p
                            .get("shape")
                            .and_then(|x| x.as_usize_vec())
                            .unwrap_or_default();
                        (nm.to_string(), sh)
                    })
                    .collect();
                m.models.push(ModelEntry {
                    name: name.clone(),
                    batch: get("batch")?,
                    cfg,
                    params,
                });
            }
        }
        Ok(m)
    }

    pub fn model(&self, name: &str) -> Option<&ModelEntry> {
        self.models.iter().find(|m| m.name == name)
    }
}

/// PJRT-backed gradient engine: executes `train_step_<name>.hlo.txt`.
/// Implements the same [`GradEngine`] interface as the builtin engines, so
/// the trainer, the experiment harness, and every optimizer work unchanged
/// on top of it.
pub struct PjrtTrainStep {
    exec: Executable,
    pub entry: ModelEntry,
}

impl PjrtTrainStep {
    pub fn load(rt: &Runtime, dir: &str, name: &str) -> Result<PjrtTrainStep> {
        let manifest = ArtifactManifest::load(dir)?;
        let entry = manifest
            .model(name)
            .ok_or_else(|| anyhow!("model '{name}' not in manifest"))?
            .clone();
        let exec = rt.load(&format!("{dir}/train_step_{name}.hlo.txt"))?;
        Ok(PjrtTrainStep { exec, entry })
    }

    /// Validate that a parameter vector matches the manifest.
    pub fn check_params(&self, params: &[Param]) -> Result<()> {
        if params.len() != self.entry.params.len() {
            return Err(anyhow!(
                "param count mismatch: have {}, artifact wants {}",
                params.len(),
                self.entry.params.len()
            ));
        }
        for (p, (name, shape)) in params.iter().zip(self.entry.params.iter()) {
            if &p.tensor.shape != shape {
                return Err(anyhow!(
                    "shape mismatch for {name}: have {:?}, artifact wants {shape:?}",
                    p.tensor.shape
                ));
            }
        }
        Ok(())
    }

    /// Execute one train step: (loss, grads in param order).
    pub fn step(&self, params: &[Param], batch: &LmBatch) -> Result<(f32, Vec<Tensor>)> {
        let bsz = self.entry.batch;
        let seq = self.entry.cfg.max_seq;
        if batch.batch_size() != bsz || batch.seq_len() != seq {
            return Err(anyhow!(
                "batch shape ({}, {}) does not match artifact ({bsz}, {seq})",
                batch.batch_size(),
                batch.seq_len()
            ));
        }
        let tokens: Vec<i32> = batch
            .tokens
            .iter()
            .flat_map(|row| row.iter().map(|&t| t as i32))
            .collect();
        let mut inputs = Vec::with_capacity(1 + params.len());
        inputs.push(i32_literal(&tokens, &[bsz, seq + 1])?);
        for p in params {
            inputs.push(tensor_to_literal(&p.tensor)?);
        }
        let outs = self.exec.run(&inputs)?;
        if outs.len() != 1 + params.len() {
            return Err(anyhow!(
                "artifact returned {} outputs, expected {}",
                outs.len(),
                1 + params.len()
            ));
        }
        let loss = literal_to_f32(&outs[0])?;
        let grads = outs[1..]
            .iter()
            .zip(params.iter())
            .map(|(l, p)| literal_to_tensor(l, &p.tensor.shape))
            .collect::<Result<Vec<_>>>()?;
        Ok((loss, grads))
    }
}

impl GradEngine<LmBatch> for PjrtTrainStep {
    fn loss_and_grads(&mut self, params: &[Param], batch: &LmBatch) -> (f32, Vec<Tensor>) {
        match self.step(params, batch) {
            Ok(r) => r,
            Err(e) => {
                // Surfaced as divergence by the trainer rather than a
                // panic deep inside the loop.
                crate::util::log(1, "pjrt", &format!("train step failed: {e}"));
                (
                    f32::NAN,
                    params.iter().map(|p| Tensor::zeros(&p.tensor.shape)).collect(),
                )
            }
        }
    }
}
