#![forbid(unsafe_code)]
//! The PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them from the rust hot path.
//! Python is never invoked here — the artifacts directory is the entire
//! interface between the layers.
//!
//! The offline crate set carries no `xla` bindings, so this module
//! currently compiles against [`xla_stub`]: literal conversions are fully
//! functional, while client construction / execution report
//! "PJRT unavailable" and every caller degrades gracefully (the CLI
//! prints the status, the fused optimizer refuses to load, integration
//! tests skip). Swap the `use` below for the real crate when available.

pub mod fused;
pub mod manifest;
pub mod xla_stub;

use self::xla_stub as xla;

use crate::tensor::Tensor;
use anyhow::{anyhow, Context, Result};
use std::path::Path;

pub use manifest::{ArtifactManifest, PjrtTrainStep};

/// A PJRT CPU client plus an executable cache. One per process.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO text artifact.
    pub fn load(&self, path: &str) -> Result<Executable> {
        if !Path::new(path).exists() {
            return Err(anyhow!(
                "artifact {path} not found — run `make artifacts` first"
            ));
        }
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| anyhow!("parsing {path}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {path}: {e:?}"))?;
        Ok(Executable { exe })
    }
}

/// A compiled computation. All our artifacts are lowered with
/// `return_tuple=True`, so every execution returns one tuple literal that
/// [`Executable::run`] unwraps into its elements.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = self
            .exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow!("execute: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e:?}"))?;
        lit.to_tuple().map_err(|e| anyhow!("untuple: {e:?}"))
    }
}

// ---------------------------------------------------------------------
// Literal <-> native conversions
// ---------------------------------------------------------------------

/// f32 tensor -> literal of the same shape.
pub fn tensor_to_literal(t: &Tensor) -> Result<xla::Literal> {
    let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(&t.data)
        .reshape(&dims)
        .map_err(|e| anyhow!("reshape literal: {e:?}"))
}

/// Literal -> f32 tensor with the given shape (shape is known to callers
/// from the artifact manifest).
pub fn literal_to_tensor(l: &xla::Literal, shape: &[usize]) -> Result<Tensor> {
    let data = l
        .to_vec::<f32>()
        .map_err(|e| anyhow!("literal to f32 vec: {e:?}"))?;
    Ok(Tensor::from_vec(shape, data))
}

/// i32 matrix literal (token batches).
pub fn i32_literal(data: &[i32], shape: &[usize]) -> Result<xla::Literal> {
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(data)
        .reshape(&dims)
        .map_err(|e| anyhow!("reshape i32 literal: {e:?}"))
}

/// u8 vector literal (quantization codes).
pub fn u8_literal(data: &[u8], shape: &[usize]) -> Result<xla::Literal> {
    xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::U8,
        shape,
        data,
    )
    .map_err(|e| anyhow!("u8 literal: {e:?}"))
}

/// Scalar f32 from a literal (losses).
pub fn literal_to_f32(l: &xla::Literal) -> Result<f32> {
    let v = l
        .to_vec::<f32>()
        .map_err(|e| anyhow!("literal to f32: {e:?}"))?;
    v.first()
        .copied()
        .ok_or_else(|| anyhow!("empty literal where scalar expected"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_tensor_roundtrip() {
        let t = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let l = tensor_to_literal(&t).unwrap();
        let back = literal_to_tensor(&l, &[2, 3]).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn u8_literal_roundtrip() {
        let data = vec![0u8, 15, 7, 255];
        let l = u8_literal(&data, &[4]).unwrap();
        assert_eq!(l.to_vec::<u8>().unwrap(), data);
    }
}
