#![forbid(unsafe_code)]
//! In-tree stand-in for the `xla` crate (PJRT bindings).
//!
//! The offline crate set this repo builds against ships no `xla` /
//! `xla_extension` bindings, so the runtime layer compiles against this
//! shim instead. The split is deliberate:
//!
//! * [`Literal`] is a **functional** host-side implementation (typed
//!   buffer + dims) so every literal<->tensor conversion in
//!   `runtime::mod` keeps working and stays unit-tested.
//! * [`PjRtClient`] / compilation / execution are **unavailable**: they
//!   return [`XlaError`] at runtime, which the callers already surface
//!   gracefully (`lowbit info` prints "PJRT unavailable", the fused
//!   optimizer refuses to load, integration tests skip).
//!
//! When a real PJRT binding lands in the crate set, delete this module
//! and re-point `use self::xla_stub as xla;` in `runtime/mod.rs` at it —
//! the API surface below mirrors the binding 1:1.

use std::fmt;

/// Error type mirroring the binding's error enum. Carries a message only.
#[derive(Debug, Clone)]
pub struct XlaError {
    pub msg: String,
}

impl XlaError {
    fn new(msg: impl Into<String>) -> XlaError {
        XlaError { msg: msg.into() }
    }
}

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl std::error::Error for XlaError {}

const UNAVAILABLE: &str = "PJRT backend not available: built against the xla stub \
     (no xla crate in the offline set); native optimizers remain fully functional";

/// Element types we transport (f32 tensors, i32 token batches, u8 codes).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    F32,
    I32,
    U8,
}

impl ElementType {
    fn byte_width(self) -> usize {
        match self {
            ElementType::F32 | ElementType::I32 => 4,
            ElementType::U8 => 1,
        }
    }
}

/// Rust native types that map onto an [`ElementType`].
pub trait NativeType: Copy {
    const TY: ElementType;
    fn write_bytes(xs: &[Self], out: &mut Vec<u8>);
    fn read_bytes(bytes: &[u8]) -> Vec<Self>;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
    fn write_bytes(xs: &[Self], out: &mut Vec<u8>) {
        for x in xs {
            out.extend_from_slice(&x.to_le_bytes());
        }
    }
    fn read_bytes(bytes: &[u8]) -> Vec<Self> {
        bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect()
    }
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::I32;
    fn write_bytes(xs: &[Self], out: &mut Vec<u8>) {
        for x in xs {
            out.extend_from_slice(&x.to_le_bytes());
        }
    }
    fn read_bytes(bytes: &[u8]) -> Vec<Self> {
        bytes
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect()
    }
}

impl NativeType for u8 {
    const TY: ElementType = ElementType::U8;
    fn write_bytes(xs: &[Self], out: &mut Vec<u8>) {
        out.extend_from_slice(xs);
    }
    fn read_bytes(bytes: &[u8]) -> Vec<Self> {
        bytes.to_vec()
    }
}

/// A typed host literal: element type, dims, raw little-endian bytes.
#[derive(Clone, Debug)]
pub struct Literal {
    ty: ElementType,
    dims: Vec<i64>,
    bytes: Vec<u8>,
}

impl Literal {
    /// 1-D literal from a native slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        let mut bytes = Vec::with_capacity(data.len() * std::mem::size_of::<T>());
        T::write_bytes(data, &mut bytes);
        Literal {
            ty: T::TY,
            dims: vec![data.len() as i64],
            bytes,
        }
    }

    /// Literal from a shape and a raw byte buffer.
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        shape: &[usize],
        data: &[u8],
    ) -> Result<Literal, XlaError> {
        let n: usize = shape.iter().product();
        if n * ty.byte_width() != data.len() {
            return Err(XlaError::new(format!(
                "shape {shape:?} ({n} x {}B) does not match {} data bytes",
                ty.byte_width(),
                data.len()
            )));
        }
        Ok(Literal {
            ty,
            dims: shape.iter().map(|&d| d as i64).collect(),
            bytes: data.to_vec(),
        })
    }

    /// Reshape to new dims (element count must match).
    pub fn reshape(mut self, dims: &[i64]) -> Result<Literal, XlaError> {
        let n: i64 = dims.iter().product();
        let have = (self.bytes.len() / self.ty.byte_width()) as i64;
        if n != have {
            return Err(XlaError::new(format!(
                "reshape to {dims:?} ({n} elems) from {have} elems"
            )));
        }
        self.dims = dims.to_vec();
        Ok(self)
    }

    /// Element count.
    pub fn element_count(&self) -> usize {
        self.bytes.len() / self.ty.byte_width()
    }

    /// Copy out as a native vector; errors on element-type mismatch.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, XlaError> {
        if self.ty != T::TY {
            return Err(XlaError::new(format!(
                "literal is {:?}, requested {:?}",
                self.ty,
                T::TY
            )));
        }
        Ok(T::read_bytes(&self.bytes))
    }

    /// Decompose a tuple literal. The stub never produces tuples (nothing
    /// executes), so this always errors.
    pub fn to_tuple(&self) -> Result<Vec<Literal>, XlaError> {
        Err(XlaError::new("stub literal is not a tuple"))
    }
}

/// Parsed HLO module (unavailable in the stub).
pub struct HloModuleProto {
    _priv: (),
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto, XlaError> {
        Err(XlaError::new(format!(
            "cannot parse {path}: {UNAVAILABLE}"
        )))
    }
}

/// A computation handle (never constructible from a real proto here).
pub struct XlaComputation {
    _priv: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _priv: () }
    }
}

/// PJRT client (unavailable in the stub).
pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, XlaError> {
        Err(XlaError::new(UNAVAILABLE))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, XlaError> {
        Err(XlaError::new(UNAVAILABLE))
    }
}

/// Device buffer handle returned by execution.
pub struct PjRtBuffer {
    _priv: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        Err(XlaError::new(UNAVAILABLE))
    }
}

/// Loaded executable (never constructible in the stub).
pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        Err(XlaError::new(UNAVAILABLE))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_typed() {
        let l = Literal::vec1(&[1.0f32, -2.5, 3.25]);
        assert_eq!(l.element_count(), 3);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, -2.5, 3.25]);
        assert!(l.to_vec::<i32>().is_err(), "type mismatch must error");
    }

    #[test]
    fn literal_reshape_checks_count() {
        let l = Literal::vec1(&[1i32, 2, 3, 4]);
        let l = l.reshape(&[2, 2]).unwrap();
        assert_eq!(l.to_vec::<i32>().unwrap(), vec![1, 2, 3, 4]);
        let l2 = Literal::vec1(&[1i32, 2, 3]);
        assert!(l2.reshape(&[2, 2]).is_err());
    }

    #[test]
    fn untyped_construction_validates() {
        let l =
            Literal::create_from_shape_and_untyped_data(ElementType::U8, &[4], &[1, 2, 3, 4])
                .unwrap();
        assert_eq!(l.to_vec::<u8>().unwrap(), vec![1, 2, 3, 4]);
        assert!(
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[2], &[0u8; 7])
                .is_err()
        );
    }

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().err().expect("stub client must not exist");
        assert!(err.to_string().contains("PJRT backend not available"));
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
    }
}
