#![forbid(unsafe_code)]
//! Builtin MLP classifier engine (manual backprop). The CLS-task
//! surrogate: ReLU MLP + softmax cross-entropy over [`ClsBatch`]es.

use crate::data::ClsBatch;
use crate::model::MlpConfig;
use crate::optim::Param;
use crate::tensor::Tensor;

pub struct MlpEngine {
    pub cfg: MlpConfig,
}

impl MlpEngine {
    pub fn new(cfg: MlpConfig) -> MlpEngine {
        MlpEngine { cfg }
    }

    /// Forward + backward. Returns (mean CE loss, grads aligned with
    /// `MlpConfig::param_specs` order).
    pub fn loss_and_grads(&self, params: &[Param], batch: &ClsBatch) -> (f32, Vec<Tensor>) {
        let (logits, hidden) = self.forward(params, &batch.x);
        let (loss, dlogits) = softmax_xent(&logits, &batch.y);
        let grads = self.backward(params, &batch.x, &hidden, dlogits);
        (loss, grads)
    }

    /// Forward only; returns per-class logits.
    pub fn forward_logits(&self, params: &[Param], x: &Tensor) -> Tensor {
        self.forward(params, x).0
    }

    /// Accuracy on a batch.
    pub fn accuracy(&self, params: &[Param], batch: &ClsBatch) -> f64 {
        let logits = self.forward_logits(params, &batch.x);
        let (n, c) = logits.dims2();
        let mut correct = 0usize;
        for i in 0..n {
            let row = &logits.data[i * c..(i + 1) * c];
            let mut best = 0;
            for j in 1..c {
                if row[j] > row[best] {
                    best = j;
                }
            }
            if best == batch.y[i] {
                correct += 1;
            }
        }
        correct as f64 / n as f64
    }

    fn forward(&self, params: &[Param], x: &Tensor) -> (Tensor, Vec<Tensor>) {
        let l = self.cfg.n_layers;
        let mut hidden = Vec::with_capacity(l);
        let mut h = x.clone();
        for i in 0..l {
            let w = &params[2 * i].tensor;
            let b = &params[2 * i + 1].tensor;
            let mut z = h.matmul(w);
            add_bias(&mut z, b);
            relu_inplace(&mut z);
            hidden.push(z.clone());
            h = z;
        }
        let w = &params[2 * l].tensor;
        let b = &params[2 * l + 1].tensor;
        let mut logits = h.matmul(w);
        add_bias(&mut logits, b);
        (logits, hidden)
    }

    fn backward(
        &self,
        params: &[Param],
        x: &Tensor,
        hidden: &[Tensor],
        dlogits: Tensor,
    ) -> Vec<Tensor> {
        let l = self.cfg.n_layers;
        let mut grads: Vec<Tensor> = params
            .iter()
            .map(|p| Tensor::zeros(&p.tensor.shape))
            .collect();
        // Head.
        let last_h = if l == 0 { x } else { &hidden[l - 1] };
        grads[2 * l] = last_h.matmul_tn(&dlogits);
        grads[2 * l + 1] = sum_rows(&dlogits);
        let mut dh = dlogits.matmul_nt(&params[2 * l].tensor);
        // Hidden layers, last to first.
        for i in (0..l).rev() {
            // ReLU mask from the stored post-activation.
            for (dv, hv) in dh.data.iter_mut().zip(hidden[i].data.iter()) {
                if *hv <= 0.0 {
                    *dv = 0.0;
                }
            }
            let inp = if i == 0 { x } else { &hidden[i - 1] };
            grads[2 * i] = inp.matmul_tn(&dh);
            grads[2 * i + 1] = sum_rows(&dh);
            if i > 0 {
                dh = dh.matmul_nt(&params[2 * i].tensor);
            }
        }
        grads
    }
}

/// Mean softmax cross-entropy and its gradient w.r.t. logits.
pub fn softmax_xent(logits: &Tensor, y: &[usize]) -> (f32, Tensor) {
    let (n, c) = logits.dims2();
    assert_eq!(n, y.len());
    let mut probs = logits.clone();
    probs.softmax_rows();
    let mut loss = 0.0f64;
    for (i, &yi) in y.iter().enumerate() {
        loss -= (probs.data[i * c + yi].max(1e-12) as f64).ln();
    }
    let inv = 1.0 / n as f32;
    let mut d = probs;
    for (i, &yi) in y.iter().enumerate() {
        d.data[i * c + yi] -= 1.0;
    }
    for v in d.data.iter_mut() {
        *v *= inv;
    }
    ((loss / n as f64) as f32, d)
}

pub(crate) fn add_bias(z: &mut Tensor, b: &Tensor) {
    let (n, c) = z.dims2();
    assert_eq!(b.numel(), c);
    for i in 0..n {
        for j in 0..c {
            z.data[i * c + j] += b.data[j];
        }
    }
}

pub(crate) fn relu_inplace(z: &mut Tensor) {
    for v in z.data.iter_mut() {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

pub(crate) fn sum_rows(z: &Tensor) -> Tensor {
    let (n, c) = z.dims2();
    let mut out = Tensor::zeros(&[c]);
    for i in 0..n {
        for j in 0..c {
            out.data[j] += z.data[i * c + j];
        }
    }
    out
}

impl Tensor {
    /// Transposed copy of a 2-D tensor (helper for the builtin engines).
    pub fn transpose2(self) -> Tensor {
        let (n, m) = self.dims2();
        let mut out = Tensor::zeros(&[m, n]);
        for i in 0..n {
            for j in 0..m {
                out.data[j * n + i] = self.data[i * m + j];
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::ClusterData;
    use crate::optim::{build, Hyper};
    use crate::util::rng::Pcg64;

    #[test]
    fn gradient_check_finite_differences() {
        let cfg = MlpConfig {
            d_in: 5,
            d_hidden: 7,
            n_layers: 2,
            n_classes: 3,
        };
        let engine = MlpEngine::new(cfg);
        let mut rng = Pcg64::seeded(123);
        let mut params = cfg.init_params(&mut rng);
        let x = Tensor::randn(&[4, 5], 1.0, &mut rng);
        let y = vec![0usize, 2, 1, 2];
        let batch = ClsBatch { x, y };
        let (_, grads) = engine.loss_and_grads(&params, &batch);
        let eps = 1e-3f32;
        let mut checked = 0;
        for pi in 0..params.len() {
            // Spot-check a few coordinates per tensor.
            let n = params[pi].tensor.numel();
            for k in [0, n / 2, n - 1] {
                let orig = params[pi].tensor.data[k];
                params[pi].tensor.data[k] = orig + eps;
                let (lp, _) = engine.loss_and_grads(&params, &batch);
                params[pi].tensor.data[k] = orig - eps;
                let (lm, _) = engine.loss_and_grads(&params, &batch);
                params[pi].tensor.data[k] = orig;
                let fd = (lp - lm) / (2.0 * eps);
                let an = grads[pi].data[k];
                assert!(
                    (fd - an).abs() < 2e-2 * (1.0 + fd.abs().max(an.abs())),
                    "param {pi} ({}) coord {k}: fd={fd} analytic={an}",
                    params[pi].name
                );
                checked += 1;
            }
        }
        assert!(checked >= 18);
    }

    #[test]
    fn trains_to_high_accuracy() {
        let cfg = MlpConfig {
            d_in: 16,
            d_hidden: 32,
            n_layers: 2,
            n_classes: 4,
        };
        let engine = MlpEngine::new(cfg);
        let data = ClusterData::new(16, 4, 7);
        let mut rng = Pcg64::seeded(5);
        let mut params = cfg.init_params(&mut rng);
        let mut opt = build("adamw32", Hyper::default()).unwrap();
        for _ in 0..200 {
            let batch = data.sample(32, &mut rng);
            let (_, grads) = engine.loss_and_grads(&params, &batch);
            opt.step(&mut params, &grads, 3e-3);
        }
        let test = data.sample(400, &mut rng);
        let acc = engine.accuracy(&params, &test);
        assert!(acc > 0.6, "accuracy {acc}");
    }

    #[test]
    fn softmax_xent_gradient_sums_to_zero_per_row() {
        let logits = Tensor::from_vec(&[2, 3], vec![0.1, 0.5, -0.2, 1.0, 0.0, 0.0]);
        let (_, d) = softmax_xent(&logits, &[1, 0]);
        for i in 0..2 {
            let s: f32 = (0..3).map(|j| d.data[i * 3 + j]).sum();
            assert!(s.abs() < 1e-6);
        }
    }
}
