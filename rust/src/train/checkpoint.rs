//! Checkpointing: parameters (and a manifest) serialized to a compact
//! binary format. Optimizer states are serialized *compressed* — a 4-bit
//! checkpoint is ~8× smaller than an fp32 one, which is the on-disk
//! mirror of the paper's in-memory claim.
//!
//! Format: a JSON manifest (`<path>.json`) describing tensors + a raw
//! little-endian blob (`<path>.bin`) holding f32 data (params) and packed
//! u8 data (quantized states).

use crate::optim::{Param, ParamKind};
use crate::util::json::Json;
use std::io::{Read, Write};

/// Save parameters to `<path>.json` + `<path>.bin`.
pub fn save_params(path: &str, params: &[Param], step: usize) -> std::io::Result<()> {
    let mut blob: Vec<u8> = Vec::new();
    let mut entries = Vec::new();
    for p in params {
        let offset = blob.len();
        for &v in &p.tensor.data {
            blob.extend_from_slice(&v.to_le_bytes());
        }
        let mut e = Json::obj();
        e.set("name", Json::Str(p.name.clone()))
            .set("kind", Json::Str(kind_str(p.kind).to_string()))
            .set("shape", Json::from_usizes(&p.tensor.shape))
            .set("offset", Json::Num(offset as f64))
            .set("len", Json::Num(p.tensor.numel() as f64));
        entries.push(e);
    }
    let mut manifest = Json::obj();
    manifest
        .set("version", Json::Num(1.0))
        .set("step", Json::Num(step as f64))
        .set("tensors", Json::Arr(entries));
    if let Some(parent) = std::path::Path::new(path).parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(format!("{path}.json"), manifest.pretty())?;
    let mut f = std::fs::File::create(format!("{path}.bin"))?;
    f.write_all(&blob)?;
    Ok(())
}

/// Load parameters saved by [`save_params`]. Returns (params, step).
pub fn load_params(path: &str) -> std::io::Result<(Vec<Param>, usize)> {
    let manifest_text = std::fs::read_to_string(format!("{path}.json"))?;
    let manifest = Json::parse(&manifest_text)
        .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, e))?;
    let mut blob = Vec::new();
    std::fs::File::open(format!("{path}.bin"))?.read_to_end(&mut blob)?;
    let step = manifest
        .get("step")
        .and_then(|s| s.as_usize())
        .unwrap_or(0);
    let tensors = manifest
        .get("tensors")
        .and_then(|t| t.as_arr())
        .ok_or_else(|| bad("missing tensors"))?;
    let mut params = Vec::with_capacity(tensors.len());
    for e in tensors {
        let name = e.get("name").and_then(|x| x.as_str()).ok_or_else(|| bad("name"))?;
        let kind = parse_kind(
            e.get("kind").and_then(|x| x.as_str()).ok_or_else(|| bad("kind"))?,
        );
        let shape = e
            .get("shape")
            .and_then(|x| x.as_usize_vec())
            .ok_or_else(|| bad("shape"))?;
        let offset = e.get("offset").and_then(|x| x.as_usize()).ok_or_else(|| bad("offset"))?;
        let len = e.get("len").and_then(|x| x.as_usize()).ok_or_else(|| bad("len"))?;
        if offset + 4 * len > blob.len() {
            return Err(bad("blob too short"));
        }
        let data: Vec<f32> = (0..len)
            .map(|i| {
                let o = offset + 4 * i;
                f32::from_le_bytes([blob[o], blob[o + 1], blob[o + 2], blob[o + 3]])
            })
            .collect();
        params.push(Param::new(
            name,
            kind,
            crate::tensor::Tensor::from_vec(&shape, data),
        ));
    }
    Ok((params, step))
}

fn bad(msg: &str) -> std::io::Error {
    std::io::Error::new(std::io::ErrorKind::InvalidData, msg.to_string())
}

fn kind_str(k: ParamKind) -> &'static str {
    match k {
        ParamKind::Embedding => "embedding",
        ParamKind::Weight => "weight",
        ParamKind::Bias => "bias",
        ParamKind::Norm => "norm",
    }
}

fn parse_kind(s: &str) -> ParamKind {
    match s {
        "embedding" => ParamKind::Embedding,
        "bias" => ParamKind::Bias,
        "norm" => ParamKind::Norm,
        _ => ParamKind::Weight,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::TransformerConfig;
    use crate::util::rng::Pcg64;

    #[test]
    fn roundtrip_exact() {
        let cfg = TransformerConfig::tiny();
        let mut rng = Pcg64::seeded(17);
        let params = cfg.init_params(&mut rng);
        let dir = std::env::temp_dir().join(format!("lowbit_ckpt_{}", std::process::id()));
        let path = dir.join("ckpt").to_str().unwrap().to_string();
        save_params(&path, &params, 42).unwrap();
        let (loaded, step) = load_params(&path).unwrap();
        assert_eq!(step, 42);
        assert_eq!(loaded.len(), params.len());
        for (a, b) in params.iter().zip(loaded.iter()) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.kind, b.kind);
            assert_eq!(a.tensor.shape, b.tensor.shape);
            assert_eq!(a.tensor.data, b.tensor.data);
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn load_missing_fails_cleanly() {
        assert!(load_params("/nonexistent/path/ckpt").is_err());
    }
}
