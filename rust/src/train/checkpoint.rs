//! Checkpointing: parameters and optimizer state serialized to a compact
//! binary format. Optimizer states are serialized *compressed* — a 4-bit
//! checkpoint is ~8× smaller than an fp32 one, which is the on-disk
//! mirror of the paper's in-memory claim — and a reloaded run continues
//! bit-identically to an uninterrupted one (the packed codes, scales and
//! step counter round-trip exactly).
//!
//! Format: a JSON manifest (`<path>.json`) describing tensors + a raw
//! little-endian blob (`<path>.bin`) holding f32 data (params, scales,
//! factored stats) and packed u8 data (quantized state codes). The blob
//! is pre-sized and filled with bulk per-tensor copies — no per-element
//! `Vec` growth — and loaders validate every manifest extent against the
//! blob length (checked arithmetic, `InvalidData` on any disagreement)
//! instead of trusting offsets.
//!
//! Durability (see `offload/mod.rs`, "Failure semantics"): both files
//! are written atomically — staged to a `.tmp` sibling, `fsync`ed, then
//! renamed over the destination — so a crash mid-save leaves the
//! previous checkpoint intact, never a torn one. Every section (one
//! tensor's data, one state's codes + scales) additionally carries a
//! CRC-32 over its blob bytes; loaders verify before decoding and
//! reject a corrupted or truncated file with an error *naming the bad
//! section*. Checkpoints written before the CRC fields existed still
//! load (extent validation alone).

use crate::fault::crc32;
use crate::optim::factor::FactoredSecond;
use crate::optim::lowbit::CompressedAdamW;
use crate::optim::state::{MomentState, SecondState};
use crate::optim::{Param, ParamKind};
use crate::quant::{packing, MapKind, NormKind, QuantizedTensor, Quantizer, Scales};
use crate::tensor::Tensor;
use crate::util::json::Json;
use std::io::{Read, Write};

/// Append a f32 slice's little-endian bytes in one bulk copy per tensor.
fn push_f32s(blob: &mut Vec<u8>, vals: &[f32]) {
    if cfg!(target_endian = "little") {
        let ptr = vals.as_ptr() as *const u8;
        // SAFETY: any f32 bit pattern is valid to view as bytes, and on
        // little-endian targets the in-memory bytes are exactly the
        // serialized little-endian form.
        let bytes = unsafe { std::slice::from_raw_parts(ptr, vals.len() * 4) };
        blob.extend_from_slice(bytes);
    } else {
        blob.reserve(vals.len() * 4);
        for &v in vals {
            blob.extend_from_slice(&v.to_le_bytes());
        }
    }
}

/// Read `len` f32s starting at byte `offset`, validating the extent.
fn read_f32s(blob: &[u8], offset: usize, len: usize) -> std::io::Result<Vec<f32>> {
    let end = len
        .checked_mul(4)
        .and_then(|b| b.checked_add(offset))
        .ok_or_else(|| bad("tensor extent overflows"))?;
    if end > blob.len() {
        return Err(bad("blob too short for manifest extents"));
    }
    Ok(blob[offset..end]
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Read `len` raw bytes starting at `offset`, validating the extent.
fn read_bytes(blob: &[u8], offset: usize, len: usize) -> std::io::Result<Vec<u8>> {
    let end = offset
        .checked_add(len)
        .ok_or_else(|| bad("byte extent overflows"))?;
    if end > blob.len() {
        return Err(bad("blob too short for manifest extents"));
    }
    Ok(blob[offset..end].to_vec())
}

/// Write `bytes` to `path` atomically: stage to a `.tmp` sibling,
/// `fsync`, then rename over the destination. A crash at any point
/// leaves either the old file or the new one — never a torn mix.
fn write_atomic(path: &str, bytes: &[u8]) -> std::io::Result<()> {
    if let Some(parent) = std::path::Path::new(path).parent() {
        std::fs::create_dir_all(parent)?;
    }
    let tmp = format!("{path}.tmp.{}", std::process::id());
    {
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(bytes)?;
        f.sync_all()?;
    }
    std::fs::rename(&tmp, path)
}

fn write_blob(path: &str, blob: &[u8]) -> std::io::Result<()> {
    write_atomic(&format!("{path}.bin"), blob)
}

/// Stamp a section's blob range and CRC-32 on its manifest entry.
/// `start` is `blob.len()` before the section's bytes were pushed — each
/// entry's pushes are contiguous, so `[start, blob.len())` covers
/// exactly the bytes the loader will read for this entry.
fn seal_section(e: &mut Json, blob: &[u8], start: usize) {
    e.set("sec_offset", Json::Num(start as f64))
        .set("sec_len", Json::Num((blob.len() - start) as f64))
        .set("crc", Json::Num(crc32(&blob[start..]) as f64));
}

/// Verify a manifest entry's section CRC against the blob, bounds first
/// (a truncated blob is reported as truncation, not a bad slice).
/// Entries without a `crc` field (pre-CRC checkpoints) pass through —
/// extent validation still applies downstream.
fn verify_section(e: &Json, blob: &[u8], name: &str) -> std::io::Result<()> {
    let stored = match e.get("crc").and_then(|x| x.as_f64()) {
        Some(c) => c as u32,
        None => return Ok(()),
    };
    let off = e
        .get("sec_offset")
        .and_then(|x| x.as_usize())
        .ok_or_else(|| bad(&format!("section {name}: crc without sec_offset")))?;
    let len = e
        .get("sec_len")
        .and_then(|x| x.as_usize())
        .ok_or_else(|| bad(&format!("section {name}: crc without sec_len")))?;
    let end = off
        .checked_add(len)
        .ok_or_else(|| bad(&format!("section {name}: extent overflows")))?;
    if end > blob.len() {
        return Err(bad(&format!(
            "section {name}: blob truncated (section ends at byte {end}, file has {})",
            blob.len()
        )));
    }
    let got = crc32(&blob[off..end]);
    if got != stored {
        return Err(bad(&format!(
            "section {name}: CRC-32 mismatch (stored {stored:#010x}, computed {got:#010x})"
        )));
    }
    Ok(())
}

/// Save parameters to `<path>.json` + `<path>.bin`.
pub fn save_params(path: &str, params: &[Param], step: usize) -> std::io::Result<()> {
    let total: usize = params.iter().map(|p| 4 * p.tensor.numel()).sum();
    let mut blob: Vec<u8> = Vec::with_capacity(total);
    let mut entries = Vec::new();
    for p in params {
        let offset = blob.len();
        push_f32s(&mut blob, &p.tensor.data);
        let mut e = Json::obj();
        e.set("name", Json::Str(p.name.clone()))
            .set("kind", Json::Str(kind_str(p.kind).to_string()))
            .set("shape", Json::from_usizes(&p.tensor.shape))
            .set("offset", Json::Num(offset as f64))
            .set("len", Json::Num(p.tensor.numel() as f64));
        seal_section(&mut e, &blob, offset);
        entries.push(e);
    }
    debug_assert_eq!(blob.len(), total);
    let mut manifest = Json::obj();
    manifest
        .set("version", Json::Num(1.0))
        .set("step", Json::Num(step as f64))
        .set("tensors", Json::Arr(entries));
    // Blob first: until the manifest rename lands, a loader still sees
    // the previous (manifest, blob) pair or fails extent validation —
    // never silently reads new offsets against old bytes.
    write_blob(path, &blob)?;
    write_atomic(&format!("{path}.json"), manifest.pretty().as_bytes())
}

/// Load parameters saved by [`save_params`]. Returns (params, step).
/// Every manifest extent is validated against the blob (including the
/// total length — a truncated or padded `.bin` is `InvalidData`, never a
/// panic).
pub fn load_params(path: &str) -> std::io::Result<(Vec<Param>, usize)> {
    let manifest_text = std::fs::read_to_string(format!("{path}.json"))?;
    let manifest = Json::parse(&manifest_text)
        .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, e))?;
    let mut blob = Vec::new();
    std::fs::File::open(format!("{path}.bin"))?.read_to_end(&mut blob)?;
    let step = manifest
        .get("step")
        .and_then(|s| s.as_usize())
        .unwrap_or(0);
    let tensors = manifest
        .get("tensors")
        .and_then(|t| t.as_arr())
        .ok_or_else(|| bad("missing tensors"))?;
    let mut params = Vec::with_capacity(tensors.len());
    let mut covered = 0usize;
    for e in tensors {
        let name = e.get("name").and_then(|x| x.as_str()).ok_or_else(|| bad("name"))?;
        let kind = parse_kind(
            e.get("kind").and_then(|x| x.as_str()).ok_or_else(|| bad("kind"))?,
        );
        let shape = e
            .get("shape")
            .and_then(|x| x.as_usize_vec())
            .ok_or_else(|| bad("shape"))?;
        let offset = e.get("offset").and_then(|x| x.as_usize()).ok_or_else(|| bad("offset"))?;
        let len = e.get("len").and_then(|x| x.as_usize()).ok_or_else(|| bad("len"))?;
        if shape.iter().product::<usize>() != len {
            return Err(bad("shape disagrees with len"));
        }
        verify_section(e, &blob, &format!("tensor '{name}'"))?;
        let data = read_f32s(&blob, offset, len)?;
        covered = covered.max(offset + 4 * len);
        params.push(Param::new(
            name,
            kind,
            crate::tensor::Tensor::from_vec(&shape, data),
        ));
    }
    if covered != blob.len() {
        return Err(bad("blob length disagrees with manifest extents"));
    }
    Ok((params, step))
}

// ---------------------------------------------------------------------
// Compressed optimizer state.
// ---------------------------------------------------------------------

fn scales_entry(e: &mut Json, blob: &mut Vec<u8>, scales: &Scales) {
    match scales {
        Scales::PerTensor(s) => {
            e.set("scale_kind", Json::Str("per-tensor".into()))
                .set("scale", Json::Num(*s as f64));
        }
        Scales::Block { block, scales } => {
            e.set("scale_kind", Json::Str("block".into()))
                .set("block", Json::Num(*block as f64))
                .set("scale_offset", Json::Num(blob.len() as f64))
                .set("scale_count", Json::Num(scales.len() as f64));
            push_f32s(blob, scales);
        }
        Scales::Rank1 { per_axis } => {
            e.set("scale_kind", Json::Str("rank1".into()))
                .set("scale_offset", Json::Num(blob.len() as f64))
                .set(
                    "axis_lens",
                    Json::from_usizes(&per_axis.iter().map(|a| a.len()).collect::<Vec<_>>()),
                );
            for axis in per_axis {
                push_f32s(blob, axis);
            }
        }
    }
}

fn quant_entry(e: &mut Json, blob: &mut Vec<u8>, qt: &QuantizedTensor) {
    let q = qt.quantizer;
    e.set("form", Json::Str("quant".into()))
        .set("shape", Json::from_usizes(&qt.shape))
        .set("bits", Json::Num(q.bits as f64))
        .set("signed", Json::Bool(q.signed))
        .set("stochastic", Json::Bool(q.stochastic))
        .set("norm", Json::Str(q.norm.name()))
        .set("map", Json::Str(q.map.name().to_string()))
        .set("code_offset", Json::Num(blob.len() as f64))
        .set("code_len", Json::Num(qt.packed.len() as f64));
    blob.extend_from_slice(&qt.packed);
    scales_entry(e, blob, &qt.scales);
}

fn state_entry(
    which: &str,
    idx: usize,
    blob: &mut Vec<u8>,
    body: impl FnOnce(&mut Json, &mut Vec<u8>),
) -> Json {
    let mut e = Json::obj();
    e.set("which", Json::Str(which.to_string()))
        .set("idx", Json::Num(idx as f64));
    let start = blob.len();
    body(&mut e, blob);
    seal_section(&mut e, blob, start);
    e
}

/// Save a compressed optimizer's state — packed codes, scales, factored
/// statistics and the step counter — to `<path>.json` + `<path>.bin`.
/// The compressed forms are persisted as-is (a 4-bit state checkpoint is
/// ~8× smaller than an fp32 one), and [`load_opt_state`] restores them
/// byte-exactly, so a resumed run continues bit-identically.
pub fn save_opt_state(path: &str, opt: &CompressedAdamW) -> std::io::Result<()> {
    let (t, ms, vs) = opt.export_states();
    let mut blob: Vec<u8> = Vec::new();
    let mut entries = Vec::new();
    for (i, m) in ms.iter().enumerate() {
        entries.push(state_entry("m", i, &mut blob, |e, blob| match m {
            MomentState::F32(tn) => {
                e.set("form", Json::Str("f32".into()))
                    .set("shape", Json::from_usizes(&tn.shape))
                    .set("offset", Json::Num(blob.len() as f64))
                    .set("len", Json::Num(tn.numel() as f64));
                push_f32s(blob, &tn.data);
            }
            MomentState::Quant(qt) => quant_entry(e, blob, qt),
        }));
    }
    for (i, v) in vs.iter().enumerate() {
        entries.push(state_entry("v", i, &mut blob, |e, blob| match v {
            SecondState::F32(tn) => {
                e.set("form", Json::Str("f32".into()))
                    .set("shape", Json::from_usizes(&tn.shape))
                    .set("offset", Json::Num(blob.len() as f64))
                    .set("len", Json::Num(tn.numel() as f64));
                push_f32s(blob, &tn.data);
            }
            SecondState::Quant(qt) => quant_entry(e, blob, qt),
            SecondState::Factored(f) => {
                e.set("form", Json::Str("factored".into()))
                    .set("shape", Json::from_usizes(&f.shape))
                    .set("row_offset", Json::Num(blob.len() as f64))
                    .set("rows", Json::Num(f.rows() as f64));
                push_f32s(blob, &f.row);
                e.set("col_offset", Json::Num(blob.len() as f64))
                    .set("cols", Json::Num(f.cols() as f64));
                push_f32s(blob, &f.col);
            }
        }));
    }
    let mut manifest = Json::obj();
    manifest
        .set("version", Json::Num(1.0))
        .set("t", Json::Num(t as f64))
        .set("count", Json::Num(ms.len() as f64))
        .set("states", Json::Arr(entries));
    write_blob(path, &blob)?;
    write_atomic(&format!("{path}.json"), manifest.pretty().as_bytes())
}

fn parse_quant(e: &Json, blob: &[u8], covered: &mut usize) -> std::io::Result<QuantizedTensor> {
    let shape = e
        .get("shape")
        .and_then(|x| x.as_usize_vec())
        .ok_or_else(|| bad("state shape"))?;
    let numel: usize = shape.iter().product();
    let bits = e.get("bits").and_then(|x| x.as_usize()).ok_or_else(|| bad("bits"))? as u8;
    let signed = e.get("signed").and_then(|x| x.as_bool()).ok_or_else(|| bad("signed"))?;
    let stochastic = e
        .get("stochastic")
        .and_then(|x| x.as_bool())
        .ok_or_else(|| bad("stochastic"))?;
    let norm = e
        .get("norm")
        .and_then(|x| x.as_str())
        .and_then(NormKind::parse)
        .ok_or_else(|| bad("norm kind"))?;
    let map = e
        .get("map")
        .and_then(|x| x.as_str())
        .and_then(MapKind::parse)
        .ok_or_else(|| bad("map kind"))?;
    let code_offset = e
        .get("code_offset")
        .and_then(|x| x.as_usize())
        .ok_or_else(|| bad("code_offset"))?;
    let code_len = e
        .get("code_len")
        .and_then(|x| x.as_usize())
        .ok_or_else(|| bad("code_len"))?;
    if code_len != packing::packed_len(numel, bits) {
        return Err(bad("code_len disagrees with shape/bits"));
    }
    let packed = read_bytes(blob, code_offset, code_len)?;
    *covered = (*covered).max(code_offset + code_len);
    let scales = match e.get("scale_kind").and_then(|x| x.as_str()) {
        Some("per-tensor") => Scales::PerTensor(
            e.get("scale").and_then(|x| x.as_f64()).ok_or_else(|| bad("scale"))? as f32,
        ),
        Some("block") => {
            let block = e.get("block").and_then(|x| x.as_usize()).ok_or_else(|| bad("block"))?;
            let off = e
                .get("scale_offset")
                .and_then(|x| x.as_usize())
                .ok_or_else(|| bad("scale_offset"))?;
            let count = e
                .get("scale_count")
                .and_then(|x| x.as_usize())
                .ok_or_else(|| bad("scale_count"))?;
            if block == 0 || count != numel.div_ceil(block) {
                return Err(bad("block scales disagree with shape"));
            }
            let scales = read_f32s(blob, off, count)?;
            *covered = (*covered).max(off + 4 * count);
            Scales::Block { block, scales }
        }
        Some("rank1") => {
            let mut off = e
                .get("scale_offset")
                .and_then(|x| x.as_usize())
                .ok_or_else(|| bad("scale_offset"))?;
            let lens = e
                .get("axis_lens")
                .and_then(|x| x.as_usize_vec())
                .ok_or_else(|| bad("axis_lens"))?;
            if lens.len() != shape.len() || lens.iter().zip(shape.iter()).any(|(a, b)| a != b) {
                return Err(bad("rank1 axis lens disagree with shape"));
            }
            let mut per_axis = Vec::with_capacity(lens.len());
            for len in lens {
                per_axis.push(read_f32s(blob, off, len)?);
                off += 4 * len;
            }
            *covered = (*covered).max(off);
            Scales::Rank1 { per_axis }
        }
        _ => return Err(bad("scale_kind")),
    };
    let mut q = Quantizer::new(norm, map, bits, signed);
    q = q.with_stochastic(stochastic);
    Ok(QuantizedTensor {
        shape,
        bits,
        packed,
        scales,
        quantizer: q,
    })
}

fn parse_f32_tensor(e: &Json, blob: &[u8], covered: &mut usize) -> std::io::Result<Tensor> {
    let shape = e
        .get("shape")
        .and_then(|x| x.as_usize_vec())
        .ok_or_else(|| bad("state shape"))?;
    let offset = e.get("offset").and_then(|x| x.as_usize()).ok_or_else(|| bad("offset"))?;
    let len = e.get("len").and_then(|x| x.as_usize()).ok_or_else(|| bad("len"))?;
    if shape.iter().product::<usize>() != len {
        return Err(bad("shape disagrees with len"));
    }
    let data = read_f32s(blob, offset, len)?;
    *covered = (*covered).max(offset + 4 * len);
    Ok(Tensor::from_vec(&shape, data))
}

/// Restore a compressed optimizer's state saved by [`save_opt_state`].
/// The optimizer must be configured with the same policy the state was
/// saved under; continuation after restore is bit-identical to the
/// uninterrupted run (pinned by the roundtrip test below).
pub fn load_opt_state(path: &str, opt: &mut CompressedAdamW) -> std::io::Result<()> {
    let manifest_text = std::fs::read_to_string(format!("{path}.json"))?;
    let manifest = Json::parse(&manifest_text)
        .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, e))?;
    let mut blob = Vec::new();
    std::fs::File::open(format!("{path}.bin"))?.read_to_end(&mut blob)?;
    let t = manifest.get("t").and_then(|x| x.as_usize()).ok_or_else(|| bad("t"))?;
    let count = manifest.get("count").and_then(|x| x.as_usize()).ok_or_else(|| bad("count"))?;
    let states = manifest
        .get("states")
        .and_then(|x| x.as_arr())
        .ok_or_else(|| bad("missing states"))?;
    let mut ms: Vec<Option<MomentState>> = (0..count).map(|_| None).collect();
    let mut vs: Vec<Option<SecondState>> = (0..count).map(|_| None).collect();
    let mut covered = 0usize;
    for e in states {
        let which = e.get("which").and_then(|x| x.as_str()).ok_or_else(|| bad("which"))?;
        let idx = e.get("idx").and_then(|x| x.as_usize()).ok_or_else(|| bad("idx"))?;
        if idx >= count {
            return Err(bad("state idx out of range"));
        }
        verify_section(e, &blob, &format!("{which}[{idx}]"))?;
        let form = e.get("form").and_then(|x| x.as_str()).ok_or_else(|| bad("form"))?;
        match which {
            "m" => {
                let state = match form {
                    "f32" => MomentState::F32(parse_f32_tensor(e, &blob, &mut covered)?),
                    "quant" => MomentState::Quant(parse_quant(e, &blob, &mut covered)?),
                    _ => return Err(bad("m form")),
                };
                if ms[idx].is_some() {
                    return Err(bad("duplicate m state entry"));
                }
                ms[idx] = Some(state);
            }
            "v" => {
                let state = match form {
                    "f32" => SecondState::F32(parse_f32_tensor(e, &blob, &mut covered)?),
                    "quant" => SecondState::Quant(parse_quant(e, &blob, &mut covered)?),
                    "factored" => {
                        let shape = e
                            .get("shape")
                            .and_then(|x| x.as_usize_vec())
                            .ok_or_else(|| bad("state shape"))?;
                        let rows =
                            e.get("rows").and_then(|x| x.as_usize()).ok_or_else(|| bad("rows"))?;
                        let cols =
                            e.get("cols").and_then(|x| x.as_usize()).ok_or_else(|| bad("cols"))?;
                        let ro = e
                            .get("row_offset")
                            .and_then(|x| x.as_usize())
                            .ok_or_else(|| bad("row_offset"))?;
                        let co = e
                            .get("col_offset")
                            .and_then(|x| x.as_usize())
                            .ok_or_else(|| bad("col_offset"))?;
                        if shape.len() < 2
                            || shape[0] != rows
                            || shape[1..].iter().product::<usize>() != cols
                        {
                            return Err(bad("factored dims disagree with shape"));
                        }
                        let row = read_f32s(&blob, ro, rows)?;
                        let col = read_f32s(&blob, co, cols)?;
                        covered = covered.max(ro + 4 * rows).max(co + 4 * cols);
                        SecondState::Factored(FactoredSecond { shape, row, col })
                    }
                    _ => return Err(bad("v form")),
                };
                if vs[idx].is_some() {
                    return Err(bad("duplicate v state entry"));
                }
                vs[idx] = Some(state);
            }
            _ => return Err(bad("which")),
        }
    }
    let ms: Vec<MomentState> = ms
        .into_iter()
        .map(|s| s.ok_or_else(|| bad("missing m state")))
        .collect::<Result<_, _>>()?;
    let vs: Vec<SecondState> = vs
        .into_iter()
        .map(|s| s.ok_or_else(|| bad("missing v state")))
        .collect::<Result<_, _>>()?;
    if covered != blob.len() {
        return Err(bad("blob length disagrees with manifest extents"));
    }
    opt.import_states(t, ms, vs).map_err(|e| bad(&e))
}

fn bad(msg: &str) -> std::io::Error {
    std::io::Error::new(std::io::ErrorKind::InvalidData, msg.to_string())
}

fn kind_str(k: ParamKind) -> &'static str {
    match k {
        ParamKind::Embedding => "embedding",
        ParamKind::Weight => "weight",
        ParamKind::Bias => "bias",
        ParamKind::Norm => "norm",
    }
}

fn parse_kind(s: &str) -> ParamKind {
    match s {
        "embedding" => ParamKind::Embedding,
        "bias" => ParamKind::Bias,
        "norm" => ParamKind::Norm,
        _ => ParamKind::Weight,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::TransformerConfig;
    use crate::optim::lowbit::QuantPolicy;
    use crate::optim::{Hyper, Optimizer};
    use crate::util::rng::Pcg64;

    fn tmp_base(tag: &str) -> (std::path::PathBuf, String) {
        let dir = std::env::temp_dir().join(format!("lowbit_ckpt_{tag}_{}", std::process::id()));
        let path = dir.join("ckpt").to_str().unwrap().to_string();
        (dir, path)
    }

    #[test]
    fn roundtrip_exact() {
        let cfg = TransformerConfig::tiny();
        let mut rng = Pcg64::seeded(17);
        let params = cfg.init_params(&mut rng);
        let (dir, path) = tmp_base("params");
        save_params(&path, &params, 42).unwrap();
        let (loaded, step) = load_params(&path).unwrap();
        assert_eq!(step, 42);
        assert_eq!(loaded.len(), params.len());
        for (a, b) in params.iter().zip(loaded.iter()) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.kind, b.kind);
            assert_eq!(a.tensor.shape, b.tensor.shape);
            assert_eq!(a.tensor.data, b.tensor.data);
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn load_missing_fails_cleanly() {
        assert!(load_params("/nonexistent/path/ckpt").is_err());
    }

    #[test]
    fn load_rejects_blob_extent_mismatch() {
        // A .bin whose length disagrees with the manifest must be
        // InvalidData — truncated, padded, or overflowing offsets alike.
        let mut rng = Pcg64::seeded(23);
        let params = vec![Param::new(
            "w",
            ParamKind::Weight,
            Tensor::randn(&[8, 8], 0.5, &mut rng),
        )];
        let (dir, path) = tmp_base("badblob");
        save_params(&path, &params, 1).unwrap();

        let bin = format!("{path}.bin");
        let good = std::fs::read(&bin).unwrap();
        // Truncated blob.
        std::fs::write(&bin, &good[..good.len() - 5]).unwrap();
        let err = load_params(&path).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
        // Padded blob (trailing garbage the manifest does not cover).
        let mut padded = good.clone();
        padded.extend_from_slice(&[0u8; 16]);
        std::fs::write(&bin, &padded).unwrap();
        let err = load_params(&path).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
        std::fs::write(&bin, &good).unwrap();

        // Manifest with an extent far past the blob (the overflow-prone
        // `offset + 4*len` path) must also be InvalidData, not a panic.
        let manifest = std::fs::read_to_string(format!("{path}.json")).unwrap();
        let huge = manifest.replace("\"offset\": 0", &format!("\"offset\": {}", usize::MAX / 2));
        std::fs::write(format!("{path}.json"), huge).unwrap();
        let err = load_params(&path).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
        let _ = std::fs::remove_dir_all(&dir);
    }

    fn grads_at(shapes: &[Vec<usize>], s: usize) -> Vec<Tensor> {
        let mut g = Pcg64::seeded(500 + s as u64);
        shapes.iter().map(|sh| Tensor::randn(sh, 0.1, &mut g)).collect()
    }

    fn mk_params(shapes: &[Vec<usize>]) -> Vec<Param> {
        let mut rng = Pcg64::seeded(9);
        shapes
            .iter()
            .enumerate()
            .map(|(i, s)| {
                Param::new(&format!("p{i}"), ParamKind::Weight, Tensor::randn(s, 0.5, &mut rng))
            })
            .collect()
    }

    #[test]
    fn compressed_state_checkpoint_resumes_bit_identical() {
        // Save mid-run, reload into a fresh optimizer, continue: the
        // resumed run must be bit-identical to the uninterrupted one —
        // weights AND decompressed states.
        let hp = Hyper::default();
        let mut policy = QuantPolicy::bit4();
        policy.min_quant_size = 0;
        let shapes: Vec<Vec<usize>> = vec![vec![12, 64], vec![600]];

        let mut opt_a = CompressedAdamW::new(hp, policy);
        let mut pa = mk_params(&shapes);
        for s in 0..6 {
            opt_a.step(&mut pa, &grads_at(&shapes, s), 1e-2);
        }

        let mut opt_b = CompressedAdamW::new(hp, policy);
        let mut pb = mk_params(&shapes);
        for s in 0..3 {
            opt_b.step(&mut pb, &grads_at(&shapes, s), 1e-2);
        }
        let (dir, path) = tmp_base("resume");
        save_params(&path, &pb, 3).unwrap();
        save_opt_state(&format!("{path}_opt"), &opt_b).unwrap();

        let (mut pc, step) = load_params(&path).unwrap();
        assert_eq!(step, 3);
        let mut opt_c = CompressedAdamW::new(hp, policy);
        load_opt_state(&format!("{path}_opt"), &mut opt_c).unwrap();
        assert_eq!(opt_c.t(), 3);
        for s in 3..6 {
            opt_c.step(&mut pc, &grads_at(&shapes, s), 1e-2);
        }

        for (a, c) in pa.iter().zip(pc.iter()) {
            assert_eq!(a.tensor.data, c.tensor.data, "{} diverged after resume", a.name);
        }
        for i in 0..shapes.len() {
            let (m1, v1) = opt_a.moments(i).unwrap();
            let (m2, v2) = opt_c.moments(i).unwrap();
            assert_eq!(m1.data, m2.data, "m[{i}]");
            assert_eq!(v1.data, v2.data, "v[{i}]");
        }
        assert_eq!(opt_a.state_bytes(), opt_c.state_bytes());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupted_section_is_rejected_by_name() {
        // One flipped byte inside a state's codes must fail the section
        // CRC and the error must say *which* section is bad.
        let hp = Hyper::default();
        let mut policy = QuantPolicy::bit4();
        policy.min_quant_size = 0;
        let shapes: Vec<Vec<usize>> = vec![vec![12, 64], vec![600]];
        let mut opt = CompressedAdamW::new(hp, policy);
        let mut params = mk_params(&shapes);
        opt.step(&mut params, &grads_at(&shapes, 0), 1e-2);
        let (dir, path) = tmp_base("crc");
        save_opt_state(&path, &opt).unwrap();
        let bin = format!("{path}.bin");
        let good = std::fs::read(&bin).unwrap();
        let mut evil = good.clone();
        evil[5] ^= 0x40;
        std::fs::write(&bin, &evil).unwrap();
        let mut opt2 = CompressedAdamW::new(hp, policy);
        let err = load_opt_state(&path, &mut opt2).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
        let msg = err.to_string();
        assert!(msg.contains("CRC-32"), "unexpected error: {msg}");
        assert!(msg.contains("m[0]"), "error should name the section: {msg}");

        // Params get the same treatment, named by tensor.
        save_params(&path, &params, 1).unwrap();
        let good = std::fs::read(&bin).unwrap();
        let mut evil = good.clone();
        let last = evil.len() - 1;
        evil[last] ^= 0x01;
        std::fs::write(&bin, &evil).unwrap();
        let err = load_params(&path).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("CRC-32"), "unexpected error: {msg}");
        assert!(msg.contains("tensor 'p1'"), "error should name the tensor: {msg}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn truncated_blob_is_reported_as_truncation() {
        let hp = Hyper::default();
        let mut policy = QuantPolicy::bit4();
        policy.min_quant_size = 0;
        let shapes: Vec<Vec<usize>> = vec![vec![12, 64]];
        let mut opt = CompressedAdamW::new(hp, policy);
        let mut params = mk_params(&shapes);
        opt.step(&mut params, &grads_at(&shapes, 0), 1e-2);
        let (dir, path) = tmp_base("torn");
        save_opt_state(&path, &opt).unwrap();
        let bin = format!("{path}.bin");
        let good = std::fs::read(&bin).unwrap();
        std::fs::write(&bin, &good[..good.len() / 2]).unwrap();
        let mut opt2 = CompressedAdamW::new(hp, policy);
        let err = load_opt_state(&path, &mut opt2).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
        assert!(err.to_string().contains("truncated"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn saves_leave_no_tmp_files() {
        // Atomic writes stage through `.tmp.<pid>` siblings; a completed
        // save must leave only the final `.json` + `.bin` pair.
        let hp = Hyper::default();
        let mut policy = QuantPolicy::bit4();
        policy.min_quant_size = 0;
        let shapes: Vec<Vec<usize>> = vec![vec![12, 64]];
        let mut opt = CompressedAdamW::new(hp, policy);
        let mut params = mk_params(&shapes);
        opt.step(&mut params, &grads_at(&shapes, 0), 1e-2);
        let (dir, path) = tmp_base("atomic");
        save_params(&path, &params, 1).unwrap();
        save_opt_state(&format!("{path}_opt"), &opt).unwrap();
        let names: Vec<String> = std::fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
            .collect();
        assert_eq!(names.len(), 4, "{names:?}");
        assert!(names.iter().all(|n| !n.contains(".tmp")), "{names:?}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn load_opt_state_rejects_policy_mismatch() {
        // A checkpoint saved under one quantization policy must not load
        // into an optimizer built with another — decoding 4-bit codes
        // with an 8-bit policy's tables would corrupt the moments.
        let hp = Hyper::default();
        let mut policy = QuantPolicy::bit4();
        policy.min_quant_size = 0;
        let shapes: Vec<Vec<usize>> = vec![vec![12, 64]];
        let mut opt = CompressedAdamW::new(hp, policy);
        let mut params = mk_params(&shapes);
        opt.step(&mut params, &grads_at(&shapes, 0), 1e-2);
        let (dir, path) = tmp_base("mismatch");
        save_opt_state(&path, &opt).unwrap();
        let mut policy8 = QuantPolicy::bit8();
        policy8.min_quant_size = 0;
        let mut opt8 = CompressedAdamW::new(hp, policy8);
        let err = load_opt_state(&path, &mut opt8).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn opt_state_roundtrips_every_form() {
        // f32 (below min_quant_size), quantized (block + rank-1 + the
        // 1-D fallback) and factored states all round-trip exactly.
        let hp = Hyper::default();
        let mut policy = QuantPolicy::bit4().factored();
        policy.min_quant_size = 1000;
        let shapes: Vec<Vec<usize>> = vec![vec![12, 64], vec![40, 64], vec![3000]];
        let mut opt = CompressedAdamW::new(hp, policy);
        let mut params = mk_params(&shapes);
        for s in 0..2 {
            opt.step(&mut params, &grads_at(&shapes, s), 1e-2);
        }
        let (dir, path) = tmp_base("forms");
        save_opt_state(&path, &opt).unwrap();
        let mut opt2 = CompressedAdamW::new(hp, policy);
        load_opt_state(&path, &mut opt2).unwrap();
        assert_eq!(opt2.t(), 2);
        assert_eq!(opt.state_bytes(), opt2.state_bytes());
        for i in 0..shapes.len() {
            let (m1, v1) = opt.moments(i).unwrap();
            let (m2, v2) = opt2.moments(i).unwrap();
            assert_eq!(m1.data, m2.data, "m[{i}]");
            assert_eq!(v1.data, v2.data, "v[{i}]");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}
