#![forbid(unsafe_code)]
//! The training loop: gradient engine + optimizer + schedule + divergence
//! detection. All paper experiments (tables 1/2/3/6, figure 4) run through
//! [`Trainer::run`]; the "Unstable %" column of Tab. 1 is exactly the
//! fraction of seeds for which [`TrainReport::diverged`] is set.

use crate::data::{ClsBatch, LmBatch};
use crate::optim::{Optimizer, Param};
use crate::tensor::Tensor;
use crate::util::stats::Timer;

/// A gradient engine: anything that can turn (params, batch) into
/// (loss, grads). Implemented by the builtin MLP/transformer engines and
/// by the PJRT runtime.
pub trait GradEngine<B> {
    fn loss_and_grads(&mut self, params: &[Param], batch: &B) -> (f32, Vec<Tensor>);
}

impl<F, B> GradEngine<B> for F
where
    F: FnMut(&[Param], &B) -> (f32, Vec<Tensor>),
{
    fn loss_and_grads(&mut self, params: &[Param], batch: &B) -> (f32, Vec<Tensor>) {
        self(params, batch)
    }
}

/// Learning-rate schedule: linear warmup then linear decay to zero (the
/// paper's fine-tuning recipe) or constant.
#[derive(Clone, Copy, Debug)]
pub enum LrSchedule {
    Constant(f32),
    LinearWarmupDecay {
        peak: f32,
        warmup: usize,
        total: usize,
    },
}

impl LrSchedule {
    pub fn at(&self, step: usize) -> f32 {
        match *self {
            LrSchedule::Constant(lr) => lr,
            LrSchedule::LinearWarmupDecay {
                peak,
                warmup,
                total,
            } => {
                if step < warmup {
                    peak * (step + 1) as f32 / warmup.max(1) as f32
                } else if step >= total {
                    0.0
                } else {
                    peak * (total - step) as f32 / (total - warmup).max(1) as f32
                }
            }
        }
    }
}

/// Divergence detector: training is "unstable" when the loss goes
/// non-finite or exceeds `blowup_factor ×` the initial-window mean after
/// the warmup window (the paper's Tab. 1 notion, made precise).
#[derive(Clone, Copy, Debug)]
pub struct DivergenceRule {
    pub warmup_steps: usize,
    pub blowup_factor: f32,
}

impl Default for DivergenceRule {
    fn default() -> DivergenceRule {
        DivergenceRule {
            warmup_steps: 20,
            blowup_factor: 2.5,
        }
    }
}

/// Outcome of one training run.
#[derive(Clone, Debug)]
pub struct TrainReport {
    pub losses: Vec<f32>,
    pub diverged: bool,
    pub final_loss: f32,
    /// Mean loss over the last 10% of steps (smoother than final_loss).
    pub tail_loss: f32,
    pub steps: usize,
    pub total_seconds: f64,
    pub step_seconds: f64,
    pub state_bytes: usize,
    /// Optimizer steps the loop *skipped* because the step was unsound
    /// to apply — the divergence rule fired (with `stop_on_divergence`
    /// off the run continues, but stepping on a blown loss would push
    /// garbage into the optimizer state) or a gradient came back
    /// non-finite. Always 0 on a healthy run.
    pub skipped_steps: usize,
}

impl TrainReport {
    fn from_losses(
        losses: Vec<f32>,
        diverged: bool,
        total_seconds: f64,
        state_bytes: usize,
    ) -> TrainReport {
        let steps = losses.len();
        let final_loss = losses.last().copied().unwrap_or(f32::NAN);
        let tail_n = (steps / 10).max(1).min(steps.max(1));
        let tail_loss = if steps == 0 {
            f32::NAN
        } else {
            losses[steps - tail_n..].iter().sum::<f32>() / tail_n as f32
        };
        TrainReport {
            losses,
            diverged,
            final_loss,
            tail_loss,
            steps,
            total_seconds,
            step_seconds: if steps > 0 {
                total_seconds / steps as f64
            } else {
                0.0
            },
            state_bytes,
            skipped_steps: 0,
        }
    }
}

/// Generic trainer over any batch type / engine / sampler.
pub struct Trainer {
    pub schedule: LrSchedule,
    pub divergence: DivergenceRule,
    pub steps: usize,
    /// Stop early on divergence (keeps ablation sweeps fast).
    pub stop_on_divergence: bool,
    /// Print the optimizer's unified [`StepReport`] every this many
    /// steps (`obs::report`; scheduler counters, offload totals, span
    /// summaries, quant metrics). `0` disables the cadence printing.
    pub report_every: usize,
}

impl Trainer {
    pub fn new(steps: usize, schedule: LrSchedule) -> Trainer {
        Trainer {
            schedule,
            divergence: DivergenceRule::default(),
            steps,
            stop_on_divergence: true,
            report_every: 0,
        }
    }

    /// Set the [`Self::report_every`] cadence (0 = off).
    pub fn with_report_every(mut self, every: usize) -> Trainer {
        self.report_every = every;
        self
    }

    /// Run the loop. `sampler(step)` provides the batch for each step.
    pub fn run<B>(
        &self,
        params: &mut Vec<Param>,
        opt: &mut dyn Optimizer,
        engine: &mut dyn GradEngine<B>,
        mut sampler: impl FnMut(usize) -> B,
    ) -> TrainReport {
        let timer = Timer::start();
        let mut losses = Vec::with_capacity(self.steps);
        let mut diverged = false;
        let mut skipped = 0usize;
        let mut ref_loss = f32::NAN;
        for step in 0..self.steps {
            let batch = sampler(step);
            let (loss, grads) = engine.loss_and_grads(params, &batch);
            losses.push(loss);
            if step + 1 == self.divergence.warmup_steps.max(1) {
                ref_loss = losses.iter().sum::<f32>() / losses.len() as f32;
            }
            let blown = !loss.is_finite()
                || (step >= self.divergence.warmup_steps
                    && ref_loss.is_finite()
                    && loss > ref_loss * self.divergence.blowup_factor)
                || params.iter().any(|p| p.tensor.any_nonfinite());
            if blown {
                diverged = true;
                if self.stop_on_divergence {
                    break;
                }
            }
            // A blown step (continuing past divergence) or a non-finite
            // gradient must not reach the optimizer: NaN/inf would
            // poison the moments — and through them every later step —
            // even if the loss itself recovers. Skip and count instead.
            if blown || grads.iter().any(|g| g.any_nonfinite()) {
                skipped += 1;
                continue;
            }
            let lr = self.schedule.at(step);
            opt.step(params, &grads, lr);
            if self.report_every > 0 && (step + 1) % self.report_every == 0 {
                if let Some(rep) = opt.step_report() {
                    println!("{}", rep.render());
                }
            }
        }
        export_trace_env(opt);
        let mut report =
            TrainReport::from_losses(losses, diverged, timer.seconds(), opt.state_bytes());
        report.skipped_steps = skipped;
        report
    }
}

/// When `LOWBIT_TRACE=path.json` is set, write the optimizer's recorded
/// spans there as a chrome://tracing document (load in `chrome://tracing`
/// or Perfetto). Called at the end of every [`Trainer::run`]; a silent
/// no-op when the variable is unset, with a stderr note (never a panic)
/// when it is set but the build lacks `--features trace` or the write
/// fails.
pub fn export_trace_env(opt: &dyn Optimizer) {
    let Ok(path) = std::env::var("LOWBIT_TRACE") else {
        return;
    };
    if path.is_empty() {
        return;
    }
    match opt.export_trace() {
        Some(doc) => {
            if let Err(e) = std::fs::write(&path, doc.to_string()) {
                eprintln!("LOWBIT_TRACE: cannot write {path}: {e}");
            }
        }
        None => eprintln!(
            "LOWBIT_TRACE is set but this optimizer records no spans \
             (build with --features trace)"
        ),
    }
}

/// Convenience samplers -----------------------------------------------

/// Build an LM batch sampler from a corpus closure.
pub fn lm_sampler<'a>(
    mut f: impl FnMut(usize) -> LmBatch + 'a,
) -> impl FnMut(usize) -> LmBatch + 'a {
    move |s| f(s)
}

/// Build a classification sampler.
pub fn cls_sampler<'a>(
    mut f: impl FnMut(usize) -> ClsBatch + 'a,
) -> impl FnMut(usize) -> ClsBatch + 'a {
    move |s| f(s)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::ClusterData;
    use crate::model::MlpConfig;
    use crate::optim::{build, Hyper};
    use crate::train::mlp::MlpEngine;
    use crate::util::rng::Pcg64;

    #[test]
    fn schedule_shapes() {
        let s = LrSchedule::LinearWarmupDecay {
            peak: 1.0,
            warmup: 10,
            total: 110,
        };
        assert!(s.at(0) > 0.0 && s.at(0) < 0.2);
        assert!((s.at(9) - 1.0).abs() < 1e-6);
        assert!(s.at(60) < 1.0 && s.at(60) > 0.0);
        assert_eq!(s.at(110), 0.0);
        assert_eq!(LrSchedule::Constant(0.5).at(1000), 0.5);
    }

    #[test]
    fn trainer_trains_mlp_and_reports() {
        let cfg = MlpConfig::tiny();
        let data = ClusterData::new(cfg.d_in, cfg.n_classes, 3);
        let mut rng = Pcg64::seeded(0);
        let mut params = cfg.init_params(&mut rng);
        let mut opt = build("adamw4", Hyper::default()).unwrap();
        let engine = MlpEngine::new(cfg);
        let mut engine_fn =
            |p: &[Param], b: &crate::data::ClsBatch| engine.loss_and_grads(p, b);
        let trainer = Trainer::new(80, LrSchedule::Constant(3e-3));
        let mut sample_rng = Pcg64::seeded(1);
        let report = trainer.run(&mut params, opt.as_mut(), &mut engine_fn, |_| {
            data.sample(16, &mut sample_rng)
        });
        assert!(!report.diverged);
        assert_eq!(report.steps, 80);
        assert!(report.final_loss < report.losses[0]);
        assert!(report.state_bytes > 0);
        assert!(report.step_seconds > 0.0);
    }

    #[test]
    fn divergence_detection_fires_on_nan() {
        let mut params = vec![Param::new(
            "w",
            crate::optim::ParamKind::Weight,
            Tensor::zeros(&[4]),
        )];
        let mut opt = build("adamw32", Hyper::default()).unwrap();
        let mut engine_fn = |_: &[Param], s: &usize| {
            let loss = if *s > 5 { f32::NAN } else { 1.0 };
            (loss, vec![Tensor::zeros(&[4])])
        };
        let trainer = Trainer::new(50, LrSchedule::Constant(1e-3));
        let report = trainer.run(&mut params, opt.as_mut(), &mut engine_fn, |s| s);
        assert!(report.diverged);
        assert!(report.steps < 50, "stopped early at {}", report.steps);
    }

    #[test]
    fn blown_or_nonfinite_steps_are_skipped_not_applied() {
        // Continuing past divergence (stop_on_divergence = false) must
        // not feed NaN losses/grads into the optimizer: the moments
        // would go NaN and stay NaN. The loop skips those steps, counts
        // them, and the optimizer's step counter only advances for the
        // applied ones.
        let mut params = vec![Param::new(
            "w",
            crate::optim::ParamKind::Weight,
            Tensor::zeros(&[4]),
        )];
        let mut opt = build("adamw32", Hyper::default()).unwrap();
        let mut engine_fn = |_: &[Param], s: &usize| {
            if *s % 3 == 2 {
                // Bad step: NaN loss AND a non-finite gradient.
                (f32::NAN, vec![Tensor::full(&[4], f32::INFINITY)])
            } else {
                (1.0, vec![Tensor::full(&[4], 0.01)])
            }
        };
        let mut trainer = Trainer::new(30, LrSchedule::Constant(1e-3));
        trainer.stop_on_divergence = false;
        let report = trainer.run(&mut params, opt.as_mut(), &mut engine_fn, |s| s);
        assert!(report.diverged);
        assert_eq!(report.steps, 30);
        assert_eq!(report.skipped_steps, 10);
        assert_eq!(opt.t(), 20, "only clean steps reach the optimizer");
        assert!(
            !params[0].tensor.any_nonfinite(),
            "weights stayed finite through skipped steps"
        );

        // And a fully healthy run skips nothing.
        let mut opt2 = build("adamw32", Hyper::default()).unwrap();
        let mut clean = |_: &[Param], _: &usize| (1.0, vec![Tensor::full(&[4], 0.01)]);
        let mut p2 = vec![Param::new(
            "w",
            crate::optim::ParamKind::Weight,
            Tensor::zeros(&[4]),
        )];
        let report2 = trainer.run(&mut p2, opt2.as_mut(), &mut clean, |s| s);
        assert_eq!(report2.skipped_steps, 0);
        assert_eq!(opt2.t(), 30);
    }

    #[test]
    fn divergence_detection_fires_on_blowup() {
        let mut params = vec![Param::new(
            "w",
            crate::optim::ParamKind::Weight,
            Tensor::zeros(&[4]),
        )];
        let mut opt = build("adamw32", Hyper::default()).unwrap();
        let mut engine_fn = |_: &[Param], s: &usize| {
            let loss = if *s > 30 { 100.0 } else { 1.0 };
            (loss, vec![Tensor::zeros(&[4])])
        };
        let trainer = Trainer::new(60, LrSchedule::Constant(1e-3));
        let report = trainer.run(&mut params, opt.as_mut(), &mut engine_fn, |s| s);
        assert!(report.diverged);
    }
}
